"""Fault-tolerance example: node crash mid-run + elastic checkpoint restart.

Part 1 — protocol level: a replica crashes during a Lilac-TM run; the
view-synchronous membership reclaims its leases and the survivors keep
committing (throughput before/after shown).

Part 2 — training level: a run checkpoints asynchronously, "loses" half
its devices, re-meshes with :mod:`repro.train.elastic` and resumes from
the last committed step with re-sharded state.

    PYTHONPATH=src python examples/failover_recovery.py
"""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import BankWorkload, SimConfig, make_cluster
from repro.train import checkpoint, elastic


def part1_protocol():
    print("== 1. Replica crash under Lilac-TM ==")
    cfg = SimConfig(duration_ms=800.0, warmup_ms=100.0)
    wl = BankWorkload(n_nodes=4, n_items=cfg.n_items, locality=0.5)
    c = make_cluster("LILAC-TM-ST", wl, cfg)
    c.events.schedule(300.0, lambda: c.gcs.fail(3))
    m = c.run()
    pre = m.throughput(100.0, 300.0)
    post = m.throughput(400.0, 800.0)
    print(f"  throughput before crash : {pre:8.0f} txn/s (4 nodes)")
    print(f"  throughput after crash  : {post:8.0f} txn/s (3 nodes)")
    zombie = sum(1 for r in c.replicas[:3] for q in r.lm.cq for l in q
                 if l.proc == 3)
    print(f"  leases of the dead node left in survivor queues: {zombie}")
    assert zombie == 0 and post > 0.5 * pre


def part2_elastic():
    print("\n== 2. Elastic checkpoint restart ==")
    state = {"w": jax.random.normal(jax.random.PRNGKey(0), (64, 64)),
             "step_count": jnp.int32(0)}
    with tempfile.TemporaryDirectory() as d:
        writer = checkpoint.AsyncCheckpointer(d)
        for step in range(1, 31):
            state = {"w": state["w"] * 0.999, "step_count": jnp.int32(step)}
            if step % 10 == 0:
                writer.submit(step, state)
        writer.close()
        print(f"  committed checkpoints: {checkpoint.committed_steps(d)}")

        # "lose" devices: re-mesh on the survivors and restore re-sharded
        survivors = jax.devices()  # 1 on CPU; the plan API is device-count agnostic
        plan = elastic.plan_remesh(len(survivors), model_size=1)
        state2, step2, mesh = elastic.resume_after_failure(
            d, state, survivors, model_size=1,
            make_shardings=lambda mesh: jax.tree.map(
                lambda _: jax.sharding.NamedSharding(
                    mesh, jax.sharding.PartitionSpec()), state),
        )
        print(f"  resumed at step {step2} on mesh {plan.mesh_shape}; "
              f"w matches: {np.allclose(np.asarray(state2['w']), np.asarray(state['w']))}")
        assert step2 == 30


if __name__ == "__main__":
    part1_protocol()
    part2_elastic()
    print("\nok")
