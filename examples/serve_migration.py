"""Serving example: real decode across 2 pods with session migration.

A small model decodes real tokens; the locality router decides per request
whether to forward it to the session's owner pod or to migrate the KV
cache.  Watch a session physically move pods (its cache column is
exported/imported) and decoding stay bit-consistent.

    PYTHONPATH=src python examples/serve_migration.py
"""
import dataclasses

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.models import decoder
from repro.models.common import init_params
from repro.serve.engine import MultiPodEngine, RealBackend, Request
from repro.serve.router import LocalityRouter


def main():
    cfg = dataclasses.replace(get_smoke_config("glm4-9b"), dtype="float32")
    ctx = decoder.RunCtx(mesh=None, use_kernel="ref")
    params = init_params(cfg, jax.random.PRNGKey(0))
    backend = RealBackend(cfg, ctx, params, n_pods=2, n_slots=8, max_len=96)
    router = LocalityRouter(2, policy="short", kv_bytes_per_token=64.0)
    eng = MultiPodEngine(2, backend, router)

    rng = np.random.default_rng(0)
    print("step  sid  origin -> target  action    home")
    for step in range(10):
        sid = int(rng.integers(4))
        origin = sid % 2 if rng.random() < 0.6 else int(rng.integers(2))
        dec = eng.submit(Request(sid=sid, origin=origin, n_tokens=3))
        print(f"{step:4d}  {sid:3d}  {origin} -> {dec.target}        "
              f"{dec.action:8s}  {eng.session_home}")
        eng.run_step()
    eng.drain()
    m = eng.metrics.as_dict()
    print(f"\ndecoded {m['tokens']} tokens; forwards={m['forwards']} "
          f"KV-migrations={m['transfers']} "
          f"lease-reuse={router.metrics.lease_reuse_rate:.2f}")
    for pod, store in enumerate(backend.stores):
        print(f"pod {pod}: sessions={sorted(store.sessions)} ")


if __name__ == "__main__":
    main()
