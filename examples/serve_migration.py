"""Serving example: real decode across 2 pods with session migration.

Phase 1 — reactive routing: a small model decodes real tokens; the
locality router decides per request whether to forward it to the session's
owner pod or to migrate the KV cache.  Watch a session physically move
pods (its cache column is exported/imported) and decoding stay
bit-consistent.

Phase 2 — proactive planning: a hot session keeps arriving at the "wrong"
pod in bursts.  The reactive router forwards every one of those requests
(the KV outweighs the work description, so the byte verdict never
acquires).  With a :class:`repro.plan.PlacementPlanner` attached, the
affinity loop notices the dominant origin between bursts and *prefetches*
the session to it — before the next burst arrives, off the request path —
after which the burst decodes locally.

    PYTHONPATH=src python examples/serve_migration.py
"""
import dataclasses

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.models import decoder
from repro.models.common import init_params
from repro.plan import PlacementPlanner, PlanConfig
from repro.serve.engine import MultiPodEngine, RealBackend, Request
from repro.serve.router import LocalityRouter


def reactive_phase(cfg, params):
    ctx = decoder.RunCtx(mesh=None, use_kernel="ref")
    backend = RealBackend(cfg, ctx, params, n_pods=2, n_slots=8, max_len=96)
    router = LocalityRouter(2, policy="short", kv_bytes_per_token=64.0)
    eng = MultiPodEngine(2, backend, router)

    rng = np.random.default_rng(0)
    print("phase 1 — reactive routing")
    print("step  sid  origin -> target  action    home")
    for step in range(10):
        sid = int(rng.integers(4))
        origin = sid % 2 if rng.random() < 0.6 else int(rng.integers(2))
        dec = eng.submit(Request(sid=sid, origin=origin, n_tokens=3))
        print(f"{step:4d}  {sid:3d}  {origin} -> {dec.target}        "
              f"{dec.action:8s}  {eng.session_home}")
        eng.run_step()
    eng.drain()
    m = eng.metrics.as_dict()
    print(f"\ndecoded {m['tokens']} tokens; forwards={m['forwards']} "
          f"KV-migrations={m['transfers']} "
          f"lease-reuse={router.metrics.lease_reuse_rate:.2f}")
    for pod, store in enumerate(backend.stores):
        print(f"pod {pod}: sessions={sorted(store.sessions)} ")


def planner_phase(cfg, params):
    ctx = decoder.RunCtx(mesh=None, use_kernel="ref")
    backend = RealBackend(cfg, ctx, params, n_pods=2, n_slots=8, max_len=96)
    # heavy per-token KV: the byte verdict always forwards, so only the
    # planner can fix the placement
    router = LocalityRouter(2, policy="short", arbitration="priced",
                            kv_bytes_per_token=8192.0)
    planner = PlacementPlanner(
        2, 8, PlanConfig(epoch_ms=2.0, top_k=2, min_events=3.0,
                         min_frac=0.6, margin=0.5, hysteresis_epochs=2),
        grow=True)
    eng = MultiPodEngine(2, backend, router, planner=planner)

    print("\nphase 2 — proactive planning (hot session, bursty origin)")
    eng.submit(Request(sid=0, origin=1, n_tokens=2))   # first lands on pod 1
    eng.run_step()
    print(f"burst 0 from pod 1: owner={router.owner[0]} (misplaced for "
          f"the bursts that follow)")
    for burst in range(3):
        for _ in range(4):
            dec = eng.submit(Request(sid=0, origin=0, n_tokens=1))
            eng.run_step()
        print(f"burst {burst + 1} from pod 0: owner={router.owner[0]} "
              f"last_action={dec.action:8s} "
              f"planned_moves={router.metrics.planned_moves}")
        for _ in range(3):                 # idle gap: the planner epoch fires
            eng.run_step()
    eng.drain()
    m = eng.metrics.as_dict()
    print(f"planner: epochs={m['plan_epochs']} re-homes={m['plan_moves']} "
          f"prefetches={m['plan_prefetches']} — session 0 now decodes "
          f"locally at pod {router.owner[0]} "
          f"(reactive acquires: {router.metrics.acquires})")


def main():
    cfg = dataclasses.replace(get_smoke_config("glm4-9b"), dtype="float32")
    params = init_params(cfg, jax.random.PRNGKey(0))
    reactive_phase(cfg, params)
    planner_phase(cfg, params)


if __name__ == "__main__":
    main()
