"""Quickstart: the paper's contribution in three minutes.

1. Runs the Bank benchmark on the 4-replica cluster simulator under the
   baseline ALC protocol and under Lilac-TM (fine-grained leases +
   transaction migration), at low and high data locality.
2. Shows the same decision machinery routing requests in the multi-pod
   serving engine.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import BankWorkload, SimConfig, make_cluster
from repro.serve.engine import MultiPodEngine, Request, SimBackend
from repro.serve.router import LocalityRouter


def part1_cluster():
    print("== 1. Replicated STM cluster (paper §4, Bank benchmark) ==")
    print(f"{'algorithm':14s} {'P=0.2':>10s} {'P=0.95':>10s}   lease-reuse @0.95")
    for algo in ("ALC", "FGL", "LILAC-TM-ST"):
        row = [algo]
        for P in (0.2, 0.95):
            cfg = SimConfig(duration_ms=600.0, warmup_ms=100.0)
            wl = BankWorkload(n_nodes=4, n_items=cfg.n_items, locality=P)
            c = make_cluster(algo, wl, cfg)
            m = c.run()
            row.append(f"{c.throughput():8.0f}/s")
            reuse = m.lease_reuse_rate()
        print(f"{row[0]:14s} {row[1]:>10s} {row[2]:>10s}   {reuse:.2f}")
    print()


def part2_serving():
    print("== 2. Same decision, serving layer: migrate request vs move KV ==")
    from repro.configs import get_config

    cfg = get_config("mixtral-8x7b")
    for P in (0.2, 0.95):
        router = LocalityRouter(4, policy="short",
                                kv_bytes_per_token=2048.0 * cfg.n_layers)
        eng = MultiPodEngine(4, SimBackend(cfg), router)
        rng = np.random.default_rng(0)
        for _ in range(30):
            for _ in range(8):
                sid = int(rng.integers(48))
                origin = sid % 4 if rng.random() < P else int(rng.integers(4))
                eng.submit(Request(sid=sid, origin=origin, n_tokens=4))
            eng.run_step()
        eng.drain()
        m = eng.metrics.as_dict()
        print(f"  locality={P}: {m['tokens_per_s']:9.0f} tok/s  "
              f"wire={m['wire_GB']:.2f} GB  forwards={m['forwards']}  "
              f"KV-moves={m['transfers']}  reuse={router.metrics.lease_reuse_rate:.2f}")
    print()


if __name__ == "__main__":
    part1_cluster()
    part2_serving()
    print("done — see benchmarks/ for the full paper evaluation.")
