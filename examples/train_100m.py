"""End-to-end training example: ~100M-parameter model, real loop.

Uses the production train driver (data pipeline -> jitted/donated train
step -> async checkpoints -> resume) on a scaled-down glm4-family config.

    PYTHONPATH=src python examples/train_100m.py [--steps 300]

Any assigned architecture works: ``--arch mixtral-8x7b`` trains the scaled
MoE variant, ``--arch mamba2-780m`` the SSD variant, etc.
"""
import argparse
import sys

from repro.launch import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="glm4-9b")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_100m_ckpt")
    args = ap.parse_args()

    out = train.main([
        "--arch", args.arch, "--preset", "p100m",
        "--steps", str(args.steps), "--batch", str(args.batch),
        "--seq", str(args.seq), "--ckpt-dir", args.ckpt_dir,
        "--ckpt-every", "50", "--resume",
    ])
    print(f"first loss {out['first_loss']:.4f} -> last loss {out['last_loss']:.4f}")
    assert out["last_loss"] < out["first_loss"], "loss did not improve"


if __name__ == "__main__":
    main()
