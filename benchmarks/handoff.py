"""Pipelined vs drain handoff sweep — the default-promotion acceptance bench.

PR 8's explorer model-checked ``handoff="pipelined"`` (Zeus-style overlap
of the lease-request round with transaction execution) violation-free
across all legal delivery interleavings; this bench is the perf leg that
justified flipping the :class:`repro.core.SimConfig` default.  It runs the
bank cells over the locality × contention grid for the drain-sensitive
algorithm variants and compares simulated throughput under both handoffs.

Simulated metrics are deterministic per (algo, locality, threads, seed)
cell, so the acceptance bands are tight:

* every cell: ``pipelined >= MIN_CELL_RATIO × drain`` (a noise floor just
  under parity — the overlap can never *cost* throughput, but ties at
  uncontended cells land within scheduler-ordering jitter);
* grid mean: ``pipelined >= drain`` — the wins at contended high-locality
  cells (where the owner's drain is longest) must survive averaging.

Writes a ``BENCH_handoff.json`` artifact (``results/BENCH_handoff.json``
tracks a full run in-repo; ``benchmarks/run.py --check`` re-validates the
committed numbers).  ``--smoke`` shrinks the grid for CI.
"""
from __future__ import annotations

import argparse
import json
from typing import Dict, List

from repro.core import BankWorkload, SimConfig, make_cluster

DEFAULT_ALGOS = ["FGL", "LILAC-TM-OPT"]
MIN_CELL_RATIO = 0.99   # noise floor: ties may jitter a hair under parity
HANDOFFS = ("drain", "pipelined")


def run_cell(algo: str, locality: float, threads: int, handoff: str,
             duration: float, seed: int = 0) -> Dict[str, float]:
    cfg = SimConfig(duration_ms=duration, warmup_ms=duration * 0.15,
                    threads_per_node=threads, seed=seed, handoff=handoff)
    wl = BankWorkload(n_nodes=cfg.n_nodes, n_items=cfg.n_items,
                      locality=locality)
    c = make_cluster(algo, wl, cfg)
    m = c.run()
    return {
        "throughput": c.throughput(),
        "reuse": m.lease_reuse_rate(),
        "forwards": m.forwards,
        "aborts": m.aborts,
    }


def sweep(algos: List[str], localities: List[float], threads: List[int],
          duration: float, seed: int) -> List[Dict]:
    rows = []
    print("algo,locality,threads,handoff,throughput_txn_s,reuse,forwards,"
          "aborts,ratio_vs_drain")
    for algo in algos:
        for p in localities:
            for th in threads:
                cell = {}
                for h in HANDOFFS:
                    cell[h] = run_cell(algo, p, th, h, duration, seed)
                base = max(cell["drain"]["throughput"], 1e-9)
                for h in HANDOFFS:
                    r = cell[h]
                    ratio = r["throughput"] / base
                    rows.append({"algo": algo, "locality": p, "threads": th,
                                 "handoff": h, "ratio_vs_drain": ratio, **r})
                    print(f"{algo},{p},{th},{h},{r['throughput']:.1f},"
                          f"{r['reuse']:.4f},{r['forwards']},{r['aborts']},"
                          f"{ratio:.4f}", flush=True)
    return rows


def check(rows: List[Dict]) -> None:
    pipe = [r for r in rows if r["handoff"] == "pipelined"]
    assert pipe, "no pipelined rows"
    worst = min(pipe, key=lambda r: r["ratio_vs_drain"])
    assert worst["ratio_vs_drain"] >= MIN_CELL_RATIO, (
        f"pipelined below drain at {worst['algo']} P={worst['locality']} "
        f"th={worst['threads']}: ratio {worst['ratio_vs_drain']:.4f} < "
        f"{MIN_CELL_RATIO}")
    mean = sum(r["ratio_vs_drain"] for r in pipe) / len(pipe)
    assert mean >= 1.0, f"grid mean ratio {mean:.4f} < 1.0"
    print(f"check ok: pipelined >= {MIN_CELL_RATIO:.2f}x drain on every "
          f"cell (worst {worst['ratio_vs_drain']:.4f}), grid mean "
          f"{mean:.4f}x")


def main(argv=None) -> List[Dict]:
    ap = argparse.ArgumentParser()
    ap.add_argument("--algos", nargs="*", default=DEFAULT_ALGOS)
    ap.add_argument("--localities", nargs="*", type=float,
                    default=[0.0, 0.5, 0.9])
    ap.add_argument("--threads", nargs="*", type=int, default=[2, 4])
    ap.add_argument("--duration", type=float, default=800.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny grid for CI: FGL only, 2 cells")
    ap.add_argument("--check", action="store_true",
                    help="enforce the pipelined >= drain bands")
    ap.add_argument("--out", default="BENCH_handoff.json")
    args = ap.parse_args(argv)
    if args.smoke:
        args.algos = ["FGL"]
        args.localities = [0.0, 0.9]
        args.threads = [2]
        args.duration = 400.0

    rows = sweep(args.algos, args.localities, args.threads, args.duration,
                 args.seed)
    art = {
        "bench": "handoff", "algos": args.algos,
        "localities": args.localities, "threads": args.threads,
        "duration_ms": args.duration, "seed": args.seed,
        "smoke": args.smoke, "min_cell_ratio": MIN_CELL_RATIO,
        "rows": rows,
    }
    with open(args.out, "w") as f:
        json.dump(art, f, indent=2)
    print(f"wrote {args.out}")
    if args.check:
        check(rows)
    return rows


if __name__ == "__main__":
    main()
