"""TPC-C benchmark — paper Fig. 4 (95% Payment / 5% New-Order).

Geographically load-balanced injection with a 0.2 misroute rate; prints
throughput over time per algorithm to expose policy convergence.
"""
from __future__ import annotations

import argparse
from typing import Dict, List

from repro.core import (SimConfig, TpccConflictMap, TpccLayout, TpccWorkload,
                        make_cluster)

ALGOS = ["ALC", "FGL", "LILAC-TM-ST", "LILAC-TM-LT"]


def run_algo(algo: str, *, duration: float = 1500.0, threads: int = 2,
             seed: int = 0) -> Dict:
    lay = TpccLayout(n_nodes=4)
    ccmap = TpccConflictMap(lay)
    cfg = SimConfig(duration_ms=duration, warmup_ms=150.0,
                    threads_per_node=threads, n_items=lay.n_items,
                    n_classes=ccmap.n_classes, seed=seed)
    c = make_cluster(algo, TpccWorkload(lay), cfg, ccmap=ccmap)
    m = c.run()
    series = [
        (t0, m.throughput(t0, t0 + 150.0))
        for t0 in range(0, int(duration) - 150, 150)
    ]
    return {
        "series": series,
        "throughput": c.throughput(),
        "reuse": m.lease_reuse_rate(),
        "lease_requests_per_s": m.lease_requests / (duration / 1e3),
    }


def main(argv=None) -> List[Dict]:
    ap = argparse.ArgumentParser()
    ap.add_argument("--threads", type=int, default=2)
    ap.add_argument("--duration", type=float, default=1500.0)
    args = ap.parse_args(argv)

    rows = []
    print("algo,t_ms,throughput_txn_s")
    summaries = []
    for algo in ALGOS:
        r = run_algo(algo, duration=args.duration, threads=args.threads)
        for (t, thr) in r["series"]:
            print(f"{algo},{t},{thr:.1f}")
        summaries.append((algo, r))
        rows.append({"algo": algo, **r})
    print("\nalgo,throughput_txn_s,lease_reuse,lease_req_per_s")
    base = summaries[0][1]["throughput"]
    for (algo, r) in summaries:
        print(f"{algo},{r['throughput']:.1f},{r['reuse']:.4f},"
              f"{r['lease_requests_per_s']:.1f}  (x{r['throughput']/base:.2f} vs ALC)")
    return rows


if __name__ == "__main__":
    main()
