"""Schedule-space explorer throughput: schedules/s and reduction ratios.

Runs the exploration grid the CI smoke job runs (plus, off-smoke, a larger
sweep over commutation windows) and reports the model-checking economics:

* ``schedules/s``       — completed re-executions per second;
* ``states_deduped``    — continuations cut by the protocol-state
  fingerprint (repro.analysis.fingerprint);
* ``pruned_sleep``      — runs cut by sleep-set partial-order reduction;
* ``reduction``         — naive enumeration runs / POR+dedup runs on the
  same cell (how much of the interleaving space the reductions prove
  redundant instead of executing).

Informational benchmark: the artifact is NOT wired into the
``benchmarks.run --check`` tolerance gates (wall-clock of a model checker
is machine-noise); the correctness side lives in ``repro-explore --smoke
--check`` and tests/test_explore*.py.  ``--check`` here enforces only the
structural floor: every smoke cell green and reduction >= 2x.
"""
from __future__ import annotations

import argparse
import json
import time
from dataclasses import replace
from typing import Dict, List

from repro.analysis.explore import (SMOKE_CELLS, ExploreStats,
                                    _explore_exhaustive, _smoke_build,
                                    explore_scenario)


def bench_cell(name: str, args: Dict, cfg, *, naive: bool = False) -> Dict:
    t0 = time.perf_counter()
    if naive:
        stats = ExploreStats()
        base = replace(cfg, por=False, dedup=False, minimize=False)
        _explore_exhaustive(lambda pol: _smoke_build(name, args, pol),
                            base, stats)
        ok = True
    else:
        res = explore_scenario(name, cfg, args)
        stats, ok = res.stats, res.ok
    dt = time.perf_counter() - t0
    return {
        "scenario": name, "args": dict(args), "strategy": cfg.strategy,
        "window_ms": cfg.window_ms, "naive": naive, "ok": ok,
        "schedules": stats.schedules, "pruned_sleep": stats.pruned_sleep,
        "states_deduped": stats.states_deduped, "branches": stats.branches,
        "decisions": stats.decisions, "truncated": stats.truncated,
        "runs": stats.runs, "wall_s": round(dt, 4),
        "schedules_per_s": round(stats.schedules / dt, 1) if dt else 0.0,
        "runs_per_s": round(stats.runs / dt, 1) if dt else 0.0,
    }


def main(argv=None) -> Dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI grid only (the repro-explore --smoke cells)")
    ap.add_argument("--check", action="store_true",
                    help="fail unless all cells green and reduction >= 2x")
    ap.add_argument("--out", default="BENCH_explore.json")
    ns = ap.parse_args(argv)

    cells = list(SMOKE_CELLS)
    if not ns.smoke:
        # off-smoke: sweep the commutation window on the first bank cell
        name, args, cfg = SMOKE_CELLS[0]
        cells += [(name, {**args, "seed": 1},
                   replace(cfg, window_ms=w)) for w in (0.2, 0.8)]

    rows: List[Dict] = []
    for name, args, cfg in cells:
        row = bench_cell(name, args, cfg)
        rows.append(row)
        print(f"{name} {args}: {row['schedules']} schedules "
              f"({row['pruned_sleep']} sleep-pruned, "
              f"{row['states_deduped']} deduped) in {row['wall_s']}s "
              f"-> {row['runs_per_s']} runs/s"
              f"{' TRUNCATED' if row['truncated'] else ''}"
              f"{'' if row['ok'] else ' VIOLATION'}")

    # reduction ratio: naive enumeration vs POR+dedup on the first cell
    name, args, cfg = SMOKE_CELLS[0]
    nrow = bench_cell(name, args, cfg, naive=True)
    rows.append(nrow)
    reduced = next(r for r in rows if not r["naive"])
    reduction = nrow["runs"] / max(1, reduced["runs"])
    print(f"reduction: naive {nrow['runs']} runs vs {reduced['runs']} "
          f"POR+dedup -> {reduction:.1f}x")

    out = {"bench": "explore", "smoke": bool(ns.smoke),
           "reduction": round(reduction, 2), "rows": rows}
    with open(ns.out, "w") as f:
        json.dump(out, f, indent=2)
    print(f"wrote {ns.out}")

    if ns.check:
        bad = [r for r in rows if not r["naive"] and
               (not r["ok"] or (r["strategy"] == "exhaustive"
                                and r["truncated"]))]
        assert not bad, f"exploration cells failed: {bad}"
        assert reduction >= 2.0, \
            f"POR+dedup reduction {reduction:.2f}x below 2x floor"
        print("check ok: all cells green, reduction >= 2x")
    return out


if __name__ == "__main__":
    main()
