"""Bank benchmark — paper Fig. 3(a) throughput + Fig. 3(b) lease reuse.

Sweeps the locality parameter P for all six algorithm variants and prints
CSV.  ``--threads 4`` reproduces the appendix (Fig. 5) run.
"""
from __future__ import annotations

import argparse
from typing import Dict, List

from repro.core import ALGORITHMS, BankWorkload, SimConfig, make_cluster

DEFAULT_ALGOS = ["ALC", "FGL", "MG-ALC", "LILAC-TM-ST", "LILAC-TM-LT",
                 "LILAC-TM-OPT"]


def run_point(algo: str, locality: float, threads: int, duration: float,
              seed: int = 0) -> Dict[str, float]:
    cfg = SimConfig(duration_ms=duration, warmup_ms=duration * 0.15,
                    threads_per_node=threads, seed=seed)
    wl = BankWorkload(n_nodes=cfg.n_nodes, n_items=cfg.n_items,
                      locality=locality)
    c = make_cluster(algo, wl, cfg)
    m = c.run()
    return {
        "throughput": c.throughput(),
        "reuse": m.lease_reuse_rate(),
        "lease_requests": m.lease_requests,
        "forwards": m.forwards,
        "aborts": m.aborts,
    }


def main(argv=None) -> List[Dict]:
    ap = argparse.ArgumentParser()
    ap.add_argument("--threads", type=int, default=2)
    ap.add_argument("--duration", type=float, default=1500.0)
    ap.add_argument("--algos", nargs="*", default=DEFAULT_ALGOS)
    ap.add_argument("--localities", nargs="*", type=float,
                    default=[0.0, 0.2, 0.4, 0.6, 0.8, 0.9, 1.0])
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    rows = []
    print("algo,locality,threads,throughput_txn_s,lease_reuse_rate,"
          "lease_requests,forwards,aborts")
    for algo in args.algos:
        for p in args.localities:
            r = run_point(algo, p, args.threads, args.duration, args.seed)
            rows.append({"algo": algo, "locality": p, **r})
            print(f"{algo},{p},{args.threads},{r['throughput']:.1f},"
                  f"{r['reuse']:.4f},{r['lease_requests']},{r['forwards']},"
                  f"{r['aborts']}", flush=True)
    return rows


if __name__ == "__main__":
    main()
