"""Roofline report: renders the dry-run JSON cells into the §Roofline table."""
from __future__ import annotations

import argparse
import glob
import json
from pathlib import Path
from typing import Dict, List

RESULTS = Path(__file__).resolve().parents[1] / "results" / "dryrun"


def load_cells(mesh: str = None, tag: str = "") -> List[Dict]:
    cells = []
    for f in sorted(glob.glob(str(RESULTS / "*.json"))):
        d = json.load(open(f))
        if mesh and d.get("mesh") != mesh:
            continue
        if (d.get("tag") or "") != tag:
            continue
        cells.append(d)
    return cells


def render(cells: List[Dict]) -> str:
    out = []
    hdr = (f"{'arch':<18}{'shape':<13}{'mesh':<11}{'status':<7}"
           f"{'t_comp':>9}{'t_mem':>9}{'t_coll':>9} {'dominant':<11}"
           f"{'rf':>6}{'useful':>8}{'fits':>6}")
    out.append(hdr)
    out.append("-" * len(hdr))
    for d in cells:
        if d["status"] != "OK":
            reason = d.get("skip_reason", d.get("error", ""))[:46]
            out.append(f"{d['arch']:<18}{d['shape']:<13}{d['mesh']:<11}"
                       f"{d['status']:<7}{reason}")
            continue
        r = d["roofline"]
        fits = d.get("memory_estimate", {}).get("fits_16GiB", "?")
        u = d.get("useful_flops_ratio")
        out.append(
            f"{d['arch']:<18}{d['shape']:<13}{d['mesh']:<11}OK     "
            f"{r['t_compute_s']:>9.4f}{r['t_memory_s']:>9.4f}"
            f"{r['t_collective_s']:>9.4f} {r['dominant']:<11}"
            f"{r['compute_fraction']:>6.2f}{(u if u else 0):>8.3f}"
            f"{str(fits):>6}"
        )
    return "\n".join(out)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="pod16x16")
    ap.add_argument("--tag", default="")
    args = ap.parse_args(argv)
    cells = load_cells(args.mesh, args.tag)
    if not cells:
        print(f"no dry-run cells found under {RESULTS} "
              f"(run python -m repro.launch.dryrun first)")
        return
    print(render(cells))


if __name__ == "__main__":
    main()
