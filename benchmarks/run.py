"""Benchmark aggregator: one harness per paper table/figure + roofline.

``python -m benchmarks.run`` runs reduced-duration versions of every
harness (full parameters via each module's own CLI):

* Fig. 3(a)/(b)  — bank.py          (locality sweep, throughput + reuse)
* Fig. 3(c)      — overload.py      (overload control)
* Fig. 4         — tpcc.py          (TPC-C 95/5)
* Fig. 5         — bank.py --threads 4 (appendix)
* §Roofline      — roofline.py      (reads results/dryrun)
* serving layer  — serve_locality.py (framework-level locality)
* self-optimization — planner.py    (proactive placement planner)
"""
from __future__ import annotations

import sys
import time


def main() -> None:
    t0 = time.time()
    from benchmarks import bank, overload, roofline, serve_locality, tpcc

    print("=" * 72)
    print("== Bank locality sweep (Fig 3a/3b), 2 threads/node")
    print("=" * 72)
    bank.main(["--duration", "800", "--localities", "0.0", "0.4", "0.8",
               "0.9", "1.0"])

    print()
    print("=" * 72)
    print("== Bank locality sweep, 4 threads/node (Fig 5 appendix)")
    print("=" * 72)
    bank.main(["--duration", "800", "--threads", "4",
               "--localities", "0.0", "0.8", "1.0",
               "--algos", "ALC", "FGL", "LILAC-TM-ST", "LILAC-TM-LT"])

    print()
    print("=" * 72)
    print("== Overload control (Fig 3c)")
    print("=" * 72)
    overload.main(["--duration", "900"])

    print()
    print("=" * 72)
    print("== TPC-C 95% Payment / 5% New-Order (Fig 4)")
    print("=" * 72)
    tpcc.main(["--duration", "900"])

    print()
    print("=" * 72)
    print("== Serving-layer locality (framework integration)")
    print("=" * 72)
    serve_locality.main(["--localities", "0.0", "0.9"])

    print()
    print("=" * 72)
    print("== Proactive placement planner (planner-on vs planner-off)")
    print("=" * 72)
    from benchmarks import planner
    planner.main(["--smoke", "--out", "/tmp/BENCH_planner_run.json"])

    print()
    print("=" * 72)
    print("== Vectorized policy sweep (lax.scan model, vmap over grid)")
    print("=" * 72)
    from repro.core import jax_sim
    import numpy as np

    locs = [0.0, 0.3, 0.6, 0.9, 1.0]
    print("variant,locality,rel_throughput,lease_reuse")
    for name, kw in (("ALC~", dict(fine_grained=False)),
                     ("FGL~", dict(fine_grained=True)),
                     ("LILAC~", dict(fine_grained=True, migrate=True))):
        out = jax_sim.locality_sweep(locs, seeds=4, **kw)
        for i, p in enumerate(locs):
            print(f"{name},{p},{float(out['throughput'][i]):.4f},"
                  f"{float(out['reuse'][i]):.3f}")

    print()
    print("=" * 72)
    print("== Roofline table (single-pod baselines from results/dryrun)")
    print("=" * 72)
    roofline.main(["--mesh", "pod16x16"])

    print()
    print(f"[benchmarks.run] total wall time {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
