"""Benchmark aggregator: one harness per paper table/figure + roofline.

``python -m benchmarks.run`` runs reduced-duration versions of every
harness (full parameters via each module's own CLI):

* Fig. 3(a)/(b)  — bank.py          (locality sweep, throughput + reuse)
* Fig. 3(c)      — overload.py      (overload control)
* Fig. 4         — tpcc.py          (TPC-C 95/5)
* Fig. 5         — bank.py --threads 4 (appendix)
* §Roofline      — roofline.py      (reads results/dryrun)
* serving layer  — serve_locality.py (framework-level locality)
* self-optimization — planner.py    (proactive placement planner)
* control plane  — lease_ops.py     (batched vs sequential lease manager)

``python -m benchmarks.run --check`` instead validates the COMMITTED
``results/BENCH_*.json`` artifacts against tolerance bands — the
regression gate for the numbers the README quotes.  Bands, not point
pins: benchmark hosts differ, but a refactor that erases an order-of-
magnitude speedup or the planner's wire reduction must fail loudly.
"""
from __future__ import annotations

import json
import os
import sys
import time

# Tolerance bands for the committed artifacts.  Floors sit well under the
# committed values (certify 9.35x, lease_ops ~30x, planner wire -78..-87%)
# so a re-run on different hardware passes, while a semantic regression
# (batching silently falling back to the loop, the planner not steering)
# cannot.
CERTIFY_MIN_SPEEDUP = 5.0
LEASE_OPS_MIN_SPEEDUP = 10.0
PLANNER_WIRE_REDUCTION = (0.70, 0.95)   # at locality >= 0.7
PLANNER_MIN_OFF_PATH = 0.8       # async split hides >=80% of scoring time
A2A_MIN_CELL_SPEEDUP = 0.95      # noise floor at parity cells
HANDOFF_MIN_CELL_RATIO = 0.99    # pipelined vs drain, per cell


def check_artifacts(results_dir: str = "results") -> None:
    def load(name):
        path = os.path.join(results_dir, name)
        assert os.path.exists(path), f"missing committed artifact {path}"
        with open(path) as f:
            return json.load(f)

    cert = load("BENCH_certify.json")
    got = cert["best_jnp_speedup_batch_ge_64"]
    assert got >= CERTIFY_MIN_SPEEDUP, (
        f"certify: jnp speedup {got:.2f}x below {CERTIFY_MIN_SPEEDUP}x")
    print(f"certify ok: jnp {got:.2f}x >= {CERTIFY_MIN_SPEEDUP}x")

    lease = load("BENCH_lease_ops.json")
    assert lease["n_classes"] >= 100_000, \
        "lease_ops artifact not in the >=100k-class regime"
    got = lease["batched_speedup"]
    assert got >= LEASE_OPS_MIN_SPEEDUP, (
        f"lease_ops: batched speedup {got:.2f}x below "
        f"{LEASE_OPS_MIN_SPEEDUP}x")
    print(f"lease_ops ok: batched {got:.2f}x >= {LEASE_OPS_MIN_SPEEDUP}x "
          f"at {lease['n_classes']} classes")

    plan = load("BENCH_planner.json")
    by = {(r["planner"], r["locality"]): r for r in plan["rows"]}
    lo_b, hi_b = PLANNER_WIRE_REDUCTION
    hi = [p for (on, p) in by if on and p >= 0.7]
    assert hi, "planner artifact has no locality >= 0.7 rows"
    for p in sorted(hi):
        off, on = by[(False, p)], by[(True, p)]
        red = 1.0 - on["wire_GB"] / off["wire_GB"]
        assert lo_b <= red <= hi_b, (
            f"planner: wire reduction {red:.2%} at P={p} outside "
            f"[{lo_b:.0%}, {hi_b:.0%}]")
        print(f"planner ok: wire -{red:.1%} at P={p}")
    ov = plan["overlap"]
    assert ov["off_path_frac"] >= PLANNER_MIN_OFF_PATH, (
        f"planner: async split hides only {ov['off_path_frac']:.0%} of "
        f"scoring wall-time (< {PLANNER_MIN_OFF_PATH:.0%})")
    print(f"planner ok: async scoring {ov['off_path_frac']:.0%} off the "
          f"step loop at {ov['n_classes']} classes")

    a2a = load("BENCH_moe_a2a.json")
    tuned = [r for r in a2a["rows"] if r["verdict_a2a"]]
    assert tuned, "moe_a2a artifact has no autotuned-to-a2a cells"
    assert any(r["tp"] > 1 for r in tuned), \
        "moe_a2a artifact has no deepseek-style (tp>1) autotuned cell"
    worst = min(r["a2a_speedup"] for r in tuned)
    assert worst >= A2A_MIN_CELL_SPEEDUP, (
        f"moe_a2a: a2a {worst:.2f}x below the {A2A_MIN_CELL_SPEEDUP}x floor "
        f"at an autotuned cell")
    best_tp = max(r["a2a_speedup"] for r in tuned if r["tp"] > 1)
    assert best_tp > 1.0, (
        f"moe_a2a: tp>1 a2a never beats replication (best {best_tp:.2f}x)")
    print(f"moe_a2a ok: {len(tuned)} autotuned cells, worst {worst:.2f}x, "
          f"best tp>1 {best_tp:.2f}x")

    hand = load("BENCH_handoff.json")
    pipe = [r for r in hand["rows"] if r["handoff"] == "pipelined"]
    assert pipe, "handoff artifact has no pipelined rows"
    worst_r = min(r["ratio_vs_drain"] for r in pipe)
    mean_r = sum(r["ratio_vs_drain"] for r in pipe) / len(pipe)
    assert worst_r >= HANDOFF_MIN_CELL_RATIO, (
        f"handoff: pipelined {worst_r:.4f}x drain below "
        f"{HANDOFF_MIN_CELL_RATIO} — the default flip is unjustified")
    assert mean_r >= 1.0, f"handoff: grid mean {mean_r:.4f}x < 1.0"
    print(f"handoff ok: pipelined worst {worst_r:.4f}x / mean {mean_r:.4f}x "
          f"vs drain over {len(pipe)} cells")


def main() -> None:
    if "--check" in sys.argv[1:]:
        check_artifacts()
        print("[benchmarks.run] committed artifacts within tolerance bands")
        return
    t0 = time.time()
    from benchmarks import bank, overload, roofline, serve_locality, tpcc

    print("=" * 72)
    print("== Bank locality sweep (Fig 3a/3b), 2 threads/node")
    print("=" * 72)
    bank.main(["--duration", "800", "--localities", "0.0", "0.4", "0.8",
               "0.9", "1.0"])

    print()
    print("=" * 72)
    print("== Bank locality sweep, 4 threads/node (Fig 5 appendix)")
    print("=" * 72)
    bank.main(["--duration", "800", "--threads", "4",
               "--localities", "0.0", "0.8", "1.0",
               "--algos", "ALC", "FGL", "LILAC-TM-ST", "LILAC-TM-LT"])

    print()
    print("=" * 72)
    print("== Overload control (Fig 3c)")
    print("=" * 72)
    overload.main(["--duration", "900"])

    print()
    print("=" * 72)
    print("== TPC-C 95% Payment / 5% New-Order (Fig 4)")
    print("=" * 72)
    tpcc.main(["--duration", "900"])

    print()
    print("=" * 72)
    print("== Serving-layer locality (framework integration)")
    print("=" * 72)
    serve_locality.main(["--localities", "0.0", "0.9"])

    print()
    print("=" * 72)
    print("== Proactive placement planner (planner-on vs planner-off)")
    print("=" * 72)
    from benchmarks import planner
    planner.main(["--smoke", "--out", "/tmp/BENCH_planner_run.json"])

    print()
    print("=" * 72)
    print("== Lease control plane (batched vs sequential manager)")
    print("=" * 72)
    from benchmarks import lease_ops
    lease_ops.main(["--smoke", "--out", "/tmp/BENCH_lease_ops_run.json"])

    print()
    print("=" * 72)
    print("== Vectorized policy sweep (lax.scan model, vmap over grid)")
    print("=" * 72)
    from repro.core import jax_sim
    import numpy as np

    locs = [0.0, 0.3, 0.6, 0.9, 1.0]
    print("variant,locality,rel_throughput,lease_reuse")
    for name, kw in (("ALC~", dict(fine_grained=False)),
                     ("FGL~", dict(fine_grained=True)),
                     ("LILAC~", dict(fine_grained=True, migrate=True))):
        out = jax_sim.locality_sweep(locs, seeds=4, **kw)
        for i, p in enumerate(locs):
            print(f"{name},{p},{float(out['throughput'][i]):.4f},"
                  f"{float(out['reuse'][i]):.3f}")

    print()
    print("=" * 72)
    print("== Roofline table (single-pod baselines from results/dryrun)")
    print("=" * 72)
    roofline.main(["--mesh", "pod16x16"])

    print()
    print(f"[benchmarks.run] total wall time {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
