"""Framework-level locality benchmark: the paper's technique at serving scale.

Sweeps request locality P over an 8-pod simulated deployment for each
routing policy (the serving analogue of Fig. 3a), with the SimBackend
pricing pod steps by the roofline model.  Also reports the wire traffic
saved by lease stickiness.
"""
from __future__ import annotations

import argparse
from typing import Dict, List

import numpy as np

from repro.configs import get_config
from repro.serve.engine import MultiPodEngine, Request, SimBackend
from repro.serve.router import LocalityRouter

POLICIES = ["local", "short", "long"]


def run_point(arch: str, policy: str, locality: float, *, n_pods: int = 8,
              n_sessions: int = 256, steps: int = 80, seed: int = 0) -> Dict:
    cfg = get_config(arch)
    kv_per_tok = 2.0 * 2 * cfg.n_kv_heads * cfg.head_dim * cfg.n_layers \
        if cfg.n_kv_heads else 4096.0 * cfg.n_layers
    router = LocalityRouter(n_pods, policy=policy,
                            kv_bytes_per_token=kv_per_tok)
    eng = MultiPodEngine(n_pods, SimBackend(cfg), router)
    rng = np.random.default_rng(seed)
    for _ in range(steps):
        for _ in range(2 * n_pods):
            sid = int(rng.integers(n_sessions))
            home = sid % n_pods
            origin = home if rng.random() < locality else int(rng.integers(n_pods))
            eng.submit(Request(sid=sid, origin=origin, n_tokens=4))
        eng.run_step()
    eng.drain()
    m = eng.metrics.as_dict()
    return {
        "tokens_per_s": m["tokens_per_s"],
        "wire_GB": m["wire_GB"],
        "reuse": router.metrics.lease_reuse_rate,
        "transfers": m["transfers"],
        "forwards": m["forwards"],
    }


def main(argv=None) -> List[Dict]:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mixtral-8x7b")
    ap.add_argument("--localities", nargs="*", type=float,
                    default=[0.0, 0.5, 0.9])
    args = ap.parse_args(argv)

    rows = []
    print("arch,policy,locality,tokens_per_s,wire_GB,lease_reuse,transfers,forwards")
    for policy in POLICIES:
        for p in args.localities:
            r = run_point(args.arch, policy, p)
            rows.append({"policy": policy, "locality": p, **r})
            print(f"{args.arch},{policy},{p},{r['tokens_per_s']:.0f},"
                  f"{r['wire_GB']:.3f},{r['reuse']:.3f},{r['transfers']},"
                  f"{r['forwards']}", flush=True)
    return rows


if __name__ == "__main__":
    main()
