"""Framework-level locality benchmark: the paper's technique at serving scale.

Sweeps request locality P over a simulated multi-pod deployment for each
(DTD policy × arbitration) pair (the serving analogue of Fig. 3a), with the
SimBackend pricing pod steps by the roofline model and the engine charging
wire time from ``price_session_dispatch`` (RTT included).  The winning pair
is reported against ``repro.dist.locality.ROUTER_DEFAULTS``, which is where
its thresholds live as the serving-stack defaults.

``--smoke`` (2 pods, 8 sessions, 10 steps) runs the full grid in seconds —
CI uses it so the sweep can't silently rot.
"""
from __future__ import annotations

import argparse
from typing import Dict, List

import numpy as np

from repro.configs import get_config
from repro.dist.locality import ROUTER_DEFAULTS
from repro.serve.engine import MultiPodEngine, Request, SimBackend
from repro.serve.router import LocalityRouter

POLICIES = ["local", "short", "long"]

# (policy, arbitration) grid: "local" never migrates so arbitration is
# moot there; every other pair matters — the policy still drives
# new-session placement (and third-pod redirects under "hybrid") even
# when the byte model settles the owned-session binary.
GRID = [
    ("local", "steps"),
    ("short", "steps"),
    ("short", "priced"),
    ("short", "hybrid"),
    ("long", "steps"),
    ("long", "priced"),
    ("long", "hybrid"),
]


def run_point(arch: str, policy: str, locality: float, *, n_pods: int = 8,
              n_sessions: int = 256, steps: int = 80, seed: int = 0,
              arbitration: str = "steps", seeds: int = 1,
              plan_epoch_ms: float = 0.0) -> Dict:
    if seeds > 1:
        pts = [run_point(arch, policy, locality, n_pods=n_pods,
                         n_sessions=n_sessions, steps=steps, seed=seed + i,
                         arbitration=arbitration, plan_epoch_ms=plan_epoch_ms)
               for i in range(seeds)]
        return {k: sum(p[k] for p in pts) / seeds for k in pts[0]}
    cfg = get_config(arch)
    kv_per_tok = 2.0 * 2 * cfg.n_kv_heads * cfg.head_dim * cfg.n_layers \
        if cfg.n_kv_heads else 4096.0 * cfg.n_layers
    router = LocalityRouter(n_pods, policy=policy, arbitration=arbitration,
                            kv_bytes_per_token=kv_per_tok)
    planner = None
    if plan_epoch_ms > 0:
        from repro.dist.sharding import make_plan_mesh
        from repro.plan import PlacementPlanner
        planner = PlacementPlanner.for_serving(
            n_pods, n_sessions, epoch_ms=plan_epoch_ms,
            mesh=make_plan_mesh())
    eng = MultiPodEngine(n_pods, SimBackend(cfg), router, planner=planner)
    rng = np.random.default_rng(seed)
    for _ in range(steps):
        for _ in range(2 * n_pods):
            sid = int(rng.integers(n_sessions))
            home = sid % n_pods
            origin = home if rng.random() < locality else int(rng.integers(n_pods))
            eng.submit(Request(sid=sid, origin=origin, n_tokens=4))
        eng.run_step()
    eng.drain()
    m = eng.metrics.as_dict()
    return {
        "tokens_per_s": m["tokens_per_s"],
        "wire_GB": m["wire_GB"],
        "reuse": router.metrics.lease_reuse_rate,
        "transfers": m["transfers"],
        "forwards": m["forwards"],
        "flips": router.metrics.flips,
        "plan_moves": m["plan_moves"],
        "plan_prefetches": m["plan_prefetches"],
        "plan_GB": m["plan_GB"],
    }


def run_long_context(*, smoke: bool, seed: int = 0) -> Dict:
    """Long-context cell: real decode over a seq-bearing host mesh.

    Small model, long ``max_len``, seq axis on — exercises the seq-sharded
    KV layout end to end: ``KVStore`` placement via ``cache_shardings``,
    sharded decode steps, export/import migrations between pods, and the
    ``1/seq_shards`` per-hop pricing in the router.  On a 1-device CI host
    the seq axis degrades to size 1 through the divisibility guards, so the
    same code path runs everywhere.
    """
    import dataclasses

    import jax

    from repro.configs import get_smoke_config
    from repro.launch.mesh import make_host_mesh
    from repro.models import decoder
    from repro.models.common import init_params
    from repro.serve.engine import RealBackend

    cfg = dataclasses.replace(get_smoke_config("glm4-9b"), dtype="float32")
    max_len = 256 if smoke else 2048
    mesh = make_host_mesh(model=1, seq=jax.device_count())
    seq_axis = "seq" if "seq" in mesh.axis_names else None
    ctx = decoder.RunCtx(mesh=mesh, batch_axes=("data",), use_kernel="ref",
                         seq_axis=seq_axis)
    params = init_params(cfg, jax.random.PRNGKey(seed))
    backend = RealBackend(cfg, ctx, params, n_pods=2, n_slots=8,
                          max_len=max_len)
    router = LocalityRouter(2, policy="short", arbitration="priced",
                            kv_bytes_per_token=256.0,
                            seq_shards=backend.seq_shards)
    eng = MultiPodEngine(2, backend, router)
    rng = np.random.default_rng(seed)
    for _ in range(6):
        for _ in range(4):
            sid = int(rng.integers(4))
            origin = sid % 2 if rng.random() < 0.5 else int(rng.integers(2))
            eng.submit(Request(sid=sid, origin=origin, n_tokens=2))
        eng.run_step()
    eng.drain()
    m = eng.metrics.as_dict()
    row = {"seq_shards": backend.seq_shards, "max_len": max_len,
           "tokens": m["tokens"], "wire_GB": m["wire_GB"],
           "transfers": m["transfers"], "forwards": m["forwards"]}
    print(f"long-context,glm4-9b,seq_shards={row['seq_shards']:g},"
          f"max_len={max_len},tokens={row['tokens']:.0f},"
          f"transfers={row['transfers']:.0f},forwards={row['forwards']:.0f},"
          f"wire_GB={row['wire_GB']:.6f}", flush=True)
    return row


def pick_winner(rows: List[Dict], localities: List[float]) -> Dict:
    """Lowest wire at the highest locality, subject to no tokens/s loss
    (>2%) versus the best thrower at the lowest locality."""
    lo, hi = min(localities), max(localities)
    best_tps = max(r["tokens_per_s"] for r in rows if r["locality"] == lo)
    ok = {(r["policy"], r["arbitration"]) for r in rows
          if r["locality"] == lo and r["tokens_per_s"] >= 0.98 * best_tps}
    cand = [r for r in rows if r["locality"] == hi
            and (r["policy"], r["arbitration"]) in ok]
    return min(cand or [r for r in rows if r["locality"] == hi],
               key=lambda r: r["wire_GB"])


def main(argv=None) -> List[Dict]:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mixtral-8x7b")
    ap.add_argument("--localities", nargs="*", type=float,
                    default=[0.0, 0.5, 0.9])
    ap.add_argument("--pods", type=int, default=8)
    ap.add_argument("--sessions", type=int, default=256)
    ap.add_argument("--steps", type=int, default=80)
    ap.add_argument("--seeds", type=int, default=3,
                    help="average each cell over this many seeds")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny grid for CI: 2 pods, 8 sessions, 10 steps")
    args = ap.parse_args(argv)
    if args.smoke:
        args.pods, args.sessions, args.steps, args.seeds = 2, 8, 10, 1

    rows = []
    print("arch,policy,arbitration,locality,tokens_per_s,wire_GB,"
          "lease_reuse,transfers,forwards,flips")
    for policy, arbitration in GRID:
        for p in args.localities:
            r = run_point(args.arch, policy, p, n_pods=args.pods,
                          n_sessions=args.sessions, steps=args.steps,
                          arbitration=arbitration, seeds=args.seeds)
            rows.append({"policy": policy, "arbitration": arbitration,
                         "locality": p, **r})
            print(f"{args.arch},{policy},{arbitration},{p},"
                  f"{r['tokens_per_s']:.0f},{r['wire_GB']:.3f},"
                  f"{r['reuse']:.3f},{r['transfers']:.0f},{r['forwards']:.0f},"
                  f"{r['flips']:.0f}", flush=True)
    # long-context cell: the real seq-sharded decode + migrate path (small
    # model, long max_len, seq axis on) — keeps the new layout running in CI
    run_long_context(smoke=args.smoke)
    w = pick_winner(rows, args.localities)
    print(f"winner: policy={w['policy']} arbitration={w['arbitration']} "
          f"(wire_GB={w['wire_GB']:.3f} at locality {w['locality']}) — "
          f"defaults: repro.dist.locality.ROUTER_DEFAULTS "
          f"(policy={ROUTER_DEFAULTS.policy}, "
          f"arbitration={ROUTER_DEFAULTS.arbitration})")
    return rows


if __name__ == "__main__":
    main()
