"""Overload-control experiment — paper Fig. 3(c).

Hot-partition workload; the hot node is overloaded with external CPU jobs
at t=inject_ms.  Prints throughput time series for ST/LT × {Ctrl, NoCtrl}.
"""
from __future__ import annotations

import argparse
from dataclasses import replace
from typing import Dict, List

from repro.core import BankWorkload, Cluster, SimConfig


def run_variant(policy: str, ctrl: bool, *, duration: float = 1200.0,
                inject_ms: float = 300.0, threads: int = 2,
                slowdown: float = 50.0, seed: int = 0,
                max_cpu: float | None = None) -> Dict:
    cfg = SimConfig(duration_ms=duration, warmup_ms=100.0, n_classes=16,
                    threads_per_node=threads, seed=seed)
    dtd = replace(cfg.dtd, policy=policy, enable_overload_ctrl=ctrl)
    if max_cpu is not None:
        dtd = replace(dtd, max_cpu=max_cpu)
    cfg = replace(cfg, dtd=dtd)
    wl = BankWorkload(n_nodes=cfg.n_nodes, n_items=cfg.n_items, locality=1.0,
                      hot_partition=0, hot_fraction=0.2)
    c = Cluster(cfg, wl)
    c.events.schedule(inject_ms, lambda: c.inject_load(
        0, extra_load=0.95, slowdown=slowdown, seize_slots=1))
    m = c.run()
    series = [
        (t0, m.throughput(t0, t0 + 100.0))
        for t0 in range(0, int(duration) - 100, 100)
    ]
    return {
        "series": series,
        "pre": m.throughput(100.0, inject_ms),
        "post": m.throughput(inject_ms + 150.0, duration),
    }


def sweep_max_cpu(values: List[float], *, duration: float = 1200.0,
                  threads: int = 2, seeds: int = 3) -> List[Dict]:
    """Re-sweep the constraint-(3) threshold against the fixed CpuMeter.

    The PR-3 ``CpuMeter`` fix means utilization now reads the true injected
    load (the valve used to trip at ~half the configured ``max_cpu``), so
    thresholds tuned against the old meter are stale.  Post-overload
    throughput, seed-averaged, per policy × max_cpu; the winner by combined
    post-overload throughput is what ``DTDConfig.max_cpu`` /
    ``ROUTER_DEFAULTS.max_cpu`` pin.
    """
    rows = []
    print("policy,max_cpu,pre_overload_txn_s,post_overload_txn_s")
    for policy in ("short", "long"):
        for v in values:
            pre = post = 0.0
            for s in range(seeds):
                r = run_variant(policy, True, duration=duration,
                                threads=threads, seed=s, max_cpu=v)
                pre += r["pre"] / seeds
                post += r["post"] / seeds
            rows.append({"policy": policy, "max_cpu": v,
                         "pre": pre, "post": post})
            print(f"{policy},{v},{pre:.1f},{post:.1f}", flush=True)
    by_v = {v: sum(r["post"] for r in rows if r["max_cpu"] == v)
            for v in values}
    best = max(by_v, key=by_v.get)
    print(f"winner: max_cpu={best} "
          f"(combined post-overload {by_v[best]:.1f} txn/s)")
    return rows


def main(argv=None) -> List[Dict]:
    ap = argparse.ArgumentParser()
    ap.add_argument("--duration", type=float, default=1200.0)
    ap.add_argument("--threads", type=int, default=2)
    ap.add_argument("--seeds", type=int, default=3)
    ap.add_argument("--sweep-max-cpu", nargs="*", type=float, default=None,
                    help="sweep constraint-(3) thresholds instead of the "
                         "Fig-3c time-series run")
    args = ap.parse_args(argv)
    if args.sweep_max_cpu is not None:
        values = args.sweep_max_cpu or [0.5, 0.6, 0.7, 0.8, 0.85, 0.9, 0.95]
        return sweep_max_cpu(values, duration=args.duration,
                             threads=args.threads, seeds=args.seeds)

    rows = []
    print("variant,t_ms,throughput_txn_s")
    summaries = []
    for policy in ("short", "long"):
        for ctrl in (True, False):
            name = f"LILAC-TM-{'ST' if policy == 'short' else 'LT'}" + \
                   ("" if ctrl else "-NoCtrl")
            r = run_variant(policy, ctrl, duration=args.duration,
                            threads=args.threads)
            for (t, thr) in r["series"]:
                print(f"{name},{t},{thr:.1f}")
            summaries.append((name, r["pre"], r["post"]))
            rows.append({"variant": name, **r})
    print("\nvariant,pre_overload_txn_s,post_overload_txn_s")
    for (n, pre, post) in summaries:
        print(f"{n},{pre:.1f},{post:.1f}")
    return rows


if __name__ == "__main__":
    main()
