"""Lease control-plane throughput: sequential per-class loop vs batched ops.

Replays the SAME replicated delivery schedule through the two lease
managers and times the protocol work only:

* ``sequential`` — :class:`repro.core.lease.FGLLeaseManager`: every
  Opt/TO/Freed/FinishedXact message handled one at a time against the
  per-class python queues (the Algorithm 1 oracle, and exactly what the
  cluster ran before ``lease_mode="batched"``);
* ``batched``    — :class:`repro.core.lease_batched.ShardedLeaseManager`:
  each delivery *instant* (one round = the batch of messages a drain
  window lands together) settled through the array ops —
  ``opt_deliver_batch`` / ``to_deliver_batch`` / ``freed_batch`` /
  ``enabled_mask`` / ``finish_batch`` — with head ownership, frees and
  enablement coming out of one ``settle_lease_batch`` dispatch.

The schedule is a miniature cluster: ``n_nodes`` replicas each applying
every round's requests (conflicts drawn from a hot set so leases block,
free and hand off), own-proc frees UR-delivered everywhere, waiters
re-checked and finished as they reach their queue heads.  The per-message
oracle pays python queue walks *and* the O(pending) born-blocked scan per
own TO-deliver — precisely the per-class bookkeeping the batched instant
replaces with scatters over the sharded arrays.

Both runs must agree exactly (owner views, the flat freed-key stream,
finish counts) — the bench asserts it, so the speedup is measured on a
byte-identical execution.  Writes a ``BENCH_lease_ops.json`` artifact;
``--check`` enforces the acceptance floor: batched ops/s >= 10x the
sequential loop at >= 100k conflict classes.
"""
from __future__ import annotations

import argparse
import json
import time
from typing import Dict, List, Tuple

import numpy as np

from repro.core.lease import FGLLeaseManager, LeaseRequest
from repro.core.lease_batched import ShardedLeaseManager


def make_schedule(n_nodes: int, n_classes: int, batch: int, rounds: int,
                  *, hot_frac: float = 0.25, hot_classes: int = 1024,
                  multi_frac: float = 0.1, seed: int = 0
                  ) -> List[List[LeaseRequest]]:
    """``rounds`` delivery instants of ``batch`` lease requests each.

    A ``hot_frac`` slice of the requests lands on a small hot set so queues
    actually conflict (blocking, frees, ownership handoff); the rest spray
    over the full class space (the million-class regime the sharded layout
    targets).  ``multi_frac`` requests span two classes, exercising
    multi-LOR enablement.
    """
    rng = np.random.default_rng(seed)
    hot = min(hot_classes, n_classes)
    schedule: List[List[LeaseRequest]] = []
    rid = 0
    for _ in range(rounds):
        reqs: List[LeaseRequest] = []
        for _ in range(batch):
            rid += 1
            space = hot if rng.random() < hot_frac else n_classes
            if rng.random() < multi_frac:
                ccs = rng.choice(space, size=2, replace=False)
                ccs = tuple(sorted(int(c) for c in ccs))
            else:
                ccs = (int(rng.integers(space)),)
            reqs.append(LeaseRequest(req_id=rid, proc=rid % n_nodes, ccs=ccs))
        schedule.append(reqs)
    return schedule


def run_protocol(mgrs, schedule, *, batched: bool) -> Dict:
    """Drive the replicated protocol over the schedule; returns its trace.

    Per round (one delivery instant): Opt-deliver the batch at every
    replica (own unblocked-and-drained heads free), UR-deliver those frees
    everywhere, TO-deliver the batch (enqueue; own LORs born blocked
    against still-pending opts), then re-check every waiting request and
    finish the newly enabled ones (their retained leases free later, when
    a conflicting opt blocks them) — delivering finish-frees everywhere.
    """
    n_nodes = len(mgrs)
    waiters: List[List[Tuple[LeaseRequest, list]]] = [[] for _ in mgrs]
    freed_log: List[Tuple] = []
    ops = finished = 0

    def deliver_freed(frees_by_node):
        nonlocal ops
        keys = [l.key() for frees in frees_by_node for l in frees]
        if not keys:
            return
        freed_log.extend(keys)
        ops += len(keys) * n_nodes
        for mgr in mgrs:
            if batched:
                mgr.freed_batch([keys])
            else:
                mgr.on_ur_deliver_freed(keys)

    for reqs in schedule:
        # 1) optimistic delivery: freeLocalLeases at every replica
        opt_frees = []
        for mgr in mgrs:
            if batched:
                opt_frees.append(mgr.opt_deliver_batch(reqs))
            else:
                fr = []
                for r in reqs:
                    fr.extend(mgr.on_opt_deliver(r))
                opt_frees.append(fr)
        ops += len(reqs) * n_nodes
        deliver_freed(opt_frees)
        # 2) total-order delivery: enqueue at every replica
        for n, mgr in enumerate(mgrs):
            if batched:
                per_req = mgr.to_deliver_batch(reqs)
            else:
                per_req = [mgr.on_to_deliver(r) for r in reqs]
            for r, lors in zip(reqs, per_req):
                if r.proc == n and lors:
                    waiters[n].append((r, lors))
        ops += len(reqs) * n_nodes
        # 3) enablement + finish at the owning replica
        fin_frees = []
        for n, mgr in enumerate(mgrs):
            w = waiters[n]
            if not w:
                fin_frees.append([])
                continue
            groups = [lors for (_r, lors) in w]
            if batched:
                en = mgr.enabled_mask(groups)
            else:
                en = [mgr.is_enabled(lors) for lors in groups]
            ops += len(w)
            done = [g for g, e in zip(groups, en) if e]
            waiters[n] = [we for we, e in zip(w, en) if not e]
            finished += len(done)
            if batched:
                fin_frees.append(mgr.finish_batch(done))
            else:
                fr = []
                for lors in done:
                    fr.extend(mgr.finished_xact(lors))
                fin_frees.append(fr)
        deliver_freed(fin_frees)

    return {
        "ops": ops,
        "finished": finished,
        "waiting": [len(w) for w in waiters],
        "freed_log": freed_log,
        "owners": [m.owner_np() for m in mgrs],
    }


def bench_mode(mode: str, n_nodes: int, n_classes: int, schedule,
               *, shards: int, jax_min: int) -> Tuple[Dict, float, list]:
    def fresh():
        if mode == "sequential":
            return [FGLLeaseManager(n, n_classes) for n in range(n_nodes)]
        mgrs = [ShardedLeaseManager(n, n_classes, n_shards=shards,
                                    jax_min=jax_min)
                for n in range(n_nodes)]
        if mode == "sanitized":
            from repro.analysis.sanitizer import LeaseSanitizer

            mgrs = [LeaseSanitizer(m) for m in mgrs]
        return mgrs

    if mode != "sequential":
        # warm the jit caches on one throwaway full run: every (pow2 class
        # count, waiter bucket) shape the schedule produces compiles here,
        # so the timed run measures steady-state dispatch only
        run_protocol(fresh(), schedule, batched=True)
    mgrs = fresh()
    t0 = time.perf_counter()
    trace = run_protocol(mgrs, schedule, batched=(mode != "sequential"))
    dt = time.perf_counter() - t0
    return trace, dt, mgrs


def main(argv=None) -> Dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n-nodes", type=int, default=2)
    ap.add_argument("--n-classes", type=int, default=1 << 20)
    ap.add_argument("--batch", type=int, default=8192)
    ap.add_argument("--rounds", type=int, default=6)
    ap.add_argument("--shards", type=int, default=8)
    ap.add_argument("--jax-min", type=int, default=64)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="BENCH_lease_ops.json")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced schedule for CI: 128k classes, 3 rounds "
                         "(implies --sanitize)")
    ap.add_argument("--check", action="store_true",
                    help="fail unless batched >= 10x sequential ops/s")
    ap.add_argument("--sanitize", action="store_true",
                    help="also run the batched manager under the protocol "
                         "sanitizer and report its overhead")
    args = ap.parse_args(argv)
    if args.smoke:
        args.sanitize = True
        # the instant must stay drain-window sized: the >=10x floor is an
        # asymptotic claim (the oracle's born-blocked scan is O(batch) per
        # own enqueue), so tiny batches would measure dispatch overhead
        args.n_classes, args.batch, args.rounds = 1 << 17, 8192, 3

    schedule = make_schedule(args.n_nodes, args.n_classes, args.batch,
                             args.rounds, seed=args.seed)
    print(f"n_classes={args.n_classes} batch={args.batch} "
          f"rounds={args.rounds} nodes={args.n_nodes}")
    print("mode,ops,ops_per_s,wall_s,finished")
    rows = []
    traces = {}
    modes = ["sequential", "batched"] + (["sanitized"] if args.sanitize
                                         else [])
    for mode in modes:
        trace, dt, mgrs = bench_mode(
            mode, args.n_nodes, args.n_classes, schedule,
            shards=args.shards, jax_min=args.jax_min)
        if mode == "sanitized":
            # end-of-run reconciliation rides the sanitized cell: queue
            # contents == ledger, every LOR accounted for
            for m in mgrs:
                m.verify_full()
        traces[mode] = trace
        rows.append({"mode": mode, "ops": trace["ops"],
                     "ops_per_s": trace["ops"] / dt, "wall_s": dt,
                     "finished": trace["finished"]})
        print(f"{mode},{trace['ops']},{trace['ops'] / dt:.0f},{dt:.3f},"
              f"{trace['finished']}", flush=True)

    # the speedup is only meaningful on a byte-identical execution — and
    # the sanitizer, a pure observer, must not perturb it either
    a, b = traces["sequential"], traces["batched"]
    assert a["freed_log"] == b["freed_log"], "freed streams diverge"
    assert a["finished"] == b["finished"] and a["waiting"] == b["waiting"]
    for oa, ob in zip(a["owners"], b["owners"]):
        np.testing.assert_array_equal(oa, ob)
    if "sanitized" in traces:
        s = traces["sanitized"]
        assert s["freed_log"] == b["freed_log"], \
            "sanitizer perturbed the freed stream"
        assert s["finished"] == b["finished"] and s["waiting"] == b["waiting"]
        for oa, ob in zip(s["owners"], b["owners"]):
            np.testing.assert_array_equal(oa, ob)

    # the CI-gated floor is measured on the UNsanitized batched row
    speedup = rows[1]["ops_per_s"] / rows[0]["ops_per_s"]
    out = {
        "bench": "lease_ops",
        "n_nodes": args.n_nodes, "n_classes": args.n_classes,
        "batch": args.batch, "rounds": args.rounds,
        "shards": args.shards, "jax_min": args.jax_min,
        "smoke": bool(args.smoke),
        "batched_speedup": speedup,
        "rows": rows,
    }
    if args.sanitize:
        out["sanitize_overhead"] = rows[2]["wall_s"] / rows[1]["wall_s"]
        print(f"sanitize overhead: {out['sanitize_overhead']:.2f}x "
              f"over batched")
    print(f"batched speedup: {speedup:.2f}x")
    with open(args.out, "w") as f:
        json.dump(out, f, indent=2)
    print(f"wrote {args.out}")
    if args.check:
        assert args.n_classes >= 100_000, \
            "check requires the >=100k-class regime"
        assert speedup >= 10.0, f"batched speedup below 10x: {speedup:.2f}"
    return out


if __name__ == "__main__":
    main()
