"""Observability overhead gate: tracing must be ~free when off, cheap when on.

Two cells, each run trace-off and trace-on with identical seeds:

- ``serve`` — the serve_locality smoke loop (MultiPodEngine + SimBackend +
  LocalityRouter), i.e. every engine-side trace site: route decisions,
  lease acquires, wire/certify/decode spans.
- ``sim``   — a Cluster BankWorkload run with ``lease_mode="batched"``,
  i.e. every cluster-side site (lease rounds, piggybacks, certify
  batches, exec spans, dispatch instants).  This is the same event loop
  ``benchmarks/lease_ops.py`` drives, with the full protocol around it.

Gates (``--check``):

Wall-clock A/B deltas at smoke scale are dominated by scheduler noise —
A/A reruns of the untraced sim cell jitter by ~+/-10%, wider than both
gates — so the gates are computed from *microbenchmarked per-site costs
times observed event counts*, which is deterministic and tighter than
any wall-time band CI could hold.  Raw min-of-N wall times are still
printed/emitted for eyeballing.

- **tracing-off <= 1%**: the disabled path is one predictable branch per
  site (``tr = self.trace; if tr is not None:``).  Microbenchmark the
  guard's per-execution cost, multiply by the number of events the
  *traced* run recorded (a stand-in for disabled-site executions —
  untraced runs skip payload construction entirely), divide by the
  untraced runtime.
- **tracing-on <= 10%**: microbenchmark one full recording site
  (f-string track + kwargs payload + tuple append, the real per-event
  work), multiply by the traced run's event count, divide by the
  untraced runtime.
- **byte-identity**: traced and untraced runs must produce identical
  result metrics (tracing observes the schedule, never perturbs it).
"""
from __future__ import annotations

import argparse
import json
import time
from typing import Callable, Dict, List, Tuple

MAX_OFF_FRAC = 0.01   # disabled tracing: <= 1% of untraced runtime
MAX_ON_FRAC = 0.10    # enabled tracing: <= 10% of untraced runtime


# --------------------------------------------------------------------------
# cells
# --------------------------------------------------------------------------

def _serve_run(*, trace: bool, pods: int, sessions: int, steps: int,
               seed: int) -> Tuple[Dict, int]:
    """One serve_locality-style engine run; returns (metrics, n_events)."""
    import numpy as np

    from repro.configs import get_config
    from repro.serve.engine import MultiPodEngine, Request, SimBackend
    from repro.serve.router import LocalityRouter

    cfg = get_config("mixtral-8x7b")
    kv_per_tok = 2.0 * 2 * cfg.n_kv_heads * cfg.head_dim * cfg.n_layers \
        if cfg.n_kv_heads else 4096.0 * cfg.n_layers
    router = LocalityRouter(pods, policy="short", arbitration="priced",
                            kv_bytes_per_token=kv_per_tok)
    eng = MultiPodEngine(pods, SimBackend(cfg), router, trace=trace)
    rng = np.random.default_rng(seed)
    for _ in range(steps):
        for _ in range(2 * pods):
            sid = int(rng.integers(sessions))
            home = sid % pods
            origin = home if rng.random() < 0.5 else int(rng.integers(pods))
            eng.submit(Request(sid=sid, origin=origin, n_tokens=4))
        eng.run_step()
    eng.drain()
    n_events = len(eng.trace) if eng.trace is not None else 0
    return eng.metrics.as_dict(), n_events


def _sim_run(*, trace: bool, duration: float, seed: int) -> Tuple[Dict, int]:
    """One batched-lease Cluster BankWorkload run; returns (metrics, n_events)."""
    from repro.core import BankWorkload, SimConfig, make_cluster

    cfg = SimConfig(duration_ms=duration, warmup_ms=duration * 0.15,
                    seed=seed, lease_mode="batched", trace=trace)
    wl = BankWorkload(n_nodes=cfg.n_nodes, n_items=cfg.n_items, locality=0.9)
    c = make_cluster("LILAC-TM-OPT", wl, cfg)
    m = c.run()
    n_events = len(c.trace) if c.trace is not None else 0
    return {"throughput": c.throughput(), "reuse": m.lease_reuse_rate(),
            "forwards": m.forwards, "aborts": m.aborts}, n_events


def _min_time(fn: Callable[[], object], repeats: int) -> float:
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def _guard_cost_s(iters: int = 1_000_000) -> float:
    """Per-execution cost of the disabled-site pattern, minus loop overhead."""
    tr = None
    t0 = time.perf_counter()
    for _ in range(iters):
        if tr is not None:
            raise AssertionError  # pragma: no cover - guard is always False
    t_guard = time.perf_counter() - t0
    t0 = time.perf_counter()
    for _ in range(iters):
        pass
    t_empty = time.perf_counter() - t0
    return max(0.0, (t_guard - t_empty) / iters)


def _record_cost_s(iters: int = 200_000) -> float:
    """Per-event cost of one full *enabled* recording site.

    Includes everything the taken branch pays that the untraced run does
    not: the f-string track, the kwargs payload dict, the method call,
    and the tuple append — measured on a representative exec-span site.
    """
    from repro.obs.trace import TraceRecorder

    tr = TraceRecorder()
    node = 2
    t0 = time.perf_counter()
    for i in range(iters):
        tr.span("exec", f"node{node}/t{i & 1}", float(i), 0.5, txid=i)
    dt = time.perf_counter() - t0
    return dt / iters


def run_cell(name: str, run, repeats: int) -> Dict:
    """Time one cell off and on, and assert result byte-identity."""
    m_off, _ = run(trace=False)
    m_on, n_events = run(trace=True)
    assert json.dumps(m_off, sort_keys=True) == \
        json.dumps(m_on, sort_keys=True), \
        f"{name}: tracing perturbed results:\noff={m_off}\non={m_on}"
    t_off = _min_time(lambda: run(trace=False), repeats)
    t_on = _min_time(lambda: run(trace=True), repeats)
    off_frac = _guard_cost_s() * n_events / max(t_off, 1e-9)
    on_frac = _record_cost_s() * n_events / max(t_off, 1e-9)
    row = {"cell": name, "t_off_s": t_off, "t_on_s": t_on,
           "events": n_events, "off_overhead_frac": off_frac,
           "on_overhead_frac": on_frac}
    print(f"{name},{t_off * 1e3:.2f}ms,{t_on * 1e3:.2f}ms,"
          f"events={n_events},off={off_frac * 100:.4f}%,"
          f"on={on_frac * 100:.2f}%", flush=True)
    return row


def check(rows: List[Dict]) -> None:
    for r in rows:
        assert r["off_overhead_frac"] <= MAX_OFF_FRAC, (
            f"{r['cell']}: disabled tracing costs "
            f"{r['off_overhead_frac'] * 100:.3f}% > {MAX_OFF_FRAC * 100:.0f}% "
            f"of the untraced runtime")
        assert r["on_overhead_frac"] <= MAX_ON_FRAC, (
            f"{r['cell']}: enabled tracing costs "
            f"{r['on_overhead_frac'] * 100:.1f}% > "
            f"{MAX_ON_FRAC * 100:.0f}% of the untraced runtime")
    worst_off = max(r["off_overhead_frac"] for r in rows)
    worst_on = max(r["on_overhead_frac"] for r in rows)
    print(f"check ok: tracing-off <= {MAX_OFF_FRAC * 100:.0f}% "
          f"(worst {worst_off * 100:.4f}%), tracing-on <= "
          f"{MAX_ON_FRAC * 100:.0f}% (worst {worst_on * 100:.2f}%)")


def main(argv=None) -> List[Dict]:
    ap = argparse.ArgumentParser()
    ap.add_argument("--repeats", type=int, default=7)
    ap.add_argument("--duration", type=float, default=300.0,
                    help="sim cell virtual duration (ms)")
    ap.add_argument("--pods", type=int, default=4)
    ap.add_argument("--sessions", type=int, default=64)
    ap.add_argument("--steps", type=int, default=40)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true",
                    help="CI scale: 2 pods, 8 sessions, 10 steps, 120ms sim")
    ap.add_argument("--check", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)
    if args.smoke:
        args.pods, args.sessions, args.steps = 2, 8, 10
        args.duration, args.repeats = 120.0, 3

    print("cell,t_off,t_on,events,off_overhead,on_overhead")
    rows = [
        run_cell("serve", lambda trace: _serve_run(
            trace=trace, pods=args.pods, sessions=args.sessions,
            steps=args.steps, seed=args.seed), args.repeats),
        run_cell("sim", lambda trace: _sim_run(
            trace=trace, duration=args.duration, seed=args.seed),
            args.repeats),
    ]
    if args.check:
        check(rows)
    if args.out:
        with open(args.out, "w") as f:
            json.dump({"bench": "obs_overhead", "rows": rows}, f, indent=1)
        print(f"wrote {args.out}")
    return rows


if __name__ == "__main__":
    main()
