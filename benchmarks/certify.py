"""Certification throughput sweep: batch × read-set length × backend.

Measures the commit-phase hot loop the batched pipeline replaces — for
each (batch size, read-set length) cell, certify the same transaction
batch with

* ``loop``   — the pre-refactor per-transaction path, reproduced verbatim:
  ``ReadSetEntry`` records walked one at a time with python/numpy-scalar
  compares, exactly what ``cluster._validate_and_commit`` ran before the
  batched drain existed;
* ``jnp``    — ``validate_batch``: compact read-log buffers packed into
  power-of-two buckets + one jit'd gather/compare dispatch (cells run
  lock-free, the common case — write packing only engages when locks are
  passed; tests/test_certify.py covers the locked path);
* ``pallas`` — the same packed arrays through the Pallas kernel
  (``interpret=True`` off-TPU, so off-TPU numbers are correctness smoke,
  not perf).

Timings include packing — the batched number is the end-to-end cost of a
drain, not just the kernel.  Writes a ``BENCH_certify.json`` trajectory
artifact (CI uploads it; ``results/BENCH_certify.json`` tracks it in-repo)
and, with ``--check``, enforces the pipeline's acceptance floor: the jnp
backend reaches >= 5x ``loop`` throughput in the batch >= 64 regime
(small batches can't amortize the dispatch; the grid shows each cell).
"""
from __future__ import annotations

import argparse
import json
import time
from typing import Dict, List

import numpy as np

from repro.core.stm import Transaction, VersionedStore, validate_batch


def make_batch(n_items: int, batch: int, read_len: int, write_len: int,
               seed: int = 0):
    """A store plus ``batch`` transactions with mostly-valid read sets.

    Returns ``(store, txns, recs)``: ``txns`` carry the pipeline's compact
    read logs, ``recs`` the same reads as the legacy ``ReadSetEntry``
    record lists the old loop walked.
    """
    rng = np.random.default_rng(seed)
    store = VersionedStore(n_items)
    store.versions[:] = rng.integers(0, 50, n_items)
    txns, recs = [], []
    for i in range(batch):
        t = Transaction(txid=i + 1, origin=0)
        stale = rng.integers(read_len) if rng.random() < 0.02 else -1
        for j, it in enumerate(rng.integers(0, n_items, read_len)):
            ver = int(store.versions[it])
            if j == stale:                   # ~2% stale txns -> aborts
                ver -= 1
            t.log_read(int(it), ver)
        for it in rng.integers(0, n_items, write_len):
            t.write_set[int(it)] = float(i)
        txns.append(t)
        recs.append(t.read_set)              # materialized record view
    return store, txns, recs


def legacy_validate(versions: np.ndarray, recs) -> bool:
    """The seed's one-at-a-time TL2 check (pre-batching ``validate``)."""
    for e in recs:
        if int(versions[e.item]) != e.version:
            return False
    return True


def bench_cell(store, txns, recs, backend: str, *, iters: int,
               locks: np.ndarray) -> Dict:
    """Certify the batch ``iters`` times; returns txns/s and the verdicts."""
    if backend == "loop":
        def run():
            versions = store.versions
            return [legacy_validate(versions, rs) for rs in recs]
    else:
        def run():
            return validate_batch(store, txns, locks=locks, backend=backend)
    ref = np.asarray(run())                  # warm the jit caches
    t0 = time.perf_counter()
    for _ in range(iters):
        out = run()
    dt = time.perf_counter() - t0
    assert np.array_equal(np.asarray(out), ref)
    return {"txns_per_s": len(txns) * iters / dt,
            "abort_rate": 1.0 - float(ref.mean())}


def main(argv=None) -> Dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--batches", nargs="*", type=int,
                    default=[16, 64, 256, 1024])
    ap.add_argument("--read-lens", nargs="*", type=int,
                    default=[16, 64, 256])
    ap.add_argument("--backends", nargs="*",
                    default=["loop", "jnp", "pallas"])
    ap.add_argument("--n-items", type=int, default=4096)
    ap.add_argument("--iters", type=int, default=30)
    ap.add_argument("--out", default="BENCH_certify.json")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny grid for CI: batches 64/1024, read len 256")
    ap.add_argument("--check", action="store_true",
                    help="fail unless jnp >= 5x loop at batch >= 64")
    args = ap.parse_args(argv)
    if args.smoke:
        args.batches, args.read_lens = [64, 1024], [256]
        args.iters = 10

    rows: List[Dict] = []
    print("backend,batch,read_len,txns_per_s,abort_rate,speedup_vs_loop")
    for batch in args.batches:
        for r in args.read_lens:
            store, txns, recs = make_batch(args.n_items, batch, r,
                                           max(1, r // 4))
            locks = None                     # lock-free cells (common case)
            base = None
            for backend in args.backends:
                cell = bench_cell(store, txns, recs, backend,
                                  iters=args.iters, locks=locks)
                if backend == "loop":
                    base = cell["txns_per_s"]
                speedup = cell["txns_per_s"] / base if base else float("nan")
                rows.append({"backend": backend, "batch": batch,
                             "read_len": r, **cell, "speedup_vs_loop": speedup})
                print(f"{backend},{batch},{r},{cell['txns_per_s']:.0f},"
                      f"{cell['abort_rate']:.3f},{speedup:.2f}", flush=True)

    out = {
        "bench": "certify",
        "n_items": args.n_items,
        "iters": args.iters,
        "rows": rows,
    }
    checked = [x for x in rows
               if x["backend"] == "jnp" and x["batch"] >= 64]
    if checked:
        best = max(checked, key=lambda x: x["speedup_vs_loop"])
        out["best_jnp_speedup_batch_ge_64"] = best["speedup_vs_loop"]
        out["best_jnp_cell"] = {"batch": best["batch"],
                                "read_len": best["read_len"]}
        print(f"best jnp speedup at batch>=64: "
              f"{best['speedup_vs_loop']:.2f}x "
              f"(batch={best['batch']}, read_len={best['read_len']})")
    with open(args.out, "w") as f:
        json.dump(out, f, indent=2)
    print(f"wrote {args.out}")
    if args.check:
        assert checked and out["best_jnp_speedup_batch_ge_64"] >= 5.0, \
            f"jnp speedup below 5x: {out.get('best_jnp_speedup_batch_ge_64')}"
    return out


if __name__ == "__main__":
    main()
