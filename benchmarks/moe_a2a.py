"""tp-aware MoE token-a2a vs replicated dispatch — the raw-speed bench.

Times one MoE layer under both dispatch plans on an 8-device host mesh
(2 data × 4 model), for the two chunk layouts the a2a path now covers:

* **mixtral-style** (``n_experts > model_size``): whole experts per model
  rank (ep=4, tp=1) — the layout the a2a path always handled;
* **deepseek-style** (``model_size > n_experts``): each expert's FFN split
  over tp ranks (ep=2, tp=2) — newly reachable via chunk dispatch + the
  partial-activation psum combine.

Per cell it also records the :func:`repro.dist.locality.price_moe_dispatch`
verdict (with the new ``tp_degree`` psum term): the autotuner's feasibility
frontier, re-run over the (tokens_per_device, ep, tp) grid.  The committed
``results/BENCH_moe_a2a.json`` is re-validated by ``benchmarks/run.py
--check``: every autotuned cell must hold a noise floor against the
replicated path, the autotuned geomean speedup must be ≥ 1, and at least
one deepseek-style (tp > 1) cell must strictly beat replication — the
newly-reachable layout has to actually pay.  (On the host-CPU mesh the
a2a's wire advantage is a memcpy, so large-token cells converge to
compute-bound parity; the wins concentrate where dispatch pricing says
they should — smaller token counts, where replication's redundant
routing+FFN work dominates.)

The process forces 8 host devices BEFORE importing jax (same pattern as
``launch/dryrun.py``); run it standalone, not from a jax-importing parent.
"""
from __future__ import annotations

import os

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

import argparse          # noqa: E402
import dataclasses       # noqa: E402
import json              # noqa: E402
import time              # noqa: E402
from typing import Dict, List  # noqa: E402

import jax               # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np       # noqa: E402


def _mesh():
    from jax.sharding import Mesh

    devs = np.array(jax.devices())
    assert devs.size >= 8, (
        "moe_a2a bench needs 8 host devices; do not import jax before this "
        "module sets XLA_FLAGS")
    return Mesh(devs[:8].reshape(2, 4), ("data", "model"))


def _cell_cfg(style: str):
    """Synthetic layer dims big enough for timing to mean something on CPU."""
    from repro.models.common import ModelConfig, MoEConfig

    if style == "mixtral":
        moe = MoEConfig(n_experts=8, top_k=2, d_expert=512)
    elif style == "deepseek":
        moe = MoEConfig(n_experts=2, top_k=2, d_expert=1024)
    else:
        raise ValueError(style)
    return ModelConfig(
        name=f"a2a-bench-{style}", family="moe", n_layers=1, d_model=256,
        n_heads=4, n_kv_heads=2, head_dim=64, d_ff=512, vocab_size=256,
        dtype="float32", moe=moe)


def run_cell(style: str, tokens: int, *, reps: int = 5) -> Dict[str, float]:
    from repro.models import moe
    from repro.models.common import chunk_plan

    cfg = _cell_cfg(style)
    m = cfg.moe
    mesh = _mesh()
    ep, tp, n_e, _ = chunk_plan(m.n_experts, 4)
    rng = np.random.default_rng(0)
    d, f = cfg.d_model, m.d_expert
    router = jnp.asarray(rng.standard_normal((d, m.n_experts)) * 0.1,
                         jnp.float32)
    wg = jnp.asarray(rng.standard_normal((m.n_experts, d, f)) * 0.05,
                     jnp.float32)
    wu = jnp.asarray(rng.standard_normal((m.n_experts, d, f)) * 0.05,
                     jnp.float32)
    wd = jnp.asarray(rng.standard_normal((m.n_experts, f, d)) * 0.05,
                     jnp.float32)
    cg, cu, cdn = moe.to_chunked(wg, wu, wd, model_size=4)
    p = {"router": router,
         "experts": {"w_gate": cg, "w_up": cu, "w_down": cdn}}
    x = jnp.asarray(rng.standard_normal((8, tokens // 8, d)), jnp.float32)

    def timed(dispatch: str) -> float:
        with mesh:
            fn = jax.jit(lambda xx: moe.moe_apply(
                p, xx, cfg, mesh, dispatch=dispatch, batch_axes=("data",)))
            fn(x).block_until_ready()          # compile
            ts = []
            for _ in range(reps):
                t0 = time.perf_counter()
                fn(x).block_until_ready()
                ts.append(time.perf_counter() - t0)
        return float(np.median(ts))

    t_rep = timed("replicate")
    t_a2a = timed("a2a")
    shards, ep_, tp_, t_pad = moe._a2a_plan(cfg, tokens, mesh, ("data",),
                                            "model")
    verdict = moe.dispatch_verdict(cfg, t_pad // shards, ep_, tp_)
    return {
        "style": style, "tokens": tokens, "ep": ep, "tp": tp,
        "d_model": d, "d_expert": f, "top_k": m.top_k,
        "n_experts": m.n_experts,
        "replicate_s": t_rep, "a2a_s": t_a2a,
        "replicate_tokens_per_s": tokens / t_rep,
        "a2a_tokens_per_s": tokens / t_a2a,
        "a2a_speedup": t_rep / t_a2a,
        "verdict_a2a": bool(verdict),
    }


MIN_CELL_SPEEDUP = 0.95   # noise floor at parity cells (CPU timing jitter)


def check(rows: List[Dict]) -> None:
    styles = {r["style"] for r in rows}
    assert "deepseek" in styles, "no deepseek-style (tp>1) cell in the grid"
    tuned = [r for r in rows if r["verdict_a2a"]]
    assert tuned, "autotuner never picked a2a — pricing regressed"
    for r in tuned:
        assert r["a2a_speedup"] >= MIN_CELL_SPEEDUP, (
            f"{r['style']}@{r['tokens']}: a2a "
            f"{r['a2a_tokens_per_s']:.0f} tok/s vs replicate "
            f"{r['replicate_tokens_per_s']:.0f} "
            f"({r['a2a_speedup']:.2f}x < {MIN_CELL_SPEEDUP}) at an "
            f"autotuned cell")
    geo = float(np.exp(np.mean([np.log(r["a2a_speedup"]) for r in tuned])))
    assert geo >= 1.0, f"autotuned geomean speedup {geo:.3f}x < 1.0"
    ds = [r for r in tuned if r["tp"] > 1]
    assert ds, "no autotuned deepseek-style (tp>1) cell"
    best = max(ds, key=lambda r: r["a2a_speedup"])
    assert best["a2a_speedup"] > 1.0, (
        f"tp>1 a2a never beat replication (best {best['a2a_speedup']:.2f}x "
        f"at {best['tokens']} tokens)")
    worst = min(tuned, key=lambda r: r["a2a_speedup"])
    print(f"check ok: {len(tuned)} autotuned cells, geomean {geo:.2f}x, "
          f"worst {worst['a2a_speedup']:.2f}x "
          f"({worst['style']}@{worst['tokens']}), best tp>1 "
          f"{best['a2a_speedup']:.2f}x @{best['tokens']}")


def main(argv=None) -> List[Dict]:
    ap = argparse.ArgumentParser()
    ap.add_argument("--tokens", nargs="*", type=int, default=[1024, 4096])
    ap.add_argument("--reps", type=int, default=11)
    ap.add_argument("--smoke", action="store_true",
                    help="one token size, fewer reps")
    ap.add_argument("--check", action="store_true")
    ap.add_argument("--out", default="BENCH_moe_a2a.json")
    args = ap.parse_args(argv)
    if args.smoke:
        args.tokens, args.reps = [1024], 3

    rows = []
    print("style,tokens,ep,tp,replicate_tok_s,a2a_tok_s,speedup,verdict_a2a")
    for style in ("mixtral", "deepseek"):
        for t in args.tokens:
            r = run_cell(style, t, reps=args.reps)
            rows.append(r)
            print(f"{style},{t},{r['ep']},{r['tp']},"
                  f"{r['replicate_tokens_per_s']:.0f},"
                  f"{r['a2a_tokens_per_s']:.0f},{r['a2a_speedup']:.2f},"
                  f"{int(r['verdict_a2a'])}", flush=True)

    art = {"bench": "moe_a2a", "mesh": "2x4 host", "reps": args.reps,
           "smoke": args.smoke, "rows": rows}
    with open(args.out, "w") as f:
        json.dump(art, f, indent=2)
    print(f"wrote {args.out}")
    if args.check:
        check(rows)
    return rows


if __name__ == "__main__":
    main()
