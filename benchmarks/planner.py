"""Planner-on vs planner-off sweep — the proactive loop's acceptance bench.

Runs the ``benchmarks/serve_locality.py`` cells (same ``run_point``, same
``ROUTER_DEFAULTS`` router) with and without a
:class:`repro.plan.PlacementPlanner` attached, across locality mixes and
seeds.  The traffic defaults are the *deep* variant of the locality cells
(fewer sessions, more steps → ~25 touches per session): affinity-driven
placement needs sessions that live long enough for their access pattern to
be evidence rather than noise — exactly the long-lived chat sessions the
serving stack targets — and at the default 5-touch depth the planner's
evidence gates correctly keep it idle.

Acceptance (``--check``, 3-seed averages):

* high-locality cells (P ≥ 0.7): planner-enabled runs ship **less total
  wire** and **fewer forwards** than ``ROUTER_DEFAULTS`` alone — the
  planner re-homes misplaced sessions early (small caches, off the
  critical path) and replaces the valve's reactive panic-acquires of
  grown caches with budgeted moves;
* P = 0 (no locality): tokens/s no worse than parity — the evidence
  gates (``min_events``, ``min_frac`` dominance) keep the planner idle
  when there is nothing to exploit.

The artifact also carries an **overlap** cell: how much of a plan epoch's
scoring wall-time the async split (``PlacementPlanner.begin``/``finish``)
takes *off* the decode step loop.  It times the synchronous
``score_moves`` (dispatch + materialize) against the async protocol —
kick, overlapped host work standing in for decode steps, harvest — at the
serving planner's pow2-padded [class, target] shape, sharded over the
plan mesh.  ``--check`` enforces ``off_path_frac ≥ 0.8``: at least 80% of
scoring wall-time overlaps decode, the PR's async-planner acceptance band.

Writes a ``BENCH_planner.json`` trajectory artifact (CI uploads it;
``results/BENCH_planner.json`` tracks a full run in-repo).  ``--smoke``
shrinks the grid for CI so the sweep can't silently rot.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys
import time
from typing import Dict, List

import numpy as np

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

from serve_locality import run_point  # noqa: E402

from repro.dist.locality import ROUTER_DEFAULTS  # noqa: E402
from repro.plan import SERVE_PLAN_DEFAULTS  # noqa: E402


def sweep(arch: str, localities: List[float], *, n_pods: int, n_sessions: int,
          steps: int, seeds: int, plan_epoch_ms: float) -> List[Dict]:
    rows = []
    print("arch,planner,locality,tokens_per_s,wire_GB,forwards,fw_rate,"
          "transfers,plan_moves,plan_prefetches,plan_GB")
    requests = float(steps * 2 * n_pods)
    for planner_on in (False, True):
        for p in localities:
            r = run_point(
                arch, ROUTER_DEFAULTS.policy, p, n_pods=n_pods,
                n_sessions=n_sessions, steps=steps, seeds=seeds,
                arbitration=ROUTER_DEFAULTS.arbitration,
                plan_epoch_ms=plan_epoch_ms if planner_on else 0.0)
            row = {"planner": planner_on, "locality": p,
                   "fw_rate": r["forwards"] / requests, **r}
            rows.append(row)
            print(f"{arch},{int(planner_on)},{p},{r['tokens_per_s']:.0f},"
                  f"{r['wire_GB']:.4f},{r['forwards']:.0f},"
                  f"{row['fw_rate']:.3f},{r['transfers']:.0f},"
                  f"{r['plan_moves']:.0f},{r['plan_prefetches']:.0f},"
                  f"{r['plan_GB']:.4f}", flush=True)
    return rows


MIN_OFF_PATH_FRAC = 0.8   # async split must hide ≥80% of scoring wall-time


def overlap_cell(*, n_classes: int = 1 << 17, n_nodes: int = 16,
                 reps: int = 5) -> Dict[str, float]:
    """Time sync vs async (kick → overlapped decode work → harvest) scoring.

    The decode stand-in is plain numpy host work, like the engine's step
    loop between epoch boundaries; jax's async dispatch evaluates the
    sharded scoring underneath it, so the step loop only pays the kick
    (input snapshot + dispatch) and the harvest (materialize + bound).
    """
    from repro.dist.sharding import make_plan_mesh
    from repro.plan.score import score_moves, score_moves_async

    mesh = make_plan_mesh()
    rng = np.random.default_rng(0)
    # float32 like AffinityTracker.rates — the scorer's input boundary
    rates = (rng.random((n_classes, n_nodes)) * 0.05).astype(np.float32)
    owner = rng.integers(0, n_nodes, n_classes).astype(np.int32)
    # float32 like price_move_costs — the other scorer input boundary
    fwd_cost = np.full(n_classes, 2e-4, np.float32)
    move_cost = np.full(n_classes, 1e-3, np.float32)
    cpu = (rng.random(n_nodes) * 0.5).astype(np.float64)
    kw = dict(horizon_ms=500.0, margin=3.0, min_frac=0.7, min_rate=0.016,
              load_gain=0.02, mesh=mesh)
    decode = [np.ones(1 << 20) for _ in range(2)]

    def decode_steps(n: int = 12) -> float:
        t0 = time.perf_counter()
        for _ in range(n):
            decode[0] = decode[0] + decode[1]
        return time.perf_counter() - t0

    score_moves(rates, owner, fwd_cost, move_cost, cpu, **kw)   # warm jit
    t_sync = t_kick = t_harvest = t_decode = 0.0
    for _ in range(reps):
        t0 = time.perf_counter()
        score_moves(rates, owner, fwd_cost, move_cost, cpu, **kw)
        t_sync += time.perf_counter() - t0

        t0 = time.perf_counter()
        fut = score_moves_async(rates, owner, fwd_cost, move_cost, cpu, **kw)
        t_kick += time.perf_counter() - t0
        t_decode += decode_steps()            # scoring runs under this
        t0 = time.perf_counter()
        np.asarray(fut)
        t_harvest += time.perf_counter() - t0
    t_sync, t_kick, t_harvest, t_decode = (
        t / reps for t in (t_sync, t_kick, t_harvest, t_decode))
    on_path = t_kick + t_harvest
    return {
        "n_classes": n_classes, "n_nodes": n_nodes, "reps": reps,
        "plan_mesh_devices": 1 if mesh is None else int(mesh.size),
        "sync_s": t_sync, "kick_s": t_kick, "harvest_s": t_harvest,
        "decode_work_s": t_decode,
        "off_path_frac": 1.0 - on_path / max(t_sync, 1e-12),
    }


def check(rows: List[Dict], localities: List[float], *, smoke: bool,
          overlap: Dict[str, float] | None = None) -> None:
    by = {(r["planner"], r["locality"]): r for r in rows}
    hi = [p for p in localities if p >= 0.7]
    if smoke:
        # CI-sized grids are too small for stable wire/forward deltas — pin
        # that the planner actually ran and nothing regressed wildly
        for p in localities:
            on = by[(True, p)]
            assert on["tokens_per_s"] > 0
        if overlap is not None:
            assert overlap["off_path_frac"] > 0.0, (
                f"async scoring saved nothing off the step loop "
                f"({overlap['off_path_frac']:.2f})")
        print("smoke check ok: planner path exercised on the full grid")
        return
    if overlap is not None:
        assert overlap["off_path_frac"] >= MIN_OFF_PATH_FRAC, (
            f"async split leaves {1 - overlap['off_path_frac']:.0%} of "
            f"scoring wall-time on the step loop (need ≤ "
            f"{1 - MIN_OFF_PATH_FRAC:.0%}): kick {overlap['kick_s']*1e3:.2f}"
            f"ms + harvest {overlap['harvest_s']*1e3:.2f}ms vs sync "
            f"{overlap['sync_s']*1e3:.2f}ms")
    for p in hi:
        off, on = by[(False, p)], by[(True, p)]
        assert on["wire_GB"] < off["wire_GB"], (
            f"P={p}: planner wire {on['wire_GB']:.4f} !< {off['wire_GB']:.4f}")
        assert on["forwards"] < off["forwards"], (
            f"P={p}: planner forwards {on['forwards']:.0f} !< "
            f"{off['forwards']:.0f}")
    lo = min(localities)
    off, on = by[(False, lo)], by[(True, lo)]
    assert on["tokens_per_s"] >= 0.97 * off["tokens_per_s"], (
        f"P={lo}: planner tokens/s {on['tokens_per_s']:.0f} below parity "
        f"with {off['tokens_per_s']:.0f}")
    print(f"check ok: wire+forwards reduced at P>={min(hi)}, "
          f"tokens/s parity at P={lo}")


def main(argv=None) -> List[Dict]:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mixtral-8x7b")
    ap.add_argument("--localities", nargs="*", type=float,
                    default=[0.0, 0.7, 0.9])
    ap.add_argument("--pods", type=int, default=8)
    ap.add_argument("--sessions", type=int, default=96)
    ap.add_argument("--steps", type=int, default=160)
    ap.add_argument("--seeds", type=int, default=3)
    ap.add_argument("--plan-epoch-ms", type=float,
                    default=SERVE_PLAN_DEFAULTS.epoch_ms)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny grid for CI: 2 pods, 8 sessions, 20 steps")
    ap.add_argument("--check", action="store_true",
                    help="enforce the acceptance deltas")
    ap.add_argument("--out", default="BENCH_planner.json")
    args = ap.parse_args(argv)
    if args.smoke:
        args.pods, args.sessions, args.steps, args.seeds = 2, 8, 20, 1

    rows = sweep(args.arch, args.localities, n_pods=args.pods,
                 n_sessions=args.sessions, steps=args.steps,
                 seeds=args.seeds, plan_epoch_ms=args.plan_epoch_ms)
    overlap = overlap_cell(n_classes=1 << 14 if args.smoke else 1 << 17,
                           reps=3 if args.smoke else 5)
    print(f"overlap: sync {overlap['sync_s']*1e3:.2f}ms, kick "
          f"{overlap['kick_s']*1e3:.2f}ms, harvest "
          f"{overlap['harvest_s']*1e3:.2f}ms, off_path "
          f"{overlap['off_path_frac']:.1%}")
    art = {
        "bench": "planner", "arch": args.arch, "pods": args.pods,
        "sessions": args.sessions, "steps": args.steps, "seeds": args.seeds,
        "plan_epoch_ms": args.plan_epoch_ms, "smoke": args.smoke,
        "plan_defaults": {
            k: (v if not isinstance(v, float) or abs(v) != float("inf")
                else str(v))
            for k, v in dataclasses.asdict(SERVE_PLAN_DEFAULTS).items()
        },
        "overlap": overlap,
        "rows": rows,
    }
    with open(args.out, "w") as f:
        json.dump(art, f, indent=2)
    print(f"wrote {args.out}")
    if args.check:
        check(rows, args.localities, smoke=args.smoke, overlap=overlap)
    return rows


if __name__ == "__main__":
    main()
