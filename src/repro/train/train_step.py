"""The jit-able train step: loss, grads, microbatching, optimizer update.

``make_train_step`` closes over static config and returns a function
``(params, opt_state, batch) -> (params, opt_state, metrics)`` suitable for
``jax.jit`` with donated params/opt_state.  Gradient accumulation over
microbatches uses ``lax.scan`` so HLO size is independent of the
accumulation factor.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import decoder
from repro.models.common import ModelConfig
from . import optimizer as opt


@dataclass(frozen=True)
class TrainConfig:
    opt: opt.OptConfig = opt.OptConfig()
    microbatches: int = 1           # gradient-accumulation factor
    param_dtype: str = "float32"


def make_train_step(
    cfg: ModelConfig,
    ctx: decoder.RunCtx,
    tcfg: TrainConfig = TrainConfig(),
) -> Callable:
    cdt = cfg.compute_dtype()

    def loss_of(params, batch):
        # cast the fp32 masters to compute dtype ONCE, before the layer scan:
        # the ZeRO-3 all-gathers then move bf16, not fp32 (2x wire saving);
        # grads flow back through the convert into the fp32 masters.
        params_c = jax.tree.map(
            lambda a: a.astype(cdt)
            if jnp.issubdtype(a.dtype, jnp.floating) else a, params)
        loss, aux = decoder.loss_fn(cfg, ctx, params_c, batch)
        return loss, aux

    grad_fn = jax.value_and_grad(loss_of, has_aux=True)

    def train_step(params, opt_state, batch):
        if tcfg.microbatches <= 1:
            (loss, aux), grads = grad_fn(params, batch)
        else:
            # split the batch leading dim into microbatches and scan
            def resh(x):
                b = x.shape[0] if x.ndim >= 1 else 1
                mb = tcfg.microbatches
                if x.ndim == 0:
                    return x
                # positions for M-RoPE carry a leading 3; split axis 1 then
                if x.shape[0] == 3 and x.ndim == 3:
                    return x.reshape(3, mb, x.shape[1] // mb, x.shape[2]).swapaxes(0, 1)
                return x.reshape(mb, b // mb, *x.shape[1:])

            micro = jax.tree.map(resh, batch)

            def acc_fn(carry, mb_batch):
                g_acc, l_acc = carry
                (l, _), g = grad_fn(params, mb_batch)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), g_acc, g)
                return (g_acc, l_acc + l), None

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss_sum), _ = jax.lax.scan(acc_fn, (g0, 0.0), micro)
            inv = 1.0 / tcfg.microbatches
            grads = jax.tree.map(lambda g: g * inv, grads)
            loss = loss_sum * inv
            aux = {"loss": loss}

        new_params, new_state, om = opt.update(tcfg.opt, params, grads, opt_state)
        metrics = {"loss": loss, **om}
        return new_params, new_state, metrics

    return train_step


def make_eval_step(cfg: ModelConfig, ctx: decoder.RunCtx) -> Callable:
    def eval_step(params, batch):
        loss, aux = decoder.loss_fn(cfg, ctx, params, batch)
        return aux

    return eval_step
