"""Fault-tolerant checkpointing: atomic two-phase commit + async writer.

Layout::

    <dir>/step_000120/          # one directory per step
        manifest.json           # tree structure, shapes, dtypes
        leaf_00000.npy ...      # row-major leaves
    <dir>/step_000120.COMMITTED # phase-2 marker (rename-based atomicity)

* ``save`` writes into ``step_X.tmp/``, fsyncs, renames to ``step_X/`` and
  only then drops the ``.COMMITTED`` marker — a crash at any point leaves
  either a complete committed checkpoint or ignorable garbage.
* ``AsyncCheckpointer`` moves serialization off the training thread
  (device→host copy happens synchronously, disk I/O in a worker).
* ``restore`` loads the newest committed step and re-shards onto the
  current mesh (elastic restart: the target sharding may differ from the
  one that wrote the checkpoint).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import queue
from pathlib import Path
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _tree_to_manifest(tree: Any) -> Tuple[Dict, list]:
    leaves, treedef = jax.tree.flatten(tree)
    manifest = {
        "treedef": str(treedef),
        "leaves": [
            {"shape": list(np.shape(l)), "dtype": str(np.asarray(l).dtype)}
            for l in leaves
        ],
    }
    return manifest, leaves


def save(ckpt_dir: str | Path, step: int, tree: Any) -> Path:
    """Synchronous atomic save; returns the committed directory."""
    ckpt_dir = Path(ckpt_dir)
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    name = f"step_{step:08d}"
    tmp = ckpt_dir / (name + ".tmp")
    final = ckpt_dir / name
    marker = ckpt_dir / (name + ".COMMITTED")
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir()

    manifest, leaves = _tree_to_manifest(tree)
    for i, leaf in enumerate(leaves):
        arr = np.asarray(leaf)
        with open(tmp / f"leaf_{i:05d}.npy", "wb") as f:
            np.save(f, arr)
            f.flush()
            os.fsync(f.fileno())
    with open(tmp / "manifest.json", "w") as f:
        json.dump({**manifest, "step": step}, f)
        f.flush()
        os.fsync(f.fileno())
    if final.exists():
        shutil.rmtree(final)
    os.rename(tmp, final)                 # phase 1: data in place
    marker.touch()                        # phase 2: commit point
    return final


def committed_steps(ckpt_dir: str | Path) -> list:
    ckpt_dir = Path(ckpt_dir)
    if not ckpt_dir.exists():
        return []
    steps = []
    for m in ckpt_dir.glob("step_*.COMMITTED"):
        s = int(m.name.removesuffix(".COMMITTED").removeprefix("step_"))
        if (ckpt_dir / f"step_{s:08d}").exists():
            steps.append(s)
    return sorted(steps)


def latest_step(ckpt_dir: str | Path) -> Optional[int]:
    steps = committed_steps(ckpt_dir)
    return steps[-1] if steps else None


def restore(
    ckpt_dir: str | Path,
    like: Any,
    step: Optional[int] = None,
    shardings: Any = None,
) -> Tuple[Any, int]:
    """Restore the newest (or given) committed step into ``like``'s structure.

    ``shardings`` (optional pytree of NamedSharding) re-shards onto the
    current mesh — this is the elastic-restart path.
    """
    ckpt_dir = Path(ckpt_dir)
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint under {ckpt_dir}")
    d = ckpt_dir / f"step_{step:08d}"
    leaves_like, treedef = jax.tree.flatten(like)
    n = len(leaves_like)
    out = []
    shard_leaves = (
        treedef.flatten_up_to(shardings) if shardings is not None else [None] * n
    )
    for i in range(n):
        arr = np.load(d / f"leaf_{i:05d}.npy")
        want = leaves_like[i]
        if hasattr(want, "dtype"):
            arr = arr.astype(want.dtype)
        sh = shard_leaves[i]
        out.append(jax.device_put(arr, sh) if sh is not None else arr)
    return treedef.unflatten(out), step


def prune(ckpt_dir: str | Path, keep: int = 3) -> None:
    steps = committed_steps(ckpt_dir)
    for s in steps[:-keep]:
        shutil.rmtree(Path(ckpt_dir) / f"step_{s:08d}", ignore_errors=True)
        (Path(ckpt_dir) / f"step_{s:08d}.COMMITTED").unlink(missing_ok=True)


class AsyncCheckpointer:
    """Background writer: ``submit`` copies to host then queues the disk I/O."""

    def __init__(self, ckpt_dir: str | Path, keep: int = 3) -> None:
        self.ckpt_dir = Path(ckpt_dir)
        self.keep = keep
        self._q: "queue.Queue[Optional[Tuple[int, Any]]]" = queue.Queue(maxsize=2)
        self._errors: list = []
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self) -> None:
        while True:
            item = self._q.get()
            if item is None:
                return
            step, host_tree = item
            try:
                save(self.ckpt_dir, step, host_tree)
                prune(self.ckpt_dir, self.keep)
            except Exception as e:  # surfaced on next submit/close
                self._errors.append(e)

    def submit(self, step: int, tree: Any) -> None:
        if self._errors:
            raise RuntimeError(f"async checkpoint failed: {self._errors[0]}")
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)
        self._q.put((step, host_tree))

    def close(self) -> None:
        self._q.put(None)
        self._thread.join()
        if self._errors:
            raise RuntimeError(f"async checkpoint failed: {self._errors[0]}")
