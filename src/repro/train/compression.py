"""Error-feedback int8 gradient compression for the DP axis.

At 1000+ nodes the DP gradient reduce is DCN-bound; int8 quantization cuts
wire bytes 4× (vs fp32) with *error feedback* (the quantization residual is
carried into the next step) keeping convergence unbiased in practice.

Mechanics (per tensor, per step)::

    g_corr = g + residual              # apply carried error
    scale  = max|g_corr| / 127
    q      = round(g_corr / scale)     # int8
    residual' = g_corr - q * scale     # what got lost
    wire   = psum(q)  (int32 accum)    # 1 byte/elem on the wire
    g_out  = wire * scale_mean / n

Exposed two ways:

* :func:`compress` / :func:`decompress` — host/SPMD-agnostic tensor math
  (unit-testable, used by the trainer's gradient hook);
* :func:`compressed_psum` — the shard_map collective: quantize locally,
  ``psum`` the int32 accumulator over the data axis, dequantize.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class Compressed(NamedTuple):
    q: jax.Array          # int8 payload
    scale: jax.Array      # f32 scalar per tensor


def compress(g: jax.Array, residual: jax.Array) -> Tuple[Compressed, jax.Array]:
    g_corr = g.astype(jnp.float32) + residual
    amax = jnp.max(jnp.abs(g_corr))
    scale = jnp.maximum(amax, 1e-30) / 127.0
    q = jnp.clip(jnp.round(g_corr / scale), -127, 127).astype(jnp.int8)
    new_residual = g_corr - q.astype(jnp.float32) * scale
    return Compressed(q, scale), new_residual


def decompress(c: Compressed) -> jax.Array:
    return c.q.astype(jnp.float32) * c.scale


def init_residuals(grads: Any) -> Any:
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def compress_tree(grads: Any, residuals: Any) -> Tuple[Any, Any]:
    """Tree version; returns (compressed tree, new residual tree)."""
    flat_g, treedef = jax.tree.flatten(grads)
    flat_r = treedef.flatten_up_to(residuals)
    outs = [compress(g, r) for g, r in zip(flat_g, flat_r)]
    comp = treedef.unflatten([o[0] for o in outs])
    res = treedef.unflatten([o[1] for o in outs])
    return comp, res


def decompress_tree(comp: Any) -> Any:
    return jax.tree.map(
        lambda c: decompress(c), comp,
        is_leaf=lambda x: isinstance(x, Compressed),
    )


def compressed_psum(g: jax.Array, residual: jax.Array, axis: str):
    """Inside shard_map: int8-on-the-wire mean over ``axis``.

    Each shard quantizes its local gradient (with error feedback), the
    int8 payloads are summed in int32 (the all-reduce moves 1B/elem +
    one f32 scale), and the mean is rebuilt with the max scale.
    """
    # psum of ones == axis size (jax.lax.axis_size only exists on newer jax)
    n = jax.lax.psum(jnp.ones((), jnp.float32), axis)
    c, new_res = compress(g, residual)
    # use the max scale across shards so the int32 sum is consistent
    scale = jax.lax.pmax(c.scale, axis)
    q = jnp.clip(jnp.round((decompress(c)) / scale), -127, 127).astype(jnp.int8)
    total = jax.lax.psum(q.astype(jnp.int32), axis)
    return total.astype(jnp.float32) * scale / n, new_res
