"""AdamW (from scratch — no optax on the image) + schedule + global clip.

States mirror the parameter tree so every sharding rule that applies to a
parameter applies verbatim to its ``m``/``v`` slots (ZeRO: optimizer state is
FSDP-sharded exactly like the weights).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    betas: Tuple[float, float] = (0.9, 0.95)
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    schedule: str = "cosine"       # "cosine" | "linear" | "const"


class OptState(NamedTuple):
    m: Any
    v: Any
    count: jax.Array


def init(params: Any) -> OptState:
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
    return OptState(m=zeros, v=jax.tree.map(jnp.copy, zeros),
                    count=jnp.zeros((), jnp.int32))


def schedule_lr(cfg: OptConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(1.0, (step + 1.0) / max(1, cfg.warmup_steps))
    t = jnp.clip(
        (step - cfg.warmup_steps) / max(1, cfg.total_steps - cfg.warmup_steps),
        0.0, 1.0,
    )
    if cfg.schedule == "cosine":
        decay = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    elif cfg.schedule == "linear":
        decay = 1.0 - (1 - cfg.min_lr_frac) * t
    else:
        decay = jnp.asarray(1.0)
    return cfg.lr * warm * decay


def global_norm(tree: Any) -> jax.Array:
    leaves = [jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads: Any, max_norm: float) -> Tuple[Any, jax.Array]:
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), gn


_NO_DECAY_SUBSTR = ("norm", "ln_", "bias", "A_log", "dt_bias", "D")


def _decay_mask(params: Any) -> Any:
    def mask(path, p):
        name = str(path[-1].key) if hasattr(path[-1], "key") else str(path[-1])
        nodecay = any(t in name for t in _NO_DECAY_SUBSTR) or p.ndim <= 1
        return 0.0 if nodecay else 1.0

    return jax.tree_util.tree_map_with_path(mask, params)


def update(
    cfg: OptConfig,
    params: Any,
    grads: Any,
    state: OptState,
) -> Tuple[Any, OptState, Dict[str, jax.Array]]:
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    grads, gn = clip_by_global_norm(grads, cfg.clip_norm)
    b1, b2 = cfg.betas
    cnt = state.count + 1
    lr = schedule_lr(cfg, state.count)
    c1 = 1.0 - b1 ** cnt.astype(jnp.float32)
    c2 = 1.0 - b2 ** cnt.astype(jnp.float32)
    decay = _decay_mask(params)

    def upd(p, g, m, v, dk):
        g32 = g.astype(jnp.float32)
        m2 = b1 * m + (1 - b1) * g32
        v2 = b2 * v + (1 - b2) * jnp.square(g32)
        step = (m2 / c1) / (jnp.sqrt(v2 / c2) + cfg.eps)
        step = step + cfg.weight_decay * dk * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step).astype(p.dtype), m2, v2

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    flat_d = treedef.flatten_up_to(decay)
    out = [upd(p, g, m, v, dk) for p, g, m, v, dk in
           zip(flat_p, flat_g, flat_m, flat_v, flat_d)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, OptState(new_m, new_v, cnt), {"grad_norm": gn, "lr": lr}
