"""Elastic scaling: rebuild the mesh from survivors and re-shard state.

The flow at scale (and in the tests, with placeholder devices):

1. the GCS view change (``repro.core.gcs``) reports the surviving hosts;
2. ``remesh`` builds the largest (data × model) mesh the survivors support
   (model axis preserved if possible — TP groups must stay intact, so we
   drop whole data rows first, which is how real pods fail);
3. training state is restored from the last committed checkpoint with the
   *new* shardings (``checkpoint.restore(..., shardings=...)``) and the
   data pipeline skips ahead to the checkpointed step — no token is lost
   or duplicated;
4. the paper's own mechanism covers the *soft* failure mode: an overloaded
   (straggling) node is excluded from DTD migration targets by constraint
   (3) long before it is declared failed.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, List, Optional, Sequence, Tuple

import jax
import numpy as np

from . import checkpoint


@dataclass(frozen=True)
class ElasticPlan:
    mesh_shape: Tuple[int, ...]
    axis_names: Tuple[str, ...]
    n_devices: int
    dropped: int


def plan_remesh(
    n_survivors: int, model_size: int, axis_names: Tuple[str, ...] = ("data", "model")
) -> ElasticPlan:
    """Largest data×model grid on the survivors, keeping TP groups whole."""
    model = model_size
    while model > 1 and n_survivors < model:
        model //= 2
    data = max(1, n_survivors // model)
    return ElasticPlan(
        mesh_shape=(data, model),
        axis_names=axis_names,
        n_devices=data * model,
        dropped=n_survivors - data * model,
    )


def remesh(devices: Sequence, plan: ElasticPlan) -> jax.sharding.Mesh:
    use = np.asarray(devices[: plan.n_devices]).reshape(plan.mesh_shape)
    return jax.sharding.Mesh(use, plan.axis_names)


def resume_after_failure(
    ckpt_dir: str,
    like: Any,
    survivors: Sequence,
    model_size: int,
    make_shardings,              # (mesh) -> sharding tree matching `like`
) -> Tuple[Any, int, jax.sharding.Mesh]:
    """Full recovery path: new mesh + resharded restore + resume step."""
    plan = plan_remesh(len(survivors), model_size)
    mesh = remesh(survivors, plan)
    shardings = make_shardings(mesh)
    state, step = checkpoint.restore(ckpt_dir, like, shardings=shardings)
    return state, step, mesh
