"""Distribution layer: locality pricing + SPMD sharding rules.

This package is the serving/training analogue of the paper's Distributed
Transactional Dispatcher (DTD).  The DTD chooses, per transaction, between

* **migrating the transaction** to the replica that owns the leases it
  needs (ship the *work*), and
* **fetching the leases** to the transaction's origin replica (ship the
  *state*),

by comparing step-count costs (SC) or access-frequency costs (LC).  In a
distributed JAX serving system the same fork appears everywhere:

* route a decode request to the pod holding the session's KV cache, or
  migrate the KV cache to the request's origin pod
  (:func:`repro.dist.locality.price_session_dispatch`);
* all-to-all the *tokens* to the devices holding the experts, or
  all-gather the *expert weights* to the tokens
  (:func:`repro.dist.locality.price_moe_dispatch`).

:mod:`repro.dist.locality` re-expresses the DTD's SC/LC decision in
bytes-over-wire against the interconnect hierarchy (ICI / PCIe / DCN);
:mod:`repro.dist.sharding` supplies the SPMD placement rules (parameter,
batch and KV-cache shardings) that make the "state owner" of every tensor
explicit in the first place.
"""
from repro.dist import locality, sharding

__all__ = ["locality", "sharding"]
