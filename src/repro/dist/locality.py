"""Roofline-based dispatch pricing — the DTD's SC decision in bytes.

The paper's Distributed Transaction Dispatching module prices two plans for
a transaction whose leases live on a remote replica:

* **forward** the transaction to the lease owner — one P2P message carrying
  the transaction (its inputs and, later, its result);
* **acquire** the leases at the origin — an atomic-broadcast round plus the
  ownership handoff, after which the state (here: KV cache / expert
  weights) crosses the wire.

The SC (short-career) policy compares fixed step constants; on hardware the
"steps" have sizes, so this module replaces them with *bytes over a known
interconnect* and divides by bandwidth.  ``prefer_migration`` below is
exactly the paper's "migrate the transaction" verdict: it becomes true as
soon as the state is heavier than the work description.

Interconnect constants are v5e-class defaults, intentionally shared with
:mod:`repro.launch.hlo_analysis` where they overlap (``ICI_BW``); they are
keyword-overridable everywhere so benchmarks can sweep them.
"""
from __future__ import annotations

from dataclasses import dataclass


# ---------------------------------------------------------------------------
# Interconnect hierarchy (bytes/s, per device unless noted)
# ---------------------------------------------------------------------------

HBM_BW = 819e9        # HBM read bandwidth per chip
ICI_BW = 50e9         # ICI, per link per direction (matches launch.hlo_analysis)
ICI_LINKS = 4         # v5e: 4 links per chip (2D torus)
PCIE_BW = 32e9        # host <-> device staging path
DCN_BW = 25e9         # cross-pod data-center network, per pod pair
DCN_RTT_S = 1e-3      # cross-pod round-trip (the paper's P2P step constant)


# ---------------------------------------------------------------------------
# Router defaults — the winning thresholds of benchmarks/serve_locality.py
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class RouterDefaults:
    """Default knobs for :class:`repro.serve.router.LocalityRouter`.

    The values are the winners of the policy×arbitration sweep in
    ``benchmarks/serve_locality.py`` (8 pods, mixtral-8x7b KV sizes, 3
    seeds): ``short`` step costs for new-session placement with the priced
    byte model settling forward-vs-acquire (``priced``) ships the least
    wire of the grid — 14% less than step-constant arbitration at locality
    0.9 (wire_GB 0.012 vs 0.014), where it is also ~11% faster — with no
    tokens/s regression at locality 0.0.
    """

    policy: str = "short"          # DTD cost policy: "local"|"short"|"long"
    arbitration: str = "priced"    # "steps" | "priced" | "hybrid"
    # constraint (3) threshold, re-swept against the fixed CpuMeter
    # (benchmarks/overload.py --sweep-max-cpu; see DTDConfig.max_cpu)
    max_cpu: float = 0.9
    freq_tau_ms: float = 500.0     # LC access-frequency decay constant


ROUTER_DEFAULTS = RouterDefaults()


# ---------------------------------------------------------------------------
# Session dispatch: forward the request vs. migrate the KV state
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class SessionDispatchCost:
    """Priced plans for serving one session step on a non-owner pod.

    ``migrate_work_s``  — forward the request to the KV owner (paper: migrate
    the transaction to the lease owner).  ``migrate_state_s`` — ship the KV
    cache to the origin and take ownership (paper: lease acquisition).
    ``prefer_migration`` is True when forwarding the work wins.

    ``state_hop_bytes`` is the heaviest single src-shard → dst-shard hop of
    the state move: when the KV cache is seq-sharded over ``seq_shards``
    devices per pod, the shards cross the DCN in parallel over distinct NIC
    pairs, so the move serializes on ``state_bytes / seq_shards`` per hop
    (``state_bytes`` stays the total put on the wire).
    """

    migrate_work_s: float
    migrate_state_s: float
    work_bytes: float
    state_bytes: float
    prefer_migration: bool
    state_hop_bytes: float = -1.0     # default: filled to state_bytes

    def __post_init__(self):
        if self.state_hop_bytes < 0:
            object.__setattr__(self, "state_hop_bytes", self.state_bytes)

    @property
    def wire_bytes(self) -> float:
        """Bytes the *chosen* plan puts on the DCN."""
        return self.work_bytes if self.prefer_migration else self.state_bytes


def price_session_dispatch(
    prompt_tokens: float,
    decode_tokens: float,
    kv_state_bytes: float,
    *,
    wire_bytes_per_token: float = 2.0,
    handoff_bytes: float = 512.0,
    dcn_bw: float = DCN_BW,
    rtt_s: float = DCN_RTT_S,
    seq_shards: float = 1,
) -> SessionDispatchCost:
    """Price forwarding a session's work vs. migrating its KV state.

    ``prompt_tokens``/``decode_tokens`` describe the work that would cross
    the wire if the request is forwarded (the callers may equivalently pass
    pre-scaled byte counts with ``wire_bytes_per_token=1``);
    ``kv_state_bytes`` is the session's KV-cache footprint, plus a fixed
    ``handoff_bytes`` for the ownership record — the paper's AB+URB round.
    Both plans pay one ``rtt_s``, so the verdict reduces to bytes.

    ``seq_shards`` > 1 models a seq-sharded cache column (the long-context
    layout of :mod:`repro.dist.sharding`): the column leaves as ``seq_shards``
    parallel shard-to-shard transfers, so the state plan serializes on
    ``1/seq_shards`` of the KV bytes per hop.  Fractional values are the
    byte-weighted effective divisor of a partially-sharded cache (hybrid
    attn+mamba trees — see ``KVStore.seq_shards``).  Total wire bytes are
    unchanged — only the time (and therefore the verdict) moves.
    """
    seq_shards = max(1.0, float(seq_shards))
    work_bytes = (prompt_tokens + decode_tokens) * wire_bytes_per_token
    state_bytes = kv_state_bytes + handoff_bytes
    state_hop_bytes = kv_state_bytes / seq_shards + handoff_bytes
    migrate_work_s = rtt_s + work_bytes / dcn_bw
    migrate_state_s = rtt_s + state_hop_bytes / dcn_bw
    return SessionDispatchCost(
        migrate_work_s=migrate_work_s,
        migrate_state_s=migrate_state_s,
        work_bytes=work_bytes,
        state_bytes=state_bytes,
        prefer_migration=migrate_work_s < migrate_state_s,
        state_hop_bytes=state_hop_bytes,
    )


# ---------------------------------------------------------------------------
# MoE dispatch: all-to-all the tokens vs. all-gather the experts
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class MoEDispatchCost:
    """Priced plans for one MoE layer under ``ep_degree``-way sharding.

    ``dispatch_s`` — all-to-all the routed tokens to their expert owners and
    combine back (migrate the work to the state).  ``allgather_s`` — gather
    every expert's weights to every device (migrate the state to the work).
    ``prefer_dispatch`` is the token-a2a verdict.
    """

    dispatch_s: float
    allgather_s: float
    dispatch_bytes: float
    allgather_bytes: float
    prefer_dispatch: bool

    @property
    def wire_bytes(self) -> float:
        return self.dispatch_bytes if self.prefer_dispatch else self.allgather_bytes


def price_moe_dispatch(
    tokens_per_device: int,
    d_model: int,
    top_k: int,
    n_experts: int,
    d_expert: int,
    ep_degree: int,
    *,
    tp_degree: int = 1,
    bytes_per_elem: float = 2.0,
    link_bw: float = ICI_BW,
    n_links: int = ICI_LINKS,
) -> MoEDispatchCost:
    """Price token all-to-all vs. expert all-gather for one MoE layer.

    Per device and per layer: the a2a plan moves each routed token activation
    out and its expert output back (``2 × T × top_k × d_model`` elements,
    scaled by the off-device fraction); the all-gather plan moves the three
    expert matrices of every non-resident expert
    (``3 × n_experts × d_model × d_expert`` elements, same fraction).
    Token traffic scales with batch, weight traffic doesn't — so dispatch
    wins at serving batch sizes and the crossover tracks ``ep_degree``.

    ``tp_degree`` > 1 is the chunked (deepseek-style) layout where each
    expert's FFN is split ``tp``-ways over the model ranks: every routed
    token is dispatched to all ``tp`` chunk ranks of its expert group and
    comes back as ``tp`` f-slice partials that the sender psums — the
    partial-activation psum term — so both a2a legs scale by ``tp_degree``
    while the off-device fraction is taken over all ``ep × tp`` shards.
    At ``tp_degree == 1`` this reduces to the whole-expert formula.
    """
    tp_degree = max(1, int(tp_degree))
    shards = ep_degree * tp_degree
    off_device = (shards - 1) / shards if shards > 1 else 0.0
    dispatch_bytes = (
        2.0 * tokens_per_device * top_k * d_model * bytes_per_elem
        * tp_degree * off_device
    )
    allgather_bytes = (
        3.0 * n_experts * d_model * d_expert * bytes_per_elem * off_device
    )
    bw = link_bw * n_links
    return MoEDispatchCost(
        dispatch_s=dispatch_bytes / bw,
        allgather_s=allgather_bytes / bw,
        dispatch_bytes=dispatch_bytes,
        allgather_bytes=allgather_bytes,
        # one shard: every expert is already whole and local — nothing migrates
        prefer_dispatch=shards > 1 and dispatch_bytes <= allgather_bytes,
    )
