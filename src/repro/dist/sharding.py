"""SPMD sharding rules: who owns which slice of every tensor.

The locality pricing in :mod:`repro.dist.locality` is only meaningful once
each tensor has a well-defined owner; this module is that ledger.  It maps
the parameter / batch / KV-cache pytrees of :mod:`repro.models` onto a mesh
whose axes are split into *batch* axes (pure data parallelism — ``pod``,
``data``) and one *model* axis (tensor/expert parallelism):

* ``param_shardings`` — megatron-style rules by leaf name: column-parallel
  projections shard their output features, row-parallel projections their
  input features, chunked MoE expert weights their EP×TP chunk axis, and
  everything small (norms, router, conv taps) is replicated.  Stacked layer
  groups (``blocks.posN``, leading ``n_groups`` axis) are handled by
  indexing dims from the *end*, so the same rule covers unrolled and
  scanned layers.
* ``batch_pspecs`` / ``cache_pspecs`` — inputs and KV caches shard their
  batch dim over the batch axes; GQA KV caches additionally shard the
  kv-head dim over the model axis, mirroring the ``wk``/``wv`` column
  sharding so decode reads stay local to the head's owner.
* the *sequence* dim of attention KV caches (GQA ``k``/``v``, MLA
  ``c_kv``/``k_pe``) shards over the optional ``seq`` mesh axis when one is
  present — the long-context rule: a migrated 128k-token session's cache
  column is split into ``seq`` chunks instead of landing on one shard, and
  :func:`repro.dist.locality.price_session_dispatch` prices the migration
  at ``1/seq_shards`` of the bytes per hop.

Every rule is guarded by divisibility: a dim that the mesh doesn't divide
is replicated rather than rejected, so smoke meshes (1×1) and production
meshes (16×16, 2×16×16, 4×4×16 with a seq axis) use one code path.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.common import ModelConfig, param_shapes

MODEL_AXIS = "model"
SEQ_AXIS = "seq"

# projections whose *last* dim is feature-parallel (column-parallel)
_COL_PARALLEL = {"wq", "wk", "wv", "wq_b", "wkv_b", "w_in", "w_gate", "w_up",
                 "lm_head"}
# projections whose second-to-last dim is feature-parallel (row-parallel)
_ROW_PARALLEL = {"wo", "w_down", "w_out"}


@dataclass(frozen=True)
class MeshAxes:
    """A mesh's axis names split into batch (data-parallel), model, and seq.

    The ``seq`` axis (when the mesh exposes one) shards the sequence dim of
    long KV caches; it never participates in batch data-parallelism.
    """

    batch: Tuple[str, ...]
    model: str = MODEL_AXIS
    seq: Optional[str] = None

    @classmethod
    def for_mesh(cls, mesh: Mesh) -> "MeshAxes":
        names = tuple(mesh.axis_names)
        seq = SEQ_AXIS if SEQ_AXIS in names else None
        # axes that are neither model nor seq are pure data parallelism; a
        # mesh without a model axis never gets megatron sharding
        return cls(
            batch=tuple(a for a in names if a not in (MODEL_AXIS, SEQ_AXIS)),
            seq=seq,
        )

    def model_size(self, mesh: Mesh) -> int:
        return int(dict(mesh.shape).get(self.model, 1))

    def seq_size(self, mesh: Mesh) -> int:
        if self.seq is None:
            return 1
        return int(dict(mesh.shape).get(self.seq, 1))


def _divisible_batch_axes(
    n: int, axes: Sequence[str], mesh: Mesh
) -> Optional[Tuple[str, ...]]:
    """Largest suffix of ``axes`` whose total size divides ``n`` (None: none).

    Mirrors :func:`repro.models.moe.moe_sharded`: leading axes (``pod``) are
    dropped first, so a batch too small for the full mesh still uses the
    inner data axis.
    """
    axes = tuple(axes)
    while axes:
        size = 1
        for a in axes:
            size *= int(mesh.shape[a])
        if size > 1 and n % size == 0:
            return axes
        axes = axes[1:]
    return None


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------

def _param_spec(path, shape: Tuple[int, ...], model: str, msize: int) -> P:
    """Sharding rule for one parameter leaf, by its name and ancestry.

    Dims are indexed from the end so the rule is invariant to the leading
    ``n_groups`` stack axis of scanned layer groups.
    """
    name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
    in_experts = any(getattr(p, "key", None) == "experts" for p in path)
    nd = len(shape)

    def at(dim_from_end: int) -> P:
        idx = nd + dim_from_end
        if msize <= 1 or idx < 0 or shape[idx] % msize:
            return P()
        spec: List[Any] = [None] * nd
        spec[idx] = model
        return P(*spec)

    if in_experts and name in ("w_gate", "w_up", "w_down"):
        return at(-4)              # [*, nc, n_e, d, f_c]: shard the chunk axis
    if name == "embed":
        return at(-2)              # [vocab, d]: vocab-parallel
    if name in _COL_PARALLEL:
        return at(-1)
    if name in _ROW_PARALLEL:
        return at(-2)
    return P()                     # norms, router, conv taps, biases, lora-a


def param_pspecs(cfg: ModelConfig, mesh: Mesh) -> Dict[str, Any]:
    """PartitionSpec tree congruent with ``param_shapes``/``init_params``."""
    ax = MeshAxes.for_mesh(mesh)
    msize = ax.model_size(mesh)
    shapes = param_shapes(cfg, model_size=msize)
    return jax.tree_util.tree_map_with_path(
        lambda p, s: _param_spec(p, s, ax.model, msize),
        shapes, is_leaf=lambda s: isinstance(s, tuple),
    )


def param_shardings(cfg: ModelConfig, mesh: Mesh) -> Dict[str, Any]:
    """NamedSharding tree congruent with the parameter pytree."""
    return jax.tree.map(
        lambda spec: NamedSharding(mesh, spec),
        param_pspecs(cfg, mesh),
        is_leaf=lambda s: isinstance(s, P),
    )


# ---------------------------------------------------------------------------
# Batch inputs
# ---------------------------------------------------------------------------

def batch_pspecs(
    cfg: ModelConfig, mesh: Mesh, specs: Dict[str, Any]
) -> Dict[str, P]:
    """PartitionSpecs for a model-input dict (``configs.shapes.input_specs``).

    Every input shards its batch dim over the batch axes; M-RoPE positions
    carry a leading ``[3]`` section axis, so their batch dim is dim 1.
    Scalars (decode ``pos``) are replicated.
    """
    ax = MeshAxes.for_mesh(mesh)
    out: Dict[str, P] = {}
    for k, v in specs.items():
        shape = tuple(v.shape)
        bdim = 1 if (k == "positions" and len(shape) == 3) else 0
        if len(shape) <= bdim:
            out[k] = P()
            continue
        baxes = _divisible_batch_axes(shape[bdim], ax.batch, mesh)
        spec: List[Any] = [None] * len(shape)
        if baxes:
            spec[bdim] = baxes
        out[k] = P(*spec)
    return out


# ---------------------------------------------------------------------------
# KV / SSM caches
# ---------------------------------------------------------------------------

# attention-cache leaves whose dim right after batch is the sequence dim;
# ndim relative to the batch dim disambiguates them from same-named params
_SEQ_CACHE_NDIM = {"k": 4, "v": 4,          # GQA [.., B, S, n_kv, head_dim]
                   "c_kv": 3, "k_pe": 3}    # MLA [.., B, S, lat]


def kv_buffer_spec(shape: Sequence[int], *, bdim: int, batch,
                   model: str = MODEL_AXIS, msize: int = 1,
                   seq: Optional[str] = None, ssize: int = 1) -> P:
    """Layout rule for one attention KV buffer ``[.., B, S, (n_kv, ) D]``.

    The single source of the KV-cache layout: batch at ``bdim``, the
    sequence dim right after it over the ``seq`` axis (long-context rule),
    and — for 4-dim GQA buffers — kv heads over the model axis, mirroring
    the ``wk``/``wv`` column sharding.  Both the ledger
    (:func:`cache_pspecs`) and the in-step activation constraints
    (``repro.models.attention._shard_kv``) call this, so the placement a
    ``KVStore`` allocates and the constraint GSPMD sees inside the jitted
    decode step can never drift apart.
    """
    shape = tuple(shape)
    spec: List[Any] = [None] * len(shape)
    if batch and len(shape) > bdim:
        spec[bdim] = batch
    if len(shape) == bdim + 4 and msize > 1 and shape[bdim + 2] % msize == 0:
        spec[bdim + 2] = model
    if seq is not None and ssize > 1 and len(shape) > bdim + 1 and \
            shape[bdim + 1] % ssize == 0:
        spec[bdim + 1] = seq
    return P(*spec)


def _cache_leaf_spec(path, leaf, bdim: int, baxes, model: str, msize: int,
                     seq: Optional[str] = None, ssize: int = 1) -> P:
    name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
    shape = tuple(leaf.shape)
    # attention KV buffers take the full layout rule; everything else (the
    # mamba conv/ssm state carries no seq dim) shards batch only
    if len(shape) == bdim + _SEQ_CACHE_NDIM.get(name, -1):
        return kv_buffer_spec(shape, bdim=bdim, batch=baxes, model=model,
                              msize=msize, seq=seq, ssize=ssize)
    spec: List[Any] = [None] * len(shape)
    if baxes and len(shape) > bdim:
        spec[bdim] = baxes
    return P(*spec)


def cache_pspecs(
    cfg: ModelConfig, mesh: Mesh, tree: Dict[str, Any], batch: int
) -> Dict[str, Any]:
    """PartitionSpec tree congruent with ``decoder.init_cache(cfg, batch, ..)``.

    ``tree`` may hold arrays or ShapeDtypeStructs (``jax.eval_shape``).  The
    ``prefix``/``suffix`` entries put batch at dim 0; the scanned ``body``
    entries carry a leading ``n_groups`` axis, so batch is dim 1 there —
    passing ``batch`` explicitly keeps that unambiguous even when a cache
    dim happens to equal ``n_groups``.
    """
    ax = MeshAxes.for_mesh(mesh)
    msize = ax.model_size(mesh)
    ssize = ax.seq_size(mesh)
    baxes = _divisible_batch_axes(batch, ax.batch, mesh)

    def layer(entry: Any, stacked: bool) -> Any:
        return jax.tree_util.tree_map_with_path(
            lambda p, l: _cache_leaf_spec(
                p, l, 1 if stacked else 0, baxes, ax.model, msize,
                ax.seq, ssize),
            entry,
        )

    out: Dict[str, Any] = {
        "prefix": [layer(c, stacked=False) for c in tree.get("prefix", [])],
        "body": None,
        "suffix": [layer(c, stacked=False) for c in tree.get("suffix", [])],
    }
    if tree.get("body") is not None:
        out["body"] = [layer(c, stacked=True) for c in tree["body"]]
    return out


def cache_shardings(
    cfg: ModelConfig, mesh: Mesh, tree: Dict[str, Any], batch: int
) -> Dict[str, Any]:
    """NamedSharding tree congruent with ``decoder.init_cache`` output.

    The serving path (``repro.serve.kvcache.KVStore``) places its slot-ring
    cache trees with this, so a migrated session's column lands pre-sharded
    on the target pod's mesh instead of being re-laid-out at first decode.
    """
    return jax.tree.map(
        lambda spec: NamedSharding(mesh, spec),
        cache_pspecs(cfg, mesh, tree, batch),
        is_leaf=lambda s: isinstance(s, P),
    )


# ---------------------------------------------------------------------------
# Planner score mesh: shard the [class, target] matrix over the pod's devices
# ---------------------------------------------------------------------------

PLAN_AXIS = "plan"


def make_plan_mesh(n_devices: Optional[int] = None) -> Optional[Mesh]:
    """1-D mesh for sharding planner move-scoring over the local devices.

    The ``[class, target]`` score matrix of :func:`repro.plan.score.
    score_moves` splits on its class axis — classes are independent rows —
    so the pow2-padded class dim shards evenly over any pow2 device count.
    Returns ``None`` on a single device (plain jit is strictly cheaper than
    a one-device mesh): callers treat ``None`` as "score unsharded".
    """
    devs = jax.devices()
    n = len(devs) if n_devices is None else min(n_devices, len(devs))
    # largest pow2 ≤ n: the class axis is pow2-padded, so a pow2 mesh always
    # divides it (the guard in plan_score_shardings stays for odd caps)
    while n & (n - 1):
        n &= n - 1
    if n <= 1:
        return None
    import numpy as np

    return Mesh(np.asarray(devs[:n]), (PLAN_AXIS,))


def plan_score_shardings(
    mesh: Mesh, n_classes: int
) -> Optional[Dict[str, NamedSharding]]:
    """Input shardings for ``_score_moves_jit`` on a plan mesh.

    Class-indexed arrays shard their leading (class) axis; the ``cpu``
    vector (node-indexed) is replicated.  Returns ``None`` when the padded
    class count doesn't divide over the mesh (callers fall back to
    unsharded scoring rather than resharding mid-epoch).
    """
    size = int(dict(mesh.shape)[PLAN_AXIS])
    if size <= 1 or n_classes % size:
        return None
    row = NamedSharding(mesh, P(PLAN_AXIS, None))
    vec = NamedSharding(mesh, P(PLAN_AXIS))
    rep = NamedSharding(mesh, P())
    return {"rates": row, "owner": vec, "fwd_cost": vec, "move_cost": vec,
            "cpu": rep, "co_adv": row}
