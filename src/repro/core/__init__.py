"""Lilac-TM core: locality-aware lease-based replicated transactional memory.

The paper's primary contribution (Hendler et al., 2013) as a composable
library:

* fine-grained lease management (:mod:`repro.core.lease`, Algorithm 1) and
  the coarse-grained ALC baseline;
* the Distributed Transaction Dispatcher ILP with short-/long-term policies
  (:mod:`repro.core.dtd`, vectorized in JAX);
* the Transaction Forwarder protocol (:mod:`repro.core.forwarder`);
* a TL2-style local STM with batched JAX certification (:mod:`repro.core.stm`);
* a simulated view-synchronous GCS (:mod:`repro.core.gcs`) and the
  discrete-event cluster simulator (:mod:`repro.core.cluster`) that together
  reproduce the paper's evaluation;
* a vectorized `lax.scan` cluster model (:mod:`repro.core.jax_sim`) for wide
  policy sweeps.
"""
from . import jax_sim
from .conflict import ConflictClassMap
from .cluster import Cluster, Metrics, SimConfig, TxnSpec, Workload
from .dtd import DTD, DTDConfig, C_AB, C_P2P, C_URB
from .events import EventQueue
from .forwarder import CommitNotice, ForwardPolicy, ForwardRequest
from .gcs import GCSLatency, SimGCS
from .lease import ALCLeaseManager, FGLLeaseManager, LeaseRequest, LOR
from .stats import CpuMeter, DecayedFrequency
from .stm import Transaction, VersionedStore, validate_batch
from .workloads import BankWorkload, TpccConflictMap, TpccLayout, TpccWorkload

ALGORITHMS = {
    # paper variant -> (lease_kind, dtd policy)
    "ALC": ("alc", "local"),
    "FGL": ("fgl", "local"),
    "MG-ALC": ("alc", "opt"),
    "LILAC-TM-ST": ("fgl", "short"),
    "LILAC-TM-LT": ("fgl", "long"),
    "LILAC-TM-OPT": ("fgl", "opt"),
}


def make_cluster(algorithm: str, workload, cfg: SimConfig = None, ccmap=None, **overrides):
    """Build a cluster configured for one of the paper's algorithm variants."""
    from dataclasses import replace

    lease_kind, policy = ALGORITHMS[algorithm]
    cfg = cfg or SimConfig()
    dtd = replace(cfg.dtd, policy=policy)
    cfg = replace(cfg, lease_kind=lease_kind, dtd=dtd, **overrides)
    return Cluster(cfg, workload, ccmap=ccmap)
