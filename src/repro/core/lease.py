"""Lease managers: coarse-grained ALC and fine-grained FGL (Algorithm 1).

Every replica runs its own lease-manager instance holding a *replica* of the
conflict-queue state ``CQ``: an array of FIFO queues, one per conflict class,
containing Lease Ownership Records (LORs).  Queue contents evolve
deterministically from the total order of lease requests (TO-deliver) and the
uniform-reliable stream of ``LeaseFreed`` messages (UR-deliver), so all
replicas converge to the same queues.

Key protocol facts preserved from the paper (and exploited by its correctness
argument — see tests/test_lease_fgl.py):

* piggybacking (line 4) only considers LORs **already enqueued locally**
  (i.e. whose request was TO-delivered here) that are owned by this process
  and not ``blocked``;
* ``Opt-deliver`` of a remote conflicting request *blocks* local LORs before
  that request's TO-deliver can possibly occur (optimistic delivery precedes
  final delivery at every node), which is what makes piggybacking
  deadlock-free;
* a LOR is freed (single ``UR-broadcast`` batching all drained LORs) when it
  is blocked and its ``activeXacts`` counter drains to zero, or immediately at
  blocking time when it is at the head of its queue with no active
  transactions.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Dict, FrozenSet, Iterable, List, Optional, Sequence, Tuple

import numpy as np


# --------------------------------------------------------------------------
# Records
# --------------------------------------------------------------------------

@dataclass
class LeaseRequest:
    """A lease request disseminated via OAB."""

    req_id: int                  # globally unique (issued per origin, see Cluster)
    proc: int                    # requesting replica
    ccs: Tuple[int, ...]         # conflict classes requested (sorted)
    coarse: bool = False         # True => single multi-cc LOR (ALC semantics)
    # planner-issued background prefetch (repro.plan): no transaction is
    # attached, so the requester drains the LORs' activeXacts immediately at
    # TO-deliver and they sit unblocked in the queues, piggybackable by
    # future local transactions.  Protocol-wise this is an ordinary lease
    # request — safety and queue replication are untouched.
    prefetch: bool = False


@dataclass
class LOR:
    """Lease Ownership Record — one replica's copy.

    ``activeXacts``/``blocked`` are only meaningful on the owning replica
    (``proc``); other replicas track queue membership for ordering/ownership
    decisions.
    """

    req_id: int
    proc: int
    ccs: Tuple[int, ...]         # FGL: single cc; ALC: the full request set
    activeXacts: int = 1
    blocked: bool = False

    @property
    def cc(self) -> int:
        assert len(self.ccs) == 1
        return self.ccs[0]

    def key(self) -> Tuple[int, int, Tuple[int, ...]]:
        return (self.req_id, self.proc, self.ccs)


# --------------------------------------------------------------------------
# Base: replicated conflict-queue state
# --------------------------------------------------------------------------

class LeaseManagerBase:
    """Shared conflict-queue machinery for both lease managers."""

    def __init__(self, proc: int, n_classes: int) -> None:
        self.proc = proc
        self.n_classes = n_classes
        # CQ: FIFO per conflict class, replicated via total order.
        self.cq: List[List[LOR]] = [[] for _ in range(n_classes)]
        # LORs indexed by (req_id) for this replica's copy.
        self._by_req: Dict[int, List[LOR]] = {}
        # Opt-delivered requests whose TO-deliver is still pending.  Needed to
        # close an opt/TO race the paper's prose glosses over: Algorithm 1
        # blocks local LORs at Opt-deliver time, but a LOR of an *earlier*
        # (in total order) request may be enqueued only at its later
        # TO-deliver — after the conflicting request's Opt-deliver already
        # ran — and would then never be blocked nor freed once drained,
        # deadlocking the later request behind a dormant LOR.  Any request
        # that is opt-delivered but not yet TO-delivered is necessarily
        # TO-ordered *after* every request already TO-delivered, so LORs
        # enqueued while a conflicting request is pending are born blocked.
        self._pending_opt: Dict[int, LeaseRequest] = {}
        # members removed by a view change: view synchrony demands that any
        # of their messages still in flight are discarded on delivery, else
        # their LORs would head queues forever (nobody left to free them).
        self._dead: set = set()
        # metrics
        self.n_piggyback = 0
        self.n_requests = 0

    # -- queue helpers ------------------------------------------------------
    def _is_first(self, lor: LOR, cc: int) -> bool:
        q = self.cq[cc]
        return bool(q) and q[0] is lor

    def head_owner(self, cc: int) -> int:
        """Current lease owner of ``cc`` per this replica's view (-1: none)."""
        q = self.cq[cc]
        return q[0].proc if q else -1

    def owner_view(self) -> List[int]:
        """L(i, x) ownership vector over all conflict classes."""
        return [self.head_owner(cc) for cc in range(self.n_classes)]

    def owns_all(self, ccs: Iterable[int]) -> bool:
        """True iff this replica's LORs head every queue in ``ccs``."""
        return all(self.head_owner(cc) == self.proc for cc in ccs)

    def owner_np(self) -> np.ndarray:
        """Ownership vector as an int32 array (-1: unowned) — the shape the
        certification kernel's write-lock derivation consumes (ids are
        int32 end to end; see the id-dtype lint rule)."""
        return np.fromiter(
            (self.head_owner(cc) for cc in range(self.n_classes)),
            np.int32, count=self.n_classes)

    def has_unblocked(self, cc: int, proc: int) -> bool:
        """True iff ``proc`` has an unblocked LOR anywhere in ``cc``'s queue
        (it holds the lease or is already queued to get it)."""
        return any(l.proc == proc and not l.blocked for l in self.cq[cc])

    def enabled_mask(self, groups: Sequence[Sequence[LOR]]) -> List[bool]:
        """``isEnabled`` over many waiting groups.  The sequential oracle
        just loops; the sharded manager overrides this with one vectorized
        settle per instant."""
        return [self.is_enabled(g) for g in groups]

    def protocol_state(self) -> Tuple:
        """Canonical protocol-state snapshot for the schedule explorer.

        Covers exactly the replicated state the fingerprint dedup keys on:
        per-class queue contents in order (req, proc, activeXacts, blocked),
        the opt-delivered-but-pending request ids, and the dead set.  Both
        managers emit the same shape, so a sequential and a sharded replica
        in the same protocol state fingerprint identically.
        """
        queues = tuple(
            (cc, tuple((l.req_id, l.proc, l.activeXacts, bool(l.blocked))
                       for l in self.cq[cc]))
            for cc in range(self.n_classes) if self.cq[cc])
        return (queues, tuple(sorted(self._pending_opt)),
                tuple(sorted(self._dead)))

    # -- protocol events (identical in both variants) -----------------------
    def on_to_deliver(self, req: LeaseRequest) -> List[LOR]:
        """TO-deliver of a lease request: enqueue its LORs (Alg. 1 l.21-23).

        Applies the total-order blocking catch-up (see ``_pending_opt``): a
        newly enqueued local LOR conflicting with any still-pending
        opt-delivered request is born blocked, so it is freed as soon as its
        transactions drain rather than lingering dormant.
        """
        self._pending_opt.pop(req.req_id, None)
        if req.proc in self._dead:
            return []
        lors = self._create_lors(req)
        self._by_req[req.req_id] = lors
        for lor in lors:
            for cc in lor.ccs:
                self.cq[cc].append(lor)
        if req.proc == self.proc and self._pending_opt:
            pending_ccs = set()
            for p in self._pending_opt.values():
                pending_ccs.update(p.ccs)
            for lor in lors:
                if any(cc in pending_ccs for cc in lor.ccs):
                    lor.blocked = True
        return lors

    def on_ur_deliver_freed(self, freed_keys: Sequence[Tuple[int, int, Tuple[int, ...]]]) -> None:
        """UR-deliver of LeaseFreed: dequeue each named LOR (Alg. 1 l.24-25)."""
        for (req_id, proc, ccs) in freed_keys:
            lors = self._by_req.get(req_id, [])
            for lor in lors:
                if lor.ccs == ccs and lor.proc == proc:
                    for cc in lor.ccs:
                        try:
                            self.cq[cc].remove(lor)
                        except ValueError:
                            pass
            # drop only the named (ccs, proc) record, matching the dequeue above
            self._by_req[req_id] = [
                l for l in lors if not (l.ccs == ccs and l.proc == proc)
            ]
            if not self._by_req[req_id]:
                del self._by_req[req_id]

    def on_opt_deliver(self, req: LeaseRequest) -> List[LOR]:
        """Opt-deliver of a lease request: freeLocalLeases (Alg. 1 l.26-33).

        Note Algorithm 1 line 36 has **no p_k ≠ p_i guard**: a node's own
        request also blocks its earlier LORs on the requested classes.  This
        matters — without it, a fresh request would queue behind the node's
        own dormant (activeXacts = 0, unblocked) LOR, which nothing would
        ever free: self-deadlock.  The newly requested LORs themselves are
        untouched because they are only enqueued at TO-deliver, which follows
        this optimistic delivery.

        Returns the list of local LORs that must be freed now (the caller
        UR-broadcasts a single LeaseFreed for them).
        """
        if req.proc in self._dead:
            return []
        self._pending_opt[req.req_id] = req
        to_free: List[LOR] = []
        for cc in req.ccs:
            for lor in self.cq[cc]:
                if lor.proc == self.proc and not lor.blocked:
                    lor.blocked = True
                    if (
                        all(self._is_first(lor, c) for c in lor.ccs)
                        and lor.activeXacts == 0
                    ):
                        to_free.append(lor)
        return _dedup(to_free)

    def finished_xact(self, lors: Sequence[LOR]) -> List[LOR]:
        """FinishedXact (Alg. 1 l.14-18): decrement; return LORs to free."""
        to_free: List[LOR] = []
        for lor in lors:
            lor.activeXacts -= 1
            assert lor.activeXacts >= 0, "activeXacts underflow"
            if lor.blocked and lor.activeXacts == 0:
                to_free.append(lor)
        return _dedup(to_free)

    def is_enabled(self, lors: Sequence[LOR]) -> bool:
        """isEnabled (Alg. 1 l.34-35): every LOR heads all its queues."""
        return all(
            self._is_first(lor, cc) for lor in lors for cc in lor.ccs
        )

    def purge_proc(self, proc: int) -> None:
        """View change: reclaim every LOR owned by a failed member.

        View synchrony guarantees all surviving replicas apply this at the
        same point of the delivery stream, so queues stay consistent.
        """
        self._dead.add(proc)
        for req_id in list(self._pending_opt):
            if self._pending_opt[req_id].proc == proc:
                del self._pending_opt[req_id]
        for cc in range(self.n_classes):
            self.cq[cc] = [l for l in self.cq[cc] if l.proc != proc]
        # all LORs of one request belong to its issuing proc, so removal is
        # whole-request: a "keep the other procs' LORs" branch here could
        # only ever retain records whose queue entries were just purged
        # (dangling LORs) — assert the invariant instead of masking it
        for req_id in list(self._by_req):
            owners = {l.proc for l in self._by_req[req_id]}
            assert len(owners) == 1, \
                "invariant violated: LORs of one request span procs"
            if proc in owners:
                del self._by_req[req_id]

    # -- to override ---------------------------------------------------------
    def _create_lors(self, req: LeaseRequest) -> List[LOR]:
        raise NotImplementedError

    def try_piggyback(self, ccs: FrozenSet[int]) -> Optional[List[LOR]]:
        raise NotImplementedError


def _dedup(lors: List[LOR]) -> List[LOR]:
    out: List[LOR] = []
    seen = set()
    for lor in lors:
        k = id(lor)
        if k not in seen:
            seen.add(k)
            out.append(lor)
    return out


# --------------------------------------------------------------------------
# FGL — fine-grained leases (the paper's new lease manager, Algorithm 1)
# --------------------------------------------------------------------------

class FGLLeaseManager(LeaseManagerBase):
    """One LOR per accessed conflict class; piggyback per class."""

    def _create_lors(self, req: LeaseRequest) -> List[LOR]:
        return [LOR(req.req_id, req.proc, (cc,)) for cc in req.ccs]

    def try_piggyback(self, ccs: FrozenSet[int]) -> Optional[List[LOR]]:
        """Alg. 1 line 4: cover ``ccs`` with own unblocked enqueued LORs."""
        S: List[LOR] = []
        for cc in sorted(ccs):
            found = None
            for lor in self.cq[cc]:
                if lor.proc == self.proc and not lor.blocked:
                    found = lor
                    break
            if found is None:
                return None
            S.append(found)
        for lor in _dedup(S):
            lor.activeXacts += 1
        self.n_piggyback += 1
        return S

    def missing_ccs(self, ccs: FrozenSet[int]) -> FrozenSet[int]:
        """Conflict classes not coverable by piggybacking (for the DTD)."""
        out = []
        for cc in ccs:
            if not any(
                l.proc == self.proc and not l.blocked for l in self.cq[cc]
            ):
                out.append(cc)
        return frozenset(out)


# --------------------------------------------------------------------------
# ALC — coarse-grained baseline (one lease record per transaction data-set)
# --------------------------------------------------------------------------

class ALCLeaseManager(LeaseManagerBase):
    """One multi-class LOR per request; reuse only on data-set inclusion."""

    def _create_lors(self, req: LeaseRequest) -> List[LOR]:
        return [LOR(req.req_id, req.proc, tuple(sorted(req.ccs)))]

    def try_piggyback(self, ccs: FrozenSet[int]) -> Optional[List[LOR]]:
        """Reuse iff the txn's data-set ⊆ a single owned, unblocked lease."""
        if not ccs:
            return None
        candidates = self.cq[min(ccs)]
        for lor in candidates:
            if (
                lor.proc == self.proc
                and not lor.blocked
                and ccs.issubset(lor.ccs)
            ):
                lor.activeXacts += 1
                self.n_piggyback += 1
                return [lor]
        return None

    def missing_ccs(self, ccs: FrozenSet[int]) -> FrozenSet[int]:
        return frozenset() if self.try_peek(ccs) else frozenset(ccs)

    def try_peek(self, ccs: FrozenSet[int]) -> bool:
        if not ccs:
            return False
        for lor in self.cq[min(ccs)]:
            if lor.proc == self.proc and not lor.blocked and ccs.issubset(lor.ccs):
                return True
        return False
