"""Distributed Transaction Dispatcher — the paper's ILP (§3.3), in JAX.

The optimization problem

    min_i  N_i · C(i, S)      s.t.  Σ N_i = 1,   CPU_i · N_i < maxCPU

selects the single node that will manage a transaction's commit phase.  Both
cost policies are evaluated for *all* candidate nodes at once as vectorized
``jnp`` expressions and solved with a masked argmin — the O(|Π|) solve noted
in the paper, expressed as one fused XLA computation.

Inputs (all per the deciding replica's local, piggybacked view):
  * ``lease_view[n_nodes, |S|]``  — L(i, x): 1 iff node i owns a lease on x;
  * ``freq[n_nodes, |S|]``        — F(j, x) access-frequency estimates;
  * ``cpu[n_nodes]``              — CPU utilization estimates;
  * ``origin``                    — O, the transaction's originating node.

Communication-step costs (paper §3.3): c_p2p=1, c_URB=2, c_AB=3.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

C_P2P = 1.0
C_URB = 2.0
C_AB = 3.0


@dataclass(frozen=True)
class DTDConfig:
    policy: str = "short"      # "short" | "long" | "opt" | "local"
    # maxCPU threshold of constraint (3).  Re-swept against the fixed
    # CpuMeter (benchmarks/overload.py --sweep-max-cpu, 3 seeds: post-
    # overload throughput is flat for thresholds <= 0.9 and degrades at
    # 0.95, where the valve trips after the ~0.95 injected load): 0.9 is
    # the combined short+long winner.  The old 0.85 was tuned while the
    # meter double-counted injected load (~2x), i.e. an effective ~0.43.
    max_cpu: float = 0.9
    enable_overload_ctrl: bool = True
    # Costs within ``tie_tol`` (relative to the largest finite cost) are
    # treated as tied and resolved by the rendezvous tie-break.  The
    # long-term policy's frequency estimates are noisy decayed counters;
    # without a tolerance, meaningless sub-noise differences pick a
    # different "best" node per transaction and no attractor ever forms.
    tie_tol: float = 0.05


@functools.partial(jax.jit, static_argnames=("max_cpu", "overload_ctrl"))
def short_term_costs(
    lease_view: jax.Array,  # [n, s] 0/1
    cpu: jax.Array,         # [n]
    origin: jax.Array,      # scalar int32
    max_cpu: float,
    overload_ctrl: bool,
) -> jax.Array:
    """SC(i, S) for every node i (∞ where constraint (3) is violated)."""
    n = lease_view.shape[0]
    owns_all = jnp.all(lease_view > 0, axis=1)          # ∀x∈S: L(i,x)=1
    is_origin = jnp.arange(n) == origin
    # The four cases of SC(i, S):
    cost = jnp.where(
        is_origin,
        jnp.where(owns_all, C_URB, C_AB + 2.0 * C_URB),
        jnp.where(owns_all, C_P2P + C_URB, C_P2P + C_AB + 2.0 * C_URB),
    )
    if overload_ctrl:
        cost = jnp.where(cpu < max_cpu, cost, jnp.inf)
    return cost


@functools.partial(jax.jit, static_argnames=("max_cpu", "overload_ctrl"))
def long_term_costs(
    freq: jax.Array,        # [n, s] F(j, x) restricted to x ∈ S
    cpu: jax.Array,         # [n]
    max_cpu: float,
    overload_ctrl: bool,
) -> jax.Array:
    """LC(i, S) = Σ_{x∈S} Σ_{j≠i} F(j, x) for every node i."""
    per_class_total = jnp.sum(freq, axis=0)             # Σ_j F(j, x)
    total = jnp.sum(per_class_total)                    # Σ_x Σ_j
    own = jnp.sum(freq, axis=1)                         # Σ_x F(i, x)
    cost = total - own
    if overload_ctrl:
        cost = jnp.where(cpu < max_cpu, cost, jnp.inf)
    return cost


@jax.jit
def solve(costs: jax.Array, origin: jax.Array, tie_node: jax.Array = None) -> jax.Array:
    """Masked argmin: ties prefer the rendezvous ``tie_node``, then the origin.

    If every node violates the CPU constraint (all costs ∞), fall back to the
    origin — the transaction must be handled somewhere.  See ``solve_np`` for
    the rendezvous tie-break rationale.
    """
    n = costs.shape[0]
    if tie_node is None:
        tie_node = jnp.asarray(-1, dtype=jnp.int32)
    finite = jnp.isfinite(costs)
    any_finite = jnp.any(finite)
    scale = jnp.maximum(jnp.max(jnp.where(finite, jnp.abs(costs), 0.0)), 1.0)
    m = jnp.min(jnp.where(finite, costs, jnp.inf))
    minima = finite & (costs <= m + 1e-9 * scale)          # the argmin set
    count = jnp.sum(minima.astype(jnp.int32))
    # rendezvous: the (tie_node mod count)-th member of the argmin set
    rank = jnp.cumsum(minima.astype(jnp.int32)) - 1        # 0-based rank among minima
    want = jnp.where(count > 0, (tie_node % jnp.maximum(count, 1)), 0)
    pick_rdv = jnp.argmax(minima & (rank == want))
    # tie_node < 0: prefer the origin if optimal, else lowest-id minimum
    origin_ok = minima[origin]
    pick_def = jnp.where(origin_ok, origin, jnp.argmax(minima))
    best = jnp.where(tie_node >= 0, pick_rdv, pick_def)
    return jnp.where(any_finite, best, origin)


# -- numpy mirrors -----------------------------------------------------------
# The discrete-event simulator issues one decision per transaction; at 4-16
# nodes the jit dispatch overhead dominates, so the inner loop uses these
# numpy twins.  tests/test_dtd.py asserts exact agreement with the jitted
# kernels across randomized inputs.

def short_term_costs_np(lease_view, cpu, origin, max_cpu, overload_ctrl):
    n = lease_view.shape[0]
    owns_all = np.all(lease_view > 0, axis=1)
    is_origin = np.arange(n) == origin
    cost = np.where(
        is_origin,
        np.where(owns_all, C_URB, C_AB + 2.0 * C_URB),
        np.where(owns_all, C_P2P + C_URB, C_P2P + C_AB + 2.0 * C_URB),
    )
    if overload_ctrl:
        cost = np.where(cpu < max_cpu, cost, np.inf)
    return cost


def long_term_costs_np(freq, cpu, max_cpu, overload_ctrl):
    total = float(np.sum(freq))
    cost = total - np.sum(freq, axis=1)
    if overload_ctrl:
        cost = np.where(cpu < max_cpu, cost, np.inf)
    return cost


def solve_np(costs: np.ndarray, origin: int, tie_node: int = -1) -> int:
    """Masked argmin; ties prefer ``tie_node`` (rendezvous), then the origin.

    The paper leaves tie-breaking unspecified.  With symmetric access
    frequencies (e.g. the Bank benchmark at P=0) the long-term costs LC(i,S)
    tie across all nodes; breaking ties toward a *deterministic rendezvous
    node* — a hash of the conflict-class set S — makes every replica route
    transactions on S to the same node, which is what turns that node into
    the "attractor" the paper describes (§1) and is required to reproduce
    the low-locality Lilac-TM gains of Fig. 3(a).  Breaking toward the
    origin instead disperses the txns and forfeits lease reuse.
    """
    n = costs.shape[0]
    finite = np.isfinite(costs)
    if not finite.any():
        return int(origin)
    scale = max(float(np.max(np.abs(costs[finite]))), 1.0)
    m = float(np.min(np.where(finite, costs, np.inf)))
    minima = np.flatnonzero(finite & (costs <= m + 1e-9 * scale))
    if origin in minima and tie_node < 0:
        return int(origin)
    if tie_node < 0:
        return int(minima[0])
    return int(minima[tie_node % len(minima)])


class DTD:
    """Per-replica dispatcher facade over the jitted policy kernels."""

    def __init__(self, cfg: DTDConfig, n_nodes: int):
        self.cfg = cfg
        self.n_nodes = n_nodes

    def feasible(self, cpu: np.ndarray, node: int) -> bool:
        """Constraint (3): may ``node`` take on more work?  Always true when
        overload control is disabled."""
        return (not self.cfg.enable_overload_ctrl) or \
            float(cpu[node]) < self.cfg.max_cpu

    def decide(
        self,
        origin: int,
        ccs: "frozenset[int]",
        lease_owner_of_cc,   # callable cc -> owner id (-1 none), local view
        freq_rates: np.ndarray,   # [n_nodes, n_classes]
        cpu: np.ndarray,          # [n_nodes]
        opt_hint: int = -1,       # OPT policy target (benchmark-provided)
    ) -> int:
        cfg = self.cfg
        if cfg.policy == "local" or not ccs:
            return origin
        if cfg.policy == "opt":
            # the benchmark-optimal static policy (e.g. bank partition home),
            # still subject to the overload constraint:
            if opt_hint < 0:
                return origin
            if cfg.enable_overload_ctrl and cpu[opt_hint] >= cfg.max_cpu:
                return origin
            return int(opt_hint)

        s = sorted(ccs)
        owners = np.array([lease_owner_of_cc(cc) for cc in s], dtype=np.int32)
        lease_view = (
            owners[None, :] == np.arange(self.n_nodes, dtype=np.int32)[:, None]
        ).astype(np.float32)
        if cfg.policy == "short":
            costs = short_term_costs_np(
                lease_view, cpu, origin, cfg.max_cpu, cfg.enable_overload_ctrl
            )
        elif cfg.policy == "long":
            costs = long_term_costs_np(
                freq_rates[:, s], cpu, cfg.max_cpu, cfg.enable_overload_ctrl
            )
        else:
            raise ValueError(f"unknown DTD policy {cfg.policy!r}")
        if cfg.tie_tol > 0:
            finite = np.isfinite(costs)
            if finite.any():
                scale = max(float(np.max(np.abs(costs[finite]))), 1e-12)
                step = cfg.tie_tol * scale
                costs = np.where(finite, np.floor(costs / step) * step, costs)
        # rendezvous tie-break: deterministic hash of the class set
        tie_node = hash(tuple(s)) % self.n_nodes
        return solve_np(costs, origin, tie_node)
