"""Deterministic discrete-event engine used by the cluster simulator.

Events are ordered by (time, seq) where ``seq`` is a monotonically increasing
issue counter — two events scheduled for the same instant fire in the order
they were scheduled, which makes every simulation run bit-reproducible for a
given seed.
"""
from __future__ import annotations

import heapq
import itertools
from dataclasses import dataclass, field
from typing import Any, Callable, Optional


@dataclass(order=True)
class _Event:
    time: float
    seq: int
    fn: Callable[[], None] = field(compare=False)
    cancelled: bool = field(default=False, compare=False)


class EventQueue:
    """A deterministic priority queue of timed callbacks."""

    def __init__(self) -> None:
        self._heap: list[_Event] = []
        self._seq = itertools.count()
        self.now: float = 0.0
        self.n_dispatched: int = 0

    def schedule(self, delay: float, fn: Callable[[], None]) -> _Event:
        if delay < 0:
            raise ValueError(f"negative delay {delay}")
        ev = _Event(self.now + delay, next(self._seq), fn)
        heapq.heappush(self._heap, ev)
        return ev

    def at(self, time: float, fn: Callable[[], None]) -> _Event:
        return self.schedule(max(0.0, time - self.now), fn)

    def cancel(self, ev: _Event) -> None:
        ev.cancelled = True

    def run(self, until: float, max_events: Optional[int] = None) -> None:
        """Dispatch events in order until simulated ``until`` time."""
        n = 0
        while self._heap and self._heap[0].time <= until:
            ev = heapq.heappop(self._heap)
            if ev.cancelled:
                continue
            self.now = ev.time
            ev.fn()
            self.n_dispatched += 1
            n += 1
            if max_events is not None and n >= max_events:
                break
        self.now = max(self.now, until)

    def empty(self) -> bool:
        return not any(not e.cancelled for e in self._heap)
