"""Deterministic discrete-event engine used by the cluster simulator.

Events are ordered by (time, seq) where ``seq`` is a monotonically increasing
issue counter — two events scheduled for the same instant fire in the order
they were scheduled, which makes every simulation run bit-reproducible for a
given seed.

Schedule exploration (repro.analysis.explore) plugs in through
:class:`SchedulePolicy`: an optional seam that controls dispatch order among
*enabled* events — the group scheduled for the same instant, plus message
deliveries falling inside a bounded commutation window after it.  The policy
only ever reorders candidates the delivery-order metadata (:class:`EvMeta`)
marks as legal: total-order, opt-before-TO, and per-sender FIFO constraints
are enforced here so every explored schedule is one the real GCS could have
produced.  With no policy installed (the default), the engine runs the exact
(time, seq) heap order it always has.
"""
from __future__ import annotations

import heapq
import itertools
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, FrozenSet, List, Optional, Set, Tuple

from repro.obs import trace as obs_trace


@dataclass(frozen=True)
class EvMeta:
    """Delivery-order metadata stamped on schedulable events.

    ``chain``/``cseq`` encode a FIFO constraint: events sharing a chain are
    only dispatchable in ``cseq`` order (TO per node, URB per (sender, node),
    p2p per (sender, node)).  ``after_opt`` marks a final TO-delivery that
    must follow its own optimistic delivery (paired via ``msgid``/``node``).
    ``keys`` are the conflict classes the event touches — the explorer's
    independence oracle (disjoint keys commute); ``None`` means opaque,
    dependent with everything.
    """

    kind: str = "local"            # opt | to | urb | p2p | view | local
    node: int = -1                 # delivering / executing node
    chain: Optional[Tuple] = None  # FIFO chain id (hashable)
    cseq: int = -1                 # dense position within the chain
    msgid: int = -1                # OAB message id pairing opt with TO
    after_opt: bool = False        # TO-delivery gated on its opt-delivery
    keys: Optional[FrozenSet[int]] = None
    label: str = ""


@dataclass(order=True)
class _Event:
    time: float
    seq: int
    fn: Callable[[], None] = field(compare=False)
    cancelled: bool = field(default=False, compare=False)
    meta: Optional[EvMeta] = field(default=None, compare=False)


class SchedulePolicy:
    """Dispatch-order seam over enabled same-instant events.

    The queue hands ``select`` the candidate pool — every live event at the
    earliest pending instant, plus delivery events (``meta.kind != "local"``)
    within ``window`` ms after it — sorted by (time, seq).  ``select``
    returns the index of the event to dispatch; candidates that would break
    a delivery-order constraint must not be chosen (``eligible`` encodes
    them).  ``on_dispatch`` is invoked for *every* dispatched event
    (including the no-choice singletons) so the FIFO-chain bookkeeping stays
    in lockstep with the run.

    The base class is the identity policy: window 0, first eligible
    candidate — byte-identical to running with no policy at all.
    """

    window: float = 0.0

    def __init__(self) -> None:
        self._chain_done: Dict[Tuple, int] = {}
        self._opts_done: Set[Tuple[int, int]] = set()

    # -- delivery-order constraints -----------------------------------------
    def eligible(self, ev: _Event) -> bool:
        m = ev.meta
        if m is None:
            return True
        if m.after_opt and (m.node, m.msgid) not in self._opts_done:
            return False
        if m.chain is not None and self._chain_done.get(m.chain, 0) != m.cseq:
            return False
        return True

    # -- hooks ---------------------------------------------------------------
    def select(self, candidates: List[_Event]) -> int:
        """Pick the next event among >= 2 candidates (sorted by time, seq)."""
        for i, ev in enumerate(candidates):
            if self.eligible(ev):
                return i
        return 0  # unreachable for well-formed metadata; fail open

    def on_dispatch(self, ev: _Event) -> None:
        m = ev.meta
        if m is None:
            return
        if m.kind == "opt":
            self._opts_done.add((m.node, m.msgid))
        if m.chain is not None:
            self._chain_done[m.chain] = m.cseq + 1


class EventQueue:
    """A deterministic priority queue of timed callbacks."""

    def __init__(self, policy: Optional[SchedulePolicy] = None) -> None:
        self._heap: list[_Event] = []
        self._seq = itertools.count()
        self.now: float = 0.0
        self.n_dispatched: int = 0
        self.policy = policy
        # queue-level dispatch tracing rides the module-level recorder,
        # captured once at construction: `repro-explore replay --trace`
        # installs it before building the scenario, so counterexample
        # replays get per-delivery timelines while ordinary runs keep a
        # None here (one dead branch per dispatch, nothing else)
        self.trace = obs_trace.TRACE if obs_trace.TRACE.enabled else None

    def schedule(self, delay: float, fn: Callable[[], None],
                 meta: Optional[EvMeta] = None) -> _Event:
        if delay < 0:
            raise ValueError(f"negative delay {delay}")
        ev = _Event(self.now + delay, next(self._seq), fn, meta=meta)
        heapq.heappush(self._heap, ev)
        return ev

    def at(self, time: float, fn: Callable[[], None]) -> _Event:
        return self.schedule(max(0.0, time - self.now), fn)

    def cancel(self, ev: _Event) -> None:
        ev.cancelled = True

    def run(self, until: float, max_events: Optional[int] = None) -> None:
        """Dispatch events in order until simulated ``until`` time."""
        n = 0
        policy = self.policy
        while self._heap and self._heap[0].time <= until:
            if policy is None:
                ev = heapq.heappop(self._heap)
                if ev.cancelled:
                    continue
                self.now = ev.time
            else:
                ev = self._pick(policy)
                if ev is None:
                    continue
                policy.on_dispatch(ev)
            tr = self.trace
            if tr is not None and ev.meta is not None:
                m = ev.meta
                tr.instant("dispatch", f"node{m.node}/gcs" if m.node >= 0
                           else "events", ts=ev.time, kind=m.kind,
                           label=m.label)
            ev.fn()
            self.n_dispatched += 1
            n += 1
            if max_events is not None and n >= max_events:
                break
        self.now = max(self.now, until)

    def _pick(self, policy: SchedulePolicy) -> Optional[_Event]:
        """Pop the policy-chosen event from the enabled candidate pool.

        The pool is every live event at the earliest pending instant plus
        delivery events within the commutation window after it.  The chosen
        event dispatches at the *base* instant (``now`` stays monotone:
        candidates never precede it), everything else is pushed back.
        """
        heap = self._heap
        base = heap[0].time
        limit = base + policy.window
        pool: List[_Event] = []
        deferred: List[_Event] = []
        while heap and heap[0].time <= limit:
            ev = heapq.heappop(heap)
            if ev.cancelled:
                continue
            in_window = ev.time <= base or (
                ev.meta is not None and ev.meta.kind != "local")
            (pool if in_window else deferred).append(ev)
        for ev in deferred:
            heapq.heappush(heap, ev)
        if not pool:
            return None
        if len(pool) == 1:
            chosen = pool[0]
        else:
            pool.sort()
            idx = policy.select(pool)
            chosen = pool.pop(idx)
            for ev in pool:
                heapq.heappush(heap, ev)
        self.now = max(self.now, base)
        return chosen

    def pending(self) -> List[_Event]:
        """Live events still queued, in (time, seq) order (for fingerprints)."""
        return sorted(e for e in self._heap if not e.cancelled)

    def empty(self) -> bool:
        return not any(not e.cancelled for e in self._heap)
