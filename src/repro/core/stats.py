"""Access-frequency and CPU-utilization statistics (DTD inputs).

The paper gathers, per node, the access frequencies F(j, x) — transactions/s
originated on node j touching conflict class x — and CPU utilization, both
piggybacked on commit / lease-request messages.  We model the piggybacking by
updating every replica's *view* of these statistics at message-delivery time
(the cluster calls :meth:`on_commit_delivered`), so views are as stale as the
message latency, exactly like the real system.

Frequencies use exponentially-decayed counters: an event at time t adds 1 to
a counter that decays as exp(-Δt/τ); the rate estimate is counter/τ.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Iterable

import numpy as np


class DecayedFrequency:
    """F[j, x] matrix of exponentially-decayed event rates."""

    def __init__(self, n_nodes: int, n_classes: int, tau_ms: float = 200.0) -> None:
        self.tau = tau_ms
        self.counts = np.zeros((n_nodes, n_classes), dtype=np.float64)
        self.last_t = 0.0

    def _decay_to(self, t: float) -> None:
        if t > self.last_t:
            self.counts *= math.exp(-(t - self.last_t) / self.tau)
            self.last_t = t

    def record(self, t: float, origin: int, ccs: Iterable[int]) -> None:
        self._decay_to(t)
        for cc in ccs:
            self.counts[origin, cc] += 1.0

    def rates(self, t: float) -> np.ndarray:
        """F(j, x) in events/ms, shape [n_nodes, n_classes]."""
        self._decay_to(t)
        return self.counts / self.tau


class CpuMeter:
    """EWMA utilization of a node's execution slots."""

    def __init__(self, n_slots: int, tau_ms: float = 50.0) -> None:
        self.n_slots = max(1, n_slots)
        self.tau = tau_ms
        self.value = 0.0
        self.busy = 0
        self.extra_load = 0.0  # injected background jobs (overload experiment)
        self.last_t = 0.0

    def _advance(self, t: float) -> None:
        if t > self.last_t:
            inst = min(1.0, self.busy / self.n_slots + self.extra_load)
            a = math.exp(-(t - self.last_t) / self.tau)
            self.value = a * self.value + (1 - a) * inst
            self.last_t = t

    def acquire(self, t: float) -> None:
        self._advance(t)
        self.busy += 1

    def release(self, t: float) -> None:
        self._advance(t)
        self.busy -= 1
        assert self.busy >= 0

    def utilization(self, t: float) -> float:
        # extra_load is already folded into the EWMA target by _advance;
        # adding it here again would double-count the injected load and trip
        # the constraint-(3) valve at ~half the configured threshold
        self._advance(t)
        return min(1.0, self.value)
