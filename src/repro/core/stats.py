"""Access-frequency and CPU-utilization statistics (DTD inputs).

The paper gathers, per node, the access frequencies F(j, x) — transactions/s
originated on node j touching conflict class x — and CPU utilization, both
piggybacked on commit / lease-request messages.  We model the piggybacking by
updating every replica's *view* of these statistics at message-delivery time
(the cluster calls :meth:`on_commit_delivered`), so views are as stale as the
message latency, exactly like the real system.

Frequencies use exponentially-decayed counters: an event at time t adds 1 to
a counter that decays as exp(-Δt/τ); the rate estimate is counter/τ.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Iterable

import numpy as np


class DecayedFrequency:
    """F[j, x] matrix of exponentially-decayed event rates.

    The single decayed-counter implementation of the repo: the simulator's
    per-replica access frequencies, the serving router's per-session touch
    rates, and the placement planner's affinity matrices
    (:mod:`repro.plan.affinity`) are all instances of this class, decayed
    against one caller-supplied clock (the event queue's ``now`` in the
    simulator, the engine-ticked router clock in serving).

    ``grow_cols=True`` lets the column space grow on demand in power-of-two
    steps (sessions appear dynamically; conflict classes are fixed), so one
    matrix replaces a dict of per-column trackers without recompiling
    consumers on every new column.
    """

    def __init__(self, n_nodes: int, n_classes: int, tau_ms: float = 200.0,
                 *, grow_cols: bool = False) -> None:
        self.tau = tau_ms
        self.grow_cols = grow_cols
        self.counts = np.zeros((n_nodes, n_classes), dtype=np.float64)
        self.last_t = 0.0

    @property
    def n_cols(self) -> int:
        return self.counts.shape[1]

    def ensure_col(self, col: int) -> None:
        """Grow the column space (power-of-two steps) to include ``col``."""
        n = self.counts.shape[1]
        if col < n:
            return
        if not self.grow_cols:
            raise IndexError(f"column {col} out of range (n_cols={n})")
        m = max(1, n)
        while m <= col:
            m *= 2
        grown = np.zeros((self.counts.shape[0], m), dtype=np.float64)
        grown[:, :n] = self.counts
        self.counts = grown

    def _decay_to(self, t: float) -> None:
        if t > self.last_t:
            self.counts *= math.exp(-(t - self.last_t) / self.tau)
            self.last_t = t

    def record(self, t: float, origin: int, ccs: Iterable[int],
               weight: float = 1.0) -> None:
        self._decay_to(t)
        for cc in ccs:
            if cc >= self.counts.shape[1]:
                self.ensure_col(cc)
            self.counts[origin, cc] += weight

    def rates(self, t: float) -> np.ndarray:
        """F(j, x) in events/ms, shape [n_nodes, n_classes]."""
        self._decay_to(t)
        return self.counts / self.tau

    def zero_col(self, col: int) -> None:
        """Forget a column (e.g. an evicted session)."""
        if col < self.counts.shape[1]:
            self.counts[:, col] = 0.0

    # zero_col under its control-plane name: eviction *frees* the column
    # for reuse by a recycled id (the epoch tombstone lives with the
    # consumer; see repro.serve.router.LocalityRouter.evict)
    free_col = zero_col

    def shrink_to(self, n_cols: int, *, floor: int = 64) -> None:
        """Shrink the grown column space to the pow2 covering ``n_cols``.

        The grow-only policy means a burst of high session ids pins memory
        forever; after mass evictions the consumer passes its highest live
        id + 1 and the matrix drops back.  Hysteresis: only shrink when at
        least 4x over target, so churn around a boundary never thrashes
        reallocation.  No-op for fixed-width matrices.
        """
        if not self.grow_cols:
            return
        target = max(1, floor)
        while target < n_cols:
            target *= 2
        if target * 4 <= self.counts.shape[1]:
            self.counts = self.counts[:, :target].copy()


class CpuMeter:
    """EWMA utilization of a node's execution slots."""

    def __init__(self, n_slots: int, tau_ms: float = 50.0) -> None:
        self.n_slots = max(1, n_slots)
        self.tau = tau_ms
        self.value = 0.0
        self.busy = 0
        self.extra_load = 0.0  # injected background jobs (overload experiment)
        self.last_t = 0.0

    def _advance(self, t: float) -> None:
        if t > self.last_t:
            inst = min(1.0, self.busy / self.n_slots + self.extra_load)
            a = math.exp(-(t - self.last_t) / self.tau)
            self.value = a * self.value + (1 - a) * inst
            self.last_t = t

    def acquire(self, t: float) -> None:
        self._advance(t)
        self.busy += 1

    def release(self, t: float) -> None:
        self._advance(t)
        self.busy -= 1
        assert self.busy >= 0

    def utilization(self, t: float) -> float:
        # extra_load is already folded into the EWMA target by _advance;
        # adding it here again would double-count the injected load and trip
        # the constraint-(3) valve at ~half the configured threshold
        self._advance(t)
        return min(1.0, self.value)
