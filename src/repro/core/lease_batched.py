"""Sharded, array-backed lease manager: the million-class control plane.

``LeaseManagerBase`` (repro.core.lease) keeps one python list per conflict
class — exactly Algorithm 1, and the byte-identical oracle — but every
delivery instant walks python objects, which is the serial bottleneck at
million-class scale.  This module re-lands the same replicated state as a
handful of dense arrays sharded by class hash, so a whole instant's worth
of protocol work (enqueue at TO-deliver, blocking+frees at Opt-deliver,
dequeues at LeaseFreed, ``isEnabled`` checks for every waiting commit
phase) settles as vectorized queue-position math, with the packed
head/wait arrays dispatched through one jit'd
:func:`repro.kernels.ops.settle_lease_batch` when the instant is large
enough to amortize it (``jax_min``, mirroring ``certify_jax_min``).

Layout: class ``cc`` lives in shard ``cc & (n_shards-1)`` at row
``cc >> log2(n_shards)``.  Each shard holds four ``[rows, cap]`` arrays
(``req``/``proc``/``active``/``blocked``) plus a ``qlen`` vector; ``cap``
grows in power-of-two steps like every other packed buffer in the repo
(``repro.core.stm._pad_bucket`` idiom).  Queue order *is* column order:
removals compact with a stable argsort, so FIFO order matches the oracle's
``list.remove`` exactly.

Only FGL fits this layout (one LOR per class per request — a queue cell is
a LOR).  ALC's multi-class LORs stay on the sequential manager; the
cluster gates construction accordingly (``SimConfig.lease_mode``).

Equivalence contract (pinned by tests/test_lease_batched.py): for any
delivery stream, every observable — queue contents and order, owner
views, freed-key lists and their order, ``is_enabled``/piggyback
verdicts — is byte-identical to ``FGLLeaseManager``.
"""
from __future__ import annotations

from typing import Dict, FrozenSet, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from .lease import LeaseRequest


def _pow2(n: int) -> int:
    p = 1
    while p < n:
        p *= 2
    return p


class BatchedLOR:
    """Handle onto one queue cell of a :class:`ShardedLeaseManager`.

    The oracle hands out ``LOR`` dataclass instances that *are* the state;
    here the state lives in the shard arrays, so the handle carries the
    immutable identity ``(req_id, proc, cc)`` and reads ``blocked`` /
    ``activeXacts`` live from its cell — consumers (the cluster, tests)
    see the same attribute surface either way.
    """

    __slots__ = ("_mgr", "req_id", "proc", "_cc")

    def __init__(self, mgr: "ShardedLeaseManager", req_id: int, proc: int,
                 cc: int) -> None:
        self._mgr = mgr
        self.req_id = req_id
        self.proc = proc
        self._cc = cc

    @property
    def cc(self) -> int:
        return self._cc

    @property
    def ccs(self) -> Tuple[int, ...]:
        return (self._cc,)

    def key(self) -> Tuple[int, int, Tuple[int, ...]]:
        return (self.req_id, self.proc, (self._cc,))

    def _cell(self) -> Tuple["_LeaseShard", int, int]:
        sh, row = self._mgr._locate(self._cc)
        pos = sh.find_one(row, self.req_id, self.proc)
        if pos < 0:
            raise LookupError(
                f"LOR (req={self.req_id}, proc={self.proc}, cc={self._cc}) "
                "is not enqueued")
        return sh, row, pos

    @property
    def blocked(self) -> bool:
        sh, row, pos = self._cell()
        return bool(sh.blocked[row, pos])

    @property
    def activeXacts(self) -> int:
        sh, row, pos = self._cell()
        return int(sh.active[row, pos])

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (f"BatchedLOR(req_id={self.req_id}, proc={self.proc}, "
                f"cc={self._cc})")


class _CQView:
    """Read-only ``cq[cc] -> [LOR-like]`` view over the shard arrays.

    Tests and diagnostics index the oracle's ``cq`` directly; this view
    materializes per-class handle lists on demand so the same code reads
    either manager.
    """

    def __init__(self, mgr: "ShardedLeaseManager") -> None:
        self._mgr = mgr

    def __len__(self) -> int:
        return self._mgr.n_classes

    def __getitem__(self, cc: int) -> List[BatchedLOR]:
        return self._mgr._queue_handles(cc)

    def __iter__(self):
        for cc in range(self._mgr.n_classes):
            yield self[cc]


class _LeaseShard:
    """Dense conflict-queue state for one shard: [slots, cap] cell arrays.

    Rows are *slots*, not class rows: a class row gets a dense slot the
    first time the protocol touches it (``lookup``), so the array
    footprint — and every scatter, gather and growth copy — scales with
    the classes in use, not the class space.  Sizing the arrays by the
    raw class-row space instead spreads the same traffic over a sparse
    multi-GB allocation where nearly every batched scatter faults fresh
    zero pages; at a million classes those soft faults cost more than
    the queue work itself.  Cell fill values are never observable:
    every reader masks by ``qlen``.
    """

    INIT_CAP = 8
    INIT_SLOTS = 1024

    def __init__(self, n_rows: int) -> None:
        self.n_rows = n_rows                 # class-row space of this shard
        self.cap = self.INIT_CAP
        self.slot_cap = min(_pow2(max(n_rows, 1)), self.INIT_SLOTS)
        self.n_slots = 0
        self.slot_of: Dict[int, int] = {}    # class row -> dense slot
        self.row_of = np.zeros((self.slot_cap,), np.int64)   # slot -> row
        self.req = np.zeros((self.slot_cap, self.cap), np.int32)
        self.proc = np.zeros((self.slot_cap, self.cap), np.int32)
        self.active = np.zeros((self.slot_cap, self.cap), np.int32)
        self.blocked = np.zeros((self.slot_cap, self.cap), bool)
        self.qlen = np.zeros((self.slot_cap,), np.int32)

    def lookup(self, rows: np.ndarray) -> np.ndarray:
        """Class rows -> dense slots, allocating on first touch.

        Allocation on read is deliberate: an untouched slot reads as an
        empty queue (qlen 0), so the translation can sit inside the one
        row-computation choke point (``_split`` / ``_locate``) and every
        array consumer stays oblivious to the indirection.
        """
        slot_of = self.slot_of
        out = np.empty((rows.size,), np.int64)
        new: List[int] = []
        for i, r in enumerate(rows.tolist()):
            s = slot_of.get(r)
            if s is None:
                s = len(slot_of)
                slot_of[r] = s
                new.append(r)
            out[i] = s
        if new:
            n = len(slot_of)
            if n > self.slot_cap:
                self._grow_slots(n)
            self.row_of[n - len(new): n] = new
            self.n_slots = n
        return out

    def lookup_one(self, r: int) -> int:
        s = self.slot_of.get(r)
        if s is not None:
            return s
        s = len(self.slot_of)
        if s + 1 > self.slot_cap:
            self._grow_slots(s + 1)
        self.slot_of[r] = s
        self.row_of[s] = r
        self.n_slots = s + 1
        return s

    def _grow_slots(self, need: int) -> None:
        slot_cap = _pow2(max(need, self.slot_cap * 2))
        ns = self.n_slots
        for name in ("req", "proc", "active", "blocked"):
            old = getattr(self, name)
            new = np.zeros((slot_cap, self.cap), old.dtype)
            new[:ns] = old[:ns]
            setattr(self, name, new)
        for name in ("row_of", "qlen"):
            old = getattr(self, name)
            new = np.zeros((slot_cap,), old.dtype)
            new[:ns] = old[:ns]
            setattr(self, name, new)
        self.slot_cap = slot_cap

    def _grow(self, need: int) -> None:
        cap = _pow2(max(need, self.cap * 2))
        ns = self.n_slots
        for name in ("req", "proc", "active", "blocked"):
            old = getattr(self, name)
            new = np.zeros((self.slot_cap, cap), old.dtype)
            new[:ns, : self.cap] = old[:ns]
            setattr(self, name, new)
        self.cap = cap

    # -- vectorized mutations ------------------------------------------------
    def enqueue(self, rows: np.ndarray, reqs: np.ndarray, procs: np.ndarray,
                blocked: np.ndarray) -> None:
        """Append one cell per entry, preserving input order within a row."""
        if rows.size == 0:
            return
        # rank duplicates of the same row so same-instant arrivals keep
        # their delivery order (stable sort = original order within a row)
        order = np.argsort(rows, kind="stable")
        sr = rows[order]
        starts = np.r_[0, np.flatnonzero(np.diff(sr)) + 1]
        lens = np.diff(np.r_[starts, sr.size])
        rank_sorted = np.arange(sr.size) - np.repeat(starts, lens)
        rank = np.empty_like(rank_sorted)
        rank[order] = rank_sorted
        pos = self.qlen[rows] + rank
        need = int(pos.max()) + 1
        if need > self.cap:
            self._grow(need)
        self.req[rows, pos] = reqs
        self.proc[rows, pos] = procs
        self.active[rows, pos] = 1
        self.blocked[rows, pos] = blocked
        np.add.at(self.qlen, rows, 1)

    def find(self, rows: np.ndarray, reqs: np.ndarray,
             procs: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        """Positions of (req, proc) cells in the given rows (-1: absent).

        A request enqueues at most one LOR per class, so (req_id, proc)
        identifies at most one cell per queue.
        """
        cols = np.arange(self.cap)[None, :]
        valid = cols < self.qlen[rows, None]
        hit = valid & (self.req[rows] == reqs[:, None]) \
            & (self.proc[rows] == procs[:, None])
        found = hit.any(axis=1)
        pos = np.where(found, hit.argmax(axis=1), -1)
        return pos, found

    def find_one(self, row: int, req_id: int, proc: int) -> int:
        n = int(self.qlen[row])
        if n == 0:
            return -1
        hit = (self.req[row, :n] == req_id) & (self.proc[row, :n] == proc)
        i = int(hit.argmax())
        return i if hit[i] else -1

    def compact_rows(self, urows: np.ndarray, delmask: np.ndarray) -> None:
        """Remove the masked cells of ``urows`` (delmask: [len(urows), cap]),
        sliding survivors left — the array rendition of ``list.remove`` in
        FIFO order (stable argsort keeps the relative order of keepers)."""
        order = np.argsort(delmask, axis=1, kind="stable")
        ndel = delmask.sum(axis=1).astype(np.int32)
        newlen = self.qlen[urows] - ndel
        cols = np.arange(self.cap)[None, :]
        tail = cols >= newlen[:, None]
        for name, fill in (("req", -1), ("proc", -1),
                           ("active", 0), ("blocked", False)):
            arr = getattr(self, name)
            sub = np.take_along_axis(arr[urows], order, axis=1)
            sub[tail] = fill
            arr[urows] = sub
        self.qlen[urows] = newlen

    def remove(self, rows: np.ndarray, reqs: np.ndarray,
               procs: np.ndarray) -> None:
        """Dequeue the named (req, proc) cells; absent keys are no-ops
        (matching the oracle's ``try: remove except ValueError: pass``)."""
        if rows.size == 0:
            return
        pos, found = self.find(rows, reqs, procs)
        if not found.any():
            return
        rows, pos = rows[found], pos[found]
        urows, inv = np.unique(rows, return_inverse=True)
        dm = np.zeros((urows.size, self.cap), bool)
        dm[inv, pos] = True
        self.compact_rows(urows, dm)


class ShardedLeaseManager:
    """FGL lease manager over sharded arrays (drop-in for FGLLeaseManager).

    The protocol surface (``on_to_deliver`` / ``on_opt_deliver`` /
    ``on_ur_deliver_freed`` / ``finished_xact`` / ``is_enabled`` /
    ``try_piggyback`` / ``purge_proc`` / owner queries) matches
    :class:`repro.core.lease.FGLLeaseManager` observable-for-observable;
    the ``*_batch`` entry points amortize one delivery instant's worth of
    events into single array ops (the microbench and serving paths).
    """

    def __init__(self, proc: int, n_classes: int, *, n_shards: int = 8,
                 jax_min: int = 64) -> None:
        if n_shards < 1 or (n_shards & (n_shards - 1)) != 0:
            raise ValueError(f"n_shards must be a power of two, got {n_shards}")
        self.proc = proc
        self.n_classes = n_classes
        self.n_shards = n_shards
        self.jax_min = jax_min
        self._smask = n_shards - 1
        self._sbits = n_shards.bit_length() - 1
        self._n_rows = (n_classes + n_shards - 1) // n_shards
        self._shards = [_LeaseShard(self._n_rows) for _ in range(n_shards)]
        # same replica-local bookkeeping as the oracle
        self._by_req: Dict[int, List[BatchedLOR]] = {}
        self._pending_opt: Dict[int, LeaseRequest] = {}
        # sparse twin of the oracle's pending-ccs union: per-class count of
        # pending opt-delivered requests touching it (born-blocked check).
        # A dict, not an int32[C] vector: pending sets are instant-sized,
        # and the hot batch loops touch it per request — O(ccs) dict ops
        # beat a C-wide scatter per message by orders of magnitude
        self._pending_cnt: Dict[int, int] = {}
        self._dead: set = set()
        self.n_piggyback = 0
        self.n_requests = 0
        self.cq = _CQView(self)

    # -- layout helpers ------------------------------------------------------
    def _locate(self, cc: int) -> Tuple[_LeaseShard, int]:
        sh = self._shards[cc & self._smask]
        return sh, sh.lookup_one(cc >> self._sbits)

    def _split(self, ccs: np.ndarray) -> Iterable[
            Tuple[_LeaseShard, np.ndarray, np.ndarray]]:
        """Group flat class ids by shard: yields (shard, slots, flat_mask).

        The returned row indices are the shard's dense *slots* — the
        class-row -> slot translation happens here (and in ``_locate``)
        so every consumer indexes the compact arrays directly.
        """
        s = ccs & self._smask
        rows = ccs >> self._sbits
        for sh_id in np.unique(s):
            m = s == sh_id
            sh = self._shards[sh_id]
            yield sh, sh.lookup(rows[m]), m

    def _queue_handles(self, cc: int) -> List[BatchedLOR]:
        sh, row = self._locate(cc)
        n = int(sh.qlen[row])
        return [BatchedLOR(self, int(sh.req[row, i]), int(sh.proc[row, i]), cc)
                for i in range(n)]

    # -- owner queries -------------------------------------------------------
    def head_owner(self, cc: int) -> int:
        sh, row = self._locate(cc)
        return int(sh.proc[row, 0]) if sh.qlen[row] > 0 else -1

    def owner_np(self) -> np.ndarray:
        """L(i, x) ownership vector as one gather (-1: unowned)."""
        _, head_proc, _, qlen = self._head_state()
        return np.where(qlen > 0, head_proc, -1).astype(np.int32)

    def owner_view(self) -> List[int]:
        return self.owner_np().tolist()

    def owns_all(self, ccs: Iterable[int]) -> bool:
        return all(self.head_owner(cc) == self.proc for cc in ccs)

    def has_unblocked(self, cc: int, proc: int) -> bool:
        """True iff ``proc`` has an unblocked LOR anywhere in ``cc``'s queue."""
        sh, row = self._locate(cc)
        n = int(sh.qlen[row])
        if n == 0:
            return False
        return bool(((sh.proc[row, :n] == proc)
                     & ~sh.blocked[row, :n]).any())

    def _head_state(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray,
                                   np.ndarray]:
        """Per-class head cell, scattered back from the dense slot tables.

        O(touched classes) work into O(n_classes) output: classes no slot
        was ever allocated for stay at qlen 0 (unowned), which is exactly
        their queue state.
        """
        C = self.n_classes
        head_req = np.zeros((C,), np.int32)
        head_proc = np.zeros((C,), np.int32)
        head_active = np.zeros((C,), np.int32)
        qlen = np.zeros((C,), np.int32)
        for s_id, sh in enumerate(self._shards):
            ns = sh.n_slots
            if not ns:
                continue
            cc = (sh.row_of[:ns] << self._sbits) | s_id
            head_req[cc] = sh.req[:ns, 0]
            head_proc[cc] = sh.proc[:ns, 0]
            head_active[cc] = sh.active[:ns, 0]
            qlen[cc] = sh.qlen[:ns]
        return head_req, head_proc, head_active, qlen

    # -- the per-instant settle ---------------------------------------------
    def settle(self, groups: Sequence[Sequence[BatchedLOR]],
               fresh_ccs: Optional[np.ndarray] = None, *,
               use_kernel: bool = False
               ) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
        """One settle over the instant's touched classes.

        Returns ``(rel, owner, free, enabled)``: ``rel`` is the sorted
        vector of classes this instant actually touched (every waiter's
        class plus ``fresh_ccs``), and ``owner``/``free`` are head verdicts
        aligned to it.  Compacting to ``rel`` is what keeps an instant
        O(batch) at million-class scale — the head state of untouched
        classes can't change a verdict, so it is never gathered.  The
        compact axis is pow2-padded (padding rows read as empty queues) so
        recurring batch sizes reuse the jit cache.

        ``fresh_ccs`` names classes whose head LOR was newly blocked at
        this instant (an Opt-deliver hit an own unblocked head); the free
        mask is exactly those heads that are also drained — the
        blocked-and-drained rule as queue-position math.  ``groups`` are
        waiting LOR groups (commit phases / prefetches); ``enabled[b]`` is
        Algorithm 1's ``isEnabled`` for group ``b``.  Dispatches the jit'd
        kernel when ``use_kernel`` (callers gate on ``jax_min``), else the
        numpy twin — the two agree bitwise (tests pin it).
        """
        if fresh_ccs is None:
            fresh_ccs = np.empty((0,), np.int32)
        fresh_ccs = np.asarray(fresh_ccs, np.int32)
        flat_cc = np.fromiter((l._cc for g in groups for l in g), np.int32)
        rel = np.unique(np.concatenate([flat_cc, fresh_ccs]))
        Cp = _pow2(max(rel.size, 1))
        head_req = np.full((Cp,), -1, np.int32)
        head_proc = np.full((Cp,), -1, np.int32)
        head_active = np.zeros((Cp,), np.int32)
        qlen = np.zeros((Cp,), np.int32)
        for sh, rows, m in self._split(rel):
            idx = np.flatnonzero(m)
            head_req[idx] = sh.req[rows, 0]
            head_proc[idx] = sh.proc[rows, 0]
            head_active[idx] = sh.active[rows, 0]
            qlen[idx] = sh.qlen[rows]
        fresh = np.zeros((Cp,), bool)
        fresh[np.searchsorted(rel, fresh_ccs)] = True
        B = len(groups)
        Bp = _pow2(max(B, 1))
        K = _pow2(max([len(g) for g in groups] + [1]))
        wait_req = np.full((Bp, K), -1, np.int32)
        wait_cc = np.full((Bp, K), -1, np.int32)
        for i, g in enumerate(groups):
            for j, l in enumerate(g):
                wait_req[i, j] = l.req_id
                wait_cc[i, j] = l._cc
        valid = wait_cc >= 0
        wait_cc[valid] = np.searchsorted(rel, wait_cc[valid])
        if use_kernel:
            from repro.kernels import ops

            owner, free, enabled = ops.settle_lease_batch(
                head_req, head_proc, head_active, qlen, fresh,
                wait_req, wait_cc, self.proc)
            return (rel, np.asarray(owner), np.asarray(free),
                    np.asarray(enabled)[:B])
        owner, free, enabled = _settle_np(
            head_req, head_proc, head_active, qlen, fresh,
            wait_req, wait_cc, self.proc)
        return rel, owner, free, enabled[:B]

    def enabled_mask(self, groups: Sequence[Sequence[BatchedLOR]]
                     ) -> List[bool]:
        """Vectorized ``isEnabled`` over many waiting groups at once."""
        if not groups:
            return []
        if len(groups) >= self.jax_min:
            _, _, _, enabled = self.settle(groups, use_kernel=True)
            return [bool(x) for x in enabled]
        return [self.is_enabled(g) for g in groups]

    def is_enabled(self, lors: Sequence[BatchedLOR]) -> bool:
        for l in lors:
            sh, row = self._locate(l.cc)
            if (sh.qlen[row] == 0 or sh.req[row, 0] != l.req_id
                    or sh.proc[row, 0] != l.proc):
                return False
        return True

    # -- protocol events -----------------------------------------------------
    def on_to_deliver(self, req: LeaseRequest) -> List[BatchedLOR]:
        return self.to_deliver_batch([req])[0]

    def to_deliver_batch(self, reqs: Sequence[LeaseRequest]
                         ) -> List[List[BatchedLOR]]:
        """TO-deliver many requests in delivery order, one batched enqueue.

        Born-blocked catch-up reads only the pending counter (never queue
        state), so deferring the enqueue scatter to the end of the batch is
        exact: within a row, batch order is delivery order.  The loop body
        is deliberately numpy-free — per-request array calls would cost
        microseconds each; flat python lists feed one concatenated scatter.
        """
        out: List[List[BatchedLOR]] = []
        ccs_l: List[int] = []
        rid_l: List[int] = []
        proc_l: List[int] = []
        blk_l: List[bool] = []
        cnt = self._pending_cnt
        for req in reqs:
            if req.coarse:
                raise ValueError(
                    "ShardedLeaseManager is FGL-only (lease_mode='batched' "
                    "requires lease_kind='fgl')")
            pending = self._pending_opt.pop(req.req_id, None)
            if pending is not None:
                for cc in pending.ccs:
                    n = cnt[cc] - 1
                    if n:
                        cnt[cc] = n
                    else:
                        del cnt[cc]
            if req.proc in self._dead:
                out.append([])
                continue
            born = req.proc == self.proc and bool(self._pending_opt)
            ccs_l.extend(req.ccs)
            rid_l.extend([req.req_id] * len(req.ccs))
            proc_l.extend([req.proc] * len(req.ccs))
            blk_l.extend((cc in cnt) if born else False for cc in req.ccs)
            handles = [BatchedLOR(self, req.req_id, req.proc, cc)
                       for cc in req.ccs]
            self._by_req[req.req_id] = handles
            out.append(handles)
        if ccs_l:
            flat = np.asarray(ccs_l, np.int32)
            flat_rid = np.asarray(rid_l, np.int32)
            flat_proc = np.asarray(proc_l, np.int32)
            flat_blk = np.asarray(blk_l, bool)
            for sh, rows, m in self._split(flat):
                sh.enqueue(rows, flat_rid[m], flat_proc[m], flat_blk[m])
        return out

    def on_opt_deliver(self, req: LeaseRequest) -> List[BatchedLOR]:
        return self.opt_deliver_batch([req])

    def opt_deliver_batch(self, reqs: Sequence[LeaseRequest]
                          ) -> List[BatchedLOR]:
        """Opt-deliver many requests: freeLocalLeases as one settle.

        Blocking is idempotent and only the *first* request of an instant
        to touch a class can see its head own-unblocked-and-drained, so
        evaluating free candidates on pre-state at first occurrence and
        OR-blocking every touched own LOR reproduces the sequential
        per-request loop exactly.  Returned frees follow the flattened
        (request-order, class-order) stream, i.e. the order the oracle
        would have appended them.
        """
        flat: List[int] = []
        cnt = self._pending_cnt
        for req in reqs:
            if req.proc in self._dead:
                continue
            self._pending_opt[req.req_id] = req
            for cc in req.ccs:
                cnt[cc] = cnt.get(cc, 0) + 1
            flat.extend(req.ccs)
        if not flat:
            return []
        return self._opt_block_stream(np.asarray(flat, np.int32))

    def _opt_block_stream(self, ccs_flat: np.ndarray) -> List[BatchedLOR]:
        uniq, first_idx = np.unique(ccs_flat, return_index=True)
        fresh_u = np.zeros((uniq.size,), bool)     # head own & unblocked, pre
        head_rid = np.full((uniq.size,), -1, np.int32)
        for sh, rows, m in self._split(uniq):
            cols = np.arange(sh.cap)[None, :]
            valid = cols < sh.qlen[rows, None]
            own_unblk = valid & (sh.proc[rows] == self.proc) \
                & ~sh.blocked[rows]
            fresh_u[m] = own_unblk[:, 0]
            head_rid[m] = sh.req[rows, 0]
            if own_unblk.any():
                sh.blocked[rows] |= own_unblk
        fresh_idx = np.flatnonzero(fresh_u)
        if not fresh_idx.size:
            return []
        # rel == uniq[fresh_idx] (already sorted unique), so free aligns 1:1
        _, _, free, _ = self.settle(
            [], uniq[fresh_idx], use_kernel=fresh_idx.size >= self.jax_min)
        sel = fresh_idx[free[: fresh_idx.size]]
        sel = sel[np.argsort(first_idx[sel], kind="stable")]
        return [BatchedLOR(self, int(head_rid[i]), self.proc, int(uniq[i]))
                for i in sel]

    def on_ur_deliver_freed(
            self, freed_keys: Sequence[Tuple[int, int, Tuple[int, ...]]]
    ) -> None:
        return self.freed_batch([freed_keys])

    def freed_batch(
            self,
            key_batches: Sequence[Sequence[Tuple[int, int, Tuple[int, ...]]]]
    ) -> None:
        """UR-deliver many LeaseFreed batches: one vectorized dequeue.

        Absent keys are no-ops (late frees after a purge), and stable
        compaction makes the final queue order independent of removal
        order — both matching the oracle.
        """
        ccs: List[int] = []
        rids: List[int] = []
        procs: List[int] = []
        for freed_keys in key_batches:
            for (req_id, proc, kccs) in freed_keys:
                lors = self._by_req.get(req_id)
                if lors is not None:
                    kept = [l for l in lors
                            if not (l.ccs == kccs and l.proc == proc)]
                    if kept:
                        self._by_req[req_id] = kept
                    else:
                        del self._by_req[req_id]
                for cc in kccs:
                    ccs.append(cc)
                    rids.append(req_id)
                    procs.append(proc)
        if not ccs:
            return
        flat = np.asarray(ccs, np.int32)
        flat_rid = np.asarray(rids, np.int32)
        flat_proc = np.asarray(procs, np.int32)
        for sh, rows, m in self._split(flat):
            sh.remove(rows, flat_rid[m], flat_proc[m])

    def finished_xact(self, lors: Sequence[BatchedLOR]) -> List[BatchedLOR]:
        """FinishedXact: decrement each LOR; return blocked-and-drained."""
        to_free: List[BatchedLOR] = []
        seen: set = set()
        for l in lors:
            sh, row = self._locate(l.cc)
            pos = sh.find_one(row, l.req_id, l.proc)
            assert pos >= 0, "finished_xact on a dequeued LOR"
            sh.active[row, pos] -= 1
            assert sh.active[row, pos] >= 0, "activeXacts underflow"
            if sh.blocked[row, pos] and sh.active[row, pos] == 0:
                k = (l.req_id, l.proc, l.cc)
                if k not in seen:
                    seen.add(k)
                    to_free.append(l)
        return to_free

    def finish_batch(self, groups: Sequence[Sequence[BatchedLOR]]
                     ) -> List[BatchedLOR]:
        """Vectorized FinishedXact over many transactions at once.

        All decrements scatter first (cells are distinct across FGL groups
        of distinct transactions — piggybacking shares cells but each
        transaction holds its own reference count); frees are then read
        out in input order.
        """
        flat: List[BatchedLOR] = [l for g in groups for l in g]
        if not flat:
            return []
        ccs = np.fromiter((l.cc for l in flat), np.int32, count=len(flat))
        rids = np.fromiter((l.req_id for l in flat), np.int32,
                           count=len(flat))
        procs = np.fromiter((l.proc for l in flat), np.int32,
                            count=len(flat))
        free_flags = np.zeros((len(flat),), bool)
        idx = np.arange(len(flat))
        for sh, rows, m in self._split(ccs):
            pos, found = sh.find(rows, rids[m], procs[m])
            assert found.all(), "finish_batch on a dequeued LOR"
            np.subtract.at(sh.active, (rows, pos), 1)
            assert (sh.active[rows, pos] >= 0).all(), "activeXacts underflow"
            free_flags[idx[m]] = sh.blocked[rows, pos] \
                & (sh.active[rows, pos] == 0)
        out: List[BatchedLOR] = []
        seen: set = set()
        for i in np.flatnonzero(free_flags):
            l = flat[i]
            k = (l.req_id, l.proc, l.cc)
            if k not in seen:
                seen.add(k)
                out.append(l)
        return out

    # -- piggybacking --------------------------------------------------------
    def try_piggyback(self, ccs: FrozenSet[int]) -> Optional[List[BatchedLOR]]:
        """Alg. 1 line 4: cover ``ccs`` with own unblocked enqueued LORs."""
        picks: List[Tuple[_LeaseShard, int, int, int, int]] = []
        for cc in sorted(ccs):
            sh, row = self._locate(cc)
            n = int(sh.qlen[row])
            if n == 0:
                return None
            m = (sh.proc[row, :n] == self.proc) & ~sh.blocked[row, :n]
            i = int(m.argmax())
            if not m[i]:
                return None
            picks.append((sh, row, i, cc, int(sh.req[row, i])))
        for (sh, row, i, _cc, _rid) in picks:
            sh.active[row, i] += 1
        self.n_piggyback += 1
        return [BatchedLOR(self, rid, self.proc, cc)
                for (_sh, _row, _i, cc, rid) in picks]

    def missing_ccs(self, ccs: FrozenSet[int]) -> FrozenSet[int]:
        return frozenset(cc for cc in ccs
                         if not self.has_unblocked(cc, self.proc))

    def protocol_state(self) -> Tuple:
        """Canonical protocol-state snapshot for the schedule explorer.

        Same shape as ``LeaseManagerBase.protocol_state`` — read straight
        off the shard arrays so fingerprinting skips the per-cell handle
        objects the ``cq`` view would allocate.
        """
        queues = []
        for s_id, sh in enumerate(self._shards):
            for row, slot in sh.slot_of.items():
                n = int(sh.qlen[slot])
                if n:
                    cc = (row << self._sbits) | s_id
                    queues.append((cc, tuple(
                        (int(sh.req[slot, i]), int(sh.proc[slot, i]),
                         int(sh.active[slot, i]), bool(sh.blocked[slot, i]))
                        for i in range(n))))
        queues.sort()
        return (tuple(queues), tuple(sorted(self._pending_opt)),
                tuple(sorted(self._dead)))

    # -- view change ---------------------------------------------------------
    def purge_proc(self, proc: int) -> None:
        """View change: reclaim every LOR owned by a failed member."""
        self._dead.add(proc)
        cnt = self._pending_cnt
        for req_id in list(self._pending_opt):
            if self._pending_opt[req_id].proc == proc:
                req = self._pending_opt.pop(req_id)
                for cc in req.ccs:
                    n = cnt[cc] - 1
                    if n:
                        cnt[cc] = n
                    else:
                        del cnt[cc]
        for sh in self._shards:
            ns = sh.n_slots
            if not ns:
                continue
            cols = np.arange(sh.cap)[None, :]
            valid = cols < sh.qlen[:ns, None]
            dm = valid & (sh.proc[:ns] == proc)
            rows = np.flatnonzero(dm.any(axis=1))
            if rows.size:
                sh.compact_rows(rows, dm[rows])
        for req_id in list(self._by_req):
            owners = {l.proc for l in self._by_req[req_id]}
            assert len(owners) == 1, \
                "invariant violated: LORs of one request span procs"
            if proc in owners:
                del self._by_req[req_id]


def _settle_np(head_req: np.ndarray, head_proc: np.ndarray,
               head_active: np.ndarray, qlen: np.ndarray,
               fresh_blocked: np.ndarray, wait_req: np.ndarray,
               wait_cc: np.ndarray, proc: int
               ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Numpy twin of :func:`repro.kernels.ref.lease_settle_ref` (bitwise)."""
    c = head_req.shape[0]
    occupied = qlen > 0
    owner = np.where(occupied, head_proc, -1).astype(np.int32)
    free = occupied & fresh_blocked & (head_proc == proc) & (head_active == 0)
    valid = wait_cc >= 0
    cc = np.clip(wait_cc, 0, max(c - 1, 0))
    at_head = occupied[cc] & (head_req[cc] == wait_req)
    enabled = np.where(valid, at_head, True).all(axis=1)
    return owner, free, enabled


def pack_lease_requests(reqs: Sequence[LeaseRequest]
                        ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Pack requests into pow2-bucketed int32 ``[B, K]`` arrays (-1 padded).

    The lease-layer sibling of ``repro.core.stm.pack_read_sets``: rows are
    requests, columns their conflict classes, both axes padded to powers
    of two so recurring instant sizes reuse compiled kernels.  Returns
    ``(cc, req_id, proc)`` arrays.
    """
    b = _pow2(max(len(reqs), 1))
    k = _pow2(max([len(r.ccs) for r in reqs] + [1]))
    cc = np.full((b, k), -1, np.int32)
    rid = np.full((b, k), -1, np.int32)
    proc = np.full((b, k), -1, np.int32)
    for i, r in enumerate(reqs):
        n = len(r.ccs)
        cc[i, :n] = r.ccs
        rid[i, :n] = r.req_id
        proc[i, :n] = r.proc
    return cc, rid, proc
