"""Conflict-class abstraction (the lease granularity indirection of ALC).

ALC/Lilac-TM associate leases with *conflict classes* rather than raw data
items: ``getConflictClasses`` maps a set of data items to the set of classes
that must be leased before the transaction can be certified.  The mapping
granularity trades accuracy (aliasing) for efficiency (lease-table size) —
exactly the knob discussed in the paper (§1, [3]).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterable, FrozenSet


@dataclass(frozen=True)
class ConflictClassMap:
    """Hash-partitioned item → conflict-class map.

    ``n_classes`` conflict classes; item ``k`` belongs to class
    ``(k * _MIX) % n_classes`` unless an explicit ``partition_of`` override is
    installed (used by the Bank benchmark to align classes with account
    partitions so that locality in *items* translates into locality in
    *leases*).
    """

    n_classes: int
    stride: int = 1  # items per contiguous class block (1 = pure modulo)

    def of_item(self, item: int) -> int:
        return (item // self.stride) % self.n_classes

    def get_conflict_classes(self, items: Iterable[int]) -> FrozenSet[int]:
        """The paper's ``getConflictClasses`` primitive."""
        return frozenset(self.of_item(i) for i in items)
