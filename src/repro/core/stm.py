"""TL2-style local software transactional memory over a versioned array store.

Each replica holds a full copy of the replicated data set (values + version
stamps).  Transactions execute optimistically against a snapshot; at commit
time the read-set is validated (every read item's version must still equal the
version observed at read time).  Commits bump the global version clock and
stamp written items.

The per-item state lives in plain numpy-backed python lists for the
discrete-event simulator (single mutation site, cheap), while **batched**
validation — the certification hot loop used when a replica validates many
remote/forwarded transactions at once — is vectorized in JAX
(:func:`validate_batch`) and has a Pallas kernel twin in
``repro.kernels.lease_validate``.
"""
from __future__ import annotations

import array
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Sequence, Tuple

import numpy as np


@dataclass
class ReadSetEntry:
    item: int
    version: int


def _read_log() -> array.array:
    return array.array("i")


@dataclass
class Transaction:
    """A transaction's footprint, as captured by its first (local) execution.

    The read log lives in one interleaved ``array.array`` int32 buffer
    (item, version, item, version, ...) rather than a list of records:
    appends are C-speed in the execution path, and the batched certification
    pipeline packs a whole batch with a single ``bytes.join`` memcpy instead
    of per-entry attribute walks (which would cost as much as the python
    validation loop the batching replaces).
    """

    txid: int
    origin: int
    read_log: array.array = field(default_factory=_read_log)
    write_set: Dict[int, float] = field(default_factory=dict)
    read_only: bool = False
    # conflict classes, filled by the replication manager via getConflictClasses
    ccs: frozenset = frozenset()
    # benchmark payload (e.g. bank partition id) used by OPT policies & stats
    tag: int = -1
    result: float = 0.0

    def log_read(self, item: int, version: int) -> None:
        self.read_log.append(item)
        self.read_log.append(version)

    @property
    def n_reads(self) -> int:
        return len(self.read_log) // 2

    @property
    def read_items(self) -> array.array:
        """The logged items (a copy; hot paths use ``read_log`` directly)."""
        return self.read_log[0::2]

    @property
    def read_set(self) -> List[ReadSetEntry]:
        """Record view of the read log (compat / inspection path)."""
        rl = self.read_log
        return [ReadSetEntry(rl[k], rl[k + 1])
                for k in range(0, len(rl), 2)]


class VersionedStore:
    """A replica's local copy of the replicated data: values + versions."""

    def __init__(self, n_items: int, init_value: float = 0.0) -> None:
        self.n_items = n_items
        self.init_value = init_value
        self.values = np.full((n_items,), init_value, dtype=np.float64)
        self.versions = np.zeros((n_items,), dtype=np.int64)
        self.clock = 0  # global version clock (per replica copy)

    def grow_to(self, n: int) -> None:
        """Grow capacity to at least ``n`` items (power-of-two steps),
        preserving contents.  The supported way for consumers to extend a
        store — direct writes to values/versions outside this module are
        lint-gated (state-mutation rule)."""
        if n <= self.n_items:
            return
        cap = max(1, self.n_items)
        while cap < n:
            cap *= 2
        values = np.full((cap,), self.init_value, dtype=np.float64)
        versions = np.zeros((cap,), dtype=np.int64)
        values[: self.n_items] = self.values
        versions[: self.n_items] = self.versions
        self.values = values
        self.versions = versions
        self.n_items = cap

    # -- execution-side API -------------------------------------------------
    def read(self, txn: Transaction, item: int) -> float:
        txn.log_read(item, int(self.versions[item]))
        if item in txn.write_set:
            return txn.write_set[item]
        return float(self.values[item])

    def write(self, txn: Transaction, item: int, value: float) -> None:
        txn.write_set[item] = value

    # -- certification ------------------------------------------------------
    def validate(self, txn: Transaction) -> bool:
        """TL2 read-set validation against the current store."""
        versions = self.versions
        rl = txn.read_log
        for k in range(0, len(rl), 2):
            if versions[rl[k]] != rl[k + 1]:
                return False
        return True

    def apply(self, write_set: Dict[int, float]) -> int:
        """Apply a validated write-set; returns the commit version."""
        self.clock += 1
        for item, value in write_set.items():
            self.values[item] = value
            self.versions[item] = self.clock
        return self.clock

    def apply_versioned(self, write_set: Dict[int, float], version: int) -> None:
        """Apply a replicated write-set stamping items with the writer's txid.

        Txids are globally unique and conflicting commits are serialized by
        the lease layer, so per-item version sequences are identical at every
        replica regardless of URB delivery interleaving of non-conflicting
        commits — which is what makes cross-replica (forwarded) validation
        sound.
        """
        for item, value in write_set.items():
            self.values[item] = value
            self.versions[item] = version
        self.clock = max(self.clock, version)

    def apply_batch(
        self,
        write_sets: Sequence[Dict[int, float]],
        versions: Sequence[int],
    ) -> None:
        """Apply many validated write-sets in one vectorized scatter.

        Equivalent to ``apply_versioned(ws, v)`` called in order — later
        write-sets win on item overlap (last-writer-wins is resolved
        explicitly, not left to fancy-indexing order), so the batched commit
        phase produces byte-identical ``values``/``versions`` arrays to the
        one-at-a-time path.
        """
        n = sum(len(ws) for ws in write_sets)
        if n == 0:
            return
        items = np.fromiter(
            (i for ws in write_sets for i in ws), np.int32, count=n)
        vals = np.fromiter(
            (v for ws in write_sets for v in ws.values()), np.float64, count=n)
        vers = np.repeat(
            np.asarray(list(versions), dtype=np.int64),
            [len(ws) for ws in write_sets],
        )
        # keep only the last write per item, preserving batch order
        _, first_in_rev = np.unique(items[::-1], return_index=True)
        keep = n - 1 - first_in_rev
        self.values[items[keep]] = vals[keep]
        self.versions[items[keep]] = vers[keep]
        self.clock = max(self.clock, int(vers.max()))

    def total(self) -> float:
        return float(self.values.sum())


# ----------------------------------------------------------------------------
# Vectorized (JAX) batched validation — the certification hot loop.
# ----------------------------------------------------------------------------

def _pad_bucket(n: int, lo: int = 8) -> int:
    """Smallest power of two >= n (floored at ``lo``).

    Packing widths are quantized to power-of-two buckets so the jit'd
    validation (and the Pallas kernel) see a handful of recurring shapes
    instead of one shape per batch — certification batches vary row count
    and read-set length every drain, and per-batch recompiles would eat the
    entire batching win.
    """
    b = lo
    while b < n:
        b <<= 1
    return b


def _scatter_rows(
    lens: np.ndarray, flat_a: np.ndarray, flat_b: np.ndarray | None,
    r: int, fill_a: int,
) -> Tuple[np.ndarray, np.ndarray | None]:
    """Scatter flat per-row segments into padded [B, r] arrays.

    ``flat_b`` may be None to pack a single column.
    """
    b = lens.shape[0]
    if b and int(lens[0]) == r and bool((lens == r).all()):
        # uniform rows fill the padded shape exactly: pure reshape+cast
        return (flat_a.astype(np.int32).reshape(b, r),
                None if flat_b is None else
                flat_b.astype(np.int32).reshape(b, r))
    items = np.full((b, r), fill_a, dtype=np.int32)
    vals = None if flat_b is None else np.zeros((b, r), dtype=np.int32)
    total = int(lens.sum())
    if total:
        rows = np.repeat(np.arange(b), lens)
        starts = np.concatenate(([0], np.cumsum(lens)[:-1]))
        cols = np.arange(total) - np.repeat(starts, lens)
        items[rows, cols] = flat_a
        if vals is not None:
            vals[rows, cols] = flat_b
    return items, vals


def pack_read_sets(
    txns: Sequence[Transaction], pad_to: int | None = None,
    pow2: bool = True,
) -> Tuple[np.ndarray, np.ndarray]:
    """Pack per-transaction read sets into padded [B, R] arrays.

    ``pow2=True`` (the default) rounds R up to a power-of-two bucket; pass
    ``pad_to`` to force a wider row.  The per-entry work is a C-level
    buffer copy (``array.array.extend`` + one vectorized scatter), keeping
    packing far below the per-entry cost of the python validation loop.
    """
    b = len(txns)
    lens = np.fromiter((len(t.read_log) for t in txns), np.int64,
                       count=b) >> 1
    r = max(1, int(lens.max()) if b else 1)
    if pad_to is not None:
        r = max(r, pad_to)
    if pow2:
        r = _pad_bucket(r)
    # buffer-protocol copies pack the whole batch: each interleaved int32
    # log lands in a preallocated numpy buffer (no per-txn allocations),
    # deinterleaved by a vectorized reshape
    out = np.empty(int(lens.sum()) * 2, np.int32)
    mv = memoryview(out)
    pos = 0
    for t in txns:
        n = len(t.read_log)
        mv[pos:pos + n] = t.read_log
        pos += n
    flat = out.reshape(-1, 2)
    return _scatter_rows(lens, flat[:, 0], flat[:, 1], r, -1)


def pack_write_sets(
    txns: Sequence[Transaction], pad_to: int | None = None,
    pow2: bool = True,
) -> np.ndarray:
    """Pack per-transaction write *items* into a padded [B, W] array.

    -1 padded like the read-set packing so the certification kernels can
    mask them; the lock check only needs the items (write values stay in
    the per-transaction dicts that ``apply_batch`` consumes).
    """
    b = len(txns)
    lens = np.fromiter((len(t.write_set) for t in txns), np.int64, count=b)
    w = max(1, int(lens.max()) if b else 1)
    if pad_to is not None:
        w = max(w, pad_to)
    if pow2:
        w = _pad_bucket(w)
    flat_i = _read_log()
    for t in txns:
        flat_i.extend(t.write_set.keys())
    return _scatter_rows(
        lens,
        np.frombuffer(flat_i, dtype=np.int32) if flat_i else np.empty(0, np.int32),
        None, w, -1)[0]


def validate_batch(store: VersionedStore, txns: Sequence[Transaction],
                   locks: np.ndarray | None = None,
                   backend: str = "auto") -> np.ndarray:
    """Batched TL2 certification of ``txns`` against ``store``.

    Packs read *and* write sets (power-of-two padded) and dispatches through
    :func:`repro.kernels.ops.validate_transactions` — the Pallas kernel on
    TPU, the jit'd jnp oracle elsewhere; tests assert the two agree bitwise.

    ``locks`` is an optional [n_items] 0/1 array of write locks (item leased
    away per the lease layer): a transaction writing a locked item fails
    certification on both backends.
    """
    if not txns:
        return np.zeros((0,), dtype=bool)
    from repro.kernels.ops import validate_transactions

    items, vers = pack_read_sets(txns)
    # without locks every write check passes — skip the write packing and
    # let the kernel mask an empty [B, 1] column
    witems = pack_write_sets(txns) if locks is not None else None
    # bucket the row count too: the jit'd kernels are shape-specialized,
    # and drain sizes vary every instant — padded rows are all-masked
    # (items -1) and certify True, sliced off below
    b = len(txns)
    bp = _pad_bucket(b)
    if bp != b:
        items = np.pad(items, ((0, bp - b), (0, 0)), constant_values=-1)
        vers = np.pad(vers, ((0, bp - b), (0, 0)))
        if witems is not None:
            witems = np.pad(witems, ((0, bp - b), (0, 0)),
                            constant_values=-1)
    out = validate_transactions(
        store.versions.astype(np.int32),     # numpy cast beats device cast
        items,
        vers,
        write_locks=locks,
        write_items=witems,
        backend=backend,
    )
    return np.asarray(out[:b])
