"""TL2-style local software transactional memory over a versioned array store.

Each replica holds a full copy of the replicated data set (values + version
stamps).  Transactions execute optimistically against a snapshot; at commit
time the read-set is validated (every read item's version must still equal the
version observed at read time).  Commits bump the global version clock and
stamp written items.

The per-item state lives in plain numpy-backed python lists for the
discrete-event simulator (single mutation site, cheap), while **batched**
validation — the certification hot loop used when a replica validates many
remote/forwarded transactions at once — is vectorized in JAX
(:func:`validate_batch`) and has a Pallas kernel twin in
``repro.kernels.lease_validate``.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclass
class ReadSetEntry:
    item: int
    version: int


@dataclass
class Transaction:
    """A transaction's footprint, as captured by its first (local) execution."""

    txid: int
    origin: int
    read_set: List[ReadSetEntry] = field(default_factory=list)
    write_set: Dict[int, float] = field(default_factory=dict)
    read_only: bool = False
    # conflict classes, filled by the replication manager via getConflictClasses
    ccs: frozenset = frozenset()
    # benchmark payload (e.g. bank partition id) used by OPT policies & stats
    tag: int = -1
    result: float = 0.0


class VersionedStore:
    """A replica's local copy of the replicated data: values + versions."""

    def __init__(self, n_items: int, init_value: float = 0.0) -> None:
        self.n_items = n_items
        self.values = np.full((n_items,), init_value, dtype=np.float64)
        self.versions = np.zeros((n_items,), dtype=np.int64)
        self.clock = 0  # global version clock (per replica copy)

    # -- execution-side API -------------------------------------------------
    def read(self, txn: Transaction, item: int) -> float:
        txn.read_set.append(ReadSetEntry(item, int(self.versions[item])))
        if item in txn.write_set:
            return txn.write_set[item]
        return float(self.values[item])

    def write(self, txn: Transaction, item: int, value: float) -> None:
        txn.write_set[item] = value

    # -- certification ------------------------------------------------------
    def validate(self, txn: Transaction) -> bool:
        """TL2 read-set validation against the current store."""
        for e in txn.read_set:
            if int(self.versions[e.item]) != e.version:
                return False
        return True

    def apply(self, write_set: Dict[int, float]) -> int:
        """Apply a validated write-set; returns the commit version."""
        self.clock += 1
        for item, value in write_set.items():
            self.values[item] = value
            self.versions[item] = self.clock
        return self.clock

    def apply_versioned(self, write_set: Dict[int, float], version: int) -> None:
        """Apply a replicated write-set stamping items with the writer's txid.

        Txids are globally unique and conflicting commits are serialized by
        the lease layer, so per-item version sequences are identical at every
        replica regardless of URB delivery interleaving of non-conflicting
        commits — which is what makes cross-replica (forwarded) validation
        sound.
        """
        for item, value in write_set.items():
            self.values[item] = value
            self.versions[item] = version
        self.clock = max(self.clock, version)

    def total(self) -> float:
        return float(self.values.sum())


# ----------------------------------------------------------------------------
# Vectorized (JAX) batched validation — the certification hot loop.
# ----------------------------------------------------------------------------

@jax.jit
def _validate_batch_jit(
    store_versions: jax.Array,  # [n_items] int32
    read_items: jax.Array,      # [B, R] int32 (padded with -1)
    read_versions: jax.Array,   # [B, R] int32
) -> jax.Array:
    """For each of B transactions: all read items unchanged -> True."""
    valid_slot = read_items >= 0
    current = store_versions[jnp.clip(read_items, 0, store_versions.shape[0] - 1)]
    ok = jnp.where(valid_slot, current == read_versions, True)
    return jnp.all(ok, axis=1)


def pack_read_sets(
    txns: Sequence[Transaction], pad_to: int | None = None
) -> Tuple[np.ndarray, np.ndarray]:
    """Pack per-transaction read sets into padded [B, R] arrays."""
    r = max((len(t.read_set) for t in txns), default=1)
    r = max(r, 1)
    if pad_to is not None:
        r = max(r, pad_to)
    b = len(txns)
    items = np.full((b, r), -1, dtype=np.int32)
    vers = np.zeros((b, r), dtype=np.int32)
    for i, t in enumerate(txns):
        for j, e in enumerate(t.read_set):
            items[i, j] = e.item
            vers[i, j] = e.version
    return items, vers


def validate_batch(store: VersionedStore, txns: Sequence[Transaction],
                   backend: str = "auto") -> np.ndarray:
    """Batched TL2 validation of ``txns`` against ``store``.

    Dispatches to the Pallas certification kernel on TPU
    (``repro.kernels.lease_validate`` — VMEM-chunked gather/compare) and to
    the jit'd jnp path elsewhere; tests assert the two agree bitwise.
    """
    if not txns:
        return np.zeros((0,), dtype=bool)
    items, vers = pack_read_sets(txns)
    use_pallas = backend == "pallas" or (
        backend == "auto" and jax.default_backend() == "tpu")
    if use_pallas:
        from repro.kernels.lease_validate import lease_validate

        out = lease_validate(
            jnp.asarray(store.versions, dtype=jnp.int32),
            jnp.asarray(items), jnp.asarray(vers),
            jnp.zeros((store.n_items,), jnp.int32),
            jnp.full((len(txns), 1), -1, jnp.int32),
        )
    else:
        out = _validate_batch_jit(
            jnp.asarray(store.versions, dtype=jnp.int32),
            jnp.asarray(items),
            jnp.asarray(vers),
        )
    return np.asarray(out)
