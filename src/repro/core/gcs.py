"""Simulated view-synchronous Group Communication Service (Appia stand-in).

Provides the three primitives the paper's stack relies on, with the
communication-step latency model the paper itself uses to quantify costs
(§3.3): point-to-point = 1 step, URB = 2 steps, OAB = 3 steps (optimistic
delivery after 1 step, final total-order delivery after 3).

Guarantees preserved by the simulation (and relied upon by the lease
protocol's deadlock-freedom — see core/lease.py docstring):

* **OAB total order**: TO-deliver order is identical at every node (we order
  by broadcast issue time with a deterministic sequence tie-break);
* **Opt-before-TO**: optimistic delivery strictly precedes final delivery at
  every node;
* **per-sender FIFO URB**: messages UR-broadcast by one node deliver in issue
  order everywhere (constant latency preserves this), and a node's own
  UR-broadcasts are causally ordered after everything it delivered;
* **view synchrony**: `fail(node)` removes a member; a view-change callback
  fires at every surviving member at the same simulated instant, allowing the
  lease layer to reclaim the failed member's LORs (primary component).

Every delivery event is stamped with :class:`core.events.EvMeta` so the
schedule explorer can reorder *concurrent* deliveries while the policy seam
enforces exactly the guarantees above (TO chains per node, opt-before-TO
pairing, per-sender FIFO chains).  ``msg_keys`` derives the conflict classes
a protocol message touches — the explorer's commutation oracle.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass
from typing import Any, Callable, Dict, FrozenSet, List, Optional

from .events import EventQueue, EvMeta


@dataclass(frozen=True)
class GCSLatency:
    step_ms: float = 0.5
    p2p_steps: float = 1.0
    urb_steps: float = 2.0
    oab_opt_steps: float = 1.0
    oab_to_steps: float = 3.0
    # Total-order broadcast is serialized through a sequencer (or token
    # ring): the final TO-delivery stream has a maximum rate of
    # 1/oab_serialize_ms messages per ms.  Optimistic deliveries are raw
    # network multicasts and do not pass through the sequencer.  This is the
    # resource whose contention the paper's protocols are designed to avoid
    # ("limiting the use of atomic broadcast exclusively for establishing
    # lease ownership").
    oab_serialize_ms: float = 0.0


def msg_keys(msg: Any) -> Optional[FrozenSet[int]]:
    """Conflict classes a protocol message touches (None: opaque).

    Used as the explorer's independence oracle: two deliveries whose key
    sets are disjoint commute.  Anything unrecognized is opaque — treated
    as dependent with everything, which only costs pruning, never soundness.
    """
    try:
        kind, payload = msg
    except (TypeError, ValueError):
        return None
    if kind == "lease":
        return frozenset(payload.ccs)
    if kind == "freed":
        return frozenset(cc for (_rid, _proc, ccs) in payload for cc in ccs)
    if kind == "commit":
        return frozenset(payload["ccs"])
    if kind == "forward":
        return frozenset(payload.ccs)
    return None


class SimGCS:
    """Event-driven GCS over an :class:`EventQueue`."""

    def __init__(self, events: EventQueue, n_nodes: int, lat: GCSLatency) -> None:
        self.events = events
        self.lat = lat
        self.members: List[int] = list(range(n_nodes))
        self._alive = [True] * n_nodes
        self._seq = itertools.count()
        # handlers[node] -> dict of callbacks
        self.on_opt: Dict[int, Callable[[Any, int], None]] = {}
        self.on_to: Dict[int, Callable[[Any, int], None]] = {}
        self.on_urb: Dict[int, Callable[[Any, int], None]] = {}
        self.on_p2p: Dict[int, Callable[[Any, int], None]] = {}
        self.on_view_change: Dict[int, Callable[[List[int], int], None]] = {}
        # traffic accounting (for benchmark reporting)
        self.n_oab = 0
        self.n_urb = 0
        self.n_p2p = 0
        self._seq_busy_until = 0.0
        # dense per-chain delivery counters for the explorer's FIFO metadata
        self._chain_seq: Dict[tuple, int] = {}
        self._msgid = itertools.count()

    def _chain_next(self, chain: tuple) -> int:
        c = self._chain_seq.get(chain, 0)
        self._chain_seq[chain] = c + 1
        return c

    # -- primitives ---------------------------------------------------------
    def oa_broadcast(self, sender: int, msg: Any) -> None:
        """OAB: Opt-deliver after 1 step, TO-deliver after >= 3 steps.

        TO-delivery additionally queues behind the sequencer: each message
        occupies the sequencer for ``oab_serialize_ms`` and messages are
        sequenced strictly one after another, which caps sustainable OAB
        throughput and models sequencer saturation under lease-request storms.
        """
        self.n_oab += 1
        lat = self.lat
        mid = next(self._msgid)
        keys = msg_keys(msg)
        opt_at = set()
        for node in self.members:
            if not self._alive[node]:
                continue
            if self._sched(lat.oab_opt_steps, node, self.on_opt, msg, sender,
                           meta=EvMeta(kind="opt", node=node, msgid=mid,
                                       keys=keys, label=f"opt@{node} m{mid}")):
                opt_at.add(node)
        # total order: constant latency + deterministic scheduling order makes
        # TO-deliver order identical across nodes (EventQueue seq tie-break).
        to_extra = 0.0
        if lat.oab_serialize_ms > 0:
            start = max(self.events.now, self._seq_busy_until)
            self._seq_busy_until = start + lat.oab_serialize_ms
            to_extra = self._seq_busy_until - self.events.now
        for node in self.members:
            # chain counters stay dense: only allocate a slot for deliveries
            # that are actually scheduled (handler registered)
            if not self._alive[node] or self.on_to.get(node) is None:
                continue
            self._sched(lat.oab_to_steps, node, self.on_to, msg, sender,
                        extra_ms=to_extra,
                        meta=EvMeta(kind="to", node=node,
                                    chain=("to", node),
                                    cseq=self._chain_next(("to", node)),
                                    msgid=mid, after_opt=node in opt_at,
                                    keys=keys, label=f"to@{node} m{mid}"))

    def ur_broadcast(self, sender: int, msg: Any) -> None:
        self.n_urb += 1
        keys = msg_keys(msg)
        for node in self.members:
            if not self._alive[node] or self.on_urb.get(node) is None:
                continue
            chain = ("urb", sender, node)
            self._sched(self.lat.urb_steps, node, self.on_urb, msg, sender,
                        meta=EvMeta(kind="urb", node=node, chain=chain,
                                    cseq=self._chain_next(chain), keys=keys,
                                    label=f"urb@{node} from {sender}"))

    def p2p_send(self, sender: int, dest: int, msg: Any) -> None:
        self.n_p2p += 1
        if self._alive[dest] and self.on_p2p.get(dest) is not None:
            chain = ("p2p", sender, dest)
            self._sched(self.lat.p2p_steps, dest, self.on_p2p, msg, sender,
                        meta=EvMeta(kind="p2p", node=dest, chain=chain,
                                    cseq=self._chain_next(chain),
                                    keys=msg_keys(msg),
                                    label=f"p2p@{dest} from {sender}"))

    # -- membership ----------------------------------------------------------
    def fail(self, node: int) -> None:
        """Crash a member; survivors get a synchronized view change."""
        if not self._alive[node]:
            return
        self._alive[node] = False
        new_view = [m for m in self.members if self._alive[m]]
        for m in new_view:
            cb = self.on_view_change.get(m)
            if cb is not None:
                chain = ("view", m)
                self.events.schedule(
                    self.lat.urb_steps * self.lat.step_ms,
                    (lambda c=cb, v=list(new_view), f=node: c(v, f)),
                    meta=EvMeta(kind="view", node=m, chain=chain,
                                cseq=self._chain_next(chain),
                                label=f"view@{m} -{node}"),
                )
        self.members = new_view

    def alive(self, node: int) -> bool:
        return self._alive[node]

    # -- internals -------------------------------------------------------------
    def _sched(self, steps: float, node: int, table, msg: Any, sender: int,
               extra_ms: float = 0.0, meta: Optional[EvMeta] = None) -> bool:
        cb = table.get(node)
        if cb is None:
            return False
        # liveness is re-checked at delivery time: a message in flight to a
        # node that crashes mid-flight is dropped, never processed by the
        # dead member (fail-stop) — senders recover via the view change
        self.events.schedule(
            steps * self.lat.step_ms + extra_ms,
            (lambda c=cb, m=msg, s=sender, n=node:
             c(m, s) if self._alive[n] else None),
            meta=meta,
        )
        return True
