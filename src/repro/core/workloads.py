"""Benchmark workloads from the paper's evaluation (§4).

* :class:`BankWorkload` — the partitioned Bank benchmark: accounts split into
  per-replica partitions; a transaction touches a single partition — its own
  replica's with probability ``locality`` (the paper's P), a random remote one
  otherwise.  50 % read-write transfers, 50 % read-only balance reads of
  varying length.
* :class:`TpccWorkload` — the TPC-C port: Payment (95 %) and New-Order (5 %)
  profiles over warehouse-partitioned data, injected through a geographic
  load-balancer that misroutes requests with probability 0.2.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple

import numpy as np

from .cluster import TxnSpec, Workload
from .stm import Transaction, VersionedStore


# --------------------------------------------------------------------------
# Bank
# --------------------------------------------------------------------------

def _make_transfer(a: int, b: int, amount: float):
    def execute(store: VersionedStore, txn: Transaction) -> float:
        va = store.read(txn, a)
        vb = store.read(txn, b)
        store.write(txn, a, va - amount)
        store.write(txn, b, vb + amount)
        return va - amount

    return execute


def _make_balance_read(items: Tuple[int, ...]):
    def execute(store: VersionedStore, txn: Transaction) -> float:
        return float(sum(store.read(txn, i) for i in items))

    return execute


@dataclass
class BankWorkload(Workload):
    n_nodes: int
    n_items: int
    locality: float = 0.9          # the paper's P
    write_fraction: float = 0.5    # 50% read-write / 50% read-only
    ro_len: Tuple[int, int] = (2, 8)
    # overload-experiment mode: with probability ``hot_fraction`` every node
    # accesses ``hot_partition``; the hot partition's home node accesses ONLY
    # its own partition (paper §4, Fig. 3c setup).
    hot_partition: int = -1
    hot_fraction: float = 0.2

    def partition_bounds(self, p: int) -> Tuple[int, int]:
        size = self.n_items // self.n_nodes
        return p * size, (p + 1) * size

    def _choose_partition(self, node: int, rng: np.random.Generator) -> int:
        if self.hot_partition >= 0:
            if node == self.hot_partition:
                return node
            if rng.random() < self.hot_fraction:
                return self.hot_partition
        if rng.random() < self.locality:
            return node
        others = [p for p in range(self.n_nodes) if p != node]
        return int(others[rng.integers(len(others))])

    def sample(self, node: int, rng: np.random.Generator) -> TxnSpec:
        p = self._choose_partition(node, rng)
        lo, hi = self.partition_bounds(p)
        if rng.random() < self.write_fraction:
            a, b = rng.choice(np.arange(lo, hi), size=2, replace=False)
            amount = float(rng.integers(1, 20))
            return TxnSpec(
                execute=_make_transfer(int(a), int(b), amount),
                items=(int(a), int(b)),
                read_only=False,
                opt_hint=p,
            )
        k = int(rng.integers(self.ro_len[0], self.ro_len[1] + 1))
        items = tuple(int(i) for i in rng.choice(np.arange(lo, hi), size=k, replace=False))
        return TxnSpec(
            execute=_make_balance_read(items),
            items=items,
            read_only=True,
            opt_hint=p,
        )


# --------------------------------------------------------------------------
# TPC-C (Payment + New-Order profiles)
# --------------------------------------------------------------------------

@dataclass
class TpccLayout:
    """Flattened item-space layout: one block per warehouse + a catalog."""

    n_nodes: int
    warehouses_per_node: int = 2
    n_districts: int = 10
    n_customers: int = 64
    n_stock: int = 128
    n_catalog: int = 256

    @property
    def n_warehouses(self) -> int:
        return self.n_nodes * self.warehouses_per_node

    @property
    def wh_block(self) -> int:
        return 1 + self.n_districts + self.n_customers + self.n_stock

    @property
    def n_items(self) -> int:
        return self.n_warehouses * self.wh_block + self.n_catalog

    def home_node(self, w: int) -> int:
        return w // self.warehouses_per_node

    def warehouse_row(self, w: int) -> int:
        return w * self.wh_block

    def district_row(self, w: int, d: int) -> int:
        return w * self.wh_block + 1 + d

    def customer_row(self, w: int, c: int) -> int:
        return w * self.wh_block + 1 + self.n_districts + c

    def stock_row(self, w: int, s: int) -> int:
        return w * self.wh_block + 1 + self.n_districts + self.n_customers + s

    def catalog_row(self, i: int) -> int:
        return self.n_warehouses * self.wh_block + i


def _make_payment(wrow: int, drow: int, crow: int, amount: float):
    def execute(store: VersionedStore, txn: Transaction) -> float:
        w = store.read(txn, wrow)
        d = store.read(txn, drow)
        c = store.read(txn, crow)
        store.write(txn, wrow, w + amount)
        store.write(txn, drow, d + amount)
        store.write(txn, crow, c - amount)
        return c - amount

    return execute


def _make_new_order(drow: int, stock_rows: Tuple[int, ...], catalog_rows: Tuple[int, ...], qty: float):
    def execute(store: VersionedStore, txn: Transaction) -> float:
        oid = store.read(txn, drow)
        store.write(txn, drow, oid + 1.0)
        total = 0.0
        for cat in catalog_rows:
            total += store.read(txn, cat)
        for s in stock_rows:
            v = store.read(txn, s)
            store.write(txn, s, v - qty if v >= qty else v - qty + 91.0)
        return total

    return execute


class TpccConflictMap:
    """Warehouse-aligned conflict classes: 4 classes per warehouse
    (warehouse+districts / customers / stock-low / stock-high) + 1 global
    class for the read-only catalog (excluded from lease footprints)."""

    CCS_PER_WH = 4

    def __init__(self, layout: TpccLayout) -> None:
        self.layout = layout
        self.n_classes = layout.n_warehouses * self.CCS_PER_WH + 1

    def of_item(self, item: int) -> int:
        lay = self.layout
        block = lay.wh_block
        if item >= lay.n_warehouses * block:
            return self.n_classes - 1  # catalog
        w, off = divmod(item, block)
        if off <= lay.n_districts:
            sub = 0  # warehouse row + districts
        elif off <= lay.n_districts + lay.n_customers:
            sub = 1  # customers
        else:
            s = off - 1 - lay.n_districts - lay.n_customers
            sub = 2 + (0 if s < lay.n_stock // 2 else 1)
        return w * self.CCS_PER_WH + sub

    def get_conflict_classes(self, items):
        return frozenset(self.of_item(i) for i in items)


@dataclass
class TpccWorkload(Workload):
    layout: TpccLayout
    payment_fraction: float = 0.95
    lb_mistake: float = 0.2            # geographic load-balancer error rate
    remote_customer: float = 0.15      # Payment: cross-warehouse customer
    remote_stock: float = 0.1          # New-Order: per-item cross-warehouse
    order_lines: Tuple[int, int] = (5, 10)
    exec_ms_payment: float = 0.12
    exec_ms_neworder: float = 0.35     # the long-running profile

    def _region_warehouse(self, node: int, rng: np.random.Generator) -> int:
        lay = self.layout
        if rng.random() < self.lb_mistake:
            w = int(rng.integers(lay.n_warehouses))
        else:
            w = int(node * lay.warehouses_per_node + rng.integers(lay.warehouses_per_node))
        return w

    def sample(self, node: int, rng: np.random.Generator) -> TxnSpec:
        lay = self.layout
        w = self._region_warehouse(node, rng)
        if rng.random() < self.payment_fraction:
            d = int(rng.integers(lay.n_districts))
            cw = w
            if rng.random() < self.remote_customer:
                cw = int(rng.integers(lay.n_warehouses))
            c = int(rng.integers(lay.n_customers))
            rows = (
                lay.warehouse_row(w),
                lay.district_row(w, d),
                lay.customer_row(cw, c),
            )
            return TxnSpec(
                execute=_make_payment(*rows, amount=float(rng.integers(1, 50))),
                items=rows,
                read_only=False,
                opt_hint=lay.home_node(w),
                exec_ms=self.exec_ms_payment,
            )
        # New-Order
        d = int(rng.integers(lay.n_districts))
        n_lines = int(rng.integers(self.order_lines[0], self.order_lines[1] + 1))
        stock_rows = []
        for _ in range(n_lines):
            sw = w
            if rng.random() < self.remote_stock:
                sw = int(rng.integers(lay.n_warehouses))
            stock_rows.append(lay.stock_row(sw, int(rng.integers(lay.n_stock))))
        catalog_rows = tuple(
            lay.catalog_row(int(i))
            for i in rng.integers(lay.n_catalog, size=n_lines)
        )
        drow = lay.district_row(w, d)
        items = tuple([drow] + stock_rows)  # catalog rows are read-only/global
        return TxnSpec(
            execute=_make_new_order(drow, tuple(stock_rows), catalog_rows, qty=5.0),
            items=items,
            read_only=False,
            opt_hint=lay.home_node(w),
            exec_ms=self.exec_ms_neworder,
        )
