"""Transaction Forwarder (TF) message types and retry policy (§3.2).

The TF migrates the *commit phase* (and, on validation failure, the
*re-execution*) of a transaction from its origin node to a target chosen by
the DTD.  The messages below are exchanged over the GCS p2p service; the
actual state machine lives in the replica logic (``core/cluster.py``), which
implements:

* the **remote validation optimization** — the forwarded message carries the
  read-set (items + observed versions) and the write-set so the target can
  certify without re-executing;
* **bounded re-forwarding** — if a re-executed transaction's data-set changed
  such that the target no longer covers it, the target *must* acquire the
  leases itself rather than forward again (``ForwardPolicy.force_acquire``),
  preventing unbounded migration chains;
* **result piggybacking** — the transaction's return value produced at the
  target rides back to the origin on the commit message so the originating
  application thread can be resumed with it.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, FrozenSet, List, Optional, Tuple


@dataclass
class ForwardRequest:
    """Serialized transaction shipped to the target node (RMI-style)."""

    txid: int
    origin: int
    origin_thread: int
    ccs: FrozenSet[int]
    # remote-validation payload:
    read_items: Tuple[int, ...]
    read_versions: Tuple[int, ...]
    write_set: Dict[int, float]
    # re-execution closure id: benchmarks register generators so the target
    # can re-run the transactional logic (same input parameters).
    spec_id: int = -1
    attempt: int = 0


@dataclass
class CommitNotice:
    """Commit (or abort) outcome returned to the origin (piggybacked result)."""

    txid: int
    origin: int
    origin_thread: int
    committed: bool
    result: float = 0.0
    executed_on: int = -1


@dataclass(frozen=True)
class ForwardPolicy:
    max_reexec: int = 5          # re-execution attempts at the target
    max_forwards: int = 1        # migration chain bound (paper: one hop, then
                                 # the holder must acquire leases itself)

    def may_forward(self, attempt: int) -> bool:
        return attempt < self.max_forwards
