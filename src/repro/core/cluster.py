"""Discrete-event simulator of a Lilac-TM / ALC replicated cluster.

This is the faithful reproduction vehicle: N replicas, each with a local STM
(TL2-style versioned store), a lease manager (coarse ALC or fine-grained FGL),
a replication manager, the Transaction Forwarder and the DTD, driven by a
deterministic event queue and the simulated GCS (OAB/URB/p2p with the paper's
communication-step latency model).

Algorithm variants (paper §4) are obtained by configuration:

=============  ==========  ================
variant        lease_kind  dtd.policy
=============  ==========  ================
ALC            alc         local
FGL            fgl         local
MG-ALC         alc         opt
LILAC-TM-ST    fgl         short
LILAC-TM-LT    fgl         long
LILAC-TM-OPT   fgl         opt
=============  ==========  ================

Threads are closed-loop load generators: each of ``threads_per_node`` worker
threads executes one transaction at a time, blocks through its commit phase,
then starts the next — matching the paper's 2/4-threads-per-node runs.
Execution (and forwarded re-execution) consumes a CPU slot at the executing
node; slot occupancy feeds the CPU_i statistic used by constraint (3).
"""
from __future__ import annotations

import itertools
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Dict, FrozenSet, List, Optional, Tuple

import numpy as np

from .conflict import ConflictClassMap
from .dtd import DTD, DTDConfig
from .events import EventQueue
from .forwarder import CommitNotice, ForwardPolicy, ForwardRequest
from .gcs import GCSLatency, SimGCS
from .lease import ALCLeaseManager, FGLLeaseManager, LeaseRequest, LOR
from .stats import CpuMeter, DecayedFrequency
from .stm import Transaction, VersionedStore, validate_batch


# --------------------------------------------------------------------------
# Workload interface
# --------------------------------------------------------------------------

@dataclass
class TxnSpec:
    """A transaction's logic + static footprint, as sampled by a workload.

    ``execute(store, stm_txn)`` performs the reads/writes (and is re-invoked
    on re-execution, reading fresh values); ``items`` is the item footprint
    used for conflict-class mapping (stable across re-executions, as in the
    Bank/TPC-C benchmarks where the data-set is determined by the input
    parameters).
    """

    execute: Callable[[VersionedStore, Transaction], float]
    items: Tuple[int, ...]
    read_only: bool = False
    opt_hint: int = -1
    exec_ms: Optional[float] = None


class Workload:
    def sample(self, node: int, rng: np.random.Generator) -> TxnSpec:
        raise NotImplementedError


# --------------------------------------------------------------------------
# Simulation config & metrics
# --------------------------------------------------------------------------

@dataclass
class SimConfig:
    n_nodes: int = 4
    threads_per_node: int = 2
    n_items: int = 4096
    n_classes: int = 256
    lease_kind: str = "fgl"               # "fgl" | "alc"
    dtd: DTDConfig = field(default_factory=lambda: DTDConfig(policy="local"))
    # Calibrated regime (EXPERIMENTS.md §Calibration): communication-
    # dominated, as in the paper's Gigabit-Ethernet cluster — short in-memory
    # transactions (tens of us), ~0.35 ms per communication step, OAB
    # sequencer serialization 0.3 ms/message.
    latency: GCSLatency = field(
        default_factory=lambda: GCSLatency(step_ms=0.35, oab_serialize_ms=0.3)
    )
    exec_ms: float = 0.03                  # mean RW execution time
    ro_exec_ms: float = 0.02               # mean read-only execution time
    validate_ms: float = 0.005
    local_commit_ms: float = 0.002
    msg_proc_ms: float = 0.01      # outbound protocol processing (dilates under load)
    think_ms: float = 0.005
    duration_ms: float = 2000.0
    warmup_ms: float = 200.0
    drain_ms: float = 200.0
    stats_update_ms: float = 5.0           # staleness of piggybacked stats
    forward: ForwardPolicy = field(default_factory=ForwardPolicy)
    seed: int = 0
    init_value: float = 1000.0
    # "batched": enabled transactions whose commit-phase slots fire within
    # the same drain window are certified in ONE vectorized validate_batch
    # call (the default pipeline); "sequential" is the per-transaction python
    # loop, retained as the equivalence-test oracle.
    certify_mode: str = "batched"
    # Coalescing window for the certification drain.  0.0 (default) drains at
    # the same simulated instant the commit-phase slots fire — bit-identical
    # to the sequential path.  > 0 defers the verdict by up to this much to
    # grow batches (leases are held across the window, so safety is
    # unchanged; commit latency takes the hit) — the knob that lets the
    # simulator run node/thread counts an order of magnitude past the
    # paper's 4-node cluster without the python certification loop
    # dominating wall-clock.
    certify_window_ms: float = 0.0
    # Batches below this size settle verdicts with the numpy loop (JAX
    # dispatch overhead would swamp a near-empty batch); at or above it the
    # packed arrays go through kernels.ops (Pallas on TPU, jit'd jnp
    # elsewhere).  The two agree bitwise — tests force this to 1 to pin the
    # vectorized path against the sequential oracle.
    certify_jax_min: int = 8
    # Lease control plane.  "batched" (default, FGL only): the replicated
    # conflict-queue state lives in the sharded array-backed manager
    # (repro.core.lease_batched) — lease_shards owner shards by class hash,
    # queue mutations as vectorized scatters, waiter/prefetch enablement
    # settled per delivery instant through kernels.ops.settle_lease_batch
    # once an instant packs >= lease_jax_min groups (numpy row math below,
    # same verdicts).  "sequential" keeps the per-class python queues
    # (LeaseManagerBase) as the byte-identical oracle; ALC always uses it
    # (coarse multi-class LORs don't fit the one-LOR-per-class layout).
    lease_mode: str = "batched"
    lease_shards: int = 8
    lease_jax_min: int = 64
    # Ownership handoff.  "pipelined" (default) is the Zeus-style overlap:
    # the footprint is known at start (spec.items), so when the DTD would
    # keep the transaction local its lease request is OA-broadcast *at
    # start* and the request round + the owner's in-flight commit drain
    # overlap the transaction's own execution; commit certification still
    # waits for both execution and enablement, so safety is untouched (the
    # explorer's CI grid model-checks both handoffs violation-free, and
    # benchmarks/handoff.py pins pipelined >= drain across the locality x
    # contention grid).  "drain" is the paper's ordering — execute, then
    # request leases, then wait for the owner's LORs to drain — kept as
    # the fallback knob and the oracle for the overlap's equivalence tests.
    handoff: str = "pipelined"
    # Commit-phase slot cost.  "amortized" (default, batched mode only):
    # the group of transactions enabled together occupies ONE worker slot
    # for cert_fixed_ms + len(group) * cert_per_txn_ms — simulated
    # throughput, not just simulator wall-clock, reflects that the batched
    # pipeline certifies the group in one kernel dispatch.  "per_txn": every
    # transaction occupies its own slot for validate_ms + local_commit_ms —
    # always used by the sequential oracle, and forced by the equivalence
    # test to pin the batched drain as a pure vectorization.
    cert_slot_mode: str = "amortized"
    cert_fixed_ms: Optional[float] = None     # default: validate_ms
    cert_per_txn_ms: Optional[float] = None   # default: local_commit_ms
    # Proactive placement planner (repro.plan): score affinity-driven lease
    # moves every plan.epoch_ms of simulated time and execute them as
    # background prefetch requests through the lease managers (None = off).
    plan: Optional["PlanConfig"] = None
    # Lease-protocol sanitizer (repro.analysis): wrap every replica's lease
    # manager in the invariant-checking observer and cross-check the
    # certification write-lock inputs.  Pure post-state reads — a
    # sanitize-on run is byte-identical to sanitize-off, just slower.
    sanitize: bool = False
    # Schedule-space exploration (repro.analysis.explore): an ExploreConfig
    # whose ``policy`` attribute, when set, is installed as the event
    # queue's SchedulePolicy — the explorer re-constructs the cluster per
    # explored schedule and swaps in its recording policy through this
    # field.  None (default): the plain (time, seq) heap order.
    explore: Optional["ExploreConfig"] = None  # noqa: F821 (repro.analysis)
    # Structured tracing (repro.obs): record lease rounds, forwards,
    # aborts, certify batches, and planner epochs as sim-time-stamped
    # spans/instants on per-node tracks, exportable to Perfetto via
    # ``Cluster.trace.export(path)``.  Stamps come from the event queue's
    # virtual clock, so a traced run is byte-identical to an untraced one
    # (asserted in tests/test_obs.py) and two seeded runs export
    # byte-identical JSON.
    trace: bool = False


@dataclass
class Metrics:
    commits: int = 0
    ro_commits: int = 0
    rw_commits: int = 0
    aborts: int = 0
    forwards: int = 0
    lease_requests: int = 0
    piggybacks: int = 0
    rw_certified: int = 0
    cert_batches: int = 0          # batched validate_batch drains issued
    cert_batch_txns: int = 0       # transactions certified through them
    plan_epochs: int = 0           # planner invocations
    plan_prefetches: int = 0       # background lease prefetches issued
    commit_times: List[Tuple[float, int]] = field(default_factory=list)
    commit_latency_sum: float = 0.0

    def throughput(self, t0: float, t1: float) -> float:
        """Committed txns per second within [t0, t1) of simulated time."""
        n = sum(1 for (t, _) in self.commit_times if t0 <= t < t1)
        return n / max(1e-9, (t1 - t0)) * 1e3

    def lease_reuse_rate(self) -> float:
        """Paper Fig. 3(b): piggybacked RW txns / total RW txns certified."""
        return self.piggybacks / max(1, self.rw_certified)


# --------------------------------------------------------------------------
# Per-replica state
# --------------------------------------------------------------------------

class Replica:
    def __init__(self, node: int, cfg: SimConfig) -> None:
        self.node = node
        self.cfg = cfg
        if cfg.lease_mode not in ("batched", "sequential"):
            raise ValueError(f"unknown lease_mode {cfg.lease_mode!r}")
        if cfg.lease_kind == "fgl" and cfg.lease_mode == "batched":
            from .lease_batched import ShardedLeaseManager

            self.lm = ShardedLeaseManager(
                node, cfg.n_classes, n_shards=cfg.lease_shards,
                jax_min=cfg.lease_jax_min)
        elif cfg.lease_kind == "fgl":
            self.lm = FGLLeaseManager(node, cfg.n_classes)
        else:
            self.lm = ALCLeaseManager(node, cfg.n_classes)
        if cfg.sanitize:
            from repro.analysis.sanitizer import LeaseSanitizer

            self.lm = LeaseSanitizer(self.lm)
        self.store = VersionedStore(cfg.n_items, cfg.init_value)
        self.freq = DecayedFrequency(cfg.n_nodes, cfg.n_classes)
        self.cpu_view = np.zeros((cfg.n_nodes,), dtype=np.float64)
        self.meter = CpuMeter(cfg.threads_per_node)
        self.free_slots = cfg.threads_per_node
        self.slot_queue: deque = deque()
        self.slowdown = 1.0  # CPU-contention multiplier on processing times
        self.waiters: List[Tuple["SimTxn", List[LOR]]] = []
        self.pending_reqs: Dict[int, "SimTxn"] = {}
        # batched certification: commit-phase slots that fired but whose
        # verdict is settled by the next drain event (same instant)
        self.certify_queue: List["SimTxn"] = []
        self.certify_pending = False
        # planner prefetches awaiting their LORs heading every queue: the
        # drain to activeXacts=0 must only happen at the head, preserving
        # the protocol invariant (drained => enabled) the free rules rely on
        self.prefetch_waiters: List[List[LOR]] = []


@dataclass
class SimTxn:
    txid: int
    origin: int
    thread: int
    spec: TxnSpec
    ccs: FrozenSet[int]
    t_start: float
    stm: Transaction
    lors: List[LOR] = field(default_factory=list)
    exec_node: int = -1
    reexecs: int = 0
    forwards: int = 0
    reused: bool = False
    result: float = 0.0
    # pipelined handoff (SimConfig.handoff="pipelined"): the lease round
    # was issued at start; commit joins on (execution done AND LORs held)
    early: bool = False
    exec_done: bool = False


# --------------------------------------------------------------------------
# The cluster
# --------------------------------------------------------------------------

class Cluster:
    def __init__(self, cfg: SimConfig, workload: Workload, ccmap=None) -> None:
        self.cfg = cfg
        self.workload = workload
        policy = None if cfg.explore is None else cfg.explore.policy
        self.events = EventQueue(policy=policy)
        # repro.obs recorder (None when off: every site is one dead branch)
        self.trace = None
        if cfg.trace:
            from repro.obs.trace import TraceRecorder

            self.trace = TraceRecorder()
        self.gcs = SimGCS(self.events, cfg.n_nodes, cfg.latency)
        self.ccmap = ccmap or ConflictClassMap(
            cfg.n_classes, stride=max(1, cfg.n_items // cfg.n_classes)
        )
        self.replicas = [Replica(i, cfg) for i in range(cfg.n_nodes)]
        self.dtd = DTD(cfg.dtd, cfg.n_nodes)
        self.metrics = Metrics()
        self.rngs = [np.random.default_rng(cfg.seed * 1000 + i) for i in range(cfg.n_nodes)]
        self._txid = itertools.count(1)
        self._reqid = itertools.count(1)
        self._stopped = False
        self._inflight: Dict[int, SimTxn] = {}
        # item -> conflict class, used to derive per-item write-lock state
        # from the lease layer for the certification kernel
        if hasattr(self.ccmap, "of_item"):
            self._item_cc = np.fromiter(
                (self.ccmap.of_item(i) for i in range(cfg.n_items)),
                np.int32, count=cfg.n_items)
        else:
            self._item_cc = None
        # proactive placement planner (repro.plan): a global control loop
        # with the same piggybacked-staleness view the DTD gets
        self.planner = None
        if cfg.plan is not None:
            from repro.plan import PlacementPlanner

            self.planner = PlacementPlanner(
                cfg.n_nodes, cfg.n_classes, cfg.plan,
                track_co=cfg.plan.co_gain > 0.0)
        self.t_throughput: List[Tuple[float, int, int]] = []  # (t, node, 1)
        for i in range(cfg.n_nodes):
            self.gcs.on_opt[i] = self._make_handler(i, self._on_opt)
            self.gcs.on_to[i] = self._make_handler(i, self._on_to)
            self.gcs.on_urb[i] = self._make_handler(i, self._on_urb)
            self.gcs.on_p2p[i] = self._make_handler(i, self._on_p2p)
            self.gcs.on_view_change[i] = (
                lambda view, failed, n=i: self._on_view_change(n, view, failed)
            )

    def _make_handler(self, node: int, fn):
        return lambda msg, sender, n=node, f=fn: f(n, msg, sender)

    # -- lifecycle -----------------------------------------------------------
    def run(self) -> Metrics:
        cfg = self.cfg
        for node in range(cfg.n_nodes):
            for thread in range(cfg.threads_per_node):
                self.events.schedule(0.0, (lambda n=node, t=thread: self._start_txn(n, t)))
        self._schedule_stats_sync()
        if self.planner is not None:
            self._schedule_plan_epoch()
        self.events.run(cfg.duration_ms)
        self._stopped = True
        self.events.run(cfg.duration_ms + cfg.drain_ms)
        if cfg.sanitize:
            # end-of-run reconciliation: queues == ledger, LORs conserved
            for r in self.replicas:
                if self.gcs.alive(r.node):
                    r.lm.verify_full()
        return self.metrics

    def throughput(self) -> float:
        return self.metrics.throughput(self.cfg.warmup_ms, self.cfg.duration_ms)

    def wedged(self) -> List[str]:
        """Stuck protocol work, for the explorer's quiescence check.

        Meaningful once the event queue has drained with ``_stopped`` set:
        the closed loop schedules nothing new, so any surviving in-flight
        transaction or waiter can only be waiting on a protocol event that
        will never come — a lease circulation deadlock no per-event
        invariant check can see.  Transactions originated by a failed
        member are excluded (fail-stop: nobody restarts them).
        """
        out: List[str] = []
        for txid in sorted(self._inflight):
            txn = self._inflight[txid]
            if self.gcs.alive(txn.origin):
                out.append(f"txn {txid} in-flight (origin {txn.origin}, "
                           f"exec {txn.exec_node})")
        for r in self.replicas:
            if not self.gcs.alive(r.node):
                continue
            for (txn, lors) in r.waiters:
                ccs = sorted({cc for l in lors for cc in l.ccs})
                out.append(f"txn {txn.txid} awaiting enablement of "
                           f"{ccs} at node {r.node}")
            if r.prefetch_waiters:
                out.append(f"{len(r.prefetch_waiters)} prefetch group(s) "
                           f"never headed their queues at node {r.node}")
        return out

    def _schedule_stats_sync(self) -> None:
        def sync():
            if self._stopped:
                return
            t = self.events.now
            truth = np.array(
                [
                    r.meter.utilization(t) if self.gcs.alive(r.node) else 1.0
                    for r in self.replicas
                ]
            )
            for r in self.replicas:
                r.cpu_view[:] = truth
            self.events.schedule(self.cfg.stats_update_ms, sync)

        self.events.schedule(self.cfg.stats_update_ms, sync)

    # -- proactive placement (repro.plan) --------------------------------------
    def _schedule_plan_epoch(self) -> None:
        def epoch():
            if self._stopped:
                return
            self._run_plan_epoch()
            self.events.schedule(self.cfg.plan.epoch_ms, epoch)

        self.events.schedule(self.cfg.plan.epoch_ms, epoch)

    def _run_plan_epoch(self) -> None:
        """Score all [class, node] lease moves in one jit'd evaluation and
        issue the bounded plan as background prefetch requests.

        A planned move costs one lease round (OAB request + URB free) *off*
        any transaction's critical path; once the prefetched LOR heads its
        queue, transactions at the target piggyback on it and the forward /
        lease round-trip they used to pay disappears.  Safety is untouched:
        the move is an ordinary lease request through the replicated
        conflict queues.
        """
        from repro.core.dtd import C_AB, C_P2P, C_URB

        alive = [i for i in range(self.cfg.n_nodes) if self.gcs.alive(i)]
        if not alive:
            return
        self.metrics.plan_epochs += 1
        coord = self.replicas[alive[0]]
        n_cls = self.cfg.n_classes
        owner = coord.lm.owner_np().astype(np.int32)
        # a lease prefetch ships no state (write-sets replicate via URB
        # regardless of ownership) — costs are the paper's step constants
        step = self.cfg.latency.step_ms
        fwd_cost = np.full((n_cls,), (C_P2P + C_URB) * step)
        move_cost = np.full((n_cls,), (C_AB + C_URB) * step)
        plan = self.planner.plan(
            self.events.now, owner, np.zeros((n_cls,)), fwd_cost, move_cost,
            coord.cpu_view)
        executed = []
        for mv in plan.moves:
            if not self.gcs.alive(mv.dst):
                continue
            dlm = self.replicas[mv.dst].lm
            if dlm.has_unblocked(mv.cc, mv.dst):
                continue                 # dst already holds / awaits it
            req = LeaseRequest(
                req_id=next(self._reqid), proc=mv.dst, ccs=(mv.cc,),
                coarse=(self.cfg.lease_kind == "alc"), prefetch=True)
            self.metrics.plan_prefetches += 1
            self.gcs.oa_broadcast(mv.dst, ("lease", req))
            executed.append(mv)
        self.planner.committed(executed)
        tr = self.trace
        if tr is not None:
            tr.span("plan-epoch", "plan", self.events.now, 0.0,
                    moves=len(executed))
            for mv in executed:
                tr.instant("plan-prefetch", "plan", ts=self.events.now,
                           cc=mv.cc, dst=mv.dst)

    # -- CPU slots -------------------------------------------------------------
    def _request_slot(self, node: int, fn: Callable[[], None]) -> None:
        r = self.replicas[node]
        if r.free_slots > 0:
            r.free_slots -= 1
            r.meter.acquire(self.events.now)
            fn()
        else:
            r.slot_queue.append(fn)

    def _release_slot(self, node: int) -> None:
        r = self.replicas[node]
        r.meter.release(self.events.now)
        if r.slot_queue:
            nxt = r.slot_queue.popleft()
            r.meter.acquire(self.events.now)
            self.events.schedule(0.0, nxt)
        else:
            r.free_slots += 1

    def inject_load(
        self, node: int, extra_load: float, slowdown: float, seize_slots: int = 0
    ) -> None:
        """Inject background CPU-intensive jobs (overload experiment, Fig 3c).

        External jobs contend for the node's cores: ``seize_slots`` worker
        slots are occupied outright, every remaining processing step at the
        node (execution, re-execution, validation, commit processing, and the
        protocol work of disseminating commits / lease releases) dilates by
        ``slowdown``, and the node's reported CPU utilization rises by
        ``extra_load`` (which is what constraint (3) reads).
        """
        r = self.replicas[node]
        r.slowdown = slowdown
        for _ in range(seize_slots):
            self._request_slot(node, lambda: None)  # held for the run
        r.meter.extra_load = extra_load

    def _send_cost_ms(self, node: int) -> float:
        """Outbound protocol-processing time (serialization, URB handoff).

        Dilated by the node's CPU contention: an overloaded node is slow to
        release leases and to disseminate write-sets, which is a large part
        of why uninformed migration towards it hurts (Fig 3c).
        """
        r = self.replicas[node]
        return self.cfg.msg_proc_ms * r.slowdown

    def _ur_broadcast_from(self, node: int, msg) -> None:
        d = self._send_cost_ms(node)
        if d <= 0:
            self.gcs.ur_broadcast(node, msg)
        else:
            self.events.schedule(d, lambda: self.gcs.ur_broadcast(node, msg))

    # -- transaction lifecycle --------------------------------------------------
    def _start_txn(self, node: int, thread: int) -> None:
        if self._stopped or not self.gcs.alive(node):
            return
        rng = self.rngs[node]
        spec = self.workload.sample(node, rng)
        txn = SimTxn(
            txid=next(self._txid),
            origin=node,
            thread=thread,
            spec=spec,
            ccs=self.ccmap.get_conflict_classes(spec.items),
            t_start=self.events.now,
            stm=Transaction(txid=0, origin=node),
        )
        txn.stm.txid = txn.txid
        mean = spec.exec_ms or (self.cfg.ro_exec_ms if spec.read_only else self.cfg.exec_ms)
        dur = float(rng.exponential(mean) * 0.5 + mean * 0.5)  # bounded jitter
        dur *= self.replicas[node].slowdown
        if self.cfg.handoff == "pipelined" and not spec.read_only:
            self._early_acquire(txn, node)
        self._request_slot(node, lambda: self.events.schedule(dur, lambda: self._exec_done(txn, node)))

    def _early_acquire(self, txn: SimTxn, node: int) -> None:
        """Zeus-style pipelined handoff: issue the lease round at start.

        The footprint is known from ``spec.items`` before execution, so
        when the DTD verdict is "certify locally" the OAB request round and
        the current owner's in-flight commit drain run *under* this
        transaction's execution instead of after it.  When the DTD wants to
        migrate the work, the reactive request-after-execute path is kept —
        acquiring remotely-homed classes early would fight the forwarder.
        """
        r = self.replicas[node]
        target = self.dtd.decide(
            origin=node,
            ccs=txn.ccs,
            lease_owner_of_cc=r.lm.head_owner,
            freq_rates=r.freq.rates(self.events.now),
            cpu=r.cpu_view,
            opt_hint=txn.spec.opt_hint,
        )
        if (target != node and self.gcs.alive(target)
                and self.cfg.forward.may_forward(txn.forwards)):
            return
        txn.early = True
        txn.exec_node = node
        self._inflight[txn.txid] = txn
        tr = self.trace
        lors = r.lm.try_piggyback(txn.ccs)
        if lors is not None:
            txn.reused = True
            self.metrics.piggybacks += 1
            txn.lors = lors
            if tr is not None:
                tr.instant("lease-piggyback", f"node{node}/lease",
                           ts=self.events.now, txid=txn.txid)
            return
        req = LeaseRequest(
            req_id=next(self._reqid),
            proc=node,
            ccs=tuple(sorted(txn.ccs)),
            coarse=(self.cfg.lease_kind == "alc"),
        )
        r.lm.n_requests += 1
        self.metrics.lease_requests += 1
        r.pending_reqs[req.req_id] = txn
        if tr is not None:
            # closed by _on_to when the TO-delivery grants the LORs; async
            # span because rounds from the node's threads overlap freely
            tr.abegin("lease-round", f"node{node}/lease", req.req_id,
                      ts=self.events.now, txid=txn.txid, ccs=len(req.ccs))
        self.gcs.oa_broadcast(node, ("lease", req))

    def _exec_done(self, txn: SimTxn, node: int) -> None:
        r = self.replicas[node]
        txn.stm = Transaction(txid=txn.txid, origin=txn.origin)
        txn.result = txn.spec.execute(r.store, txn.stm)
        self._release_slot(node)
        txn.exec_done = True
        tr = self.trace
        if tr is not None:
            tr.span("exec", f"node{node}/t{txn.thread}", txn.t_start,
                    self.events.now - txn.t_start, txid=txn.txid)
        if txn.spec.read_only:
            self.events.schedule(
                self.cfg.local_commit_ms, lambda: self._txn_done(txn, committed=True)
            )
            return
        if txn.early:
            # pipelined handoff: the lease round ran under execution; enter
            # the commit phase now if the LORs are already held, else the
            # pending TO-deliver joins (_on_to sees exec_done)
            self.metrics.rw_certified += 1
            if txn.lors:
                self._wait_enabled(txn, node)
            return
        self._dispatch(txn, node)

    # -- DTD dispatch -------------------------------------------------------------
    def _dispatch(self, txn: SimTxn, node: int) -> None:
        self._inflight[txn.txid] = txn
        r = self.replicas[node]
        target = self.dtd.decide(
            origin=node,
            ccs=txn.ccs,
            lease_owner_of_cc=r.lm.head_owner,
            freq_rates=r.freq.rates(self.events.now),
            cpu=r.cpu_view,
            opt_hint=txn.spec.opt_hint,
        )
        if target != node and self.gcs.alive(target) and self.cfg.forward.may_forward(txn.forwards):
            txn.forwards += 1
            self.metrics.forwards += 1
            tr = self.trace
            if tr is not None:
                tr.instant("forward", f"node{node}/dtd", ts=self.events.now,
                           txid=txn.txid, target=target)
            if self.planner is not None:
                # the planner's target signal: work shipped away from origin
                self.planner.affinity.record_forward(
                    self.events.now, node, txn.ccs)
            # record the forward target NOW: if it fails while the message is
            # in flight (the GCS drops p2p to dead nodes), the view-change
            # handler must still see exec_node == failed to restart this
            # transaction — waiting for the target's _certify to set it would
            # wedge the originating thread forever
            txn.exec_node = target
            self.gcs.p2p_send(
                node,
                target,
                ("forward", txn),
            )
        else:
            self._certify(txn, node)

    # -- certification (replication manager) ----------------------------------------
    def _certify(self, txn: SimTxn, node: int) -> None:
        txn.exec_node = node
        r = self.replicas[node]
        self.metrics.rw_certified += 1
        tr = self.trace
        lors = r.lm.try_piggyback(txn.ccs)
        if lors is not None:
            txn.reused = True
            self.metrics.piggybacks += 1
            txn.lors = lors
            if tr is not None:
                tr.instant("lease-piggyback", f"node{node}/lease",
                           ts=self.events.now, txid=txn.txid)
            self._wait_enabled(txn, node)
        else:
            req = LeaseRequest(
                req_id=next(self._reqid),
                proc=node,
                ccs=tuple(sorted(txn.ccs)),
                coarse=(self.cfg.lease_kind == "alc"),
            )
            r.lm.n_requests += 1
            self.metrics.lease_requests += 1
            r.pending_reqs[req.req_id] = txn
            if tr is not None:
                tr.abegin("lease-round", f"node{node}/lease", req.req_id,
                          ts=self.events.now, txid=txn.txid,
                          ccs=len(req.ccs))
            self.gcs.oa_broadcast(node, ("lease", req))

    def _wait_enabled(self, txn: SimTxn, node: int) -> None:
        r = self.replicas[node]
        r.waiters.append((txn, txn.lors))
        self._check_waiters(node)

    def _check_waiters(self, node: int) -> None:
        r = self.replicas[node]
        if r.prefetch_waiters:
            self._settle_prefetches(node)
        still: List[Tuple[SimTxn, List[LOR]]] = []
        ready: List[SimTxn] = []
        # one vectorized isEnabled settle over every waiting commit phase
        # (the sequential oracle's enabled_mask is the per-group loop)
        enabled = r.lm.enabled_mask([lors for (_txn, lors) in r.waiters])
        for (txn, lors), ok in zip(r.waiters, enabled):
            if ok:
                ready.append(txn)
            else:
                still.append((txn, lors))
        r.waiters = still
        if not ready:
            return
        cfg = self.cfg
        if cfg.certify_mode == "batched" and cfg.cert_slot_mode == "amortized":
            # PR-4's pipeline certifies the whole enabled group in ONE
            # kernel dispatch, so the commit phase is one core's work:
            # a single slot charges fixed + per-txn increment for the group
            # instead of every transaction paying the full
            # validate+commit on its own slot — simulated throughput, not
            # just simulator wall-clock, reflects the batching
            fixed = cfg.cert_fixed_ms if cfg.cert_fixed_ms is not None \
                else cfg.validate_ms
            per_txn = cfg.cert_per_txn_ms if cfg.cert_per_txn_ms is not None \
                else cfg.local_commit_ms
            dur = (fixed + per_txn * len(ready)) * r.slowdown

            def start(group=tuple(ready), d=dur):
                def fin():
                    self._release_slot(node)
                    for t in group:
                        self._enqueue_certify(t, node)
                self.events.schedule(d, fin)

            self._request_slot(node, start)
            return
        for txn in ready:
            # certification + commit processing is CPU work at the executing
            # node: occupy a worker slot for its (dilated) duration, so an
            # overloaded node's commit phase queues behind the external jobs
            dur = (cfg.validate_ms + cfg.local_commit_ms) * r.slowdown

            def start(t=txn, d=dur):
                def fin():
                    self._release_slot(node)
                    if cfg.certify_mode == "batched":
                        self._enqueue_certify(t, node)
                    else:
                        self._validate_and_commit(t, node)
                self.events.schedule(d, fin)

            self._request_slot(node, start)

    def _settle_prefetches(self, node: int) -> None:
        """Drain prefetched LORs that now head every queue they touch.

        A prefetch carries no transaction, so its LOR must end at
        activeXacts=0 to be freeable — but draining it while still queued
        behind another owner would create a dormant *non-head* LOR that no
        protocol event ever frees (the blocked-and-drained rule only fires
        at the head), wedging the class.  So the drain waits for
        ``is_enabled``, exactly like a transaction's commit phase: at the
        head, a drained unblocked LOR is the protocol's ordinary dormant
        state (piggybackable; freed the moment a conflicting request blocks
        it), and one blocked while waiting is freed here as it drains.
        """
        r = self.replicas[node]
        still: List[List[LOR]] = []
        to_free: List[LOR] = []
        enabled = r.lm.enabled_mask(r.prefetch_waiters)
        for lors, ok in zip(r.prefetch_waiters, enabled):
            if ok:
                to_free.extend(r.lm.finished_xact(lors))
            else:
                still.append(lors)
        r.prefetch_waiters = still
        if to_free:
            self._ur_broadcast_from(node, ("freed", [l.key() for l in to_free]))

    # -- batched certification drain ------------------------------------------
    def _enqueue_certify(self, txn: SimTxn, node: int) -> None:
        """Queue a commit-phase-complete transaction for the batch drain.

        All commit-phase slots armed by one ``_check_waiters`` call share the
        same duration, so they land here at the same instant; the drain event
        (scheduled at zero delay, i.e. after every same-instant fin) packs
        them into one ``validate_batch`` dispatch.
        """
        r = self.replicas[node]
        r.certify_queue.append(txn)
        if not r.certify_pending:
            r.certify_pending = True
            self.events.schedule(
                self.cfg.certify_window_ms, lambda: self._drain_certify(node))

    def _write_locks(self, node: int) -> Optional[np.ndarray]:
        """Per-item write-lock state from the lease layer's ownership view.

        An item is write-locked at ``node`` when its conflict class is
        currently leased to a *different* replica.  Enabled transactions head
        every queue they touch, so a lock conflict here means the batch was
        fed a transaction the lease layer never enabled — the kernel turns
        that protocol violation into an abort instead of a silent pass.
        """
        if self._item_cc is None:
            return None
        lm = self.replicas[node].lm
        owners = lm.owner_np()
        per_item = owners[self._item_cc]
        return ((per_item >= 0) & (per_item != node)).astype(np.int32)

    def _locked_write(self, txn: SimTxn, node: int) -> bool:
        """Per-txn twin of the kernels' lock check (small-batch path)."""
        if self._item_cc is None:
            return False
        lm = self.replicas[node].lm
        for item in txn.stm.write_set:
            owner = lm.head_owner(int(self._item_cc[item]))
            if owner >= 0 and owner != node:
                return True
        return False

    def _drain_certify(self, node: int) -> None:
        r = self.replicas[node]
        r.certify_pending = False
        batch, r.certify_queue = r.certify_queue, []
        if not batch:
            return
        locks = self._write_locks(node)
        if len(batch) >= self.cfg.certify_jax_min:
            ok = validate_batch(r.store, [t.stm for t in batch], locks=locks)
        else:
            # near-empty batch: JAX dispatch overhead would dominate — the
            # numpy loop settles the same verdicts, including the lock
            # check, so a protocol violation aborts regardless of how many
            # transactions happened to share the drain instant
            ok = [r.store.validate(t.stm) and not self._locked_write(t, node)
                  for t in batch]
        if self.cfg.sanitize:
            # single-writer cross-check: the locks input must match the
            # lease layer's live ownership, and no passing transaction may
            # write an item leased elsewhere
            from repro.analysis.sanitizer import check_write_locks

            check_write_locks(
                node, r.lm.owner_np(), self._item_cc, locks,
                [t.stm for t in batch], [bool(o) for o in ok])
        self.metrics.cert_batches += 1
        self.metrics.cert_batch_txns += len(batch)
        # Intra-batch serialization: the one-at-a-time path applies each
        # commit before validating the next, so a transaction reading an item
        # written by an earlier committer in the same batch must abort (the
        # earlier commit stamped a fresh txid, which can never equal the
        # snapshot version).  Writes are resolved by the single apply_batch.
        written: set = set()
        verdicts: List[bool] = []
        committers: List[SimTxn] = []
        for t, o in zip(batch, ok):
            good = bool(o) and not any(
                it in written for it in t.stm.read_items)
            verdicts.append(good)
            if good:
                written.update(t.stm.write_set)
                committers.append(t)
        if committers:
            r.store.apply_batch(
                [t.stm.write_set for t in committers],
                [t.txid for t in committers])
        tr = self.trace
        if tr is not None:
            tr.span("certify-batch", f"node{node}/cert", self.events.now,
                    0.0, batch=len(batch),
                    aborts=len(batch) - len(committers))
        for t, good in zip(batch, verdicts):
            if good:
                self._commit_applied(t, node)
            else:
                self._certify_failed(t, node)

    def _validate_and_commit(self, txn: SimTxn, node: int) -> None:
        """One-at-a-time certification — the batched drain's test oracle."""
        r = self.replicas[node]
        if r.store.validate(txn.stm):
            self._commit(txn, node)
        else:
            self._certify_failed(txn, node)

    def _certify_failed(self, txn: SimTxn, node: int) -> None:
        r = self.replicas[node]
        self.metrics.aborts += 1
        tr = self.trace
        if tr is not None:
            tr.instant("abort", f"node{node}/dtd", ts=self.events.now,
                       txid=txn.txid)
        if self.planner is not None:
            # contention at the executing node damps its affinity
            self.planner.affinity.record_abort(self.events.now, node, txn.ccs)
        txn.reexecs += 1
        if txn.reexecs > self.cfg.forward.max_reexec:
            # give up: release leases, notify origin with an abort
            self._finish_leases(txn, node)
            if node != txn.origin:
                self.gcs.p2p_send(
                    node,
                    txn.origin,
                    ("notice", CommitNotice(txn.txid, txn.origin, txn.thread, False)),
                )
            else:
                self._txn_done(txn, committed=False)
            return
        # re-execute holding the leases (ALC re-execution rule): no other
        # replica can have updated the leased classes, so the re-run is
        # conflict-free provided the data-set is unchanged.
        rng = self.rngs[node]
        mean = txn.spec.exec_ms or self.cfg.exec_ms
        dur = float(rng.exponential(mean) * 0.5 + mean * 0.5) * r.slowdown
        def reexec():
            self.events.schedule(dur, lambda: self._reexec_done(txn, node))
        self._request_slot(node, reexec)

    def _reexec_done(self, txn: SimTxn, node: int) -> None:
        r = self.replicas[node]
        txn.stm = Transaction(txid=txn.txid, origin=txn.origin)
        txn.result = txn.spec.execute(r.store, txn.stm)
        self._release_slot(node)
        tr = self.trace
        if tr is not None:
            tr.span("reexec", f"node{node}/t{txn.thread}", self.events.now,
                    0.0, txid=txn.txid, n=txn.reexecs)
        if self.cfg.certify_mode == "batched":
            self._enqueue_certify(txn, node)
        else:
            self._validate_and_commit(txn, node)

    def _commit(self, txn: SimTxn, node: int) -> None:
        r = self.replicas[node]
        r.store.apply_versioned(txn.stm.write_set, txn.txid)
        self._commit_applied(txn, node)

    def _commit_applied(self, txn: SimTxn, node: int) -> None:
        """Post-apply commit work: disseminate the write-set, free leases.

        The batched drain applies all committers' write-sets in one
        ``apply_batch`` scatter and then runs this per transaction in batch
        order, so broadcast/free ordering matches the sequential path.
        """
        self._ur_broadcast_from(
            node,
            (
                "commit",
                {
                    "txid": txn.txid,
                    "origin": txn.origin,
                    "thread": txn.thread,
                    "ccs": tuple(sorted(txn.ccs)),
                    "writes": dict(txn.stm.write_set),
                    "result": txn.result,
                    "executed_on": node,
                },
            ),
        )
        if self.planner is not None:
            self.planner.affinity.record_commit(
                self.events.now, txn.origin, txn.ccs)
        self._finish_leases(txn, node)

    def _finish_leases(self, txn: SimTxn, node: int) -> None:
        r = self.replicas[node]
        if not txn.lors:
            return
        to_free = r.lm.finished_xact(txn.lors)
        txn.lors = []
        if to_free:
            self._ur_broadcast_from(node, ("freed", [l.key() for l in to_free]))

    def _txn_done(self, txn: SimTxn, committed: bool) -> None:
        self._inflight.pop(txn.txid, None)
        m = self.metrics
        if committed:
            m.commits += 1
            if txn.spec.read_only:
                m.ro_commits += 1
            else:
                m.rw_commits += 1
            m.commit_times.append((self.events.now, txn.origin))
            m.commit_latency_sum += self.events.now - txn.t_start
        # closed loop: the originating thread starts its next transaction
        self.events.schedule(
            self.cfg.think_ms, (lambda: self._start_txn(txn.origin, txn.thread))
        )

    # -- GCS handlers ----------------------------------------------------------------
    def _on_opt(self, node: int, msg, sender: int) -> None:
        kind, payload = msg
        if kind != "lease":
            return
        req: LeaseRequest = payload
        r = self.replicas[node]
        to_free = r.lm.on_opt_deliver(req)
        if to_free:
            self._ur_broadcast_from(node, ("freed", [l.key() for l in to_free]))

    def _on_to(self, node: int, msg, sender: int) -> None:
        kind, payload = msg
        if kind != "lease":
            return
        req: LeaseRequest = payload
        r = self.replicas[node]
        lors = r.lm.on_to_deliver(req)
        if req.proc == node:
            if req.prefetch:
                # planner prefetch: no transaction is attached; the LORs
                # wait like a commit phase would and are drained to
                # activeXacts=0 only once they head their queues
                # (_settle_prefetches) — afterwards they sit unblocked and
                # piggybackable, freed by the usual rule the moment a
                # conflicting request blocks them
                if lors:
                    if self.cfg.sanitize:
                        # prefetch-head rule: these LORs may only drain to
                        # activeXacts=0 while heading their queues
                        r.lm.mark_prefetch(lors)
                    r.prefetch_waiters.append(lors)
            else:
                txn = r.pending_reqs.pop(req.req_id, None)
                if txn is not None:
                    txn.lors = lors
                    tr = self.trace
                    if tr is not None:
                        tr.aend("lease-round", f"node{node}/lease",
                                req.req_id, ts=self.events.now)
                    if txn.exec_done:
                        self._wait_enabled(txn, node)
                    # else: pipelined handoff — the lease round finished
                    # before the overlapped execution; _exec_done joins
        self._check_waiters(node)

    def _on_urb(self, node: int, msg, sender: int) -> None:
        kind, payload = msg
        r = self.replicas[node]
        if kind == "freed":
            r.lm.on_ur_deliver_freed(payload)
            tr = self.trace
            if tr is not None and node == sender:
                # once per broadcast (at the freeing node), not per replica
                tr.instant("lease-free", f"node{node}/lease",
                           ts=self.events.now, n=len(payload))
            self._check_waiters(node)
        elif kind == "commit":
            c = payload
            if node != c["executed_on"]:
                r.store.apply_versioned(c["writes"], c["txid"])
            r.freq.record(self.events.now, c["origin"], c["ccs"])
            if node == c["origin"]:
                # resume the originating thread (result piggybacked on the
                # commit message, §3.2)
                self._complete_origin(c["txid"])

    def _on_p2p(self, node: int, msg, sender: int) -> None:
        kind, payload = msg
        if kind == "forward":
            txn: SimTxn = payload
            self._certify(txn, node)
        elif kind == "notice":
            n: CommitNotice = payload
            # aborted after max re-executions: surface to the application
            # (paper: explicit exception); the thread moves on.
            self._inflight.pop(n.txid, None)
            self.events.schedule(
                self.cfg.think_ms, (lambda: self._start_txn(n.origin, n.origin_thread))
            )

    # origin-side completion bookkeeping -------------------------------------------
    def _complete_origin(self, txid: int) -> None:
        txn = self._inflight.pop(txid, None)
        if txn is None:
            return
        self._txn_done(txn, committed=True)

    def _on_view_change(self, node: int, view: List[int], failed: int) -> None:
        r = self.replicas[node]
        r.lm.purge_proc(failed)
        if self.planner is not None:
            # the planner's state must die with the member too: its affinity
            # rows would keep attracting moves toward the dead node, and
            # history entries naming it would mis-gate reversals (idempotent
            # — every surviving replica's view change applies it)
            self.planner.purge_node(failed)
        # transactions this node forwarded to (or had pending at) the failed
        # member are restarted locally — fail-stop recovery for the TF path.
        for txid, txn in list(self._inflight.items()):
            if txn.origin == node and txn.exec_node == failed:
                del self._inflight[txid]
                self.events.schedule(
                    self.cfg.think_ms,
                    (lambda t=txn: self._start_txn(t.origin, t.thread)),
                )
        self._check_waiters(node)
