"""Vectorized (lax.scan) cluster model for wide policy sweeps.

A round-based, fixed-capacity re-formulation of the lease/migration
dynamics: each round every node originates one transaction (two conflict
classes drawn from a partition by locality), the DTD picks the executing
node with the vectorized short-term cost, ownership moves when leases are
acquired, and per-transaction latency is accumulated in communication
steps (p2p=1, URB=2, OAB=3 — the paper's own cost model).

This is *not* the faithful reproduction vehicle (that is
:mod:`repro.core.cluster`, a discrete-event simulator); it is the
jit/vmap-able approximation used to sweep hundreds of (seed × locality ×
policy) points in milliseconds — e.g. for tuning the DTD's cost constants
or the conflict-class granularity before committing to event-sim runs.
Cross-checked against the event simulator for the qualitative trends the
paper establishes (tests/test_jax_sim.py): lease reuse rises with
locality; migration reduces lease traffic; throughput ordering
ALC < FGL < FGL+migration at high locality.
"""
from __future__ import annotations

import functools
from typing import Dict, NamedTuple

import jax
import jax.numpy as jnp

C_P2P, C_URB, C_AB = 1.0, 2.0, 3.0


class SweepResult(NamedTuple):
    steps_total: jax.Array        # accumulated communication steps
    commits: jax.Array
    piggybacks: jax.Array
    lease_moves: jax.Array
    forwards: jax.Array

    @property
    def throughput(self) -> jax.Array:
        """Commits per communication step (relative units)."""
        return self.commits / jnp.maximum(self.steps_total, 1e-9)

    @property
    def reuse_rate(self) -> jax.Array:
        return self.piggybacks / jnp.maximum(self.commits, 1.0)


@functools.partial(
    jax.jit,
    static_argnames=("n_nodes", "n_classes", "n_rounds", "fine_grained",
                     "migrate"),
)
def simulate(
    key: jax.Array,
    locality: jax.Array,          # scalar in [0, 1]
    *,
    n_nodes: int = 4,
    n_classes: int = 64,
    n_rounds: int = 512,
    fine_grained: bool = True,
    migrate: bool = False,
) -> SweepResult:
    """One sweep point.  vmap over ``key``/``locality`` for grids."""
    classes_per_node = n_classes // n_nodes

    def sample_ccs(k, node):
        k1, k2, k3 = jax.random.split(k, 3)
        local = jax.random.uniform(k1) < locality
        part = jnp.where(
            local, node,
            jax.random.randint(k2, (), 0, n_nodes))
        base = part * classes_per_node
        offs = jax.random.randint(k3, (2,), 0, classes_per_node)
        return base + offs                                  # [2]

    def round_fn(carry, k):
        owner, last_owner_req = carry                       # owner: [C] int32
        ks = jax.random.split(k, n_nodes)
        ccs = jax.vmap(sample_ccs)(ks, jnp.arange(n_nodes))  # [N, 2]

        def one_txn(owner, node, cc2):
            own0 = owner[cc2[0]] == node
            own1 = owner[cc2[1]] == node
            owns_all = own0 & own1
            # coarse ALC: reuse only if the *pair* was acquired together —
            # approximate by requiring both owned AND last request on the
            # head class came from this node as a pair
            reuse = owns_all if fine_grained else (
                owns_all & (last_owner_req[cc2[0]] == last_owner_req[cc2[1]]))
            # candidate executor: owner of the first class (attractor)
            cand = owner[cc2[0]]
            cand_owns = (owner[cc2[0]] == cand) & (owner[cc2[1]] == cand)
            do_forward = jnp.asarray(migrate) & ~reuse & cand_owns & (cand != node)
            exec_node = jnp.where(do_forward, cand, node)
            exec_reuse = reuse | do_forward
            cost = jnp.where(
                exec_reuse,
                jnp.where(do_forward, C_P2P + C_URB, C_URB),
                C_AB + 2.0 * C_URB,
            )
            acquire = ~exec_reuse
            return exec_node, acquire, do_forward, reuse, cost

        exec_nodes, acquires, forwards, reuses, costs = jax.vmap(
            one_txn, in_axes=(None, 0, 0))(owner, jnp.arange(n_nodes), ccs)

        # apply lease moves (later nodes win ties within a round — the
        # total order of the round's OABs)
        def apply(owner_lor, i):
            owner, lor = owner_lor
            take = acquires[i]
            owner = jnp.where(
                take,
                owner.at[ccs[i, 0]].set(exec_nodes[i]).at[ccs[i, 1]].set(exec_nodes[i]),
                owner)
            lor = jnp.where(
                take,
                lor.at[ccs[i, 0]].set(i * 7919 + 1).at[ccs[i, 1]].set(i * 7919 + 1),
                lor)
            return (owner, lor), None

        (owner, last_owner_req), _ = jax.lax.scan(
            apply, (owner, last_owner_req), jnp.arange(n_nodes))

        stats = jnp.stack([
            jnp.max(costs),                     # round time = slowest txn
            jnp.asarray(n_nodes, jnp.float32),  # commits
            jnp.sum(reuses.astype(jnp.float32)),
            jnp.sum(acquires.astype(jnp.float32)),
            jnp.sum(forwards.astype(jnp.float32)),
        ])
        return (owner, last_owner_req), stats

    owner0 = jnp.repeat(jnp.arange(n_nodes, dtype=jnp.int32), classes_per_node)
    lor0 = jnp.zeros((n_classes,), jnp.int32)
    keys = jax.random.split(key, n_rounds)
    _, stats = jax.lax.scan(round_fn, (owner0, lor0), keys)
    tot = jnp.sum(stats, axis=0)
    return SweepResult(tot[0], tot[1], tot[2], tot[3], tot[4])


def locality_sweep(
    localities, seeds=4, *, n_nodes=4, n_classes=64, n_rounds=512,
    fine_grained=True, migrate=False,
) -> Dict[str, jax.Array]:
    """vmapped grid: returns arrays [len(localities)] averaged over seeds."""
    loc = jnp.asarray(localities, jnp.float32)
    keys = jax.vmap(jax.random.PRNGKey)(jnp.arange(seeds))

    f = functools.partial(
        simulate, n_nodes=n_nodes, n_classes=n_classes, n_rounds=n_rounds,
        fine_grained=fine_grained, migrate=migrate)
    res = jax.vmap(lambda l: jax.vmap(lambda k: f(k, l))(keys))(loc)
    thr = jnp.mean(res.commits / jnp.maximum(res.steps_total, 1e-9), axis=1)
    reuse = jnp.mean(res.piggybacks / jnp.maximum(res.commits, 1.0), axis=1)
    moves = jnp.mean(res.lease_moves, axis=1)
    return {"locality": loc, "throughput": thr, "reuse": reuse,
            "lease_moves": moves}
