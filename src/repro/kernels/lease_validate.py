"""Batched TL2 certification — Pallas TPU kernel (the paper's hot loop).

When a replica (pod controller) validates a *batch* of remote/forwarded
transactions (Lilac-TM §3.2: forwarded transactions are certified at the
target without re-execution), the work is: gather each transaction's
read-set versions from the store's version array, compare against the
snapshot versions, and check write locks.  At pod scale (thousands of
in-flight certifications per lease window) this is a bandwidth-bound
gather+compare — exactly the kind of loop worth a VMEM-resident kernel.

Tiling: transactions are tiled over the grid; the version array is tiled
into VMEM *chunks* with the gather performed as ``chunk-local compare``
(a one-hot-free masked equality over the chunk) — the TPU-native
reformulation of a random gather: each (txn-tile × version-chunk) cell
checks only the read entries whose item falls in the chunk, accumulating a
per-transaction conflict flag across chunks (innermost grid dim, scratch
persists).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _validate_kernel(
    items_ref, vers_ref, witems_ref, store_ref, locks_ref,   # inputs
    ok_ref,                                                   # output [Bt]
    bad_scr,                                                  # scratch [Bt]
    *, n_chunks: int, chunk: int,
):
    ic = pl.program_id(1)

    @pl.when(ic == 0)
    def _init():
        bad_scr[...] = jnp.zeros_like(bad_scr)

    items = items_ref[...]            # [Bt, R] int32 (-1 padded)
    vers = vers_ref[...]              # [Bt, R] int32
    witems = witems_ref[...]          # [Bt, W] int32 (-1 padded)
    store = store_ref[...]            # [chunk] int32
    locks = locks_ref[...]            # [chunk] int32 (0/1)

    lo = ic * chunk
    # read-set: entries whose item falls in this chunk must match versions
    in_chunk = (items >= lo) & (items < lo + chunk)
    local = jnp.clip(items - lo, 0, chunk - 1)
    cur = jnp.take(store, local, axis=0)              # [Bt, R]
    mismatch = in_chunk & (cur != vers)
    # write-set: locked items are conflicts
    w_in = (witems >= lo) & (witems < lo + chunk)
    wlocal = jnp.clip(witems - lo, 0, chunk - 1)
    wlocked = w_in & (jnp.take(locks, wlocal, axis=0) > 0)
    bad_scr[...] = (
        bad_scr[...]
        + jnp.sum(mismatch.astype(jnp.int32), axis=1)
        + jnp.sum(wlocked.astype(jnp.int32), axis=1)
    )

    @pl.when(ic == n_chunks - 1)
    def _finish():
        ok_ref[...] = (bad_scr[...] == 0)


@functools.partial(
    jax.jit, static_argnames=("block_txns", "chunk", "interpret"),
)
def lease_validate(
    store_versions: jax.Array,    # [n_items] int32
    read_items: jax.Array,        # [B, R] int32 (-1 padded)
    read_versions: jax.Array,     # [B, R] int32
    write_locks: jax.Array,       # [n_items] int32 (0/1)
    write_items: jax.Array,       # [B, W] int32 (-1 padded)
    *,
    block_txns: int = 256,
    chunk: int = 4096,
    interpret: bool = False,
) -> jax.Array:
    # normalize dtypes at the boundary: callers hand numpy buffers of
    # whatever width their logs use; a silent int64 view of an int32 buffer
    # once produced garbage write items (see tests/test_certify.py lock
    # parity), so the kernel refuses to rely on caller dtypes
    store_versions = jnp.asarray(store_versions, jnp.int32)
    read_items = jnp.asarray(read_items, jnp.int32)
    read_versions = jnp.asarray(read_versions, jnp.int32)
    write_locks = jnp.asarray(write_locks, jnp.int32)
    write_items = jnp.asarray(write_items, jnp.int32)
    b, r = read_items.shape
    n = store_versions.shape[0]
    chunk = min(chunk, n)
    pad_n = (-n) % chunk
    if pad_n:
        store_versions = jnp.pad(store_versions, (0, pad_n), constant_values=-2)
        write_locks = jnp.pad(write_locks, (0, pad_n))
    bt = min(block_txns, b)
    pad_b = (-b) % bt
    if pad_b:
        read_items = jnp.pad(read_items, ((0, pad_b), (0, 0)), constant_values=-1)
        read_versions = jnp.pad(read_versions, ((0, pad_b), (0, 0)))
        write_items = jnp.pad(write_items, ((0, pad_b), (0, 0)), constant_values=-1)
    nb = read_items.shape[0] // bt
    nc = store_versions.shape[0] // chunk

    kernel = functools.partial(_validate_kernel, n_chunks=nc, chunk=chunk)
    ok = pl.pallas_call(
        kernel,
        grid=(nb, nc),
        in_specs=[
            pl.BlockSpec((bt, r), lambda ib, ic: (ib, 0)),
            pl.BlockSpec((bt, r), lambda ib, ic: (ib, 0)),
            pl.BlockSpec((bt, write_items.shape[1]), lambda ib, ic: (ib, 0)),
            pl.BlockSpec((chunk,), lambda ib, ic: (ic,)),
            pl.BlockSpec((chunk,), lambda ib, ic: (ic,)),
        ],
        out_specs=pl.BlockSpec((bt,), lambda ib, ic: (ib,)),
        out_shape=jax.ShapeDtypeStruct((read_items.shape[0],), jnp.bool_),
        scratch_shapes=[_vmem((bt,), jnp.int32)],
        # lint: allow(host-sync): trace-time backend probe — picks the
        # interpret path off-TPU; retracing on backend change is intended
        interpret=interpret or (jax.default_backend() != "tpu"),
    )(read_items, read_versions, write_items, store_versions, write_locks)
    return ok[:b]


def _vmem(shape, dtype):
    from jax.experimental.pallas import tpu as pltpu

    return pltpu.VMEM(shape, dtype)
