"""Blocked online-softmax (flash) attention — Pallas TPU kernel.

Design (TPU-native, not a CUDA port):

* grid ``(B, Hq, nQ, nK)`` — the kv-block axis is the innermost (minor) grid
  dim, so VMEM scratch (running max ``m``, normalizer ``l``, accumulator
  ``acc``) persists across the kv sweep of one q block: the classic
  flash-attention recurrence expressed through TPU grid semantics rather
  than a thread-block loop.
* BlockSpec tiles q/k/v into VMEM at MXU-aligned shapes (multiples of 128
  on the contraction dims).
* masking is *position-based*: q/kv absolute positions ride in as tiny VMEM
  blocks, so the same kernel serves causal, sliding-window, bidirectional
  (encoder) and padded-cache attention; GQA is an index-map (kv head =
  q head // group) — no head replication in HBM.

``flash_attention`` (bottom) is the public wrapper: layout transposes,
padding to block multiples, and the pallas_call.  The pure-jnp oracle is
``repro.kernels.ref.sdpa_ref``.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)
VALID_POS_LIMIT = 2 ** 29          # kv positions >= this are padding


def _flash_kernel(
    qpos_ref, kpos_ref, q_ref, k_ref, v_ref,   # inputs
    o_ref,                                      # output
    m_scr, l_scr, acc_scr,                      # VMEM scratch
    *, scale: float, causal: bool, window: Optional[int],
    softcap: float, nk: int,
):
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)         # [Bq, Dk]
    k = k_ref[0, 0].astype(jnp.float32)         # [Bk, Dk]
    v = v_ref[0, 0].astype(jnp.float32)         # [Bk, Dv]
    qp = qpos_ref[0].astype(jnp.int32)          # [Bq]
    kp = kpos_ref[0].astype(jnp.int32)          # [Bk]

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32,
    ) * scale                                    # [Bq, Bk]
    if softcap > 0:
        s = softcap * jnp.tanh(s / softcap)

    mask = (kp < VALID_POS_LIMIT)[None, :]
    if causal:
        mask &= kp[None, :] <= qp[:, None]
    if window is not None:
        mask &= kp[None, :] > qp[:, None] - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1)
    acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32,
    )
    m_scr[...] = m_new

    @pl.when(ik == nk - 1)
    def _finish():
        l = l_scr[...]
        safe = jnp.where(l > 0, l, 1.0)
        o_ref[0, 0] = (acc_scr[...] / safe[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "sliding_window", "logit_softcap", "scale",
                     "block_q", "block_k", "interpret"),
)
def flash_attention(
    q: jax.Array,                 # [B, Sq, Hq, Dk]
    k: jax.Array,                 # [B, Skv, Hkv, Dk]
    v: jax.Array,                 # [B, Skv, Hkv, Dv]
    *,
    q_positions: jax.Array,       # [B, Sq]
    kv_positions: jax.Array,      # [B, Skv]
    causal: bool = True,
    sliding_window: Optional[int] = None,
    logit_softcap: float = 0.0,
    scale: Optional[float] = None,
    block_q: int = 256,
    block_k: int = 256,
    interpret: bool = False,
) -> jax.Array:
    b, sq, hq, dk = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    dv = v.shape[-1]
    group = hq // hkv
    scale = scale if scale is not None else dk ** -0.5

    bq = min(block_q, max(8, sq))
    bk = min(block_k, max(8, skv))
    pad_q = (-sq) % bq
    pad_k = (-skv) % bk

    # layout: [B, H, S, D]
    qt = jnp.moveaxis(q, 2, 1)
    kt = jnp.moveaxis(k, 2, 1)
    vt = jnp.moveaxis(v, 2, 1)
    qp, kp = q_positions.astype(jnp.int32), kv_positions.astype(jnp.int32)
    if pad_q:
        qt = jnp.pad(qt, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
        qp = jnp.pad(qp, ((0, 0), (0, pad_q)))
    if pad_k:
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        kp = jnp.pad(kp, ((0, 0), (0, pad_k)),
                     constant_values=2 ** 30)    # padding -> invalid
    nq = qt.shape[2] // bq
    nk = kt.shape[2] // bk

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=sliding_window,
        softcap=logit_softcap, nk=nk,
    )
    out = pl.pallas_call(
        kernel,
        grid=(b, hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq), lambda ib, ih, iq, ik: (ib, iq)),
            pl.BlockSpec((1, bk), lambda ib, ih, iq, ik: (ib, ik)),
            pl.BlockSpec((1, 1, bq, dk), lambda ib, ih, iq, ik: (ib, ih, iq, 0)),
            pl.BlockSpec((1, 1, bk, dk),
                         lambda ib, ih, iq, ik, g=group: (ib, ih // g, ik, 0)),
            pl.BlockSpec((1, 1, bk, dv),
                         lambda ib, ih, iq, ik, g=group: (ib, ih // g, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, dv), lambda ib, ih, iq, ik: (ib, ih, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, qt.shape[2], dv), q.dtype),
        scratch_shapes=[
            pl.MemorySpace.ANY if False else _vmem((bq,), jnp.float32),
            _vmem((bq,), jnp.float32),
            _vmem((bq, dv), jnp.float32),
        ],
        # lint: allow(host-sync): trace-time backend probe — picks the
        # interpret path off-TPU; retracing on backend change is intended
        interpret=interpret or (jax.default_backend() != "tpu"),
    )(qp, kp, qt, kt, vt)
    out = jnp.moveaxis(out, 1, 2)
    if pad_q:
        out = out[:, :sq]
    return out


def _vmem(shape, dtype):
    from jax.experimental.pallas import tpu as pltpu

    return pltpu.VMEM(shape, dtype)
