"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


# --- flash attention oracle --------------------------------------------------

def sdpa_ref(
    q, k, v, *, q_positions, kv_positions, causal=True, sliding_window=None,
    logit_softcap=0.0, scale=None,
):
    from repro.models.attention import _sdpa_ref, attn_mask

    scale = scale if scale is not None else q.shape[-1] ** -0.5
    mask = attn_mask(q_positions, kv_positions, causal, sliding_window)
    return _sdpa_ref(q, k, v, mask, scale, logit_softcap)


# --- SSD oracle ---------------------------------------------------------------

def ssd_ref(
    x, dt, a, b_mat, c_mat, *, chunk=256, h0=None,
) -> Tuple[jax.Array, jax.Array]:
    from repro.models.ssm import ssd_chunked

    return ssd_chunked(x, dt, a, b_mat, c_mat, chunk, h0=h0,
                       return_final_state=True)


# --- lease-validate oracle -----------------------------------------------------

def lease_validate_ref(
    store_versions: jax.Array,   # [n_items] int32
    read_items: jax.Array,       # [B, R] int32, -1 padded
    read_versions: jax.Array,    # [B, R] int32
    write_locks: Optional[jax.Array] = None,   # [n_items] bool
    write_items: Optional[jax.Array] = None,   # [B, W] int32, -1 padded
) -> jax.Array:
    """TL2 certification: read versions unchanged AND write set unlocked."""
    n = store_versions.shape[0]
    valid = read_items >= 0
    cur = store_versions[jnp.clip(read_items, 0, n - 1)]
    ok = jnp.all(jnp.where(valid, cur == read_versions, True), axis=1)
    if write_locks is not None and write_items is not None:
        wvalid = write_items >= 0
        locked = write_locks[jnp.clip(write_items, 0, n - 1)]
        ok &= jnp.all(jnp.where(wvalid, ~locked, True), axis=1)
    return ok
