"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


# --- flash attention oracle --------------------------------------------------

def sdpa_ref(
    q, k, v, *, q_positions, kv_positions, causal=True, sliding_window=None,
    logit_softcap=0.0, scale=None,
):
    from repro.models.attention import _sdpa_ref, attn_mask

    scale = scale if scale is not None else q.shape[-1] ** -0.5
    mask = attn_mask(q_positions, kv_positions, causal, sliding_window)
    return _sdpa_ref(q, k, v, mask, scale, logit_softcap)


# --- SSD oracle ---------------------------------------------------------------

def ssd_ref(
    x, dt, a, b_mat, c_mat, *, chunk=256, h0=None,
) -> Tuple[jax.Array, jax.Array]:
    from repro.models.ssm import ssd_chunked

    return ssd_chunked(x, dt, a, b_mat, c_mat, chunk, h0=h0,
                       return_final_state=True)


# --- lease-settle oracle -------------------------------------------------------

def lease_settle_ref(
    head_req: jax.Array,      # [C] int32, -1 when the queue is empty
    head_proc: jax.Array,     # [C] int32
    head_active: jax.Array,   # [C] int32
    qlen: jax.Array,          # [C] int32
    fresh_blocked: jax.Array,  # [C] bool: head newly blocked this instant
    wait_req: jax.Array,      # [B, K] int32, -1 padded (waiting groups)
    wait_cc: jax.Array,       # [B, K] int32, -1 padded
    proc,                     # scalar int32: the settling replica
):
    """One lease-settle over a replica's packed conflict-queue heads.

    Algorithm 1's three per-instant queries as gather/compare math:

    * ``owner[c]``   — head ownership L(i, x) (-1: unowned);
    * ``free[c]``    — the blocked-and-drained rule: a head that is ours,
      was *newly* blocked at this instant (``fresh_blocked``), and has no
      active transactions must be freed now (already-blocked dormant heads
      were freed when they first blocked — re-freeing them would dequeue
      twice);
    * ``enabled[b]`` — ``isEnabled``: every LOR of waiting group ``b``
      heads its queue (matched by req_id, which is unique per queue).
    """
    c = head_req.shape[0]
    occupied = qlen > 0
    owner = jnp.where(occupied, head_proc, -1).astype(jnp.int32)
    free = occupied & fresh_blocked & (head_proc == proc) & (head_active == 0)
    valid = wait_cc >= 0
    cc = jnp.clip(wait_cc, 0, c - 1)
    at_head = occupied[cc] & (head_req[cc] == wait_req)
    enabled = jnp.all(jnp.where(valid, at_head, True), axis=1)
    return owner, free, enabled


# --- MoE combine oracle --------------------------------------------------------

def moe_combine_ref(
    back: jax.Array,          # [ep * tp * capacity, d] returned partials
    tok_slot: jax.Array,      # [ep * capacity] int32, t_out when empty
    gate_slot: jax.Array,     # [ep * capacity] f32, 0 when empty
    *,
    tp: int,
    capacity: int,
    t_out: int,
) -> jax.Array:
    """Combine leg of the tp-aware MoE a2a: the partial-activation psum.

    Each expert-group slot came back as ``tp`` f-slice partials (one per
    chunk rank, contiguous blocks of ``capacity`` rows per rank); gate each
    partial, sum over the tp blocks, and scatter the rows to their owning
    token rows.  Gating *before* the sum mirrors the replicated path's
    ``(h @ wd) * gate`` → psum association (``repro.models.moe._moe_local``)
    so the two paths agree to the same float-order; at ``tp == 1`` this
    degenerates to the plain gated scatter of the whole-expert path.
    """
    d = back.shape[-1]
    gate = gate_slot.reshape(-1, 1, capacity, 1).astype(back.dtype)
    gated = (back.reshape(-1, tp, capacity, d) * gate).sum(axis=1)
    return jnp.zeros((t_out, d), back.dtype).at[tok_slot].add(
        gated.reshape(-1, d), mode="drop")


# --- lease-validate oracle -----------------------------------------------------

def lease_validate_ref(
    store_versions: jax.Array,   # [n_items] int32
    read_items: jax.Array,       # [B, R] int32, -1 padded
    read_versions: jax.Array,    # [B, R] int32
    write_locks: Optional[jax.Array] = None,   # [n_items] bool
    write_items: Optional[jax.Array] = None,   # [B, W] int32, -1 padded
) -> jax.Array:
    """TL2 certification: read versions unchanged AND write set unlocked."""
    n = store_versions.shape[0]
    valid = read_items >= 0
    cur = store_versions[jnp.clip(read_items, 0, n - 1)]
    ok = jnp.all(jnp.where(valid, cur == read_versions, True), axis=1)
    if write_locks is not None and write_items is not None:
        wvalid = write_items >= 0
        locked = write_locks[jnp.clip(write_items, 0, n - 1)]
        ok &= jnp.all(jnp.where(wvalid, ~locked, True), axis=1)
    return ok
