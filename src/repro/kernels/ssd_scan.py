"""Mamba2 SSD chunked scan — Pallas TPU kernel.

Grid ``(B, nHeadBlocks, nChunks)`` with the chunk axis innermost: the
inter-chunk recurrent state (``[Hb, P, N]`` fp32) lives in VMEM scratch and
persists across the chunk sweep — the sequential recurrence is expressed
through TPU grid semantics, while each chunk's quadratic intra-chunk term
is MXU work on VMEM tiles.  Head-blocking keeps the [Hb, L, L] decay
matrices inside VMEM.

Restriction: ``n_groups == 1`` (true for every assigned SSM arch); the
general grouped case falls back to the jnp oracle
(:func:`repro.kernels.ref.ssd_ref`).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ssd_kernel(
    x_ref, dt_ref, a_ref, b_ref, c_ref, h0_ref,     # inputs
    y_ref, hout_ref,                                 # outputs
    state_scr,                                       # VMEM scratch [Hb, P, N]
    *, nc: int,
):
    inc = pl.program_id(2)

    @pl.when(inc == 0)
    def _init():
        state_scr[...] = h0_ref[0].astype(jnp.float32)

    x = x_ref[0].astype(jnp.float32)          # [L, Hb, P]
    dt = dt_ref[0].astype(jnp.float32)        # [L, Hb]
    a = a_ref[...].astype(jnp.float32)        # [Hb]
    bm = b_ref[0, :, 0, :].astype(jnp.float32)   # [L, N]   (G == 1)
    cm = c_ref[0, :, 0, :].astype(jnp.float32)   # [L, N]

    l = x.shape[0]
    da = dt * a[None, :]                      # [L, Hb] log-decay per step
    dacum = jnp.cumsum(da, axis=0)            # [L, Hb]

    # --- intra-chunk quadratic term -------------------------------------
    # seg[h, i, j] = dacum[i,h] - dacum[j,h]  (i >= j)
    seg = dacum.T[:, :, None] - dacum.T[:, None, :]          # [Hb, L, L]
    tri = jnp.tril(jnp.ones((l, l), jnp.float32))
    decay = jnp.exp(jnp.where(tri > 0, seg, -jnp.inf)) * tri  # [Hb, L, L]
    cb = jax.lax.dot_general(                                 # [L, L]
        cm, bm, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32,
    )
    w = cb[None, :, :] * decay * dt.T[:, None, :]             # [Hb, L(i), L(j)]
    y_diag = jnp.einsum("hij,jhp->ihp", w, x)                 # [L, Hb, P]

    # --- contribution of the carried state --------------------------------
    state = state_scr[...]                                     # [Hb, P, N]
    y_off = jnp.einsum("ln,hpn,lh->lhp", cm, state, jnp.exp(dacum))

    # --- state update -------------------------------------------------------
    tail = jnp.exp(dacum[-1:, :] - dacum)                      # [L, Hb]
    upd = jnp.einsum("ln,lh,lhp->hpn", bm, tail * dt, x)
    state_scr[...] = state * jnp.exp(dacum[-1, :])[:, None, None] + upd

    y_ref[0] = (y_diag + y_off).astype(y_ref.dtype)

    @pl.when(inc == nc - 1)
    def _finish():
        hout_ref[0] = state_scr[...]


@functools.partial(
    jax.jit, static_argnames=("chunk", "block_heads", "interpret"),
)
def ssd_scan(
    x: jax.Array,       # [B, S, H, P]
    dt: jax.Array,      # [B, S, H]  (softplus'd)
    a: jax.Array,       # [H]
    b_mat: jax.Array,   # [B, S, 1, N]
    c_mat: jax.Array,   # [B, S, 1, N]
    *,
    chunk: int = 256,
    h0: Optional[jax.Array] = None,     # [B, H, P, N]
    block_heads: int = 8,
    interpret: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    """Returns (y [B, S, H, P], final_state [B, H, P, N] fp32)."""
    bsz, s, h, p = x.shape
    g, n = b_mat.shape[2], b_mat.shape[3]
    if g != 1:
        from . import ref

        return ref.ssd_ref(x, dt, a, b_mat, c_mat, chunk=chunk, h0=h0)
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    hb = min(block_heads, h)
    while h % hb:
        hb -= 1
    nh = h // hb
    if h0 is None:
        h0 = jnp.zeros((bsz, h, p, n), jnp.float32)

    kernel = functools.partial(_ssd_kernel, nc=nc)
    y, hout = pl.pallas_call(
        kernel,
        grid=(bsz, nh, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, hb, p), lambda ib, ih, ic: (ib, ic, ih, 0)),
            pl.BlockSpec((1, chunk, hb), lambda ib, ih, ic: (ib, ic, ih)),
            pl.BlockSpec((hb,), lambda ib, ih, ic: (ih,)),
            pl.BlockSpec((1, chunk, 1, n), lambda ib, ih, ic: (ib, ic, 0, 0)),
            pl.BlockSpec((1, chunk, 1, n), lambda ib, ih, ic: (ib, ic, 0, 0)),
            pl.BlockSpec((1, hb, p, n), lambda ib, ih, ic: (ib, ih, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, hb, p), lambda ib, ih, ic: (ib, ic, ih, 0)),
            pl.BlockSpec((1, hb, p, n), lambda ib, ih, ic: (ib, ih, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, s, h, p), x.dtype),
            jax.ShapeDtypeStruct((bsz, h, p, n), jnp.float32),
        ],
        scratch_shapes=[_vmem((hb, p, n), jnp.float32)],
        # lint: allow(host-sync): trace-time backend probe — picks the
        # interpret path off-TPU; retracing on backend change is intended
        interpret=interpret or (jax.default_backend() != "tpu"),
    )(x, dt, a, b_mat, c_mat, h0)
    return y, hout


def _vmem(shape, dtype):
    from jax.experimental.pallas import tpu as pltpu

    return pltpu.VMEM(shape, dtype)
