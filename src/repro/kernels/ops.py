"""Jit'd public wrappers over the Pallas kernels with automatic fallback.

``backend="auto"`` uses the Pallas kernel on TPU and the pure-jnp oracle
elsewhere (kernels still run under ``interpret=True`` in the test-suite
shape sweeps).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from . import ref
from .flash_attention import flash_attention as _flash
from .lease_validate import lease_validate as _lease_validate
from .ssd_scan import ssd_scan as _ssd


def _use_pallas(backend: str) -> bool:
    if backend == "auto":
        return jax.default_backend() == "tpu"
    return backend == "pallas"


def attention(q, k, v, *, q_positions, kv_positions, causal=True,
              sliding_window=None, logit_softcap=0.0, scale=None,
              backend: str = "auto"):
    if _use_pallas(backend):
        return _flash(q, k, v, q_positions=q_positions,
                      kv_positions=kv_positions, causal=causal,
                      sliding_window=sliding_window,
                      logit_softcap=logit_softcap, scale=scale)
    return ref.sdpa_ref(q, k, v, q_positions=q_positions,
                        kv_positions=kv_positions, causal=causal,
                        sliding_window=sliding_window,
                        logit_softcap=logit_softcap, scale=scale)


def ssd(x, dt, a, b_mat, c_mat, *, chunk=256, h0=None, backend: str = "auto"):
    if _use_pallas(backend) and b_mat.shape[2] == 1:
        return _ssd(x, dt, a, b_mat, c_mat, chunk=chunk, h0=h0)
    return ref.ssd_ref(x, dt, a, b_mat, c_mat, chunk=chunk, h0=h0)


@jax.jit
def _lease_settle_jit(head_req, head_proc, head_active, qlen, fresh_blocked,
                      wait_req, wait_cc, proc):
    return ref.lease_settle_ref(head_req, head_proc, head_active, qlen,
                                fresh_blocked, wait_req, wait_cc, proc)


def settle_lease_batch(head_req, head_proc, head_active, qlen, fresh_blocked,
                       wait_req, wait_cc, proc, *, backend: str = "auto"):
    """One jit'd lease settle per delivery instant — the dispatch point of
    the sharded lease control plane (``repro.core.lease_batched``).

    Returns ``(owner[C], free[C], enabled[B])``: head ownership,
    blocked-and-drained frees, and ``isEnabled`` verdicts for the packed
    waiting groups.  All inputs are pow2-bucketed by the caller so
    recurring instant shapes reuse the compiled kernel; there is no
    hand-written Pallas variant yet — the jit'd jnp path is the dispatch
    on every backend (same structure as ``validate_transactions``'s ref
    path, and the hook point for a TPU kernel later).
    """
    del backend  # single jit'd path for now; kept for API symmetry
    return _lease_settle_jit(
        jnp.asarray(head_req, jnp.int32), jnp.asarray(head_proc, jnp.int32),
        jnp.asarray(head_active, jnp.int32), jnp.asarray(qlen, jnp.int32),
        jnp.asarray(fresh_blocked, bool), jnp.asarray(wait_req, jnp.int32),
        jnp.asarray(wait_cc, jnp.int32), jnp.int32(proc))


def moe_combine(back, tok_slot, gate_slot, *, tp: int, capacity: int,
                t_out: int, backend: str = "auto"):
    """Partial-activation psum + gated scatter closing the MoE a2a combine
    leg (``repro.models.moe._moe_local_a2a``): sums the ``tp`` f-slice
    partials per expert-group slot, then scatters gated rows to tokens.
    Runs inside ``shard_map``, so it must stay traceable — no jit wrapper
    of its own; the jnp oracle is the dispatch on every backend (hook
    point for a fused Pallas scatter later).
    """
    del backend  # single path for now; kept for API symmetry
    return ref.moe_combine_ref(back, tok_slot, gate_slot, tp=tp,
                               capacity=capacity, t_out=t_out)


@jax.jit
def _lease_validate_ref_jit(store_versions, read_items, read_versions,
                            write_locks, write_items):
    return ref.lease_validate_ref(store_versions, read_items, read_versions,
                                  write_locks > 0, write_items)


def validate_transactions(
    store_versions, read_items, read_versions,
    write_locks=None, write_items=None, *, backend: str = "auto",
):
    """Batched TL2 certification — the single dispatch point both the
    simulator (``repro.core.stm.validate_batch``) and the serving certifier
    (``repro.serve.certifier``) go through.  Write locks default to none
    (all zeros); both backends honor them identically.
    """
    b = read_items.shape[0]
    store_versions = jnp.asarray(store_versions, jnp.int32)
    if write_locks is None:
        write_locks = jnp.zeros_like(store_versions)
    else:
        write_locks = jnp.asarray(write_locks, jnp.int32)
    if write_items is None:
        write_items = jnp.full((b, 1), -1, jnp.int32)
    if _use_pallas(backend):
        return _lease_validate(store_versions, read_items, read_versions,
                               write_locks, write_items)
    return _lease_validate_ref_jit(store_versions, read_items, read_versions,
                                   write_locks, write_items)
