"""HuBERT X-Large [arXiv:2106.07447].

48L d_model=1280 16H d_ff=5120, 504 masked-prediction classes
(encoder-only, bidirectional; same block as wav2vec2).  The CNN waveform
frontend is a stub — ``input_specs`` feeds precomputed frame embeddings.

Adaptation note (DESIGN.md): the conv positional embedding is replaced by
bidirectional RoPE, which preserves relative-position behaviour and is the
TPU-idiomatic choice.
"""
import dataclasses

from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge",
    family="audio",
    n_layers=48,
    d_model=1280,
    n_heads=16,
    n_kv_heads=16,
    head_dim=80,
    d_ff=5120,
    vocab_size=504,
    mlp_act="gelu",
    causal=False,
    rope_theta=1e4,
    max_seq_len=32768,
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, vocab_size=64, max_seq_len=512,
    )
