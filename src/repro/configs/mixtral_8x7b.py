"""Mixtral-8x7B [arXiv:2401.04088; hf].

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=32000; 8 experts top-2
(renormalized gates), sliding-window attention (4096).
"""
import dataclasses

from repro.models.common import MoEConfig, ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b",
    family="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=32000,
    mlp_act="swiglu",
    rope_theta=1e6,
    sliding_window=4096,
    moe=MoEConfig(n_experts=8, top_k=2, d_expert=14336),
    max_seq_len=131072,
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=256, sliding_window=64,
        moe=MoEConfig(n_experts=4, top_k=2, d_expert=128),
        max_seq_len=512,
    )
