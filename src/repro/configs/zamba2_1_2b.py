"""Zamba2-1.2B [arXiv:2411.15242; hf].

38L d_model=2048: Mamba2 backbone (ssm_state=64) with a *shared* global
attention block (32H) every 6 layers (shared weights, per-site KV cache),
d_ff=8192 on the attention sites, vocab=32000.
"""
import dataclasses

from repro.models.common import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    n_layers=38,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab_size=32000,
    mlp_act="swiglu",
    rope_theta=1e4,
    ssm=SSMConfig(d_state=64, d_conv=4, expand=2, head_dim=64, n_groups=1,
                  chunk=256),
    hybrid_attn_every=6,
    max_seq_len=1048576,
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=8, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, vocab_size=256, hybrid_attn_every=3,
        ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=16, n_groups=1,
                      chunk=32),
        max_seq_len=512,
    )
