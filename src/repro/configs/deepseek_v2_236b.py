"""DeepSeek-V2 (236B, 21B active) [arXiv:2405.04434; hf].

60L d_model=5120 128H, MLA (kv_lora=512, q_lora=1536, rope split 128+64),
d_ff(expert)=1536, vocab=102400; MoE: 2 shared + 160 routed experts top-6,
first layer dense (d_ff 12288), routed scaling 16.
"""
import dataclasses

from repro.models.common import MLAConfig, MoEConfig, ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b",
    family="moe",
    n_layers=60,
    d_model=5120,
    n_heads=128,
    n_kv_heads=128,
    head_dim=128,
    d_ff=1536,
    vocab_size=102400,
    mlp_act="swiglu",
    rope_theta=1e4,
    mla=MLAConfig(
        q_lora_rank=1536,
        kv_lora_rank=512,
        qk_nope_head_dim=128,
        qk_rope_head_dim=64,
        v_head_dim=128,
    ),
    moe=MoEConfig(
        n_experts=160,
        top_k=6,
        d_expert=1536,
        n_shared=2,
        d_shared=1536,
        first_dense_layers=1,
        d_first_dense=12288,
        router_scale=16.0,
    ),
    max_seq_len=131072,
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=3, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=32, vocab_size=256,
        mla=MLAConfig(q_lora_rank=32, kv_lora_rank=16, qk_nope_head_dim=16,
                      qk_rope_head_dim=8, v_head_dim=16),
        moe=MoEConfig(n_experts=8, top_k=2, d_expert=32, n_shared=1,
                      d_shared=32, first_dense_layers=1, d_first_dense=64,
                      router_scale=4.0),
        max_seq_len=512,
    )
