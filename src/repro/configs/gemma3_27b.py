"""Gemma-3-27B [hf:google/gemma-3-*-pt].

62L d_model=5376 32H (GQA kv=16) d_ff=21504 vocab=262144; 5:1
local(sliding-window 1024):global attention, dual RoPE theta (10k local /
1M global), gemma-style (1+w) RMSNorm with pre+post block norms, QK-norm,
128k context.
"""
import dataclasses

from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-27b",
    family="dense",
    n_layers=62,
    d_model=5376,
    n_heads=32,
    n_kv_heads=16,
    head_dim=128,
    d_ff=21504,
    vocab_size=262144,
    mlp_act="geglu",
    gemma_norm=True,
    use_qk_norm=True,
    tie_embeddings=True,
    rope_theta=1e4,
    rope_theta_global=1e6,
    sliding_window=1024,
    global_every=6,
    max_seq_len=131072,
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=8, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=256, sliding_window=32, global_every=6,
        max_seq_len=512,
    )
