"""Minitron-4B (pruned Nemotron) [arXiv:2407.14679; hf].

32L d_model=3072 24H (GQA kv=8) d_ff=9216 vocab=256000; squared-ReLU MLP
(Nemotron family), partial rotary 0.5.
"""
import dataclasses

from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="minitron-4b",
    family="dense",
    n_layers=32,
    d_model=3072,
    n_heads=24,
    n_kv_heads=8,
    head_dim=128,
    d_ff=9216,
    vocab_size=256000,
    mlp_act="relu2",
    rope_theta=1e4,
    partial_rotary=0.5,
    max_seq_len=4096,
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=256, max_seq_len=512,
    )
