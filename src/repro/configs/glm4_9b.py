"""GLM-4-9B [hf:THUDM/glm-4-9b].

40L d_model=4096 32H (GQA kv=2) d_ff=13696 vocab=151552; partial rotary
(GLM applies RoPE to half the head dim), SwiGLU.
"""
import dataclasses

from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="glm4-9b",
    family="dense",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=2,
    head_dim=128,
    d_ff=13696,
    vocab_size=151552,
    mlp_act="swiglu",
    rope_theta=1e4,
    partial_rotary=0.5,
    max_seq_len=131072,
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=192, vocab_size=256, max_seq_len=512,
    )
