"""Qwen2-VL-2B backbone [arXiv:2409.12191; hf].

28L d_model=1536 12H (GQA kv=2) d_ff=8960 vocab=151936.  M-RoPE with
(temporal, height, width) frequency sections (16, 24, 24); the vision
frontend is a stub — ``input_specs`` feeds precomputed patch embeddings.
"""
import dataclasses

from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-2b",
    family="vlm",
    n_layers=28,
    d_model=1536,
    n_heads=12,
    n_kv_heads=2,
    head_dim=128,
    d_ff=8960,
    vocab_size=151936,
    mlp_act="swiglu",
    rope_theta=1e6,
    mrope_sections=(16, 24, 24),
    tie_embeddings=True,
    max_seq_len=32768,
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=256, mrope_sections=(2, 3, 3), max_seq_len=512,
    )
