"""Assigned-architecture registry: ``--arch <id>`` lookup.

Each module defines ``CONFIG`` (the exact published configuration) and
``smoke_config()`` (a reduced same-family config for CPU smoke tests).
"""
from __future__ import annotations

import importlib
from typing import Dict

from repro.models.common import ModelConfig

_MODULES = {
    "qwen2-vl-2b": "qwen2_vl_2b",
    "glm4-9b": "glm4_9b",
    "phi4-mini-3.8b": "phi4_mini_3_8b",
    "minitron-4b": "minitron_4b",
    "gemma3-27b": "gemma3_27b",
    "deepseek-v2-236b": "deepseek_v2_236b",
    "mixtral-8x7b": "mixtral_8x7b",
    "hubert-xlarge": "hubert_xlarge",
    "mamba2-780m": "mamba2_780m",
    "zamba2-1.2b": "zamba2_1_2b",
}

ARCH_IDS = tuple(_MODULES)


def get_config(arch: str) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_MODULES)}")
    return importlib.import_module(f"repro.configs.{_MODULES[arch]}").CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    return importlib.import_module(f"repro.configs.{_MODULES[arch]}").smoke_config()


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS}
