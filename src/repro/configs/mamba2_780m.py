"""Mamba2-780m [arXiv:2405.21060].

48L d_model=1536, attention-free SSD blocks, ssm_state=128, vocab=50280;
expand=2 (d_inner 3072), head_dim 64 (48 SSD heads), chunked scan.
"""
import dataclasses

from repro.models.common import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-780m",
    family="ssm",
    n_layers=48,
    d_model=1536,
    n_heads=0,
    n_kv_heads=0,
    head_dim=0,
    d_ff=0,
    vocab_size=50280,
    tie_embeddings=True,
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64, n_groups=1,
                  chunk=256),
    max_seq_len=1048576,
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, vocab_size=256,
        ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=16, n_groups=1,
                      chunk=32),
        max_seq_len=512,
    )
