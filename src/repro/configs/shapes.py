"""The assigned input-shape grid and ShapeDtypeStruct input specs.

Four shapes per LM arch (40 cells):

* ``train_4k``     seq 4096  × global_batch 256   -> train_step
* ``prefill_32k``  seq 32768 × global_batch 32    -> prefill (serve)
* ``decode_32k``   KV 32768  × global_batch 128   -> serve_step (1 new token)
* ``long_500k``    KV 524288 × global_batch 1     -> serve_step, sub-quadratic
                   archs only

Skips (documented in DESIGN.md §Shape-grid):
* encoder-only (hubert) has no autoregressive step -> decode/long are SKIP;
* pure full-attention archs skip ``long_500k`` (no sub-quadratic mechanism).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                     # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}

# archs with a sub-quadratic long-context mechanism (may run long_500k)
SUBQUADRATIC = frozenset({
    "mamba2-780m",        # constant-state SSM
    "zamba2-1.2b",        # hybrid (mamba body, periodic attn)
    "gemma3-27b",         # 5:1 sliding-window:global
    "mixtral-8x7b",       # SWA 4096 bounds the window
    "deepseek-v2-236b",   # MLA latent cache (576/token/layer)
})


def skip_reason(arch: str, cfg: ModelConfig, shape: str) -> Optional[str]:
    """None if the (arch, shape) cell runs; otherwise the documented reason."""
    spec = SHAPES[shape]
    if not cfg.causal and spec.kind == "decode":
        return "encoder-only: no autoregressive decode step"
    if shape == "long_500k" and arch not in SUBQUADRATIC:
        return "pure full attention: unbounded full KV at 500k (no sub-quadratic mechanism)"
    return None


def grid_cells():
    """All 40 (arch, shape) cells in deterministic order."""
    from repro.configs import ARCH_IDS

    return [(a, s) for a in ARCH_IDS for s in SHAPES]


# ---------------------------------------------------------------------------
# Input specs (ShapeDtypeStruct stand-ins; no allocation)
# ---------------------------------------------------------------------------

def _pos_struct(cfg: ModelConfig, b: int, s: int):
    if cfg.mrope_sections is not None:
        return jax.ShapeDtypeStruct((3, b, s), jnp.int32)
    return jax.ShapeDtypeStruct((b, s), jnp.int32)


def input_specs(cfg: ModelConfig, shape: str,
                batch_override: Optional[int] = None) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStructs for every model input of a (config, shape) cell.

    ``train``/``prefill``: token (or stub-frontend embedding) batch;
    ``decode``: one new token per sequence (the KV cache is a separate
    argument supplied by the caller via ``decoder.init_cache`` eval_shape).
    """
    spec = SHAPES[shape]
    b = batch_override or spec.global_batch
    s = spec.seq_len
    stub_frontend = cfg.family in ("vlm", "audio")
    out: Dict[str, jax.ShapeDtypeStruct] = {}
    if spec.kind in ("train", "prefill"):
        if stub_frontend:
            out["embeds"] = jax.ShapeDtypeStruct((b, s, cfg.d_model), jnp.bfloat16)
        else:
            out["tokens"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
        out["positions"] = _pos_struct(cfg, b, s)
        if spec.kind == "train":
            out["labels"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
    else:  # decode: one token step against a seq_len-deep cache
        out["tokens"] = jax.ShapeDtypeStruct((b,), jnp.int32)
        out["pos"] = jax.ShapeDtypeStruct((), jnp.int32)
    return out
