"""Deterministic synthetic LM data pipeline.

Properties a 1000-node deployment needs, reproduced at laptop scale:

* **step-addressable determinism**: batch(step) is a pure function of
  (seed, step, host), so any host can reproduce any step — this is what
  makes checkpoint-restart and elastic re-sharding exact (no data loss or
  duplication on restart);
* **per-host slicing**: each host materializes only its shard of the
  global batch (``host_id``/``n_hosts``);
* **skip-ahead**: stragglers (or a restart) jump to an arbitrary step in
  O(1) — no sequential scan through the stream.

The token stream itself is a seeded Zipf-ish mixture with local n-gram
structure (so losses move during the example runs, unlike uniform noise).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_hosts: int = 1
    host_id: int = 0
    stub_frontend: bool = False          # vlm/audio: emit embeddings
    d_model: int = 0
    mrope: bool = False

    @property
    def host_batch(self) -> int:
        assert self.global_batch % self.n_hosts == 0
        return self.global_batch // self.n_hosts


class SyntheticLM:
    """batch(step) -> dict of numpy arrays for this host's slice."""

    def __init__(self, cfg: DataConfig) -> None:
        self.cfg = cfg
        base = np.random.default_rng(cfg.seed)
        v = cfg.vocab_size
        # a fixed bigram transition table gives the stream learnable structure
        self._hot = base.integers(0, v, size=(min(v, 4096),), dtype=np.int64)

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng(
            (cfg.seed * 1_000_003 + step) * 1009 + cfg.host_id
        )
        b, s, v = cfg.host_batch, cfg.seq_len, cfg.vocab_size
        # Zipf-flavored unigram + deterministic bigram continuation
        z = rng.zipf(1.3, size=(b, s)).astype(np.int64)
        toks = np.minimum(z - 1, v - 1)
        follow = rng.random((b, s)) < 0.5
        prev = np.roll(toks, 1, axis=1)
        toks = np.where(follow, self._hot[prev % len(self._hot)] % v, toks)
        toks[:, 0] = rng.integers(0, v, size=b)
        labels = np.roll(toks, -1, axis=1)
        labels[:, -1] = -1                       # no target for the last token
        out: Dict[str, np.ndarray] = {
            "tokens": toks.astype(np.int32),
            "labels": labels.astype(np.int32),
        }
        if cfg.stub_frontend:
            emb = rng.standard_normal((b, s, cfg.d_model)).astype(np.float32)
            out = {"embeds": emb, "labels": labels.astype(np.int32)}
        if cfg.mrope:
            pos = np.broadcast_to(np.arange(s, dtype=np.int32)[None], (b, s))
            out["positions"] = np.broadcast_to(pos[None], (3, b, s)).copy()
        return out


def make_iterator(
    cfg: DataConfig, start_step: int = 0
) -> Iterator[Dict[str, np.ndarray]]:
    """Resumable iterator; ``start_step`` implements restart/skip-ahead."""
    ds = SyntheticLM(cfg)
    step = start_step
    while True:
        yield ds.batch(step)
        step += 1
