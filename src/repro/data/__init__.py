"""Data pipeline: deterministic synthetic shards with per-host slicing."""
from .pipeline import DataConfig, SyntheticLM, make_iterator
