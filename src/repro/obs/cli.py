"""``repro-trace``: export / summarize / diff repro.obs timelines.

- ``export``  — run the seeded serve_locality smoke scenario with tracing
  on (engine routing, lease acquires, certify batches, decode spans,
  planner epochs) plus one tiny MoE forward (the jit-trace-time dispatch
  verdict), and write the combined Perfetto ``trace_event`` JSON.
- ``summarize`` — per-event-name counts and duration quantiles of an
  exported trace.
- ``diff``    — per-name count/total-duration deltas between two traces
  (the regression view: sim-time stamps make this signal, not noise).

Load exported files at https://ui.perfetto.dev or ``chrome://tracing``.
"""
from __future__ import annotations

import argparse
import sys
from typing import List, Optional

from repro.obs import trace as obs_trace


# --------------------------------------------------------------------------
# export: the seeded smoke scenario, traced
# --------------------------------------------------------------------------

def _run_serve_smoke(rec, *, arch: str, pods: int, sessions: int,
                     steps: int, locality: float, seed: int,
                     plan_epoch_ms: float) -> dict:
    """The serve_locality smoke loop with the recorder threaded through."""
    import numpy as np

    from repro.configs import get_config
    from repro.plan import PlacementPlanner
    from repro.serve.engine import MultiPodEngine, Request, SimBackend
    from repro.serve.router import LocalityRouter

    cfg = get_config(arch)
    kv_per_tok = 2.0 * 2 * cfg.n_kv_heads * cfg.head_dim * cfg.n_layers \
        if cfg.n_kv_heads else 4096.0 * cfg.n_layers
    router = LocalityRouter(pods, policy="short", arbitration="priced",
                            kv_bytes_per_token=kv_per_tok)
    planner = PlacementPlanner.for_serving(pods, sessions,
                                           epoch_ms=plan_epoch_ms)
    eng = MultiPodEngine(pods, SimBackend(cfg), router, planner=planner,
                         trace=rec)
    rng = np.random.default_rng(seed)
    for _ in range(steps):
        for _ in range(2 * pods):
            sid = int(rng.integers(sessions))
            home = sid % pods
            origin = home if rng.random() < locality \
                else int(rng.integers(pods))
            eng.submit(Request(sid=sid, origin=origin, n_tokens=4))
        eng.run_step()
    eng.drain()
    return eng.metrics.as_dict()


def _run_moe_smoke(arch: str, seed: int) -> None:
    """One tiny MoE forward so the jit-trace-time dispatch span fires.

    Params are hand-built in the chunked n_chunks=1 layout (the
    tests/test_sharded.py pattern) — no decoder init, runs on one host
    device in well under a second.
    """
    import dataclasses

    import jax.numpy as jnp
    import numpy as np

    from repro.configs import get_smoke_config
    from repro.models import moe

    cfg = dataclasses.replace(get_smoke_config(arch), dtype="float32")
    m = cfg.moe
    rng = np.random.default_rng(seed)
    d, f = cfg.d_model, m.d_expert
    p = {
        "router": jnp.asarray(
            rng.standard_normal((d, m.n_experts)) * 0.1, jnp.float32),
        "experts": {
            "w_gate": jnp.asarray(
                rng.standard_normal((1, m.n_experts, d, f)) * 0.05,
                jnp.float32),
            "w_up": jnp.asarray(
                rng.standard_normal((1, m.n_experts, d, f)) * 0.05,
                jnp.float32),
            "w_down": jnp.asarray(
                rng.standard_normal((1, m.n_experts, f, d)) * 0.05,
                jnp.float32),
        },
    }
    x = jnp.asarray(rng.standard_normal((1, 4, d)), jnp.float32)
    moe.moe_apply(p, x, cfg, mesh=None)


def _cmd_export(argv: List[str]) -> int:
    ap = argparse.ArgumentParser(
        prog="repro-trace export",
        description="Run the seeded serve_locality smoke with tracing on "
                    "and export a Perfetto trace.")
    ap.add_argument("--out", default="trace.json")
    ap.add_argument("--arch", default="mixtral-8x7b")
    ap.add_argument("--pods", type=int, default=2)
    ap.add_argument("--sessions", type=int, default=8)
    ap.add_argument("--steps", type=int, default=10)
    ap.add_argument("--locality", type=float, default=0.5)
    ap.add_argument("--plan-epoch-ms", type=float, default=0.01)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--no-moe", action="store_true",
                    help="skip the MoE forward (saves the jax import; the "
                         "trace then has no moe-dispatch span)")
    ns = ap.parse_args(argv)

    rec = obs_trace.TraceRecorder()
    # module-wide install so siteless emitters (models/moe.py) land in the
    # same timeline as the engine's threaded recorder
    obs_trace.install(rec)
    try:
        m = _run_serve_smoke(rec, arch=ns.arch, pods=ns.pods,
                             sessions=ns.sessions, steps=ns.steps,
                             locality=ns.locality, seed=ns.seed,
                             plan_epoch_ms=ns.plan_epoch_ms)
        if not ns.no_moe:
            _run_moe_smoke(ns.arch, ns.seed)
    finally:
        obs_trace.uninstall()
    rec.export(ns.out)
    print(f"{len(rec)} events -> {ns.out}")
    print(f"tokens={m['tokens']} forwards={m['forwards']} "
          f"token_lat_p50={m['token_lat_p50_s']:.4g}s "
          f"p99={m['token_lat_p99_s']:.4g}s")
    for row in obs_trace.summarize(obs_trace.load(ns.out)):
        print(f"  {row['name']:<18} n={row['count']:<6} "
              f"total={row['total_us']:.1f}us")
    return 0


# --------------------------------------------------------------------------
# summarize / diff
# --------------------------------------------------------------------------

def _cmd_summarize(argv: List[str]) -> int:
    ap = argparse.ArgumentParser(prog="repro-trace summarize")
    ap.add_argument("trace", help="exported trace_event JSON")
    ns = ap.parse_args(argv)
    rows = obs_trace.summarize(obs_trace.load(ns.trace))
    if not rows:
        print("empty trace")
        return 0
    print(f"{'name':<20} {'count':>8} {'total_us':>12} "
          f"{'p50_us':>10} {'p99_us':>10}")
    for r in rows:
        p50 = f"{r['p50_us']:.1f}" if "p50_us" in r else "-"
        p99 = f"{r['p99_us']:.1f}" if "p99_us" in r else "-"
        print(f"{r['name']:<20} {r['count']:>8} {r['total_us']:>12.1f} "
              f"{p50:>10} {p99:>10}")
    return 0


def _cmd_diff(argv: List[str]) -> int:
    ap = argparse.ArgumentParser(prog="repro-trace diff")
    ap.add_argument("a")
    ap.add_argument("b")
    ns = ap.parse_args(argv)
    rows = obs_trace.diff(obs_trace.load(ns.a), obs_trace.load(ns.b))
    print(f"{'name':<20} {'count_a':>8} {'count_b':>8} {'d_count':>8} "
          f"{'d_total_us':>12}")
    changed = 0
    for r in rows:
        if r["d_count"] == 0 and abs(r["d_total_us"]) < 1e-9:
            continue
        changed += 1
        print(f"{r['name']:<20} {r['count_a']:>8} {r['count_b']:>8} "
              f"{r['d_count']:>+8} {r['d_total_us']:>+12.1f}")
    if not changed:
        print("(no per-name differences)")
    return 0


def main(argv: Optional[List[str]] = None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    cmds = {"export": _cmd_export, "summarize": _cmd_summarize,
            "diff": _cmd_diff}
    if not argv or argv[0] not in cmds:
        print("usage: repro-trace {export,summarize,diff} [options]\n"
              f"{__doc__}")
        return 0 if argv and argv[0] in ("-h", "--help") else 2
    return cmds[argv[0]](argv[1:])


if __name__ == "__main__":
    sys.exit(main())
