"""repro.obs — deterministic tracing + quantile metrics.

- :mod:`repro.obs.trace`: span/instant recorder stamped in sim time,
  Perfetto ``trace_event`` JSON export, module-level ``TRACE`` no-op
  singleton for siteless call points.
- :mod:`repro.obs.metrics`: counters / gauges / exact-quantile
  histograms behind a registry, the ``MetricSet`` attribute facade,
  and the ``MonotonicSampler`` wall-clock seam.
- :mod:`repro.obs.cli`: the ``repro-trace`` console script
  (export / summarize / diff).
"""
from repro.obs.trace import (  # noqa: F401
    NULL,
    NullRecorder,
    TraceRecorder,
    install,
    uninstall,
)
from repro.obs.metrics import (  # noqa: F401
    Counter,
    Gauge,
    Histogram,
    MetricSet,
    MonotonicSampler,
    Registry,
)
