"""Deterministic structured tracing stamped in **simulated** time.

One recorder serves every subsystem: the discrete-event simulator stamps
events with ``EventQueue.now`` (ms), the serving engine with its per-pod
busy clocks and the router's tick clock, the planner with its epoch
boundaries, and the MoE layer with the recorder's last-set time (spans
there fire at jit-trace time — one per compiled (shape, path) cell, which
is exactly when the dispatch verdict is decided).  Because every timestamp
comes from deterministic simulation clocks, two seeded runs export
byte-identical traces (pinned in tests/test_obs.py) and a trace diff is a
meaningful regression signal, not noise.

Event kinds map 1:1 onto the Chrome/Perfetto ``trace_event`` format:

=============  ====  =======================================================
recorder call  ph    use
=============  ====  =======================================================
``span``       X     a closed duration on one track (pod step phases,
                     certifier batches, exec slots)
``instant``    i     a point event (forward, abort, lease free)
``abegin``     b     async span open — overlapping rounds on one track
``aend``       e     async span close (paired by track + id)
``counter``    C     a sampled scalar (queue depths, busy clocks)
=============  ====  =======================================================

Tracks are strings like ``"node0/lease"`` or ``"pod3"``; the component
before the first ``/`` becomes the Perfetto process row, the full string
the thread row.  Export with :meth:`TraceRecorder.export` and load the
JSON straight into https://ui.perfetto.dev (or ``chrome://tracing``).

**Zero-cost when disabled** is a hard contract: hot sites hold a reference
to either a recorder or ``None``/:data:`NULL` and guard with ONE branch —
``if tr is not None: tr.span("name", ...)`` — so the disabled path
allocates nothing (no f-strings, no payload dicts).  The
``event-trace-site`` lint rule (analysis/rules/trace_site.py) additionally
requires every site to pass a *static* event name, keeping the taken path
cheap and the trace vocabulary greppable.

The module-level :data:`TRACE` singleton exists for call sites with no
object to thread a recorder through (``models/moe.py``, the event queue's
replay capture).  ``install()``/``uninstall()`` swap it; everything else
threads explicit recorder instances.
"""
from __future__ import annotations

import json
from typing import Any, Dict, List, Optional, Tuple

# internal event tuple layout: (ph, name, track, ts_ms, dur_ms, aid, payload)
_Event = Tuple[str, str, str, float, float, Any, Optional[Dict[str, Any]]]


class NullRecorder:
    """The disabled recorder: every method is a no-op, ``enabled`` False.

    Sites that cannot hold ``None`` (the module global) hold this instead;
    the one-branch contract is then ``if tr.enabled: ...``.
    """

    enabled = False
    time = 0.0

    def set_time(self, ts_ms: float) -> None:  # pragma: no cover - trivial
        pass

    def span(self, name, track, ts, dur, **payload) -> None:
        pass

    def instant(self, name, track, ts=None, **payload) -> None:
        pass

    def abegin(self, name, track, aid, ts=None, **payload) -> None:
        pass

    def aend(self, name, track, aid, ts=None, **payload) -> None:
        pass

    def counter(self, name, track, ts, value) -> None:
        pass


NULL = NullRecorder()

# module-level recorder for sites with nothing to thread through (moe,
# EventQueue replay capture).  Rebinding via install() is visible to every
# site because they read it through the module attribute.
TRACE = NULL


def install(recorder: "TraceRecorder") -> None:
    """Make ``recorder`` the module-level :data:`TRACE` singleton."""
    global TRACE
    TRACE = recorder


def uninstall() -> None:
    """Restore the no-op singleton."""
    global TRACE
    TRACE = NULL


class TraceRecorder:
    """Append-only span/instant recorder; export to Perfetto JSON.

    Timestamps are whatever simulated clock the caller passes (ms); pass
    ``ts=None`` to instants/async events to stamp the recorder's last
    ``set_time`` value (used by jit-trace-time sites that have no clock of
    their own).  Insertion order is preserved end to end, which together
    with sim-time stamps makes the export a pure function of the run.
    """

    enabled = True

    def __init__(self) -> None:
        self._events: List[_Event] = []
        self.time = 0.0       # last set_time() value, the ts=None fallback

    # -- recording -----------------------------------------------------------
    def set_time(self, ts_ms: float) -> None:
        self.time = ts_ms

    def span(self, name: str, track: str, ts: float, dur: float,
             **payload) -> None:
        """A closed [ts, ts+dur] slice on ``track`` (ms)."""
        self._events.append(
            ("X", name, track, ts, dur, None, payload or None))

    def instant(self, name: str, track: str, ts: Optional[float] = None,
                **payload) -> None:
        self._events.append(
            ("i", name, track, self.time if ts is None else ts, 0.0, None,
             payload or None))

    def abegin(self, name: str, track: str, aid,
               ts: Optional[float] = None, **payload) -> None:
        """Open an async span; overlapping spans coexist on one track."""
        self._events.append(
            ("b", name, track, self.time if ts is None else ts, 0.0, aid,
             payload or None))

    def aend(self, name: str, track: str, aid,
             ts: Optional[float] = None, **payload) -> None:
        self._events.append(
            ("e", name, track, self.time if ts is None else ts, 0.0, aid,
             payload or None))

    def counter(self, name: str, track: str, ts: float, value) -> None:
        self._events.append(
            ("C", name, track, ts, 0.0, None, {"value": value}))

    def __len__(self) -> int:
        return len(self._events)

    # -- export --------------------------------------------------------------
    def _track_ids(self) -> Dict[str, Tuple[int, int]]:
        """track -> (pid, tid), assigned in first-use order (deterministic)."""
        pids: Dict[str, int] = {}
        tids: Dict[str, Tuple[int, int]] = {}
        for (_ph, _name, track, _ts, _dur, _aid, _p) in self._events:
            if track in tids:
                continue
            proc = track.split("/", 1)[0]
            pid = pids.setdefault(proc, len(pids) + 1)
            tid = sum(1 for t in tids.values() if t[0] == pid) + 1
            tids[track] = (pid, tid)
        return tids

    def to_events(self) -> List[Dict[str, Any]]:
        """The Chrome ``trace_event`` dict list (ts/dur in microseconds)."""
        tids = self._track_ids()
        out: List[Dict[str, Any]] = []
        named_procs = set()
        for track, (pid, tid) in tids.items():
            proc = track.split("/", 1)[0]
            if proc not in named_procs:
                named_procs.add(proc)
                out.append({"ph": "M", "name": "process_name", "pid": pid,
                            "tid": 0, "args": {"name": proc}})
            out.append({"ph": "M", "name": "thread_name", "pid": pid,
                        "tid": tid, "args": {"name": track}})
        for (ph, name, track, ts, dur, aid, payload) in self._events:
            pid, tid = tids[track]
            ev: Dict[str, Any] = {"ph": ph, "name": name, "cat": "repro",
                                  "pid": pid, "tid": tid,
                                  "ts": round(ts * 1000.0, 3)}
            if ph == "X":
                ev["dur"] = round(dur * 1000.0, 3)
            elif ph == "i":
                ev["s"] = "t"
            elif ph in ("b", "e"):
                ev["id"] = str(aid)
            if payload:
                ev["args"] = payload
            out.append(ev)
        return out

    def export(self, path: str) -> None:
        """Write ``{"traceEvents": [...]}`` — Perfetto/Chrome loadable."""
        with open(path, "w") as f:
            json.dump({"traceEvents": self.to_events(),
                       "displayTimeUnit": "ms"}, f, separators=(",", ":"))


# --------------------------------------------------------------------------
# Offline helpers: load / summarize / diff exported traces
# --------------------------------------------------------------------------

def load(path: str) -> List[Dict[str, Any]]:
    """Load an exported trace; accepts the object or bare-list JSON forms."""
    with open(path) as f:
        data = json.load(f)
    events = data["traceEvents"] if isinstance(data, dict) else data
    if not isinstance(events, list):
        raise ValueError(f"{path}: not a trace_event JSON")
    return events


def summarize(events: List[Dict[str, Any]]) -> List[Dict[str, Any]]:
    """Per-name aggregate rows: count, total/p50/p99 duration (us).

    Durations come from complete (``X``) events and from matched async
    ``b``/``e`` pairs; instants contribute counts only.
    """
    durs: Dict[str, List[float]] = {}
    counts: Dict[str, int] = {}
    open_async: Dict[Tuple[str, str], float] = {}
    for ev in events:
        ph = ev.get("ph")
        name = ev.get("name", "?")
        if ph == "X":
            counts[name] = counts.get(name, 0) + 1
            durs.setdefault(name, []).append(float(ev.get("dur", 0.0)))
        elif ph == "i":
            counts[name] = counts.get(name, 0) + 1
        elif ph == "b":
            counts[name] = counts.get(name, 0) + 1
            open_async[(name, str(ev.get("id")))] = float(ev["ts"])
        elif ph == "e":
            t0 = open_async.pop((name, str(ev.get("id"))), None)
            if t0 is not None:
                durs.setdefault(name, []).append(float(ev["ts"]) - t0)
    rows = []
    for name in sorted(counts):
        ds = sorted(durs.get(name, []))
        row = {"name": name, "count": counts[name],
               "total_us": sum(ds) if ds else 0.0}
        if ds:
            row["p50_us"] = _q(ds, 0.5)
            row["p99_us"] = _q(ds, 0.99)
        rows.append(row)
    return rows


def _q(sorted_vals: List[float], q: float) -> float:
    """Exact linear-interpolated quantile (numpy 'linear' semantics)."""
    n = len(sorted_vals)
    if n == 1:
        return sorted_vals[0]
    pos = q * (n - 1)
    lo = int(pos)
    hi = min(lo + 1, n - 1)
    frac = pos - lo
    return sorted_vals[lo] * (1.0 - frac) + sorted_vals[hi] * frac


def diff(a: List[Dict[str, Any]], b: List[Dict[str, Any]]
         ) -> List[Dict[str, Any]]:
    """Per-name deltas between two summarized traces (b minus a)."""
    sa = {r["name"]: r for r in summarize(a)}
    sb = {r["name"]: r for r in summarize(b)}
    rows = []
    for name in sorted(set(sa) | set(sb)):
        ra, rb = sa.get(name), sb.get(name)
        rows.append({
            "name": name,
            "count_a": ra["count"] if ra else 0,
            "count_b": rb["count"] if rb else 0,
            "d_count": (rb["count"] if rb else 0) - (ra["count"] if ra else 0),
            "d_total_us": (rb["total_us"] if rb else 0.0)
                          - (ra["total_us"] if ra else 0.0),
        })
    return rows
