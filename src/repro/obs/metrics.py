"""Counters, gauges, and quantile histograms behind one registry.

The serving stack previously tracked everything as loose dataclass
fields (``EngineMetrics``, ``CertifierMetrics``) which made per-pod
breakdowns and latency distributions bolt-ons.  This module is the
single source of truth those migrate onto:

- :class:`Counter` / :class:`Gauge` — plain scalars with a name.
- :class:`Histogram` — keeps **raw samples** (exact quantiles, numpy
  'linear' interpolation semantics) plus pow2 log-bucket counts
  ``[2^k, 2^(k+1))`` for cheap shape summaries, and an SLO-attainment
  helper (fraction of samples ≤ limit).
- :class:`Registry` — name → metric, with ``as_dict()``.
- :class:`MetricSet` — an attribute facade over a registry so existing
  call sites (``m.forwards += 1``) and tests keep working unchanged
  while the values live in the registry.
- :class:`MonotonicSampler` — the one sanctioned wall-clock seam.  Sim
  metrics are deterministic by construction; anything that *must* read
  host time (planner scoring runs on the host CPU, so its wall block
  time is real) goes through a sampler instance, which tests can swap
  for a fake.  This keeps the ``event-determinism`` lint honest: no
  bare ``time.*`` reads in step loops.

Everything here is stdlib-only; numpy is used nowhere so the registry
can be imported from lint/CI contexts without heavyweight deps.
"""
from __future__ import annotations

import math
import time
from typing import Any, Dict, List, Optional


class Counter:
    """A monotonically-meant (but not enforced) named scalar."""

    __slots__ = ("name", "value")

    def __init__(self, name: str, value=0):
        self.name = name
        self.value = value

    def inc(self, n=1) -> None:
        self.value += n


class Gauge:
    """A named last-write-wins scalar."""

    __slots__ = ("name", "value")

    def __init__(self, name: str, value=0.0):
        self.name = name
        self.value = value

    def set(self, v) -> None:
        self.value = v


class Histogram:
    """Exact-quantile histogram with pow2 log-bucket counts.

    ``observe(v)`` appends the raw sample (quantiles stay exact — the
    sample counts here are tool-scale, not telemetry-scale) and bumps
    the log bucket ``k = floor(log2(v))``, i.e. bucket ``k`` covers
    ``[2^k, 2^(k+1))``.  Non-positive samples land in the reserved
    ``"le_zero"`` bucket.
    """

    __slots__ = ("name", "samples", "buckets")

    def __init__(self, name: str):
        self.name = name
        self.samples: List[float] = []
        self.buckets: Dict[Any, int] = {}

    def observe(self, v: float, n: int = 1) -> None:
        for _ in range(n):
            self.samples.append(v)
        if v > 0.0:
            k = math.floor(math.log2(v))
        else:
            k = "le_zero"
        self.buckets[k] = self.buckets.get(k, 0) + n

    @property
    def count(self) -> int:
        return len(self.samples)

    def quantile(self, q: float) -> Optional[float]:
        """Exact quantile, numpy ``method='linear'`` semantics."""
        if not self.samples:
            return None
        s = sorted(self.samples)
        n = len(s)
        if n == 1:
            return s[0]
        pos = q * (n - 1)
        lo = int(pos)
        hi = min(lo + 1, n - 1)
        frac = pos - lo
        return s[lo] * (1.0 - frac) + s[hi] * frac

    def slo_attainment(self, limit: float) -> Optional[float]:
        """Fraction of samples ``<= limit`` (the SLO-met rate)."""
        if not self.samples:
            return None
        return sum(1 for v in self.samples if v <= limit) / len(self.samples)

    def summary(self) -> Dict[str, Any]:
        out: Dict[str, Any] = {"count": self.count}
        for label, q in (("p50", 0.5), ("p90", 0.9), ("p99", 0.99)):
            v = self.quantile(q)
            if v is not None:
                out[label] = v
        return out


class Registry:
    """Flat name → metric map with factory accessors."""

    def __init__(self) -> None:
        self._metrics: Dict[str, Any] = {}

    def counter(self, name: str, value=0) -> Counter:
        m = self._metrics.get(name)
        if m is None:
            m = self._metrics[name] = Counter(name, value)
        return m

    def gauge(self, name: str, value=0.0) -> Gauge:
        m = self._metrics.get(name)
        if m is None:
            m = self._metrics[name] = Gauge(name, value)
        return m

    def histogram(self, name: str) -> Histogram:
        m = self._metrics.get(name)
        if m is None:
            m = self._metrics[name] = Histogram(name)
        return m

    def get(self, name: str):
        return self._metrics.get(name)

    def __contains__(self, name: str) -> bool:
        return name in self._metrics

    def names(self) -> List[str]:
        return list(self._metrics)

    def as_dict(self) -> Dict[str, Any]:
        out: Dict[str, Any] = {}
        for name, m in self._metrics.items():
            if isinstance(m, Histogram):
                out[name] = m.summary()
            else:
                out[name] = m.value
        return out


class MetricSet:
    """Attribute facade over a :class:`Registry`.

    Subclasses declare ``FIELDS = {"forwards": 0, ...}``; reads and
    writes of those attribute names route to registry counters/gauges,
    so pre-existing idioms like ``metrics.forwards += 1`` keep working
    while the registry is the single source of truth.  Attributes not
    in ``FIELDS`` behave normally (stored on the instance).
    """

    FIELDS: Dict[str, Any] = {}

    def __init__(self, registry: Optional[Registry] = None,
                 prefix: str = "") -> None:
        # bypass our own __setattr__ while bootstrapping
        object.__setattr__(self, "registry", registry or Registry())
        object.__setattr__(self, "_prefix", prefix)
        for name, default in type(self).FIELDS.items():
            self.registry.counter(prefix + name, default)

    def _key(self, name: str) -> str:
        return self._prefix + name

    def __getattr__(self, name: str):
        # only called when normal lookup fails — i.e. FIELDS entries
        fields = type(self).FIELDS
        if name in fields:
            return self.registry.counter(self._prefix + name).value
        raise AttributeError(name)

    def __setattr__(self, name: str, value) -> None:
        if name in type(self).FIELDS:
            self.registry.counter(self._prefix + name).value = value
        else:
            object.__setattr__(self, name, value)

    def as_dict(self) -> Dict[str, Any]:
        return {name: self.registry.counter(self._prefix + name).value
                for name in type(self).FIELDS}


class MonotonicSampler:
    """The sanctioned host-clock read: ``elapsed = s.lap()`` pairs.

    ``clock`` is injectable (tests pass a fake) and defaults to
    ``time.perf_counter``.  Call :meth:`mark` to open an interval and
    :meth:`lap` to close it and get the elapsed seconds.
    """

    __slots__ = ("_clock", "_t0")

    def __init__(self, clock=time.perf_counter):
        self._clock = clock
        self._t0: Optional[float] = None

    def mark(self) -> None:
        self._t0 = self._clock()

    def lap(self) -> float:
        if self._t0 is None:
            return 0.0
        dt = self._clock() - self._t0
        self._t0 = None
        return dt
