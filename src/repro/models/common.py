"""Shared model-definition substrate for the 10 assigned architectures.

Everything is functional pure-JAX: a model is (init_fn, apply fns, sharding
rules).  Parameters are plain nested dicts of jnp arrays; layer stacks are
``lax.scan``-compatible (params stacked over a leading "group" axis), which
keeps HLO size independent of depth and makes per-layer sharding rules
uniform.

The configuration dataclasses below span every architectural feature the
assignment requires: GQA, partial/M-RoPE rotary, sliding-window + periodic
global attention (gemma3), MLA latent attention (deepseek-v2), mixture of
experts (mixtral / deepseek-v2), Mamba2 SSD blocks (mamba2), hybrid shared
attention (zamba2), bidirectional encoders (hubert) and vision/audio frontend
stubs (qwen2-vl / hubert).
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# Configuration
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-V2 Multi-head Latent Attention dimensions."""

    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 8
    top_k: int = 2
    d_expert: int = 0              # expert FFN hidden dim
    n_shared: int = 0              # always-on shared experts (deepseek-v2)
    d_shared: int = 0              # hidden dim of the fused shared expert
    first_dense_layers: int = 0    # leading layers that use a dense FFN
    d_first_dense: int = 0
    router_scale: float = 1.0      # routed-expert weight scale


@dataclass(frozen=True)
class SSMConfig:
    """Mamba2 (SSD) block dimensions."""

    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    chunk: int = 256

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | encoder | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    # activations / norms
    mlp_act: str = "swiglu"        # swiglu | geglu | relu2 | gelu
    norm_eps: float = 1e-5
    use_qk_norm: bool = False
    gemma_norm: bool = False       # (1+w) RMSNorm + sqrt(d) embedding scale
    tie_embeddings: bool = False
    logit_softcap: float = 0.0
    # rotary
    rope_theta: float = 1e4
    rope_theta_global: Optional[float] = None    # gemma3 global layers
    partial_rotary: float = 1.0
    mrope_sections: Optional[Tuple[int, ...]] = None    # qwen2-vl
    # attention pattern
    causal: bool = True            # False => bidirectional encoder
    sliding_window: Optional[int] = None
    global_every: Optional[int] = None   # 1 global layer per this many layers
    # specials
    mla: Optional[MLAConfig] = None
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    hybrid_attn_every: Optional[int] = None   # zamba2 shared-attn period
    max_seq_len: int = 131072
    dtype: str = "bfloat16"

    # -- derived -------------------------------------------------------------
    @property
    def q_per_kv(self) -> int:
        return max(1, self.n_heads // max(1, self.n_kv_heads))

    def compute_dtype(self):
        return jnp.dtype(self.dtype)

    def param_count(self) -> int:
        """Analytic parameter count (used by roofline MODEL_FLOPS)."""
        return _param_count_slow(self)

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: shared + top-k experts only)."""
        total = _param_count_slow(self)
        if self.moe is None:
            return total
        m = self.moe
        n_moe_layers = self.n_layers - m.first_dense_layers
        per_expert = 3 * self.d_model * m.d_expert
        inactive = n_moe_layers * (m.n_experts - m.top_k) * per_expert
        return total - inactive


def _param_count_slow(cfg: ModelConfig) -> int:
    shapes = param_shapes(cfg)
    leaves = jax.tree.leaves(shapes, is_leaf=lambda s: isinstance(s, tuple))
    return int(sum(int(np.prod(s)) for s in leaves))


# ---------------------------------------------------------------------------
# Layer pattern
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class LayerKind:
    mixer: str                     # "attn" | "attn_local" | "mamba" | "shared_attn"
    ffn: str                       # "dense" | "moe" | "none"


@dataclass(frozen=True)
class LayerPlan:
    """How the n_layers stack maps onto prefix + scanned body + suffix.

    ``kinds`` covers all layers; ``prefix`` leading layers and ``suffix``
    trailing layers are unrolled (own params), the middle
    ``n_groups × period`` layers are ``lax.scan``-stacked (params stacked on
    a leading group axis), keeping HLO size depth-independent.
    """

    kinds: Tuple[LayerKind, ...]
    prefix: int
    period: int
    n_groups: int

    @property
    def suffix(self) -> int:
        return len(self.kinds) - self.prefix - self.period * self.n_groups

    @property
    def suffix_start(self) -> int:
        return self.prefix + self.period * self.n_groups


def layer_plan(cfg: ModelConfig) -> LayerPlan:
    kinds: List[LayerKind] = []
    for i in range(cfg.n_layers):
        if cfg.ssm is not None and cfg.hybrid_attn_every:
            # zamba2: shared attention block every `hybrid_attn_every` layers
            if (i + 1) % cfg.hybrid_attn_every == 0:
                kinds.append(LayerKind("shared_attn", "dense"))
            else:
                kinds.append(LayerKind("mamba", "none"))
        elif cfg.ssm is not None:
            kinds.append(LayerKind("mamba", "none"))
        elif cfg.global_every:
            # gemma3: 1 global layer per `global_every`, rest sliding-window
            if (i + 1) % cfg.global_every == 0:
                kinds.append(LayerKind("attn", "dense"))
            else:
                kinds.append(LayerKind("attn_local", "dense"))
        else:
            ffn = "dense"
            if cfg.moe is not None and i >= cfg.moe.first_dense_layers:
                ffn = "moe"
            local = cfg.sliding_window is not None and cfg.global_every is None
            kinds.append(LayerKind("attn_local" if local else "attn", ffn))
    prefix = 0
    if cfg.moe is not None and cfg.moe.first_dense_layers:
        prefix = cfg.moe.first_dense_layers
    body = kinds[prefix:]
    # smallest period p whose repetition covers a maximal prefix of the body;
    # the remainder becomes the unrolled suffix
    period, n_groups = len(body), 1 if body else 0
    for p in range(1, len(body) + 1):
        k = len(body) // p
        if k >= 1 and all(body[j] == body[j % p] for j in range(k * p)):
            period, n_groups = p, k
            break
    return LayerPlan(tuple(kinds), prefix, period, n_groups)


# ---------------------------------------------------------------------------
# Primitive layers
# ---------------------------------------------------------------------------

def rms_norm(x: jax.Array, w: jax.Array, eps: float, gemma: bool = False) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    scale = (1.0 + w.astype(jnp.float32)) if gemma else w.astype(jnp.float32)
    return (x * scale).astype(dt)


def mlp_apply(p: Dict[str, jax.Array], x: jax.Array, act: str) -> jax.Array:
    """Feed-forward: gated (swiglu/geglu) or plain (relu2/gelu)."""
    if act in ("swiglu", "geglu"):
        g = x @ p["w_gate"]
        u = x @ p["w_up"]
        h = (jax.nn.silu(g) if act == "swiglu" else jax.nn.gelu(g)) * u
    elif act == "relu2":
        h = jnp.square(jax.nn.relu(x @ p["w_up"]))
    elif act == "gelu":
        h = jax.nn.gelu(x @ p["w_up"])
    else:
        raise ValueError(act)
    return h @ p["w_down"]


def mlp_shapes(d_model: int, d_ff: int, act: str) -> Dict[str, Tuple[int, ...]]:
    if act in ("swiglu", "geglu"):
        return {
            "w_gate": (d_model, d_ff),
            "w_up": (d_model, d_ff),
            "w_down": (d_ff, d_model),
        }
    return {"w_up": (d_model, d_ff), "w_down": (d_ff, d_model)}


# ---------------------------------------------------------------------------
# Rotary embeddings (standard / partial / M-RoPE / dual-theta)
# ---------------------------------------------------------------------------

def rope_freqs(dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))


def apply_rope(
    x: jax.Array,                 # [B, S, H, D]
    positions: jax.Array,         # [B, S] or [3, B, S] for M-RoPE
    theta: float,
    partial: float = 1.0,
    mrope_sections: Optional[Tuple[int, ...]] = None,
) -> jax.Array:
    d = x.shape[-1]
    rot = int(d * partial)
    rot -= rot % 2
    if rot == 0:
        return x
    xr, xp = x[..., :rot], x[..., rot:]
    inv = rope_freqs(rot, theta)                         # [rot/2]
    if mrope_sections is not None:
        # M-RoPE: frequency bands are split into sections, each rotated by a
        # different positional stream (temporal / height / width).  Text-only
        # inputs pass identical streams, which reduces to standard RoPE.
        assert positions.ndim == 3, "M-RoPE expects positions [n_sections, B, S]"
        assert sum(mrope_sections) == rot // 2
        pos_parts = []
        start = 0
        for sec_i, sec in enumerate(mrope_sections):
            pos_parts.append(
                positions[sec_i][..., None] * inv[start:start + sec][None, None, :]
            )
            start += sec
        ang = jnp.concatenate(pos_parts, axis=-1)        # [B, S, rot/2]
    else:
        if positions.ndim == 3:
            positions = positions[0]
        ang = positions[..., None].astype(jnp.float32) * inv[None, None, :]
    cos = jnp.cos(ang)[:, :, None, :].astype(x.dtype)    # [B, S, 1, rot/2]
    sin = jnp.sin(ang)[:, :, None, :].astype(x.dtype)
    x1, x2 = xr[..., : rot // 2], xr[..., rot // 2:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return jnp.concatenate([out, xp], axis=-1) if rot < d else out


# ---------------------------------------------------------------------------
# Parameter shapes & init
# ---------------------------------------------------------------------------

def attn_shapes(cfg: ModelConfig) -> Dict[str, Any]:
    d, hq, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    if cfg.mla is not None:
        m = cfg.mla
        qk_dim = m.qk_nope_head_dim + m.qk_rope_head_dim
        return {
            "wq_a": (d, m.q_lora_rank),
            "q_norm": (m.q_lora_rank,),
            "wq_b": (m.q_lora_rank, hq * qk_dim),
            "wkv_a": (d, m.kv_lora_rank + m.qk_rope_head_dim),
            "kv_norm": (m.kv_lora_rank,),
            "wkv_b": (m.kv_lora_rank, hq * (m.qk_nope_head_dim + m.v_head_dim)),
            "wo": (hq * m.v_head_dim, d),
        }
    sh: Dict[str, Any] = {
        "wq": (d, hq * hd),
        "wk": (d, hkv * hd),
        "wv": (d, hkv * hd),
        "wo": (hq * hd, d),
    }
    if cfg.use_qk_norm:
        sh["q_norm"] = (hd,)
        sh["k_norm"] = (hd,)
    return sh


def mamba_shapes(cfg: ModelConfig) -> Dict[str, Any]:
    s = cfg.ssm
    d = cfg.d_model
    di = s.d_inner(d)
    nh = s.n_heads(d)
    conv_dim = di + 2 * s.n_groups * s.d_state
    return {
        # in_proj -> [z (gate), x, B, C, dt]
        "w_in": (d, 2 * di + 2 * s.n_groups * s.d_state + nh),
        "conv_w": (s.d_conv, conv_dim),
        "conv_b": (conv_dim,),
        "A_log": (nh,),
        "D": (nh,),
        "dt_bias": (nh,),
        "gate_norm": (di,),
        "w_out": (di, d),
    }


def chunk_plan(n_experts: int, model_size: int) -> Tuple[int, int, int, int]:
    """Expert layout plan: (ep, tp, experts_per_chunk, n_chunks=model_size).

    The model mesh axis is split into ``ep`` expert groups × ``tp``-way
    tensor parallelism inside each expert, so any expert count divides any
    axis size (one of the two must divide the other).
    """
    if model_size <= 1:
        return 1, 1, n_experts, 1
    if n_experts >= model_size:
        assert n_experts % model_size == 0, (n_experts, model_size)
        return model_size, 1, n_experts // model_size, model_size
    assert model_size % n_experts == 0, (n_experts, model_size)
    tp = model_size // n_experts
    return n_experts, tp, 1, model_size


def moe_shapes(cfg: ModelConfig, model_size: int = 1) -> Dict[str, Any]:
    """Expert weights in chunked [n_chunks, n_e, d, f_c] layout (EP × TP)."""
    m = cfg.moe
    d = cfg.d_model
    ep, tp, n_e, nc = chunk_plan(m.n_experts, model_size)
    f_c = m.d_expert // tp
    sh: Dict[str, Any] = {
        "router": (d, m.n_experts),
        "experts": {
            "w_gate": (nc, n_e, d, f_c),
            "w_up": (nc, n_e, d, f_c),
            "w_down": (nc, n_e, f_c, d),
        },
    }
    if m.n_shared:
        sh["shared"] = mlp_shapes(d, m.d_shared * m.n_shared, "swiglu")
    return sh


def _layer_shapes(cfg: ModelConfig, kind: LayerKind, model_size: int = 1) -> Dict[str, Any]:
    sh: Dict[str, Any] = {}
    if kind.mixer in ("attn", "attn_local"):
        sh["attn"] = attn_shapes(cfg)
        sh["ln_attn"] = (cfg.d_model,)
        if cfg.gemma_norm:
            sh["ln_post_attn"] = (cfg.d_model,)
    elif kind.mixer == "mamba":
        sh["mamba"] = mamba_shapes(cfg)
        sh["ln_mix"] = (cfg.d_model,)
    # shared_attn params live outside the stacked tree (they are shared)
    if kind.ffn == "dense":
        sh["mlp"] = mlp_shapes(cfg.d_model, cfg.d_ff, cfg.mlp_act)
        sh["ln_mlp"] = (cfg.d_model,)
        if cfg.gemma_norm:
            sh["ln_post_mlp"] = (cfg.d_model,)
    elif kind.ffn == "moe":
        sh["moe"] = moe_shapes(cfg, model_size)
        sh["ln_mlp"] = (cfg.d_model,)
    return sh


def param_shapes(cfg: ModelConfig, model_size: int = 1) -> Dict[str, Any]:
    """The full parameter tree, with per-pattern-group stacking.

    Layout::

        embed:   [vocab, d]
        prefix:  {layer0: {...}, ...}     unrolled leading layers (MoE dense prefix)
        blocks:  {pos0: [n_groups, ...]}  one stacked entry per pattern position
        suffix:  {layerK: {...}, ...}     unrolled trailing remainder layers
        shared_attn: {...}                zamba2 only (shared across groups)
        final_norm: [d]
        lm_head: [d, vocab]               (absent if tied)

    ``model_size`` fixes the MoE chunked-expert layout (EP × TP grid over the
    model mesh axis); 1 = single-device reference layout.
    """
    plan = layer_plan(cfg)
    kinds, prefix = plan.kinds, plan.prefix
    tree: Dict[str, Any] = {}
    tree["embed"] = (cfg.vocab_size, cfg.d_model)
    if prefix:
        dense_cfg = dataclasses.replace(
            cfg, moe=None, d_ff=cfg.moe.d_first_dense or cfg.d_ff
        )
        tree["prefix"] = {
            f"layer{i}": _layer_shapes(dense_cfg, LayerKind("attn", "dense"), model_size)
            for i in range(prefix)
        }
    body: Dict[str, Any] = {}
    for j in range(plan.period):
        kind = kinds[prefix + j]
        ls = _layer_shapes(cfg, kind, model_size)
        body[f"pos{j}"] = jax.tree.map(
            lambda s: (plan.n_groups,) + tuple(s),
            ls,
            is_leaf=lambda s: isinstance(s, tuple),
        )
    tree["blocks"] = body
    if plan.suffix:
        tree["suffix"] = {
            f"layer{plan.suffix_start + i}": _layer_shapes(
                cfg, kinds[plan.suffix_start + i], model_size
            )
            for i in range(plan.suffix)
        }
    if any(k.mixer == "shared_attn" for k in kinds):
        tree["shared_attn"] = {
            "attn": attn_shapes(cfg),
            "ln_attn": (cfg.d_model,),
        }
    tree["final_norm"] = (cfg.d_model,)
    if not cfg.tie_embeddings:
        tree["lm_head"] = (cfg.d_model, cfg.vocab_size)
    return tree


def init_params(cfg: ModelConfig, key: jax.Array, dtype=jnp.float32,
                model_size: int = 1) -> Dict[str, Any]:
    shapes = param_shapes(cfg, model_size)
    leaves, treedef = jax.tree.flatten(
        shapes, is_leaf=lambda s: isinstance(s, tuple)
    )
    keys = jax.random.split(key, len(leaves))

    def init_one(shape, k):
        if len(shape) == 1 or (len(shape) == 2 and shape[-1] in (1,)):
            return jnp.zeros(shape, dtype)
        fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
        std = 1.0 / math.sqrt(fan_in)
        return (jax.random.truncated_normal(k, -3, 3, shape, jnp.float32) * std).astype(dtype)

    params = treedef.unflatten([init_one(s, k) for s, k in zip(leaves, keys)])
    # special inits
    def fix(path, x):
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        if name == "A_log":
            return jnp.log(jnp.linspace(1.0, 16.0, x.shape[-1], dtype=jnp.float32)
                           * jnp.ones(x.shape, jnp.float32)).astype(x.dtype)
        if name == "D":
            return jnp.ones_like(x)
        if name == "dt_bias":
            # softplus^-1 of dt in [1e-3, 1e-1]
            return jnp.log(jnp.expm1(jnp.full(x.shape, 0.01, jnp.float32))).astype(x.dtype)
        if name in ("gate_norm", "q_norm", "k_norm", "kv_norm", "final_norm",
                    "ln_attn", "ln_mlp", "ln_mix", "ln_post_attn", "ln_post_mlp"):
            return jnp.zeros_like(x) if False else jnp.ones_like(x)
        return x

    params = jax.tree_util.tree_map_with_path(fix, params)
    if cfg.gemma_norm:
        # gemma RMSNorm computes (1 + w): init scales to zero
        def zero_norms(path, x):
            name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
            if name.startswith("ln_") or name == "final_norm":
                return jnp.zeros_like(x)
            return x
        params = jax.tree_util.tree_map_with_path(zero_norms, params)
    return params
