"""Mamba2 (state-space duality) mixer: chunked SSD scan + recurrent decode.

The SSD forward follows the Mamba2 paper's chunked decomposition: within a
chunk of length L the output is a (masked, decay-weighted) quadratic form —
attention-shaped, MXU-friendly; across chunks a small [H, P, N] state is
carried by an associative recurrence.  The Pallas kernel twin
(``repro.kernels.ssd_scan``) tiles chunks into VMEM; this module holds the
pure-jnp oracle and the layer plumbing (conv, gating, projections, caches).

Decode is O(1)/token: the recurrent form ``h ← h·exp(dtA) + dt·x⊗B`` over the
cached state, which is why SSM archs are the `long_500k`-capable family.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .common import ModelConfig, rms_norm


# ---------------------------------------------------------------------------
# Chunked SSD scan (oracle; kernel twin in repro.kernels.ssd_scan)
# ---------------------------------------------------------------------------

def segsum(log_a: jax.Array) -> jax.Array:
    """Stable segment-sum: out[..., i, j] = sum log_a[..., j+1..i] (−inf j>i)."""
    l = log_a.shape[-1]
    cs = jnp.cumsum(log_a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((l, l), bool), k=0)
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(
    x: jax.Array,      # [B, S, H, P]
    dt: jax.Array,     # [B, S, H]   (already softplus'd, >0)
    a: jax.Array,      # [H]         (negative: -exp(A_log))
    b_mat: jax.Array,  # [B, S, G, N]
    c_mat: jax.Array,  # [B, S, G, N]
    chunk: int,
    h0: Optional[jax.Array] = None,   # [B, H, P, N] initial state
    return_final_state: bool = False,
):
    """Chunked state-space-duality scan; S must be a multiple of ``chunk``."""
    bsz, s, h, p = x.shape
    g, n = b_mat.shape[2], b_mat.shape[3]
    assert s % chunk == 0, f"seq {s} not a multiple of chunk {chunk}"
    nc = s // chunk
    hpg = h // g
    f32 = jnp.float32

    xr = x.reshape(bsz, nc, chunk, h, p).astype(f32)
    dtr = dt.reshape(bsz, nc, chunk, h).astype(f32)
    br = b_mat.reshape(bsz, nc, chunk, g, n).astype(f32)
    cr = c_mat.reshape(bsz, nc, chunk, g, n).astype(f32)
    # expand groups -> heads
    be = jnp.repeat(br, hpg, axis=3)           # [B,nc,L,H,N]
    ce = jnp.repeat(cr, hpg, axis=3)

    da = dtr * a.astype(f32)[None, None, None, :]          # log decay per step
    da_cum = jnp.cumsum(da, axis=2)                        # [B,nc,L,H]
    seg = segsum(jnp.moveaxis(da, -1, -2))                 # [B,nc,H,L,L]

    # 1. intra-chunk (diagonal) term: masked decay-weighted attention
    cb = jnp.einsum("bnlhs,bnmhs->bnhlm", ce, be)          # [B,nc,H,L,L]
    y_diag = jnp.einsum(
        "bnhlm,bnhlm,bnmh,bnmhp->bnlhp", cb, jnp.exp(seg), dtr, xr
    )

    # 2. chunk-final states
    decay_states = jnp.exp(da_cum[:, :, -1:, :] - da_cum)  # [B,nc,L,H]
    states = jnp.einsum("bnlhs,bnlh,bnlh,bnlhp->bnhps", be, decay_states, dtr, xr)

    # 3. inter-chunk recurrence over the nc chunk states
    chunk_decay = jnp.exp(da_cum[:, :, -1, :])             # [B,nc,H]
    init = (
        jnp.zeros((bsz, h, p, n), f32)
        if h0 is None else h0.astype(f32)
    )

    def step(carry, inp):
        st, dec = inp                                       # [B,H,P,N], [B,H]
        new = carry * dec[..., None, None] + st
        return new, carry                                   # emit state *entering* the chunk

    (final, prevs) = jax.lax.scan(
        step,
        init,
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)),
    )
    prev_states = jnp.moveaxis(prevs, 0, 1)                 # [B,nc,H,P,N]

    # 4. off-diagonal contribution from the carried state
    state_decay = jnp.exp(da_cum)                           # decay from chunk start
    y_off = jnp.einsum("bnlhs,bnhps,bnlh->bnlhp", ce, prev_states, state_decay)

    y = (y_diag + y_off).reshape(bsz, s, h, p)
    if return_final_state:
        return y, final
    return y


def ssd_recurrent_step(
    h_state: jax.Array,  # [B, H, P, N]
    x_t: jax.Array,      # [B, H, P]
    dt_t: jax.Array,     # [B, H]
    a: jax.Array,        # [H]
    b_t: jax.Array,      # [B, G, N]
    c_t: jax.Array,      # [B, G, N]
) -> Tuple[jax.Array, jax.Array]:
    """One decode step of the SSD recurrence; returns (y_t, new_state)."""
    f32 = jnp.float32
    h, g = x_t.shape[1], b_t.shape[1]
    hpg = h // g
    be = jnp.repeat(b_t.astype(f32), hpg, axis=1)           # [B,H,N]
    ce = jnp.repeat(c_t.astype(f32), hpg, axis=1)
    da = jnp.exp(dt_t.astype(f32) * a.astype(f32)[None, :])  # [B,H]
    upd = jnp.einsum("bh,bhp,bhn->bhpn", dt_t.astype(f32), x_t.astype(f32), be)
    new = h_state.astype(f32) * da[..., None, None] + upd
    y = jnp.einsum("bhpn,bhn->bhp", new, ce)
    return y.astype(x_t.dtype), new


# ---------------------------------------------------------------------------
# Causal depthwise conv (d_conv small, e.g. 4)
# ---------------------------------------------------------------------------

def causal_conv1d(x: jax.Array, w: jax.Array, b: jax.Array,
                  state: Optional[jax.Array] = None) -> jax.Array:
    """x [B,S,C], w [K,C], b [C]; optional left-context state [B,K-1,C]."""
    k = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    # depthwise: sum_k w[k,c] * x[t-K+1+k, c]
    out = sum(xp[:, i: i + x.shape[1], :] * w[i][None, None, :] for i in range(k))
    return out + b[None, None, :]


# ---------------------------------------------------------------------------
# Mamba2 block
# ---------------------------------------------------------------------------

def _split_in_proj(cfg: ModelConfig, proj: jax.Array):
    s = cfg.ssm
    di = s.d_inner(cfg.d_model)
    nh = s.n_heads(cfg.d_model)
    gn = s.n_groups * s.d_state
    z, xbc_dt = jnp.split(proj, [di], axis=-1)
    xbc, dt = jnp.split(xbc_dt, [di + 2 * gn], axis=-1)
    return z, xbc, dt                                       # dt: [B,S,nh]


def mamba2_block(
    p: Dict[str, jax.Array],
    x: jax.Array,                 # [B, S, d]
    cfg: ModelConfig,
    *,
    cache: Optional[Dict[str, jax.Array]] = None,
    return_cache: bool = False,
    use_kernel: str = "auto",
) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    """Full Mamba2 mixer.  ``cache`` = {conv [B,K-1,C], ssm [B,H,P,N]}."""
    s = cfg.ssm
    bsz, seq, _ = x.shape
    di = s.d_inner(cfg.d_model)
    nh = s.n_heads(cfg.d_model)
    gn = s.n_groups * s.d_state
    a = -jnp.exp(p["A_log"].astype(jnp.float32))

    proj = x @ p["w_in"]
    z, xbc, dt_raw = _split_in_proj(cfg, proj)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))

    if seq == 1 and cache is not None:
        # --- decode: shift conv state, recurrent SSD step --------------------
        conv_state = jnp.concatenate(
            [cache["conv"], xbc.astype(cache["conv"].dtype)], axis=1)  # [B,K,C]
        xbc_t = jnp.einsum("bkc,kc->bc", conv_state.astype(jnp.float32),
                           p["conv_w"].astype(jnp.float32)) + p["conv_b"]
        xbc_t = jax.nn.silu(xbc_t).astype(x.dtype)[:, None, :]
        xs, b_mat, c_mat = jnp.split(xbc_t, [di, di + gn], axis=-1)
        y_t, new_ssm = ssd_recurrent_step(
            cache["ssm"],
            xs.reshape(bsz, nh, s.head_dim),
            dt[:, 0],
            a,
            b_mat.reshape(bsz, s.n_groups, s.d_state),
            c_mat.reshape(bsz, s.n_groups, s.d_state),
        )
        y = y_t.reshape(bsz, 1, di)
        y = y + xs * p["D"].astype(x.dtype).repeat(s.head_dim)[None, None, :]
        new_cache = (
            {"conv": conv_state[:, 1:, :], "ssm": new_ssm} if return_cache else None
        )
    else:
        # --- train / prefill: chunked scan -----------------------------------
        conv_in_state = cache["conv"] if cache is not None else None
        xbc_c = jax.nn.silu(causal_conv1d(xbc, p["conv_w"], p["conv_b"], conv_in_state))
        xs, b_mat, c_mat = jnp.split(xbc_c, [di, di + gn], axis=-1)
        xh = xs.reshape(bsz, seq, nh, s.head_dim)
        bm = b_mat.reshape(bsz, seq, s.n_groups, s.d_state)
        cm = c_mat.reshape(bsz, seq, s.n_groups, s.d_state)
        h0 = cache["ssm"] if cache is not None else None
        if use_kernel == "auto":
            use_kernel = "pallas" if jax.default_backend() == "tpu" else "ref"
        if use_kernel == "pallas":
            from repro.kernels import ssd_scan as ssd_k

            y_h, final = ssd_k.ssd_scan(xh, dt, a, bm, cm, chunk=s.chunk, h0=h0)
        else:
            pad = (-seq) % s.chunk
            if pad:
                xh_p = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
                dt_p = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
                bm_p = jnp.pad(bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
                cm_p = jnp.pad(cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
            else:
                xh_p, dt_p, bm_p, cm_p = xh, dt, bm, cm
            y_h, final = ssd_chunked(
                xh_p, dt_p, a, bm_p, cm_p, s.chunk, h0=h0, return_final_state=True
            )
            y_h = y_h[:, :seq]
        y = y_h.reshape(bsz, seq, di).astype(x.dtype)
        y = y + xs * p["D"].astype(x.dtype).repeat(s.head_dim)[None, None, :]
        new_cache = None
        if return_cache:
            k = s.d_conv
            tail = xbc[:, -(k - 1):, :]
            if cache is not None:
                tail = jnp.concatenate([cache["conv"], xbc], axis=1)[:, -(k - 1):, :]
            elif seq < k - 1:
                tail = jnp.pad(xbc, ((0, 0), (k - 1 - seq, 0), (0, 0)))
            new_cache = {"conv": tail, "ssm": final}

    # gated RMSNorm (Mamba2: norm(y * silu(z)))
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                 p["gate_norm"], cfg.norm_eps)
    return y @ p["w_out"], new_cache


def init_ssm_cache(cfg: ModelConfig, batch: int, dtype) -> Dict[str, jax.Array]:
    s = cfg.ssm
    di = s.d_inner(cfg.d_model)
    nh = s.n_heads(cfg.d_model)
    conv_dim = di + 2 * s.n_groups * s.d_state
    return {
        "conv": jnp.zeros((batch, s.d_conv - 1, conv_dim), dtype),
        "ssm": jnp.zeros((batch, nh, s.head_dim, s.d_state), jnp.float32),
    }
