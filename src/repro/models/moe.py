"""Mixture-of-Experts FFN: reference routing + sharded EP/TP execution.

Two execution paths with identical math:

* :func:`moe_ref` — per-expert dense masking, exact top-k, no capacity drops.
  Used by smoke tests / single-device runs and as the oracle.
* :func:`moe_sharded` — `shard_map` over the ``model`` mesh axis.  Expert
  weights are laid out in *chunks*: the model axis is split into
  ``ep × tp`` (ep = expert parallelism, tp = tensor parallelism inside an
  expert) so any expert count works on any axis size (mixtral: 8 experts ×
  f/2 halves on 16 devices; deepseek-v2: 10 experts/device).  Tokens are
  replicated across the model axis (as in TP dense FFN), so *dispatch is a
  local gather* on each expert owner and *combine is the single
  psum(model)* that TP needs anyway — no all_to_all, no cross-device
  dispatch tensor.  Capacity-factor token dropping bounds the gather size.

This dispatch-free formulation is the "migrate work to the state owner"
choice of the paper's cost model applied inside one step: tokens (work)
visit the expert shard (state owner) by *being already there* (replication
over the model axis), while the alternative — all_gathering expert weights
to the tokens — is the "migrate state" branch.  `repro.dist.locality`
prices both with the paper's SC cost formula.

A third path, :func:`moe_sharded_a2a`, shards the tokens over the model
axis too and moves only the *routed* activations with a pair of
``all_to_all`` collectives — the literal token-dispatch plan the pricing
model calls ``dispatch_s``.  :func:`moe_apply` consults
:func:`repro.dist.locality.price_moe_dispatch` per
``(tokens_per_device, ep_degree)`` cell (verdicts cached) and picks a2a
vs. the replicated-token path instead of always replicating.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.obs import trace as obs_trace
from .common import ModelConfig, chunk_plan, mlp_apply


# ---------------------------------------------------------------------------
# Routing
# ---------------------------------------------------------------------------

def router_topk(
    logits: jax.Array,            # [T, E] float32
    top_k: int,
    norm_topk: bool,
    router_scale: float,
) -> Tuple[jax.Array, jax.Array]:
    """Return (gate values [T, K] float32, expert ids [T, K] int32)."""
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    vals, ids = jax.lax.top_k(probs, top_k)
    if norm_topk:
        vals = vals / jnp.maximum(jnp.sum(vals, axis=-1, keepdims=True), 1e-9)
    return vals * router_scale, ids.astype(jnp.int32)


def aux_load_balance_loss(logits: jax.Array, ids: jax.Array, n_experts: int) -> jax.Array:
    """Switch-style load-balance auxiliary loss (mean prob × token fraction)."""
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    me = jnp.mean(probs, axis=0)                                    # [E]
    onehot = jax.nn.one_hot(ids[..., 0], n_experts, dtype=jnp.float32)
    ce = jnp.mean(onehot, axis=0)
    return n_experts * jnp.sum(me * ce)


# ---------------------------------------------------------------------------
# Reference path (oracle; exact, no drops)
# ---------------------------------------------------------------------------

def moe_ref(p: Dict[str, Any], x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """[B, S, d] -> [B, S, d]; loops over experts with dense masks.

    Expert weights are in the chunked layout with n_chunks=1:
    ``experts.w_gate [1, E, d, f]`` etc.
    """
    m = cfg.moe
    b, s, d = x.shape
    xt = x.reshape(-1, d)
    logits = xt.astype(jnp.float32) @ p["router"].astype(jnp.float32)
    gates, ids = router_topk(logits, m.top_k, norm_topk=(m.n_shared == 0),
                             router_scale=m.router_scale)
    out = jnp.zeros_like(xt, dtype=jnp.float32)
    we = p["experts"]
    for e in range(m.n_experts):
        w = jnp.sum(jnp.where(ids == e, gates, 0.0), axis=-1)       # [T]
        h = jax.nn.silu(xt @ we["w_gate"][0, e]) * (xt @ we["w_up"][0, e])
        out = out + (h @ we["w_down"][0, e]).astype(jnp.float32) * w[:, None]
    y = out.astype(x.dtype)
    if m.n_shared:
        y = y + mlp_apply(p["shared"], xt, "swiglu")
    return y.reshape(b, s, d)


# ---------------------------------------------------------------------------
# Chunked expert weight layout (EP × TP over the model axis)
# ---------------------------------------------------------------------------

def to_chunked(w_gate, w_up, w_down, model_size: int):
    """[E, d, f] expert weights -> chunked [n_chunks, n_e, d, f_c] layout.

    Chunk m holds experts ``(m // tp) * n_e + [0, n_e)`` restricted to
    f-slice ``m % tp``.
    """
    e, d, f = w_gate.shape
    ep, tp, n_e, nc = chunk_plan(e, model_size)
    f_c = f // tp

    def slice_chunks(w, transpose=False):
        # w [E, d, f] -> [ep, n_e, d, tp, f_c] -> [ep, tp, n_e, d, f_c] -> [nc, ...]
        wr = w.reshape(ep, n_e, d, tp, f_c) if not transpose else None
        if transpose:  # w_down [E, f, d] -> slice along f
            wr = w.reshape(ep, n_e, tp, f_c, d)
            wr = jnp.moveaxis(wr, 2, 1)                     # [ep, tp, n_e, f_c, d]
            return wr.reshape(nc, n_e, f_c, d)
        wr = jnp.moveaxis(wr, 3, 1)                         # [ep, tp, n_e, d, f_c]
        return wr.reshape(nc, n_e, d, f_c)

    return slice_chunks(w_gate), slice_chunks(w_up), slice_chunks(w_down, transpose=True)


def chunked_shapes(cfg: ModelConfig, model_size: int) -> Dict[str, Tuple[int, ...]]:
    m = cfg.moe
    ep, tp, n_e, nc = chunk_plan(m.n_experts, model_size)
    f_c = m.d_expert // tp
    return {
        "w_gate": (nc, n_e, cfg.d_model, f_c),
        "w_up": (nc, n_e, cfg.d_model, f_c),
        "w_down": (nc, n_e, f_c, cfg.d_model),
    }


# ---------------------------------------------------------------------------
# Sharded path
# ---------------------------------------------------------------------------

def _moe_local(
    x_loc: jax.Array,             # [T_loc, d]  (this device's tokens)
    router: jax.Array,            # [d, E]
    wg: jax.Array, wu: jax.Array, wd: jax.Array,   # [n_e, d, f_c] / [n_e, f_c, d]
    *,
    cfg: ModelConfig,
    model_axis: str,
    model_size: int,
    capacity: int,
) -> jax.Array:
    """Per-device body: route, gather my experts' tokens, FFN, scatter, psum."""
    m = cfg.moe
    ep, tp, n_e, _ = chunk_plan(m.n_experts, model_size)
    midx = jax.lax.axis_index(model_axis)
    ep_rank = midx // tp

    t_loc, d = x_loc.shape
    acc_dt = x_loc.dtype   # accumulate in compute dtype: keeps the backward
    # cotangent chain (and its psum over the model axis) out of fp32
    logits = x_loc.astype(jnp.float32) @ router.astype(jnp.float32)
    gates, ids = router_topk(logits, m.top_k, norm_topk=(m.n_shared == 0),
                             router_scale=m.router_scale)

    # slot assignment: for each (token, k) choice, its position among all
    # choices of the same expert (arrival order), for capacity dropping
    flat_ids = ids.reshape(-1)                               # [T*K]
    flat_gates = gates.reshape(-1)
    onehot_pos = jax.nn.one_hot(flat_ids, m.n_experts, dtype=jnp.int32)
    slot = jnp.cumsum(onehot_pos, axis=0) - onehot_pos       # [T*K, E] slot per expert
    my_first = ep_rank * n_e

    y = jnp.zeros((t_loc, d), acc_dt)
    token_of = jnp.arange(t_loc * m.top_k, dtype=jnp.int32) // m.top_k
    for le in range(n_e):
        gid = my_first + le
        sel = flat_ids == gid
        slot_e = slot[:, gid]
        keep = sel & (slot_e < capacity)
        # scatter (token, gate) into the capacity buffer
        dest = jnp.where(keep, slot_e, capacity)             # drops -> overflow row
        tok_idx = jnp.full((capacity + 1,), t_loc, jnp.int32).at[dest].set(
            jnp.where(keep, token_of, t_loc), mode="drop")[:capacity]
        gate_buf = jnp.zeros((capacity + 1,), jnp.float32).at[dest].set(
            jnp.where(keep, flat_gates, 0.0), mode="drop")[:capacity]
        xg = jnp.where(
            (tok_idx < t_loc)[:, None],
            jnp.take(x_loc, jnp.minimum(tok_idx, t_loc - 1), axis=0),
            0.0,
        )                                                     # [C, d]
        h = jax.nn.silu(xg @ wg[le]) * (xg @ wu[le])          # [C, f_c]
        o = (h @ wd[le]) * gate_buf[:, None].astype(acc_dt)
        y = y.at[jnp.minimum(tok_idx, t_loc - 1)].add(
            jnp.where((tok_idx < t_loc)[:, None], o, jnp.zeros((), acc_dt)))
    # one reduction: sums (a) expert contributions across ep ranks and
    # (b) partial f-slices across tp ranks.  Reduce in compute dtype — a
    # fp32 psum here doubles the layer's wire bytes for no accuracy gain
    # (each token sums at most top_k + tp partials).
    return jax.lax.psum(y, model_axis)


def moe_sharded(
    p: Dict[str, Any],
    x: jax.Array,                 # [B, S, d]
    cfg: ModelConfig,
    mesh: jax.sharding.Mesh,
    *,
    batch_axes: Tuple[str, ...] = ("data",),
    model_axis: str = "model",
    capacity_factor: float = 1.25,
) -> jax.Array:
    """EP/TP MoE over ``mesh``; expert weights must be in chunked layout."""
    from jax.experimental.shard_map import shard_map

    m = cfg.moe
    b, s, d = x.shape
    # shard the batch dim over as many batch axes as divide it; batch==1
    # (long-context decode) degrades to replication over the batch axes
    # (each data row computes identical routing; experts stay model-sharded)
    baxes: Tuple[str, ...] = tuple(batch_axes)
    while baxes:
        n = 1
        for a in baxes:
            n *= int(mesh.shape[a])
        if b % n == 0:
            break
        baxes = baxes[1:]
    n_batch_shards = 1
    for a in baxes:
        n_batch_shards *= int(mesh.shape[a])
    t_loc = (b // n_batch_shards) * s
    model_size = mesh.shape[model_axis]
    capacity = int(max(1, t_loc * m.top_k * capacity_factor) // m.n_experts)
    capacity = max(capacity, 8)

    def body(x_blk, router, wg, wu, wd):
        bl, sl, dl = x_blk.shape
        y = _moe_local(
            x_blk.reshape(-1, dl), router, wg[0], wu[0], wd[0],
            cfg=cfg, model_axis=model_axis, model_size=int(model_size),
            capacity=capacity,
        )
        return y.reshape(bl, sl, dl).astype(x_blk.dtype)

    bspec = P(baxes if baxes else None, None, None)
    out = shard_map(
        body, mesh=mesh,
        in_specs=(
            bspec,
            P(None, None),
            P(model_axis, None, None, None),
            P(model_axis, None, None, None),
            P(model_axis, None, None, None),
        ),
        out_specs=bspec,
        check_rep=False,
    )(x, p["router"], p["experts"]["w_gate"], p["experts"]["w_up"],
      p["experts"]["w_down"])
    if m.n_shared:
        out = out + mlp_apply(p["shared"], x, "swiglu")
    return out


# ---------------------------------------------------------------------------
# Token all-to-all path (the priced "dispatch" plan)
# ---------------------------------------------------------------------------

def _moe_local_a2a(
    x_loc: jax.Array,             # [T_loc, d] (this device's token shard)
    router: jax.Array,            # [d, E]
    wg: jax.Array, wu: jax.Array, wd: jax.Array,   # [n_e, d, f_c] / [n_e, f_c, d]
    *,
    cfg: ModelConfig,
    axes: Tuple[str, ...],        # token-shard axes, major to minor
    axis_sizes: Tuple[int, ...],
    model_axis: str,
    model_size: int,
    capacity: int,
    t_valid: int,                 # global tokens that are real (rest is pad)
) -> jax.Array:
    """Per-device body: route my tokens, a2a them to their expert *chunks*,
    partial FFN there, a2a the partial activations back, psum-combine.

    tp-aware: model rank ``m`` owns chunk ``m`` of the EP×TP layout —
    experts ``(m // tp) * n_e + [0, n_e)`` restricted to f-slice ``m % tp``.
    A routed token is dispatched to all ``tp`` ranks of its expert's chunk
    group; each computes the f-slice partial ``(silu(x·wg)·(x·wu))·wd``
    (full d, partial sum over f), and the return a2a lands the ``tp``
    partials back in the sender's per-group slot where
    :func:`repro.kernels.ops.moe_combine` sums them — the partial-
    activation psum of the combine leg, materialized as a block-sum so the
    two ``all_to_all`` legs stay the layer's entire wire traffic (priced
    by ``price_moe_dispatch``'s ``tp_degree`` term).

    Each destination block is laid out ``[n_e, cap_e]`` — sub-blocked by
    the chunk's local expert — so the receiver selects each expert's rows
    with a reshape instead of a masked pass over the whole buffer, and no
    expert-id metadata crosses the wire.  ``capacity = n_e * cap_e`` bounds
    the routed rows per (source, expert) pair at ``cap_e``; token rows at
    global index ≥ ``t_valid`` are ragged-batch padding and are never
    dispatched.
    """
    from repro.kernels import ops as kops

    m = cfg.moe
    ep, tp, n_e, _ = chunk_plan(m.n_experts, model_size)
    cap_e = capacity // n_e                               # per (src, expert)
    t_loc, d = x_loc.shape
    acc_dt = x_loc.dtype
    logits = x_loc.astype(jnp.float32) @ router.astype(jnp.float32)
    gates, ids = router_topk(logits, m.top_k, norm_topk=(m.n_shared == 0),
                             router_scale=m.router_scale)

    flat_ids = ids.reshape(-1)                            # [T*K]
    flat_gates = gates.reshape(-1)
    grp = flat_ids // n_e                                 # owning ep group
    le = flat_ids % n_e                                   # its local expert
    token_of = jnp.arange(t_loc * m.top_k, dtype=jnp.int32) // m.top_k
    # ragged batches pad the flattened token axis up to the shard multiple;
    # the pad rows live at the tail of the global order — mask them out of
    # dispatch so they neither consume capacity nor pollute the psum
    shard = jnp.zeros((), jnp.int32)
    for a, n in zip(axes, axis_sizes):
        shard = shard * n + jax.lax.axis_index(a)
    valid = (shard * t_loc + token_of) < t_valid
    # per-expert arrival slot (for capacity bounding), exactly the
    # replicated path's slots; all tp copies of a token share one slot
    onehot = jax.nn.one_hot(flat_ids, m.n_experts, dtype=jnp.int32) \
        * valid[:, None]
    slot = jnp.cumsum(onehot, axis=0) - onehot            # [T*K, E]
    slot_d = jnp.sum(slot * onehot, axis=1)
    keep = (slot_d < cap_e) & valid

    nbuf = model_size * capacity                          # = ep * tp * capacity
    send_x = jnp.zeros((nbuf + 1, d), x_loc.dtype)
    x_routed = jnp.take(x_loc, token_of, axis=0)
    sub = le * cap_e + slot_d                             # expert sub-block
    for j in range(tp):                                   # tp dest copies
        row = jnp.where(keep, (grp * tp + j) * capacity + sub, nbuf)
        send_x = send_x.at[row].set(x_routed, mode="drop")
    send_x = send_x[:nbuf]
    # sender-side combine metadata, per (group, expert-slot) — never
    # crosses the wire
    crow = jnp.where(keep, grp * capacity + sub, ep * capacity)
    tok_slot = jnp.full((ep * capacity + 1,), t_loc, jnp.int32).at[crow].set(
        jnp.where(keep, token_of, t_loc), mode="drop")[:ep * capacity]
    gate_slot = jnp.zeros((ep * capacity + 1,), jnp.float32).at[crow].set(
        jnp.where(keep, flat_gates, 0.0), mode="drop")[:ep * capacity]

    recv_x = jax.lax.all_to_all(send_x, model_axis, 0, 0, tiled=True)
    # each source block arrives sub-blocked [n_e, cap_e]: slicing an
    # expert's rows is a transpose of the reshape, not a masked pass —
    # every recv row runs exactly one expert's FFN, like the dense path
    recv_e = recv_x.reshape(model_size, n_e, cap_e, d)
    outs = []
    for e in range(n_e):
        xe = recv_e[:, e].reshape(model_size * cap_e, d)
        h = jax.nn.silu(xe @ wg[e]) * (xe @ wu[e])           # [.., f_c]
        outs.append((h @ wd[e]).astype(acc_dt)
                    .reshape(model_size, cap_e, d))
    out = jnp.stack(outs, axis=1).reshape(nbuf, d)
    # the return a2a lands each chunk's partial output back in its sender's
    # (group, tp, expert-slot) cell; moe_combine sums the tp partials per
    # slot (the f-slice psum) and scatters the gated rows to their tokens
    back = jax.lax.all_to_all(out, model_axis, 0, 0, tiled=True)
    return kops.moe_combine(back, tok_slot, gate_slot, tp=tp,
                            capacity=capacity, t_out=t_loc)


def _a2a_plan(cfg: ModelConfig, t_total: int, mesh, batch_axes, model_axis):
    """(token_shards, ep, tp, t_pad) for the a2a layout.

    Any ``(n_experts, model_size)`` pair the chunk layout accepts is
    feasible: tp > 1 dispatches to chunks with a partial psum on the
    combine leg, and ragged token counts pad the flattened token axis up
    to ``t_pad`` (the next shard multiple) with masked rows rather than
    forfeiting the a2a plan to the dense fallback.
    """
    model_size = int(mesh.shape[model_axis])
    ep, tp, _, _ = chunk_plan(cfg.moe.n_experts, model_size)
    shards = model_size
    for a in batch_axes:
        shards *= int(mesh.shape[a])
    t_pad = -(-t_total // shards) * shards
    return shards, ep, tp, t_pad


def moe_sharded_a2a(
    p: Dict[str, Any],
    x: jax.Array,                 # [B, S, d]
    cfg: ModelConfig,
    mesh: jax.sharding.Mesh,
    *,
    batch_axes: Tuple[str, ...] = ("data",),
    model_axis: str = "model",
    capacity_factor: float = 1.25,
) -> jax.Array:
    """Token-dispatch MoE: tokens sharded over (batch × model) axes, routed
    activations moved by a2a pairs; expert chunks stay put (EP × TP)."""
    from jax.experimental.shard_map import shard_map

    m = cfg.moe
    b, s, d = x.shape
    shards, ep, tp, t_pad = _a2a_plan(cfg, b * s, mesh, batch_axes,
                                      model_axis)
    t_loc = t_pad // shards
    # per-(source, expert) slots, sub-blocked n_e per destination rank
    _, _, n_e, _ = chunk_plan(m.n_experts, int(mesh.shape[model_axis]))
    cap_e = max(8, -(-int(t_loc * m.top_k * capacity_factor) // m.n_experts))
    capacity = n_e * cap_e
    model_size = int(mesh.shape[model_axis])
    axes = (*tuple(batch_axes), model_axis)
    axis_sizes = tuple(int(mesh.shape[a]) for a in axes)

    def body(xt, router, wg, wu, wd):
        y = _moe_local_a2a(
            xt, router, wg[0], wu[0], wd[0], cfg=cfg, axes=axes,
            axis_sizes=axis_sizes, model_axis=model_axis,
            model_size=model_size, capacity=capacity, t_valid=b * s)
        return y.astype(xt.dtype)

    xt = x.reshape(b * s, d)
    if t_pad != b * s:
        xt = jnp.pad(xt, ((0, t_pad - b * s), (0, 0)))
    spec = P(axes, None)
    out = shard_map(
        body, mesh=mesh,
        in_specs=(
            spec,
            P(None, None),
            P(model_axis, None, None, None),
            P(model_axis, None, None, None),
            P(model_axis, None, None, None),
        ),
        out_specs=spec,
        check_rep=False,
    )(xt, p["router"], p["experts"]["w_gate"],
      p["experts"]["w_up"], p["experts"]["w_down"])
    y = out[:b * s].reshape(b, s, d)
    if m.n_shared:
        y = y + mlp_apply(p["shared"], x, "swiglu")
    return y


# ---------------------------------------------------------------------------
# Dispatch autotuning: the DTD verdict, cached per cell
# ---------------------------------------------------------------------------

# (tokens_per_device, ep_degree, tp_degree, layer dims) -> prefer token
# a2a.  One pricing call per cell ever: decode/prefill shapes recur, so the
# verdict lookup is a dict hit on the trace path.
_DISPATCH_CACHE: Dict[Tuple[int, ...], bool] = {}


def dispatch_verdict(cfg: ModelConfig, tokens_per_device: int,
                     ep_degree: int, tp_degree: int = 1) -> bool:
    """Cached ``price_moe_dispatch`` verdict for one (T/device, ep, tp)
    cell — tp > 1 prices the chunked layout's partial-activation psum."""
    m = cfg.moe
    key = (tokens_per_device, ep_degree, tp_degree, cfg.d_model, m.top_k,
           m.n_experts, m.d_expert)
    v = _DISPATCH_CACHE.get(key)
    if v is None:
        from repro.dist.locality import price_moe_dispatch

        v = price_moe_dispatch(
            tokens_per_device, cfg.d_model, m.top_k, m.n_experts,
            m.d_expert, ep_degree, tp_degree=tp_degree).prefer_dispatch
        _DISPATCH_CACHE[key] = v
    return v


def moe_apply(
    p: Dict[str, Any],
    x: jax.Array,
    cfg: ModelConfig,
    mesh: Optional[jax.sharding.Mesh] = None,
    *,
    dispatch: str = "auto",
    **kw,
) -> jax.Array:
    """MoE layer entry point with autotuned dispatch.

    ``dispatch``: ``"auto"`` consults the cached
    :func:`repro.dist.locality.price_moe_dispatch` verdict for this
    (tokens_per_device, ep_degree, tp_degree) cell — token a2a when the
    routed activations are lighter on the wire than replication, the
    replicated-token path otherwise; ``"a2a"`` / ``"replicate"`` force a
    path.  The a2a path covers every chunk layout (tp > 1 dispatches to
    expert chunks with a partial psum combine) and every token count
    (ragged batches are padded and masked), so the forced path is taken
    verbatim.
    """
    # the dispatch-verdict span fires at jit-trace time — one event per
    # compiled (shape, path) cell, stamped at the recorder's last set_time;
    # this is exactly when the verdict is decided, so the trace records
    # which path each compilation cell took (the module-level recorder is
    # used because the layer has no engine/cluster to thread one through)
    tr = obs_trace.TRACE
    if mesh is None or mesh.shape.get("model", 1) == 1:
        if tr.enabled:
            tr.span("moe-dispatch", "moe", tr.time, 0.0, path="ref",
                    tokens=int(x.shape[0] * x.shape[1]))
        return moe_ref(p, x, cfg)
    if dispatch not in ("auto", "a2a", "replicate"):
        raise ValueError(f"unknown moe dispatch {dispatch!r}")
    use_a2a = False
    ep = tp = 0
    if dispatch != "replicate":
        b, s, _ = x.shape
        batch_axes = tuple(kw.get("batch_axes", ("data",)))
        model_axis = kw.get("model_axis", "model")
        shards, ep, tp, t_pad = _a2a_plan(cfg, b * s, mesh, batch_axes,
                                          model_axis)
        use_a2a = (
            dispatch == "a2a"
            or dispatch_verdict(cfg, t_pad // shards, ep, tp))
    if tr.enabled:
        tr.span("moe-dispatch", "moe", tr.time, 0.0,
                path="a2a" if use_a2a else "replicate",
                tokens=int(x.shape[0] * x.shape[1]), ep=ep, tp=tp)
    if use_a2a:
        return moe_sharded_a2a(p, x, cfg, mesh, **kw)
    return moe_sharded(p, x, cfg, mesh, **kw)
