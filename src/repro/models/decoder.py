"""Composable decoder/encoder stack: train forward, prefill and decode.

The stack is prefix (unrolled) + body (``lax.scan`` over stacked layer
groups) + suffix (unrolled), per :func:`repro.models.common.layer_plan`.
Every apply is a pure function of ``(params, batch)``; distribution comes
from a :class:`RunCtx` carrying the mesh and axis names (None = single
device, used by smoke tests).
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .attention import gqa_attention, init_attn_cache, mla_attention
from .common import (LayerKind, LayerPlan, ModelConfig, layer_plan, mlp_apply,
                     param_shapes, rms_norm)
from .moe import moe_apply
from .ssm import init_ssm_cache, mamba2_block


@dataclass(frozen=True)
class RunCtx:
    """Execution context: mesh, axis names, kernel/remat policy."""

    mesh: Optional[jax.sharding.Mesh] = None
    batch_axes: Tuple[str, ...] = ("data",)
    model_axis: str = "model"
    use_kernel: str = "auto"          # "auto" | "pallas" | "ref"
    remat: str = "none"               # "none" | "full" | "dots"
    capacity_factor: float = 1.25
    seq_axis: Optional[str] = None    # shard long KV caches over this axis

    @property
    def model_size(self) -> int:
        if self.mesh is None:
            return 1
        return int(self.mesh.shape[self.model_axis])

    @property
    def seq_size(self) -> int:
        """Devices along the seq axis (1 when the mesh exposes none)."""
        if self.mesh is None or self.seq_axis is None:
            return 1
        return int(dict(self.mesh.shape).get(self.seq_axis, 1))

    def seq_spec(self, seqlen: int) -> Optional[str]:
        """Seq-axis name if the mesh divides ``seqlen``, else None.

        The divisibility guard mirrors :mod:`repro.dist.sharding`: an
        indivisible (or unit) sequence dim is replicated, so decode steps
        (S=1) and smoke meshes share the sharded code path.
        """
        s = self.seq_size
        return self.seq_axis if s > 1 and seqlen % s == 0 else None

    def shard_act(self, x: jax.Array, *spec) -> jax.Array:
        if self.mesh is None:
            return x
        return jax.lax.with_sharding_constraint(
            x, jax.sharding.NamedSharding(self.mesh, P(*spec))
        )


# ---------------------------------------------------------------------------
# One block
# ---------------------------------------------------------------------------

def block_apply(
    cfg: ModelConfig,
    ctx: RunCtx,
    kind: LayerKind,
    p: Dict[str, Any],
    shared_attn_p: Optional[Dict[str, Any]],
    x: jax.Array,
    positions: jax.Array,
    *,
    cache: Optional[Dict[str, Any]] = None,
    cache_index: Optional[jax.Array] = None,
    return_cache: bool = False,
) -> Tuple[jax.Array, Dict[str, Any]]:
    eps, gm = cfg.norm_eps, cfg.gemma_norm
    # params may be stored fp32 (training master copies); compute in cfg.dtype
    cdt = cfg.compute_dtype()
    cast = lambda t: jax.tree.map(
        lambda a: a.astype(cdt) if jnp.issubdtype(a.dtype, jnp.floating) else a, t
    )
    p = cast(p)
    if shared_attn_p is not None:
        shared_attn_p = cast(shared_attn_p)
    new_cache: Dict[str, Any] = {}
    attn_kw = dict(
        cache=None if cache is None else cache.get("attn"),
        cache_index=cache_index,
        return_cache=return_cache,
        use_kernel=ctx.use_kernel,
        ctx=ctx,
    )

    if kind.mixer in ("attn", "attn_local"):
        h = rms_norm(x, p["ln_attn"], eps, gemma=gm)
        fn = mla_attention if cfg.mla is not None else gqa_attention
        a, c = fn(p["attn"], h, cfg, positions,
                  is_global=(kind.mixer == "attn"), **attn_kw)
        if gm and "ln_post_attn" in p:
            a = rms_norm(a, p["ln_post_attn"], eps, gemma=gm)
        x = x + a
        if return_cache:
            new_cache["attn"] = c
    elif kind.mixer == "shared_attn":
        h = rms_norm(x, shared_attn_p["ln_attn"], eps, gemma=gm)
        a, c = gqa_attention(shared_attn_p["attn"], h, cfg, positions,
                             is_global=True, **attn_kw)
        x = x + a
        if return_cache:
            new_cache["attn"] = c
    elif kind.mixer == "mamba":
        h = rms_norm(x, p["ln_mix"], eps, gemma=gm)
        y, c = mamba2_block(
            p["mamba"], h, cfg,
            cache=None if cache is None else cache.get("mamba"),
            return_cache=return_cache,
            use_kernel=ctx.use_kernel,
        )
        x = x + y
        if return_cache:
            new_cache["mamba"] = c
    else:
        raise ValueError(kind.mixer)

    if kind.ffn == "dense":
        h = rms_norm(x, p["ln_mlp"], eps, gemma=gm)
        f = mlp_apply(p["mlp"], h, cfg.mlp_act)
        if gm and "ln_post_mlp" in p:
            f = rms_norm(f, p["ln_post_mlp"], eps, gemma=gm)
        x = x + f
    elif kind.ffn == "moe":
        h = rms_norm(x, p["ln_mlp"], eps, gemma=gm)
        x = x + moe_apply(
            p["moe"], h, cfg, mesh=ctx.mesh,
            batch_axes=ctx.batch_axes, model_axis=ctx.model_axis,
            capacity_factor=ctx.capacity_factor,
        )
    # residual boundary: batch over the data axes and, for multi-token
    # passes on a seq-bearing mesh, sequence over the seq axis (long-context
    # prefill work is then partitioned like its KV cache)
    x = ctx.shard_act(x, ctx.batch_axes, ctx.seq_spec(x.shape[1]), None)
    return x, new_cache


# ---------------------------------------------------------------------------
# Stack
# ---------------------------------------------------------------------------

def _remat_wrap(fn, remat: str):
    if remat == "none":
        return fn
    if remat == "full":
        return jax.checkpoint(fn)
    if remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        )
    raise ValueError(remat)


def _unrolled_names(params_sub: Dict[str, Any]) -> list:
    return sorted(params_sub, key=lambda s: int(s.removeprefix("layer")))


def stack_apply(
    cfg: ModelConfig,
    ctx: RunCtx,
    params: Dict[str, Any],
    x: jax.Array,
    positions: jax.Array,
    *,
    caches: Optional[Dict[str, Any]] = None,
    cache_index: Optional[jax.Array] = None,
    return_cache: bool = False,
) -> Tuple[jax.Array, Optional[Dict[str, Any]]]:
    plan = layer_plan(cfg)
    kinds = plan.kinds
    shared_p = params.get("shared_attn")
    new_caches: Dict[str, Any] = {"prefix": [], "body": None, "suffix": []}

    def one(kind, p, xx, cc):
        return block_apply(
            cfg, ctx, kind, p, shared_p, xx, positions,
            cache=cc, cache_index=cache_index, return_cache=return_cache,
        )

    # --- prefix (unrolled) ---------------------------------------------------
    if plan.prefix:
        for i, name in enumerate(_unrolled_names(params["prefix"])):
            cc = caches["prefix"][i] if caches is not None else None
            x, nc = one(kinds[i], params["prefix"][name], x, cc)
            new_caches["prefix"].append(nc)

    # --- body (scanned over groups) -------------------------------------------
    if plan.n_groups:
        def group_body(xx, scanned):
            gp, gc = scanned
            ncs = []
            for j in range(plan.period):
                cc = None if gc is None else gc[j]
                xx, nc = one(kinds[plan.prefix + j], gp[f"pos{j}"], xx, cc)
                ncs.append(nc)
            return xx, ncs

        group_fn = _remat_wrap(group_body, ctx.remat)
        body_caches = caches["body"] if caches is not None else None
        if body_caches is None:
            body_caches_xs = [None] * plan.period
            xs = (params["blocks"], None)

            def scan_fn(xx, gp):
                xx, ncs = group_fn(xx, (gp, None))
                return xx, ncs if return_cache else None

            x, ys = jax.lax.scan(scan_fn, x, params["blocks"])
        else:
            def scan_fn(xx, scanned):
                xx, ncs = group_fn(xx, scanned)
                return xx, ncs if return_cache else None

            x, ys = jax.lax.scan(scan_fn, x, (params["blocks"], body_caches))
        new_caches["body"] = ys

    # --- suffix (unrolled) ------------------------------------------------------
    if plan.suffix:
        for i, name in enumerate(_unrolled_names(params["suffix"])):
            li = plan.suffix_start + i
            cc = caches["suffix"][i] if caches is not None else None
            x, nc = one(kinds[li], params["suffix"][name], x, cc)
            new_caches["suffix"].append(nc)

    return x, (new_caches if return_cache else None)


# ---------------------------------------------------------------------------
# Model-level entry points
# ---------------------------------------------------------------------------

def embed_in(cfg: ModelConfig, params, batch: Dict[str, jax.Array]) -> jax.Array:
    """Token ids -> embeddings, or pass through stub-frontend features."""
    if "embeds" in batch:
        x = batch["embeds"].astype(jnp.dtype(cfg.dtype))
    else:
        x = jnp.take(params["embed"], batch["tokens"], axis=0).astype(jnp.dtype(cfg.dtype))
    if cfg.gemma_norm:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
    return x


def lm_logits(cfg: ModelConfig, ctx: RunCtx, params, x: jax.Array) -> jax.Array:
    x = rms_norm(x, params["final_norm"].astype(x.dtype), cfg.norm_eps,
                 gemma=cfg.gemma_norm)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = x @ head.astype(x.dtype)
    if cfg.logit_softcap > 0:
        logits = cfg.logit_softcap * jnp.tanh(logits / cfg.logit_softcap)
    return logits


def forward(
    cfg: ModelConfig,
    ctx: RunCtx,
    params: Dict[str, Any],
    batch: Dict[str, jax.Array],
) -> jax.Array:
    """Full-sequence forward -> logits [B, S, V]."""
    x = embed_in(cfg, params, batch)
    x = ctx.shard_act(x, ctx.batch_axes, ctx.seq_spec(x.shape[1]), None)
    positions = batch.get("positions")
    if positions is None:
        b, s = x.shape[:2]
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    x, _ = stack_apply(cfg, ctx, params, x, positions)
    return lm_logits(cfg, ctx, params, x)


def loss_fn(
    cfg: ModelConfig,
    ctx: RunCtx,
    params: Dict[str, Any],
    batch: Dict[str, jax.Array],
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Mean next-token (or masked-frame) cross entropy; labels < 0 ignored."""
    logits = forward(cfg, ctx, params, batch).astype(jnp.float32)
    labels = batch["labels"]
    mask = (labels >= 0).astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    picked = jnp.take_along_axis(
        logits, jnp.maximum(labels, 0)[..., None], axis=-1
    )[..., 0]
    nll = (lse - picked) * mask
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    loss = jnp.sum(nll) / denom
    return loss, {"loss": loss, "ntokens": jnp.sum(mask)}


# ---------------------------------------------------------------------------
# Caches: allocation + prefill + decode
# ---------------------------------------------------------------------------

def _layer_cache(cfg: ModelConfig, kind: LayerKind, batch: int, max_len: int, dtype):
    if kind.mixer in ("attn", "attn_local", "shared_attn"):
        win = cfg.sliding_window
        ln = max_len
        if kind.mixer == "attn_local" and win is not None:
            ln = min(max_len, win)  # ring-capped local cache (allocated full
            # here for simplicity of positions; engine may cap)
            ln = max_len
        return {"attn": init_attn_cache(cfg, batch, ln, dtype)}
    if kind.mixer == "mamba":
        return {"mamba": init_ssm_cache(cfg, batch, dtype)}
    raise ValueError(kind.mixer)


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    plan = layer_plan(cfg)
    kinds = plan.kinds
    out: Dict[str, Any] = {"prefix": [], "body": None, "suffix": []}
    for i in range(plan.prefix):
        out["prefix"].append(_layer_cache(cfg, kinds[i], batch, max_len, dtype))
    if plan.n_groups:
        body = []
        for j in range(plan.period):
            one = _layer_cache(cfg, kinds[plan.prefix + j], batch, max_len, dtype)
            body.append(jax.tree.map(
                lambda a: jnp.broadcast_to(a[None], (plan.n_groups,) + a.shape).copy()
                if False else jnp.zeros((plan.n_groups,) + a.shape, a.dtype),
                one,
            ))
        out["body"] = body
    for i in range(plan.suffix):
        out["suffix"].append(
            _layer_cache(cfg, kinds[plan.suffix_start + i], batch, max_len, dtype)
        )
    return out


def prefill(
    cfg: ModelConfig,
    ctx: RunCtx,
    params: Dict[str, Any],
    batch: Dict[str, jax.Array],
    max_len: Optional[int] = None,
) -> Tuple[jax.Array, Dict[str, Any]]:
    """Run the prompt, return (last-position logits [B, V], cache).

    The returned cache holds exactly the prompt (length S); the serving
    engine pads/relocates it into its ring buffers.
    """
    x = embed_in(cfg, params, batch)
    x = ctx.shard_act(x, ctx.batch_axes, ctx.seq_spec(x.shape[1]), None)
    positions = batch.get("positions")
    if positions is None:
        b, s = x.shape[:2]
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    x, caches = stack_apply(
        cfg, ctx, params, x, positions, return_cache=True
    )
    logits = lm_logits(cfg, ctx, params, x[:, -1:, :])
    return logits[:, 0, :], caches


def decode_step(
    cfg: ModelConfig,
    ctx: RunCtx,
    params: Dict[str, Any],
    caches: Dict[str, Any],
    tokens: jax.Array,           # [B] int32 (or embeds [B, 1, d])
    pos: jax.Array,              # () or [B] int32 — write position(s)
) -> Tuple[jax.Array, Dict[str, Any]]:
    """One autoregressive step over a pre-allocated cache; returns logits [B, V].

    A scalar ``pos`` steps all sequences in lockstep; a ``[B]`` vector is
    the continuous-batching path (each session at its own depth).
    """
    if tokens.ndim == 1:
        x = jnp.take(params["embed"], tokens[:, None], axis=0).astype(jnp.dtype(cfg.dtype))
        if cfg.gemma_norm:
            x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
    else:
        x = tokens.astype(jnp.dtype(cfg.dtype))
    b = x.shape[0]
    pos = jnp.asarray(pos, jnp.int32)
    if pos.ndim == 0:
        positions = jnp.broadcast_to(pos[None, None], (b, 1))
    else:
        positions = pos[:, None]
    if cfg.mrope_sections is not None:
        positions = jnp.broadcast_to(positions[None], (3, b, 1))
    x, new_caches = stack_apply(
        cfg, ctx, params, x, positions,
        caches=caches, cache_index=pos, return_cache=True,
    )
    logits = lm_logits(cfg, ctx, params, x)
    return logits[:, 0, :], new_caches
