"""Attention mixers: GQA (causal / bidirectional / sliding-window), MLA.

All functions are pure; KV caches are explicit pytrees threaded by the
caller.  Three entry points per mixer:

* ``*_train``   — full-sequence forward (no cache), used by train steps and
  encoder forwards;
* ``*_prefill`` — full-sequence forward that also returns the populated cache;
* ``*_decode``  — single-token step consuming/updating the cache.

The inner attention product dispatches to the Pallas flash kernel on TPU
(``repro.kernels.flash_attention``) and to the fused-mask jnp reference on
other backends (and always under ``interpret`` tests).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .common import ModelConfig, apply_rope, rms_norm

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def _cache_update(buf: jax.Array, new: jax.Array, index) -> jax.Array:
    """Write ``new`` into the seq axis (1) at scalar or per-row ``index``."""
    new = new.astype(buf.dtype)
    if jnp.ndim(index) == 0:
        return jax.lax.dynamic_update_slice_in_dim(buf, new, index, axis=1)
    return jax.vmap(
        lambda c, n, i: jax.lax.dynamic_update_slice_in_dim(c, n, i, axis=0)
    )(buf, new, index.astype(jnp.int32))


def _shard_kv(ctx, arr: jax.Array) -> jax.Array:
    """Constrain a KV buffer [B, S, ...] onto the ctx mesh's cache layout.

    Delegates to :func:`repro.dist.sharding.kv_buffer_spec` — the same rule
    ``cache_pspecs`` allocates with — so the in-step constraint and the
    ``KVStore`` placement cannot drift apart.  With the constraint inside
    the jitted step, GSPMD keeps the cache resident in its sharded
    placement across decode steps and partitions the score/context
    products over the seq shards, gathering only the O(S·d) softmax
    statistics instead of re-laying-out the cache.
    """
    if ctx is None or ctx.mesh is None:
        return arr
    from repro.dist.sharding import kv_buffer_spec

    spec = kv_buffer_spec(
        arr.shape, bdim=0, batch=ctx.batch_axes,
        model=ctx.model_axis, msize=ctx.model_size,
        seq=ctx.seq_axis, ssize=ctx.seq_size)
    return jax.lax.with_sharding_constraint(
        arr, jax.sharding.NamedSharding(ctx.mesh, spec))


# ---------------------------------------------------------------------------
# Masking
# ---------------------------------------------------------------------------

def attn_mask(
    q_pos: jax.Array,            # [B, Sq] absolute positions of the queries
    kv_pos: jax.Array,           # [B, Skv]
    causal: bool,
    sliding_window: Optional[int],
) -> jax.Array:
    """Boolean [B, Sq, Skv] mask (True = attend)."""
    dq = q_pos[:, :, None]
    dk = kv_pos[:, None, :]
    m = jnp.ones((q_pos.shape[0], q_pos.shape[1], kv_pos.shape[1]), bool)
    if causal:
        m &= dk <= dq
    if sliding_window is not None:
        m &= dk > dq - sliding_window
    return m


def _sdpa_ref(
    q: jax.Array,                # [B, Sq, Hq, D]
    k: jax.Array,                # [B, Skv, Hkv, D]
    v: jax.Array,                # [B, Skv, Hkv, Dv]
    mask: jax.Array,             # [B, Sq, Skv] bool
    scale: float,
    logit_softcap: float = 0.0,
) -> jax.Array:
    """Pure-jnp grouped-query attention (the oracle path)."""
    b, sq, hq, d = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    qg = q.reshape(b, sq, hkv, g, d)
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if logit_softcap > 0:
        logits = logit_softcap * jnp.tanh(logits / logit_softcap)
    logits = jnp.where(mask[:, None, None, :, :], logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return out.reshape(b, sq, hq, v.shape[-1]).astype(q.dtype)


def sdpa(
    q, k, v, *,
    q_positions: jax.Array,
    kv_positions: jax.Array,
    causal: bool,
    sliding_window: Optional[int] = None,
    logit_softcap: float = 0.0,
    scale: Optional[float] = None,
    use_kernel: str = "auto",
) -> jax.Array:
    """Scaled dot-product attention with GQA + optional flash kernel."""
    scale = scale if scale is not None else q.shape[-1] ** -0.5
    if use_kernel == "auto":
        use_kernel = "pallas" if jax.default_backend() == "tpu" else "ref"
    if use_kernel == "pallas" and q.shape[1] > 1:
        from repro.kernels import flash_attention as fa

        return fa.flash_attention(
            q, k, v,
            q_positions=q_positions, kv_positions=kv_positions,
            causal=causal, sliding_window=sliding_window,
            logit_softcap=logit_softcap, scale=scale,
        )
    mask = attn_mask(q_positions, kv_positions, causal, sliding_window)
    return _sdpa_ref(q, k, v, mask, scale, logit_softcap)


# ---------------------------------------------------------------------------
# GQA attention layer
# ---------------------------------------------------------------------------

def _split_heads(x: jax.Array, n_heads: int) -> jax.Array:
    b, s, _ = x.shape
    return x.reshape(b, s, n_heads, -1)


def gqa_project_qkv(
    p: Dict[str, jax.Array],
    x: jax.Array,                  # [B, S, d]
    cfg: ModelConfig,
    positions: jax.Array,          # [B, S] or [3, B, S]
    rope_theta: float,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    q = _split_heads(x @ p["wq"], cfg.n_heads)
    k = _split_heads(x @ p["wk"], cfg.n_kv_heads)
    v = _split_heads(x @ p["wv"], cfg.n_kv_heads)
    if cfg.use_qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps, gemma=cfg.gemma_norm)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps, gemma=cfg.gemma_norm)
    q = apply_rope(q, positions, rope_theta, cfg.partial_rotary, cfg.mrope_sections)
    k = apply_rope(k, positions, rope_theta, cfg.partial_rotary, cfg.mrope_sections)
    return q, k, v


def gqa_attention(
    p: Dict[str, jax.Array],
    x: jax.Array,
    cfg: ModelConfig,
    positions: jax.Array,
    *,
    is_global: bool = True,
    cache: Optional[Dict[str, jax.Array]] = None,
    cache_index: Optional[jax.Array] = None,
    return_cache: bool = False,
    use_kernel: str = "auto",
    ctx=None,
) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    """One GQA attention block (no residual / norm — the caller owns those).

    ``cache`` (decode/prefill): dict(k=[B, S_max, Hkv, D], v=...).  In decode,
    ``x`` is [B, 1, d] and ``cache_index`` is the write offset.

    When the head count does not divide the model mesh axis (e.g. 24 heads
    on a 16-way axis), head TP is impossible without splitting head_dim —
    which GSPMD resolves by all-reducing the full [S, S] score matrix.
    Instead we switch to *sequence-parallel attention*: the query sequence
    dim is sharded over the model axis (k/v stay whole), so the quadratic
    score work is partitioned with only O(S·d)-sized gathers.
    """
    theta = cfg.rope_theta
    window = None
    if not is_global and cfg.sliding_window is not None:
        window = cfg.sliding_window
    elif is_global and cfg.rope_theta_global is not None:
        theta = cfg.rope_theta_global

    q, k, v = gqa_project_qkv(p, x, cfg, positions, theta)
    q_pos = positions[0] if positions.ndim == 3 else positions

    seq_parallel = (
        ctx is not None and ctx.mesh is not None and x.shape[1] > 1
        and cfg.n_heads % ctx.model_size != 0
        and x.shape[1] % ctx.model_size == 0
    )
    if seq_parallel:
        q = ctx.shard_act(q, ctx.batch_axes, ctx.model_axis, None, None)
        k = ctx.shard_act(k, ctx.batch_axes, None, None, None)
        v = ctx.shard_act(v, ctx.batch_axes, None, None, None)

    new_cache = None
    if cache is not None and cache_index is not None:
        # decode: append to the cache ring.  cache_index is a scalar (all
        # sequences aligned) or a [B] vector (continuous batching).
        b = x.shape[0]
        k_all = _shard_kv(ctx, _cache_update(cache["k"], k, cache_index))
        v_all = _shard_kv(ctx, _cache_update(cache["v"], v, cache_index))
        if return_cache:
            new_cache = {"k": k_all, "v": v_all}
        kv_pos = jnp.arange(cache["k"].shape[1], dtype=jnp.int32)[None, :]
        kv_pos = jnp.broadcast_to(kv_pos, (b, cache["k"].shape[1]))
        # entries beyond the current write point are invalid -> mask via pos
        valid_upto = cache_index + x.shape[1]
        if jnp.ndim(valid_upto) == 1:
            valid_upto = valid_upto[:, None]
        kv_pos = jnp.where(kv_pos < valid_upto, kv_pos, jnp.int32(2**30))
        out = sdpa(
            q, k_all, v_all,
            q_positions=q_pos, kv_positions=kv_pos,
            causal=cfg.causal, sliding_window=window,
            logit_softcap=0.0, use_kernel=use_kernel,
        )
    else:
        if return_cache:
            # the prefill cache leaves in the long-context layout (seq
            # sharded) even though the score product below keeps k/v whole
            new_cache = {"k": _shard_kv(ctx, k), "v": _shard_kv(ctx, v)}
        out = sdpa(
            q, k, v,
            q_positions=q_pos, kv_positions=q_pos,
            causal=cfg.causal, sliding_window=window,
            logit_softcap=0.0, use_kernel=use_kernel,
        )
    b, s = x.shape[:2]
    out = out.reshape(b, s, -1)
    if seq_parallel:
        # the output projection is row-local on the S-sharded activations;
        # GSPMD re-gathers S at the residual boundary (Megatron-SP style)
        out = ctx.shard_act(out, ctx.batch_axes, ctx.model_axis, None)
    return out @ p["wo"], new_cache


# ---------------------------------------------------------------------------
# MLA — DeepSeek-V2 multi-head latent attention
# ---------------------------------------------------------------------------
#
# The KV cache stores only the compressed latent c_kv [B, S, kv_lora] and the
# decoupled rope key k_pe [B, S, rope_dim] — 576 values/token/layer — which is
# the paper-exact memory saving that makes 500k-token decode shardable.

def mla_attention(
    p: Dict[str, jax.Array],
    x: jax.Array,
    cfg: ModelConfig,
    positions: jax.Array,
    *,
    cache: Optional[Dict[str, jax.Array]] = None,
    cache_index: Optional[jax.Array] = None,
    return_cache: bool = False,
    use_kernel: str = "auto",
    is_global: bool = True,
    ctx=None,
) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    m = cfg.mla
    b, s, _ = x.shape
    h = cfg.n_heads
    qk_dim = m.qk_nope_head_dim + m.qk_rope_head_dim

    # --- queries (low-rank) -------------------------------------------------
    cq = rms_norm(x @ p["wq_a"], p["q_norm"], cfg.norm_eps)
    q = (cq @ p["wq_b"]).reshape(b, s, h, qk_dim)
    q_nope, q_pe = q[..., : m.qk_nope_head_dim], q[..., m.qk_nope_head_dim:]
    q_pe = apply_rope(q_pe, positions, cfg.rope_theta)

    # --- compressed KV latent ------------------------------------------------
    ckv_full = x @ p["wkv_a"]                              # [B,S,kv_lora+rope]
    c_kv = rms_norm(ckv_full[..., : m.kv_lora_rank], p["kv_norm"], cfg.norm_eps)
    k_pe = apply_rope(ckv_full[..., None, m.kv_lora_rank:], positions, cfg.rope_theta)
    k_pe = k_pe[..., 0, :]                                 # [B,S,rope_dim]

    q_pos = positions[0] if positions.ndim == 3 else positions
    if cache is not None and cache_index is not None:
        c_all = _shard_kv(ctx, _cache_update(cache["c_kv"], c_kv, cache_index))
        pe_all = _shard_kv(ctx, _cache_update(cache["k_pe"], k_pe, cache_index))
        if return_cache:
            new_cache = {"c_kv": c_all, "k_pe": pe_all}
        else:
            new_cache = None
        skv = c_all.shape[1]
        kv_pos = jnp.broadcast_to(jnp.arange(skv, dtype=jnp.int32)[None, :], (b, skv))
        valid_upto = cache_index + s
        if jnp.ndim(valid_upto) == 1:
            valid_upto = valid_upto[:, None]
        kv_pos = jnp.where(kv_pos < valid_upto, kv_pos, jnp.int32(2**30))
        c_kv_use, k_pe_use = c_all, pe_all
    else:
        new_cache = ({"c_kv": _shard_kv(ctx, c_kv),
                      "k_pe": _shard_kv(ctx, k_pe)} if return_cache else None)
        skv = s
        kv_pos = q_pos
        c_kv_use, k_pe_use = c_kv, k_pe

    # --- expand latent to per-head K/V (absorbed form for decode) -----------
    wkv_b = p["wkv_b"].reshape(m.kv_lora_rank, h, m.qk_nope_head_dim + m.v_head_dim)
    w_k = wkv_b[..., : m.qk_nope_head_dim]                 # [r, h, dk]
    w_v = wkv_b[..., m.qk_nope_head_dim:]                  # [r, h, dv]
    scale = qk_dim ** -0.5
    if s == 1 and cache is not None:
        # decode: absorb w_k into the query -> score directly in latent space,
        # never materializing [B, Skv, h, dk].  FLOPs/token: h*(dk*r + r) per
        # key instead of expanding the whole cache.
        q_lat = jnp.einsum("bqhd,rhd->bqhr", q_nope.astype(jnp.float32), w_k.astype(jnp.float32))
        logits = jnp.einsum("bqhr,bkr->bhqk", q_lat, c_kv_use.astype(jnp.float32))
        logits += jnp.einsum("bqhd,bkd->bhqk", q_pe.astype(jnp.float32),
                             k_pe_use.astype(jnp.float32))
        logits *= scale
        mask = attn_mask(q_pos, kv_pos, cfg.causal, None)
        logits = jnp.where(mask[:, None, :, :], logits, NEG_INF)
        pr = jax.nn.softmax(logits, axis=-1)
        ctx_lat = jnp.einsum("bhqk,bkr->bqhr", pr, c_kv_use.astype(jnp.float32))
        out = jnp.einsum("bqhr,rhd->bqhd", ctx_lat, w_v.astype(jnp.float32)).astype(x.dtype)
    else:
        k_nope = jnp.einsum("bkr,rhd->bkhd", c_kv_use, w_k.astype(c_kv_use.dtype))
        v_full = jnp.einsum("bkr,rhd->bkhd", c_kv_use, w_v.astype(c_kv_use.dtype))
        k_full = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_pe_use[:, :, None, :], (b, skv, h, m.qk_rope_head_dim))],
            axis=-1,
        )
        q_full = jnp.concatenate([q_nope, q_pe], axis=-1)
        out = sdpa(
            q_full, k_full, v_full,
            q_positions=q_pos, kv_positions=kv_pos,
            causal=cfg.causal, sliding_window=None,
            scale=scale, use_kernel=use_kernel,
        )
    return out.reshape(b, s, -1) @ p["wo"], new_cache


# ---------------------------------------------------------------------------
# Cache allocation
# ---------------------------------------------------------------------------

def init_attn_cache(cfg: ModelConfig, batch: int, max_len: int, dtype) -> Dict[str, Any]:
    """Zeroed per-layer cache entry for one attention layer."""
    if cfg.mla is not None:
        m = cfg.mla
        return {
            "c_kv": jnp.zeros((batch, max_len, m.kv_lora_rank), dtype),
            "k_pe": jnp.zeros((batch, max_len, m.qk_rope_head_dim), dtype),
        }
    return {
        "k": jnp.zeros((batch, max_len, cfg.n_kv_heads, cfg.head_dim), dtype),
        "v": jnp.zeros((batch, max_len, cfg.n_kv_heads, cfg.head_dim), dtype),
    }
