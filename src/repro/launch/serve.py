"""Serving driver: multi-pod engine with the Lilac locality router.

Real decode on host devices (RealBackend) for smoke-scale models, or the
roofline-priced SimBackend for full assigned-architecture configs:

    PYTHONPATH=src python -m repro.launch.serve --arch glm4-9b --preset smoke \
        --pods 2 --requests 64
    PYTHONPATH=src python -m repro.launch.serve --arch deepseek-v2-236b \
        --backend sim --pods 8 --requests 512 --locality 0.8
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.dist.locality import ROUTER_DEFAULTS
from repro.models import decoder
from repro.models.common import init_params
from repro.serve.engine import MultiPodEngine, RealBackend, Request, SimBackend
from repro.serve.router import ARBITRATIONS, LocalityRouter


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="glm4-9b")
    ap.add_argument("--preset", default="smoke", choices=["smoke", "full"])
    ap.add_argument("--backend", default="real", choices=["real", "sim"])
    ap.add_argument("--pods", type=int, default=2)
    ap.add_argument("--policy", default=ROUTER_DEFAULTS.policy,
                    choices=["local", "short", "long"])
    ap.add_argument("--arbitration", default=ROUTER_DEFAULTS.arbitration,
                    choices=list(ARBITRATIONS))
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--sessions", type=int, default=16)
    ap.add_argument("--tokens-per-request", type=int, default=4)
    ap.add_argument("--locality", type=float, default=0.8)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--seq-axis", type=int, default=0, metavar="N",
                    help="shard KV seq dims over an N-way seq mesh axis "
                         "(0 = off); the sim backend uses N for pricing only")
    ap.add_argument("--plan-epoch-ms", type=float, default=0.0,
                    help="run the proactive placement planner (repro.plan) "
                         "every this many ms of simulated time (0 = off): "
                         "affinity-scored lease prefetch + session re-homes "
                         "off the critical path")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--trace", default=None, metavar="OUT.json",
                    help="record a repro.obs timeline of the run (routing, "
                         "lease acquires, certify batches, decode spans, "
                         "planner epochs, MoE dispatch verdicts) and export "
                         "Perfetto trace_event JSON here")
    args = ap.parse_args(argv)

    recorder = None
    if args.trace:
        from repro.obs import trace as obs_trace

        recorder = obs_trace.TraceRecorder()
        # installed module-wide too, so jit-trace-time sites with no engine
        # to thread through (models/moe.py) land in the same timeline
        obs_trace.install(recorder)

    cfg = (get_smoke_config(args.arch) if args.preset == "smoke"
           else get_config(args.arch))
    if not cfg.causal:
        raise SystemExit(f"{args.arch} is encoder-only: no decode serving")

    if args.backend == "real":
        mesh = None
        seq_axis = None
        if args.seq_axis > 0:
            from repro.launch.mesh import make_host_mesh
            mesh = make_host_mesh(model=1, seq=args.seq_axis)
            if "seq" in mesh.axis_names:
                seq_axis = "seq"
        ctx = decoder.RunCtx(mesh=mesh, batch_axes=("data",),
                             use_kernel="auto", seq_axis=seq_axis)
        params = init_params(cfg, jax.random.PRNGKey(args.seed))
        backend = RealBackend(cfg, ctx, params, n_pods=args.pods,
                              n_slots=max(8, args.sessions), max_len=args.max_len)
        kv_per_tok = 256.0
        seq_shards = backend.seq_shards
    else:
        backend = SimBackend(cfg)
        kv_per_tok = (2.0 * 2 * cfg.n_kv_heads * cfg.head_dim * cfg.n_layers
                      if cfg.n_kv_heads else 4096.0 * cfg.n_layers)
        seq_shards = max(1, args.seq_axis)

    router = LocalityRouter(args.pods, policy=args.policy,
                            arbitration=args.arbitration,
                            kv_bytes_per_token=kv_per_tok,
                            seq_shards=seq_shards)
    planner = None
    if args.plan_epoch_ms > 0:
        from repro.dist.sharding import make_plan_mesh
        from repro.plan import PlacementPlanner
        planner = PlacementPlanner.for_serving(
            args.pods, args.sessions, epoch_ms=args.plan_epoch_ms,
            mesh=make_plan_mesh())
    eng = MultiPodEngine(args.pods, backend, router, planner=planner,
                         trace=recorder)
    rng = np.random.default_rng(args.seed)
    submitted = 0
    while submitted < args.requests:
        for _ in range(min(args.pods * 2, args.requests - submitted)):
            sid = int(rng.integers(args.sessions))
            home = sid % args.pods
            origin = home if rng.random() < args.locality else int(rng.integers(args.pods))
            eng.submit(Request(sid=sid, origin=origin,
                               n_tokens=args.tokens_per_request))
            submitted += 1
        eng.run_step()
    eng.drain()
    m = eng.metrics.as_dict()
    print(f"arch={cfg.name} pods={args.pods} policy={args.policy} "
          f"arbitration={args.arbitration} locality={args.locality} "
          f"seq_shards={seq_shards:g}")
    print(f"tokens={m['tokens']} forwards={m['forwards']} "
          f"kv_migrations={m['transfers']} wire={m['wire_GB']:.4f}GB "
          f"lease_reuse={router.metrics.lease_reuse_rate:.3f}")
    if planner is not None:
        print(f"planner: epochs={m['plan_epochs']} moves={m['plan_moves']} "
              f"prefetches={m['plan_prefetches']} "
              f"planned={m['plan_GB']:.4f}GB")
    if args.backend == "sim":
        print(f"simulated throughput: {m['tokens_per_s']:.0f} tok/s")
    print(f"token latency: p50={m['token_lat_p50_s']:.4g}s "
          f"p99={m['token_lat_p99_s']:.4g}s")
    if recorder is not None:
        from repro.obs import trace as obs_trace

        obs_trace.uninstall()
        recorder.export(args.trace)
        print(f"trace: {len(recorder)} events -> {args.trace}")
    return m


if __name__ == "__main__":
    main()
