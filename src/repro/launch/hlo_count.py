"""Trip-count-aware HLO cost model (FLOPs + collectives).

XLA's ``compiled.cost_analysis()`` counts each ``while`` body **once**
(verified experimentally: a 10-iteration ``lax.scan`` of matmuls reports
exactly 1/10 of the true FLOPs), which silently misprices every
scan-over-layers model and every collective inside the scanned body.

This module re-derives costs from the optimized HLO text with call-graph
multiplicity:

* computations are parsed into instruction lists with a name -> shape table;
* ``while`` trip counts come from the loop-condition computation (the
  ``constant(N)`` compared against the induction variable — exact for
  ``lax.scan``/``fori_loop`` lowerings);
* a DFS from ENTRY propagates multiplicity through while bodies, fusions,
  calls and conditionals;
* per instruction: ``dot`` FLOPs are ``2 · prod(result) · contraction``
  (read off ``dot_dimension_numbers`` + operand shapes); elementwise /
  reduce ops count 1 FLOP/elem (dots dominate);
* collectives reuse the ring-cost model of :mod:`hlo_analysis`, now
  weighted by multiplicity.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from .hlo_analysis import (COLLECTIVES, CollectiveStats, _DTYPE_BYTES,
                           _group_size)

_COMP_HEAD_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\("
)
_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_OPERANDS_RE = re.compile(r"%([\w.\-]+)")
_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_TO_APPLY_RE = re.compile(r"to_apply=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_LHS_C_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_LHS_B_RE = re.compile(r"lhs_batch_dims=\{([0-9,]*)\}")

_ELEMWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "power",
    "exponential", "tanh", "log", "rsqrt", "sqrt", "negate", "abs",
    "logistic", "cosine", "sine", "select", "compare", "and", "or", "xor",
    "clamp", "floor", "ceil", "round-nearest-even", "sign", "atan2",
    "exponential-minus-one", "log-plus-one", "reduce", "erf",
}


def _shape_elems(ty: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(ty):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n
    return total


def _shape_bytes_ty(ty: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(ty):
        bs = _DTYPE_BYTES.get(dt)
        if bs is None:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * bs
    return total


@dataclass
class Instr:
    name: str
    ty: str
    opcode: str
    line: str


@dataclass
class Computation:
    name: str
    instrs: List[Instr] = field(default_factory=list)
    shapes: Dict[str, str] = field(default_factory=dict)


def parse_module(text: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    comps: Dict[str, Computation] = {}
    entry: Optional[str] = None
    cur: Optional[Computation] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        s = line.strip()
        if cur is None:
            # computation header: column-0 "<name> (params) -> type {"
            if (s.endswith("{") and "->" in s and line and not line[0].isspace()
                    and (s.startswith("%") or s.startswith("ENTRY"))):
                m = _COMP_HEAD_RE.match(s)
                if m:
                    cur = Computation(m.group(2))
                    if m.group(1):
                        entry = m.group(2)
                continue
        else:
            if s == "}":
                comps[cur.name] = cur
                cur = None
                continue
            m = _INSTR_RE.match(line)
            if m:
                ins = Instr(m.group(1), m.group(2), m.group(3), line)
                cur.instrs.append(ins)
                cur.shapes[ins.name] = ins.ty
    if cur is not None:
        comps[cur.name] = cur
    return comps, entry


def _trip_count(cond: Computation) -> int:
    """lax.scan lowers to (i < N): the compare constant is the trip count."""
    best = 1
    for ins in cond.instrs:
        if "constant(" in ins.line:
            for c in _CONST_RE.findall(ins.line):
                best = max(best, int(c))
    return best


def _dot_flops(ins: Instr, shapes: Dict[str, str]) -> float:
    ops = _OPERANDS_RE.findall(ins.line[ins.line.index("(") :])
    if not ops:
        return 0.0
    lhs_ty = shapes.get(ops[0], "")
    lhs_dims: List[int] = []
    m = _SHAPE_RE.search(lhs_ty)
    if m:
        lhs_dims = [int(d) for d in m.group(2).split(",") if d]
    contr = _LHS_C_RE.search(ins.line)
    k = 1
    if contr and lhs_dims:
        for d in contr.group(1).split(","):
            if d and int(d) < len(lhs_dims):
                k *= lhs_dims[int(d)]
    out_elems = _shape_elems(ins.ty)
    return 2.0 * out_elems * k


@dataclass
class HloCosts:
    flops: float = 0.0
    elemwise_flops: float = 0.0
    collectives: CollectiveStats = field(default_factory=CollectiveStats)
    n_while: int = 0
    trip_counts: List[int] = field(default_factory=list)

    def as_dict(self) -> Dict:
        return {
            "flops": self.flops,
            "elemwise_flops": self.elemwise_flops,
            "collectives": self.collectives.as_dict(),
            "n_while": self.n_while,
            "trip_counts": self.trip_counts,
        }


def _collective_line(kind: str, ins: Instr, mult: float, st: CollectiveStats):
    shapes = _SHAPE_RE.findall(ins.ty)
    sizes, f32_sizes = [], []
    for dt, dims in shapes:
        bs = _DTYPE_BYTES.get(dt)
        if bs is None:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        sizes.append(n * bs)
        f32_sizes.append(dt in ("f32", "f64"))
    if ins.opcode.endswith("-start") and len(sizes) > 1:
        sizes = sizes[len(sizes) // 2:]
        f32_sizes = f32_sizes[len(f32_sizes) // 2:]
    size = sum(sizes)
    size_f32 = sum(s for s, is32 in zip(sizes, f32_sizes) if is32)
    g = _group_size(ins.line)
    ring = (g - 1) / g if g > 1 else 0.0
    if kind == "all-reduce":
        factor = 2.0 * ring
    elif kind == "all-gather":
        factor = ring
    elif kind == "reduce-scatter":
        factor = float(g - 1)
    elif kind == "all-to-all":
        factor = ring
    else:
        factor = 1.0
    st.bytes_by_kind[kind] = st.bytes_by_kind.get(kind, 0.0) + size * factor * mult
    st.count_by_kind[kind] = st.count_by_kind.get(kind, 0) + int(mult)
    st.f32_bytes += size_f32 * factor * mult


def analyze(text: str) -> HloCosts:
    comps, entry = parse_module(text)
    costs = HloCosts()
    if entry is None:
        # fall back: look for a computation named like main
        entry = next((n for n in comps if n.startswith("main")), None)
        if entry is None and comps:
            entry = max(comps.values(), key=lambda c: len(c.instrs)).name
    seen_stack: List[str] = []

    def visit(name: str, mult: float) -> None:
        comp = comps.get(name)
        if comp is None or name in seen_stack:
            return
        seen_stack.append(name)
        for ins in comp.instrs:
            op = ins.opcode
            base = op[:-6] if op.endswith("-start") else op
            if base in COLLECTIVES:
                _collective_line(base, ins, mult, costs.collectives)
            elif op == "dot":
                costs.flops += _dot_flops(ins, comp.shapes) * mult
            elif op == "while":
                cond = _COND_RE.search(ins.line)
                body = _BODY_RE.search(ins.line)
                trips = 1
                if cond and cond.group(1) in comps:
                    trips = _trip_count(comps[cond.group(1)])
                costs.n_while += 1
                costs.trip_counts.append(trips)
                if body:
                    visit(body.group(1), mult * trips)
            elif op == "fusion":
                m = _CALLS_RE.search(ins.line)
                if m:
                    visit(m.group(1), mult)
            elif op in ("call", "custom-call", "reduce", "sort", "scatter",
                        "map", "reduce-window", "select-and-scatter"):
                m = _TO_APPLY_RE.search(ins.line)
                if m:
                    visit(m.group(1), mult)
                if op == "reduce":
                    costs.elemwise_flops += _shape_elems(ins.ty) * mult
            elif op == "conditional":
                m = _BRANCHES_RE.search(ins.line)
                if m:
                    for b in _OPERANDS_RE.findall(m.group(1)):
                        visit(b, mult)
            elif op in _ELEMWISE:
                costs.elemwise_flops += _shape_elems(ins.ty) * mult
        seen_stack.pop()

    if entry:
        visit(entry, 1.0)
    return costs
