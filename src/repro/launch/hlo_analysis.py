"""HLO-text analysis: collective-byte accounting + roofline terms.

``cost_analysis`` gives FLOPs and memory bytes but no collective traffic, so
we parse the optimized HLO and sum operand sizes of every collective op
(all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute,
sync or ``-start`` async forms).

Roofline constants are TPU v5e-class: 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link (per chip, per direction)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)

# "%name = <result-type> <op>(" where result-type is a shape or tuple of shapes.
_OP_RE = re.compile(
    r"=\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\]\S*)\s+("
    + "|".join(COLLECTIVES) + r")(-start|-done)?\("
)
_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
# iota-style groups "[n_groups,group_size]<=[...]"
_GROUPS_V2_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=")
# literal groups "{{0,1},{2,3}}"
_GROUPS_V1_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")


def _shape_bytes(dtype: str, dims: str) -> int:
    bs = _DTYPE_BYTES.get(dtype)
    if bs is None:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * bs


def _group_size(line: str) -> int:
    m = _GROUPS_V2_RE.search(line)
    if m:
        return max(1, int(m.group(2)))
    m = _GROUPS_V1_RE.search(line)
    if m:
        return max(1, len(m.group(1).split(",")))
    return 1


@dataclass
class CollectiveStats:
    """Per-device *wire* bytes under the standard ring-algorithm cost model:

    all-reduce: 2·S·(g-1)/g   (reduce-scatter + all-gather phases)
    all-gather: S_out·(g-1)/g
    reduce-scatter: S_in·(g-1)/g = S_out·(g-1)
    all-to-all: S·(g-1)/g
    collective-permute: S
    """

    bytes_by_kind: Dict[str, float] = field(default_factory=dict)
    count_by_kind: Dict[str, int] = field(default_factory=dict)
    f32_bytes: float = 0.0

    @property
    def total_bytes(self) -> float:
        return sum(self.bytes_by_kind.values())

    @property
    def tpu_bf16_bytes(self) -> float:
        """CPU-widening-corrected wire bytes.

        The CPU backend legalizes bf16 by wrapping every collective in
        convert(bf16->f32) / convert(f32->bf16) pairs (verified on a psum
        microbench), so bf16 traffic is *reported* as f32.  On TPU those
        collectives move bf16: count f32 collective bytes at half weight.
        Genuinely-f32 collectives (fp32-master grad reductions) are
        undercounted 2x by this rule — negligible in the measured
        breakdowns and zero in the recommended bf16-params configuration.
        """
        return self.total_bytes - 0.5 * self.f32_bytes

    @property
    def total_count(self) -> int:
        return sum(self.count_by_kind.values())

    def as_dict(self) -> Dict:
        return {
            "total_bytes": self.total_bytes,
            "tpu_bf16_bytes": self.tpu_bf16_bytes,
            "f32_bytes": self.f32_bytes,
            "total_count": self.total_count,
            "bytes_by_kind": dict(self.bytes_by_kind),
            "count_by_kind": dict(self.count_by_kind),
        }


def collective_bytes(hlo_text: str) -> CollectiveStats:
    """Ring-model wire bytes for every collective in (optimized) HLO text."""
    st = CollectiveStats()
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        result_ty, kind, async_suffix = m.group(1), m.group(2), m.group(3)
        if async_suffix == "-done":
            continue  # counted at -start
        shapes = _SHAPE_RE.findall(result_ty)
        if async_suffix == "-start" and len(shapes) > 1:
            # async start returns (operand..., result...): use the trailing half
            shapes = shapes[len(shapes) // 2:]
        size = sum(_shape_bytes(dt, dims) for dt, dims in shapes)
        g = _group_size(line)
        ring = (g - 1) / g
        if kind == "all-reduce":
            b = 2.0 * size * ring
        elif kind == "all-gather":
            b = size * ring
        elif kind == "reduce-scatter":
            b = size * (g - 1)
        elif kind == "all-to-all":
            b = size * ring
        else:  # collective-permute
            b = float(size)
        st.bytes_by_kind[kind] = st.bytes_by_kind.get(kind, 0.0) + b
        st.count_by_kind[kind] = st.count_by_kind.get(kind, 0) + 1
    return st


# ---------------------------------------------------------------------------
# Roofline terms
# ---------------------------------------------------------------------------

def roofline_terms(
    flops_per_device: float,
    hbm_bytes_per_device: float,
    collective_bytes_per_device: float,
    *,
    n_links: int = 4,            # v5e: 4 ICI links per chip (2D torus)
) -> Dict[str, float]:
    """The three per-step roofline times (seconds) and the dominant term."""
    t_compute = flops_per_device / PEAK_FLOPS
    t_memory = hbm_bytes_per_device / HBM_BW
    t_collective = collective_bytes_per_device / (ICI_BW * n_links)
    dom = max(
        ("compute", t_compute), ("memory", t_memory), ("collective", t_collective),
        key=lambda kv: kv[1],
    )[0]
    bound = max(t_compute, t_memory, t_collective)
    return {
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_collective,
        "dominant": dom,
        "bound_s": bound,
        # fraction of the bound that is useful compute = roofline fraction
        "compute_fraction": (t_compute / bound) if bound > 0 else 0.0,
    }


def model_flops(param_count: int, tokens: int, active_param_count: Optional[int] = None) -> float:
    """6·N·D (dense) or 6·N_active·D (MoE) — the useful-FLOPs yardstick."""
    n = active_param_count if active_param_count is not None else param_count
    return 6.0 * float(n) * float(tokens)
