"""Analytic per-device HBM traffic & residency model (TPU-faithful).

The CPU backend's ``cost_analysis()['bytes accessed']`` counts every
unfused elementwise op's operands — a ~10-50× overestimate of what a TPU
(which fuses aggressively) moves through HBM.  For the §Roofline memory
term we therefore use an *analytic* traffic model with documented
constants, and report XLA's number alongside as an upper bound.

Traffic model (per device, per step; bytes):

train (ZeRO-3 / FSDP + TP):
  weights   3 · P·bw_c / TP     gathered copy written once, read fwd + bwd
  optimizer 28 · P/chips · 4    p,m,v read+write in fp32 (+grad read)
  acts      C_act · L · tok_dev · d · 2 · 2   saved activations w+r (bf16)
  logits    3 · tok_dev · V/TP · 4

prefill:
  weights   P_active·2 / TP
  acts      C_act · L · tok_dev · d · 2 · 2 (+ KV write)

decode (per token):
  weights   P_active·2 / TP     every active weight read once per step
  cache     full KV slice read once (+ 1-token write)

``C_act`` = 8 effective transfers of d-wide tensors per layer per token
(≈4 saved tensors under the dots-saveable remat policy, written + read).

Residency model (per device, bytes): what must be simultaneously resident —
params + grads + optimizer (train, fp32, fully sharded over all chips) or
params bf16/TP (serve), + KV cache slice + one layer's activation working
set.  Compared against v5e's 16 GiB.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict

from repro.configs.shapes import ShapeSpec
from repro.models.common import ModelConfig

HBM_PER_CHIP = 16 * 1024 ** 3      # v5e
C_ACT = 8.0


def _cache_bytes_global(cfg: ModelConfig, batch: int, seq: int) -> float:
    """Total KV/state cache bytes across the fleet (bf16, fp32 SSM state)."""
    from repro.models.common import layer_plan

    plan = layer_plan(cfg)
    total = 0.0
    for kind in plan.kinds:
        if kind.mixer in ("attn", "attn_local", "shared_attn"):
            if cfg.mla is not None:
                per_tok = cfg.mla.kv_lora_rank + cfg.mla.qk_rope_head_dim
            else:
                per_tok = 2 * cfg.n_kv_heads * cfg.head_dim
            total += batch * seq * per_tok * 2
        elif kind.mixer == "mamba":
            s = cfg.ssm
            di = s.d_inner(cfg.d_model)
            nh = s.n_heads(cfg.d_model)
            total += batch * (nh * s.head_dim * s.d_state * 4
                              + (s.d_conv - 1) * (di + 2 * s.n_groups * s.d_state) * 2)
    return total


@dataclass
class MemEstimate:
    traffic_bytes: float
    residency_bytes: float
    fits: bool
    detail: Dict[str, float]

    def as_dict(self):
        return {
            "traffic_bytes": self.traffic_bytes,
            "residency_bytes": self.residency_bytes,
            "fits_16GiB": self.fits,
            "detail": self.detail,
        }


def estimate(cfg: ModelConfig, spec: ShapeSpec, n_chips: int, tp: int,
             param_bytes: int = 4) -> MemEstimate:
    """``param_bytes``: 4 = fp32 masters, 2 = bf16 weights (+ fp32 m/v)."""
    p_total = float(cfg.param_count())
    p_active = float(cfg.active_param_count())
    d: Dict[str, float] = {}

    if spec.kind == "train":
        tok_dev = spec.global_batch * spec.seq_len / n_chips
        d["weights"] = 3.0 * p_total * 2.0 / tp
        # p r+w (2·pb) + m,v r+w (16, fp32) + grad read (pb)
        d["optimizer"] = p_total / n_chips * (16.0 + 3.0 * param_bytes)
        d["acts"] = C_ACT * cfg.n_layers * tok_dev * cfg.d_model * 2.0 * 2.0
        d["logits"] = 3.0 * tok_dev * cfg.vocab_size / tp * 4.0
        traffic = sum(d.values())
        resident = (
            # p + grad (param dtype) + m + v (fp32), fully sharded
            p_total / n_chips * (2.0 * param_bytes + 8.0)
            + p_total * 2.0 / tp / max(1, cfg.n_layers) * 2  # 2 gathered layers
            + d["acts"] / 4.0                  # saved checkpoints (resident once)
            + tok_dev * cfg.vocab_size / tp * 4.0
        )
    elif spec.kind == "prefill":
        tok_dev = spec.global_batch * spec.seq_len / n_chips
        d["weights"] = p_active * 2.0 / tp
        d["acts"] = C_ACT * cfg.n_layers * tok_dev * cfg.d_model * 2.0
        d["kv_write"] = _cache_bytes_global(cfg, spec.global_batch, spec.seq_len) / n_chips
        traffic = sum(d.values())
        resident = (
            p_total * 2.0 / tp / max(1, cfg.n_layers) * 2
            + p_total * 2.0 / n_chips
            + d["kv_write"]
            + 4.0 * tok_dev * cfg.d_model * 2.0
        )
    else:  # decode
        d["weights"] = p_active * 2.0 / tp
        cache = _cache_bytes_global(cfg, spec.global_batch, spec.seq_len) / n_chips
        d["cache_read"] = cache
        traffic = sum(d.values())
        resident = p_total * 2.0 / n_chips + cache * 1.05
    return MemEstimate(
        traffic_bytes=traffic,
        residency_bytes=resident,
        fits=resident < HBM_PER_CHIP,
        detail=d,
    )
