import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)
"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell we build ShapeDtypeStruct stand-ins (weak-type-correct,
sharded, zero allocation), ``jit(...).lower(...).compile()`` against the
production mesh, and record:

* ``memory_analysis()``  — per-device bytes (proves it fits),
* ``cost_analysis()``    — FLOPs / bytes for the roofline,
* collective operand bytes parsed from the optimized HLO,
* the derived roofline terms.

Results are cached as JSON per cell under ``results/dryrun/`` so reruns
skip completed cells (``--force`` recomputes).

Usage::

    PYTHONPATH=src python -m repro.launch.dryrun --arch glm4-9b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
"""
import argparse
import dataclasses
import json
import time
import traceback
from pathlib import Path
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.configs.shapes import SHAPES, input_specs, skip_reason
from repro.dist import sharding as shd
from repro.launch import estimates
from repro.launch import hlo_analysis as hlo
from repro.launch import hlo_count as hc
from repro.launch.mesh import make_production_mesh
from repro.models import decoder
from repro.models.common import param_shapes
from repro.train import optimizer as opt
from repro.train.train_step import TrainConfig, make_train_step

RESULTS_DIR = Path(__file__).resolve().parents[3] / "results" / "dryrun"


# ---------------------------------------------------------------------------
# Struct builders (no allocation)
# ---------------------------------------------------------------------------

def _struct_tree(shapes_tree, dtype, shardings):
    return jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(tuple(s), dtype, sharding=sh),
        shapes_tree, shardings,
        is_leaf=lambda s: isinstance(s, tuple),
    )


def param_structs(cfg, mesh, dtype):
    shapes = param_shapes(cfg, model_size=int(mesh.shape["model"]))
    shards = shd.param_shardings(cfg, mesh)
    return _struct_tree(shapes, dtype, shards)


def opt_structs(params_struct):
    zeros = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32, sharding=s.sharding),
        params_struct,
    )
    m = zeros
    v = jax.tree.map(lambda s: s, zeros)
    return opt.OptState(m=m, v=v, count=jax.ShapeDtypeStruct((), jnp.int32))


def batch_structs(cfg, mesh, shape_name):
    specs = input_specs(cfg, shape_name)
    pspecs = shd.batch_pspecs(cfg, mesh, specs)
    return {
        k: jax.ShapeDtypeStruct(
            v.shape, v.dtype, sharding=jax.sharding.NamedSharding(mesh, pspecs[k])
        )
        for k, v in specs.items()
    }


def cache_structs(cfg, mesh, batch: int, max_len: int, dtype=jnp.bfloat16):
    tree = jax.eval_shape(lambda: decoder.init_cache(cfg, batch, max_len, dtype))
    pspecs = shd.cache_pspecs(cfg, mesh, tree, batch)
    return jax.tree.map(
        lambda s, p: jax.ShapeDtypeStruct(
            s.shape, s.dtype, sharding=jax.sharding.NamedSharding(mesh, p)),
        tree, pspecs,
    )


# ---------------------------------------------------------------------------
# Cell runner
# ---------------------------------------------------------------------------

def run_cell(
    arch: str,
    shape_name: str,
    multi_pod: bool,
    *,
    remat: str = "dots",
    extra_tag: str = "",
    ctx_overrides: Optional[Dict[str, Any]] = None,
) -> Dict[str, Any]:
    cfg = get_config(arch)
    spec = SHAPES[shape_name]
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    cell = {"arch": arch, "shape": shape_name, "mesh": mesh_name, "tag": extra_tag}

    reason = skip_reason(arch, cfg, shape_name)
    if reason:
        cell["status"] = "SKIP"
        cell["skip_reason"] = reason
        return cell

    mesh = make_production_mesh(multi_pod=multi_pod)
    ax = shd.MeshAxes.for_mesh(mesh)
    n_chips = int(np.prod([int(mesh.shape[a]) for a in mesh.axis_names]))

    ctx_kw: Dict[str, Any] = dict(
        mesh=mesh, batch_axes=ax.batch, use_kernel="ref",
        remat=(remat if spec.kind == "train" else "none"),
    )
    ctx_kw.update(ctx_overrides or {})
    param_bf16 = ctx_kw.pop("_param_bf16", False)
    ctx = decoder.RunCtx(**ctx_kw)

    t0 = time.time()
    if spec.kind == "train":
        pdt = jnp.bfloat16 if param_bf16 else jnp.float32
        pstr = param_structs(cfg, mesh, pdt)
        ostr = opt_structs(pstr)
        bstr = batch_structs(cfg, mesh, shape_name)
        step = make_train_step(cfg, ctx, TrainConfig())
        jitted = jax.jit(step, donate_argnums=(0, 1))
        lowered = jitted.lower(pstr, ostr, bstr)
        tokens = spec.global_batch * spec.seq_len
    elif spec.kind == "prefill":
        pstr = param_structs(cfg, mesh, jnp.bfloat16)
        bstr = batch_structs(cfg, mesh, shape_name)

        def prefill_fn(params, batch):
            return decoder.prefill(cfg, ctx, params, batch)

        lowered = jax.jit(prefill_fn).lower(pstr, bstr)
        tokens = spec.global_batch * spec.seq_len
    else:  # decode
        pstr = param_structs(cfg, mesh, jnp.bfloat16)
        bstr = batch_structs(cfg, mesh, shape_name)
        cstr = cache_structs(cfg, mesh, spec.global_batch, spec.seq_len)

        def serve_step(params, caches, tokens, pos):
            return decoder.decode_step(cfg, ctx, params, caches, tokens, pos)

        lowered = jax.jit(serve_step, donate_argnums=(1,)).lower(
            pstr, cstr, bstr["tokens"], bstr["pos"])
        tokens = spec.global_batch  # one new token per sequence

    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):     # older jax: one dict per device
        cost = cost[0] if cost else {}
    mem = None
    try:
        ma = compiled.memory_analysis()
        if ma is not None:
            mem = {
                k: int(getattr(ma, k))
                for k in ("argument_size_in_bytes", "output_size_in_bytes",
                          "temp_size_in_bytes", "generated_code_size_in_bytes",
                          "alias_size_in_bytes")
                if hasattr(ma, k)
            }
    except Exception as e:  # CPU backend may not implement it
        mem = {"error": str(e)}

    text = compiled.as_text()
    # trip-count-aware costs (XLA's cost_analysis counts while bodies ONCE —
    # verified experimentally; hlo_count multiplies through the call graph)
    counted = hc.analyze(text)
    coll = counted.collectives
    flops = float(counted.flops + counted.elemwise_flops)

    # memory term: analytic TPU traffic model (CPU 'bytes accessed' counts
    # unfused elementwise traffic and misses scan trip counts)
    est = estimates.estimate(cfg, spec, n_chips, tp=int(mesh.shape["model"]),
                             param_bytes=(2 if param_bf16 else 4))
    bytes_analytic = est.traffic_bytes
    bytes_xla_once = float(cost.get("bytes accessed", 0.0))

    # collective term uses the CPU-widening-corrected (TPU-dtype) bytes
    terms = hlo.roofline_terms(flops, bytes_analytic, float(coll.tpu_bf16_bytes))
    # 6·N·D counts fwd+bwd (train); inference steps are fwd-only -> 2·N·D
    mf = hlo.model_flops(cfg.param_count(), tokens, cfg.active_param_count())
    if spec.kind != "train":
        mf /= 3.0

    cell.update({
        "status": "OK",
        "n_chips": n_chips,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "tokens": tokens,
        "flops_per_device": flops,
        "dot_flops_per_device": float(counted.flops),
        "flops_xla_body_once": float(cost.get("flops", 0.0)),
        "bytes_per_device": bytes_analytic,
        "bytes_xla_body_once": bytes_xla_once,
        "n_while": counted.n_while,
        "trip_counts": counted.trip_counts,
        "collectives": coll.as_dict(),
        "memory_analysis": mem,
        "memory_estimate": est.as_dict(),
        "roofline": terms,
        "model_flops_global": mf,
        "model_flops_per_device": mf / n_chips,
        "useful_flops_ratio": (mf / n_chips / flops) if flops else None,
        "hlo_bytes": len(text),
    })
    return cell


def cell_path(arch: str, shape: str, mesh_name: str, tag: str = "") -> Path:
    t = f"__{tag}" if tag else ""
    return RESULTS_DIR / f"{arch}__{shape}__{mesh_name}{t}.json"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="one arch id (default: all)")
    ap.add_argument("--shape", default=None, help="one shape (default: all)")
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--remat", default="dots")
    ap.add_argument("--tag", default="")
    ap.add_argument("--param-bf16", action="store_true",
                    help="bf16 weights + fp32 m/v (halves ZeRO gather wire)")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else list(ARCH_IDS)
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    RESULTS_DIR.mkdir(parents=True, exist_ok=True)
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                mesh_name = "pod2x16x16" if mp else "pod16x16"
                out = cell_path(arch, shape, mesh_name, args.tag)
                if out.exists() and not args.force:
                    print(f"[cached] {arch} {shape} {mesh_name}")
                    continue
                print(f"[run]    {arch} {shape} {mesh_name} ...", flush=True)
                try:
                    cell = run_cell(
                        arch, shape, mp, remat=args.remat, extra_tag=args.tag,
                        ctx_overrides=(
                            {"_param_bf16": True} if args.param_bf16 else None),
                    )
                except Exception:
                    cell = {
                        "arch": arch, "shape": shape, "mesh": mesh_name,
                        "status": "FAIL", "error": traceback.format_exc(),
                    }
                out.write_text(json.dumps(cell, indent=2))
                status = cell["status"]
                extra = ""
                if status == "OK":
                    r = cell["roofline"]
                    extra = (f" compile={cell['compile_s']}s dom={r['dominant']}"
                             f" tc={r['t_compute_s']:.4f} tm={r['t_memory_s']:.4f}"
                             f" tx={r['t_collective_s']:.4f}")
                elif status == "SKIP":
                    extra = f" ({cell['skip_reason']})"
                print(f"[{status}] {arch} {shape} {mesh_name}{extra}", flush=True)


if __name__ == "__main__":
    main()
