"""End-to-end training driver (deliverable (b)'s main example).

Runs a real training loop on the host devices: data pipeline → jitted
train step (remat, donation) → metrics → async checkpoints → resume.

Example (the ~100M-param run)::

    PYTHONPATH=src python -m repro.launch.train --arch glm4-9b --preset p100m \
        --steps 300 --batch 8 --seq 256 --ckpt-dir /tmp/ckpt_100m

Presets scale the assigned architecture down while keeping its family
features (GQA ratios, MoE, SSD, ...) intact.
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.data import DataConfig, SyntheticLM
from repro.dist import sharding as shd
from repro.launch.mesh import make_host_mesh
from repro.models import decoder
from repro.models.common import init_params
from repro.train import checkpoint, optimizer as opt
from repro.train.train_step import TrainConfig, make_train_step


def scaled_config(arch: str, preset: str):
    if preset == "full":
        return get_config(arch)
    if preset == "smoke":
        return get_smoke_config(arch)
    if preset == "p100m":
        cfg = get_config(arch)
        kw = dict(
            n_layers=min(cfg.n_layers, 10),
            d_model=512,
            n_heads=8 if cfg.n_heads else 0,
            n_kv_heads=min(8, cfg.n_kv_heads) if cfg.n_kv_heads else 0,
            head_dim=64 if cfg.head_dim else 0,
            d_ff=2048 if cfg.d_ff else 0,
            vocab_size=min(cfg.vocab_size, 49152),
            max_seq_len=4096,
        )
        if cfg.moe is not None:
            kw["moe"] = dataclasses.replace(
                cfg.moe, n_experts=min(8, cfg.moe.n_experts), top_k=2,
                d_expert=768, d_shared=768,
                d_first_dense=1536 if cfg.moe.first_dense_layers else 0,
            )
        if cfg.ssm is not None:
            kw["ssm"] = dataclasses.replace(cfg.ssm, d_state=64, head_dim=64)
        if cfg.global_every:
            kw["global_every"] = 4
            kw["sliding_window"] = 128
        if cfg.hybrid_attn_every:
            kw["hybrid_attn_every"] = 4
        return dataclasses.replace(cfg, **kw)
    raise ValueError(preset)


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="glm4-9b")
    ap.add_argument("--preset", default="p100m",
                    choices=["smoke", "p100m", "full"])
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--remat", default="none")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = scaled_config(args.arch, args.preset)
    mesh = make_host_mesh()
    ctx = decoder.RunCtx(mesh=mesh, batch_axes=("data",), remat=args.remat,
                         use_kernel="auto")
    n_params_note = cfg.param_count()
    print(f"arch={cfg.name} preset={args.preset} params={n_params_note/1e6:.1f}M "
          f"devices={jax.device_count()}")

    key = jax.random.PRNGKey(args.seed)
    params = init_params(cfg, key, model_size=int(mesh.shape["model"]))
    opt_state = opt.init(params)
    tcfg = TrainConfig(
        opt=opt.OptConfig(lr=args.lr, warmup_steps=20, total_steps=args.steps),
        microbatches=args.microbatches,
    )
    step_fn = jax.jit(make_train_step(cfg, ctx, tcfg), donate_argnums=(0, 1))

    data_cfg = DataConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq, global_batch=args.batch,
        seed=args.seed, stub_frontend=cfg.family in ("vlm", "audio"),
        d_model=cfg.d_model, mrope=cfg.mrope_sections is not None,
    )
    ds = SyntheticLM(data_cfg)

    start = 0
    writer = None
    if args.ckpt_dir:
        writer = checkpoint.AsyncCheckpointer(args.ckpt_dir)
        if args.resume and checkpoint.latest_step(args.ckpt_dir) is not None:
            (params, opt_state), start = checkpoint.restore(
                args.ckpt_dir, (params, opt_state))
            print(f"resumed from step {start}")

    losses = []
    t0 = time.time()
    for step in range(start, args.steps):
        batch = {k: jnp.asarray(v) for k, v in ds.batch(step).items()}
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        losses.append(float(metrics["loss"]))
        if step % args.log_every == 0 or step == args.steps - 1:
            dt = time.time() - t0
            print(f"step {step:5d} loss {losses[-1]:.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  f"lr {float(metrics['lr']):.2e} ({dt:.1f}s)", flush=True)
        if writer and args.ckpt_every and (step + 1) % args.ckpt_every == 0:
            writer.submit(step + 1, (params, opt_state))
    if writer:
        writer.submit(args.steps, (params, opt_state))
        writer.close()
    return {"first_loss": losses[0] if losses else None,
            "last_loss": losses[-1] if losses else None,
            "steps": len(losses)}


if __name__ == "__main__":
    main()
