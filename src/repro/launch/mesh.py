"""Production meshes.

``make_production_mesh`` is a function (not a module-level constant) so that
importing this module never touches jax device state; callers that need the
512-placeholder-device dry-run must set ``XLA_FLAGS`` before *any* jax
import (see ``launch/dryrun.py``).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    """16×16 chips per pod; the multi-pod mesh prepends a 2-pod axis.

    With the dry-run's 512 placeholder devices the single-pod mesh uses the
    first 256 (one pod's worth), so both meshes are constructible in one
    process.
    """
    import numpy as np

    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devs = jax.devices()
    if len(devs) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}, have {len(devs)} — the "
            "dry-run entrypoint must set XLA_FLAGS=--xla_force_host_platform_"
            "device_count=512 before any jax import"
        )
    return jax.sharding.Mesh(np.asarray(devs[:n]).reshape(shape), axes)


def make_host_mesh(model: int = 1) -> jax.sharding.Mesh:
    """Tiny mesh over however many (real) devices exist — smoke tests."""
    n = jax.device_count()
    model = min(model, n)
    return jax.make_mesh((n // model, model), ("data", "model"))
