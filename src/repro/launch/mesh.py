"""Production meshes.

``make_production_mesh`` is a function (not a module-level constant) so that
importing this module never touches jax device state; callers that need the
512-placeholder-device dry-run must set ``XLA_FLAGS`` before *any* jax
import (see ``launch/dryrun.py``).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False,
                         seq: int = 1) -> jax.sharding.Mesh:
    """16×16 chips per pod; the multi-pod mesh prepends a 2-pod axis.

    ``seq`` > 1 splits the data axis into ``data × seq`` (e.g. ``seq=4``
    yields a 4×4×16 pod) so long-context KV caches shard their sequence
    dim (:mod:`repro.dist.sharding`'s long-context rule) without changing
    the chip count per pod.

    With the dry-run's 512 placeholder devices the single-pod mesh uses the
    first 256 (one pod's worth), so both meshes are constructible in one
    process.
    """
    import numpy as np

    if 16 % seq:
        raise ValueError(f"seq axis {seq} must divide the 16-wide data axis")
    data = 16 // seq
    if seq > 1:
        shape = (2, data, seq, 16) if multi_pod else (data, seq, 16)
        axes = (("pod",) if multi_pod else ()) + ("data", "seq", "model")
    else:
        shape = (2, 16, 16) if multi_pod else (16, 16)
        axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devs = jax.devices()
    if len(devs) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}, have {len(devs)} — the "
            "dry-run entrypoint must set XLA_FLAGS=--xla_force_host_platform_"
            "device_count=512 before any jax import"
        )
    return jax.sharding.Mesh(np.asarray(devs[:n]).reshape(shape), axes)


def make_host_mesh(model: int = 1, seq: int = 1) -> jax.sharding.Mesh:
    """Tiny mesh over however many (real) devices exist — smoke tests.

    ``seq`` > 1 inserts a ``seq`` axis between data and model (capped at
    what the device count allows), for exercising the long-context KV
    layout on host devices.
    """
    n = jax.device_count()
    model = min(model, n)
    seq = max(1, min(seq, n // model))
    while (n // model) % seq:
        seq -= 1                      # largest feasible seq axis <= requested
    if seq > 1:
        return jax.make_mesh(
            (n // (model * seq), seq, model), ("data", "seq", "model"))
    return jax.make_mesh((n // model, model), ("data", "model"))
