"""Shared AST helpers for the lint rules (stdlib-only by design)."""
from __future__ import annotations

import ast
from typing import Dict, List, Optional

# name tokens that mark an array as carrying protocol identifiers
# (conflict classes, sessions, queue slots, requests, replicas, items)
ID_TOKENS = {"cc", "sid", "slot", "req", "rid", "proc", "owner", "item",
             "cls", "lor"}


def is_id_name(name: Optional[str]) -> bool:
    """True if ``name`` reads like a protocol-id binding (``ccs_l``,
    ``head_rid``, ``_item_cc``, ...)."""
    if not name:
        return False
    for tok in name.lower().split("_"):
        if tok in ID_TOKENS or (tok.endswith("s") and tok[:-1] in ID_TOKENS):
            return True
    return False


def is_jit_name(e: ast.expr) -> bool:
    return (isinstance(e, ast.Attribute) and e.attr == "jit") or (
        isinstance(e, ast.Name) and e.id == "jit")


def jit_decorator(dec: ast.expr) -> bool:
    """True when ``dec`` puts the decorated body under jax.jit tracing:
    ``@jax.jit``, ``@jax.jit(...)`` or ``@functools.partial(jax.jit, ...)``."""
    if is_jit_name(dec):
        return True
    if isinstance(dec, ast.Call):
        if is_jit_name(dec.func):
            return True
        f = dec.func
        is_partial = (isinstance(f, ast.Attribute) and f.attr == "partial") \
            or (isinstance(f, ast.Name) and f.id == "partial")
        if is_partial and dec.args and is_jit_name(dec.args[0]):
            return True
    return False


def jit_functions(tree: ast.AST) -> List[ast.FunctionDef]:
    """Function defs whose bodies are traced under jax.jit — decorated
    directly, or wrapped module-side via ``g = jax.jit(f)``."""
    out: List[ast.FunctionDef] = []
    wrapped: List[tuple] = []          # (name, lineno of the jit call)
    defs: List[ast.FunctionDef] = []
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            defs.append(node)
            if any(jit_decorator(d) for d in node.decorator_list):
                out.append(node)
        elif isinstance(node, ast.Call) and is_jit_name(node.func):
            if node.args and isinstance(node.args[0], ast.Name):
                wrapped.append((node.args[0].id, node.lineno))
    done = {id(f) for f in out}
    for name, call_line in wrapped:
        # nearest preceding def wins: `jax.jit(step)` refers to the local
        # `step` above it, not a later same-named method
        cands = [f for f in defs
                 if f.name == name and f.lineno <= call_line]
        if cands:
            f = max(cands, key=lambda f: f.lineno)
            if id(f) not in done:
                done.add(id(f))
                out.append(f)
    return out


def call_name(node: ast.Call) -> str:
    """Dotted callee name: ``np.full(...)`` -> ``"np.full"`` ('' if exotic)."""
    f = node.func
    parts: List[str] = []
    while isinstance(f, ast.Attribute):
        parts.append(f.attr)
        f = f.value
    if isinstance(f, ast.Name):
        parts.append(f.id)
    elif parts:
        parts.append("?")
    return ".".join(reversed(parts))


def kwarg(node: ast.Call, name: str) -> Optional[ast.expr]:
    for kw in node.keywords:
        if kw.arg == name:
            return kw.value
    return None


def assign_targets(tree: ast.AST) -> Dict[int, str]:
    """Map id(call-node) -> the simple name it is assigned to, for calls
    (possibly nested) on the RHS of single-target assignments."""
    out: Dict[int, str] = {}
    for node in ast.walk(tree):
        tgt = None
        if isinstance(node, ast.Assign) and len(node.targets) == 1:
            tgt = node.targets[0]
        elif isinstance(node, (ast.AnnAssign, ast.AugAssign)):
            tgt = node.target
        value = getattr(node, "value", None)
        if tgt is None or value is None:
            continue
        if isinstance(tgt, ast.Name):
            name = tgt.id
        elif isinstance(tgt, ast.Attribute):
            name = tgt.attr
        else:
            continue
        for sub in ast.walk(value):
            if isinstance(sub, ast.Call):
                out[id(sub)] = name
    return out
