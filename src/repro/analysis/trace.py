"""Replayable schedule traces + delta-debugging minimization.

A trace is the full record of one explored schedule's branching decisions:
at every point where more than one enabled event was dispatchable, the
candidate pool (with delivery metadata) and the chosen event.  Traces are
JSON so a counterexample survives as a CI artifact and replays with
``repro-explore replay <trace.json>`` — the recording policy re-runs the
model forcing each recorded choice, which is deterministic because event
``seq`` numbers are a pure function of the choice prefix.

``ddmin`` is the classic minimizing delta debugger (Zeller): applied here
to the schedule's *deviations from the default order* — the decisions
where the explored schedule departed from first-eligible-FIFO — so a
minimized counterexample reads as "the default schedule plus these K
reorderings".
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple


@dataclass(frozen=True)
class Cand:
    """One dispatchable candidate at a decision point."""

    seq: int
    time: float
    kind: str = "local"
    node: int = -1
    label: str = ""
    keys: Optional[Tuple[int, ...]] = None
    eligible: bool = True


@dataclass
class Decision:
    """One branching point: the pool, the choice, and the FIFO default."""

    time: float
    cands: List[Cand]
    chosen: int                    # seq of the dispatched event
    default: int                   # seq first-eligible FIFO would have picked


@dataclass
class Trace:
    model: str
    args: Dict = field(default_factory=dict)
    window_ms: float = 0.0
    decisions: List[Decision] = field(default_factory=list)
    violation: Optional[Tuple[str, str]] = None   # (invariant, detail)

    @property
    def chosen(self) -> List[int]:
        return [d.chosen for d in self.decisions]

    def deviations(self) -> List[Tuple[int, int]]:
        """(decision index, chosen seq) where the run departed from FIFO."""
        return [(i, d.chosen) for i, d in enumerate(self.decisions)
                if d.chosen != d.default]

    # -- JSON ----------------------------------------------------------------
    def to_json(self) -> Dict:
        return {
            "version": 1,
            "model": self.model,
            "args": self.args,
            "window_ms": self.window_ms,
            "violation": (None if self.violation is None
                          else {"invariant": self.violation[0],
                                "detail": self.violation[1]}),
            "decisions": [
                {
                    "t": d.time,
                    "chosen": d.chosen,
                    "default": d.default,
                    "cands": [
                        {"seq": c.seq, "t": c.time, "kind": c.kind,
                         "node": c.node, "label": c.label,
                         "keys": None if c.keys is None else sorted(c.keys),
                         "eligible": c.eligible}
                        for c in d.cands
                    ],
                }
                for d in self.decisions
            ],
        }

    @classmethod
    def from_json(cls, obj: Dict) -> "Trace":
        vio = obj.get("violation")
        return cls(
            model=obj["model"],
            args=dict(obj.get("args") or {}),
            window_ms=float(obj.get("window_ms", 0.0)),
            violation=None if vio is None
            else (vio["invariant"], vio["detail"]),
            decisions=[
                Decision(
                    time=float(d["t"]),
                    chosen=int(d["chosen"]),
                    default=int(d["default"]),
                    cands=[
                        Cand(seq=int(c["seq"]), time=float(c["t"]),
                             kind=c.get("kind", "local"),
                             node=int(c.get("node", -1)),
                             label=c.get("label", ""),
                             keys=None if c.get("keys") is None
                             else tuple(c["keys"]),
                             eligible=bool(c.get("eligible", True)))
                        for c in d["cands"]
                    ],
                )
                for d in obj.get("decisions", [])
            ],
        )


def save_trace(path, trace: Trace) -> None:
    with open(path, "w") as f:
        json.dump(trace.to_json(), f, indent=1, sort_keys=True)
        f.write("\n")


def load_trace(path) -> Trace:
    with open(path) as f:
        return Trace.from_json(json.load(f))


def ddmin(items: Sequence, test: Callable[[List], bool]) -> List:
    """Zeller's minimizing delta debugger.

    ``test(subset)`` must return True iff the failure still reproduces
    with only that subset applied; ``test(items)`` must be True on entry.
    Returns a 1-minimal failing subset (removing any single element makes
    the failure vanish).
    """
    items = list(items)
    n = 2
    while len(items) >= 2:
        size = len(items)
        chunk = max(1, size // n)
        chunks = [items[i: i + chunk] for i in range(0, size, chunk)]
        reduced = False
        for c in chunks:
            if len(c) < size and test(c):
                items, n, reduced = c, 2, True
                break
        if not reduced:
            for c in chunks:
                comp = [x for x in items if x not in c]
                if 0 < len(comp) < size and test(comp):
                    items, n, reduced = comp, max(n - 1, 2), True
                    break
        if not reduced:
            if n >= size:
                break
            n = min(size, 2 * n)
    return items
