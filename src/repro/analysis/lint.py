"""repro.analysis.lint — repo-specific static checks, CI-gated.

AST-based rules encoding the bug classes this repo has actually shipped
(see README "repro.analysis"): host syncs inside jit bodies, int64 id
arrays (the PR 4 ``frombuffer`` view bug), ops<->ref twin pairing,
protocol-state mutation outside the owning module, ``static_argnames``
typos, and unpadded compact axes feeding kernel dispatches.

Stdlib-only by design: the CI lint job runs without jax or numpy.

Usage::

    python -m repro.analysis.lint               # lint src/repro vs baseline
    python -m repro.analysis.lint --no-baseline # strict (no baseline)
    python -m repro.analysis.lint --write-baseline
    python -m repro.analysis.lint path/to/file.py

An intentional exemption carries an inline ``# lint: allow(<rule>): <why>``
on (or directly above) the flagged line; an allow comment without a reason
does not suppress.  Everything else unbaselined exits non-zero.
"""
from __future__ import annotations

import argparse
import ast
import re
import sys
from dataclasses import dataclass
from pathlib import Path
from typing import List, Optional, Sequence, Set

HERE = Path(__file__).resolve().parent
SRC_ROOT = HERE.parents[1]              # .../src
REPO_ROOT = SRC_ROOT.parent
DEFAULT_BASELINE = HERE / "lint_baseline.txt"
DEFAULT_TARGET = SRC_ROOT / "repro"

ALLOW_RE = re.compile(r"#\s*lint:\s*allow\(([A-Za-z0-9_-]+)\)[:\s-]*(.*)")


@dataclass(frozen=True)
class Violation:
    path: str           # repo-relative posix path
    line: int
    rule: str
    msg: str

    @property
    def key(self) -> str:
        # line-free so baseline entries survive unrelated edits above them
        return f"{self.path}::{self.rule}::{self.msg}"

    def render(self) -> str:
        return f"{self.path}:{self.line}: [{self.rule}] {self.msg}"


class Project:
    """Repo context rules may consult (ops<->ref pairing, tests)."""

    def __init__(self, src_root: Path = SRC_ROOT,
                 repo_root: Path = REPO_ROOT) -> None:
        self.src_root = src_root
        self.repo_root = repo_root
        self._tests: Optional[str] = None

    def read_text(self, rel: str) -> Optional[str]:
        try:
            return (self.repo_root / rel).read_text()
        except OSError:
            return None

    def tests_text(self) -> str:
        """Concatenated tests/ sources (cached) — parity-test existence."""
        if self._tests is None:
            chunks: List[str] = []
            tdir = self.repo_root / "tests"
            if tdir.is_dir():
                for p in sorted(tdir.glob("**/*.py")):
                    try:
                        chunks.append(p.read_text())
                    except OSError:
                        pass
            self._tests = "\n".join(chunks)
        return self._tests


class FileCtx:
    """One parsed source file handed to every rule."""

    def __init__(self, path: Path, rel: str, src: str,
                 project: Project) -> None:
        self.path = path
        self.rel = rel
        self.src = src
        self.project = project
        self.tree = ast.parse(src, filename=str(path))
        self.lines = src.splitlines()

    def violation(self, node: ast.AST, rule: str, msg: str) -> Violation:
        return Violation(self.rel, getattr(node, "lineno", 0) or 0, rule, msg)


def apply_allows(ctx: FileCtx, violations: Sequence[Violation]
                 ) -> List[Violation]:
    """Apply inline ``# lint: allow(<rule>): <reason>`` suppressions.

    The comment must sit on the flagged line or in the contiguous comment
    block directly above it, name the rule, and carry a reason — a
    reasonless allow keeps the violation (with a note) so exemptions stay
    self-documenting.
    """
    allows = {}
    for i, text in enumerate(ctx.lines, start=1):
        m = ALLOW_RE.search(text)
        if m:
            allows[i] = (m.group(1), m.group(2).strip())

    def find(line: int):
        a = allows.get(line)
        # walk up through the contiguous comment block above the flagged
        # line (allow comments often wrap onto a second line)
        k = line - 1
        while a is None and 1 <= k <= len(ctx.lines) \
                and ctx.lines[k - 1].lstrip().startswith("#"):
            a = allows.get(k)
            k -= 1
        return a

    out: List[Violation] = []
    for v in violations:
        a = find(v.line)
        if a and a[0] == v.rule:
            if len(a[1]) >= 3:
                continue
            out.append(Violation(v.path, v.line, v.rule,
                                 v.msg + " (allow comment lacks a reason)"))
            continue
        out.append(v)
    return out


def lint_paths(paths: Sequence, project: Optional[Project] = None
               ) -> List[Violation]:
    from .rules import ALL_RULES

    project = project or Project()
    files: List[Path] = []
    for p in paths:
        p = Path(p)
        files.extend(sorted(p.rglob("*.py")) if p.is_dir() else [p])
    out: List[Violation] = []
    for f in files:
        try:
            rel = f.resolve().relative_to(project.repo_root).as_posix()
        except ValueError:
            rel = f.as_posix()
        try:
            ctx = FileCtx(f, rel, f.read_text(), project)
        except SyntaxError as e:
            out.append(Violation(rel, e.lineno or 0, "parse",
                                 f"syntax error: {e.msg}"))
            continue
        vs: List[Violation] = []
        for rule in ALL_RULES:
            vs.extend(rule.check(ctx))
        out.extend(apply_allows(ctx, vs))
    return out


def load_baseline(path: Path) -> Set[str]:
    try:
        text = path.read_text()
    except OSError:
        return set()
    out = set()
    for line in text.splitlines():
        line = line.strip()
        if line and not line.startswith("#"):
            out.add(line)
    return out


def write_baseline(path: Path, violations: Sequence[Violation]) -> int:
    keys = sorted({v.key for v in violations})
    header = ("# repro.analysis.lint baseline — known legacy violations.\n"
              "# New violations fail CI; burn these down, never add here\n"
              "# by hand (use --write-baseline).  Hot-path files (kernels/,\n"
              "# plan/) must stay absent: fix or inline-allow there.\n")
    path.write_text(header + "\n".join(keys) + ("\n" if keys else ""))
    return len(keys)


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro-lint", description=__doc__.splitlines()[0])
    ap.add_argument("paths", nargs="*",
                    help=f"files/dirs to lint (default: {DEFAULT_TARGET})")
    ap.add_argument("--baseline", default=str(DEFAULT_BASELINE),
                    help="baseline file of tolerated legacy violations")
    ap.add_argument("--no-baseline", action="store_true",
                    help="strict mode: ignore the baseline file")
    ap.add_argument("--write-baseline", action="store_true",
                    help="rewrite the baseline from current violations")
    ap.add_argument("--list-rules", action="store_true")
    args = ap.parse_args(argv)

    from .rules import ALL_RULES

    if args.list_rules:
        for rule in ALL_RULES:
            print(f"{rule.id}: {rule.doc}")
        return 0

    paths = args.paths or [DEFAULT_TARGET]
    violations = lint_paths(paths)
    if args.write_baseline:
        n = write_baseline(Path(args.baseline), violations)
        print(f"wrote {n} baseline entr{'y' if n == 1 else 'ies'} "
              f"to {args.baseline}")
        return 0
    baseline = set() if args.no_baseline else load_baseline(
        Path(args.baseline))
    fresh = [v for v in violations if v.key not in baseline]
    matched = {v.key for v in violations} & baseline
    for v in fresh:
        print(v.render())
    stale = len(baseline) - len(matched)
    print(f"{len(fresh)} violation(s), {len(violations) - len(fresh)} "
          f"baselined, {stale} stale baseline entr"
          f"{'y' if stale == 1 else 'ies'}")
    return 1 if fresh else 0


if __name__ == "__main__":
    sys.exit(main())
