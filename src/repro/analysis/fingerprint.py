"""Canonical protocol-state fingerprints for the schedule explorer.

Two explored schedules that reach the same protocol state *and* the same
pending-event future will unfold identically from there — the explorer
dedupes on this fingerprint and counts the pruned continuations
(``states_deduped``).

Soundness note: a *false merge* (two genuinely different states hashing
equal) silently prunes schedules, so the fingerprint errs conservative —
it must cover every input the continuation depends on.  Delivery events
are identified schedule-robustly by their :class:`~repro.core.events.EvMeta`
(kind, chain position, label) with the issue ``seq`` excluded, because seqs
legitimately differ between interleavings that reach the same state.
*Unlabeled* local events (``meta is None``) are opaque closures, so for
them the seq IS the identity — including it forfeits some merging but
never merges distinct continuations.  For the full cluster model the state
side additionally covers the hidden drivers of future behavior: workload
RNG states, id counters, per-transaction phase, per-replica slot/stat
state, and the GCS sequencer clock.
"""
from __future__ import annotations

import hashlib
from typing import Tuple

import numpy as np


def digest(*parts) -> str:
    """Stable short hex digest of canonical (repr-able) state tuples."""
    h = hashlib.blake2b(digest_size=12)
    for p in parts:
        h.update(repr(p).encode())
        h.update(b"\x00")
    return h.hexdigest()


def _blob(o):
    """Canonicalize arbitrary small state for hashing (arrays by bytes)."""
    if isinstance(o, np.ndarray):
        return ("nd", str(o.dtype), o.shape, o.tobytes())
    if isinstance(o, dict):
        return tuple(sorted(((repr(k), _blob(v)) for k, v in o.items())))
    if isinstance(o, (list, tuple)):
        return tuple(_blob(x) for x in o)
    if isinstance(o, (set, frozenset)):
        return tuple(sorted(repr(x) for x in o))
    return repr(o)


def queue_state(events) -> Tuple:
    """Canonical view of the pending events of an ``EventQueue``."""
    out = []
    for ev in events.pending():
        m = ev.meta
        t = round(ev.time, 9)
        if m is None:
            out.append((t, "local", ev.seq))
        elif m.kind == "local":
            # labeled local events are identified by their label (the
            # scenario harnesses label every scheduled step)
            out.append((t, m.kind, m.node, m.label, ev.seq if not m.label
                        else -1))
        else:
            out.append((t, m.kind, m.node, m.chain, m.cseq, m.label))
    return tuple(out)


def cluster_state(cluster) -> Tuple:
    """Canonical behavioral state of a ``core.cluster.Cluster``."""
    reps = []
    for r in cluster.replicas:
        store = r.store
        reps.append((
            r.node,
            cluster.gcs.alive(r.node),
            r.lm.protocol_state(),
            int(store.clock),
            digest(store.versions.tobytes(), store.values.tobytes()),
            tuple(sorted(t.txid for (t, _l) in r.waiters)),
            tuple(sorted(r.pending_reqs)),
            len(r.prefetch_waiters),
            tuple(sorted(t.txid for t in r.certify_queue)),
            bool(r.certify_pending),
            r.free_slots,
            len(r.slot_queue),
            round(r.slowdown, 9),
            digest(_blob(vars(r.freq)), r.cpu_view.tobytes(),
                   _blob(vars(r.meter))),
        ))
    txns = tuple(
        (t.txid, t.origin, t.exec_node, t.thread, t.reexecs, t.forwards,
         t.reused, t.early, t.exec_done)
        for t in (cluster._inflight[k] for k in sorted(cluster._inflight)))
    m = cluster.metrics
    counters = (m.commits, m.ro_commits, m.rw_commits, m.aborts, m.forwards,
                m.lease_requests, m.piggybacks, m.rw_certified,
                len(m.commit_times))
    extras = (
        tuple(repr(r.bit_generator.state) for r in cluster.rngs),
        repr(cluster._txid), repr(cluster._reqid),
        round(cluster.gcs._seq_busy_until, 9),
        tuple(cluster.gcs.members),
        None if cluster.planner is None
        else digest(_blob(vars(cluster.planner))),
    )
    return (tuple(reps), txns, counters, extras)


def cluster_fingerprint(cluster) -> str:
    """Behavioral state + pending events, as one dedup key."""
    return digest(cluster_state(cluster), queue_state(cluster.events))
