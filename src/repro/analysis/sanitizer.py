"""Runtime lease-protocol sanitizer: Algorithm 1's invariants, checked live.

:class:`LeaseSanitizer` is an invariant-checking proxy around either lease
manager (the sequential oracle *or* the sharded array-backed manager —
instrumenting both is what localizes a divergence to the first violated
invariant instead of a trailing byte-diff).  It is a pure observer: every
protocol call forwards to the wrapped manager unchanged and returns its
result as-is, reading only post-state — so a sanitize-on run is
byte-identical to sanitize-off.

Checked per delivery instant (paper references in README "repro.analysis"):

* **single-owner / no double grant** — at most one live LOR per
  (req_id, proc, ccs); queue heads are owners by construction.
* **blocked-and-drained before free** — every freed LOR is blocked with
  ``activeXacts == 0``; opt-deliver frees additionally head all their
  queues (Alg. 1 l.26-33).
* **LOR conservation** — LORs are created at TO-deliver and retired by
  exactly one of UR-free / view-change purge; ``purge_proc`` removes the
  failed member's LORs and nobody else's.
* **prefetch-head** — a planner-prefetch LOR drains to ``activeXacts=0``
  only while heading its queue (else it wedges the class: the PR 5 bug).
* **enabled-divergence** — the sharded manager's vectorized
  ``enabled_mask`` is cross-checked against the sequential ``isEnabled``.

:func:`check_write_locks` covers the certification side (single-writer
write-locks in ``validate_batch`` inputs), and :class:`SanitizerError` is
also raised by :class:`repro.serve.certifier.StepCertifier` in sanitize
mode for lease-epoch monotonicity / owner-at-drain violations.
"""
from __future__ import annotations

from typing import Dict, FrozenSet, List, Optional, Sequence, Tuple

import numpy as np

Key = Tuple[int, int, Tuple[int, ...]]


class SanitizerError(AssertionError):
    """First violated protocol invariant, with localizing context."""

    def __init__(self, invariant: str, detail: str) -> None:
        self.invariant = invariant
        self.detail = detail
        super().__init__(f"[{invariant}] {detail}")


class LeaseSanitizer:
    """Invariant-checking proxy around a lease manager (oracle or sharded).

    Unknown attributes (owner queries, metrics, shard internals) forward to
    the wrapped manager, so the proxy is a drop-in at every call site.
    """

    _OWN = frozenset({
        "inner", "_live", "_prefetch", "_purged",
        "n_created", "n_freed", "n_purged", "n_events", "n_checks"})

    def __init__(self, inner) -> None:
        object.__setattr__(self, "inner", inner)
        object.__setattr__(self, "_live", set())      # keys currently queued
        object.__setattr__(self, "_prefetch", set())  # keys awaiting drain
        object.__setattr__(self, "_purged", set())    # keys view-changes took
        object.__setattr__(self, "n_created", 0)
        object.__setattr__(self, "n_freed", 0)
        object.__setattr__(self, "n_purged", 0)
        object.__setattr__(self, "n_events", 0)
        object.__setattr__(self, "n_checks", 0)

    # -- proxy plumbing ------------------------------------------------------
    def __getattr__(self, name):
        return getattr(self.inner, name)

    def __setattr__(self, name, value) -> None:
        if name in LeaseSanitizer._OWN:
            object.__setattr__(self, name, value)
        else:
            setattr(self.inner, name, value)

    def _fail(self, invariant: str, detail: str) -> None:
        raise SanitizerError(invariant, f"proc {self.inner.proc}: {detail}")

    def _in_queue(self, key: Key) -> bool:
        req_id, proc, ccs = key
        return all(
            any(l.req_id == req_id and l.proc == proc
                for l in self.inner.cq[cc])
            for cc in ccs)

    # -- TO-deliver: grants --------------------------------------------------
    def on_to_deliver(self, req):
        out = self.inner.on_to_deliver(req)
        self._granted([out])
        return out

    def to_deliver_batch(self, reqs):
        out = self.inner.to_deliver_batch(reqs)
        self._granted(out)
        return out

    def _granted(self, groups) -> None:
        for lors in groups:
            for l in lors:
                k = l.key()
                self.n_events += 1
                if k in self._live:
                    self._fail("single-owner",
                               f"double grant: LOR {k} enqueued while an "
                               f"identical live LOR exists")
                self._live.add(k)
                self.n_created += 1
                if not self._in_queue(k):
                    self._fail("conservation",
                               f"granted LOR {k} is absent from its "
                               f"conflict-class queue(s)")

    # -- Opt-deliver: blocking frees ----------------------------------------
    def on_opt_deliver(self, req):
        frees = self.inner.on_opt_deliver(req)
        self._check_frees(frees, "opt-deliver", require_head=True)
        return frees

    def opt_deliver_batch(self, reqs):
        frees = self.inner.opt_deliver_batch(reqs)
        self._check_frees(frees, "opt-deliver", require_head=True)
        return frees

    def _check_frees(self, frees, source: str, require_head: bool) -> None:
        for l in frees:
            k = l.key()
            self.n_checks += 1
            if k not in self._live:
                self._fail("conservation", f"{source} freed unknown LOR {k}")
            if not l.blocked:
                self._fail("blocked-and-drained",
                           f"{source} freed unblocked LOR {k}")
            if l.activeXacts != 0:
                self._fail("blocked-and-drained",
                           f"{source} freed LOR {k} with "
                           f"activeXacts={l.activeXacts}")
            if require_head and not self.inner.is_enabled([l]):
                # Alg. 1 l.30: the immediate free at blocking time only
                # fires for a LOR heading its queue
                self._fail("blocked-and-drained",
                           f"{source} freed LOR {k} that does not head "
                           f"all its queues")

    # -- FinishedXact: drains ------------------------------------------------
    def finished_xact(self, lors):
        frees = self.inner.finished_xact(lors)
        self._after_finish(lors, frees)
        return frees

    def finish_batch(self, groups):
        frees = self.inner.finish_batch(groups)
        self._after_finish([l for g in groups for l in g], frees)
        return frees

    def _after_finish(self, touched, frees) -> None:
        self._check_frees(frees, "finished_xact", require_head=False)
        for l in touched:
            k = l.key()
            if k in self._prefetch and l.activeXacts == 0:
                # PR 5 bug class: a prefetch LOR drained while non-head is
                # freed out of order (if blocked) or wedges its class as an
                # unfreeable dormant record (if not)
                self.n_checks += 1
                if not self.inner.is_enabled([l]):
                    self._fail("prefetch-head",
                               f"prefetch LOR {k} drained to activeXacts=0 "
                               f"while not heading its queue")
                self._prefetch.discard(k)

    # -- UR-deliver: retirement ----------------------------------------------
    def on_ur_deliver_freed(self, freed_keys):
        self._before_ur(freed_keys)
        out = self.inner.on_ur_deliver_freed(freed_keys)
        self._after_ur(freed_keys)
        return out

    def freed_batch(self, key_batches):
        flat = [k for batch in key_batches for k in batch]
        self._before_ur(flat)
        out = self.inner.freed_batch(key_batches)
        self._after_ur(flat)
        return out

    def _before_ur(self, keys) -> None:
        own = self.inner.proc
        for key in keys:
            self.n_events += 1
            req_id, proc, ccs = key
            if key not in self._live:
                if proc in self.inner._dead or key in self._purged:
                    continue  # late free after a purge: a legal no-op
                self._fail("conservation",
                           f"LeaseFreed for LOR {key} that was never "
                           f"granted or was already freed")
            if proc != own:
                # blocked/activeXacts are owner-local state — only the
                # generating replica's copy is meaningful (lease.LOR doc)
                continue
            for cc in ccs:
                for l in self.inner.cq[cc]:
                    if l.req_id == req_id and l.proc == proc:
                        self.n_checks += 1
                        if not l.blocked or l.activeXacts != 0:
                            self._fail(
                                "blocked-and-drained",
                                f"own LOR {key} freed while blocked="
                                f"{l.blocked}, activeXacts={l.activeXacts}")

    def _after_ur(self, keys) -> None:
        for key in keys:
            if key in self._live:
                self._live.discard(key)
                self.n_freed += 1
                if self._in_queue(key):
                    self._fail("conservation",
                               f"LeaseFreed for {key} left a queue entry "
                               f"behind")

    # -- view change ---------------------------------------------------------
    def purge_proc(self, proc: int):
        doomed = {k for k in self._live if k[1] == proc}
        survivors = self._live - doomed
        out = self.inner.purge_proc(proc)
        for k in doomed:
            if self._in_queue(k):
                self._fail("conservation",
                           f"purge_proc({proc}) left LOR {k} of the failed "
                           f"member queued")
        for k in survivors:
            self.n_checks += 1
            if not self._in_queue(k):
                self._fail("conservation",
                           f"purge_proc({proc}) dropped LOR {k} of a "
                           f"surviving member")
        self._live = survivors
        self._purged |= doomed
        self._prefetch -= doomed
        self.n_purged += len(doomed)
        return out

    # -- enablement ----------------------------------------------------------
    def enabled_mask(self, groups):
        out = self.inner.enabled_mask(groups)
        if getattr(self.inner, "settle", None) is not None:
            # sharded manager: cross-check the vectorized verdicts against
            # the sequential isEnabled loop — the first divergent group
            # names the kernel bug instead of a downstream byte-diff
            for g, got in zip(groups, out):
                self.n_checks += 1
                if bool(got) != self.inner.is_enabled(g):
                    self._fail(
                        "enabled-divergence",
                        f"enabled_mask verdict {bool(got)} diverges from "
                        f"sequential isEnabled for group "
                        f"{[l.key() for l in g]}")
        return out

    # -- piggybacking ---------------------------------------------------------
    def try_piggyback(self, ccs: FrozenSet[int]):
        out = self.inner.try_piggyback(ccs)
        if out:
            for l in out:
                self.n_checks += 1
                k = l.key()
                if k not in self._live:
                    self._fail("conservation",
                               f"piggyback returned unknown LOR {k}")
                if l.proc != self.inner.proc:
                    self._fail("single-owner",
                               f"piggyback on a remote LOR {k}")
                if l.blocked:
                    self._fail("blocked-and-drained",
                               f"piggyback on blocked LOR {k}")
        return out

    # -- hooks / reconciliation ----------------------------------------------
    def mark_prefetch(self, lors) -> None:
        """Cluster hook: these LORs belong to a planner prefetch and must
        drain to activeXacts=0 only at the head (prefetch-head rule)."""
        for l in lors:
            self._prefetch.add(l.key())

    def verify_full(self) -> None:
        """Full reconciliation: queue contents == live ledger, and
        created == freed + purged + live.  O(classes) — end-of-run/tests."""
        inq = set()
        for cc in range(self.inner.n_classes):
            for l in self.inner.cq[cc]:
                inq.add(l.key())
        if inq != self._live:
            extra = sorted(inq - self._live)
            missing = sorted(self._live - inq)
            self._fail("conservation",
                       f"queue/ledger divergence: {len(extra)} unledgered, "
                       f"{len(missing)} missing; e.g. "
                       f"{(extra + missing)[:3]}")
        if self.n_created != self.n_freed + self.n_purged + len(self._live):
            self._fail("conservation",
                       f"created={self.n_created} != freed={self.n_freed} "
                       f"+ purged={self.n_purged} + live={len(self._live)}")

    def counters(self) -> Dict[str, int]:
        return {"events": self.n_events, "checks": self.n_checks,
                "created": self.n_created, "freed": self.n_freed,
                "purged": self.n_purged, "live": len(self._live)}


def check_write_locks(node: int, owners: np.ndarray,
                      item_cc: Optional[np.ndarray],
                      locks: Optional[np.ndarray],
                      txns: Sequence, verdicts: Sequence) -> int:
    """Single-writer check on one certification batch (simulator side).

    Recomputes per-item write locks from the lease layer's *current*
    ownership view — independently of the production derivation — and
    flags (a) a stale/forged ``locks`` input to ``validate_batch``, and
    (b) any passing transaction that writes an item leased elsewhere.
    Returns the number of write slots checked.
    """
    if item_cc is None:
        return 0
    per_item = np.asarray(owners)[np.asarray(item_cc)]
    expected = (per_item >= 0) & (per_item != node)
    if locks is not None:
        got = np.asarray(locks).astype(bool)
        if not np.array_equal(got, expected):
            bad = np.flatnonzero(got != expected)
            raise SanitizerError(
                "write-locks",
                f"stale write-lock input at node {node}: {bad.size} "
                f"item(s) diverge from the lease ownership view, e.g. "
                f"item {int(bad[0])}")
    n = 0
    for t, ok in zip(txns, verdicts):
        if not ok:
            continue
        for item in t.write_set:
            n += 1
            if expected[item]:
                raise SanitizerError(
                    "write-locks",
                    f"txn {t.txid} passed certification at node {node} "
                    f"while writing item {item} leased to proc "
                    f"{int(per_item[item])}")
    return n
