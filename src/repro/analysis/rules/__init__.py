"""Lint rule registry.  Each module exposes a RULE with id/doc/check."""
from __future__ import annotations

from . import (event_determinism, host_sync, id_dtype, jit_static, ops_ref,
               pow2_pad, state_mut, trace_site)

ALL_RULES = [
    host_sync.RULE,
    id_dtype.RULE,
    ops_ref.RULE,
    state_mut.RULE,
    jit_static.RULE,
    pow2_pad.RULE,
    event_determinism.RULE,
    trace_site.RULE,
]
