"""event-determinism: core/ event code must be schedule-reproducible.

The explorer (repro.analysis.explore) re-executes the simulator once per
schedule and trusts that a run is a pure function of its decision trace.
Three bug classes silently break that contract, and each has bitten a
model checker before:

* **wall-clock reads** (``time.time`` & friends) — real time differs
  between runs, so any branch on it makes replay diverge;
* **unordered set iteration feeding scheduling decisions** — ``for x in
  some_set: events.schedule(...)`` dispatches in hash order, which varies
  with PYTHONHASHSEED and insertion history (iterate ``sorted(s)``);
* **id()-based ordering** — ``sorted(key=id)`` or ``id(a) < id(b)`` orders
  by allocation address, fresh every process.  Plain ``id()`` *membership*
  (``id(x) in seen``) is deterministic within a run and stays legal.

The rule only patrols ``core/`` — analysis/benchmark code may time itself.
"""
from __future__ import annotations

import ast
from typing import List, Optional, Set

from .. import astutil
from ..lint import FileCtx, Violation

WALL_CLOCK = {
    "time.time", "time.time_ns", "time.monotonic", "time.monotonic_ns",
    "time.perf_counter", "time.perf_counter_ns",
    "datetime.now", "datetime.utcnow", "datetime.today",
    "datetime.datetime.now", "datetime.datetime.utcnow",
}

# attribute-call names that commit a scheduling decision
SCHED_CALLS = {"schedule", "at", "oa_broadcast", "ur_broadcast", "send",
               "broadcast", "call_later"}

ORDERING_FNS = {"sorted", "min", "max", "sort"}


def _is_set_expr(e: ast.expr, set_names: Set[str]) -> bool:
    """Conservatively: is this expression an unordered set?"""
    if isinstance(e, ast.Set):
        return True
    if isinstance(e, ast.Call):
        fn = astutil.call_name(e).split(".")[-1]
        return fn in ("set", "frozenset")
    if isinstance(e, ast.Name):
        return e.id in set_names
    if isinstance(e, ast.BinOp) and isinstance(
            e.op, (ast.BitAnd, ast.BitOr, ast.Sub)):
        return _is_set_expr(e.left, set_names) or \
            _is_set_expr(e.right, set_names)
    return False


def _local_set_names(fn: ast.AST) -> Set[str]:
    """Names bound to set literals / set() calls inside this function."""
    out: Set[str] = set()
    for node in ast.walk(fn):
        if isinstance(node, ast.Assign):
            if _is_set_expr(node.value, out):
                for t in node.targets:
                    if isinstance(t, ast.Name):
                        out.add(t.id)
    return out


def _schedules_inside(body) -> Optional[ast.Call]:
    for stmt in body:
        for node in ast.walk(stmt):
            if isinstance(node, ast.Call):
                name = astutil.call_name(node).split(".")[-1]
                if name in SCHED_CALLS:
                    return node
    return None


def _is_id_func(e: Optional[ast.expr]) -> bool:
    return isinstance(e, ast.Name) and e.id == "id"


class Rule:
    id = "event-determinism"
    doc = ("core/ event code must be schedule-reproducible: no wall-clock "
           "reads, no unordered-set iteration feeding scheduling calls, "
           "no id()-based ordering")

    def check(self, ctx: FileCtx) -> List[Violation]:
        if "/core/" not in f"/{ctx.rel}":
            return []
        out: List[Violation] = []
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.Call):
                name = astutil.call_name(node)
                if name in WALL_CLOCK or name.split(".", 1)[-1] in WALL_CLOCK:
                    out.append(ctx.violation(
                        node, self.id,
                        f"wall-clock read '{name}' — the simulator runs on "
                        f"virtual time; real time diverges across replays"))
                    continue
                # sorted/min/max(..., key=id) and .sort(key=id)
                tail = name.split(".")[-1]
                if tail in ORDERING_FNS and \
                        _is_id_func(astutil.kwarg(node, "key")):
                    out.append(ctx.violation(
                        node, self.id,
                        f"'{tail}' ordered by id() — allocation addresses "
                        f"are fresh every process; order by a stable field"))
            elif isinstance(node, ast.Compare):
                # id(a) < id(b) is address ordering; id(x) in seen is a
                # legal identity-membership idiom and stays quiet
                operands = [node.left] + list(node.comparators)
                if any(isinstance(op, (ast.Lt, ast.LtE, ast.Gt, ast.GtE))
                       for op in node.ops) and \
                        any(isinstance(o, ast.Call) and _is_id_func(o.func)
                            for o in operands):
                    out.append(ctx.violation(
                        node, self.id,
                        "comparison of id() values orders by allocation "
                        "address — fresh every process"))
        # unordered iteration feeding scheduling (set-bound names resolved
        # file-wide; conservative but deterministic)
        set_names = _local_set_names(ctx.tree)
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.For):
                continue
            if not _is_set_expr(node.iter, set_names):
                continue
            call = _schedules_inside(node.body)
            if call is not None:
                out.append(ctx.violation(
                    node, self.id,
                    f"iterating an unordered set drives "
                    f"'{astutil.call_name(call).split('.')[-1]}' — "
                    f"dispatch order follows hash order; iterate "
                    f"sorted(...)"))
        return out


RULE = Rule()
