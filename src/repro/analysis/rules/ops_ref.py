"""ops-ref-parity: every public op in kernels/ops.py has a numpy twin.

The equivalence contract the whole repo leans on: each kernel dispatch
(`kernels/ops.py`) must reach a reference implementation in
``kernels/ref.py`` (the oracle the parity tests pin it against), and a
test under tests/ must actually exercise the op by name.  An op without a
twin has no bitwise oracle; an op without a test has an unpinned one.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Set

from .. import astutil
from ..lint import FileCtx, Violation


class Rule:
    id = "ops-ref-parity"
    doc = ("every public op in kernels/ops.py must reach a kernels/ref.py "
           "twin and be exercised by name in a test under tests/")

    def check(self, ctx: FileCtx) -> List[Violation]:
        if not ctx.rel.endswith("kernels/ops.py"):
            return []
        ref_src = ctx.project.read_text("src/repro/kernels/ref.py")
        if ref_src is None:
            return [Violation(ctx.rel, 0, self.id,
                              "kernels/ref.py missing: no twin registry")]
        ref_defs = {n.name for n in ast.parse(ref_src).body
                    if isinstance(n, ast.FunctionDef)}
        fns = {n.name: n for n in ctx.tree.body
               if isinstance(n, ast.FunctionDef)}
        refs: Dict[str, Set[str]] = {}
        calls: Dict[str, Set[str]] = {}
        for name, fn in fns.items():
            rr: Set[str] = set()
            cc: Set[str] = set()
            for node in ast.walk(fn):
                if isinstance(node, ast.Attribute) \
                        and isinstance(node.value, ast.Name) \
                        and node.value.id == "ref" \
                        and node.attr in ref_defs:
                    rr.add(node.attr)
                if isinstance(node, ast.Call) \
                        and isinstance(node.func, ast.Name):
                    cc.add(node.func.id)
            refs[name], calls[name] = rr, cc
        # propagate twin reachability through module-local helpers
        changed = True
        while changed:
            changed = False
            for name in fns:
                for callee in calls[name] & fns.keys():
                    extra = refs[callee] - refs[name]
                    if extra:
                        refs[name] |= extra
                        changed = True
        tests = ctx.project.tests_text()
        out: List[Violation] = []
        for name, fn in fns.items():
            if name.startswith("_"):
                continue
            if not refs[name]:
                out.append(ctx.violation(
                    fn, self.id,
                    f"public op '{name}' reaches no kernels/ref.py twin — "
                    f"no bitwise oracle"))
            elif name not in tests:
                out.append(ctx.violation(
                    fn, self.id,
                    f"public op '{name}' is never exercised by name in "
                    f"tests/ — parity unpinned"))
        return out


RULE = Rule()
