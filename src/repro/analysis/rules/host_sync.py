"""host-sync: no host synchronization inside jax.jit bodies.

Guards the hot paths (kernels/, plan/score.py) against the dispatch-stall
bug class: a numpy call, ``.item()``/``.tolist()``/``.block_until_ready()``,
or ``float()``/``int()``/``bool()`` on a traced value forces a device sync
per call, and environment queries (``jax.default_backend()``) silently bake
host state into the trace.
"""
from __future__ import annotations

import ast
from typing import List

from .. import astutil
from ..lint import FileCtx, Violation

NP_ROOTS = {"np", "numpy", "onp"}
HOST_ATTR_CALLS = {"item", "tolist", "block_until_ready",
                   "copy_to_host_async"}
ENV_QUERIES = {"jax.default_backend", "jax.devices", "jax.device_get",
               "jax.device_put", "jax.local_devices"}
CAST_BUILTINS = {"float", "int", "bool"}


class Rule:
    id = "host-sync"
    doc = ("no numpy calls, .item()/.tolist()/.block_until_ready(), "
           "float()/int()/bool() on tracers, or environment queries inside "
           "jax.jit bodies")

    def check(self, ctx: FileCtx) -> List[Violation]:
        out: List[Violation] = []
        for fn in astutil.jit_functions(ctx.tree):
            for node in ast.walk(fn):
                if not isinstance(node, ast.Call):
                    continue
                name = astutil.call_name(node)
                root = name.split(".")[0]
                if root in NP_ROOTS and "." in name:
                    out.append(ctx.violation(
                        node, self.id,
                        f"numpy call {name}() inside jit body '{fn.name}'"))
                elif name in ENV_QUERIES:
                    out.append(ctx.violation(
                        node, self.id,
                        f"{name}() inside jit body '{fn.name}' bakes host "
                        f"environment state into the trace"))
                elif isinstance(node.func, ast.Attribute) \
                        and node.func.attr in HOST_ATTR_CALLS:
                    out.append(ctx.violation(
                        node, self.id,
                        f".{node.func.attr}() inside jit body '{fn.name}' "
                        f"forces a device sync"))
                elif isinstance(node.func, ast.Name) \
                        and node.func.id in CAST_BUILTINS and node.args \
                        and not all(isinstance(a, ast.Constant)
                                    for a in node.args):
                    out.append(ctx.violation(
                        node, self.id,
                        f"{node.func.id}() on a traced value inside jit "
                        f"body '{fn.name}'"))
        return out


RULE = Rule()
