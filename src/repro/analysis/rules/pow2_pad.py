"""pow2-pad: compact axes feeding a kernel dispatch are pow2-padded.

A jit'd dispatch retraces per distinct shape: feeding it arrays sized by
raw ``len(...)``/``.size`` compiles one executable per batch size and
floods the trace cache.  Every compact axis that crosses the boundary is
blessed through ``_pow2``/``_pad_bucket`` first (the PR 4/PR 6 packing
discipline).  Only allocations actually passed to a dispatch call are
checked — host-side temporaries may size freely.
"""
from __future__ import annotations

import ast
import re
from typing import Dict, List, Set

from .. import astutil
from ..lint import FileCtx, Violation

DISPATCHERS = {"settle_lease_batch", "validate_transactions",
               "validate_batch", "lease_validate", "flash_attention",
               "ssd_scan", "_lease_settle_jit", "_lease_validate_ref_jit",
               "_score_moves_jit"}
ALLOC = {"full", "zeros", "empty", "ones"}
BLESS = re.compile(r"pow2|pad_bucket|next_pow|round_up")


class Rule:
    id = "pow2-pad"
    doc = ("arrays passed to a kernel dispatch must have their compact "
           "axes blessed through _pow2/_pad_bucket, not raw len()/.size")

    def check(self, ctx: FileCtx) -> List[Violation]:
        out: List[Violation] = []
        for fn in (n for n in ast.walk(ctx.tree)
                   if isinstance(n, ast.FunctionDef)):
            dispatch_args: Set[str] = set()
            for c in ast.walk(fn):
                if isinstance(c, ast.Call) and astutil.call_name(
                        c).split(".")[-1] in DISPATCHERS:
                    for a in list(c.args) + [kw.value for kw in c.keywords]:
                        for sub in ast.walk(a):
                            if isinstance(sub, ast.Name):
                                dispatch_args.add(sub.id)
            if not dispatch_args:
                continue
            # last-wins local dataflow: name -> source callee/attr
            env: Dict[str, str] = {}
            targets = astutil.assign_targets(fn)
            for node in ast.walk(fn):
                if isinstance(node, ast.Assign) \
                        and len(node.targets) == 1 \
                        and isinstance(node.targets[0], ast.Name):
                    v = node.value
                    if isinstance(v, ast.Call):
                        env[node.targets[0].id] = \
                            astutil.call_name(v).split(".")[-1]
                    elif isinstance(v, ast.Attribute):
                        env[node.targets[0].id] = v.attr
            for node in ast.walk(fn):
                if not (isinstance(node, ast.Call) and astutil.call_name(
                        node).split(".")[-1] in ALLOC and node.args):
                    continue
                if targets.get(id(node)) not in dispatch_args:
                    continue
                shape = node.args[0]
                elts = shape.elts if isinstance(
                    shape, (ast.Tuple, ast.List)) else [shape]
                for e in elts:
                    bad = None
                    if isinstance(e, ast.Call) and astutil.call_name(
                            e) == "len":
                        bad = "len(...)"
                    elif isinstance(e, ast.Attribute) and e.attr == "size":
                        bad = ".size"
                    elif isinstance(e, ast.Name):
                        src = env.get(e.id, "")
                        if src in ("len", "size", "shape"):
                            bad = f"'{e.id}' (= {src})"
                        elif src and BLESS.search(src):
                            continue
                    if bad:
                        out.append(ctx.violation(
                            node, self.id,
                            f"unpadded compact axis {bad} allocated for "
                            f"kernel dispatch in '{fn.name}' — bless "
                            f"through _pow2/_pad_bucket"))
        return out


RULE = Rule()
