"""id-dtype: protocol id arrays are int32 end to end.

The PR 4 bug class: an int32 read-log buffer viewed through
``np.frombuffer`` without an explicit dtype reads at the platform default
width (int64), silently interleaving garbage ids.  The rule bans
dtype-less ``frombuffer`` everywhere and flags id-named arrays
(class/slot/sid/req/proc/owner/item) created or cast as int64 — every
kernel boundary casts ids to int32, so int64 id arrays are a per-dispatch
conversion at best and a width bug at worst.
"""
from __future__ import annotations

import ast
from typing import List, Optional

from .. import astutil
from ..lint import FileCtx, Violation

# creator -> positional index of its dtype argument (None: kwarg-only)
CREATORS = {"asarray": 1, "array": 1, "empty": 1, "zeros": 1, "ones": 1,
            "full": 2, "fromiter": 1, "arange": None}
# creators whose first positional argument is a shape, not data — names in
# a shape (counts like n_items) are not id payloads
SHAPE_FIRST = {"empty", "zeros", "ones", "full"}


def _is_int64(e: Optional[ast.expr]) -> bool:
    if e is None:
        return False
    return (isinstance(e, ast.Attribute) and e.attr == "int64") or \
        (isinstance(e, ast.Name) and e.id == "int64") or \
        (isinstance(e, ast.Constant) and e.value == "int64")


def _mentioned_id(exprs) -> Optional[str]:
    for expr in exprs:
        for sub in ast.walk(expr):
            if isinstance(sub, ast.Name) and astutil.is_id_name(sub.id):
                return sub.id
            if isinstance(sub, ast.Attribute) \
                    and astutil.is_id_name(sub.attr):
                return sub.attr
    return None


class Rule:
    id = "id-dtype"
    doc = ("np.frombuffer needs an explicit dtype, and id-named arrays "
           "(cc/sid/slot/req/proc/owner/item) must not be created or cast "
           "as int64 — ids are int32 end to end")

    def check(self, ctx: FileCtx) -> List[Violation]:
        out: List[Violation] = []
        targets = astutil.assign_targets(ctx.tree)
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            name = astutil.call_name(node)
            attr = name.split(".")[-1]
            if attr == "frombuffer":
                if astutil.kwarg(node, "dtype") is None \
                        and len(node.args) < 2:
                    out.append(ctx.violation(
                        node, self.id,
                        "np.frombuffer without an explicit dtype views the "
                        "buffer at the platform default width"))
                continue
            dty = None
            data_args: List[ast.expr] = []
            if attr in CREATORS:
                dty = astutil.kwarg(node, "dtype")
                pos = CREATORS[attr]
                if dty is None and pos is not None and len(node.args) > pos:
                    dty = node.args[pos]
                skip = 1 if attr in SHAPE_FIRST else 0
                data_args = [a for a in node.args[skip:] if a is not dty]
            elif attr in ("astype", "view") and node.args:
                dty = node.args[0]
                if isinstance(node.func, ast.Attribute):
                    data_args = [node.func.value]
            else:
                continue
            if not _is_int64(dty):
                continue
            # binding name first (`versions = np.zeros(..., np.int64)` is a
            # version vector even if a count like n_items sits in its
            # shape), then id names in the *data* arguments — shapes and
            # dtypes never carry id payloads
            ident = targets.get(id(node))
            if not astutil.is_id_name(ident):
                ident = _mentioned_id(data_args)
            if ident:
                out.append(ctx.violation(
                    node, self.id,
                    f"int64 id array '{ident}' — protocol ids are int32 "
                    f"end to end"))
        return out


RULE = Rule()
