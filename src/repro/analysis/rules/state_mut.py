"""state-mutation: replicated protocol state has exactly one owner module.

``VersionedStore`` arrays and the lease managers' queue/cell state are
replicated via total order — an out-of-band write at one replica silently
diverges the cluster.  Everyone outside the owning module goes through the
manager API (``apply_batch``, ``grow_to``, the ``on_*`` protocol events).
"""
from __future__ import annotations

import ast
from typing import List

from ..lint import FileCtx, Violation

# VersionedStore internals (owner: core/stm.py)
STORE_ATTRS = {"values", "versions", "clock", "n_items"}
# lease-manager structural state (owners: core/lease.py,
# core/lease_batched.py); n_slots is deliberately absent — too generic
# (CpuMeter, KVStore slabs) and never moves without slot_of/qlen anyway
LEASE_STRICT = {"cq", "qlen", "slot_of", "row_of",
                "_by_req", "_pending_opt", "_pending_cnt", "_dead"}
# per-cell arrays: common names, so only subscripted stores are flagged
LEASE_CELLS = {"blocked", "active", "req", "proc"}

OWNERS = ("core/stm.py", "core/lease.py", "core/lease_batched.py")


def _flat_targets(node):
    tgts = []
    if isinstance(node, ast.Assign):
        tgts = list(node.targets)
    elif isinstance(node, (ast.AugAssign, ast.AnnAssign)):
        tgts = [node.target]
    out = []
    while tgts:
        t = tgts.pop()
        if isinstance(t, (ast.Tuple, ast.List)):
            tgts.extend(t.elts)
        else:
            out.append(t)
    return out


class Rule:
    id = "state-mutation"
    doc = ("VersionedStore / lease-manager replicated state is mutated "
           "only by its owning core module; use the manager API elsewhere")

    def check(self, ctx: FileCtx) -> List[Violation]:
        if ctx.rel.endswith(OWNERS):
            return []
        out: List[Violation] = []
        for node in ast.walk(ctx.tree):
            for t in _flat_targets(node):
                sub = isinstance(t, ast.Subscript)
                base = t.value if sub else t
                if not isinstance(base, ast.Attribute):
                    continue
                a = base.attr
                if a in STORE_ATTRS or a in LEASE_STRICT \
                        or (sub and a in LEASE_CELLS):
                    out.append(ctx.violation(
                        node, self.id,
                        f"mutation of protected protocol state '.{a}' "
                        f"outside its owning module"))
        return out


RULE = Rule()
