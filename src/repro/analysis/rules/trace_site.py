"""event-trace-site: trace call sites must pass static event names.

The tracing contract (repro.obs.trace) is zero-cost when disabled: one
branch per site, nothing evaluated on the untaken path.  An f-string (or
any computed expression) as the event *name* breaks that two ways — the
string is built before the call even when the recorder drops it, and the
trace vocabulary stops being greppable (``rg '"lease-round"'`` must find
every emitter).  Dynamic *track* strings and payload kwargs are fine:
they are only evaluated inside the enabled branch.

The rule fires on ``<recv>.span/instant/abegin/aend/counter(...)`` calls
whose receiver reads like a trace recorder (``tr``, anything containing
``trace``) and whose first positional argument is not a string literal.
"""
from __future__ import annotations

import ast
from typing import List

from ..lint import FileCtx, Violation

RECORDER_METHODS = {"span", "instant", "abegin", "aend", "counter"}


def _recv_text(e: ast.expr) -> str:
    try:
        return ast.unparse(e).lower()
    except Exception:  # pragma: no cover - exotic receivers
        return ""


def _is_trace_receiver(e: ast.expr) -> bool:
    text = _recv_text(e)
    return text == "tr" or "trace" in text


class Rule:
    id = "event-trace-site"
    doc = ("trace recorder call sites must pass a static string event "
           "name — computed names allocate on the disabled path and break "
           "trace-vocabulary grepability")

    def check(self, ctx: FileCtx) -> List[Violation]:
        out: List[Violation] = []
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            f = node.func
            if not isinstance(f, ast.Attribute) \
                    or f.attr not in RECORDER_METHODS:
                continue
            if not _is_trace_receiver(f.value):
                continue
            if not node.args:
                continue
            first = node.args[0]
            if isinstance(first, ast.Constant) \
                    and isinstance(first.value, str):
                continue
            kind = ("f-string" if isinstance(first, ast.JoinedStr)
                    else type(first).__name__)
            out.append(ctx.violation(
                node, self.id,
                f"trace .{f.attr}() called with a computed event name "
                f"({kind}) — pass a string literal; put variability in "
                f"the track or payload"))
        return out


RULE = Rule()
