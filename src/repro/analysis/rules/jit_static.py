"""static-args: static_argnames hygiene on jit decorators.

A ``static_argnames`` entry that names no real parameter is silently
ignored by jax — the intended-static argument then retraces (or fails to
hash) per call.  A static parameter with an unhashable default raises only
on the first defaulted call, usually in production.
"""
from __future__ import annotations

import ast
from typing import List

from .. import astutil
from ..lint import FileCtx, Violation


def _static_names(sa: ast.expr) -> List[str]:
    if isinstance(sa, ast.Constant) and isinstance(sa.value, str):
        return [sa.value]
    if isinstance(sa, (ast.Tuple, ast.List, ast.Set)):
        return [e.value for e in sa.elts
                if isinstance(e, ast.Constant) and isinstance(e.value, str)]
    return []


class Rule:
    id = "static-args"
    doc = ("static_argnames entries must name real parameters, and "
           "statically-marked parameters need hashable defaults")

    def check(self, ctx: FileCtx) -> List[Violation]:
        out: List[Violation] = []
        for node in ast.walk(ctx.tree):
            if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            for dec in node.decorator_list:
                if not (isinstance(dec, ast.Call)
                        and astutil.jit_decorator(dec)):
                    continue
                sa = astutil.kwarg(dec, "static_argnames")
                if sa is None:
                    continue
                a = node.args
                params = {p.arg for p in a.args + a.posonlyargs
                          + a.kwonlyargs}
                defaults = {}
                pos = a.posonlyargs + a.args
                for p, d in zip(pos[len(pos) - len(a.defaults):],
                                a.defaults):
                    defaults[p.arg] = d
                for p, d in zip(a.kwonlyargs, a.kw_defaults):
                    if d is not None:
                        defaults[p.arg] = d
                for name in _static_names(sa):
                    if name not in params:
                        out.append(ctx.violation(
                            dec, self.id,
                            f"static_argnames entry '{name}' is not a "
                            f"parameter of '{node.name}'"))
                    elif isinstance(defaults.get(name),
                                    (ast.List, ast.Dict, ast.Set)):
                        out.append(ctx.violation(
                            dec, self.id,
                            f"static parameter '{name}' of '{node.name}' "
                            f"has an unhashable default"))
        return out


RULE = Rule()
