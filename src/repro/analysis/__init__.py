"""repro.analysis — mechanical checks for the lease/certification stack.

Two engines:

* :mod:`repro.analysis.lint` — AST-based static rules over the source tree
  (host syncs in jit bodies, id-dtype discipline, ops<->ref parity,
  protocol-state mutation, static_argnames hygiene, pow2 padding).
  Stdlib-only; runnable as ``python -m repro.analysis.lint``.
* :mod:`repro.analysis.sanitizer` — runtime lease-protocol invariant
  checker (``SimConfig.sanitize=True`` / ``StepCertifier(sanitize=True)``)
  asserting Algorithm 1's invariants per delivery instant.

The sanitizer import is deferred so the lint CLI never pulls in numpy.
"""
from __future__ import annotations

__all__ = ["LeaseSanitizer", "SanitizerError", "check_write_locks"]


def __getattr__(name):
    if name in __all__:
        from . import sanitizer

        return getattr(sanitizer, name)
    raise AttributeError(name)
