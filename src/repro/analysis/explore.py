"""Schedule-space explorer: model-checking the lease protocol.

Stateless model checking over the deterministic simulator.  A *model* is a
re-constructible simulation (a full :class:`~repro.core.cluster.Cluster`, or
a scripted protocol scenario from :mod:`repro.analysis.scenarios`); the
explorer re-executes it once per schedule with a recording
:class:`~repro.core.events.SchedulePolicy` that controls dispatch order among
the *enabled* events — the same-instant group plus message deliveries within
a bounded commutation window.  Eligibility (TO total order, opt-before-TO,
per-sender FIFO) is enforced by the policy seam, so every explored schedule
is one the real GCS could have produced.

Strategies
----------
* ``exhaustive`` — depth-first enumeration of all legal interleavings with
  **sleep-set partial-order reduction** (two deliveries whose conflict-class
  key sets are disjoint commute; exploring both orders is redundant) and
  **state dedup** on a canonical protocol-state fingerprint
  (:mod:`repro.analysis.fingerprint`).
* ``pct`` — randomized priority schedules (PCT-style): each run draws lazy
  per-event priorities from a seeded RNG and occasionally demotes the
  running winner, probing deep reorderings exhaustive search can't reach
  within budget.
* ``replay`` — re-run one recorded schedule exactly (counterexample replay).

Every schedule runs with the :class:`~repro.analysis.sanitizer.LeaseSanitizer`
installed, plus a terminal **quiescence** check: once the closed-loop
simulation drains, any surviving waiter or in-flight transaction is a lease
circulation deadlock no per-event invariant can see.  On a violation the
decision trace is delta-debugged (``ddmin``) to a minimal set of deviations
from the default FIFO order and written as a JSON artifact that
``repro-explore replay <trace.json>`` reproduces deterministically.
"""
from __future__ import annotations

import argparse
import sys
from dataclasses import dataclass, field, replace
from typing import Callable, Dict, FrozenSet, List, Optional, Set, Tuple

import numpy as np

from repro.analysis.fingerprint import cluster_fingerprint, digest
from repro.analysis.sanitizer import SanitizerError
from repro.analysis.trace import (Cand, Decision, Trace, ddmin, load_trace,
                                  save_trace)
from repro.core.events import SchedulePolicy, _Event


# --------------------------------------------------------------------------
# Configuration / results
# --------------------------------------------------------------------------

@dataclass
class ExploreConfig:
    """Exploration knobs; also the ``SimConfig.explore`` payload.

    ``policy`` is runtime plumbing, not a knob: the explorer re-constructs
    the model per schedule and injects its recording policy through this
    field (see ``SimConfig.explore`` / ``Cluster.__init__``).
    """

    strategy: str = "exhaustive"       # exhaustive | pct | replay
    window_ms: float = 0.0             # delivery commutation window
    max_schedules: int = 2000
    max_depth: int = 1 << 30           # branching depth bound (decisions)
    por: bool = True                   # sleep-set partial-order reduction
    dedup: bool = True                 # fingerprint state dedup
    minimize: bool = True              # ddmin counterexamples
    pct_seeds: int = 16
    pct_change: float = 0.1            # priority-demotion probability
    seed: int = 0
    check_quiescence: bool = True
    max_events: int = 500_000          # per-schedule dispatch bound
    policy: Optional[SchedulePolicy] = field(
        default=None, repr=False, compare=False)


@dataclass
class ExploreStats:
    schedules: int = 0                 # completed (non-pruned) runs
    pruned_sleep: int = 0              # runs cut by sleep sets
    states_deduped: int = 0            # runs cut by fingerprint dedup
    branches: int = 0                  # alternatives enqueued
    decisions: int = 0                 # total branching points visited
    truncated: bool = False            # hit max_schedules with work left

    @property
    def runs(self) -> int:
        """Everything started, including pruned runs."""
        return self.schedules + self.pruned_sleep + self.states_deduped


@dataclass
class ExploreResult:
    stats: ExploreStats
    violation: Optional[Trace] = None      # first counterexample, as run
    minimized: Optional[Trace] = None      # ddmin'd counterexample

    @property
    def ok(self) -> bool:
        return self.violation is None


class ReplayDivergence(RuntimeError):
    """A forced choice was absent or ineligible — the model diverged."""


class _Pruned(Exception):
    """Internal: this schedule is redundant; abandon the run."""

    def __init__(self, why: str) -> None:
        self.why = why
        super().__init__(why)


def _indep(a: Optional[FrozenSet[int]], b: Optional[FrozenSet[int]]) -> bool:
    """Commutation oracle: disjoint, known conflict-class footprints."""
    return a is not None and b is not None and not (a & b)


# --------------------------------------------------------------------------
# The recording policy
# --------------------------------------------------------------------------

class RecorderPolicy(SchedulePolicy):
    """A :class:`SchedulePolicy` that forces a prefix and records the rest.

    Modes (mutually exclusive):

    * *explore* (default): replay ``prefix`` choices, then pick the first
      eligible non-sleeping candidate (or by PCT priorities when ``rng`` is
      set), recording every decision.  Sleep-set filtering and fingerprint
      dedup activate only once the forced prefix is consumed — the prefix
      deterministically re-creates the branch point, it is not a new
      exploration.
    * *deviation* (``devs``): follow default FIFO order except at the given
      ``{decision index: seq}`` overrides — the ddmin replay primitive.
    """

    def __init__(self, window: float = 0.0,
                 prefix: Optional[List[int]] = None,
                 sleep: Optional[Dict[int, Optional[FrozenSet[int]]]] = None,
                 devs: Optional[Dict[int, int]] = None,
                 rng=None, change_prob: float = 0.0) -> None:
        super().__init__()
        self.window = window
        self.prefix = list(prefix or [])
        self.init_sleep = dict(sleep or {})
        self.devs = devs
        self.rng = rng
        self.change_prob = change_prob
        self.use_sleep = devs is None and rng is None
        # recording
        self.decisions: List[Decision] = []
        self.choices: List[int] = []
        self.sleep_at: List[Optional[Dict[int, Optional[FrozenSet[int]]]]] = []
        # live sleep set (seq -> keys); armed once the prefix is consumed
        self.sleep: Dict[int, Optional[FrozenSet[int]]] = {}
        self._armed = False
        self._prio: Dict[int, float] = {}
        # dedup plumbing, injected by the explorer after model construction
        self.fingerprint_fn: Optional[Callable[[], str]] = None
        self.seen: Optional[Set[str]] = None
        self.stats: Optional[ExploreStats] = None

    # -- helpers -------------------------------------------------------------
    def _arm(self) -> None:
        if not self._armed and len(self.choices) >= len(self.prefix):
            self.sleep = dict(self.init_sleep)
            self._armed = True

    def _forced(self, k: int) -> Optional[int]:
        if self.devs is not None:
            return self.devs.get(k)
        if k < len(self.prefix):
            return self.prefix[k]
        return None

    def _choose(self, free: List[int], pool: List[_Event]) -> int:
        if self.rng is None:
            return free[0]
        best, bestp = free[0], -1.0
        for i in free:
            s = pool[i].seq
            p = self._prio.get(s)
            if p is None:
                p = float(self.rng.random())
                self._prio[s] = p
            if p > bestp:
                best, bestp = i, p
        if self.change_prob and self.rng.random() < self.change_prob:
            # PCT change point: demote the winner so later decisions differ
            self._prio[pool[best].seq] = float(self.rng.random()) * 0.01
        return best

    # -- SchedulePolicy hooks ------------------------------------------------
    def select(self, pool: List[_Event]) -> int:
        cands = []
        eligible: List[int] = []
        for i, ev in enumerate(pool):
            ok = self.eligible(ev)
            if ok:
                eligible.append(i)
            m = ev.meta
            cands.append(Cand(
                seq=ev.seq, time=round(ev.time, 9),
                kind="local" if m is None else m.kind,
                node=-1 if m is None else m.node,
                label="" if m is None else m.label,
                keys=None if m is None or m.keys is None
                else tuple(sorted(m.keys)),
                eligible=ok))
        if not eligible:
            return 0  # unreachable for well-formed metadata; fail open
        default = pool[eligible[0]].seq
        k = len(self.choices)
        want = self._forced(k)
        if want is not None:
            idx = next((i for i, ev in enumerate(pool)
                        if ev.seq == want), None)
            if idx is None or idx not in eligible:
                raise ReplayDivergence(
                    f"decision {k}: forced seq {want} "
                    f"{'absent' if idx is None else 'ineligible'} in pool "
                    f"[{', '.join(c.label or str(c.seq) for c in cands)}]")
            snap = None
        else:
            self._arm()
            if self.fingerprint_fn is not None:
                # the queue's _pick pops the candidate pool off the heap
                # before select runs, so the model's pending-event view
                # excludes it — hash the pool into the key (labels
                # identify deliveries schedule-robustly; raw seqs only
                # identify opaque unlabeled locals)
                pool_view = tuple(
                    (c.time, c.kind, c.node, c.label) if c.label
                    else (c.time, c.kind, c.node, c.seq) for c in cands)
                fp = digest(self.fingerprint_fn(), pool_view)
                if fp in self.seen:
                    if self.stats is not None:
                        self.stats.states_deduped += 1
                    raise _Pruned("dedup")
                self.seen.add(fp)
            if self.use_sleep:
                free = [i for i in eligible if pool[i].seq not in self.sleep]
                if not free:
                    if self.stats is not None:
                        self.stats.pruned_sleep += 1
                    raise _Pruned("sleep")
            else:
                free = eligible
            idx = self._choose(free, pool)
            snap = dict(self.sleep) if self.use_sleep else {}
        ev = pool[idx]
        self.decisions.append(Decision(
            time=round(ev.time, 9), cands=cands, chosen=ev.seq,
            default=default))
        self.choices.append(ev.seq)
        self.sleep_at.append(snap)
        if self.stats is not None:
            self.stats.decisions += 1
        return idx

    def on_dispatch(self, ev: _Event) -> None:
        super().on_dispatch(ev)
        if not self.use_sleep:
            return
        self._arm()
        if not self._armed:
            return
        if ev.seq in self.sleep:
            # a sleeping event fired with no competition: this whole
            # continuation was already covered from the sibling branch
            if self.stats is not None:
                self.stats.pruned_sleep += 1
            raise _Pruned("sleep")
        k = None if ev.meta is None else ev.meta.keys
        if self.sleep:
            self.sleep = {s: sk for s, sk in self.sleep.items()
                          if _indep(sk, k)}


# --------------------------------------------------------------------------
# Models
# --------------------------------------------------------------------------

class ClusterModel:
    """A full :class:`~repro.core.cluster.Cluster` run as an explorable model.

    The config is forced to ``sanitize=True`` and the recording policy is
    injected through ``SimConfig.explore``.  ``go()`` runs the configured
    duration + drain, then keeps draining to quiescence (the loop is closed
    once ``_stopped`` is set, so the queue empties unless the protocol
    wedged) and re-verifies every surviving replica's full lease state.
    """

    def __init__(self, cfg, workload, policy: SchedulePolicy,
                 fail_at: Optional[Tuple[float, int]] = None,
                 max_events: int = 500_000) -> None:
        from repro.core.cluster import Cluster

        cfg = replace(cfg, sanitize=True,
                      explore=ExploreConfig(policy=policy))
        self.cluster = Cluster(cfg, workload)
        self.events = self.cluster.events
        self.max_events = max_events
        if fail_at is not None:
            t, node = fail_at
            self.events.schedule(
                t, (lambda c=self.cluster, n=node: c.gcs.fail(n)))

    def go(self) -> None:
        c = self.cluster
        c.run()
        horizon = c.cfg.duration_ms + c.cfg.drain_ms + 60_000.0
        c.events.run(horizon, max_events=self.max_events)
        for r in c.replicas:
            if c.gcs.alive(r.node):
                r.lm.verify_full()

    def fingerprint(self) -> str:
        return cluster_fingerprint(self.cluster)

    def wedged(self) -> List[str]:
        if not self.cluster.events.empty():
            return ["event queue never quiesced (dispatch bound hit)"]
        return self.cluster.wedged()


# --------------------------------------------------------------------------
# Single-schedule execution
# --------------------------------------------------------------------------

def _execute(model, cfg: ExploreConfig) -> Optional[Tuple[str, str]]:
    """Run one schedule to completion; return the violation, if any.

    Raises :class:`_Pruned` / :class:`ReplayDivergence` through (the caller
    decides what they mean); converts sanitizer and assertion failures into
    ``(invariant, detail)`` tuples and appends the quiescence check.
    """
    try:
        model.go()
    except (_Pruned, ReplayDivergence):
        raise
    except SanitizerError as e:
        return (e.invariant, e.detail)
    except AssertionError as e:
        return ("assertion", str(e))
    if cfg.check_quiescence:
        w = model.wedged()
        if w:
            return ("quiescence", "; ".join(w))
    return None


def _run_one(build, cfg: ExploreConfig, stats: ExploreStats,
             prefix: List[int],
             sleep: Dict[int, Optional[FrozenSet[int]]],
             seen: Optional[Set[str]], rng=None):
    """Execute one schedule; returns (outcome, policy, violation)."""
    pol = RecorderPolicy(cfg.window_ms, prefix=prefix,
                         sleep=sleep if cfg.por else {},
                         rng=rng, change_prob=cfg.pct_change)
    if not cfg.por:
        pol.use_sleep = False
    model = build(pol)
    if cfg.dedup and seen is not None:
        pol.fingerprint_fn = model.fingerprint
        pol.seen = seen
    pol.stats = stats
    try:
        vio = _execute(model, cfg)
    except _Pruned as p:
        return (p.why, pol, None)
    stats.schedules += 1
    return ("done", pol, vio)


# --------------------------------------------------------------------------
# Strategies
# --------------------------------------------------------------------------

def _branches(pol: RecorderPolicy, cfg: ExploreConfig, stats: ExploreStats,
              stack: List) -> None:
    """Enumerate untried alternatives of a completed run (DFS, sleep sets).

    Only decisions at depth >= the forced prefix are branched — shallower
    alternatives were enqueued when the ancestor run completed.
    """
    lo = len(pol.prefix)
    hi = min(len(pol.decisions), cfg.max_depth)
    for k in range(lo, hi):
        d = pol.decisions[k]
        snap = pol.sleep_at[k] or {}
        node_sleep = dict(snap) if cfg.por else {}
        by_seq = {c.seq: c for c in d.cands}
        if cfg.por:
            chosen = by_seq[d.chosen]
            node_sleep[d.chosen] = (None if chosen.keys is None
                                    else frozenset(chosen.keys))
        for c in d.cands:
            if c.seq == d.chosen or not c.eligible:
                continue
            if cfg.por and c.seq in node_sleep:
                continue
            ckeys = None if c.keys is None else frozenset(c.keys)
            child = ({u: ku for u, ku in node_sleep.items()
                      if _indep(ku, ckeys)} if cfg.por else {})
            stack.append((pol.choices[:k] + [c.seq], child))
            stats.branches += 1
            if cfg.por:
                node_sleep[c.seq] = ckeys


def _explore_exhaustive(build, cfg: ExploreConfig, stats: ExploreStats):
    seen: Optional[Set[str]] = set() if cfg.dedup else None
    stack: List = [([], {})]
    while stack:
        if stats.runs >= cfg.max_schedules:
            stats.truncated = True
            return None
        prefix, sleep = stack.pop()
        outcome, pol, vio = _run_one(build, cfg, stats, prefix, sleep, seen)
        if outcome != "done":
            continue
        if vio is not None:
            return (pol, vio)
        _branches(pol, cfg, stats, stack)
    return None


def _explore_pct(build, cfg: ExploreConfig, stats: ExploreStats):
    seen: Optional[Set[str]] = set() if cfg.dedup else None
    for run in range(cfg.pct_seeds):
        if stats.runs >= cfg.max_schedules:
            stats.truncated = True
            return None
        # run 0 is the default FIFO schedule (rng=None): PCT results always
        # include the schedule the plain simulator would have executed
        rng = (None if run == 0
               else np.random.default_rng(cfg.seed * 10_000 + run))
        outcome, pol, vio = _run_one(build, cfg, stats, [], {}, seen,
                                     rng=rng)
        if outcome == "done" and vio is not None:
            return (pol, vio)
    return None


# --------------------------------------------------------------------------
# Minimization + replay
# --------------------------------------------------------------------------

def _run_devs(build, cfg: ExploreConfig,
              devs: Dict[int, int]) -> Tuple[RecorderPolicy,
                                             Optional[Tuple[str, str]]]:
    pol = RecorderPolicy(cfg.window_ms, devs=devs)
    model = build(pol)
    vio = _execute(model, cfg)
    return pol, vio


def minimize(build, cfg: ExploreConfig, trace: Trace) -> Trace:
    """ddmin the trace's deviations-from-FIFO to a 1-minimal counterexample.

    The minimized trace reproduces the *same invariant* (details may differ
    textually).  Falls back to the original trace if the deviation replay
    unexpectedly fails to reproduce (model nondeterminism would be a bug —
    tests pin against it).
    """
    assert trace.violation is not None
    target = trace.violation[0]

    def test(subset) -> bool:
        try:
            _, vio = _run_devs(build, cfg, dict(subset))
        except ReplayDivergence:
            return False
        return vio is not None and vio[0] == target

    devs = trace.deviations()
    if not test(devs):
        return trace
    mind = ddmin(devs, test) if devs else devs
    pol, vio = _run_devs(build, cfg, dict(mind))
    return Trace(model=trace.model, args=trace.args,
                 window_ms=cfg.window_ms, decisions=pol.decisions,
                 violation=vio)


def replay_trace(build, trace: Trace,
                 cfg: Optional[ExploreConfig] = None) -> Optional[Tuple[str, str]]:
    """Re-run a recorded schedule exactly; return the violation observed."""
    cfg = cfg or ExploreConfig(strategy="replay", window_ms=trace.window_ms)
    pol = RecorderPolicy(trace.window_ms, prefix=trace.chosen)
    pol.use_sleep = False
    model = build(pol)
    return _execute(model, cfg)


# --------------------------------------------------------------------------
# Entry point
# --------------------------------------------------------------------------

def explore(build, cfg: ExploreConfig, model: str = "model",
            args: Optional[Dict] = None) -> ExploreResult:
    """Explore the schedule space of ``build(policy) -> model``.

    ``model``/``args`` name a :mod:`repro.analysis.scenarios` entry so the
    emitted counterexample traces are replayable from the CLI.
    """
    stats = ExploreStats()
    if cfg.strategy == "exhaustive":
        hit = _explore_exhaustive(build, cfg, stats)
    elif cfg.strategy == "pct":
        hit = _explore_pct(build, cfg, stats)
    else:
        raise ValueError(f"unknown strategy {cfg.strategy!r}")
    if hit is None:
        return ExploreResult(stats=stats)
    pol, vio = hit
    trace = Trace(model=model, args=dict(args or {}),
                  window_ms=cfg.window_ms, decisions=pol.decisions,
                  violation=vio)
    minimized = minimize(build, cfg, trace) if cfg.minimize else None
    return ExploreResult(stats=stats, violation=trace, minimized=minimized)


def explore_scenario(name: str, cfg: ExploreConfig,
                     args: Optional[Dict] = None) -> ExploreResult:
    """Explore a registered scenario by name (see analysis/scenarios.py)."""
    from repro.analysis.scenarios import get_scenario

    build = get_scenario(name)
    a = dict(args or {})
    return explore(lambda pol: build(a, pol), cfg, model=name, args=a)


# --------------------------------------------------------------------------
# Smoke grid (CI): explore tiny real-cluster configs, expect NO violations
# --------------------------------------------------------------------------

SMOKE_CELLS: List[Tuple[str, Dict, ExploreConfig]] = [
    # exhaustive on a 2-node / 4-class bank, both control planes x handoffs
    # (1.5 ms of simulated traffic: sized so the POR+dedup exploration
    # COMPLETES well under the budget while the naive enumeration blows
    # through it — the --check reduction-ratio gate measures exactly that)
    *[
        ("smoke-bank", {"lease_mode": lm, "handoff": ho,
                        "duration_ms": 1.5},
         ExploreConfig(strategy="exhaustive", window_ms=0.4,
                       max_schedules=600))
        for lm in ("sequential", "batched")
        for ho in ("drain", "pipelined")
    ],
    # randomized priorities on the planner-on failure-injection config
    ("smoke-planner-failure", {},
     ExploreConfig(strategy="pct", pct_seeds=12, window_ms=0.4,
                   max_schedules=64)),
]


def run_smoke(out_dir: Optional[str] = None,
              max_schedules: Optional[int] = None,
              check_reduction: bool = False,
              quiet: bool = False) -> int:
    """Run the CI exploration grid; returns a process exit code.

    Writes any counterexample traces into ``out_dir`` (CI uploads them as
    artifacts).  With ``check_reduction``, also measures sleep-set POR
    pruning on the first exhaustive cell and fails unless it cuts the naive
    schedule count at least 2x.
    """
    import os
    import time

    failures = 0
    reduced_runs: Dict[int, int] = {}
    say = (lambda *a: None) if quiet else print
    for i, (name, args, cfg) in enumerate(SMOKE_CELLS):
        if max_schedules is not None:
            cfg = replace(cfg, max_schedules=max_schedules)
        t0 = time.perf_counter()
        res = explore_scenario(name, cfg, args)
        dt = time.perf_counter() - t0
        s = res.stats
        reduced_runs[i] = s.runs
        tag = f"{name} {args}" if args else name
        rate = s.runs / dt if dt > 0 else float("inf")
        say(f"[{i + 1}/{len(SMOKE_CELLS)}] {tag}: "
            f"{s.schedules} schedules ({s.pruned_sleep} sleep-pruned, "
            f"{s.states_deduped} deduped, {s.branches} branches) "
            f"in {dt:.2f}s ({rate:.0f} runs/s)"
            f"{' [truncated]' if s.truncated else ''}")
        if not res.ok:
            failures += 1
            inv, detail = res.violation.violation
            say(f"    VIOLATION [{inv}] {detail}")
            if out_dir is not None:
                os.makedirs(out_dir, exist_ok=True)
                path = os.path.join(out_dir, f"counterexample-{i + 1}.json")
                save_trace(path, res.minimized or res.violation)
                say(f"    minimized counterexample -> {path} "
                    f"(repro-explore replay {path})")
    if check_reduction:
        name, args, cfg = SMOKE_CELLS[0]
        if max_schedules is not None:
            cfg = replace(cfg, max_schedules=max_schedules)
        naive = ExploreStats()
        base = replace(cfg, por=False, dedup=False, minimize=False)
        _explore_exhaustive(
            lambda pol: _smoke_build(name, args, pol), base, naive)
        red = max(1, reduced_runs.get(0, 1))
        ratio = naive.runs / red
        say(f"POR reduction on {name} {args}: naive {naive.runs} runs vs "
            f"{red} reduced -> {ratio:.1f}x")
        if ratio < 2.0:
            say("    FAIL: reduction ratio below 2x")
            failures += 1
    return 1 if failures else 0


def _smoke_build(name: str, args: Dict, pol: SchedulePolicy):
    from repro.analysis.scenarios import get_scenario

    return get_scenario(name)(dict(args), pol)


# --------------------------------------------------------------------------
# CLI
# --------------------------------------------------------------------------

def _main_replay(argv: List[str]) -> int:
    ap = argparse.ArgumentParser(
        prog="repro-explore replay",
        description="Deterministically re-run a counterexample trace.")
    ap.add_argument("trace", help="trace JSON emitted by the explorer")
    ap.add_argument("--trace-out", "--trace", dest="trace_out", default=None,
                    metavar="OUT.json",
                    help="also export a repro.obs timeline of the replay "
                         "(Perfetto trace_event JSON): per-delivery "
                         "dispatch instants on per-node tracks, so the "
                         "minimized counterexample is visually "
                         "inspectable")
    ns = ap.parse_args(argv)
    from repro.analysis.scenarios import get_scenario

    trace = load_trace(ns.trace)
    build = get_scenario(trace.model)
    rec = None
    if ns.trace_out:
        # installed module-wide so the scenario's EventQueue (constructed
        # inside replay_trace) captures it at construction
        from repro.obs import trace as obs_trace

        rec = obs_trace.TraceRecorder()
        obs_trace.install(rec)
    try:
        vio = replay_trace(lambda pol: build(dict(trace.args), pol), trace)
    except ReplayDivergence as e:
        print(f"replay DIVERGED: {e}")
        return 2
    finally:
        if rec is not None:
            from repro.obs import trace as obs_trace

            obs_trace.uninstall()
            rec.export(ns.trace_out)
            print(f"timeline: {len(rec)} events -> {ns.trace_out}")
    want = trace.violation
    if vio is None and want is None:
        print("replay clean (trace recorded no violation)")
        return 0
    if vio is not None and want is not None and vio[0] == want[0]:
        print(f"reproduced [{vio[0]}] {vio[1]}")
        return 0
    print(f"replay MISMATCH: trace recorded {want}, replay got {vio}")
    return 1


def main(argv: Optional[List[str]] = None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    if argv and argv[0] == "replay":
        return _main_replay(argv[1:])
    ap = argparse.ArgumentParser(
        prog="repro-explore",
        description="Model-check the lease protocol across event "
                    "interleavings (see README: Schedule-space explorer).")
    ap.add_argument("--smoke", action="store_true",
                    help="run the bounded CI exploration grid")
    ap.add_argument("--check", action="store_true",
                    help="with --smoke: also assert POR reduction >= 2x")
    ap.add_argument("--scenario", help="explore one registered scenario")
    ap.add_argument("--strategy", default="exhaustive",
                    choices=["exhaustive", "pct"])
    ap.add_argument("--window-ms", type=float, default=0.4)
    ap.add_argument("--max-schedules", type=int, default=None)
    ap.add_argument("--pct-seeds", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--no-por", action="store_true")
    ap.add_argument("--no-dedup", action="store_true")
    ap.add_argument("--out", default=None,
                    help="directory for counterexample traces")
    ap.add_argument("--list", action="store_true",
                    help="list registered scenarios")
    ns = ap.parse_args(argv)
    if ns.list:
        from repro.analysis.scenarios import SCENARIOS

        for name in sorted(SCENARIOS):
            print(name)
        return 0
    if ns.smoke:
        return run_smoke(out_dir=ns.out, max_schedules=ns.max_schedules,
                         check_reduction=ns.check)
    if ns.scenario:
        cfg = ExploreConfig(
            strategy=ns.strategy, window_ms=ns.window_ms,
            max_schedules=ns.max_schedules or 2000,
            pct_seeds=ns.pct_seeds, seed=ns.seed,
            por=not ns.no_por, dedup=not ns.no_dedup)
        res = explore_scenario(ns.scenario, cfg)
        s = res.stats
        print(f"{ns.scenario}: {s.schedules} schedules "
              f"({s.pruned_sleep} sleep-pruned, {s.states_deduped} deduped)"
              f"{' [truncated]' if s.truncated else ''}")
        if res.ok:
            print("no violation found")
            return 0
        inv, detail = res.violation.violation
        print(f"VIOLATION [{inv}] {detail}")
        tr = res.minimized or res.violation
        print(f"minimized to {len(tr.deviations())} deviation(s) from the "
              f"default schedule")
        if ns.out:
            import os

            os.makedirs(ns.out, exist_ok=True)
            path = os.path.join(ns.out, f"counterexample-{ns.scenario}.json")
            save_trace(path, tr)
            print(f"trace -> {path} (repro-explore replay {path})")
        return 1
    ap.print_help()
    return 2


if __name__ == "__main__":
    raise SystemExit(main())
