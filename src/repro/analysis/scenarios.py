"""Explorable protocol scenarios, including the seeded-mutant catalogue.

Every entry in :data:`SCENARIOS` maps a name to ``builder(args, policy) ->
model`` — a freshly constructed simulation wired with the explorer's
recording :class:`~repro.core.events.SchedulePolicy`.  The name + args pair
is recorded in every emitted trace, which is what makes counterexamples
replayable from the CLI (``repro-explore replay trace.json``) without
pickling live objects.

Three families:

* **scripted mutants** (``mutant-*``) — the nine seeded protocol bugs from
  ``tests/test_sanitizer_mutants.py``, wrapped as event sequences so the
  explorer re-finds each one (they trip the sanitizer on *every* schedule,
  including the default).
* **schedule-only mutants** (``mutant-no-born-blocked``,
  ``mutant-stale-piggyback``) — bugs the single-schedule sanitizer run
  provably cannot catch: the default FIFO schedule is clean, and only a
  legal reordering of an optimistic delivery against a same-instant
  total-order delivery (resp. a local piggyback) exposes them.  Pass
  ``{"mutant": False}`` for the un-mutated control.
* **smoke cells** (``smoke-*``) — tiny real-:class:`Cluster` configurations
  explored in CI, expected violation-free.
"""
from __future__ import annotations

from typing import Callable, Dict, FrozenSet, List, Optional

import numpy as np

from repro.analysis.fingerprint import digest, queue_state
from repro.analysis.sanitizer import LeaseSanitizer, check_write_locks
from repro.core.events import EventQueue, EvMeta, SchedulePolicy
from repro.core.gcs import GCSLatency, SimGCS
from repro.core.lease import FGLLeaseManager, LeaseRequest, _dedup
from repro.core.lease_batched import ShardedLeaseManager


# --------------------------------------------------------------------------
# Scripted single-manager scenarios (the sanitizer-mutant catalogue)
# --------------------------------------------------------------------------

class ScriptedModel:
    """A fixed step sequence on one event queue — the simplest model shape.

    Steps are scheduled at distinct instants at build time; the explorer
    can still reorder them wherever the commutation window pools them.
    """

    def __init__(self, policy: Optional[SchedulePolicy],
                 horizon: float = 100.0) -> None:
        self.events = EventQueue(policy=policy)
        self.horizon = horizon
        self._state_fns: List[Callable[[], object]] = []

    def track(self, lm) -> None:
        self._state_fns.append(lm.protocol_state)

    def step(self, at: float, fn: Callable[[], None],
             keys: Optional[FrozenSet[int]] = None, label: str = "") -> None:
        self.events.schedule(at, fn, meta=EvMeta(
            kind="local",
            keys=None if keys is None else frozenset(keys), label=label))

    def go(self) -> None:
        self.events.run(self.horizon, max_events=10_000)

    def fingerprint(self) -> str:
        return digest(tuple(f() for f in self._state_fns),
                      queue_state(self.events))

    def wedged(self) -> List[str]:
        return []


def _mgr(kind: str, proc: int, n_classes: int = 8):
    if kind == "sharded":
        return LeaseSanitizer(
            ShardedLeaseManager(proc, n_classes, n_shards=2, jax_min=1))
    return LeaseSanitizer(FGLLeaseManager(proc, n_classes))


def _req(req_id: int, proc: int, ccs) -> LeaseRequest:
    return LeaseRequest(req_id=req_id, proc=proc, ccs=tuple(sorted(ccs)))


def _sc_skipped_epoch_bump(args: Dict, pol) -> ScriptedModel:
    from repro.serve.certifier import StepCertifier

    m = ScriptedModel(pol)
    owner = {4: 0}
    c = StepCertifier(2, sanitize=True, owner_of=lambda s: owner.get(s, -1))

    class R:
        sid = 4

    m.step(1.0, lambda: c.bump(4, 1), label="bump sid4 e1")
    m.step(2.0, lambda: c.enqueue(0, R(), 1), label="enqueue step")
    # the bug: apply_move updates the router only — no certifier.bump
    m.step(3.0, lambda: owner.__setitem__(4, 1), label="move sid4")
    m.step(4.0, lambda: c.drain(0), label="drain")
    return m


def _sc_drain_prefetch_non_head(args: Dict, pol) -> ScriptedModel:
    m = ScriptedModel(pol)
    lm = _mgr(args.get("kind", "oracle"), proc=1)
    m.track(lm)
    box: Dict[str, list] = {}
    m.step(1.0, lambda: lm.on_to_deliver(_req(1, 0, (5,))),
           keys={5}, label="to r1 (remote head)")

    def own():
        box["lors"] = lm.on_to_deliver(_req(2, 1, (5,)))
        lm.mark_prefetch(box["lors"])

    m.step(2.0, own, keys={5}, label="to r2 (own prefetch)")
    # the bug (pre-PR 5): draining without waiting for is_enabled
    m.step(3.0, lambda: lm.finished_xact(box["lors"]),
           keys={5}, label="drain prefetch non-head")
    return m


def _sc_view_change_overpurge(args: Dict, pol) -> ScriptedModel:
    class OverPurging(FGLLeaseManager):
        def purge_proc(self, proc):
            super().purge_proc(proc)
            super().purge_proc(2)  # the bug: an innocent member's LORs go too

    m = ScriptedModel(pol)
    lm = LeaseSanitizer(OverPurging(0, 8))
    m.track(lm)
    m.step(1.0, lambda: lm.on_to_deliver(_req(1, 1, (3,))),
           keys={3}, label="to r1")
    m.step(2.0, lambda: lm.on_to_deliver(_req(2, 2, (4,))),
           keys={4}, label="to r2")
    m.step(3.0, lambda: lm.purge_proc(1), label="view -1")
    return m


def _sc_double_grant(args: Dict, pol) -> ScriptedModel:
    m = ScriptedModel(pol)
    lm = _mgr(args.get("kind", "oracle"), proc=0)
    m.track(lm)
    req = _req(1, 0, (2,))
    m.step(1.0, lambda: lm.on_to_deliver(req), keys={2}, label="to r1")
    # the bug: duplicate TO delivery not deduped
    m.step(2.0, lambda: lm.on_to_deliver(req), keys={2}, label="to r1 dup")
    return m


class _WTxn:
    def __init__(self, txid: int, writes) -> None:
        self.txid = txid
        self.write_set = {w: 1.0 for w in writes}


def _sc_stale_write_locks(args: Dict, pol) -> ScriptedModel:
    m = ScriptedModel(pol)
    owners = np.array([0, 1], np.int32)          # cc=1 leased to proc 1
    item_cc = np.array([0, 1, 1], np.int32)
    stale = np.zeros(3, np.int32)                # the bug: locks not refreshed
    m.step(1.0, lambda: check_write_locks(0, owners, item_cc, stale, [], []),
           keys={0, 1}, label="certify with stale locks")
    return m


def _sc_leased_away_write(args: Dict, pol) -> ScriptedModel:
    m = ScriptedModel(pol)
    owners = np.array([0, 1], np.int32)
    item_cc = np.array([0, 1, 1], np.int32)
    # the bug: verdict True for a txn writing item 2 (leased to proc 1)
    m.step(1.0, lambda: check_write_locks(0, owners, item_cc, None,
                                          [_WTxn(7, [2])], [True]),
           keys={0, 1}, label="certify leased-away write")
    return m


def _sc_recycled_sid(args: Dict, pol) -> ScriptedModel:
    from repro.serve.certifier import StepCertifier

    m = ScriptedModel(pol)
    c = StepCertifier(2, sanitize=True)
    m.step(1.0, lambda: c.bump(5, 7), label="bump sid5 e7")
    # the bug: a recycled sid restarts below its tombstone
    m.step(2.0, lambda: c.bump(5, 3), label="bump sid5 e3")
    return m


def _sc_free_active_lease(args: Dict, pol) -> ScriptedModel:
    m = ScriptedModel(pol)
    lm = _mgr(args.get("kind", "oracle"), proc=0)
    m.track(lm)
    box: Dict[str, list] = {}

    def grant():
        box["lors"] = lm.on_to_deliver(_req(1, 0, (2, 3)))

    m.step(1.0, grant, keys={2, 3}, label="to r1")
    # the bug: freeing a lease that was never blocked nor drained
    m.step(2.0, lambda: lm.on_ur_deliver_freed([box["lors"][0].key()]),
           keys={2, 3}, label="freed live r1")
    return m


def _sc_forged_free(args: Dict, pol) -> ScriptedModel:
    m = ScriptedModel(pol)
    lm = _mgr("oracle", proc=0)
    m.track(lm)
    m.step(1.0, lambda: lm.on_to_deliver(_req(1, 0, (2,))),
           keys={2}, label="to r1")
    m.step(2.0, lambda: lm.on_ur_deliver_freed([(99, 1, (5,))]),
           keys={5}, label="forged free r99")
    return m


def _sc_enabled_mask_flip(args: Dict, pol) -> ScriptedModel:
    m = ScriptedModel(pol)
    lm = _mgr("sharded", proc=0)
    m.track(lm)
    box: Dict[str, list] = {}

    def setup():
        box["g1"] = lm.on_to_deliver(_req(1, 0, (1,)))
        lm.on_to_deliver(_req(2, 1, (2,)))
        box["g2"] = lm.on_to_deliver(_req(3, 0, (2,)))
        inner = lm.inner
        orig = inner.enabled_mask
        # the bug: a settle-kernel defect flips the packed verdicts
        inner.enabled_mask = lambda groups: [not v for v in orig(groups)]

    m.step(1.0, setup, keys={1, 2}, label="grant + flip settle")
    m.step(2.0, lambda: lm.enabled_mask([box["g1"], box["g2"]]),
           keys={1, 2}, label="settle")
    return m


# --------------------------------------------------------------------------
# Schedule-only mutants: clean on the default schedule, buggy under reorder
# --------------------------------------------------------------------------

class NoBornBlockedFGL(FGLLeaseManager):
    """Mutant: drops the ``_pending_opt`` born-blocked catch-up.

    Algorithm 1 blocks local LORs at Opt-deliver; the catch-up in
    ``on_to_deliver`` closes the race where a conflicting request's
    Opt-deliver lands *before* this request's own TO-deliver enqueues its
    LORs.  On the default FIFO schedule the TO-deliver always dispatches
    first (lower issue seq at the shared instant), so no per-event invariant
    ever fires — only the reordered schedule wedges, which the explorer's
    quiescence check catches.
    """

    def on_to_deliver(self, req: LeaseRequest):
        self._pending_opt.pop(req.req_id, None)
        if req.proc in self._dead:
            return []
        lors = self._create_lors(req)
        # lint: allow(state-mutation): seeded mutant re-implements the
        # manager's own enqueue minus the catch-up under test
        self._by_req[req.req_id] = lors
        for lor in lors:
            for cc in lor.ccs:
                self.cq[cc].append(lor)
        # the bug: no born-blocked catch-up against _pending_opt
        return lors


class StalePiggybackFGL(FGLLeaseManager):
    """Mutant: piggybacking consults a pre-Opt-deliver blocked snapshot.

    ``on_opt_deliver`` snapshots which own LORs were unblocked before it
    blocks them; ``try_piggyback`` then treats snapshot members as still
    piggybackable.  Harmless when the piggyback dispatches before the
    conflicting Opt-deliver (the default order here); under the legal
    reordering it attaches a transaction to a blocked LOR — which the
    sanitizer flags (blocked-and-drained) on that schedule only.
    """

    def __init__(self, proc: int, n_classes: int) -> None:
        super().__init__(proc, n_classes)
        self._stale = set()

    def on_opt_deliver(self, req: LeaseRequest):
        for cc in req.ccs:
            for lor in self.cq[cc]:
                if lor.proc == self.proc and not lor.blocked:
                    self._stale.add(id(lor))
        return super().on_opt_deliver(req)

    def try_piggyback(self, ccs: FrozenSet[int]):
        S = []
        for cc in sorted(ccs):
            found = None
            for lor in self.cq[cc]:
                if lor.proc == self.proc and (
                        not lor.blocked or id(lor) in self._stale):
                    found = lor
                    break
            if found is None:
                return None
            S.append(found)
        for lor in _dedup(S):
            lor.activeXacts += 1
        self.n_piggyback += 1
        return S


class LeaseHarness:
    """A miniature lease-protocol deployment over :class:`SimGCS`.

    Wires sanitized lease managers into the GCS exactly like the cluster's
    lease path (opt-deliver frees, TO-deliver enqueues + waiter tracking,
    UR freed dequeues + waiter recheck), without the STM/certification
    machinery — small enough for exhaustive exploration, real enough that
    protocol liveness bugs show up as wedged waiters at quiescence.
    """

    def __init__(self, policy: Optional[SchedulePolicy], n_nodes: int,
                 n_classes: int, mgr_factory: Callable[[int], object],
                 step_ms: float = 0.35, horizon: float = 60.0) -> None:
        self.events = EventQueue(policy=policy)
        self.gcs = SimGCS(self.events, n_nodes,
                          GCSLatency(step_ms=step_ms, oab_serialize_ms=0.0))
        self.lms = [LeaseSanitizer(mgr_factory(i)) for i in range(n_nodes)]
        self.waiters: List[Dict[int, list]] = [{} for _ in range(n_nodes)]
        self.holds: Dict[int, float] = {}
        self.pg_failed: List = []
        self.horizon = horizon
        for i in range(n_nodes):
            self.gcs.on_opt[i] = lambda msg, sender, n=i: self._on_opt(n, msg)
            self.gcs.on_to[i] = lambda msg, sender, n=i: self._on_to(n, msg)
            self.gcs.on_urb[i] = lambda msg, sender, n=i: self._on_urb(n, msg)

    # -- scripted stimulus ---------------------------------------------------
    def request(self, at: float, proc: int, req_id: int, ccs,
                hold_ms: float = 1.0) -> None:
        """Broadcast a lease request at ``at``; the owning txn holds its
        LORs for ``hold_ms`` once enabled, then finishes."""
        self.holds[req_id] = hold_ms
        ccs = tuple(sorted(ccs))
        self.events.schedule(
            at,
            (lambda p=proc, r=req_id, c=ccs:
             self.gcs.oa_broadcast(p, ("lease", _req(r, p, c)))),
            meta=EvMeta(kind="local", node=proc, keys=frozenset(ccs),
                        label=f"req{req_id}@{proc}"))

    def piggyback(self, at: float, proc: int, ccs,
                  hold_ms: float = 1.0) -> None:
        """Attempt Alg. 1 line 4 reuse at ``at``; on success the attached
        txn holds for ``hold_ms``.  A failed attempt is recorded and the
        txn is simply not run (no fallback request)."""
        keys = frozenset(ccs)

        def fn():
            lors = self.lms[proc].try_piggyback(keys)
            if lors is None:
                self.pg_failed.append((proc, tuple(sorted(keys))))
                return
            self.events.schedule(
                hold_ms, (lambda n=proc, ls=lors: self._finish(n, ls)),
                meta=EvMeta(kind="local", node=proc, keys=keys,
                            label=f"fin pg@{proc}"))

        self.events.schedule(at, fn, meta=EvMeta(
            kind="local", node=proc, keys=keys, label=f"pg@{proc}"))

    # -- protocol plumbing ---------------------------------------------------
    def _on_opt(self, node: int, msg) -> None:
        _, req = msg
        to_free = self.lms[node].on_opt_deliver(req)
        if to_free:
            self.gcs.ur_broadcast(
                node, ("freed", [l.key() for l in to_free]))

    def _on_to(self, node: int, msg) -> None:
        _, req = msg
        lors = self.lms[node].on_to_deliver(req)
        if req.proc == node and lors:
            if self.lms[node].is_enabled(lors):
                self._start(node, req.req_id, lors)
            else:
                self.waiters[node][req.req_id] = lors
        self._recheck(node)

    def _on_urb(self, node: int, msg) -> None:
        kind, payload = msg
        if kind == "freed":
            self.lms[node].on_ur_deliver_freed(payload)
        self._recheck(node)

    def _recheck(self, node: int) -> None:
        w = self.waiters[node]
        for rid in list(w):
            if self.lms[node].is_enabled(w[rid]):
                self._start(node, rid, w.pop(rid))

    def _start(self, node: int, req_id: int, lors) -> None:
        keys = frozenset(cc for l in lors for cc in l.ccs)
        self.events.schedule(
            self.holds.get(req_id, 1.0),
            (lambda n=node, ls=lors: self._finish(n, ls)),
            meta=EvMeta(kind="local", node=node, keys=keys,
                        label=f"fin r{req_id}@{node}"))

    def _finish(self, node: int, lors) -> None:
        to_free = self.lms[node].finished_xact(lors)
        if to_free:
            self.gcs.ur_broadcast(
                node, ("freed", [l.key() for l in to_free]))

    # -- model protocol ------------------------------------------------------
    def go(self) -> None:
        self.events.run(self.horizon, max_events=20_000)
        for lm in self.lms:
            lm.verify_full()

    def fingerprint(self) -> str:
        return digest(
            tuple(lm.protocol_state() for lm in self.lms),
            tuple(tuple(sorted(w)) for w in self.waiters),
            queue_state(self.events))

    def wedged(self) -> List[str]:
        out = []
        for n, w in enumerate(self.waiters):
            for rid in sorted(w):
                out.append(f"req {rid} awaiting enablement at node {n}")
        if not self.events.empty():
            out.append("event queue never quiesced")
        return out


def _sc_no_born_blocked(args: Dict, pol) -> LeaseHarness:
    mutant = bool(args.get("mutant", True))
    mk = ((lambda i: NoBornBlockedFGL(i, 4)) if mutant
          else (lambda i: FGLLeaseManager(i, 4)))
    h = LeaseHarness(pol, n_nodes=2, n_classes=4, mgr_factory=mk)
    # proc 0's TO-deliver of its own request races proc 1's conflicting
    # Opt-deliver at the same instant (t = 1.05 with 0.35 ms steps)
    h.request(0.0, 0, 1, (0,), hold_ms=2.0)
    h.request(0.7, 1, 2, (0,), hold_ms=1.0)
    return h


def _sc_stale_piggyback(args: Dict, pol) -> LeaseHarness:
    mutant = bool(args.get("mutant", True))
    mk = ((lambda i: StalePiggybackFGL(i, 4)) if mutant
          else (lambda i: FGLLeaseManager(i, 4)))
    h = LeaseHarness(pol, n_nodes=2, n_classes=4, mgr_factory=mk)
    h.request(0.0, 0, 1, (0,), hold_ms=3.0)   # lease granted at t = 1.05
    h.request(1.7, 1, 2, (0,), hold_ms=1.0)   # conflicting opt at t = 2.05
    h.piggyback(2.05, 0, (0,), hold_ms=2.5)   # races that opt-delivery
    return h


# --------------------------------------------------------------------------
# Smoke cells: tiny real clusters, expected violation-free
# --------------------------------------------------------------------------

def _smoke_cfg(**kw):
    from repro.core.cluster import SimConfig

    base = dict(
        n_nodes=2, threads_per_node=1, n_items=32, n_classes=4,
        duration_ms=3.0, warmup_ms=0.0, drain_ms=25.0,
        # force the numpy settle/certify paths: per-schedule JAX dispatch
        # would dominate a model-checking run that re-executes thousands
        # of tiny simulations
        certify_jax_min=1 << 30, lease_jax_min=1 << 30,
        seed=0)
    base.update(kw)
    return SimConfig(**base)


def _sc_smoke_bank(args: Dict, pol):
    from repro.analysis.explore import ClusterModel
    from repro.core.workloads import BankWorkload

    cfg = _smoke_cfg(
        lease_mode=args.get("lease_mode", "sequential"),
        handoff=args.get("handoff", "drain"),
        duration_ms=float(args.get("duration_ms", 3.0)),
        seed=int(args.get("seed", 0)))
    wl = BankWorkload(n_nodes=cfg.n_nodes, n_items=cfg.n_items,
                      locality=float(args.get("locality", 0.5)))
    return ClusterModel(cfg, wl, pol)


def _sc_smoke_planner_failure(args: Dict, pol):
    from repro.analysis.explore import ClusterModel
    from repro.core.workloads import BankWorkload
    from repro.plan import PlanConfig

    cfg = _smoke_cfg(
        n_nodes=3, n_items=48, n_classes=6,
        duration_ms=float(args.get("duration_ms", 6.0)),
        lease_mode="sequential",
        plan=PlanConfig(epoch_ms=2.0, top_k=2, min_events=1.0, margin=0.0,
                        hysteresis_epochs=1, node_budget_bytes=1e9),
        seed=int(args.get("seed", 1)))
    wl = BankWorkload(n_nodes=cfg.n_nodes, n_items=cfg.n_items,
                      locality=float(args.get("locality", 0.7)))
    return ClusterModel(cfg, wl, pol,
                        fail_at=(float(args.get("fail_ms", 3.0)),
                                 int(args.get("fail_node", 2))))


# --------------------------------------------------------------------------
# Registry
# --------------------------------------------------------------------------

SCENARIOS: Dict[str, Callable] = {
    # the nine seeded sanitizer mutants (tests/test_sanitizer_mutants.py)
    "mutant-skipped-epoch-bump": _sc_skipped_epoch_bump,
    "mutant-drain-prefetch-non-head": _sc_drain_prefetch_non_head,
    "mutant-view-change-overpurge": _sc_view_change_overpurge,
    "mutant-double-grant": _sc_double_grant,
    "mutant-stale-write-locks": _sc_stale_write_locks,
    "mutant-leased-away-write": _sc_leased_away_write,
    "mutant-recycled-sid": _sc_recycled_sid,
    "mutant-free-active-lease": _sc_free_active_lease,
    "mutant-forged-free": _sc_forged_free,
    "mutant-enabled-mask-flip": _sc_enabled_mask_flip,
    # schedule-dependent mutants only the explorer can catch
    "mutant-no-born-blocked": _sc_no_born_blocked,
    "mutant-stale-piggyback": _sc_stale_piggyback,
    # CI smoke cells
    "smoke-bank": _sc_smoke_bank,
    "smoke-planner-failure": _sc_smoke_planner_failure,
}

# the invariant each mutant's counterexample must name (None: any)
MUTANT_INVARIANTS: Dict[str, str] = {
    "mutant-skipped-epoch-bump": "owner-at-drain",
    "mutant-drain-prefetch-non-head": "prefetch-head",
    "mutant-view-change-overpurge": "conservation",
    "mutant-double-grant": "single-owner",
    "mutant-stale-write-locks": "write-locks",
    "mutant-leased-away-write": "write-locks",
    "mutant-recycled-sid": "epoch-monotonicity",
    "mutant-free-active-lease": "blocked-and-drained",
    "mutant-forged-free": "conservation",
    "mutant-enabled-mask-flip": "enabled-divergence",
    "mutant-no-born-blocked": "quiescence",
    "mutant-stale-piggyback": "blocked-and-drained",
}


def get_scenario(name: str) -> Callable:
    try:
        return SCENARIOS[name]
    except KeyError:
        raise KeyError(
            f"unknown scenario {name!r}; known: {sorted(SCENARIOS)}")
