"""Batched certification of forwarded requests at the pod controller.

The serving analogue of the simulator's commit phase (Lilac-TM §3.2:
forwarded transactions are certified at the lease owner *without
re-execution*).  Sessions play the conflict classes; a session's *lease
epoch* — bumped by :class:`repro.serve.router.LocalityRouter` whenever
ownership moves — plays the version stamp.  A forwarded request snapshots
the epoch at routing time; the owning pod certifies the step's forwarded
batch in ONE :func:`repro.core.stm.validate_batch` dispatch (the same
packed-array path the simulator drains through, Pallas on TPU / jit'd jnp
elsewhere).  A request whose session was acquired away while it was on the
wire fails certification and is re-routed with a fresh snapshot — the
serving rendition of "the forwarded transaction lost its lease".

The batch's validate time is priced into the pod's busy clock by a
roofline model that scales with the batch (one fixed kernel dispatch plus
gather/compare bytes), replacing any per-request certification constant.
"""
from __future__ import annotations

from typing import Dict, List, Sequence, Tuple

import numpy as np

from repro.core.stm import Transaction, VersionedStore
from repro.dist.locality import HBM_BW
from repro.obs.metrics import MetricSet

# fixed per-batch cost: kernel dispatch + result sync
CERT_DISPATCH_S = 20e-6
# packed bytes per read-set slot crossing HBM: item + snapshot version +
# gathered current version (int32 each)
CERT_BYTES_PER_SLOT = 12.0


class CertifierMetrics(MetricSet):
    """Certification counters on the repro.obs registry.

    Attribute reads/writes (``m.batches += 1``) route to registry
    counters via the MetricSet facade; ``as_dict`` keeps the exact key
    set the engine has always merged into its own dict.
    """

    FIELDS = {"batches": 0, "certified": 0, "aborts": 0,
              "time_s": 0.0, "max_batch": 0}

    def as_dict(self) -> Dict[str, float]:
        return {
            "cert_batches": self.batches, "certified": self.certified,
            "cert_aborts": self.aborts, "cert_time_s": self.time_s,
            "cert_max_batch": self.max_batch,
        }


class StepCertifier:
    """Per-pod certification queues over a replicated session-epoch store."""

    def __init__(self, n_pods: int, *, backend: str = "auto",
                 hbm_bw: float = HBM_BW,
                 dispatch_s: float = CERT_DISPATCH_S,
                 jax_min: int = 8, sanitize: bool = False,
                 owner_of=None) -> None:
        self.n_pods = n_pods
        self.backend = backend
        self.hbm_bw = hbm_bw
        self.dispatch_s = dispatch_s
        # protocol sanitizer (repro.analysis): epoch monotonicity per sid
        # and owner-at-drain cross-checks; ``owner_of(sid) -> pod`` is wired
        # by the engine from the router's ownership map
        self.sanitize = sanitize
        self.owner_of = owner_of
        self._last_epoch: Dict[int, int] = {}
        # batches below this settle with the numpy loop (same verdicts,
        # no JAX dispatch overhead); tests force 1 to pin the packed path
        self.jax_min = jax_min
        # session-epoch store (grows in power-of-two steps); versions[sid]
        # is the session's current lease epoch, replicated at every pod —
        # the engine bumps it synchronously on acquire, standing in for the
        # AB+URB ownership round
        self.store = VersionedStore(64)
        self.pending: List[List[Tuple[object, int]]] = [
            [] for _ in range(n_pods)]
        # deferred epoch stamps: bump() appends here and the queue settles
        # through ONE VersionedStore.apply_batch scatter at the next store
        # read (drain / epoch), instead of a per-call apply_versioned —
        # the ownership round's writes ride the same array path as the
        # certification reads
        self._bumps: List[Tuple[int, int]] = []
        self.metrics = CertifierMetrics()

    # -- epoch store ---------------------------------------------------------
    def _ensure(self, sid: int) -> None:
        self.store.grow_to(sid + 1)

    def epoch(self, sid: int) -> int:
        self._ensure(sid)
        self._flush_bumps()
        return int(self.store.versions[sid])

    def bump(self, sid: int, epoch: int) -> None:
        """Ownership moved: stamp the session's new lease epoch (deferred
        to the next store read; ordering within the queue is preserved —
        ``apply_batch`` is last-writer-wins per item)."""
        self._ensure(sid)
        if self.sanitize:
            prev = self._last_epoch.get(sid)
            if prev is not None and epoch < prev:
                from repro.analysis.sanitizer import SanitizerError

                raise SanitizerError(
                    "epoch-monotonicity",
                    f"sid {sid}: lease epoch stamped backwards "
                    f"({prev} -> {epoch}); a recycled sid must start past "
                    f"its tombstone epoch")
            self._last_epoch[sid] = epoch
        self._bumps.append((sid, epoch))

    def _flush_bumps(self) -> None:
        if not self._bumps:
            return
        self.store.apply_batch(
            [{sid: float(e)} for (sid, e) in self._bumps],
            [e for (_sid, e) in self._bumps])
        self._bumps = []

    def purge(self, sid: int) -> int:
        """Drop the evicted session's queued forwards everywhere; returns
        how many were dropped.  Without this an in-flight forward of a dead
        session would abort at drain and *resubmit*, resurrecting the
        session the caller just retired."""
        n = 0
        for pod in range(self.n_pods):
            kept = [(r, e) for (r, e) in self.pending[pod] if r.sid != sid]
            n += len(self.pending[pod]) - len(kept)
            self.pending[pod] = kept
        return n

    # -- the per-step batch --------------------------------------------------
    def enqueue(self, pod: int, req, epoch: int) -> None:
        """Queue a forwarded request for the pod's next certification batch."""
        self._ensure(getattr(req, "sid"))
        self.pending[pod].append((req, epoch))

    def has_pending(self) -> bool:
        return any(self.pending)

    def certify_time_s(self, n_txns: int, read_len: int = 1) -> float:
        """Roofline validate time for one batch: fixed dispatch + bytes.

        Scales with the batch (rows × packed read slots), not per request —
        the whole point of draining the step's forwards in one call.
        """
        if n_txns == 0:
            return 0.0
        return self.dispatch_s + (
            n_txns * max(1, read_len) * CERT_BYTES_PER_SLOT / self.hbm_bw)

    def drain(self, pod: int) -> Tuple[List, List, float]:
        """Certify the pod's queued forwards in one batch.

        Returns ``(passed_requests, aborted_requests, validate_time_s)``;
        aborted requests carried a stale lease epoch (the session was
        acquired away after routing) and must be re-routed by the caller.
        """
        entries = self.pending[pod]
        if not entries:
            self._flush_bumps()
            return [], [], 0.0
        self.pending[pod] = []
        self._flush_bumps()
        if len(entries) >= self.jax_min:
            from repro.core.stm import validate_batch

            txns = []
            for i, (req, epoch) in enumerate(entries):
                t = Transaction(txid=i + 1, origin=pod)
                t.log_read(req.sid, epoch)
                txns.append(t)
            ok = validate_batch(self.store, txns, backend=self.backend)
        else:
            ok = [int(self.store.versions[req.sid]) == epoch
                  for (req, epoch) in entries]
        m = self.metrics
        m.batches += 1
        m.max_batch = max(m.max_batch, len(entries))
        t_s = self.certify_time_s(len(entries))
        m.time_s += t_s
        passed = [req for (req, _), o in zip(entries, ok) if o]
        aborted = [req for (req, _), o in zip(entries, ok) if not o]
        if self.sanitize and self.owner_of is not None:
            from repro.analysis.sanitizer import SanitizerError

            for req in passed:
                owner = self.owner_of(req.sid)
                if owner != pod:
                    # a request can only certify at the current lease
                    # owner: passing elsewhere means an ownership move
                    # skipped its epoch bump
                    raise SanitizerError(
                        "owner-at-drain",
                        f"sid {req.sid} certified at pod {pod} but the "
                        f"router owner is {owner}; an apply_move/evict "
                        f"skipped its epoch bump")
        m.certified += len(passed)
        m.aborts += len(aborted)
        return passed, aborted, t_s
