"""Batched KV-session store: fixed-slot ring caches + alloc/free ledger.

The engine decodes a *batch* of sessions at once; each session owns a slot
in the batched cache trees produced by ``decoder.init_cache``.  Slots are
recycled; session → slot indirection lives here.  ``export_session`` /
``import_session`` move one session's cache column between pods (the
"migrate state" branch of the locality router).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.models import decoder
from repro.models.common import ModelConfig


@dataclass
class Session:
    sid: int
    slot: int
    length: int = 0              # tokens currently in the cache
    last_token: int = 0


def _map_with_bdim(fn, tree: Dict[str, Any], *rest: Dict[str, Any]):
    """``jax.tree.map`` over decoder cache trees with the batch dim explicit.

    Unrolled ``prefix``/``suffix`` entries put batch at dim 0; the scanned
    ``body`` entries carry a leading ``n_groups`` axis, so batch is dim 1
    there.  Passing the dim structurally (instead of sniffing shapes)
    matches ``repro.dist.sharding.cache_pspecs`` and stays correct when a
    body cache's ``n_groups`` equals the slot count.
    """
    def sub(key: str, bdim: int):
        entries = [t[key] for t in (tree, *rest)]
        if entries[0] is None:
            return None
        return jax.tree.map(lambda *ls: fn(bdim, *ls), *entries)

    return {"prefix": sub("prefix", 0), "body": sub("body", 1),
            "suffix": sub("suffix", 0)}


class KVStore:
    def __init__(self, cfg: ModelConfig, n_slots: int, max_len: int,
                 dtype=jnp.bfloat16, *, mesh=None) -> None:
        self.cfg = cfg
        self.n_slots = n_slots
        self.max_len = max_len
        self.mesh = mesh
        self._shardings = None
        self._pspecs = None
        self.caches = decoder.init_cache(cfg, n_slots, max_len, dtype)
        if mesh is not None:
            # place the slot-ring trees per the ownership ledger, so imported
            # sessions land pre-sharded on this pod's mesh
            from repro.dist.sharding import cache_pspecs, cache_shardings
            self._pspecs = cache_pspecs(cfg, mesh, self.caches, n_slots)
            self._shardings = cache_shardings(cfg, mesh, self.caches, n_slots)
            self.caches = jax.device_put(self.caches, self._shardings)
        self.free_slots: List[int] = list(range(n_slots))[::-1]
        self.sessions: Dict[int, Session] = {}

    @property
    def seq_shards(self) -> float:
        """Effective parallel-hop divisor for a migrated column's bytes.

        Byte-weighted over the leaves the ledger actually seq-shards: a
        leaf carrying the seq axis ships as ``seq``-many parallel chunks,
        anything without a seq dim (the mamba conv/ssm state) ships whole.
        A pure-attention cache on an 8-way seq mesh reports 8.0; a pure
        mamba cache reports 1.0 regardless of the mesh; hybrids land in
        between.  This is the ``seq_shards`` the locality pricing divides
        the state bytes by, so it must track the real layout, not just the
        mesh shape.
        """
        if self._pspecs is None:
            return 1
        from jax.sharding import PartitionSpec as P

        from repro.dist.sharding import SEQ_AXIS, MeshAxes
        ssize = MeshAxes.for_mesh(self.mesh).seq_size(self.mesh)
        if ssize <= 1:
            return 1
        total = hop = 0.0
        specs = jax.tree.leaves(self._pspecs,
                                is_leaf=lambda s: isinstance(s, P))
        for leaf, spec in zip(jax.tree.leaves(self.caches), specs):
            b = leaf.nbytes / self.n_slots
            total += b
            split = any(a == SEQ_AXIS for a in spec)
            hop += b / (ssize if split else 1.0)
        return total / hop if hop > 0 else 1

    # -- session lifecycle -------------------------------------------------
    def alloc(self, sid: int) -> Session:
        if sid in self.sessions:
            return self.sessions[sid]
        if not self.free_slots:
            raise RuntimeError("KV store full")
        s = Session(sid, self.free_slots.pop())
        self.sessions[sid] = s
        return s

    def free(self, sid: int) -> None:
        s = self.sessions.pop(sid, None)
        if s is not None:
            self.free_slots.append(s.slot)

    def has(self, sid: int) -> bool:
        return sid in self.sessions

    # -- cross-pod state migration ------------------------------------------
    def export_session(self, sid: int) -> Dict[str, Any]:
        """Slice one session's cache column out (the bytes a lease move ships).

        With a seq-bearing mesh the exported column stays seq-sharded: each
        shard's chunk is a separate wire transfer, which is exactly the
        ``1/seq_shards``-bytes-per-hop state move the router prices.
        """
        s = self.sessions[sid]

        def slice_slot(bdim, leaf):
            return jnp.take(leaf, jnp.asarray([s.slot]), axis=bdim)

        return {
            "sid": sid,
            "length": s.length,
            "last_token": s.last_token,
            "seq_shards": self.seq_shards,
            "tree": _map_with_bdim(slice_slot, self.caches),
        }

    def import_session(self, blob: Dict[str, Any]) -> Session:
        s = self.alloc(blob["sid"])
        s.length = blob["length"]
        s.last_token = blob["last_token"]

        def put(bdim, dst, src):
            idx = [slice(None)] * dst.ndim
            idx[bdim] = s.slot
            src_idx = [slice(None)] * dst.ndim
            src_idx[bdim] = 0
            return dst.at[tuple(idx)].set(src[tuple(src_idx)].astype(dst.dtype))

        self.caches = _map_with_bdim(put, self.caches, blob["tree"])
        if self._shardings is not None:
            # re-place the updated trees on this pod's mesh: an imported
            # long-context column lands seq-sharded instead of wherever the
            # eager scatter above materialized it
            self.caches = jax.device_put(self.caches, self._shardings)
        return s

    def nbytes_session(self) -> float:
        """Bytes shipped per exported session (for the cost model)."""
        total = 0
        for leaf in jax.tree.leaves(self.caches):
            total += leaf.nbytes / self.n_slots
        return total
