"""Batched KV-session store: fixed-slot ring caches + alloc/free ledger.

The engine decodes a *batch* of sessions at once; each session owns a slot
in the batched cache trees produced by ``decoder.init_cache``.  Slots are
recycled; session → slot indirection lives here.  ``export_session`` /
``import_session`` move one session's cache column between pods (the
"migrate state" branch of the locality router).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.models import decoder
from repro.models.common import ModelConfig


@dataclass
class Session:
    sid: int
    slot: int
    length: int = 0              # tokens currently in the cache
    last_token: int = 0


class KVStore:
    def __init__(self, cfg: ModelConfig, n_slots: int, max_len: int,
                 dtype=jnp.bfloat16, *, mesh=None) -> None:
        self.cfg = cfg
        self.n_slots = n_slots
        self.max_len = max_len
        self.mesh = mesh
        self.caches = decoder.init_cache(cfg, n_slots, max_len, dtype)
        if mesh is not None:
            # place the slot-ring trees per the ownership ledger, so imported
            # sessions land pre-sharded on this pod's mesh
            from repro.dist.sharding import cache_shardings
            self.caches = jax.device_put(
                self.caches, cache_shardings(cfg, mesh, self.caches, n_slots))
        self.free_slots: List[int] = list(range(n_slots))[::-1]
        self.sessions: Dict[int, Session] = {}

    # -- session lifecycle -------------------------------------------------
    def alloc(self, sid: int) -> Session:
        if sid in self.sessions:
            return self.sessions[sid]
        if not self.free_slots:
            raise RuntimeError("KV store full")
        s = Session(sid, self.free_slots.pop())
        self.sessions[sid] = s
        return s

    def free(self, sid: int) -> None:
        s = self.sessions.pop(sid, None)
        if s is not None:
            self.free_slots.append(s.slot)

    def has(self, sid: int) -> bool:
        return sid in self.sessions

    # -- cross-pod state migration ------------------------------------------
    def export_session(self, sid: int) -> Dict[str, Any]:
        """Slice one session's cache column out (the bytes a lease move ships)."""
        s = self.sessions[sid]

        def slice_slot(leaf):
            if leaf is None:
                return None
            # batch dim is axis 0 for prefix/suffix caches, axis 1 for
            # group-stacked body caches
            ax = 1 if leaf.ndim >= 4 and leaf.shape[0] != self.n_slots else 0
            return jnp.take(leaf, jnp.asarray([s.slot]), axis=ax)

        return {
            "sid": sid,
            "length": s.length,
            "last_token": s.last_token,
            "tree": jax.tree.map(slice_slot, self.caches),
        }

    def import_session(self, blob: Dict[str, Any]) -> Session:
        s = self.alloc(blob["sid"])
        s.length = blob["length"]
        s.last_token = blob["last_token"]

        def put(dst, src):
            if src is None:
                return dst
            ax = 1 if dst.ndim >= 4 and dst.shape[0] != self.n_slots else 0
            idx = [slice(None)] * dst.ndim
            idx[ax] = s.slot
            src_idx = [slice(None)] * dst.ndim
            src_idx[ax] = 0
            return dst.at[tuple(idx)].set(src[tuple(src_idx)].astype(dst.dtype))

        self.caches = jax.tree.map(put, self.caches, blob["tree"])
        return s

    def nbytes_session(self) -> float:
        """Bytes shipped per exported session (for the cost model)."""
        total = 0
        for leaf in jax.tree.leaves(self.caches):
            total += leaf.nbytes / self.n_slots
        return total
