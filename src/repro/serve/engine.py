"""Multi-pod serving engine: continuous batching + locality routing.

Two backends behind one engine:

* :class:`RealBackend` — actually decodes with the JAX model (per-session
  positions, KV slots); used by the runnable example on host devices.
* :class:`SimBackend` — prices each pod-step with the roofline model;
  used by the pod-scale benchmarks where 256-chip pods are simulated.

Per engine step: (1) the geo load-balancer assigns incoming requests to
origin pods, (2) the :class:`LocalityRouter` (the paper's DTD) picks
local/forward/acquire per request, applying KV-state migrations, (3) each
pod certifies its forwarded batch in one :mod:`repro.serve.certifier`
validate dispatch (stale lease epochs re-route), (4) each pod runs one
batched decode over its active sessions, (5) queue depths feed back as
the CPU_i statistic.

With a :class:`repro.plan.PlacementPlanner` attached, a sixth phase runs
every ``plan.epoch_ms`` of simulated time: the planner scores all
[session, pod] moves in one jit'd evaluation over the router's touch
affinity and executes the bounded plan *between* steps — zero-byte lease
prefetches for cacheless sessions, KV re-homes for misplaced ones — with
wire time priced onto the pod busy clocks exactly like reactive moves.
The router's constraint-(3) panic-acquire is disabled in this mode
(rebalancing is the planner's job; see ``LocalityRouter.planned``).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.dist.locality import DCN_RTT_S, price_session_dispatch
from repro.launch.hlo_analysis import HBM_BW
from repro.obs.metrics import MetricSet, MonotonicSampler
from repro.obs.trace import TraceRecorder
from .certifier import StepCertifier
from .router import LocalityRouter, RouteDecision

# router-clock advance per decode step when the backend reports no decode
# time (RealBackend): keeps DecayedFrequency decaying deterministically
REAL_STEP_MS = 1.0


@dataclass
class Request:
    sid: int
    origin: int                  # pod chosen by the geo load balancer
    n_tokens: int = 8            # decode tokens requested


# ---------------------------------------------------------------------------
# Backends
# ---------------------------------------------------------------------------

class SimBackend:
    """Roofline-priced pod: decode time = max(weights, cache) HBM reads."""

    def __init__(self, cfg, pod_chips: int = 256) -> None:
        self.cfg = cfg
        self.pod_chips = pod_chips
        self.weight_bytes = cfg.active_param_count() * 2.0
        self.lengths: Dict[Tuple[int, int], int] = {}   # (pod, sid) -> len

    def ensure(self, pod: int, sid: int, length: int) -> None:
        self.lengths[(pod, sid)] = max(self.lengths.get((pod, sid), 0), length)

    def drop(self, pod: int, sid: int) -> int:
        return self.lengths.pop((pod, sid), 0)

    def decode_time_s(self, pod: int, sids: List[int],
                      kv_bytes_per_token: float) -> float:
        if not sids:
            return 0.0
        cache = sum(self.lengths.get((pod, s), 0) for s in sids) * kv_bytes_per_token
        t_w = self.weight_bytes / self.pod_chips / HBM_BW
        t_c = cache / self.pod_chips / HBM_BW
        return max(t_w, t_c)

    def step(self, pod: int, sids: List[int]) -> None:
        for s in sids:
            self.lengths[(pod, s)] = self.lengths.get((pod, s), 0) + 1


class RealBackend:
    """Actual JAX decode on host devices (one KVStore per pod)."""

    def __init__(self, cfg, ctx, params, n_pods: int, n_slots: int,
                 max_len: int) -> None:
        import jax
        import jax.numpy as jnp

        from repro.models import decoder
        from .kvcache import KVStore

        self.cfg, self.ctx, self.params = cfg, ctx, params
        self.stores = [
            KVStore(cfg, n_slots, max_len, mesh=getattr(ctx, "mesh", None))
            for _ in range(n_pods)
        ]
        # seq shards per pod mesh: the engine re-prices actual-byte state
        # moves with this, so a seq-sharded migration charges 1/seq_shards
        # of the bytes per hop
        self.seq_shards = self.stores[0].seq_shards
        self._jnp = jnp

        def step(params, caches, tokens, pos):
            return decoder.decode_step(cfg, ctx, params, caches, tokens, pos)

        self._step = jax.jit(step)

    def ensure(self, pod: int, sid: int, length: int) -> None:
        st = self.stores[pod]
        if not st.has(sid):
            s = st.alloc(sid)
            s.length = length

    def transfer(self, src: int, dst: int, sid: int) -> float:
        """Move a session's KV column between pods; returns bytes shipped."""
        st = self.stores[src]
        if not st.has(sid):
            self.ensure(dst, sid, 0)
            return 0.0
        blob = st.export_session(sid)
        st.free(sid)
        self.stores[dst].import_session(blob)
        return self.stores[dst].nbytes_session()

    def drop(self, pod: int, sid: int) -> int:
        st = self.stores[pod]
        n = st.sessions[sid].length if st.has(sid) else 0
        st.free(sid)
        return n

    def step(self, pod: int, sids: List[int]) -> Dict[int, int]:
        """One batched decode for the pod's sessions; returns new tokens."""
        jnp = self._jnp
        st = self.stores[pod]
        if not sids:
            return {}
        tokens = np.zeros((st.n_slots,), np.int32)
        pos = np.zeros((st.n_slots,), np.int32)
        for sid in sids:
            s = st.sessions[sid]
            tokens[s.slot] = s.last_token
            pos[s.slot] = s.length
        logits, st.caches = self._step(
            self.params, st.caches, jnp.asarray(tokens), jnp.asarray(pos))
        nxt = np.asarray(jnp.argmax(logits, axis=-1), np.int32)
        out = {}
        for sid in sids:
            s = st.sessions[sid]
            s.last_token = int(nxt[s.slot])
            s.length += 1
            out[sid] = s.last_token
        return out


# ---------------------------------------------------------------------------
# Engine
# ---------------------------------------------------------------------------

class EngineMetrics(MetricSet):
    """Fleet counters + per-pod breakdown + token-latency histograms,
    all on one repro.obs registry.

    Attribute access (``m.forwards += 1``) keeps working via the
    MetricSet facade; the registry additionally carries per-pod
    ``pod{p}.forwards/local/wire_bytes`` counters and per-pod
    ``pod{p}.token_lat_s`` histograms (plus the fleet-wide one), which
    ``as_dict`` surfaces as p50/p90/p99 and a ``per_pod`` table — the
    attribution the ROADMAP's SLO-gated trace benchmark reads.
    """

    FIELDS = {
        "steps": 0, "tokens": 0, "sim_time_s": 0.0, "wire_bytes": 0.0,
        "transfers": 0, "forwards": 0, "local": 0,
        "plan_epochs": 0,        # planner invocations
        "plan_moves": 0,         # planned session re-homes executed
        "plan_prefetches": 0,    # planned zero-byte lease prefetches
        "plan_bytes": 0.0,       # state shipped by planned moves
        "plan_block_s": 0.0,     # host wall-time planning spent ON the token
        # path (begin dispatch + finish harvest; sync mode pays the full
        # scoring wait here, async mode only the dispatch + a drained
        # harvest) — sampled through obs.metrics.MonotonicSampler, the one
        # sanctioned wall-clock seam
    }

    def __init__(self, n_pods: int = 0, cert: Optional[object] = None,
                 registry=None) -> None:
        super().__init__(registry)
        # certification counters live in the StepCertifier (single source
        # of truth); as_dict merges them when the engine links it here
        self.cert = cert
        self.n_pods = n_pods
        reg = self.registry
        for p in range(n_pods):
            reg.counter(f"pod{p}.forwards")
            reg.counter(f"pod{p}.local")
            reg.counter(f"pod{p}.wire_bytes", 0.0)
            reg.histogram(f"pod{p}.token_lat_s")
        reg.histogram("token_lat_s")

    # -- per-pod attribution -------------------------------------------------
    def pod_add(self, pod: int, name: str, n=1) -> None:
        self.registry.counter(f"pod{pod}.{name}").value += n

    def observe_token_latency(self, pod: int, lat_s: float,
                              n: int = 1) -> None:
        """Record ``n`` tokens decoded at ``pod`` whose step latency was
        ``lat_s`` (the pod's full busy time for that step: wire + certify
        + decode — what a request experiences per token)."""
        self.registry.histogram("token_lat_s").observe(lat_s, n)
        if 0 <= pod < self.n_pods:
            self.registry.histogram(f"pod{pod}.token_lat_s").observe(
                lat_s, n)

    def token_latency(self, pod: Optional[int] = None):
        """The (per-pod) token-latency histogram, for quantile/SLO reads."""
        name = "token_lat_s" if pod is None else f"pod{pod}.token_lat_s"
        return self.registry.histogram(name)

    def as_dict(self) -> Dict[str, Any]:
        out: Dict[str, Any] = {
            "steps": self.steps, "tokens": self.tokens,
            "sim_time_s": self.sim_time_s,
            "tokens_per_s": self.tokens / max(1e-9, self.sim_time_s),
            "wire_GB": self.wire_bytes / 1e9,
            "transfers": self.transfers, "forwards": self.forwards,
            "local": self.local,
            "plan_epochs": self.plan_epochs, "plan_moves": self.plan_moves,
            "plan_prefetches": self.plan_prefetches,
            "plan_GB": self.plan_bytes / 1e9,
            "plan_block_s": self.plan_block_s,
        }
        reg = self.registry
        fleet = reg.histogram("token_lat_s")
        for label, q in (("p50", 0.5), ("p90", 0.9), ("p99", 0.99)):
            v = fleet.quantile(q)
            out[f"token_lat_{label}_s"] = 0.0 if v is None else v
        per_pod: Dict[int, Dict[str, Any]] = {}
        for p in range(self.n_pods):
            h = reg.histogram(f"pod{p}.token_lat_s")
            p50, p99 = h.quantile(0.5), h.quantile(0.99)
            per_pod[p] = {
                "forwards": reg.counter(f"pod{p}.forwards").value,
                "local": reg.counter(f"pod{p}.local").value,
                "wire_GB": reg.counter(f"pod{p}.wire_bytes").value / 1e9,
                "token_lat_p50_s": 0.0 if p50 is None else p50,
                "token_lat_p99_s": 0.0 if p99 is None else p99,
            }
        out["per_pod"] = per_pod
        if self.cert is not None:
            out.update(self.cert.as_dict())
        return out


class MultiPodEngine:
    def __init__(self, n_pods: int, backend, router: LocalityRouter,
                 certifier: Optional[StepCertifier] = None,
                 planner=None, sanitize: bool = False,
                 plan_async: bool = True, trace=None) -> None:
        self.n_pods = n_pods
        self.backend = backend
        self.router = router
        # repro.obs tracing: pass a TraceRecorder (or True for a fresh
        # one); None/False keeps every site a single dead branch.  Spans
        # are stamped from the deterministic pod busy clocks / router
        # tick clock, so traced and untraced runs are byte-identical.
        if trace is True:
            trace = TraceRecorder()
        elif trace is False:
            trace = None
        self.trace = trace
        # the sanctioned wall-clock seam for plan_block_s (host scoring
        # time is genuinely wall time; everything else here is simulated)
        self._mono = MonotonicSampler()
        # forwarded requests are certified at the owning pod in one batch
        # per engine step (the paper's commit phase at the lease owner)
        self.certifier = certifier or StepCertifier(n_pods, sanitize=sanitize)
        if self.certifier.sanitize and self.certifier.owner_of is None:
            # owner-at-drain cross-check reads the router's live ownership
            self.certifier.owner_of = \
                lambda sid: self.router.owner.get(sid, -1)
        # optional proactive placement planner (repro.plan): shares the
        # router's clock/stats implementation and takes over rebalancing.
        # plan_async overlaps each epoch's scoring with the following
        # decode step (kick at the epoch boundary, harvest at the next
        # step's start); the plan is byte-identical to synchronous mode
        # because every input is snapshotted at the kick
        self.planner = planner
        self.plan_async = plan_async
        self._plan_clock_ms = 0.0
        self._pending_plan = None
        if planner is not None:
            router.planned = True
            router.affinity = planner.affinity
        self.queues: List[List[Request]] = [[] for _ in range(n_pods)]
        self.session_len: Dict[int, int] = {}
        self.session_home: Dict[int, int] = {}
        # (latency, serialization) charges per pod since its last step,
        # split from the priced wire_s; settled in run_step
        self._pending_wire: List[List[Tuple[float, float]]] = \
            [[] for _ in range(n_pods)]
        # per-pod busy clocks: pods decode independently (no cross-pod
        # barrier), so simulated wall time is the busiest pod's clock
        self._pod_clock = np.zeros((n_pods,), np.float64)
        self.metrics = EngineMetrics(n_pods=n_pods,
                                     cert=self.certifier.metrics)

    def submit(self, req: Request) -> RouteDecision:
        m = self.metrics
        length = self.session_len.get(req.sid, 0)
        dec = self.router.route(req.origin, req.sid, length)
        src = req.origin if dec.action == "forward" else -1
        if dec.action == "acquire":
            src = self.session_home.get(req.sid, dec.target)
            if src != dec.target:
                shipped = self._move_session_state(
                    req.sid, src, dec.target, length)
                if hasattr(self.backend, "transfer") \
                        and shipped > dec.wire_bytes:
                    # the real cache column outweighed the router's
                    # estimate: re-price the state move with actual bytes
                    # (seq-sharded columns move in parallel shard hops)
                    repriced = price_session_dispatch(
                        0.0, 0.0, shipped, handoff_bytes=0.0,
                        seq_shards=getattr(self.backend, "seq_shards", 1))
                    dec = dataclasses.replace(
                        dec, wire_bytes=shipped,
                        wire_s=repriced.migrate_state_s)
                m.transfers += 1
        elif dec.action == "forward":
            m.forwards += 1
            m.pod_add(dec.target, "forwards")
        else:
            m.local += 1
            m.pod_add(dec.target, "local")
        tr = self.trace
        if tr is not None:
            if dec.action == "acquire":
                # the lease/ownership round + state landing, priced as
                # wire_s; rendered on the acquiring pod's lease track
                tr.span("lease-acquire", f"pod{dec.target}/lease",
                        self.router._now, 1e3 * dec.wire_s, sid=req.sid)
            elif dec.action == "forward":
                tr.instant("route-forward", "router", ts=self.router._now,
                           sid=req.sid, target=dec.target)
            else:
                tr.instant("route-local", "router", ts=self.router._now,
                           sid=req.sid, target=dec.target)
        # the ownership round stamps the session's lease epoch at every
        # pod (idempotent when ownership didn't move): forwards still in
        # flight with an older epoch fail certification and re-route
        self.certifier.bump(req.sid, dec.epoch)
        self.backend.ensure(dec.target, req.sid, length)
        self.session_home[req.sid] = dec.target
        if dec.action == "forward":
            # forwarded work is certified at the owner before it may decode:
            # it joins the pod's next per-step certification batch
            self.certifier.enqueue(dec.target, req, dec.epoch)
        else:
            self.queues[dec.target].append(req)
        m.wire_bytes += dec.wire_bytes
        if dec.wire_bytes > 0:
            m.pod_add(dec.target, "wire_bytes", dec.wire_bytes)
        if dec.wire_s > 0:
            # receiver waits out the RTT; byte serialization occupies the
            # NIC at both endpoints of the transfer
            serial_s = max(0.0, dec.wire_s - DCN_RTT_S)
            self._pending_wire[dec.target].append((DCN_RTT_S, serial_s))
            if 0 <= src < self.n_pods and src != dec.target:
                self._pending_wire[src].append((0.0, serial_s))
        return dec

    def _move_session_state(self, sid: int, src: int, dst: int,
                            length: int) -> float:
        """Physically relocate a session between pods — cache column plus
        its queued work (the lease carries the class's pending
        transactions with it, paper §2) — and return the bytes shipped
        (the router's estimate for drop-based backends).  Shared by the
        reactive acquire path and the planner's re-homes, so the two can
        never drift."""
        if hasattr(self.backend, "transfer"):
            shipped = self.backend.transfer(src, dst, sid)
        else:
            self.backend.drop(src, sid)
            shipped = length * self.router.kv_bytes_per_token
        self.backend.ensure(dst, sid, length)
        self.session_home[sid] = dst
        moved = [r for r in self.queues[src] if r.sid == sid]
        if moved:
            self.queues[src] = [r for r in self.queues[src] if r.sid != sid]
            self.queues[dst].extend(moved)
        return shipped

    def _wire_time_s(self, pod: int) -> float:
        """Settle the pod's transfers since its last step.

        Each entry is (latency, serialization) split out of the priced plan
        time from ``price_session_dispatch``.  Concurrent RPCs overlap
        their latency but serialize on the pod's NIC: one RTT (if the pod
        awaits any inbound data), summed byte time.
        """
        arrivals = self._pending_wire[pod]
        if not arrivals:
            return 0.0
        self._pending_wire[pod] = []
        return max(rtt for rtt, _ in arrivals) + sum(s for _, s in arrivals)

    def run_step(self) -> None:
        """One decode step on every pod over its queued sessions."""
        m = self.metrics
        # harvest the plan kicked at the previous step's epoch boundary:
        # its scoring ran on-device while that whole step decoded, so the
        # wait here is (near) zero — the overlapped epoch's landing point
        if self._pending_plan is not None:
            pending, self._pending_plan = self._pending_plan, None
            self._harvest_plan_epoch(pending)
        step_t = 0.0
        for pod in range(self.n_pods):
            t_base_ms = 1e3 * float(self._pod_clock[pod])
            # inbound KV/requests must land before the pod decodes them
            t_wire = self._wire_time_s(pod)
            pod_t = t_wire
            # certify the step's forwarded batch in one validate dispatch;
            # its time lands on the pod's busy clock (scaling with the
            # batch, not a per-request constant)
            passed, aborted, t_cert = self.certifier.drain(pod)
            n_cert = len(passed) + len(aborted)
            pod_t += t_cert
            self.queues[pod].extend(passed)
            for r in aborted:
                # the session was acquired away while the forward was in
                # flight: certification rejected the stale lease epoch —
                # re-route against the current ownership ledger
                if self.router.affinity is not None:
                    # cert aborts damp the pod's affinity: sessions whose
                    # forwards keep dying here are contended, not attracted
                    self.router.affinity.record_abort(
                        self.router._now, pod, (r.sid,))
                self.submit(r)
            reqs = self.queues[pod]
            t_dec = 0.0
            n_dec = 0
            if reqs:
                sids = []
                for r in reqs:
                    if r.n_tokens > 0:
                        sids.append(r.sid)
                sids = list(dict.fromkeys(sids))
                if hasattr(self.backend, "decode_time_s"):
                    t_dec = self.backend.decode_time_s(
                        pod, sids, self.router.kv_bytes_per_token)
                    pod_t += t_dec
                self.backend.step(pod, sids)
                for r in reqs:
                    r.n_tokens -= 1
                # the pod decodes each *session* once per step, however many
                # requests share it — advance session_len in lockstep with
                # the backend's cache length so KV migrations are priced on
                # real sizes
                for sid in sids:
                    self.session_len[sid] = self.session_len.get(sid, 0) + 1
                    m.tokens += 1
                n_dec = len(sids)
                # per-token latency at this pod this step: the busy time a
                # decoded token just experienced (wire + certify + decode)
                if pod_t > 0:
                    m.observe_token_latency(pod, pod_t, n_dec)
                self.queues[pod] = [r for r in reqs if r.n_tokens > 0]
            tr = self.trace
            if tr is not None:
                # the pod's step timeline: wire landing, certify batch,
                # decode — laid back-to-back on the pod's busy clock
                if t_wire > 0:
                    tr.span("wire", f"pod{pod}", t_base_ms, 1e3 * t_wire)
                if t_cert > 0:
                    tr.span("certify", f"pod{pod}",
                            t_base_ms + 1e3 * t_wire, 1e3 * t_cert,
                            batch=n_cert, aborts=len(aborted))
                if t_dec > 0:
                    tr.span("decode", f"pod{pod}",
                            t_base_ms + 1e3 * (t_wire + t_cert),
                            1e3 * t_dec, sessions=n_dec)
            self._pod_clock[pod] += pod_t
            step_t = max(step_t, pod_t)
        # pods run in parallel with no cross-pod barrier: simulated wall
        # time is the busiest pod's accumulated clock
        m.sim_time_s = float(np.max(self._pod_clock))
        dt_ms = 1000.0 * step_t if step_t > 0 else REAL_STEP_MS
        self.router.tick(dt_ms)
        m.steps += 1
        # queue depth -> CPU_i statistic for constraint (3): backlog relative
        # to the fleet mean, so the valve trips on genuine stragglers (~2x
        # the mean) instead of always flagging whichever pod is busiest
        depths = np.asarray([float(len(q)) for q in self.queues])
        cap = max(8.0, 2.0 * float(depths.mean()))
        self.router.observe_cpu(depths / cap)
        if self.planner is not None:
            self._plan_clock_ms += dt_ms
            if self._plan_clock_ms >= self.planner.cfg.epoch_ms:
                self._plan_clock_ms = 0.0
                if self.plan_async:
                    # kick now, harvest at the next step's start: the jit'd
                    # scoring overlaps the coming decode step instead of
                    # stalling the loop here
                    self._pending_plan = self._begin_plan_epoch()
                else:
                    self._harvest_plan_epoch(self._begin_plan_epoch())

    # -- proactive placement (repro.plan) -----------------------------------
    def _begin_plan_epoch(self):
        """Snapshot the epoch's inputs and dispatch the [session, pod]
        scoring (one jit'd evaluation, mesh-sharded when the planner holds
        a plan mesh) without waiting on it."""
        from repro.plan.score import price_move_costs

        self._mono.mark()
        r = self.router
        self.metrics.plan_epochs += 1
        n_cls = r.affinity.node.n_cols
        owner = np.full((n_cls,), -1, dtype=np.int32)
        state = np.zeros((n_cls,), dtype=np.float64)
        for sid, pod in r.owner.items():
            if sid < n_cls:
                owner[sid] = pod
                state[sid] = self.session_len.get(sid, 0) * r.kv_bytes_per_token
        work = np.full((n_cls,), r.request_bytes + r.response_bytes)
        fwd_cost, move_cost = price_move_costs(
            state, work, seq_shards=r.seq_shards)
        pending = self.planner.begin(r._now, owner, state, fwd_cost,
                                     move_cost, r.cpu)
        self.metrics.plan_block_s += self._mono.lap()
        tr = self.trace
        if tr is not None:
            # async epoch: opened at the kick, closed at the harvest — the
            # PR 9 scoring/decode overlap shows up as this span bracketing
            # the next step's pod spans
            tr.abegin("plan-epoch", "plan", pending.epoch, ts=r._now,
                      classes=int(n_cls))
        return pending

    def _harvest_plan_epoch(self, pending) -> None:
        """Materialize a kicked epoch's plan and execute it between steps
        (off the critical path).  Staleness guards re-check live ownership:
        a session acquired away (or evicted) since the kick keeps its
        snapshot move from firing."""
        r = self.router
        self._mono.mark()
        plan = self.planner.finish(pending)
        self.metrics.plan_block_s += self._mono.lap()
        executed = []
        for mv in plan.moves:
            if r.owner.get(mv.cc) == mv.src and mv.src != mv.dst:
                self._execute_move(mv.cc, mv.dst)
                executed.append(mv)
        self.planner.committed(executed)
        tr = self.trace
        if tr is not None:
            tr.aend("plan-epoch", "plan", pending.epoch, ts=r._now,
                    moves=len(executed))

    def _execute_move(self, sid: int, dst: int) -> None:
        """Planned lease prefetch / session re-home.

        Ownership and epoch semantics are identical to a reactive acquire
        (in-flight forwards against the old owner abort and re-route); the
        difference is *when*: between steps, with the state's wire time
        priced onto the endpoint pods' busy clocks instead of stalling a
        request."""
        r, m = self.router, self.metrics
        src = self.session_home.get(sid, r.owner[sid])
        epoch = r.apply_move(sid, dst)
        self.certifier.bump(sid, epoch)
        length = self.session_len.get(sid, 0)
        shipped = self._move_session_state(sid, src, dst, length) \
            if src != dst else 0.0
        tr = self.trace
        if shipped > 0:
            m.plan_moves += 1
            m.transfers += 1
            m.wire_bytes += shipped
            m.plan_bytes += shipped
            m.pod_add(dst, "wire_bytes", shipped)
            if tr is not None:
                tr.instant("plan-move", "plan", ts=r._now, sid=sid, dst=dst)
            priced = price_session_dispatch(
                0.0, 0.0, shipped, handoff_bytes=0.0,
                seq_shards=getattr(self.backend, "seq_shards", r.seq_shards))
            # off the critical path: nobody awaits this transfer, so its
            # RTT overlaps decode — only the byte serialization occupies
            # the endpoint NICs (contrast submit(), where the acquiring
            # pod waits out the RTT before it may decode the session)
            serial = max(0.0, priced.migrate_state_s - DCN_RTT_S)
            self._pending_wire[dst].append((0.0, serial))
            if 0 <= src < self.n_pods and src != dst:
                self._pending_wire[src].append((0.0, serial))
        else:
            m.plan_prefetches += 1
            if tr is not None:
                tr.instant("plan-prefetch", "plan", ts=r._now, sid=sid,
                           dst=dst)

    def evict_session(self, sid: int) -> None:
        """Retire a finished session everywhere it has state.

        Frees the cache column and queued work, drops its queued forwards
        from the certification batches (they would otherwise abort at drain
        and *resubmit*, resurrecting the session), and stamps the router's
        tombstone epoch into the certifier store — so a forward of the dead
        tenancy still on the wire fails certification, and a later recycle
        of the sid places at an epoch above the tombstone (see
        ``LocalityRouter.evict``).
        """
        home = self.session_home.pop(sid, None)
        self.session_len.pop(sid, None)
        for pod in range(self.n_pods):
            self.queues[pod] = [r for r in self.queues[pod] if r.sid != sid]
        if home is not None:
            self.backend.drop(home, sid)
        self.certifier.purge(sid)
        tomb = self.router.evict(sid)
        self.certifier.bump(sid, tomb)

    def drain(self, max_steps: int = 10_000) -> None:
        steps = 0
        while (any(self.queues) or self.certifier.has_pending()) \
                and steps < max_steps:
            self.run_step()
            steps += 1
