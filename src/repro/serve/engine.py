"""Multi-pod serving engine: continuous batching + locality routing.

Two backends behind one engine:

* :class:`RealBackend` — actually decodes with the JAX model (per-session
  positions, KV slots); used by the runnable example on host devices.
* :class:`SimBackend` — prices each pod-step with the roofline model;
  used by the pod-scale benchmarks where 256-chip pods are simulated.

Per engine step: (1) the geo load-balancer assigns incoming requests to
origin pods, (2) the :class:`LocalityRouter` (the paper's DTD) picks
local/forward/acquire per request, applying KV-state migrations, (3) each
pod runs one batched decode over its active sessions, (4) queue depths
feed back as the CPU_i statistic.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.dist.locality import DCN_BW
from repro.launch.hlo_analysis import HBM_BW
from .router import LocalityRouter, RouteDecision


@dataclass
class Request:
    sid: int
    origin: int                  # pod chosen by the geo load balancer
    n_tokens: int = 8            # decode tokens requested


# ---------------------------------------------------------------------------
# Backends
# ---------------------------------------------------------------------------

class SimBackend:
    """Roofline-priced pod: decode time = max(weights, cache) HBM reads."""

    def __init__(self, cfg, pod_chips: int = 256) -> None:
        self.cfg = cfg
        self.pod_chips = pod_chips
        self.weight_bytes = cfg.active_param_count() * 2.0
        self.lengths: Dict[Tuple[int, int], int] = {}   # (pod, sid) -> len

    def ensure(self, pod: int, sid: int, length: int) -> None:
        self.lengths[(pod, sid)] = max(self.lengths.get((pod, sid), 0), length)

    def drop(self, pod: int, sid: int) -> int:
        return self.lengths.pop((pod, sid), 0)

    def decode_time_s(self, pod: int, sids: List[int],
                      kv_bytes_per_token: float) -> float:
        if not sids:
            return 0.0
        cache = sum(self.lengths.get((pod, s), 0) for s in sids) * kv_bytes_per_token
        t_w = self.weight_bytes / self.pod_chips / HBM_BW
        t_c = cache / self.pod_chips / HBM_BW
        return max(t_w, t_c)

    def step(self, pod: int, sids: List[int]) -> None:
        for s in sids:
            self.lengths[(pod, s)] = self.lengths.get((pod, s), 0) + 1


class RealBackend:
    """Actual JAX decode on host devices (one KVStore per pod)."""

    def __init__(self, cfg, ctx, params, n_pods: int, n_slots: int,
                 max_len: int) -> None:
        import jax
        import jax.numpy as jnp

        from repro.models import decoder
        from .kvcache import KVStore

        self.cfg, self.ctx, self.params = cfg, ctx, params
        self.stores = [KVStore(cfg, n_slots, max_len) for _ in range(n_pods)]
        self._jnp = jnp

        def step(params, caches, tokens, pos):
            return decoder.decode_step(cfg, ctx, params, caches, tokens, pos)

        self._step = jax.jit(step)

    def ensure(self, pod: int, sid: int, length: int) -> None:
        st = self.stores[pod]
        if not st.has(sid):
            s = st.alloc(sid)
            s.length = length

    def transfer(self, src: int, dst: int, sid: int) -> float:
        """Move a session's KV column between pods; returns bytes shipped."""
        st = self.stores[src]
        if not st.has(sid):
            self.ensure(dst, sid, 0)
            return 0.0
        blob = st.export_session(sid)
        st.free(sid)
        self.stores[dst].import_session(blob)
        return self.stores[dst].nbytes_session()

    def drop(self, pod: int, sid: int) -> int:
        st = self.stores[pod]
        n = st.sessions[sid].length if st.has(sid) else 0
        st.free(sid)
        return n

    def step(self, pod: int, sids: List[int]) -> Dict[int, int]:
        """One batched decode for the pod's sessions; returns new tokens."""
        jnp = self._jnp
        st = self.stores[pod]
        if not sids:
            return {}
        tokens = np.zeros((st.n_slots,), np.int32)
        pos = np.zeros((st.n_slots,), np.int32)
        for sid in sids:
            s = st.sessions[sid]
            tokens[s.slot] = s.last_token
            pos[s.slot] = s.length
        logits, st.caches = self._step(
            self.params, st.caches, jnp.asarray(tokens), jnp.asarray(pos))
        nxt = np.asarray(jnp.argmax(logits, axis=-1), np.int32)
        out = {}
        for sid in sids:
            s = st.sessions[sid]
            s.last_token = int(nxt[s.slot])
            s.length += 1
            out[sid] = s.last_token
        return out


# ---------------------------------------------------------------------------
# Engine
# ---------------------------------------------------------------------------

@dataclass
class EngineMetrics:
    steps: int = 0
    tokens: int = 0
    sim_time_s: float = 0.0
    wire_bytes: float = 0.0
    transfers: int = 0
    forwards: int = 0
    local: int = 0

    def as_dict(self) -> Dict[str, float]:
        return {
            "steps": self.steps, "tokens": self.tokens,
            "sim_time_s": self.sim_time_s,
            "tokens_per_s": self.tokens / max(1e-9, self.sim_time_s),
            "wire_GB": self.wire_bytes / 1e9,
            "transfers": self.transfers, "forwards": self.forwards,
            "local": self.local,
        }


class MultiPodEngine:
    def __init__(self, n_pods: int, backend, router: LocalityRouter) -> None:
        self.n_pods = n_pods
        self.backend = backend
        self.router = router
        self.queues: List[List[Request]] = [[] for _ in range(n_pods)]
        self.session_len: Dict[int, int] = {}
        self.session_home: Dict[int, int] = {}
        self.metrics = EngineMetrics()

    def submit(self, req: Request) -> RouteDecision:
        m = self.metrics
        length = self.session_len.get(req.sid, 0)
        dec = self.router.route(req.origin, req.sid, length)
        if dec.action == "acquire":
            src = self.session_home.get(req.sid, dec.target)
            if src != dec.target:
                if hasattr(self.backend, "transfer"):
                    shipped = self.backend.transfer(src, dec.target, req.sid)
                    dec = dataclasses.replace(dec, wire_bytes=max(dec.wire_bytes, shipped))
                else:
                    self.backend.drop(src, req.sid)
                m.transfers += 1
        elif dec.action == "forward":
            m.forwards += 1
        else:
            m.local += 1
        self.backend.ensure(dec.target, req.sid, length)
        self.session_home[req.sid] = dec.target
        self.queues[dec.target].append(req)
        m.wire_bytes += dec.wire_bytes
        self.metrics.sim_time_s += dec.wire_bytes / DCN_BW
        return dec

    def run_step(self) -> None:
        """One decode step on every pod over its queued sessions."""
        m = self.metrics
        pod_times = []
        for pod in range(self.n_pods):
            reqs = self.queues[pod]
            if not reqs:
                pod_times.append(0.0)
                continue
            sids = []
            for r in reqs:
                if r.n_tokens > 0:
                    sids.append(r.sid)
            sids = list(dict.fromkeys(sids))
            if hasattr(self.backend, "decode_time_s"):
                pod_times.append(self.backend.decode_time_s(
                    pod, sids, self.router.kv_bytes_per_token))
                self.backend.step(pod, sids)
            else:
                self.backend.step(pod, sids)
                pod_times.append(0.0)
            for r in reqs:
                r.n_tokens -= 1
                self.session_len[r.sid] = self.session_len.get(r.sid, 0) + 1
                m.tokens += 1
            self.queues[pod] = [r for r in reqs if r.n_tokens > 0]
        # pods run in parallel; the step takes as long as the slowest pod
        m.sim_time_s += max(pod_times) if pod_times else 0.0
        m.steps += 1
        # queue depth -> CPU_i statistic for constraint (3)
        cap = max(1, max((len(q) for q in self.queues), default=1))
        self.router.observe_cpu(
            np.asarray([len(q) / max(8.0, cap) for q in self.queues]))

    def drain(self, max_steps: int = 10_000) -> None:
        steps = 0
        while any(self.queues) and steps < max_steps:
            self.run_step()
            steps += 1
