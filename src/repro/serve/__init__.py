"""Serving: KV-session store, decode engine, Lilac locality router."""
