"""Locality-aware multi-pod request router — Lilac-TM at the serving layer.

Sessions are the conflict classes; the pod holding a session's KV cache is
its lease owner.  Per request the router solves the paper's ILP
(:mod:`repro.core.dtd`) over the pods:

* ``short`` policy — the SC communication cost, with the step constants
  replaced by roofline-priced byte costs (:mod:`repro.dist.locality`):
  forwarding a request is a p2p of the prompt/response; acquiring the
  session locally ships the KV slice + an ownership handoff;
* ``long`` policy — the LC access-frequency cost over piggybacked
  per-pod session-touch rates (an attractor forms where a session's
  requests concentrate);
* constraint (3) — pods above ``max_cpu`` (queue depth / capacity) are
  not eligible migration targets: the paper's own straggler valve.

The router maintains the fine-grained ownership ledger with per-session
*lease stickiness*: ownership only moves when the DTD decides the state
should travel, so repeated requests on a session are certified locally —
the serving analogue of FGL lease reuse.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.dtd import DTD, DTDConfig
from repro.core.stats import DecayedFrequency
from repro.dist.locality import price_session_dispatch


@dataclass
class RouteDecision:
    target: int                  # pod that will run the decode
    action: str                  # "local" | "forward" | "acquire"
    wire_bytes: float = 0.0
    wire_s: float = 0.0


@dataclass
class RouterMetrics:
    requests: int = 0
    local_hits: int = 0
    forwards: int = 0
    acquires: int = 0
    wire_bytes: float = 0.0

    @property
    def lease_reuse_rate(self) -> float:
        return self.local_hits / max(1, self.requests)


class LocalityRouter:
    def __init__(
        self,
        n_pods: int,
        *,
        policy: str = "short",
        max_cpu: float = 0.85,
        kv_bytes_per_token: float = 2048.0,
        request_bytes: float = 4096.0,
        response_bytes: float = 1024.0,
        freq_tau_ms: float = 500.0,
    ) -> None:
        self.n_pods = n_pods
        self.policy = policy
        self.dtd = DTD(DTDConfig(policy=policy, max_cpu=max_cpu), n_pods)
        self.owner: Dict[int, int] = {}          # session -> owning pod
        self.freq = DecayedFrequency(n_pods, 1, tau_ms=freq_tau_ms)
        self._freq_by_sid: Dict[int, np.ndarray] = {}
        self.cpu = np.zeros((n_pods,), np.float64)
        self.kv_bytes_per_token = kv_bytes_per_token
        self.request_bytes = request_bytes
        self.response_bytes = response_bytes
        self.metrics = RouterMetrics()
        self._now = 0.0

    # -- stats ingestion -----------------------------------------------------
    def observe_cpu(self, cpu: np.ndarray) -> None:
        self.cpu[:] = cpu

    def tick(self, dt_ms: float) -> None:
        self._now += dt_ms

    def _touch(self, origin: int, sid: int) -> None:
        f = self._freq_by_sid.setdefault(sid, np.zeros((self.n_pods,), np.float64))
        f *= 0.98
        f[origin] += 1.0

    # -- the decision ----------------------------------------------------------
    def route(self, origin: int, sid: int, session_len: int) -> RouteDecision:
        m = self.metrics
        m.requests += 1
        self._touch(origin, sid)
        owner = self.owner.get(sid, -1)

        if owner == origin:
            m.local_hits += 1
            return RouteDecision(origin, "local")

        if owner < 0:
            # new session: place at the DTD's choice (long-term policy may
            # pick the attractor; default to origin)
            target = self._dtd_target(origin, sid, owner)
            self.owner[sid] = target
            if target == origin:
                m.local_hits += 1
                return RouteDecision(origin, "local")
            m.forwards += 1
            wire = self.request_bytes + self.response_bytes
            m.wire_bytes += wire
            return RouteDecision(target, "forward", wire)

        target = self._dtd_target(origin, sid, owner)
        kv_bytes = session_len * self.kv_bytes_per_token
        # request/response sizes are already bytes, not tokens
        costs = price_session_dispatch(
            self.request_bytes, self.response_bytes, kv_bytes,
            wire_bytes_per_token=1.0)
        if target == owner:
            # migrate the work to the state owner
            m.forwards += 1
            m.wire_bytes += self.request_bytes + self.response_bytes
            return RouteDecision(owner, "forward",
                                 self.request_bytes + self.response_bytes,
                                 costs.migrate_work_s)
        # migrate the state to the target (lease + KV move)
        self.owner[sid] = target
        m.acquires += 1
        m.wire_bytes += kv_bytes
        return RouteDecision(target, "acquire", kv_bytes, costs.migrate_state_s)

    def _dtd_target(self, origin: int, sid: int, owner: int) -> int:
        f = self._freq_by_sid.get(sid)
        freq = np.zeros((self.n_pods, 1), np.float64)
        if f is not None:
            freq[:, 0] = f
        return self.dtd.decide(
            origin=origin,
            ccs=frozenset({0}),
            lease_owner_of_cc=lambda cc: owner,
            freq_rates=freq,
            cpu=self.cpu,
            opt_hint=owner if owner >= 0 else origin,
        )

    def evict(self, sid: int) -> None:
        self.owner.pop(sid, None)
        self._freq_by_sid.pop(sid, None)
