"""Locality-aware multi-pod request router — Lilac-TM at the serving layer.

Sessions are the conflict classes; the pod holding a session's KV cache is
its lease owner.  Per request the router solves the paper's ILP
(:mod:`repro.core.dtd`) over the pods:

* ``short`` policy — the SC communication cost, with the step constants
  replaced by roofline-priced byte costs (:mod:`repro.dist.locality`):
  forwarding a request is a p2p of the prompt/response; acquiring the
  session locally ships the KV slice + an ownership handoff;
* ``long`` policy — the LC access-frequency cost over piggybacked
  per-pod session-touch rates (an attractor forms where a session's
  requests concentrate);
* constraint (3) — pods above ``max_cpu`` (queue depth / capacity) are
  not eligible migration targets: the paper's own straggler valve.

The step-constant ILP and the byte model can disagree: the SC constants say
"forward to the owner" regardless of how many bytes the alternatives put on
the DCN, and its all-overloaded fallback acquires at the origin even when
that ships megabytes of KV.  ``arbitration`` selects who settles the
forward-vs-acquire binary for an owned session:

* ``steps``  — the DTD step constants alone (legacy behaviour);
* ``priced`` — ``price_session_dispatch.prefer_migration`` alone: forward
  when the work description is lighter than the KV state, acquire
  otherwise, with constraint (3) flipping the verdict only when the
  preferred side is overloaded and the other is not;
* ``hybrid`` — the DTD picks first; when it redirects to a third pod
  (overload valve, LC attractor) that stands, but whenever its choice is
  the plain origin/owner binary the byte model breaks the disagreement.

The router maintains the fine-grained ownership ledger with per-session
*lease stickiness*: ownership only moves when the DTD decides the state
should travel, so repeated requests on a session are certified locally —
the serving analogue of FGL lease reuse.  Per-session access frequencies
(the LC inputs) live in ONE growable
:class:`repro.core.stats.DecayedFrequency` matrix ([pod, sid]) decayed on
the router clock — the engine advances it via :meth:`tick` with simulated
step time, so the attractor is rate-based, not per-touch.  The placement
planner's affinity tracker (:mod:`repro.plan.affinity`) is the same
implementation on the same clock; attach one via :attr:`affinity` and the
router feeds it touch/forward events as they happen.

When a planner drives placement (:attr:`planned` set by the engine),
constraint-(3) overload no longer flips the arbitration verdict onto the
byte-heavy plan: panic-acquiring a grown KV cache on the critical path is
exactly the reactive churn the proactive planner replaces — rebalancing
becomes the planner's job, off the critical path and byte-budgeted.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.dtd import DTD, DTDConfig
from repro.core.stats import DecayedFrequency
from repro.dist.locality import ROUTER_DEFAULTS, price_session_dispatch

ARBITRATIONS = ("steps", "priced", "hybrid")


@dataclass
class RouteDecision:
    target: int                  # pod that will run the decode
    action: str                  # "local" | "forward" | "acquire"
    wire_bytes: float = 0.0
    wire_s: float = 0.0          # DCN time of the chosen plan, RTT included
    # the session's lease epoch after this decision: bumped on every
    # ownership move, snapshotted onto forwarded requests so the owner's
    # batched certifier (repro.serve.certifier) can reject forwards that
    # lost their lease while on the wire
    epoch: int = 0


@dataclass
class RouterMetrics:
    requests: int = 0
    local_hits: int = 0
    forwards: int = 0
    acquires: int = 0
    wire_bytes: float = 0.0
    flips: int = 0               # byte model overrode the step-constant verdict
    planned_moves: int = 0       # ownership moves applied by the planner

    @property
    def lease_reuse_rate(self) -> float:
        return self.local_hits / max(1, self.requests)


class LocalityRouter:
    def __init__(
        self,
        n_pods: int,
        *,
        policy: str = ROUTER_DEFAULTS.policy,
        arbitration: str = ROUTER_DEFAULTS.arbitration,
        max_cpu: float = ROUTER_DEFAULTS.max_cpu,
        kv_bytes_per_token: float = 2048.0,
        request_bytes: float = 4096.0,
        response_bytes: float = 1024.0,
        freq_tau_ms: float = ROUTER_DEFAULTS.freq_tau_ms,
        seq_shards: float = 1,
    ) -> None:
        if arbitration not in ARBITRATIONS:
            raise ValueError(f"unknown arbitration {arbitration!r}")
        self.n_pods = n_pods
        self.policy = policy
        self.arbitration = arbitration
        self.dtd = DTD(DTDConfig(policy=policy, max_cpu=max_cpu), n_pods)
        self.owner: Dict[int, int] = {}          # session -> owning pod
        self.lease_epoch: Dict[int, int] = {}    # session -> ownership epoch
        # tombstone floor for evicted sids: lease_epoch holds *live*
        # sessions only; an absent sid resolves to this floor, which is
        # raised past every evicted session's last epoch.  A recycled sid
        # therefore starts above anything its previous tenancy ever
        # stamped — the no-alias guarantee without an ever-growing dict.
        self._epoch_floor = 0
        self.freq_tau_ms = freq_tau_ms
        # per-session touch rates, one growable [pod, sid] matrix on the
        # router clock (shared implementation with the planner's affinity)
        self.freq = DecayedFrequency(n_pods, 64, tau_ms=freq_tau_ms,
                                     grow_cols=True)
        # optional planner hookups (set by the engine when a planner runs)
        self.affinity = None         # repro.plan.affinity.AffinityTracker
        self.planned = False         # rebalancing delegated to the planner
        self.cpu = np.zeros((n_pods,), np.float64)
        self.kv_bytes_per_token = kv_bytes_per_token
        self.request_bytes = request_bytes
        self.response_bytes = response_bytes
        # seq-sharded KV layout: a state move leaves as this many parallel
        # shard-to-shard hops (fractional for partially-sharded hybrid
        # caches), shifting the forward-vs-acquire crossover toward
        # acquisition for long-context sessions
        self.seq_shards = max(1.0, float(seq_shards))
        self.metrics = RouterMetrics()
        self._now = 0.0              # router clock, ms (advanced by tick())

    # -- stats ingestion -----------------------------------------------------
    def observe_cpu(self, cpu: np.ndarray) -> None:
        self.cpu[:] = cpu

    def tick(self, dt_ms: float) -> None:
        """Advance the router clock; session touch rates decay against it."""
        self._now += dt_ms

    def _touch(self, origin: int, sid: int) -> None:
        self.freq.record(self._now, origin, (sid,))
        if self.affinity is not None:
            self.affinity.record_touch(self._now, origin, (sid,))

    # -- the decision ----------------------------------------------------------
    def route(self, origin: int, sid: int, session_len: int) -> RouteDecision:
        m = self.metrics
        m.requests += 1
        self._touch(origin, sid)
        owner = self.owner.get(sid, -1)
        epoch = self.lease_epoch.get(sid, self._epoch_floor)

        if owner == origin:
            m.local_hits += 1
            return RouteDecision(origin, "local", epoch=epoch)

        kv_bytes = session_len * self.kv_bytes_per_token
        # request/response sizes are already bytes, not tokens
        costs = price_session_dispatch(
            self.request_bytes, self.response_bytes, kv_bytes,
            wire_bytes_per_token=1.0, seq_shards=self.seq_shards)

        if owner < 0:
            # new session (or re-placement after evict): place at the DTD's
            # choice (long-term policy may pick the attractor; default to
            # origin).  Every placement is an ownership transition, so the
            # epoch bumps — forwards snapshotted against a prior placement
            # of a recycled sid must not certify against the new one
            target = self._dtd_target(origin, sid, owner)
            self.owner[sid] = target
            epoch += 1
            self.lease_epoch[sid] = epoch
            if target == origin:
                m.local_hits += 1
                return RouteDecision(origin, "local", epoch=epoch)
            m.forwards += 1
            wire = self.request_bytes + self.response_bytes
            m.wire_bytes += wire
            return RouteDecision(target, "forward", wire,
                                 costs.migrate_work_s, epoch=epoch)

        target = self._dtd_target(origin, sid, owner)
        action = "forward" if target == owner else "acquire"
        if self.arbitration != "steps":
            action, target = self._arbitrate(origin, owner, target, action, costs)

        if action == "forward":
            # migrate the work to the state owner
            m.forwards += 1
            m.wire_bytes += costs.work_bytes
            if self.affinity is not None:
                self.affinity.record_forward(self._now, origin, (sid,))
            return RouteDecision(owner, "forward", costs.work_bytes,
                                 costs.migrate_work_s, epoch=epoch)
        # migrate the state to the target (lease + KV move): the epoch bump
        # invalidates forwards still in flight toward the old owner
        self.owner[sid] = target
        epoch += 1
        self.lease_epoch[sid] = epoch
        m.acquires += 1
        m.wire_bytes += kv_bytes
        return RouteDecision(target, "acquire", kv_bytes,
                             costs.migrate_state_s, epoch=epoch)

    def _arbitrate(self, origin: int, owner: int, target: int, action: str,
                   costs) -> Tuple[str, int]:
        """Settle forward-vs-acquire with the priced verdict.

        ``prefer_migration`` (forward the work) wins unless the preferred
        side violates constraint (3) while the other side doesn't; when both
        sides are overloaded the cheap-wire plan is the fallback — this is
        where the step-constant solver's acquire-at-origin fallback ships
        whole KV caches for nothing.
        """
        if self.arbitration == "hybrid" and target not in (origin, owner):
            return action, target    # DTD redirect (valve / attractor) stands
        if self.planned:
            # planner mode: the byte verdict stands unconditionally — the
            # constraint-(3) escape hatch (acquire a grown cache because the
            # owner runs hot) is the reactive churn the planner replaces
            # with budgeted, off-critical-path rebalancing
            byte_action = ("forward", owner) if costs.prefer_migration \
                else ("acquire", origin)
            if byte_action[0] != action:
                self.metrics.flips += 1
            return byte_action
        fwd_ok = self.dtd.feasible(self.cpu, owner)
        acq_ok = self.dtd.feasible(self.cpu, origin)
        if costs.prefer_migration:
            byte_action = ("forward", owner) if fwd_ok or not acq_ok \
                else ("acquire", origin)
        else:
            byte_action = ("acquire", origin) if acq_ok or not fwd_ok \
                else ("forward", owner)
        if byte_action[0] != action:
            self.metrics.flips += 1
        return byte_action

    def _dtd_target(self, origin: int, sid: int, owner: int) -> int:
        freq = np.zeros((self.n_pods, 1), np.float64)
        if sid < self.freq.n_cols:
            freq[:, 0] = self.freq.rates(self._now)[:, sid]
        return self.dtd.decide(
            origin=origin,
            ccs=frozenset({0}),
            lease_owner_of_cc=lambda cc: owner,
            freq_rates=freq,
            cpu=self.cpu,
            opt_hint=owner if owner >= 0 else origin,
        )

    def apply_move(self, sid: int, dst: int) -> int:
        """Apply a planner move to the ownership ledger; returns the new
        lease epoch.  Epoch semantics are identical to a reactive acquire:
        every ownership transition bumps, so forwards routed against the
        old owner fail certification and re-route."""
        self.owner[sid] = dst
        epoch = self.lease_epoch.get(sid, self._epoch_floor) + 1
        self.lease_epoch[sid] = epoch
        self.metrics.planned_moves += 1
        return epoch

    def evict(self, sid: int) -> int:
        """Retire a session from the ledger; returns its tombstone epoch.

        The sid's epoch entry is *folded into* ``_epoch_floor`` rather than
        kept (the dict holds live sessions only): the floor is raised past
        the evicted epoch, and an absent sid resolves to the floor on its
        next appearance.  Callers stamp the returned tombstone into their
        epoch store (:meth:`repro.serve.certifier.StepCertifier.bump`) so a
        forward of the dead tenancy still on the wire fails certification —
        and a recycled sid's first placement bumps *above* the tombstone,
        so it can never be aliased by that stale forward either.
        """
        self.owner.pop(sid, None)
        e = self.lease_epoch.pop(sid, self._epoch_floor)
        self._epoch_floor = max(self._epoch_floor, e + 1)
        self.freq.zero_col(sid)
        if self.affinity is not None:
            self.affinity.forget(sid)
        self._maybe_compact()
        return self._epoch_floor

    def _maybe_compact(self) -> None:
        """Shrink the grown per-session stat columns back toward the live
        sid range (pow2 + 4x hysteresis, see ``DecayedFrequency.shrink_to``)
        — a burst of high sids must not pin memory after mass eviction."""
        hi = (max(self.owner) + 1) if self.owner else 0
        self.freq.shrink_to(hi)
        if self.affinity is not None:
            self.affinity.compact(hi)
