"""Turn batched move scores into a bounded, damped placement plan.

The planner is deliberately conservative: decayed affinity counters are
noisy, and an over-eager plan would churn leases (the exact failure mode
the paper's overload experiment warns about).  Three dampers:

* **top-K** moves per epoch — the control loop nudges, it never reshuffles
  the fleet in one step;
* **per-node byte budget** — the inbound state a target node may receive
  per epoch is capped, so planned migrations can't swamp a NIC (and total
  planned wire is bounded by ``n_nodes · node_budget_bytes`` per epoch);
* **hysteresis** — a move that *reverses* a move executed within the last
  ``hysteresis_epochs`` epochs is rejected, so two attractors can't
  ping-pong a class between them.

Candidates are ranked by score per shipped byte (a zero-byte lease
prefetch ranks above any re-home of equal score), and at most one target —
the argmax — is considered per class.  Constraint-(3) feasibility is
already masked in the scorer; the planner re-checks nothing about safety
because it never touches the lease protocol: executors route every move
through the existing lease manager / ownership ledger.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field, replace
from typing import Deque, List, Optional, Tuple

import numpy as np

from .affinity import AffinityTracker
from .score import score_moves_async


@dataclass(frozen=True)
class PlanConfig:
    """Knobs of the affinity → score → plan loop (see module docstrings)."""

    epoch_ms: float = 50.0           # plan cadence on the consumer's clock
    top_k: int = 8                   # max moves per epoch
    node_budget_bytes: float = 4e6   # max inbound state per target per epoch
    hysteresis_epochs: int = 4       # W: reversal-rejection window
    horizon_ms: float = 500.0        # benefit horizon (≈ affinity tau)
    margin: float = 2.0              # benefit must exceed margin × move cost
    min_frac: float = 0.45           # dominance share a target must hold
    min_events: float = 6.0          # decayed evidence a class needs to move
    load_gain: float = 0.0           # rebalancing pressure (events/ms per cpu)
    co_gain: float = 0.0             # co-location credit (sim multi-class txns)
    min_score: float = 0.0           # floor on the final score
    max_cpu: float = 0.9             # DTD constraint (3) threshold
    overload_ctrl: bool = True
    tau_ms: float = 500.0            # affinity decay constant
    forward_weight: float = 2.0      # forwards count this much in affinity


# Serving: epochs are engine sim-time ms (a pod step is ~0.1–0.5 ms), moves
# ship real KV bytes — tight budget, strict evidence gates.  Winners of the
# benchmarks/planner.py sweep (mixtral KV sizes, 3 seeds): vs ROUTER_DEFAULTS
# the planner cuts total wire 4.6–7.5× and forwards 8–26% at locality ≥ 0.7
# with tokens/s parity at locality 0 (where the gates keep it idle).
SERVE_PLAN_DEFAULTS = PlanConfig(
    epoch_ms=5.0, top_k=4, node_budget_bytes=2e6, hysteresis_epochs=6,
    horizon_ms=500.0, margin=3.0, min_frac=0.7, min_events=8.0,
    load_gain=0.02, forward_weight=1.5)

# Simulator: epochs are simulated wall ms, costs are the paper's
# communication steps (a lease prefetch ships no state), multi-class
# footprints make co-location worth crediting.
SIM_PLAN_DEFAULTS = PlanConfig(
    epoch_ms=50.0, top_k=16, node_budget_bytes=float("inf"),
    hysteresis_epochs=2, horizon_ms=200.0, margin=4.0, min_frac=0.5,
    co_gain=0.25, tau_ms=200.0)


@dataclass(frozen=True)
class PlannedMove:
    cc: int                 # conflict class / session id
    src: int                # owner at planning time
    dst: int                # target node/pod
    state_bytes: float      # state the move ships (0 ⇒ pure lease prefetch)
    score: float

    @property
    def is_prefetch(self) -> bool:
        return self.state_bytes <= 0.0


@dataclass
class PlacementPlan:
    epoch: int
    moves: List[PlannedMove] = field(default_factory=list)
    n_candidates: int = 0   # finite-scored candidates before bounding

    @property
    def total_bytes(self) -> float:
        return sum(m.state_bytes for m in self.moves)

    def __bool__(self) -> bool:
        return bool(self.moves)


@dataclass
class PendingPlan:
    """An in-flight epoch: scoring dispatched, bounding deferred.

    Everything the bounding loop reads is snapshotted at :meth:`
    PlacementPlanner.begin` time (epoch-stamped inputs), so however many
    decode steps run between ``begin`` and ``finish``, the finished plan is
    byte-identical to the plan a synchronous call would have produced at
    the begin instant.  ``scores`` is the un-materialized jax dispatch;
    ``view`` stamps the membership view for purge invalidation.
    """

    epoch: int
    view: int
    c: int
    owner: "np.ndarray"
    state_bytes: "np.ndarray"
    scores: object          # jax.Array future ([cap, N]); None when c == 0


class PlacementPlanner:
    """The decision half of the loop: affinity in, bounded plan out."""

    def __init__(self, n_nodes: int, n_classes: int,
                 cfg: Optional[PlanConfig] = None, *,
                 grow: bool = False, track_co: bool = False,
                 mesh=None) -> None:
        self.cfg = cfg or PlanConfig()
        self.n_nodes = n_nodes
        self.affinity = AffinityTracker(
            n_nodes, n_classes, tau_ms=self.cfg.tau_ms,
            forward_weight=self.cfg.forward_weight,
            track_co=track_co or self.cfg.co_gain > 0.0, grow=grow)
        self.epoch = 0
        # executed-move history for the reversal check: (epoch, cc, src, dst)
        self._history: Deque[Tuple[int, int, int, int]] = deque()
        self.planned_moves = 0
        self.planned_bytes = 0.0
        # plan mesh for sharded scoring (None: plain jit); membership view
        # counter + bounded purge log for invalidating in-flight plans
        self.mesh = mesh
        self._view = 0
        self._purge_log: Deque[Tuple[int, int]] = deque(maxlen=256)

    @classmethod
    def for_serving(cls, n_pods: int, n_sessions: int,
                    epoch_ms: Optional[float] = None, *,
                    mesh=None) -> "PlacementPlanner":
        """The serving-stack construction (growable session space, pinned
        ``SERVE_PLAN_DEFAULTS``, optional epoch override) — the one used by
        ``launch/serve.py`` and the benches."""
        cfg = SERVE_PLAN_DEFAULTS if epoch_ms is None else \
            replace(SERVE_PLAN_DEFAULTS, epoch_ms=epoch_ms)
        return cls(n_pods, n_sessions, cfg, grow=True, mesh=mesh)

    # -- view change ---------------------------------------------------------
    def purge_node(self, node: int) -> None:
        """A member failed: drop every planner trace of it.

        Without this the planner keeps steering at a ghost — the dead
        node's affinity rows still attract moves toward it, and history
        entries naming it mis-gate live moves (a class moved *to* the dead
        node recently would refuse its rescue move back as a "reversal").
        Executors already skip dead targets, so this is about not wasting
        the bounded plan (top-K slots, byte budget) on them and not
        blocking the survivors.  Idempotent: every surviving replica's
        view-change handler may call it.

        Also bumps the membership view: a :class:`PendingPlan` begun before
        this purge scored against the dead node's affinity rows, so
        :meth:`finish` drops its moves that name the node (the async
        epoch's invalidation seam).
        """
        self.affinity.purge_node(node)
        self._history = deque(
            h for h in self._history if h[2] != node and h[3] != node)
        self._view += 1
        self._purge_log.append((self._view, node))

    # -- hysteresis ----------------------------------------------------------
    def _reverses_recent(self, cc: int, dst: int, epoch: int) -> bool:
        w = self.cfg.hysteresis_epochs
        for (ep, c, src, _d) in self._history:
            if c == cc and src == dst and epoch - ep < w:
                return True
        return False

    def _prune_history(self) -> None:
        w = self.cfg.hysteresis_epochs
        while self._history and self.epoch - self._history[0][0] >= w:
            self._history.popleft()

    # -- the plan ------------------------------------------------------------
    def begin(
        self,
        now: float,
        owner: np.ndarray,          # [C] int, -1 = unowned (skipped)
        state_bytes: np.ndarray,    # [C] bytes a move of class c ships
        fwd_cost: np.ndarray,       # [C] per-access forward cost
        move_cost: np.ndarray,      # [C] one-time migration cost
        cpu: np.ndarray,            # [N]
    ) -> PendingPlan:
        """Kick one epoch's scoring; return without waiting for it.

        Snapshots every input (including the decayed affinity rates at
        ``now``) and dispatches the jit'd evaluation — sharded over
        ``self.mesh`` when one is set — so the caller's decode steps overlap
        the device work.  :meth:`finish` harvests; ``finish(begin(...))``
        with nothing in between IS the synchronous plan.
        """
        cfg = self.cfg
        self.epoch += 1
        self._prune_history()
        c = len(owner)
        owner = np.asarray(owner, dtype=np.int32).copy()
        if c == 0:
            return PendingPlan(epoch=self.epoch, view=self._view, c=0,
                               owner=owner, state_bytes=np.zeros((0,)),
                               scores=None)
        # pow2-pad the class axis so recurring session counts reuse the jit
        # cache (the serving session space grows dynamically)
        cap = 1
        while cap < c:
            cap *= 2
        owner_p = np.full((cap,), -1, dtype=np.int32)
        owner_p[:c] = owner
        # float32 like the cost/rate producers: the scorer computes in
        # float32, and float64 here would put [cap]-sized host conversions
        # back on the kick path
        pad = lambda a: np.pad(np.asarray(a, np.float32), (0, cap - c))
        rates = self.affinity.rates(now, cap)
        co = (self.affinity.co_rates(now, cap)
              if cfg.co_gain > 0.0 else None)
        scores = score_moves_async(
            rates, owner_p, pad(fwd_cost), pad(move_cost), cpu,
            horizon_ms=cfg.horizon_ms, margin=cfg.margin,
            min_frac=cfg.min_frac, min_rate=cfg.min_events / cfg.tau_ms,
            load_gain=cfg.load_gain,
            co_gain=cfg.co_gain, co_rates=co, max_cpu=cfg.max_cpu,
            overload_ctrl=cfg.overload_ctrl, mesh=self.mesh)
        return PendingPlan(
            epoch=self.epoch, view=self._view, c=c, owner=owner,
            state_bytes=np.asarray(state_bytes, dtype=np.float64).copy(),
            scores=scores)

    def finish(self, pending: PendingPlan) -> PlacementPlan:
        """Harvest a :meth:`begin` dispatch into the bounded plan.

        Pure host work over the epoch-stamped snapshot: materialize the
        scores (the only wait), argmax per class, rank by score per shipped
        byte, bound by top-K / byte budget / hysteresis.  Nodes purged
        since ``begin`` (``pending.view``) invalidate their moves — the
        snapshot scored against a membership view that no longer exists.
        """
        cfg = self.cfg
        plan = PlacementPlan(epoch=pending.epoch)
        c = pending.c
        if c == 0:
            return plan
        scores = np.asarray(pending.scores)[:c]
        purged = {node for (v, node) in self._purge_log
                  if v > pending.view}

        # one candidate per class: its argmax target
        best_n = np.argmax(scores, axis=1)
        best_s = scores[np.arange(c), best_n]
        cand = np.flatnonzero(np.isfinite(best_s) & (best_s > cfg.min_score))
        plan.n_candidates = int(cand.size)
        if not cand.size:
            return plan
        sb = pending.state_bytes
        # rank by score per shipped byte: a lease prefetch (0 bytes) beats
        # any re-home of equal score, small caches beat grown ones
        rank = best_s[cand] / np.maximum(sb[cand], 1.0)
        order = cand[np.argsort(-rank)]

        spent = np.zeros((self.n_nodes,), dtype=np.float64)
        for idx in order:
            if len(plan.moves) >= cfg.top_k:
                break
            cc, dst = int(idx), int(best_n[idx])
            src, bytes_ = int(pending.owner[idx]), float(sb[idx])
            if src in purged or dst in purged:
                continue
            if spent[dst] + bytes_ > cfg.node_budget_bytes:
                continue
            if self._reverses_recent(cc, dst, pending.epoch):
                continue
            plan.moves.append(PlannedMove(
                cc=cc, src=src, dst=dst, state_bytes=bytes_,
                score=float(best_s[idx])))
            spent[dst] += bytes_
        return plan

    def plan(
        self,
        now: float,
        owner: np.ndarray,
        state_bytes: np.ndarray,
        fwd_cost: np.ndarray,
        move_cost: np.ndarray,
        cpu: np.ndarray,
    ) -> PlacementPlan:
        """Synchronous epoch: ``finish(begin(...))`` at zero distance."""
        return self.finish(self.begin(
            now, owner, state_bytes, fwd_cost, move_cost, cpu))

    def committed(self, moves: List[PlannedMove]) -> None:
        """Record the moves a consumer actually executed.

        Hysteresis and the planned_moves/planned_bytes counters track
        *executed* work: a move the executor skipped (dead target, stale
        ownership) must neither block its class's real move as a phantom
        "reversal" nor inflate the accounting."""
        for m in moves:
            self._history.append((self.epoch, m.cc, m.src, m.dst))
        self.planned_moves += len(moves)
        self.planned_bytes += sum(m.state_bytes for m in moves)
