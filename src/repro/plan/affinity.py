"""Affinity statistics feeding the placement planner.

Two decayed matrices built on the repo's single decayed-counter
implementation (:class:`repro.core.stats.DecayedFrequency`, one clock
source — the simulator's event clock or the engine-ticked router clock):

* ``node``  — A[j, x]: access rate of node/pod ``j`` on conflict class /
  session ``x``.  Fed by commit deliveries and request touches; forwards
  count extra (they are the cost signal a move removes), aborts are
  recorded separately and damp the executing node's affinity (a class
  aborting at a node is contended there, not attracted).
* ``co``    — Co[x, y]: co-access rate of classes ``x`` and ``y`` within
  one transaction footprint.  Moving a class toward nodes that own its
  co-accessed classes saves multi-class lease round-trips, so the scorer
  credits co-location (:func:`repro.plan.score.score_moves`).

The tracker never decides anything — it is the measurement half of the
affinity → score → plan → prefetch loop.
"""
from __future__ import annotations

from typing import Iterable, Optional

import numpy as np

from repro.core.stats import DecayedFrequency


class AffinityTracker:
    def __init__(self, n_nodes: int, n_classes: int, *,
                 tau_ms: float = 500.0, forward_weight: float = 2.0,
                 abort_weight: float = 1.0, track_co: bool = False,
                 grow: bool = False) -> None:
        self.n_nodes = n_nodes
        self.forward_weight = forward_weight
        self.abort_weight = abort_weight
        self.node = DecayedFrequency(n_nodes, n_classes, tau_ms=tau_ms,
                                     grow_cols=grow)
        self.aborts = DecayedFrequency(n_nodes, n_classes, tau_ms=tau_ms,
                                       grow_cols=grow)
        # co-access is [n_classes, n_classes]: rows grow with the same
        # pow2 policy, columns via the shared grow_cols machinery
        self.co: Optional[DecayedFrequency] = (
            DecayedFrequency(n_classes, n_classes, tau_ms=tau_ms,
                             grow_cols=grow) if track_co else None)

    # -- event ingestion -----------------------------------------------------
    def record_commit(self, t: float, origin: int, ccs: Iterable[int]) -> None:
        """A transaction/request from ``origin`` committed touching ``ccs``."""
        ccs = tuple(ccs)
        self.node.record(t, origin, ccs)
        self._record_co(t, ccs)

    # serving touches are the same signal with request granularity
    record_touch = record_commit

    def record_forward(self, t: float, origin: int, ccs: Iterable[int]) -> None:
        """``origin`` had to ship work away for ``ccs`` — the planner's
        target signal, weighted above plain accesses."""
        self.node.record(t, origin, tuple(ccs), weight=self.forward_weight)

    def record_abort(self, t: float, node: int, ccs: Iterable[int]) -> None:
        """A certification abort at ``node``: contention, not attraction."""
        self.aborts.record(t, node, tuple(ccs))

    def _record_co(self, t: float, ccs) -> None:
        if self.co is None or len(ccs) < 2:
            return
        for x in ccs:
            self.co.record(t, x, (y for y in ccs if y != x))

    # -- planner inputs ------------------------------------------------------
    def rates(self, t: float, n_classes: Optional[int] = None) -> np.ndarray:
        """Effective affinity [n_classes, n_nodes]: access minus damped
        abort rates, clipped at zero (an abort can cancel an access, not
        turn a node repulsive below "never goes there").

        float32: this is the scorer's input boundary, and the jit computes
        in float32 regardless — handing it float64 would just put a [C, N]
        host-side conversion on the plan epoch's kick path."""
        a = self.node.rates(t).T
        b = self.aborts.rates(t).T
        out = np.maximum(a - self.abort_weight * b, 0.0).astype(np.float32)
        if n_classes is not None and out.shape[0] < n_classes:
            grown = np.zeros((n_classes, out.shape[1]), dtype=out.dtype)
            grown[: out.shape[0]] = out
            out = grown
        return out if n_classes is None else out[:n_classes]

    def co_rates(self, t: float, n_classes: int) -> Optional[np.ndarray]:
        """Co[x, y] co-access rates, [n_classes, n_classes] (or None)."""
        if self.co is None:
            return None
        c = self.co.rates(t)
        rows = min(c.shape[0], n_classes)
        cols = min(c.shape[1], n_classes)
        out = np.zeros((n_classes, n_classes), dtype=c.dtype)
        out[:rows, :cols] = c[:rows, :cols]
        return out

    def forget(self, cc: int) -> None:
        """Drop a class's statistics (e.g. an evicted session)."""
        self.node.zero_col(cc)
        self.aborts.zero_col(cc)
        if self.co is not None:
            self.co.zero_col(cc)
            if cc < self.co.counts.shape[0]:
                self.co.counts[cc, :] = 0.0

    def purge_node(self, node: int) -> None:
        """Drop a failed node's rows: a dead member must stop attracting
        (or repelling) planned moves.  Co-access is class-to-class and
        keeps no node axis."""
        self.node.counts[node, :] = 0.0
        self.aborts.counts[node, :] = 0.0

    def compact(self, n_classes: int) -> None:
        """Shrink the grown column spaces down to ``n_classes`` live
        classes (see :meth:`DecayedFrequency.shrink_to` for the pow2 +
        hysteresis policy)."""
        self.node.shrink_to(n_classes)
        self.aborts.shrink_to(n_classes)
        if self.co is not None:
            self.co.shrink_to(n_classes)
