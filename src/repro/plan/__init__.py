"""Proactive placement planner — self-optimizing lease circulation.

Everything the repo shipped before this package is *reactive*: the DTD
(:mod:`repro.core.dtd`) and the serving router (:mod:`repro.serve.router`)
only move a lease when a transaction or request is already stalled on it,
so every ownership change eats a forward/acquire round-trip on the
critical path.  This package is the proactive counterpart — the paper's
"self-optimizing lease circulation" run as a background control loop:

* :mod:`repro.plan.affinity` watches commit/forward/abort events (the
  simulator) or touch/forward metrics (the serving stack) and maintains a
  decayed conflict-class ↔ node affinity matrix plus class ↔ class
  co-access rates;
* :mod:`repro.plan.score` scores every [class, target-node] candidate
  move in one jit'd array evaluation — expected forward savings over a
  horizon minus the migration cost, with DTD constraint-(3) CPU
  feasibility masked out;
* :mod:`repro.plan.planner` turns scores into a bounded, hysteresis-damped
  :class:`PlacementPlan` (top-K moves per epoch, per-node byte budget, no
  move that reverses a recent one).

Consumers execute plans off the critical path: the cluster simulator as
background lease prefetches through the existing lease manager (safety
untouched), the serving engine as KV prefetch + session re-homes priced
onto pod busy clocks.  Division of labor: the reactive DTD keeps settling
per-request forward-vs-acquire; the planner owns *placement* — locality
repair and load rebalancing — so the router no longer has to panic-acquire
state on the critical path when a pod runs hot.
"""
from .affinity import AffinityTracker
from .planner import (PlacementPlan, PlacementPlanner, PlanConfig,
                      PlannedMove, SERVE_PLAN_DEFAULTS, SIM_PLAN_DEFAULTS)
from .score import price_move_costs, score_moves, score_moves_np

__all__ = [
    "AffinityTracker", "PlacementPlan", "PlacementPlanner", "PlanConfig",
    "PlannedMove", "SERVE_PLAN_DEFAULTS", "SIM_PLAN_DEFAULTS",
    "price_move_costs", "score_moves", "score_moves_np",
]
