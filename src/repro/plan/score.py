"""Batched move scoring — every [class, target-node] candidate in one jit.

A candidate move relocates conflict class (or session) ``c``'s lease to
node ``n``.  Its score is the *expected forward time saved over a horizon*
minus the *one-time migration cost*:

    score[c, n] = (adv + load + co) · horizon_ms · fwd_cost[c]
                  − margin · move_cost[c]

* ``adv``  — A[c, n] − A[c, owner[c]]: the affinity-rate advantage of the
  target over the current owner (accesses/ms that stop being forwards);
* ``load`` — ``load_gain · max(0, cpu[owner] − cpu[n])``: proactive
  rebalancing pressure away from hot owners;
* ``co``   — ``co_gain ·`` co-access rate delta toward nodes owning the
  class's co-accessed classes (multi-class footprints commit in one
  piggyback when they land together).

Infeasible candidates are masked to −inf: the no-op ``n == owner[c]``,
unowned classes, targets violating the DTD's CPU constraint (3), targets
below the ``min_frac`` dominance share, and classes whose total affinity
rate is below ``min_rate`` (decayed counters are noisy; sub-dominant
"advantages" and two-event "trends" are noise and would churn leases).

The jit'd evaluation (`score_moves`) is the hot path — no per-candidate
Python loop; `score_moves_np` is its numpy twin, kept for the parity test
exactly like :mod:`repro.core.dtd`'s `*_np` mirrors.  Costs come from the
same byte model the router prices with: :func:`price_move_costs` is the
array twin of :func:`repro.dist.locality.price_session_dispatch`.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.dist.locality import DCN_BW, DCN_RTT_S

NEG_INF = float("-inf")


def price_move_costs(
    state_bytes,
    work_bytes,
    *,
    handoff_bytes: float = 512.0,
    dcn_bw: float = DCN_BW,
    rtt_s: float = DCN_RTT_S,
    seq_shards: float = 1.0,
) -> Tuple[np.ndarray, np.ndarray]:
    """Array twin of ``price_session_dispatch``: per-class plan times.

    Returns ``(fwd_cost_s, move_cost_s)`` — the per-access forward time and
    the one-time state-migration time of every class, elementwise equal to
    ``price_session_dispatch(...).migrate_work_s`` / ``.migrate_state_s``
    for the same inputs (tests pin the parity).  float32 out: these feed
    the float32 scorer directly — float64 would put two [C] host-side
    conversions on the plan epoch's kick path.
    """
    seq_shards = max(1.0, float(seq_shards))
    state_bytes = np.asarray(state_bytes, dtype=np.float64)
    work_bytes = np.asarray(work_bytes, dtype=np.float64)
    fwd_cost_s = rtt_s + work_bytes / dcn_bw
    move_cost_s = rtt_s + (state_bytes / seq_shards + handoff_bytes) / dcn_bw
    return (fwd_cost_s.astype(np.float32), move_cost_s.astype(np.float32))


@functools.partial(
    jax.jit,
    static_argnames=("horizon_ms", "margin", "min_frac", "min_rate",
                     "load_gain", "co_gain", "max_cpu", "overload_ctrl"),
)
def _score_moves_jit(
    rates: jax.Array,        # [C, N] affinity rates, events/ms
    owner: jax.Array,        # [C] int32 current owner (-1 = unowned)
    fwd_cost: jax.Array,     # [C] per-access forward cost (s or steps)
    move_cost: jax.Array,    # [C] one-time migration cost (same unit)
    cpu: jax.Array,          # [N]
    co_adv: jax.Array,       # [C, N] co-location advantage (zeros if untracked)
    *,
    horizon_ms: float,
    margin: float,
    min_frac: float,
    min_rate: float,
    load_gain: float,
    co_gain: float,
    max_cpu: float,
    overload_ctrl: bool,
) -> jax.Array:
    c, n = rates.shape
    owned = owner >= 0
    safe_owner = jnp.clip(owner, 0, n - 1)
    own_rate = jnp.where(
        owned, jnp.take_along_axis(rates, safe_owner[:, None], axis=1)[:, 0], 0.0
    )
    adv = rates - own_rate[:, None]
    own_cpu = jnp.where(owned, cpu[safe_owner], 0.0)
    load = load_gain * jnp.maximum(0.0, own_cpu[:, None] - cpu[None, :])
    benefit = (adv + load + co_gain * co_adv) * horizon_ms * fwd_cost[:, None]
    score = benefit - margin * move_cost[:, None]

    is_owner = jnp.arange(n)[None, :] == owner[:, None]
    total = jnp.sum(rates, axis=1, keepdims=True)
    dominant = (rates >= min_frac * total) & (total >= min_rate)
    mask = (~is_owner) & owned[:, None] & dominant
    if overload_ctrl:
        mask &= (cpu < max_cpu)[None, :]
    return jnp.where(mask, score, NEG_INF)


def score_moves_async(
    rates: np.ndarray,
    owner: np.ndarray,
    fwd_cost: np.ndarray,
    move_cost: np.ndarray,
    cpu: np.ndarray,
    *,
    horizon_ms: float,
    margin: float = 1.0,
    min_frac: float = 0.0,
    min_rate: float = 0.0,
    load_gain: float = 0.0,
    co_gain: float = 0.0,
    co_rates: Optional[np.ndarray] = None,
    max_cpu: float = 0.9,
    overload_ctrl: bool = True,
    mesh=None,
) -> jax.Array:
    """Dispatch the [class, target] scoring and return WITHOUT materializing.

    The returned ``jax.Array`` is a future under jax's async dispatch: the
    caller keeps doing host work (decode steps) while the evaluation runs,
    and pays the wait only at ``np.asarray`` time — the harvest half of the
    planner's overlapped epochs.  ``mesh`` (a 1-D plan mesh from
    :func:`repro.dist.sharding.make_plan_mesh`) shards the class axis over
    the pod's devices; sharded and unsharded evaluations compute the same
    elementwise math, so the result is byte-identical either way and the
    mesh is a pure throughput knob.
    """
    c, n = np.asarray(rates).shape
    owner = np.asarray(owner, dtype=np.int32)
    if co_rates is not None and co_gain != 0.0:
        # co-location advantage: co-access mass owned at the target minus at
        # the current owner — one matmul, still a single fused evaluation
        onehot = (owner[:, None] == np.arange(n)[None, :]).astype(np.float64)
        m = np.asarray(co_rates, dtype=np.float64) @ onehot          # [C, N]
        at_owner = np.where(owner >= 0,
                            np.take_along_axis(
                                m, np.clip(owner, 0, n - 1)[:, None], axis=1)[:, 0],
                            0.0)
        co_adv = m - at_owner[:, None]
    else:
        # no co-tracking: a [1, 1] zero broadcasts inside the jit — putting
        # a dead [C, N] zeros array on the kick path would cost more host
        # time than the whole dispatch
        co_adv = np.zeros((1, 1), dtype=np.float64)
    args = {
        "rates": jnp.asarray(rates, jnp.float32),
        "owner": jnp.asarray(owner),
        "fwd_cost": jnp.asarray(fwd_cost, jnp.float32),
        "move_cost": jnp.asarray(move_cost, jnp.float32),
        "cpu": jnp.asarray(cpu, jnp.float32),
        "co_adv": jnp.asarray(co_adv, jnp.float32),
    }
    if mesh is not None:
        from repro.dist.sharding import plan_score_shardings

        shardings = plan_score_shardings(mesh, c)
        if shardings is not None:
            if args["co_adv"].shape[0] == 1:    # broadcast stub: replicate
                shardings = dict(shardings, co_adv=shardings["cpu"])
            args = {k: jax.device_put(v, shardings[k])
                    for k, v in args.items()}
    return _score_moves_jit(
        args["rates"], args["owner"], args["fwd_cost"], args["move_cost"],
        args["cpu"], args["co_adv"],
        horizon_ms=float(horizon_ms), margin=float(margin),
        min_frac=float(min_frac), min_rate=float(min_rate),
        load_gain=float(load_gain),
        co_gain=float(co_gain), max_cpu=float(max_cpu),
        overload_ctrl=bool(overload_ctrl))


def score_moves(*args, **kwargs) -> np.ndarray:
    """Score all [class, target] moves in ONE jit'd evaluation (blocking:
    dispatch + materialize — ``score_moves_async`` split at zero distance)."""
    return np.asarray(score_moves_async(*args, **kwargs))


def score_moves_np(
    rates, owner, fwd_cost, move_cost, cpu, *,
    horizon_ms, margin=1.0, min_frac=0.0, min_rate=0.0, load_gain=0.0,
    co_gain=0.0, co_rates=None, max_cpu=0.9, overload_ctrl=True,
) -> np.ndarray:
    """Numpy twin of :func:`score_moves` (test oracle, float32 like the jit)."""
    rates = np.asarray(rates, dtype=np.float32)
    owner = np.asarray(owner, dtype=np.int32)
    fwd_cost = np.asarray(fwd_cost, dtype=np.float32)
    move_cost = np.asarray(move_cost, dtype=np.float32)
    cpu = np.asarray(cpu, dtype=np.float32)
    c, n = rates.shape
    owned = owner >= 0
    safe = np.clip(owner, 0, n - 1)
    own_rate = np.where(
        owned, np.take_along_axis(rates, safe[:, None], axis=1)[:, 0], 0.0
    ).astype(np.float32)
    adv = rates - own_rate[:, None]
    own_cpu = np.where(owned, cpu[safe], 0.0).astype(np.float32)
    load = np.float32(load_gain) * np.maximum(
        np.float32(0.0), own_cpu[:, None] - cpu[None, :])
    if co_rates is not None and co_gain != 0.0:
        onehot = (owner[:, None] == np.arange(n)[None, :]).astype(np.float64)
        m = np.asarray(co_rates, dtype=np.float64) @ onehot
        at_owner = np.where(owned,
                            np.take_along_axis(m, safe[:, None], axis=1)[:, 0],
                            0.0)
        co_adv = (m - at_owner[:, None]).astype(np.float32)
    else:
        co_adv = np.zeros((c, n), dtype=np.float32)
    benefit = (adv + load + np.float32(co_gain) * co_adv) \
        * np.float32(horizon_ms) * fwd_cost[:, None]
    score = benefit - np.float32(margin) * move_cost[:, None]
    is_owner = np.arange(n)[None, :] == owner[:, None]
    total = rates.sum(axis=1, keepdims=True)
    dominant = (rates >= np.float32(min_frac) * total) \
        & (total >= np.float32(min_rate))
    mask = (~is_owner) & owned[:, None] & dominant
    if overload_ctrl:
        mask &= (cpu < max_cpu)[None, :]
    return np.where(mask, score, NEG_INF).astype(np.float32)
