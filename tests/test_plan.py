"""repro.plan: scoring parity, plan invariants, sim + serving integration.

The contract under test (ISSUE 5): move scoring is ONE jit'd array
evaluation whose numpy twin agrees bitwise-modulo-float32, plans respect
the DTD CPU constraint and their move/byte budgets, the simulator's
planner lowers forwards on a shifted high-locality workload without
touching STM safety, and the serving engine executes plans as
off-critical-path prefetch/re-homes with correct lease-epoch semantics.
"""
import dataclasses

import numpy as np
import pytest

from repro.core import BankWorkload, SimConfig, make_cluster
from repro.dist.locality import price_session_dispatch
from repro.plan import (AffinityTracker, PlacementPlanner, PlanConfig,
                        SIM_PLAN_DEFAULTS, price_move_costs, score_moves,
                        score_moves_np)


# ---------------------------------------------------------------------------
# Scoring: jit kernel == numpy twin, pricing == the router's byte model
# ---------------------------------------------------------------------------

def _rand_inputs(seed, c=24, n=6):
    rng = np.random.default_rng(seed)
    rates = rng.random((c, n)) * rng.choice([0.0, 0.02], (c, 1))
    owner = rng.integers(-1, n, c).astype(np.int32)
    fwd = rng.random(c) * 2e-3
    mv = rng.random(c) * 3e-3
    cpu = rng.random(n) * 1.2
    co = rng.random((c, c)) * 0.01
    return rates, owner, fwd, mv, cpu, co


@pytest.mark.parametrize("seed", [0, 1, 2])
@pytest.mark.parametrize("co_gain", [0.0, 0.3])
def test_score_moves_jit_matches_numpy_twin(seed, co_gain):
    rates, owner, fwd, mv, cpu, co = _rand_inputs(seed)
    kw = dict(horizon_ms=400.0, margin=2.5, min_frac=0.3, min_rate=1e-3,
              load_gain=0.05, co_gain=co_gain, co_rates=co, max_cpu=0.9)
    a = score_moves(rates, owner, fwd, mv, cpu, **kw)
    b = score_moves_np(rates, owner, fwd, mv, cpu, **kw)
    np.testing.assert_array_equal(np.isneginf(a), np.isneginf(b))
    fin = np.isfinite(a)
    np.testing.assert_allclose(a[fin], b[fin], rtol=1e-5, atol=1e-7)


def test_score_masks_owner_unowned_overload_and_noise():
    rates = np.array([[0.01, 0.02], [0.0, 0.03], [0.01, 0.0],
                      [1e-9, 2e-9]])
    owner = np.array([0, -1, 0, 0], np.int32)
    fwd = np.full(4, 1e-3)
    mv = np.zeros(4)
    cpu = np.array([0.0, 0.95])
    s = score_moves(rates, owner, fwd, mv, cpu, horizon_ms=100.0,
                    min_rate=1e-6, max_cpu=0.9)
    assert np.isneginf(s[:, 0]).all()      # own column masked
    assert np.isneginf(s[1]).all()         # unowned class masked
    assert np.isneginf(s[:, 1]).all()      # overloaded target masked (3)
    s2 = score_moves(rates, owner, fwd, mv, np.zeros(2), horizon_ms=100.0,
                     min_rate=1e-6, max_cpu=0.9)
    assert np.isfinite(s2[0, 1])           # feasible target scores
    assert np.isneginf(s2[3, 1])           # sub-min_rate evidence masked


def test_price_move_costs_matches_price_session_dispatch():
    state = np.array([0.0, 5e5, 2.6e6, 1e9])
    work = np.full(4, 5120.0)
    for shards in (1, 4):
        f, m = price_move_costs(state, work, seq_shards=shards)
        for i in range(len(state)):
            ref = price_session_dispatch(work[i], 0.0, state[i],
                                         wire_bytes_per_token=1.0,
                                         seq_shards=shards)
            assert f[i] == pytest.approx(ref.migrate_work_s)
            assert m[i] == pytest.approx(ref.migrate_state_s)


# ---------------------------------------------------------------------------
# Plan invariants (property tests)
# ---------------------------------------------------------------------------

def _planner_with_counts(counts, cfg):
    n_nodes, n_classes = counts.shape
    p = PlacementPlanner(n_nodes, n_classes, cfg)
    p.affinity.node.counts[:] = counts
    return p


@pytest.mark.parametrize("seed", range(20))
def test_plan_respects_budgets_and_cpu_feasibility(seed):
    """A PlacementPlan NEVER targets a CPU-infeasible node, never exceeds
    top_k moves, never exceeds the per-node inbound byte budget, and never
    plans a no-op (dst == src)."""
    rng = np.random.default_rng(seed)
    n, c = int(rng.integers(2, 8)), int(rng.integers(1, 40))
    counts = rng.random((n, c)) * rng.choice([0.0, 30.0], (1, c))
    cfg = PlanConfig(
        top_k=int(rng.integers(1, 6)),
        node_budget_bytes=float(rng.choice([5e5, 2e6, np.inf])),
        margin=float(rng.random() * 2), min_frac=float(rng.random() * 0.6),
        min_events=float(rng.choice([0.0, 4.0])),
        load_gain=float(rng.choice([0.0, 0.05])))
    p = _planner_with_counts(counts, cfg)
    owner = rng.integers(-1, n, c).astype(np.int32)
    state = rng.random(c) * 2e6
    fwd, mv = price_move_costs(state, np.full(c, 5120.0))
    cpu = rng.random(n) * 1.2
    plan = p.plan(0.0, owner, state, fwd, mv, cpu)

    assert len(plan.moves) <= cfg.top_k
    spent = np.zeros(n)
    for m in plan.moves:
        assert cpu[m.dst] < cfg.max_cpu          # constraint (3)
        assert m.src == owner[m.cc] and m.dst != m.src
        spent[m.dst] += m.state_bytes
    assert (spent <= cfg.node_budget_bytes + 1e-9).all()


def test_plan_hysteresis_blocks_reversals():
    """A move that reverses one *executed* (reported via committed())
    within the last W epochs is rejected; after W epochs it is admitted
    again.  Unexecuted plans leave no phantom history."""
    n, c = 2, 1
    cfg = PlanConfig(top_k=4, hysteresis_epochs=3, margin=0.0, min_frac=0.0,
                     min_events=0.0, node_budget_bytes=np.inf)
    p = PlacementPlanner(n, c, cfg)
    state = np.zeros(c)
    fwd = np.full(c, 1e-3)
    mv = np.zeros(c)
    cpu = np.zeros(n)

    # epoch 1: class 0 is hot at node 1, owned by node 0 -> move 0 -> 1
    p.affinity.node.counts[:] = [[0.0], [50.0]]
    plan = p.plan(0.0, np.array([0]), state, fwd, mv, cpu)
    assert [(m.cc, m.src, m.dst) for m in plan.moves] == [(0, 0, 1)]
    p.committed(plan.moves)
    assert p.planned_moves == 1
    # flip the affinity: node 0 now dominates — the reversal (-> 0) must be
    # blocked for W epochs even though it scores best
    p.affinity.node.counts[:] = [[50.0], [0.0]]
    for _ in range(cfg.hysteresis_epochs - 1):
        plan = p.plan(0.0, np.array([1]), state, fwd, mv, cpu)
        assert not plan.moves
    plan = p.plan(0.0, np.array([1]), state, fwd, mv, cpu)
    assert [(m.cc, m.src, m.dst) for m in plan.moves] == [(0, 1, 0)]


def test_plan_unexecuted_moves_leave_no_phantom_hysteresis():
    """A planned move the executor skipped (dead node, stale ownership)
    must not block the class's real move as a 'reversal'."""
    cfg = PlanConfig(top_k=4, hysteresis_epochs=5, margin=0.0, min_frac=0.0,
                     min_events=0.0, node_budget_bytes=np.inf)
    p = PlacementPlanner(2, 1, cfg)
    p.affinity.node.counts[:] = [[0.0], [50.0]]
    args = (np.zeros(1), np.full(1, 1e-3), np.zeros(1), np.zeros(2))
    plan = p.plan(0.0, np.array([0]), *args)
    assert plan.moves                      # planned 0 -> 1, NOT committed
    assert p.planned_moves == 0
    p.affinity.node.counts[:] = [[50.0], [0.0]]
    plan = p.plan(0.0, np.array([1]), *args)
    assert [(m.cc, m.src, m.dst) for m in plan.moves] == [(0, 1, 0)]


def test_planner_idle_without_evidence():
    """min_events keeps the planner from acting on two-touch noise."""
    p = PlacementPlanner(4, 8, PlanConfig(min_events=6.0, min_frac=0.5))
    p.affinity.record_touch(0.0, 2, (3,))
    p.affinity.record_touch(1.0, 2, (3,))
    owner = np.zeros(8, np.int32)
    state = np.zeros(8)
    fwd, mv = price_move_costs(state, np.full(8, 5120.0))
    plan = p.plan(2.0, owner, state, fwd, mv, np.zeros(4))
    assert not plan.moves and plan.n_candidates == 0


def test_async_plan_byte_identical_to_sync():
    """begin() epoch-stamps every input (affinity rates, owner, state
    bytes), so a plan finished after arbitrary mid-epoch mutation — new
    affinity events, the caller scribbling over its arrays — is
    byte-identical to the synchronous plan at the begin instant."""
    rng = np.random.default_rng(5)
    n, c = 4, 24
    counts = rng.random((n, c)) * 40.0
    cfg = PlanConfig(top_k=8, margin=0.0, min_frac=0.0, min_events=0.0,
                     node_budget_bytes=np.inf)
    p_sync = _planner_with_counts(counts, cfg)
    p_async = _planner_with_counts(counts.copy(), cfg)
    owner = rng.integers(0, n, c).astype(np.int32)
    state = rng.random(c) * 1e6
    fwd, mv = price_move_costs(state, np.full(c, 5120.0))
    cpu = rng.random(n) * 0.5
    want = p_sync.plan(0.0, owner, state, fwd, mv, cpu)
    assert want.moves                       # a vacuous identity proves nothing

    pending = p_async.begin(0.0, owner, state, fwd, mv, cpu)
    # mid-epoch: decode steps record fresh affinity, the caller reuses its
    # buffers — none of it may leak into the already-begun epoch
    p_async.affinity.record_touch(0.0, 1, tuple(range(c)))
    owner[:] = -1
    state[:] = 0.0
    got = p_async.finish(pending)
    key = lambda pl: [(m.cc, m.src, m.dst, m.state_bytes, m.score)
                      for m in pl.moves]
    assert key(got) == key(want)
    assert (got.epoch, got.n_candidates) == (want.epoch, want.n_candidates)


def test_async_plan_view_change_invalidates_purged_nodes():
    """purge_node between begin and finish bumps the membership view: the
    pending plan's moves naming the purged node (as src or dst) are
    dropped at harvest, moves between survivors land untouched."""
    n, c = 3, 2
    cfg = PlanConfig(top_k=4, margin=0.0, min_frac=0.0, min_events=0.0,
                     node_budget_bytes=np.inf)
    counts = np.zeros((n, c))
    counts[1, 0] = 50.0     # class 0 (owned by 0) is hot at node 1
    counts[2, 1] = 50.0     # class 1 (owned by 0) is hot at node 2
    p = _planner_with_counts(counts, cfg)
    owner = np.zeros(c, np.int32)
    state = np.zeros(c)
    fwd, mv = np.full(c, 1e-3), np.zeros(c)
    cpu = np.zeros(n)

    pending = p.begin(0.0, owner, state, fwd, mv, cpu)
    p.purge_node(1)                         # mid-epoch view change
    plan = p.finish(pending)
    assert [(m.cc, m.dst) for m in plan.moves] == [(1, 2)]

    # a purge BEFORE begin is part of the epoch's view — nothing to drop,
    # and the purged node's zeroed affinity no longer attracts anyway
    pending = p.begin(0.0, owner, state, fwd, mv, cpu)
    plan = p.finish(pending)
    assert [(m.cc, m.dst) for m in plan.moves] == [(1, 2)]


# ---------------------------------------------------------------------------
# Affinity tracker
# ---------------------------------------------------------------------------

def test_affinity_forward_weight_and_abort_damping():
    a = AffinityTracker(2, 4, tau_ms=100.0, forward_weight=2.0,
                        abort_weight=1.0)
    a.record_commit(0.0, 0, (1,))
    a.record_forward(0.0, 0, (1,))
    r = a.rates(0.0)
    assert r[1, 0] == pytest.approx(3.0 / 100.0)     # 1 + weighted 2
    a.record_abort(0.0, 0, (1,))
    assert a.rates(0.0)[1, 0] == pytest.approx(2.0 / 100.0)
    # damping clips at zero, never repulsive
    for _ in range(5):
        a.record_abort(0.0, 0, (1,))
    assert a.rates(0.0)[1, 0] == 0.0


def test_affinity_co_access_and_forget():
    a = AffinityTracker(2, 4, tau_ms=100.0, track_co=True)
    a.record_commit(0.0, 0, (1, 2))
    co = a.co_rates(0.0, 4)
    assert co[1, 2] > 0 and co[2, 1] > 0 and co[1, 1] == 0
    a.forget(1)
    co = a.co_rates(0.0, 4)
    assert co[1, 2] == 0 and co[2, 1] == 0
    assert a.rates(0.0)[1].sum() == 0


def test_shared_decayed_frequency_grows_and_zeroes():
    from repro.core.stats import DecayedFrequency

    f = DecayedFrequency(2, 2, tau_ms=50.0, grow_cols=True)
    f.record(0.0, 1, (9,))                 # auto-grow past col 2
    assert f.n_cols == 16 and f.rates(0.0)[1, 9] > 0
    f.zero_col(9)
    assert f.rates(0.0)[1, 9] == 0.0
    fixed = DecayedFrequency(2, 2)
    with pytest.raises(IndexError):
        fixed.ensure_col(5)


# ---------------------------------------------------------------------------
# Simulator regression: the shifted high-locality workload
# ---------------------------------------------------------------------------

class RotatingBank(BankWorkload):
    """Bank whose node→partition affinity rotates mid-run (phase shift):
    after the shift every node's dominant partition is its neighbour's, so
    the reactive stack forwards its local transactions forever while the
    planner re-circulates the leases to the new dominant accessors."""

    rotation: int = 0

    def _choose_partition(self, node, rng):
        home = (node + self.rotation) % self.n_nodes
        if rng.random() < self.locality:
            return home
        others = [p for p in range(self.n_nodes) if p != home]
        return int(others[rng.integers(len(others))])


def _run_shifted(plan, seed=0):
    cfg = SimConfig(duration_ms=1000.0, warmup_ms=100.0, seed=seed,
                    n_classes=64, plan=plan)
    wl = RotatingBank(n_nodes=cfg.n_nodes, n_items=cfg.n_items, locality=0.9)
    c = make_cluster("LILAC-TM-ST", wl, cfg)
    marks = {}

    def shift():
        wl.rotation = 1
        marks["fw"] = c.metrics.forwards
        marks["commits"] = c.metrics.commits

    c.events.schedule(300.0, shift)
    m = c.run()
    return c, m, m.forwards - marks["fw"], m.commits - marks["commits"]


def test_sim_planner_preserves_safety_and_lowers_forwards():
    """Seeded planner run: STM safety invariants hold (money conserved, no
    commit of a conflicting pair — replicated stores stay byte-identical)
    and the post-shift forward count is strictly below the reactive run."""
    base_c, base_m, base_fw, base_commits = _run_shifted(None)
    plan_c, plan_m, plan_fw, plan_commits = _run_shifted(SIM_PLAN_DEFAULTS)

    for c in (base_c, plan_c):
        expect = c.cfg.n_items * c.cfg.init_value
        for r in c.replicas:
            assert r.store.total() == pytest.approx(expect, abs=1e-6)
        v0 = c.replicas[0].store.values
        ver0 = c.replicas[0].store.versions
        for r in c.replicas[1:]:
            np.testing.assert_array_equal(v0, r.store.values)
            np.testing.assert_array_equal(ver0, r.store.versions)

    assert plan_m.plan_prefetches > 0
    assert plan_fw < base_fw                       # strictly fewer forwards
    assert plan_commits >= base_commits            # and no throughput loss
    # the fix is structural, not marginal: post-shift forward *rate* halves
    assert plan_fw / max(1, plan_commits) < 0.5 * base_fw / max(1, base_commits)


def test_sim_prefetch_behind_active_owner_cannot_wedge_the_class():
    """Review regression: a prefetch whose LOR enqueues *behind* an active
    owner must not be drained to activeXacts=0 while queued — a dormant
    non-head LOR is unfreeable (the blocked-and-drained rule only fires at
    the head) and would wedge the class for every later request.  The
    drain now waits for the LOR to head its queues, so the interleaving
    owner-active → prefetch → third-party request → owner frees resolves
    with the third party owning the class."""
    from repro.core.lease import LeaseRequest

    cfg = SimConfig(n_nodes=3, n_classes=8)
    wl = BankWorkload(n_nodes=3, n_items=cfg.n_items)
    c = make_cluster("FGL", wl, cfg)
    cc = 5

    def deliver(req):
        for node in range(3):
            c._on_opt(node, ("lease", req), req.proc)
        for node in range(3):
            c._on_to(node, ("lease", req), req.proc)

    # node 0 holds cc with an active (undrained) transaction
    deliver(LeaseRequest(req_id=1, proc=0, ccs=(cc,)))
    # planner prefetch for node 1 enqueues second — must NOT drain yet
    deliver(LeaseRequest(req_id=2, proc=1, ccs=(cc,), prefetch=True))
    pre_lor = c.replicas[1].lm.cq[cc][1]
    assert pre_lor.proc == 1 and pre_lor.activeXacts == 1
    # node 2 requests cc: blocks the prefetch LOR while it is still queued
    deliver(LeaseRequest(req_id=3, proc=2, ccs=(cc,)))
    assert pre_lor.blocked
    # owner 0 finishes its transaction and frees its LOR
    lor0 = c.replicas[0].lm.cq[cc][0]
    keys = [l.key() for l in c.replicas[0].lm.finished_xact([lor0])]
    assert keys, "owner's blocked+drained LOR must free"
    for node in range(3):
        c._on_urb(node, ("freed", keys), 0)
    c.events.run(until=100.0)              # flush the prefetch's own free
    # the class is NOT wedged: node 2's request reaches the head everywhere
    for r in c.replicas:
        assert r.lm.head_owner(cc) == 2, r.lm.cq[cc]


def test_sim_prefetch_is_piggybackable_and_freed_on_conflict():
    """A prefetched LOR sits unblocked with activeXacts drained, so local
    transactions piggyback on it; a conflicting remote request frees it by
    the ordinary blocked-and-drained rule (no wedging)."""
    from repro.core.lease import FGLLeaseManager, LeaseRequest

    lms = [FGLLeaseManager(p, 4) for p in range(2)]
    pre = LeaseRequest(req_id=1, proc=0, ccs=(2,), prefetch=True)
    for lm in lms:
        lors = lm.on_to_deliver(pre)
        if lm.proc == 0:
            assert not lm.finished_xact(lors)      # head, unblocked: stays
    got = lms[0].try_piggyback(frozenset({2}))
    assert got is not None and got[0].req_id == 1  # reuse without a request
    assert not lms[0].finished_xact(got)
    # a remote conflicting request blocks it; drained -> freed immediately
    req = LeaseRequest(req_id=2, proc=1, ccs=(2,))
    to_free = lms[0].on_opt_deliver(req)
    assert [l.req_id for l in to_free] == [1]


# ---------------------------------------------------------------------------
# Serving engine integration
# ---------------------------------------------------------------------------

def _serve_engine(plan_cfg, kvb=1000.0, n_pods=2):
    from repro.configs import get_smoke_config
    from repro.serve.engine import MultiPodEngine, SimBackend
    from repro.serve.router import LocalityRouter

    cfg = get_smoke_config("mixtral-8x7b")
    router = LocalityRouter(n_pods, policy="short", arbitration="priced",
                            kv_bytes_per_token=kvb)
    planner = PlacementPlanner(n_pods, 16, plan_cfg, grow=True)
    eng = MultiPodEngine(n_pods, SimBackend(cfg), router, planner=planner)
    return eng, router, planner


def test_engine_planner_rehomes_misplaced_session():
    """A session owned by the wrong pod but touched from its dominant
    origin is re-homed by a planned move (not by a reactive acquire), with
    the lease epoch bumped so stale forwards abort."""
    from repro.serve.engine import Request

    cfg = PlanConfig(epoch_ms=0.5, top_k=4, node_budget_bytes=np.inf,
                     hysteresis_epochs=2, margin=0.5, min_frac=0.5,
                     min_events=3.0, horizon_ms=500.0)
    # heavy KV per token: the byte verdict keeps forwarding (never a
    # reactive acquire), so any re-home must come from the planner
    eng, router, planner = _serve_engine(cfg, kvb=10_000.0)
    eng.submit(Request(sid=5, origin=1, n_tokens=2))   # misplaced at pod 1
    eng.run_step()
    epoch0 = router.lease_epoch[5]
    for _ in range(12):                                # dominant origin: pod 0
        eng.submit(Request(sid=5, origin=0, n_tokens=1))
        eng.run_step()
    assert router.owner[5] == 0                        # planner re-homed it
    assert router.metrics.planned_moves >= 1
    assert eng.metrics.plan_moves + eng.metrics.plan_prefetches >= 1
    assert router.lease_epoch[5] > epoch0              # epoch bumped
    assert router.metrics.acquires == 0                # no reactive acquire
    eng.drain()
    assert not any(eng.queues)


def test_engine_planner_prefetch_counts_zero_byte_moves():
    """A cacheless session (length 0) moves as a pure lease prefetch: no
    wire bytes, counted separately from KV re-homes."""
    cfg = PlanConfig(epoch_ms=0.5, top_k=4, node_budget_bytes=np.inf,
                     hysteresis_epochs=2, margin=0.0, min_frac=0.5,
                     min_events=2.0, horizon_ms=500.0)
    eng, router, planner = _serve_engine(cfg)
    # a session known to the ledger but with no cache yet, whose touch
    # affinity (fed out-of-band, e.g. piggybacked metrics) points at pod 0
    router.owner[7] = 1
    router.lease_epoch[7] = 1
    eng.session_home[7] = 1
    for t in range(6):
        planner.affinity.record_touch(float(t), 0, (7,))
    wire0 = eng.metrics.wire_bytes
    for _ in range(4):
        eng.run_step()                     # idle steps advance the clock
    assert router.owner[7] == 0
    assert eng.metrics.plan_prefetches >= 1
    assert eng.metrics.plan_moves == 0
    assert eng.metrics.plan_bytes == 0.0
    assert eng.metrics.wire_bytes == wire0             # nothing on the wire


def test_router_planned_mode_keeps_byte_verdict_under_overload():
    """With a planner attached the router never panic-acquires: the byte
    verdict stands even when the owner violates constraint (3)."""
    from repro.serve.router import LocalityRouter

    r = LocalityRouter(4, policy="short", arbitration="priced",
                       kv_bytes_per_token=1e6)
    r.planned = True
    r.route(0, 9, 0)                       # pod 0 owns sid 9
    r.observe_cpu(np.array([1.0, 0.0, 0.0, 0.0]))
    d = r.route(2, 9, 50)                  # heavy KV, owner overloaded
    assert d.action == "forward" and d.target == 0
    # un-planned router flips to acquire on the same inputs
    r2 = LocalityRouter(4, policy="short", arbitration="priced",
                        kv_bytes_per_token=1e6)
    r2.route(0, 9, 0)
    r2.observe_cpu(np.array([1.0, 0.0, 0.0, 0.0]))
    assert r2.route(2, 9, 50).action == "acquire"
