"""Property tests for the deterministic event engine (core.events).

Four contracts the schedule explorer leans on, checked over generated
schedules: same-instant FIFO, cancel semantics, ``at()`` clamping, and
``run(max_events=)`` resumption.  Uses hypothesis when installed; in
minimal environments the same properties run over a seeded random-case
sweep (deterministic, no extra dependency)."""
import random

import pytest

from repro.core.events import EventQueue, SchedulePolicy

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYP = True
except ImportError:          # container without dev extras: seeded sweep
    HAVE_HYP = False


# delays quantized to a coarse grid so same-instant collisions are common
# (the interesting regime for FIFO and policy-identity properties)
def _gen_delays(rnd, n_max=24):
    return [rnd.randrange(0, 8) * 0.5 for _ in range(rnd.randrange(0, n_max))]


def forall_delays(test):
    """Run ``test(delays)`` over many generated schedules."""
    if HAVE_HYP:
        strat = st.lists(
            st.integers(0, 7).map(lambda k: k * 0.5), max_size=24)
        return settings(deadline=None, max_examples=120)(given(strat)(test))

    def runner():
        rnd = random.Random(0xA11CE)
        for _ in range(200):
            test(_gen_delays(rnd))
    # plain rename, not functools.wraps: copying __wrapped__ would make
    # pytest read the one-argument signature and look for a fixture
    runner.__name__ = test.__name__
    runner.__doc__ = test.__doc__
    return runner


def _schedule_all(q, delays, log):
    return [q.schedule(d, (lambda i=i: log.append(i))) for i, d in
            enumerate(delays)]


# ---------------------------------------------------------------------------
# 1. same-instant FIFO: equal-time events fire in scheduling order
# ---------------------------------------------------------------------------

@forall_delays
def test_same_instant_fifo(delays):
    log = []
    q = EventQueue()
    _schedule_all(q, delays, log)
    q.run(1e9)
    want = [i for _, i in sorted((d, i) for i, d in enumerate(delays))]
    assert log == want
    assert q.empty() and q.n_dispatched == len(delays)


@forall_delays
def test_identity_policy_matches_no_policy(delays):
    """The base SchedulePolicy is byte-identical to running policy-free."""
    logs = []
    for pol in (None, SchedulePolicy()):
        log = []
        q = EventQueue(policy=pol)
        _schedule_all(q, delays, log)
        q.run(1e9)
        logs.append((log, q.now, q.n_dispatched))
    assert logs[0] == logs[1]


# ---------------------------------------------------------------------------
# 2. cancel semantics
# ---------------------------------------------------------------------------

@forall_delays
def test_cancel_before_run_suppresses_exactly_those(delays):
    rnd = random.Random(len(delays) * 1000 + int(sum(delays) * 2))
    log = []
    q = EventQueue()
    evs = _schedule_all(q, delays, log)
    dropped = {i for i in range(len(delays)) if rnd.random() < 0.4}
    for i in dropped:
        q.cancel(evs[i])
    q.run(1e9)
    want = [i for _, i in sorted((d, i) for i, d in enumerate(delays))
            if i not in dropped]
    assert log == want
    assert q.empty()


def test_cancel_mid_run_and_after_dispatch():
    log = []
    q = EventQueue()
    late = q.schedule(2.0, lambda: log.append("late"))
    first = q.schedule(1.0, lambda: (log.append("first"), q.cancel(late)))
    q.run(10.0)
    assert log == ["first"]
    q.cancel(first)            # cancelling an already-fired event: no-op
    assert q.empty() and q.n_dispatched == 1


# ---------------------------------------------------------------------------
# 3. at() clamping + negative-delay rejection
# ---------------------------------------------------------------------------

@forall_delays
def test_at_clamps_past_times_to_now(delays):
    q = EventQueue()
    q.schedule(5.0, lambda: None)
    q.run(5.0)
    assert q.now == 5.0
    log = []
    for i, d in enumerate(delays):
        # request times both before and after `now`; the past ones clamp
        ev = q.at(d * 2.0, (lambda i=i: log.append(i)))
        assert ev.time >= q.now
    q.run(1e9)
    # clamped events (target <= now) keep their scheduling order at `now`,
    # future ones sort by requested time — overall (time, seq) order
    want = [i for _, _, i in sorted((max(d * 2.0, 5.0), i, i)
                                    for i, d in enumerate(delays))]
    assert log == want


def test_negative_delay_rejected():
    q = EventQueue()
    with pytest.raises(ValueError):
        q.schedule(-0.1, lambda: None)


# ---------------------------------------------------------------------------
# 4. run(max_events=) resumption
# ---------------------------------------------------------------------------

def _closed_loop(q, log, fanout, depth):
    """Callbacks that reschedule: a realistic self-extending workload."""
    def fire(tag, d):
        log.append(tag)
        if d < depth:
            for j in range(fanout):
                q.schedule(0.5 * (j + 1),
                           (lambda t=(tag * 10 + j), dd=d + 1: fire(t, dd)))
    for i in range(3):
        q.schedule(0.5 * i, (lambda i=i: fire(i, 0)))


@pytest.mark.parametrize("chunk", [1, 2, 7])
def test_run_max_events_resumption_matches_one_shot(chunk):
    full_log = []
    q = EventQueue()
    _closed_loop(q, full_log, fanout=2, depth=3)
    q.run(1e9)

    log = []
    q2 = EventQueue()
    _closed_loop(q2, log, fanout=2, depth=3)
    for _ in range(10_000):
        if q2.empty():
            break
        q2.run(1e9, max_events=chunk)
    assert log == full_log
    assert q2.now == q.now and q2.n_dispatched == q.n_dispatched


@forall_delays
def test_run_until_partitions_compose(delays):
    """run(t1); run(t2) dispatches exactly what one run(t2) would."""
    full = []
    q = EventQueue()
    _schedule_all(q, delays, full)
    q.run(4.0)

    split = []
    q2 = EventQueue()
    _schedule_all(q2, delays, split)
    q2.run(1.5)
    assert q2.now == 1.5
    q2.run(4.0)
    assert split == full and q2.now == q.now == 4.0
