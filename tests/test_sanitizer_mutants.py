"""Mutation-kill harness: every injected protocol bug must be flagged.

Each test plants one seeded bug from the repo's historical catalogue
(or the paper's failure modes) and asserts the sanitizer names the right
invariant — pinning that the checks detect, not merely tolerate.
"""
import numpy as np
import pytest

from repro.analysis.sanitizer import (LeaseSanitizer, SanitizerError,
                                      check_write_locks)
from repro.core.lease import FGLLeaseManager, LeaseRequest
from repro.core.lease_batched import ShardedLeaseManager
from repro.serve.certifier import StepCertifier


def _req(req_id, proc, ccs):
    return LeaseRequest(req_id=req_id, proc=proc, ccs=tuple(sorted(ccs)))


def _mgr(kind, proc, n_classes=8):
    if kind == "oracle":
        return LeaseSanitizer(FGLLeaseManager(proc, n_classes))
    return LeaseSanitizer(
        ShardedLeaseManager(proc, n_classes, n_shards=2, jax_min=1))


# -- mutant 1: ownership re-place skips its epoch bump -----------------------

def test_mutant_skipped_epoch_bump_on_replace():
    owner = {4: 0}
    c = StepCertifier(2, sanitize=True, owner_of=lambda s: owner.get(s, -1))

    class R:
        sid = 4

    c.bump(4, 1)
    c.enqueue(0, R(), 1)
    owner[4] = 1          # the bug: apply_move updates the router only —
    #                       no certifier.bump, so the stale forward passes
    with pytest.raises(SanitizerError) as e:
        c.drain(0)
    assert e.value.invariant == "owner-at-drain"


# -- mutant 2: prefetch LOR freed/drained while non-head ---------------------

@pytest.mark.parametrize("kind", ["oracle", "sharded"])
def test_mutant_drain_prefetch_lor_while_non_head(kind):
    lm = _mgr(kind, proc=1)
    lm.on_to_deliver(_req(1, 0, (5,)))          # remote head owns cc=5
    lors = lm.on_to_deliver(_req(2, 1, (5,)))   # own prefetch queued behind
    lm.mark_prefetch(lors)
    with pytest.raises(SanitizerError) as e:
        # the bug (pre-PR 5): draining without waiting for is_enabled
        lm.finished_xact(lors)
    assert e.value.invariant == "prefetch-head"


# -- mutant 3: view change drops a surviving member's queued LOR -------------

def test_mutant_view_change_drops_survivor_lor():
    class OverPurging(FGLLeaseManager):
        def purge_proc(self, proc):
            super().purge_proc(proc)
            super().purge_proc(2)   # the bug: an innocent member's LORs go too

    lm = LeaseSanitizer(OverPurging(0, 8))
    lm.on_to_deliver(_req(1, 1, (3,)))
    lm.on_to_deliver(_req(2, 2, (4,)))
    with pytest.raises(SanitizerError) as e:
        lm.purge_proc(1)
    assert e.value.invariant == "conservation"
    assert "surviving" in e.value.detail


# -- mutant 4: the same request granted twice --------------------------------

@pytest.mark.parametrize("kind", ["oracle", "sharded"])
def test_mutant_double_grant(kind):
    lm = _mgr(kind, proc=0)
    req = _req(1, 0, (2,))
    lm.on_to_deliver(req)
    with pytest.raises(SanitizerError) as e:
        lm.on_to_deliver(req)   # the bug: duplicate TO delivery not deduped
    assert e.value.invariant == "single-owner"


# -- mutant 5: stale write-lock input to validate_batch ----------------------

class _T:
    def __init__(self, txid, writes):
        self.txid = txid
        self.write_set = {w: 1.0 for w in writes}


def test_mutant_stale_write_locks_input():
    owners = np.array([0, 1], np.int32)         # cc=1 leased to proc 1
    item_cc = np.array([0, 1, 1], np.int32)
    stale = np.zeros(3, np.int32)               # the bug: locks not refreshed
    with pytest.raises(SanitizerError) as e:
        check_write_locks(0, owners, item_cc, stale, [], [])
    assert e.value.invariant == "write-locks"
    assert "stale" in e.value.detail


def test_mutant_certified_write_to_leased_away_item():
    owners = np.array([0, 1], np.int32)
    item_cc = np.array([0, 1, 1], np.int32)
    with pytest.raises(SanitizerError) as e:
        # the bug: verdict True for a txn writing item 2 (leased to proc 1)
        check_write_locks(0, owners, item_cc, None,
                          [_T(7, [2])], [True])
    assert e.value.invariant == "write-locks"
    assert "txn 7" in e.value.detail


# -- mutant 6: recycled sid resurrects an old epoch --------------------------

def test_mutant_recycled_sid_resurrection():
    c = StepCertifier(2, sanitize=True)
    c.bump(5, 7)
    with pytest.raises(SanitizerError) as e:
        c.bump(5, 3)   # the bug: a recycled sid restarts below its tombstone
    assert e.value.invariant == "epoch-monotonicity"


# -- mutant 7: UR-free of a live (unblocked, active) lease -------------------

@pytest.mark.parametrize("kind", ["oracle", "sharded"])
def test_mutant_free_active_lease(kind):
    lm = _mgr(kind, proc=0)
    lors = lm.on_to_deliver(_req(1, 0, (2, 3)))
    with pytest.raises(SanitizerError) as e:
        lm.on_ur_deliver_freed([lors[0].key()])   # never blocked nor drained
    assert e.value.invariant == "blocked-and-drained"


# -- mutant 8: forged free for a never-granted LOR ---------------------------

def test_mutant_forged_free():
    lm = _mgr("oracle", proc=0)
    lm.on_to_deliver(_req(1, 0, (2,)))
    with pytest.raises(SanitizerError) as e:
        lm.on_ur_deliver_freed([(99, 1, (5,))])
    assert e.value.invariant == "conservation"


# -- mutant 9: vectorized enablement diverges from the oracle ----------------

def test_mutant_enabled_mask_divergence():
    lm = _mgr("sharded", proc=0)
    g1 = lm.on_to_deliver(_req(1, 0, (1,)))
    lm.on_to_deliver(_req(2, 1, (2,)))
    g2 = lm.on_to_deliver(_req(3, 0, (2,)))     # queued behind proc 1
    inner = lm.inner
    orig = inner.enabled_mask
    # the bug: a settle-kernel defect flips the packed verdicts
    inner.enabled_mask = lambda groups: [not v for v in orig(groups)]
    with pytest.raises(SanitizerError) as e:
        lm.enabled_mask([g1, g2])
    assert e.value.invariant == "enabled-divergence"
