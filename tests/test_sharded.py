"""Multi-device SPMD correctness, run in a subprocess with 8 host devices.

(The main pytest process must keep seeing 1 device — the brief forbids
forcing the device count globally — so these tests exec a child python
with ``XLA_FLAGS=--xla_force_host_platform_device_count=8``.)
"""
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

SRC = str(Path(__file__).resolve().parents[1] / "src")


def _run(code: str) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env, timeout=600)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


def test_moe_sharded_matches_ref():
    _run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh
        from repro.configs import get_smoke_config
        from repro.models import moe
        from repro.models.common import init_params, moe_shapes
        import dataclasses

        cfg = dataclasses.replace(get_smoke_config("mixtral-8x7b"), dtype="float32")
        m = cfg.moe
        mesh = Mesh(np.array(jax.devices()).reshape(2, 4), ("data", "model"))
        rng = np.random.default_rng(0)
        d, f = cfg.d_model, m.d_expert
        router = jnp.asarray(rng.standard_normal((d, m.n_experts)) * 0.1, jnp.float32)
        wg = jnp.asarray(rng.standard_normal((m.n_experts, d, f)) * 0.05, jnp.float32)
        wu = jnp.asarray(rng.standard_normal((m.n_experts, d, f)) * 0.05, jnp.float32)
        wd = jnp.asarray(rng.standard_normal((m.n_experts, f, d)) * 0.05, jnp.float32)
        x = jnp.asarray(rng.standard_normal((4, 16, d)), jnp.float32)

        p_ref = {"router": router, "experts": {
            "w_gate": wg[None], "w_up": wu[None], "w_down": wd[None]}}
        y_ref = moe.moe_ref(p_ref, x, cfg)

        cg, cu, cdn = moe.to_chunked(wg, wu, wd, model_size=4)
        p_sh = {"router": router, "experts": {"w_gate": cg, "w_up": cu, "w_down": cdn}}
        with mesh:
            y_sh = moe.moe_sharded(p_sh, x, cfg, mesh, batch_axes=("data",),
                                   capacity_factor=8.0)  # no drops
        err = float(jnp.max(jnp.abs(y_sh - y_ref)))
        scale = float(jnp.max(jnp.abs(y_ref))) + 1e-9
        assert err / scale < 2e-4, (err, scale)
        print("MOE OK", err / scale)
    """)


def test_moe_a2a_matches_ref_and_autotune_picks_it():
    _run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh
        from repro.configs import get_smoke_config
        from repro.models import moe
        import dataclasses

        cfg = dataclasses.replace(get_smoke_config("mixtral-8x7b"), dtype="float32")
        m = cfg.moe
        mesh = Mesh(np.array(jax.devices()).reshape(2, 4), ("data", "model"))
        rng = np.random.default_rng(1)
        d, f = cfg.d_model, m.d_expert
        router = jnp.asarray(rng.standard_normal((d, m.n_experts)) * 0.1, jnp.float32)
        wg = jnp.asarray(rng.standard_normal((m.n_experts, d, f)) * 0.05, jnp.float32)
        wu = jnp.asarray(rng.standard_normal((m.n_experts, d, f)) * 0.05, jnp.float32)
        wd = jnp.asarray(rng.standard_normal((m.n_experts, f, d)) * 0.05, jnp.float32)
        x = jnp.asarray(rng.standard_normal((4, 16, d)), jnp.float32)

        p_ref = {"router": router, "experts": {
            "w_gate": wg[None], "w_up": wu[None], "w_down": wd[None]}}
        y_ref = moe.moe_ref(p_ref, x, cfg)
        cg, cu, cdn = moe.to_chunked(wg, wu, wd, model_size=4)
        p_sh = {"router": router, "experts": {"w_gate": cg, "w_up": cu, "w_down": cdn}}
        with mesh:
            y_a2a = moe.moe_sharded_a2a(p_sh, x, cfg, mesh, batch_axes=("data",),
                                        capacity_factor=8.0)
            y_auto = moe.moe_apply(p_sh, x, cfg, mesh, batch_axes=("data",),
                                   capacity_factor=8.0)
        scale = float(jnp.max(jnp.abs(y_ref))) + 1e-9
        assert float(jnp.max(jnp.abs(y_a2a - y_ref))) / scale < 2e-4
        # the autotuner consulted the priced verdict: serving-size batches
        # prefer token a2a, and the cell verdict is cached
        assert float(jnp.max(jnp.abs(y_auto - y_ref))) / scale < 2e-4
        (key,) = moe._DISPATCH_CACHE
        assert moe._DISPATCH_CACHE[key] is True and key[:2] == (8, 4)
        # the same cell never reprices: verdict comes from the cache
        assert moe.dispatch_verdict(cfg, 8, 4) is True
        # token traffic scales with batch, weight traffic doesn't: the
        # verdict flips to the replicated-token path at large batch
        assert moe.dispatch_verdict(cfg, 10_000, 4) is False
        print("MOE A2A OK")
    """)


def test_moe_a2a_tp_chunks_match_dense_reference():
    """tp-aware a2a: mixtral-style (ep=4, tp=1) must be bitwise against the
    dense reference; deepseek-style (model_size > n_experts → ep=2, tp=2)
    dispatches to expert chunks and psums the f-slice partials on the
    combine leg — same math as the reference modulo one float
    reassociation across the psum tree, so the band is float32-tight."""
    _run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh
        from repro.models import moe
        from repro.models.common import ModelConfig, MoEConfig, chunk_plan

        mesh = Mesh(np.array(jax.devices()).reshape(2, 4), ("data", "model"))
        rng = np.random.default_rng(2)
        for style, mc, want_ep, want_tp in (
                ("mixtral", MoEConfig(n_experts=8, top_k=2, d_expert=64), 4, 1),
                ("deepseek", MoEConfig(n_experts=2, top_k=2, d_expert=128), 2, 2)):
            cfg = ModelConfig(name=style, family="moe", n_layers=1,
                              d_model=32, n_heads=2, n_kv_heads=2,
                              head_dim=16, d_ff=64, vocab_size=64,
                              dtype="float32", moe=mc)
            assert chunk_plan(mc.n_experts, 4)[:2] == (want_ep, want_tp)
            d, f = cfg.d_model, mc.d_expert
            router = jnp.asarray(rng.standard_normal((d, mc.n_experts)) * 0.1,
                                 jnp.float32)
            wg = jnp.asarray(rng.standard_normal((mc.n_experts, d, f)) * 0.05,
                             jnp.float32)
            wu = jnp.asarray(rng.standard_normal((mc.n_experts, d, f)) * 0.05,
                             jnp.float32)
            wd = jnp.asarray(rng.standard_normal((mc.n_experts, f, d)) * 0.05,
                             jnp.float32)
            rg, ru, rd = moe.to_chunked(wg, wu, wd, model_size=1)
            p_ref = {"router": router,
                     "experts": {"w_gate": rg, "w_up": ru, "w_down": rd}}
            cg, cu, cdn = moe.to_chunked(wg, wu, wd, model_size=4)
            p_sh = {"router": router,
                    "experts": {"w_gate": cg, "w_up": cu, "w_down": cdn}}
            x = jnp.asarray(rng.standard_normal((8, 16, d)), jnp.float32)
            y_ref = moe.moe_ref(p_ref, x, cfg)
            with mesh:
                y = moe.moe_apply(p_sh, x, cfg, mesh, dispatch="a2a",
                                  batch_axes=("data",), capacity_factor=8.0)
            err = float(jnp.max(jnp.abs(y - y_ref)))
            if want_tp == 1:
                assert err == 0.0, (style, err)      # bitwise: no psum leg
            else:
                scale = float(jnp.max(jnp.abs(y_ref))) + 1e-9
                assert err / scale < 1e-6, (style, err, scale)
            print(style, "TP CHUNK OK", err)
        print("MOE A2A TP OK")
    """)


def test_moe_a2a_ragged_tokens_pad_not_fallback():
    """Regression: a ragged token count (not a multiple of the shard grid)
    used to silently fall back to the dense path; now the a2a plan pads the
    flattened token axis to the next shard multiple and masks the pad rows
    out of dispatch, so the forced-a2a result still matches the dense
    reference exactly (tp=1 layout)."""
    _run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh
        from repro.configs import get_smoke_config
        from repro.models import moe
        import dataclasses

        cfg = dataclasses.replace(get_smoke_config("mixtral-8x7b"),
                                  dtype="float32")
        m = cfg.moe
        mesh = Mesh(np.array(jax.devices()).reshape(2, 4), ("data", "model"))
        # 4 x 15 = 60 tokens: not a multiple of the 8-way shard grid
        shards, ep, tp, t_pad = moe._a2a_plan(cfg, 60, mesh, ("data",),
                                              "model")
        assert (shards, t_pad) == (8, 64) and t_pad % shards == 0
        rng = np.random.default_rng(3)
        d, f = cfg.d_model, m.d_expert
        router = jnp.asarray(rng.standard_normal((d, m.n_experts)) * 0.1,
                             jnp.float32)
        wg = jnp.asarray(rng.standard_normal((m.n_experts, d, f)) * 0.05,
                         jnp.float32)
        wu = jnp.asarray(rng.standard_normal((m.n_experts, d, f)) * 0.05,
                         jnp.float32)
        wd = jnp.asarray(rng.standard_normal((m.n_experts, f, d)) * 0.05,
                         jnp.float32)
        p_ref = {"router": router, "experts": {
            "w_gate": wg[None], "w_up": wu[None], "w_down": wd[None]}}
        cg, cu, cdn = moe.to_chunked(wg, wu, wd, model_size=4)
        p_sh = {"router": router,
                "experts": {"w_gate": cg, "w_up": cu, "w_down": cdn}}
        x = jnp.asarray(rng.standard_normal((4, 15, d)), jnp.float32)
        y_ref = moe.moe_ref(p_ref, x, cfg)
        with mesh:
            y = moe.moe_apply(p_sh, x, cfg, mesh, dispatch="a2a",
                              batch_axes=("data",), capacity_factor=8.0)
        err = float(jnp.max(jnp.abs(y - y_ref)))
        assert y.shape == y_ref.shape == (4, 15, d)
        assert err == 0.0, err
        print("MOE A2A RAGGED OK")
    """)


def test_sharded_train_step_matches_single_device():
    _run("""
        import jax, jax.numpy as jnp, numpy as np, dataclasses
        from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
        from repro.configs import get_smoke_config
        from repro.models import decoder
        from repro.models.common import init_params, param_shapes
        from repro.dist import sharding as shd
        from repro.train.train_step import make_train_step, TrainConfig
        from repro.train import optimizer as opt

        cfg = dataclasses.replace(get_smoke_config("glm4-9b"), dtype="float32")
        key = jax.random.PRNGKey(0)
        params = init_params(cfg, key)
        opt_state = opt.init(params)
        batch = {
            "tokens": jax.random.randint(key, (8, 32), 0, cfg.vocab_size),
            "labels": jax.random.randint(key, (8, 32), 0, cfg.vocab_size),
        }
        # single device
        ctx1 = decoder.RunCtx(mesh=None, use_kernel="ref")
        s1 = make_train_step(cfg, ctx1, TrainConfig())
        p1, o1, m1 = jax.jit(s1)(params, opt_state, batch)

        # 8-device mesh with full sharding rules
        mesh = Mesh(np.array(jax.devices()).reshape(4, 2), ("data", "model"))
        ctx8 = decoder.RunCtx(mesh=mesh, batch_axes=("data",), use_kernel="ref")
        pspec = shd.param_shardings(cfg, mesh)
        p_sh = jax.tree.map(jax.device_put, params, pspec)
        o_sh = opt.OptState(
            m=jax.tree.map(jax.device_put, opt_state.m, pspec),
            v=jax.tree.map(jax.device_put, opt_state.v, pspec),
            count=opt_state.count)
        bspec = NamedSharding(mesh, P("data", None))
        b_sh = {k: jax.device_put(v, bspec) for k, v in batch.items()}
        s8 = make_train_step(cfg, ctx8, TrainConfig())
        p8, o8, m8 = jax.jit(s8)(p_sh, o_sh, b_sh)

        assert abs(float(m1["loss"]) - float(m8["loss"])) < 1e-4, (m1, m8)
        for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p8)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=3e-5, rtol=3e-4)
        print("TRAIN SPMD OK", float(m1["loss"]))
    """)


def test_compressed_psum_shard_map():
    _run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh, PartitionSpec as P
        from jax.experimental.shard_map import shard_map
        from repro.train.compression import compressed_psum

        mesh = Mesh(np.array(jax.devices()).reshape(8,), ("data",))
        rng = np.random.default_rng(0)
        g = jnp.asarray(rng.standard_normal((8, 128)) * 0.01, jnp.float32)
        res = jnp.zeros((8, 128), jnp.float32)

        def body(g, r):
            out, new_r = compressed_psum(g[0], r[0], "data")
            return out[None], new_r[None]

        out, new_res = shard_map(body, mesh=mesh,
                                 in_specs=(P("data", None), P("data", None)),
                                 out_specs=(P("data", None), P("data", None)),
                                 check_rep=False)(g, res)
        true_mean = np.asarray(g).mean(axis=0)
        got = np.asarray(out)[0]
        np.testing.assert_allclose(got, true_mean, atol=5e-4)
        # every shard sees the same mean
        for i in range(8):
            np.testing.assert_allclose(np.asarray(out)[i], got, atol=1e-7)
        print("COMPRESSED PSUM OK")
    """)


def test_seq_sharded_decode_matches_unsharded():
    """Long-context layout: decode over a seq-sharded KV cache must match
    the single-device reference bit-for-tolerance, for both GQA (with a
    model axis for kv heads) and MLA (latent cache), and the output caches
    must land with the seq axis in their sharding spec."""
    _run("""
        import dataclasses
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh
        from repro.configs import get_smoke_config
        from repro.dist import sharding as shd
        from repro.models import decoder
        from repro.models.common import init_params

        def leaf_of(c):
            return jax.tree.leaves(c["body"][0]["attn"])[0]

        for arch, mesh_shape in (("glm4-9b", (1, 4, 2)),
                                 ("deepseek-v2-236b", (2, 4, 1))):
            cfg = dataclasses.replace(get_smoke_config(arch), dtype="float32")
            params = init_params(cfg, jax.random.PRNGKey(0))
            toks = jnp.arange(4, dtype=jnp.int32)

            ctx1 = decoder.RunCtx(mesh=None, use_kernel="ref")
            c1 = decoder.init_cache(cfg, 4, 32, jnp.float32)
            step1 = jax.jit(lambda p, c, t, i:
                            decoder.decode_step(cfg, ctx1, p, c, t, i))
            ref, c1 = step1(params, c1, toks, jnp.asarray(0, jnp.int32))

            mesh = Mesh(np.array(jax.devices()).reshape(mesh_shape),
                        ("data", "seq", "model"))
            ctx8 = decoder.RunCtx(mesh=mesh, batch_axes=("data",),
                                  use_kernel="ref", seq_axis="seq")
            c8 = decoder.init_cache(cfg, 4, 32, jnp.float32)
            c8 = jax.device_put(c8, shd.cache_shardings(cfg, mesh, c8, 4))
            step8 = jax.jit(lambda p, c, t, i:
                            decoder.decode_step(cfg, ctx8, p, c, t, i))
            with mesh:
                out, c8 = step8(params, c8, toks, jnp.asarray(0, jnp.int32))
                nxt = jnp.argmax(out, -1).astype(jnp.int32)
                out2, c8 = step8(params, c8, nxt, jnp.asarray(1, jnp.int32))
            ref2, c1 = step1(params, c1, jnp.argmax(ref, -1).astype(jnp.int32),
                             jnp.asarray(1, jnp.int32))
            err = float(jnp.max(jnp.abs(out2 - ref2)))
            assert err < 2e-4, (arch, err)
            assert "seq" in str(leaf_of(c8).sharding.spec), leaf_of(c8).sharding
            print(arch, "SEQ DECODE OK", err)
        print("SEQ SPMD OK")
    """)


def test_seq_sharded_migrate_roundtrip():
    """Export/import a session between two KVStores on a seq-bearing mesh:
    the imported column decodes identically and lands seq-sharded, and the
    store reports the seq_shards the pricing consumes."""
    _run("""
        import dataclasses
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh
        from repro.configs import get_smoke_config
        from repro.models import decoder
        from repro.models.common import init_params
        from repro.serve.kvcache import KVStore

        cfg = dataclasses.replace(get_smoke_config("glm4-9b"), dtype="float32")
        mesh = Mesh(np.array(jax.devices()).reshape(1, 8, 1),
                    ("data", "seq", "model"))
        ctx = decoder.RunCtx(mesh=mesh, batch_axes=("data",),
                             use_kernel="ref", seq_axis="seq")
        params = init_params(cfg, jax.random.PRNGKey(0))
        src = KVStore(cfg, 4, 64, jnp.float32, mesh=mesh)
        dst = KVStore(cfg, 4, 64, jnp.float32, mesh=mesh)
        assert src.seq_shards == 8, src.seq_shards
        s = src.alloc(42)
        tok = jnp.zeros((4,), jnp.int32)
        pos = jnp.zeros((4,), jnp.int32)
        step = jax.jit(lambda p, c, t, i:
                       decoder.decode_step(cfg, ctx, p, c, t, i))
        with mesh:
            for _ in range(3):
                logits, src.caches = step(params, src.caches, tok, pos)
                tok = jnp.argmax(logits, -1).astype(jnp.int32)
                pos = pos + 1
            s.length, s.last_token = 3, int(tok[s.slot])
            logits_src, _ = step(params, src.caches, tok, pos)

            blob = src.export_session(42)
            assert blob["seq_shards"] == 8
            dst.alloc(7)                      # force a different slot
            s2 = dst.import_session(blob)
            # imported column landed per the ledger: seq axis in the spec
            k = dst.caches["body"][0]["attn"]["k"]
            assert "seq" in str(k.sharding.spec), k.sharding
            tok2 = jnp.zeros((4,), jnp.int32).at[s2.slot].set(s.last_token)
            logits_dst, _ = step(params, dst.caches, tok2,
                                 jnp.full((4,), 3, jnp.int32))
        np.testing.assert_allclose(
            np.asarray(logits_dst[s2.slot]), np.asarray(logits_src[s.slot]),
            rtol=1e-4, atol=1e-4)

        # a cache with nothing to seq-shard must not claim parallel hops:
        # the mamba state has no seq dim, so pricing sees seq_shards == 1
        mcfg = dataclasses.replace(get_smoke_config("mamba2-780m"),
                                   dtype="float32")
        mst = KVStore(mcfg, 4, 64, jnp.float32, mesh=mesh)
        assert mst.seq_shards == 1, mst.seq_shards
        print("SEQ MIGRATE OK")
    """)


def test_decode_step_sharded_lowers_and_runs():
    _run("""
        import jax, jax.numpy as jnp, numpy as np, dataclasses
        from jax.sharding import Mesh
        from repro.configs import get_smoke_config
        from repro.models import decoder
        from repro.models.common import init_params
        from repro.dist import sharding as shd

        cfg = dataclasses.replace(get_smoke_config("deepseek-v2-236b"), dtype="float32")
        mesh = Mesh(np.array(jax.devices()).reshape(2, 4), ("data", "model"))
        ctx = decoder.RunCtx(mesh=mesh, batch_axes=("data",), use_kernel="ref")
        params = init_params(cfg, jax.random.PRNGKey(0), model_size=4)
        caches = decoder.init_cache(cfg, 8, 32, jnp.float32)
        toks = jnp.zeros((8,), jnp.int32)
        with mesh:
            logits, caches = jax.jit(
                lambda p, c, t: decoder.decode_step(cfg, ctx, p, c, t,
                                                     jnp.asarray(4, jnp.int32))
            )(params, caches, toks)
        assert np.isfinite(np.asarray(logits, np.float32)).all()
        print("DECODE SPMD OK")
    """)
