"""DTD tests: SC/LC cost formulas, the O(n) solve, numpy/jit agreement."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.dtd import (C_AB, C_P2P, C_URB, long_term_costs,
                            long_term_costs_np, short_term_costs,
                            short_term_costs_np, solve, solve_np)


def test_sc_four_cases():
    lease = np.array([[1, 1], [1, 0], [0, 0], [1, 1]], np.float32)
    cpu = np.zeros(4)
    c = short_term_costs_np(lease, cpu, origin=0, max_cpu=0.9, overload_ctrl=True)
    assert c[0] == C_URB                               # origin owns all
    assert c[1] == C_P2P + C_AB + 2 * C_URB            # remote, missing leases
    assert c[2] == C_P2P + C_AB + 2 * C_URB
    assert c[3] == C_P2P + C_URB                       # remote, owns all
    c2 = short_term_costs_np(lease, cpu, origin=1, max_cpu=0.9, overload_ctrl=True)
    assert c2[1] == C_AB + 2 * C_URB                   # origin, missing leases


def test_lc_formula():
    freq = np.array([[5.0, 1.0], [0.0, 2.0], [1.0, 1.0]])
    c = long_term_costs_np(freq, np.zeros(3), 0.9, True)
    total = freq.sum()
    for i in range(3):
        assert c[i] == pytest.approx(total - freq[i].sum())


def test_overload_constraint_excludes_node():
    lease = np.ones((3, 2), np.float32)
    cpu = np.array([0.2, 0.95, 0.2])
    c = short_term_costs_np(lease, cpu, 0, 0.85, True)
    assert np.isinf(c[1])
    assert solve_np(c, origin=0) == 0


def test_all_overloaded_falls_back_to_origin():
    c = np.array([np.inf, np.inf, np.inf])
    assert solve_np(c, origin=2) == 2


def test_tie_break_rendezvous_consistent():
    c = np.array([1.0, 1.0, 5.0, 1.0])
    picks = {solve_np(c, origin=o, tie_node=7) for o in range(4)}
    assert len(picks) == 1                          # all origins agree
    assert solve_np(c, origin=0, tie_node=-1) == 0  # origin preferred if tied


@settings(max_examples=60, deadline=None)
@given(
    n=st.integers(2, 8),
    s=st.integers(1, 5),
    origin=st.integers(0, 7),
    seed=st.integers(0, 2**31 - 1),
    ctrl=st.booleans(),
)
def test_np_matches_jit(n, s, origin, seed, ctrl):
    rng = np.random.default_rng(seed)
    origin = origin % n
    lease = (rng.random((n, s)) < 0.5).astype(np.float32)
    freq = rng.random((n, s)).astype(np.float32) * 3
    cpu = rng.random(n).astype(np.float32)
    a = short_term_costs_np(lease, cpu, origin, 0.85, ctrl)
    b = np.asarray(short_term_costs(lease, cpu, np.int32(origin), 0.85, ctrl))
    np.testing.assert_allclose(a, b, rtol=1e-6)
    a = long_term_costs_np(freq, cpu, 0.85, ctrl)
    b = np.asarray(long_term_costs(freq, cpu, 0.85, ctrl))
    np.testing.assert_allclose(a, b, rtol=1e-5)


@settings(max_examples=60, deadline=None)
@given(n=st.integers(1, 8), seed=st.integers(0, 2**31 - 1),
       tie=st.integers(-1, 12))
def test_solve_optimality(n, seed, tie):
    rng = np.random.default_rng(seed)
    costs = rng.random(n)
    costs[rng.random(n) < 0.3] = np.inf
    origin = int(rng.integers(n))
    pick = solve_np(costs, origin, tie)
    jpick = int(np.asarray(solve(costs, np.int32(origin),
                                 np.int32(tie))))
    if np.isfinite(costs).any():
        best = np.min(costs[np.isfinite(costs)])
        assert costs[pick] <= best + 1e-9           # picked an argmin
        assert costs[jpick] <= best + 1e-9
    else:
        assert pick == origin and jpick == origin
