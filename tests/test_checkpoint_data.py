"""Checkpoint (atomicity, async, prune, elastic) + data-pipeline tests."""
import os
import shutil
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import DataConfig, SyntheticLM
from repro.train import checkpoint as ck
from repro.train import compression as comp
from repro.train import elastic


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "a": jax.random.normal(k, (8, 16)),
        "b": {"c": jnp.arange(10, dtype=jnp.int32), "d": jnp.float32(3.5)},
    }


def test_save_restore_roundtrip(tmp_path):
    t = _tree()
    ck.save(tmp_path, 7, t)
    got, step = ck.restore(tmp_path, t)
    assert step == 7
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_uncommitted_checkpoint_ignored(tmp_path):
    t = _tree()
    ck.save(tmp_path, 1, t)
    # simulate a crash between phase 1 and 2 of a later save
    d = tmp_path / "step_00000002"
    d.mkdir()
    (d / "manifest.json").write_text("{}")      # incomplete, no marker
    assert ck.latest_step(tmp_path) == 1
    got, step = ck.restore(tmp_path, t)
    assert step == 1


def test_prune_keeps_latest(tmp_path):
    t = _tree()
    for s in (1, 2, 3, 4, 5):
        ck.save(tmp_path, s, t)
    ck.prune(tmp_path, keep=2)
    assert ck.committed_steps(tmp_path) == [4, 5]


def test_async_checkpointer(tmp_path):
    t = _tree()
    w = ck.AsyncCheckpointer(tmp_path, keep=2)
    for s in (10, 20):
        w.submit(s, jax.tree.map(lambda x: x + s, t))
    w.close()
    got, step = ck.restore(tmp_path, t)
    assert step == 20
    np.testing.assert_allclose(np.asarray(got["b"]["d"]), 23.5)


def test_elastic_plan_and_restore(tmp_path):
    t = _tree()
    ck.save(tmp_path, 3, t)
    plan = elastic.plan_remesh(n_survivors=1, model_size=1)
    assert plan.mesh_shape == (1, 1)
    mesh = elastic.remesh(jax.devices(), plan)

    def make_shardings(mesh):
        sh = jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())
        return jax.tree.map(lambda _: sh, t)

    state, step, mesh = elastic.resume_after_failure(
        tmp_path, t, jax.devices(), model_size=1, make_shardings=make_shardings)
    assert step == 3
    np.testing.assert_array_equal(np.asarray(state["a"]), np.asarray(t["a"]))


def test_plan_remesh_preserves_tp_groups():
    p = elastic.plan_remesh(n_survivors=24, model_size=8)
    assert p.mesh_shape == (3, 8)
    assert p.dropped == 0
    p = elastic.plan_remesh(n_survivors=6, model_size=8)
    assert p.mesh_shape[1] <= 6 and p.n_devices <= 6


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_data_determinism_and_skip_ahead():
    cfg = DataConfig(vocab_size=512, seq_len=32, global_batch=8, seed=1)
    a, b = SyntheticLM(cfg), SyntheticLM(cfg)
    np.testing.assert_array_equal(a.batch(5)["tokens"], b.batch(5)["tokens"])
    assert not np.array_equal(a.batch(5)["tokens"], a.batch(6)["tokens"])


def test_data_host_slicing_differs():
    base = dict(vocab_size=512, seq_len=16, global_batch=8, seed=1, n_hosts=2)
    h0 = SyntheticLM(DataConfig(**base, host_id=0)).batch(3)
    h1 = SyntheticLM(DataConfig(**base, host_id=1)).batch(3)
    assert h0["tokens"].shape == (4, 16)
    assert not np.array_equal(h0["tokens"], h1["tokens"])


def test_labels_are_shifted_tokens():
    cfg = DataConfig(vocab_size=512, seq_len=16, global_batch=4, seed=0)
    b = SyntheticLM(cfg).batch(0)
    np.testing.assert_array_equal(b["labels"][:, :-1], b["tokens"][:, 1:])
    assert (b["labels"][:, -1] == -1).all()


# ---------------------------------------------------------------------------
# gradient compression
# ---------------------------------------------------------------------------

def test_compression_error_feedback_converges():
    """Accumulated dequantized sums track the true sums (error feedback)."""
    rng = np.random.default_rng(0)
    g_stream = [jnp.asarray(rng.standard_normal(256) * 0.01, jnp.float32)
                for _ in range(50)]
    res = jnp.zeros(256, jnp.float32)
    acc = jnp.zeros(256, jnp.float32)
    for g in g_stream:
        c, res = comp.compress(g, res)
        acc = acc + comp.decompress(c)
    true = sum(np.asarray(g) for g in g_stream)
    # residual carries at most one step's quantization error
    err = np.abs(np.asarray(acc) - true).max()
    assert err < 2 * float(np.abs(np.asarray(res)).max() + 1e-6) + 1e-3


def test_compression_wire_dtype_is_int8():
    c, _ = comp.compress(jnp.ones(16) * 0.5, jnp.zeros(16))
    assert c.q.dtype == jnp.int8
    np.testing.assert_allclose(np.asarray(comp.decompress(c)), 0.5, rtol=1e-2)
