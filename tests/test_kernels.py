"""Kernel allclose sweeps (interpret=True) against the pure-jnp oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.lease_validate import lease_validate
from repro.kernels.ssd_scan import ssd_scan

RNG = np.random.default_rng(0)


@pytest.mark.parametrize(
    "b,sq,skv,hq,hkv,dk,dv,causal,window,cap,dtype",
    [
        (2, 128, 128, 4, 2, 32, 32, True, None, 0.0, jnp.float32),
        (1, 100, 100, 4, 4, 16, 16, True, None, 0.0, jnp.float32),
        (2, 128, 128, 4, 2, 32, 32, True, 40, 0.0, jnp.float32),
        (2, 64, 192, 4, 2, 32, 32, True, None, 0.0, jnp.float32),   # cache
        (2, 128, 128, 4, 4, 32, 32, False, None, 0.0, jnp.float32),  # encoder
        (2, 128, 128, 8, 2, 64, 64, True, None, 30.0, jnp.bfloat16),
        (1, 256, 256, 2, 2, 192, 128, True, None, 0.0, jnp.float32),  # MLA dims
        (1, 72, 72, 2, 1, 24, 24, True, 16, 0.0, jnp.float32),  # odd sizes
    ],
)
def test_flash_attention_vs_ref(b, sq, skv, hq, hkv, dk, dv, causal, window,
                                cap, dtype):
    q = jnp.asarray(RNG.standard_normal((b, sq, hq, dk)), dtype)
    k = jnp.asarray(RNG.standard_normal((b, skv, hkv, dk)), dtype)
    v = jnp.asarray(RNG.standard_normal((b, skv, hkv, dv)), dtype)
    qp = jnp.broadcast_to(jnp.arange(skv - sq, skv, dtype=jnp.int32)[None], (b, sq))
    kp = jnp.broadcast_to(jnp.arange(skv, dtype=jnp.int32)[None], (b, skv))
    out = flash_attention(q, k, v, q_positions=qp, kv_positions=kp,
                          causal=causal, sliding_window=window,
                          logit_softcap=cap, block_q=64, block_k=64)
    want = ref.sdpa_ref(q, k, v, q_positions=qp, kv_positions=kp,
                        causal=causal, sliding_window=window, logit_softcap=cap)
    tol = 5e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), atol=tol, rtol=tol)


@pytest.mark.parametrize(
    "b,s,h,p,n,chunk,hb",
    [
        (2, 256, 8, 16, 32, 64, 4),
        (1, 128, 16, 64, 128, 32, 8),
        (2, 512, 48, 64, 128, 256, 8),
        (1, 64, 4, 32, 16, 64, 4),       # single chunk
    ],
)
def test_ssd_scan_vs_ref(b, s, h, p, n, chunk, hb):
    x = jnp.asarray(RNG.standard_normal((b, s, h, p)) * 0.5, jnp.float32)
    dt = jax.nn.softplus(jnp.asarray(RNG.standard_normal((b, s, h)), jnp.float32))
    a = -jnp.exp(jnp.asarray(RNG.standard_normal((h,)) * 0.3, jnp.float32))
    bm = jnp.asarray(RNG.standard_normal((b, s, 1, n)) * 0.4, jnp.float32)
    cm = jnp.asarray(RNG.standard_normal((b, s, 1, n)) * 0.4, jnp.float32)
    h0 = jnp.asarray(RNG.standard_normal((b, h, p, n)) * 0.1, jnp.float32)
    y_k, f_k = ssd_scan(x, dt, a, bm, cm, chunk=chunk, h0=h0, block_heads=hb)
    y_r, f_r = ref.ssd_ref(x, dt, a, bm, cm, chunk=chunk, h0=h0)
    scale = float(jnp.max(jnp.abs(y_r))) + 1e-9
    assert float(jnp.max(jnp.abs(y_k - y_r))) / scale < 2e-5
    np.testing.assert_allclose(np.asarray(f_k), np.asarray(f_r),
                               atol=2e-3, rtol=1e-4)


def test_ssd_decode_recurrence_matches_scan():
    """Recurrent single steps replayed == chunked scan on the same stream."""
    from repro.models.ssm import ssd_recurrent_step
    b, s, h, p, n = 1, 32, 4, 8, 16
    x = jnp.asarray(RNG.standard_normal((b, s, h, p)) * 0.5, jnp.float32)
    dt = jax.nn.softplus(jnp.asarray(RNG.standard_normal((b, s, h)), jnp.float32))
    a = -jnp.exp(jnp.asarray(RNG.standard_normal((h,)) * 0.3, jnp.float32))
    bm = jnp.asarray(RNG.standard_normal((b, s, 1, n)) * 0.4, jnp.float32)
    cm = jnp.asarray(RNG.standard_normal((b, s, 1, n)) * 0.4, jnp.float32)
    y_scan, _ = ref.ssd_ref(x, dt, a, bm, cm, chunk=16)
    hstate = jnp.zeros((b, h, p, n), jnp.float32)
    outs = []
    for t in range(s):
        y_t, hstate = ssd_recurrent_step(
            hstate, x[:, t], dt[:, t], a, bm[:, t], cm[:, t])
        outs.append(y_t)
    y_rec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y_rec), np.asarray(y_scan),
                               atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("B,R,W,n_items,chunk,bt", [
    (64, 8, 4, 1024, 256, 32),
    (200, 16, 8, 5000, 512, 64),
    (7, 3, 2, 100, 64, 8),
])
def test_lease_validate_vs_ref(B, R, W, n_items, chunk, bt):
    store = jnp.asarray(RNG.integers(0, 50, n_items), jnp.int32)
    locks = jnp.asarray(RNG.random(n_items) < 0.05, jnp.int32)
    items = jnp.asarray(RNG.integers(-1, n_items, (B, R)), jnp.int32)
    vers = jnp.where(jnp.asarray(RNG.random((B, R)) < 0.8),
                     store[jnp.clip(items, 0, n_items - 1)],
                     jnp.asarray(RNG.integers(0, 50, (B, R)), jnp.int32))
    witems = jnp.asarray(RNG.integers(-1, n_items, (B, W)), jnp.int32)
    got = lease_validate(store, items, vers, locks, witems,
                         block_txns=bt, chunk=chunk)
    want = ref.lease_validate_ref(store, items, vers, locks > 0, witems)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("B,R,W,n_items", [(32, 8, 4, 512), (8, 4, 2, 64)])
def test_validate_transactions_backends_agree(B, R, W, n_items):
    """ops.validate_transactions: the dispatch point's pallas(interpret)
    and jit'd-ref paths agree bitwise, locks honored on both."""
    from repro.kernels.ops import validate_transactions
    store = jnp.asarray(RNG.integers(0, 40, n_items), jnp.int32)
    locks = jnp.asarray(RNG.random(n_items) < 0.1, jnp.int32)
    items = jnp.asarray(RNG.integers(-1, n_items, (B, R)), jnp.int32)
    vers = jnp.where(jnp.asarray(RNG.random((B, R)) < 0.8),
                     store[jnp.clip(items, 0, n_items - 1)],
                     jnp.asarray(RNG.integers(0, 40, (B, R)), jnp.int32))
    witems = jnp.asarray(RNG.integers(-1, n_items, (B, W)), jnp.int32)
    kern = validate_transactions(store, items, vers, write_locks=locks,
                                 write_items=witems, backend="pallas")
    ref_out = validate_transactions(store, items, vers, write_locks=locks,
                                    write_items=witems, backend="jnp")
    want = ref.lease_validate_ref(store, items, vers, locks > 0, witems)
    np.testing.assert_array_equal(np.asarray(kern), np.asarray(want))
    np.testing.assert_array_equal(np.asarray(ref_out), np.asarray(want))
    # lock-free default: all-zero locks
    base = validate_transactions(store, items, vers, backend="jnp")
    want_nolock = ref.lease_validate_ref(
        store, items, vers, jnp.zeros_like(store) > 0,
        jnp.full((B, 1), -1, jnp.int32))
    np.testing.assert_array_equal(np.asarray(base), np.asarray(want_nolock))


def test_stm_batched_validation_matches_kernel():
    """The STM's jnp batched validation, the kernel, and the python loop agree."""
    from repro.core.stm import Transaction, VersionedStore, pack_read_sets, validate_batch
    store = VersionedStore(500)
    rng = np.random.default_rng(7)
    txns = []
    for i in range(40):
        t = Transaction(txid=i, origin=0)
        for item in rng.integers(0, 500, rng.integers(1, 6)):
            store.read(t, int(item))
        txns.append(t)
    # mutate some items
    store.apply({int(i): 1.0 for i in rng.integers(0, 500, 60)})
    batched = validate_batch(store, txns)
    loop = np.asarray([store.validate(t) for t in txns])
    np.testing.assert_array_equal(batched, loop)
    items, vers = pack_read_sets(txns)
    kern = lease_validate(
        jnp.asarray(store.versions, jnp.int32), jnp.asarray(items),
        jnp.asarray(vers), jnp.zeros((500,), jnp.int32),
        jnp.full((len(txns), 1), -1, jnp.int32), block_txns=16, chunk=128)
    np.testing.assert_array_equal(np.asarray(kern), loop)


@pytest.mark.parametrize("ep,tp,capacity,t_out", [
    (4, 1, 8, 16),    # mixtral-style: whole experts, no psum
    (2, 2, 8, 16),    # deepseek-style: tp partials summed per slot
    (2, 4, 4, 8),
])
def test_moe_combine_vs_ref(ep, tp, capacity, t_out):
    """ops.moe_combine (the a2a combine leg's partial-activation psum)
    against an independent numpy oracle: gate each tp partial, sum the tp
    f-slice partials per (group, slot), scatter-add to the slot's token."""
    from repro.kernels import ops

    d = 12
    back = RNG.standard_normal((ep * tp * capacity, d)).astype(np.float32)
    # slot -> token map; index t_out marks an empty slot (dropped)
    tok_slot = RNG.integers(0, t_out + 1, ep * capacity).astype(np.int32)
    gate_slot = (RNG.random(ep * capacity).astype(np.float32)
                 * (tok_slot < t_out))
    got = np.asarray(ops.moe_combine(
        jnp.asarray(back), jnp.asarray(tok_slot), jnp.asarray(gate_slot),
        tp=tp, capacity=capacity, t_out=t_out))
    gated = (back.reshape(ep, tp, capacity, d)
             * gate_slot.reshape(ep, 1, capacity, 1)).sum(axis=1)
    want = np.zeros((t_out, d), np.float32)
    for i, t in enumerate(tok_slot):
        if t < t_out:
            want[t] += gated.reshape(-1, d)[i]
    np.testing.assert_allclose(got, want, atol=1e-5, rtol=1e-5)
