"""The explorer re-finds every seeded protocol mutant with a minimized,
deterministically replayable counterexample.

Ten mutants mirror tests/test_sanitizer_mutants.py (single-schedule
catchable); two — no-born-blocked and stale-piggyback — are *schedule-
dependent*: the default FIFO schedule masks them, so the plain sanitizer
run provably passes and only exploring legal delivery reorderings exposes
the bug.  That separation is the point of the explorer and is pinned here.
"""
import pytest

from repro.analysis.explore import (ExploreConfig, explore_scenario, main,
                                    replay_trace)
from repro.analysis.scenarios import MUTANT_INVARIANTS, get_scenario
from repro.analysis.trace import load_trace, save_trace

CFG = ExploreConfig(strategy="exhaustive", window_ms=0.6, max_schedules=400)

SCHEDULE_ONLY = ("mutant-no-born-blocked", "mutant-stale-piggyback")


@pytest.mark.parametrize("name", sorted(MUTANT_INVARIANTS))
def test_explorer_finds_mutant_with_expected_invariant(name):
    res = explore_scenario(name, CFG)
    assert not res.ok, f"{name}: explorer found no violation"
    inv, _detail = res.violation.violation
    assert inv == MUTANT_INVARIANTS[name]
    # minimization ran and preserved the invariant
    assert res.minimized is not None
    assert res.minimized.violation is not None
    assert res.minimized.violation[0] == MUTANT_INVARIANTS[name]


@pytest.mark.parametrize("name", sorted(MUTANT_INVARIANTS))
def test_minimized_counterexample_replays_deterministically(name):
    res = explore_scenario(name, CFG)
    tr = res.minimized
    build = get_scenario(name)
    vio = replay_trace(lambda pol: build(dict(tr.args), pol), tr)
    assert vio is not None and vio[0] == MUTANT_INVARIANTS[name]


@pytest.mark.parametrize("name", SCHEDULE_ONLY)
def test_schedule_only_mutants_pass_the_default_schedule(name):
    """The acceptance property: a single-schedule sanitizer run CANNOT
    catch these — run 1 is exactly the default FIFO schedule and must be
    clean; only deeper exploration finds the interleaving."""
    res = explore_scenario(
        name, ExploreConfig(strategy="exhaustive", window_ms=0.6,
                            max_schedules=1, minimize=False))
    assert res.ok, (f"{name} fired on the default schedule — it is not "
                    f"schedule-dependent: {res.violation.violation}")


@pytest.mark.parametrize("name", SCHEDULE_ONLY)
def test_schedule_only_mutants_minimize_to_one_deviation(name):
    """ddmin reduces the counterexample to the default schedule plus a
    single reordering — the one delivery swap that exposes the bug."""
    res = explore_scenario(name, CFG)
    assert len(res.minimized.deviations()) == 1


@pytest.mark.parametrize("name", SCHEDULE_ONLY)
def test_clean_controls_explore_violation_free(name):
    """With the mutation disabled, the same scenario's full schedule space
    is clean — the counterexample indicts the mutant, not the harness."""
    res = explore_scenario(name, CFG, {"mutant": False})
    assert res.ok
    assert not res.stats.truncated          # the whole space was covered
    assert res.stats.schedules >= 2         # and it genuinely branched


def test_cli_replay_reproduces_saved_counterexample(tmp_path):
    res = explore_scenario("mutant-no-born-blocked", CFG)
    path = tmp_path / "counterexample.json"
    save_trace(path, res.minimized)
    # the artifact round-trips and the CLI confirms the same invariant
    tr = load_trace(path)
    assert tr.violation[0] == "quiescence"
    assert main(["replay", str(path)]) == 0
