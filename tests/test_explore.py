"""Explorer machinery tests: trace round-trip, ddmin, identity-policy
byte-identity on a real cluster, POR reduction, and the CI smoke grid
(green by construction, including the pipelined-handoff cell)."""
from dataclasses import replace

import numpy as np
import pytest

from repro.analysis.explore import (SMOKE_CELLS, ExploreConfig, ExploreStats,
                                    _explore_exhaustive, _smoke_build,
                                    explore_scenario, main)
from repro.analysis.trace import Cand, Decision, Trace, ddmin
from repro.core.events import SchedulePolicy


# ---------------------------------------------------------------------------
# ddmin
# ---------------------------------------------------------------------------

def test_ddmin_reduces_to_the_failing_core():
    items = list(range(16))
    culprits = {3, 11}
    calls = []

    def test_fn(subset):
        calls.append(list(subset))
        return culprits <= set(subset)

    out = ddmin(items, test_fn)
    assert sorted(out) == sorted(culprits)
    # 1-minimality: dropping either remaining element loses the failure
    for x in out:
        assert not test_fn([y for y in out if y != x])


def test_ddmin_single_culprit_and_degenerate_inputs():
    assert ddmin([7], lambda s: True) == [7]
    assert ddmin([], lambda s: True) == []
    out = ddmin(list(range(10)), lambda s: 4 in s)
    assert out == [4]


# ---------------------------------------------------------------------------
# Trace JSON round-trip
# ---------------------------------------------------------------------------

def test_trace_json_roundtrip():
    tr = Trace(
        model="mutant-stale-piggyback", args={"mutant": True},
        window_ms=0.6,
        violation=("blocked-and-drained", "piggyback on blocked LOR"),
        decisions=[
            Decision(time=1.05, chosen=9, default=4, cands=[
                Cand(seq=4, time=1.05, kind="to", node=0, label="to:lease:1",
                     keys=(0,), eligible=True),
                Cand(seq=9, time=1.05, kind="opt", node=0,
                     label="opt:lease:2", keys=(0, 2), eligible=True),
                Cand(seq=12, time=1.05, kind="to", node=1, label="",
                     keys=None, eligible=False),
            ]),
            Decision(time=2.0, chosen=20, default=20,
                     cands=[Cand(seq=20, time=2.0)]),
        ])
    back = Trace.from_json(tr.to_json())
    assert back.to_json() == tr.to_json()
    assert back.violation == tr.violation
    assert back.chosen == [9, 20]
    assert back.deviations() == [(0, 9)]
    assert back.decisions[0].cands[1].keys == (0, 2)
    assert back.decisions[0].cands[2].eligible is False


# ---------------------------------------------------------------------------
# Identity: the policy seam is byte-invisible when it never reorders
# ---------------------------------------------------------------------------

def test_identity_policy_byte_identical_to_no_policy():
    from repro.core.cluster import Cluster, SimConfig
    from repro.core.workloads import BankWorkload

    def run(explore):
        cfg = SimConfig(n_nodes=3, threads_per_node=2, n_items=48,
                        n_classes=6, duration_ms=40.0, warmup_ms=0.0,
                        drain_ms=30.0, certify_jax_min=1 << 30,
                        lease_jax_min=1 << 30, seed=3, sanitize=True,
                        explore=explore)
        wl = BankWorkload(n_nodes=cfg.n_nodes, n_items=cfg.n_items,
                          locality=0.6)
        c = Cluster(cfg, wl)
        c.run()
        c.events.run(cfg.duration_ms + cfg.drain_ms + 60_000.0)
        return c

    a = run(None)
    b = run(ExploreConfig(policy=SchedulePolicy()))
    assert a.metrics.commits == b.metrics.commits > 0
    assert a.events.n_dispatched == b.events.n_dispatched
    for ra, rb in zip(a.replicas, b.replicas):
        assert np.array_equal(ra.store.versions, rb.store.versions)
        assert np.array_equal(ra.store.values, rb.store.values)


# ---------------------------------------------------------------------------
# Smoke grid: every CI cell is green, including handoff="pipelined"
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("i", range(len(SMOKE_CELLS)),
                         ids=[f"{n}-{a.get('lease_mode', 'pct')}-"
                              f"{a.get('handoff', '')}".rstrip("-")
                              for n, a, _ in SMOKE_CELLS])
def test_smoke_cell_green(i):
    name, args, cfg = SMOKE_CELLS[i]
    res = explore_scenario(name, cfg, args)
    assert res.ok, f"{name} {args}: {res.violation.violation}"
    if cfg.strategy == "exhaustive":
        # the cell is sized so POR+dedup exploration COMPLETES in budget
        assert not res.stats.truncated
        assert res.stats.schedules > 1      # it genuinely explored


def test_pipelined_handoff_cell_present_and_explored():
    """Promotion gate for handoff="pipelined": its schedule space (not just
    the default schedule) is model-checked clean — see ROADMAP."""
    cells = [(n, a) for n, a, _ in SMOKE_CELLS
             if a.get("handoff") == "pipelined"]
    assert len(cells) >= 2       # sequential + batched control planes


def test_por_reduction_at_least_2x_on_smoke_cell():
    name, args, cfg = SMOKE_CELLS[0]
    reduced = explore_scenario(name, cfg, args)
    assert reduced.ok and not reduced.stats.truncated
    naive_stats = ExploreStats()
    naive_cfg = replace(cfg, por=False, dedup=False, minimize=False)
    _explore_exhaustive(lambda pol: _smoke_build(name, args, pol),
                        naive_cfg, naive_stats)
    ratio = naive_stats.runs / max(1, reduced.stats.runs)
    assert ratio >= 2.0, (f"POR+dedup reduction {ratio:.2f}x "
                          f"({naive_stats.runs} naive vs "
                          f"{reduced.stats.runs} reduced)")


# ---------------------------------------------------------------------------
# CLI surface
# ---------------------------------------------------------------------------

def test_cli_list_and_scenario_run(capsys):
    assert main(["--list"]) == 0
    out = capsys.readouterr().out
    assert "smoke-bank" in out and "mutant-stale-piggyback" in out

    assert main(["--scenario", "mutant-double-grant",
                 "--max-schedules", "50"]) == 1
    out = capsys.readouterr().out
    assert "VIOLATION [single-owner]" in out


def test_cli_scenario_writes_replayable_trace(tmp_path):
    rc = main(["--scenario", "mutant-no-born-blocked", "--window-ms", "0.6",
               "--max-schedules", "400", "--out", str(tmp_path)])
    assert rc == 1
    path = tmp_path / "counterexample-mutant-no-born-blocked.json"
    assert path.exists()
    assert main(["replay", str(path)]) == 0
