"""Unit tests for repro.analysis.lint: each rule fires on a distilled
repro of the bug class it encodes and stays quiet on the idiomatic form,
plus the repo-wide gate (clean vs baseline; hot paths baseline-free)."""
import textwrap
from pathlib import Path

import pytest

from repro.analysis import lint as L
from repro.analysis.rules import (ALL_RULES, event_determinism, host_sync,
                                  id_dtype, jit_static, ops_ref, pow2_pad,
                                  state_mut, trace_site)

REPO = Path(__file__).resolve().parents[1]


def _ctx(src, rel="src/repro/core/fake.py", project=None):
    src = textwrap.dedent(src)
    return L.FileCtx(Path(rel), rel, src, project or L.Project())


def _rules(src, rule, **kw):
    ctx = _ctx(src, **kw)
    return ctx, L.apply_allows(ctx, rule.check(ctx))


# ---------------------------------------------------------------------------
# host-sync
# ---------------------------------------------------------------------------

def test_host_sync_flags_numpy_and_item_in_jit():
    _, vs = _rules("""
        import jax, numpy as np

        @jax.jit
        def f(x):
            y = np.asarray(x)       # host round-trip
            return float(y.sum()) + x.item()

        def host_side(x):
            return np.asarray(x)    # fine outside jit
    """, host_sync.RULE)
    assert len(vs) == 3
    assert all(v.rule == "host-sync" for v in vs)


def test_host_sync_sees_partial_and_wrapper_forms():
    _, vs = _rules("""
        import functools, jax

        @functools.partial(jax.jit, static_argnames=("n",))
        def f(x, n):
            return jax.default_backend()

        def g(x):
            return jax.devices()

        g = jax.jit(g)
    """, host_sync.RULE)
    assert len(vs) == 2


def test_host_sync_allow_comment_needs_reason():
    ok = """
        import jax, numpy as np

        @jax.jit
        def f(x):
            # lint: allow(host-sync): probe resolved at trace time on purpose
            return np.asarray(x)
    """
    _, vs = _rules(ok, host_sync.RULE)
    assert vs == []
    _, vs = _rules(ok.replace(
        ": probe resolved at trace time on purpose", ")").replace(
        "allow(host-sync))", "allow(host-sync)"), host_sync.RULE)
    assert len(vs) == 1 and "lacks a reason" in vs[0].msg


def test_host_sync_allow_in_wrapped_comment_block():
    _, vs = _rules("""
        import jax, numpy as np

        @jax.jit
        def f(x):
            # lint: allow(host-sync): this wrapped exemption spans two
            # comment lines before the flagged statement
            return np.asarray(x)
    """, host_sync.RULE)
    assert vs == []


# ---------------------------------------------------------------------------
# id-dtype
# ---------------------------------------------------------------------------

def test_id_dtype_flags_dtypeless_frombuffer():
    _, vs = _rules("""
        import numpy as np

        def unpack(buf):
            return np.frombuffer(buf)   # PR 4 bug: int64 view of int32 log
    """, id_dtype.RULE)
    assert len(vs) == 1 and "frombuffer" in vs[0].msg


def test_id_dtype_flags_int64_id_arrays_only():
    _, vs = _rules("""
        import numpy as np

        def build(n_items, ccs, sids):
            cc_arr = np.asarray(ccs, np.int64)          # id: flagged
            flat = np.fromiter(sids, np.int64)          # id data: flagged
            versions = np.zeros((n_items,), np.int64)   # payload: fine
            vals = np.asarray([1.0], np.float64)        # fine
            return cc_arr, flat, versions, vals
    """, id_dtype.RULE)
    assert len(vs) == 2


# ---------------------------------------------------------------------------
# state-mutation
# ---------------------------------------------------------------------------

def test_state_mut_flags_foreign_writes_not_owner_files():
    src = """
        def grow(self, store, lm):
            store.versions = store.versions + 1
            lm.qlen[0] = 3
            self.blocked = True          # plain attr, not a subscript cell
    """
    _, vs = _rules(src, state_mut.RULE)
    assert len(vs) == 2
    _, vs = _rules(src, state_mut.RULE, rel="src/repro/core/lease.py")
    assert vs == []


def test_state_mut_flags_tuple_target_writes():
    _, vs = _rules("""
        def swap(self):
            self.store.values, self.store.versions = 1, 2
    """, state_mut.RULE)
    assert len(vs) == 2


# ---------------------------------------------------------------------------
# jit-static
# ---------------------------------------------------------------------------

def test_jit_static_flags_typo_and_unhashable_default():
    _, vs = _rules("""
        import functools, jax

        @functools.partial(jax.jit, static_argnames=("chunk", "chunks"))
        def f(x, chunk=64, shape=[1, 2]):
            return x
    """, jit_static.RULE)
    msgs = "\n".join(v.msg for v in vs)
    assert "chunks" in msgs            # not a parameter
    assert "shape" not in msgs or True
    _, vs2 = _rules("""
        import functools, jax

        @functools.partial(jax.jit, static_argnames=("shape",))
        def f(x, shape=[1, 2]):
            return x
    """, jit_static.RULE)
    assert any("unhashable" in v.msg for v in vs2)


# ---------------------------------------------------------------------------
# pow2-pad
# ---------------------------------------------------------------------------

def test_pow2_pad_flags_raw_len_alloc_feeding_dispatch():
    _, vs = _rules("""
        import numpy as np
        from repro.kernels.ops import settle_lease_batch

        def bad(groups):
            wait_req = np.zeros((len(groups), 4), np.int32)
            return settle_lease_batch(1, 2, 3, 4, 5, wait_req, 7, 8)

        def good(groups, _pad_bucket):
            b = _pad_bucket(len(groups))
            wait_req = np.zeros((b, 4), np.int32)
            return settle_lease_batch(1, 2, 3, 4, 5, wait_req, 7, 8)
    """, pow2_pad.RULE)
    assert len(vs) == 1 and "'bad'" in vs[0].msg   # 'good' is blessed


# ---------------------------------------------------------------------------
# event-determinism
# ---------------------------------------------------------------------------

def test_event_determinism_flags_wall_clock_reads_in_core_only():
    src = """
        import time

        def on_deliver(self):
            t = time.time()
            self.events.schedule(t, lambda: None)
    """
    _, vs = _rules(src, event_determinism.RULE)
    assert len(vs) == 1 and "wall-clock" in vs[0].msg
    # benchmarks / analysis code may time itself
    _, vs = _rules(src, event_determinism.RULE,
                   rel="src/repro/analysis/bench.py")
    assert vs == []


def test_event_determinism_flags_set_iteration_feeding_scheduling():
    _, vs = _rules("""
        def recheck(self, nodes):
            pending = set(nodes)
            for n in pending:                  # hash order drives dispatch
                self.events.schedule(0.0, lambda: None)
            for n in sorted(pending):          # deterministic: fine
                self.events.schedule(0.0, lambda: None)
            for n in pending:                  # no scheduling inside: fine
                self.count += 1
    """, event_determinism.RULE)
    assert len(vs) == 1 and "unordered set" in vs[0].msg


def test_event_determinism_flags_id_ordering_not_membership():
    _, vs = _rules("""
        def order(self, lors, seen):
            worst = sorted(lors, key=id)       # address order: flagged
            if id(lors[0]) < id(lors[1]):      # address compare: flagged
                pass
            return [l for l in lors if id(l) in seen]   # membership: fine
    """, event_determinism.RULE)
    assert len(vs) == 2
    msgs = "\n".join(v.msg for v in vs)
    assert "id()" in msgs and "allocation address" in msgs


def test_event_determinism_quiet_on_core_modules():
    for rel in ("src/repro/core/events.py", "src/repro/core/lease.py",
                "src/repro/core/gcs.py", "src/repro/core/cluster.py"):
        src = (REPO / rel).read_text()
        ctx = L.FileCtx(REPO / rel, rel, src, L.Project())
        vs = L.apply_allows(ctx, event_determinism.RULE.check(ctx))
        assert vs == [], "\n".join(v.render() for v in vs)


# ---------------------------------------------------------------------------
# event-trace-site
# ---------------------------------------------------------------------------

def test_trace_site_flags_computed_event_names():
    _, vs = _rules("""
        def f(self, node, kind):
            tr = self.trace
            if tr is not None:
                tr.instant(f"dispatch-{kind}", "events", ts=1.0)
                tr.span("exec" if kind else "x", "t", 0.0, 1.0)
                self.trace.counter(kind, "t", 0.0, 1)
    """, trace_site.RULE)
    assert len(vs) == 3
    assert all(v.rule == "event-trace-site" for v in vs)
    assert "f-string" in vs[0].msg


def test_trace_site_quiet_on_literal_names_and_other_receivers():
    _, vs = _rules("""
        def f(self, node, txid):
            tr = self.trace
            if tr is not None:
                tr.instant("forward", f"node{node}/dtd", ts=1.0, txid=txid)
                tr.span("exec", f"node{node}/t0", 0.0, 1.0)
            self.stats.counter(txid)        # not a trace receiver
            span = make_span(txid)          # bare name, not a method call
    """, trace_site.RULE)
    assert vs == []


# ---------------------------------------------------------------------------
# ops<->ref parity
# ---------------------------------------------------------------------------

class _FakeProject(L.Project):
    def __init__(self, ref_src, tests_src):
        super().__init__()
        self._ref = ref_src
        self._tests_src = tests_src

    def read_text(self, rel):
        return self._ref if rel.endswith("ref.py") else None

    def tests_text(self):
        return self._tests_src


def test_ops_ref_requires_twin_and_named_test():
    ops_src = """
        from . import ref

        def covered(x):
            return ref.covered_ref(x)

        def untested(x):
            return ref.untested_ref(x)

        def twinless(x):
            return x
    """
    ref_src = "def covered_ref(x):\n    return x\n\ndef untested_ref(x):\n    return x\n"
    proj = _FakeProject(ref_src, "def test_covered():\n    covered(1)\n")
    _, vs = _rules(ops_src, ops_ref.RULE,
                   rel="src/repro/kernels/ops.py", project=proj)
    msgs = "\n".join(v.msg for v in vs)
    assert "covered" not in msgs.replace("untested", "")
    assert "untested" in msgs and "twinless" in msgs


# ---------------------------------------------------------------------------
# Repo-wide gate
# ---------------------------------------------------------------------------

def test_repo_lints_fully_clean_no_baseline():
    """The legacy id-dtype debt is burned down: the repo must lint clean
    with NO baseline at all — new violations are fixed or inline-allowed,
    never grandfathered."""
    violations = L.lint_paths([L.DEFAULT_TARGET])
    assert violations == [], "\n".join(v.render() for v in violations)


def test_committed_baseline_is_empty():
    """The baseline file stays empty forever; re-adding entries reopens the
    burn-down this gate exists to close."""
    assert L.load_baseline(L.DEFAULT_BASELINE) == set()


def test_baseline_roundtrip(tmp_path):
    vs = [L.Violation("a.py", 3, "r", "m"), L.Violation("b.py", 9, "r2", "m2")]
    p = tmp_path / "b.txt"
    assert L.write_baseline(p, vs) == 2
    assert L.load_baseline(p) == {v.key for v in vs}
    # keys are line-free: the same violation moved down the file still matches
    assert L.Violation("a.py", 30, "r", "m").key in L.load_baseline(p)


def test_cli_runs_clean_and_strict_mode_fails_on_injected(tmp_path):
    assert L.main([]) == 0
    bad = tmp_path / "bad.py"
    bad.write_text("import numpy as np\nimport jax\n\n@jax.jit\n"
                   "def f(x):\n    return np.asarray(x)\n")
    assert L.main([str(bad), "--no-baseline"]) == 1
