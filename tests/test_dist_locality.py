"""Pricing invariants of :mod:`repro.dist.locality`.

The DTD's migrate-work / migrate-state verdict must (a) flip exactly once
as the state grows, (b) respond monotonically to bandwidth, and (c) favor
token dispatch over expert gathering once expert parallelism is wide.
"""
import pytest

from repro.dist.locality import (DCN_BW, MoEDispatchCost, SessionDispatchCost,
                                 price_moe_dispatch, price_session_dispatch)


def test_session_crossover_as_kv_grows():
    """prefer_migration flips from False to True exactly once in kv bytes."""
    verdicts = [
        price_session_dispatch(4096, 1024, kv_state_bytes=kv).prefer_migration
        for kv in (0, 1_000, 5_000, 10_000, 100_000, 10_000_000, 1e9)
    ]
    assert verdicts[0] is False            # empty session: fetch the (no) state
    assert verdicts[-1] is True            # 1GB of KV: ship the request
    flips = sum(a != b for a, b in zip(verdicts, verdicts[1:]))
    assert flips == 1


def test_session_crossover_point_is_the_work_bytes():
    c = price_session_dispatch(4096, 1024, kv_state_bytes=0.0,
                               handoff_bytes=0.0)
    # at kv == work_bytes the two plans cost the same; just above, migrate
    at = price_session_dispatch(4096, 1024, kv_state_bytes=c.work_bytes,
                                handoff_bytes=0.0)
    above = price_session_dispatch(4096, 1024,
                                   kv_state_bytes=c.work_bytes * 1.01,
                                   handoff_bytes=0.0)
    assert at.migrate_work_s == pytest.approx(at.migrate_state_s)
    assert above.prefer_migration


def test_session_costs_monotone_in_bandwidth():
    slow = price_session_dispatch(4096, 1024, kv_state_bytes=1e6,
                                  dcn_bw=DCN_BW / 4)
    fast = price_session_dispatch(4096, 1024, kv_state_bytes=1e6,
                                  dcn_bw=DCN_BW * 4)
    assert slow.migrate_state_s > fast.migrate_state_s
    assert slow.migrate_work_s > fast.migrate_work_s
    # the verdict is a byte comparison: bandwidth scales both plans equally
    assert slow.prefer_migration == fast.prefer_migration


def test_session_wire_bytes_tracks_chosen_plan():
    c = price_session_dispatch(4096, 1024, kv_state_bytes=50_000_000)
    assert isinstance(c, SessionDispatchCost)
    assert c.prefer_migration and c.wire_bytes == c.work_bytes
    c2 = price_session_dispatch(4096, 1024, kv_state_bytes=100.0)
    assert not c2.prefer_migration and c2.wire_bytes == c2.state_bytes


def test_session_seq_shards_cuts_per_hop_state_time():
    """A seq-sharded column moves as parallel shard hops: the state plan's
    serialization shrinks by 1/seq_shards while total wire bytes stay put."""
    whole = price_session_dispatch(4096, 1024, kv_state_bytes=64_000_000,
                                   handoff_bytes=0.0)
    split = price_session_dispatch(4096, 1024, kv_state_bytes=64_000_000,
                                   handoff_bytes=0.0, seq_shards=16)
    assert split.state_bytes == whole.state_bytes          # total unchanged
    assert split.state_hop_bytes == pytest.approx(whole.state_bytes / 16)
    assert split.migrate_state_s < whole.migrate_state_s
    # work plan is untouched by the state layout
    assert split.migrate_work_s == whole.migrate_work_s


def test_session_seq_shards_can_flip_the_verdict():
    """Near the crossover, the cheaper per-hop state move flips the verdict
    from forward-the-work to acquire-the-state."""
    kv = price_session_dispatch(4096, 1024, kv_state_bytes=0.0,
                                handoff_bytes=0.0).work_bytes * 4
    whole = price_session_dispatch(4096, 1024, kv_state_bytes=kv,
                                   handoff_bytes=0.0)
    split = price_session_dispatch(4096, 1024, kv_state_bytes=kv,
                                   handoff_bytes=0.0, seq_shards=8)
    assert whole.prefer_migration             # 4x the work bytes: forward
    assert not split.prefer_migration         # /8 per hop: acquire wins


def test_moe_dispatch_flips_with_ep_degree():
    """Wide EP favors token a2a; a single device needs no wire at all."""
    kw = dict(tokens_per_device=4096, d_model=4096, top_k=2,
              n_experts=8, d_expert=14336)
    c1 = price_moe_dispatch(ep_degree=1, **kw)
    c8 = price_moe_dispatch(ep_degree=8, **kw)
    assert isinstance(c8, MoEDispatchCost)
    assert c1.dispatch_bytes == 0.0 and not c1.prefer_dispatch
    assert c8.prefer_dispatch
    assert c8.dispatch_s < c8.allgather_s


def test_moe_dispatch_flips_with_batch():
    """Weight traffic is batch-independent: tiny batches flip to all-gather."""
    kw = dict(d_model=4096, top_k=2, n_experts=8, d_expert=14336, ep_degree=8)
    small = price_moe_dispatch(tokens_per_device=1, **kw)
    big = price_moe_dispatch(tokens_per_device=1 << 20, **kw)
    assert small.prefer_dispatch            # 1 token beats 8 experts' weights
    assert big.dispatch_bytes > big.allgather_bytes and not big.prefer_dispatch
