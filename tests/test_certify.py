"""Batched certification pipeline: equivalence, locks, packing, serving.

The contract under test (ISSUE 4): the batched commit phase is a pure
vectorization of the one-at-a-time path — byte-identical store state and
identical commit/abort/forward counts on seeded runs — with write locks
actually threaded through both kernels, and the serving certifier draining
each pod's forwarded batch in one dispatch per engine step.
"""
import dataclasses

import numpy as np
import pytest

from repro.core import BankWorkload, SimConfig, make_cluster
from repro.core.stm import (Transaction, VersionedStore, pack_read_sets,
                            pack_write_sets, validate_batch)


def _run_mode(mode, *, algo="LILAC-TM-ST", locality=0.5, seed=3, **cfg_kw):
    cfg = SimConfig(duration_ms=300.0, warmup_ms=50.0, seed=seed,
                    certify_mode=mode, **cfg_kw)
    wl = BankWorkload(n_nodes=cfg.n_nodes, n_items=cfg.n_items,
                      locality=locality)
    c = make_cluster(algo, wl, cfg)
    m = c.run()
    return c, m


# ---------------------------------------------------------------------------
# Tentpole: batched drain == sequential oracle, byte for byte
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("algo,locality", [
    ("LILAC-TM-ST", 0.3), ("FGL", 0.9), ("ALC", 0.5)])
def test_batched_certification_byte_identical_to_sequential(algo, locality):
    """Seeded runs: batched drain (forced through the vectorized kernel,
    certify_jax_min=1) produces byte-identical per-replica values/versions
    arrays and identical commit/abort/forward counts.  The amortized slot
    cost is pinned off: with ``cert_slot_mode="per_txn"`` the batched drain
    is a *pure vectorization* of the one-at-a-time path."""
    seq_c, seq_m = _run_mode("sequential", algo=algo, locality=locality)
    bat_c, bat_m = _run_mode("batched", algo=algo, locality=locality,
                             certify_jax_min=1, cert_slot_mode="per_txn")
    assert (bat_m.commits, bat_m.aborts, bat_m.forwards) == \
        (seq_m.commits, seq_m.aborts, seq_m.forwards)
    assert bat_m.commit_times == seq_m.commit_times
    for rs, rb in zip(seq_c.replicas, bat_c.replicas):
        assert rs.store.values.tobytes() == rb.store.values.tobytes()
        assert rs.store.versions.tobytes() == rb.store.versions.tobytes()
    # the batched path actually ran: every certification went through it
    assert bat_m.cert_batches > 0
    assert bat_m.cert_batch_txns >= bat_m.rw_certified - bat_m.forwards


def test_amortized_slot_cost_keeps_invariants_and_lifts_throughput():
    """ROADMAP item: with the amortized slot model (the batched-mode
    default), the commit-phase group charges ONE slot fixed + per-txn
    increment, so *simulated* throughput reflects PR 4's batching — it must
    be at least the per-txn model's, and safety must be untouched."""
    assert SimConfig().cert_slot_mode == "amortized"
    thr = {}
    for mode in ("per_txn", "amortized"):
        c, m = _run_mode("batched", locality=0.3, cert_slot_mode=mode)
        assert m.commits > 100
        expect = c.cfg.n_items * c.cfg.init_value
        for r in c.replicas:
            assert r.store.total() == pytest.approx(expect, abs=1e-6)
        v0 = c.replicas[0].store.values
        for r in c.replicas[1:]:
            np.testing.assert_array_equal(v0, r.store.values)
        thr[mode] = c.throughput()
    assert thr["amortized"] >= thr["per_txn"]


def test_amortized_slot_charges_fixed_plus_increment_per_group():
    """Two transactions enabled together occupy one slot for
    fixed + 2*per_txn (not two slots for the full cost each)."""
    from repro.core.cluster import Cluster, Replica

    cfg = SimConfig(certify_mode="batched", cert_slot_mode="amortized",
                    cert_fixed_ms=1.0, cert_per_txn_ms=0.25)
    wl = BankWorkload(n_nodes=cfg.n_nodes, n_items=cfg.n_items)
    c = make_cluster("FGL", wl, cfg)

    class _Txn:
        def __init__(self):
            self.lors = []
    r = c.replicas[0]
    t1, t2 = _Txn(), _Txn()
    r.lm.is_enabled = lambda lors: True
    drained = []
    c._enqueue_certify = lambda t, node: drained.append(t)
    r.waiters = [(t1, []), (t2, [])]
    c._check_waiters(0)
    assert r.free_slots == cfg.threads_per_node - 1   # ONE slot for the group
    c.events.run(until=1.49)                          # fixed + 2*inc = 1.5
    assert drained == []
    c.events.run(until=2.0)
    assert drained == [t1, t2]
    assert r.free_slots == cfg.threads_per_node


def test_batched_is_the_default_and_window_keeps_invariants():
    """Batched is the default simulator path; a coalescing window > 0 still
    conserves money and converges replicas (safety under deferral)."""
    assert SimConfig().certify_mode == "batched"
    c, m = _run_mode("batched", certify_window_ms=2.0, seed=5)
    assert m.commits > 100
    expect = c.cfg.n_items * c.cfg.init_value
    for r in c.replicas:
        assert r.store.total() == pytest.approx(expect, abs=1e-6)
    v0 = c.replicas[0].store.values
    for r in c.replicas[1:]:
        np.testing.assert_array_equal(v0, r.store.values)


# ---------------------------------------------------------------------------
# Satellite: the write-lock path is live on both backends
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_lock_conflict_flips_verdict(backend):
    """Regression for the silent stub: the old pallas branch fabricated
    witems = -1 and zero locks, so a locked-write conflict could never be
    reported.  Now a lock on a written item flips the verdict, and only for
    the writer of that item, on both backends."""
    store = VersionedStore(64)
    t1 = Transaction(txid=1, origin=0)
    t1.log_read(3, 0)
    t1.write_set[7] = 1.0
    t2 = Transaction(txid=2, origin=0)
    t2.log_read(4, 0)
    t2.write_set[9] = 2.0
    no_locks = validate_batch(store, [t1, t2], backend=backend)
    np.testing.assert_array_equal(no_locks, [True, True])
    locks = np.zeros((64,), np.int32)
    locks[7] = 1
    with_locks = validate_batch(store, [t1, t2], locks=locks, backend=backend)
    np.testing.assert_array_equal(with_locks, [False, True])


def test_backends_agree_bitwise_with_locks_and_writes():
    """jnp <-> Pallas(interpret) <-> python loop, randomized, bitwise —
    including lock conflicts and stale reads."""
    rng = np.random.default_rng(11)
    store = VersionedStore(500)
    store.versions[:] = rng.integers(0, 30, 500)
    locks = (rng.random(500) < 0.15).astype(np.int32)
    txns = []
    for i in range(60):
        t = Transaction(txid=i + 1, origin=0)
        for it in rng.integers(0, 500, rng.integers(1, 9)):
            ver = int(store.versions[it])
            if rng.random() < 0.2:
                ver += 1                      # stale
            t.log_read(int(it), ver)
        for it in rng.integers(0, 500, rng.integers(0, 5)):
            t.write_set[int(it)] = float(it)
        txns.append(t)
    jnp_out = validate_batch(store, txns, locks=locks, backend="jnp")
    pls_out = validate_batch(store, txns, locks=locks, backend="pallas")
    loop = np.asarray([
        store.validate(t) and not any(locks[it] for it in t.write_set)
        for t in txns])
    np.testing.assert_array_equal(jnp_out, loop)
    np.testing.assert_array_equal(pls_out, loop)


def test_cluster_write_locks_reflect_lease_ownership():
    """_write_locks marks exactly the items whose conflict class is leased
    to another replica."""
    c, _ = _run_mode("batched", locality=0.3, seed=7)
    for node in range(c.cfg.n_nodes):
        locks = c._write_locks(node)
        lm = c.replicas[node].lm
        items = np.random.default_rng(0).integers(0, c.cfg.n_items, 200)
        for it in items:
            cc = c.ccmap.of_item(int(it))
            owner = lm.head_owner(cc)
            assert bool(locks[it]) == (owner >= 0 and owner != node)


# ---------------------------------------------------------------------------
# Packing + batched apply
# ---------------------------------------------------------------------------

def test_pack_pow2_buckets_and_padding():
    txns = []
    for n in (3, 5, 2):
        t = Transaction(txid=1, origin=0)
        for k in range(n):
            t.log_read(k, k + 10)
        t.write_set = {k: float(k) for k in range(n)}
        txns.append(t)
    items, vers = pack_read_sets(txns)
    assert items.shape == (3, 8)             # 5 reads -> pow2 bucket 8
    witems = pack_write_sets(txns)
    assert witems.shape == (3, 8)
    # padded slots masked, real slots in order
    assert list(items[1, :5]) == [0, 1, 2, 3, 4]
    assert list(vers[1, :5]) == [10, 11, 12, 13, 14]
    assert (items[1, 5:] == -1).all() and (items[2, 2:] == -1).all()
    assert set(witems[0, :3]) == {0, 1, 2} and (witems[0, 3:] == -1).all()
    # pad_to widens, pow2 keeps buckets stable across nearby batch shapes
    assert pack_read_sets(txns, pad_to=11)[0].shape == (3, 16)
    assert pack_read_sets(txns[:2])[0].shape == (2, 8)


def test_apply_batch_matches_sequential_apply_versioned():
    """Vectorized scatter == ordered apply_versioned loop, including
    item overlap across write-sets (last writer wins)."""
    rng = np.random.default_rng(3)
    a, b = VersionedStore(200), VersionedStore(200)
    write_sets, versions = [], []
    for i in range(40):
        ws = {int(it): float(rng.random())
              for it in rng.integers(0, 200, rng.integers(0, 6))}
        write_sets.append(ws)
        versions.append(100 + i)
    for ws, v in zip(write_sets, versions):
        a.apply_versioned(ws, v)
    b.apply_batch(write_sets, versions)
    assert a.values.tobytes() == b.values.tobytes()
    assert a.versions.tobytes() == b.versions.tobytes()
    assert a.clock == b.clock


def test_read_log_record_view_roundtrip():
    """The compact read log and its ReadSetEntry view stay in sync."""
    store = VersionedStore(16)
    store.apply({3: 1.5})
    t = Transaction(txid=1, origin=0)
    assert store.read(t, 3) == 1.5
    store.read(t, 4)
    assert t.n_reads == 2
    assert [(e.item, e.version) for e in t.read_set] == [(3, 1), (4, 0)]
    assert list(t.read_items) == [3, 4]
    assert store.validate(t)
    store.apply({3: 2.0})
    assert not store.validate(t)


# ---------------------------------------------------------------------------
# Serving-layer certifier
# ---------------------------------------------------------------------------

def _engine(n_pods=2, **router_kw):
    from repro.configs import get_smoke_config
    from repro.serve.certifier import StepCertifier
    from repro.serve.engine import MultiPodEngine, SimBackend
    from repro.serve.router import LocalityRouter

    cfg = get_smoke_config("mixtral-8x7b")
    router = LocalityRouter(n_pods, policy="short",
                            kv_bytes_per_token=router_kw.pop("kvb", 1e9),
                            **router_kw)
    certifier = StepCertifier(n_pods, jax_min=1)   # pin the packed path
    return MultiPodEngine(n_pods, SimBackend(cfg), router, certifier)


def test_engine_certifies_forwarded_batch_in_one_dispatch():
    from repro.serve.engine import Request

    eng = _engine()
    eng.submit(Request(sid=1, origin=0, n_tokens=1))   # pod 0 owns sid 1
    eng.submit(Request(sid=2, origin=0, n_tokens=1))   # pod 0 owns sid 2
    eng.run_step()
    base_batches = eng.certifier.metrics.batches
    # two forwarded requests from pod 1 -> one batch at the owner
    d1 = eng.submit(Request(sid=1, origin=1, n_tokens=1))
    d2 = eng.submit(Request(sid=2, origin=1, n_tokens=1))
    assert d1.action == d2.action == "forward"
    cm = eng.certifier.metrics
    t0, clock0 = cm.time_s, float(eng._pod_clock[0])
    eng.run_step()
    assert cm.batches == base_batches + 1              # ONE dispatch
    assert cm.max_batch >= 2 and cm.aborts == 0
    assert cm.certified >= 2
    # the batch's validate time landed on the owner pod's busy clock
    assert cm.time_s > t0
    assert float(eng._pod_clock[0]) - clock0 >= eng.certifier.certify_time_s(2)
    # engine metrics expose the certifier's counters (single source)
    assert eng.metrics.as_dict()["certified"] == cm.certified


def test_certify_time_scales_with_batch_not_per_request():
    from repro.serve.certifier import StepCertifier

    c = StepCertifier(1)
    one, many = c.certify_time_s(1), c.certify_time_s(64)
    assert many < 64 * one                  # amortized, not a constant each
    assert many > one                       # but it does scale with rows


def test_stale_epoch_forward_aborts_and_reroutes():
    """A forward in flight when the session is acquired away fails
    certification (stale lease epoch) and is re-routed, then completes."""
    from repro.serve.engine import Request

    eng = _engine(kvb=1.0)                  # featherweight KV: acquires win
    eng.submit(Request(sid=5, origin=0, n_tokens=1))   # pod 0 owns sid 5
    eng.run_step()
    # force a forward to the owner, then move ownership before the step
    eng.router.owner[5] = 0
    d = eng.router.route(1, 5, 10**9)       # huge KV -> forward verdict
    assert d.action == "forward"
    req = Request(sid=5, origin=1, n_tokens=1)
    eng.certifier.enqueue(0, req, d.epoch)
    acq = eng.submit(Request(sid=5, origin=1, n_tokens=1))
    assert acq.action == "acquire"          # bumps the lease epoch
    aborts0 = eng.certifier.metrics.aborts
    eng.drain()
    assert eng.certifier.metrics.aborts == aborts0 + 1
    assert not eng.certifier.has_pending()
    assert req.n_tokens == 0                # re-routed and decoded


def test_router_epoch_bumps_on_every_ownership_move():
    from repro.serve.router import LocalityRouter

    r = LocalityRouter(2, policy="short", arbitration="priced",
                       kv_bytes_per_token=1.0)
    d0 = r.route(0, 9, 0)
    assert d0.epoch == 1                    # placement is a transition
    assert r.route(0, 9, 5).epoch == 1      # local reuse
    acq = r.route(1, 9, 5)                  # tiny KV: state moves
    assert acq.action == "acquire" and acq.epoch == 2
    fwd = r.route(0, 9, 10**9)              # heavy KV: work moves
    assert fwd.action == "forward" and fwd.epoch == 2


def test_evicted_session_replacement_invalidates_stale_forwards():
    """Regression: evict() keeps the epoch, and re-placement bumps it, so a
    forward snapshotted before the evict can never certify against the new
    placement (it used to pass and decode on the dropped cache's pod)."""
    from repro.serve.engine import Request

    eng = _engine()
    eng.submit(Request(sid=7, origin=0, n_tokens=1))   # pod 0 owns sid 7
    eng.run_step()
    d = eng.router.route(1, 7, 10**9)       # forward verdict, epoch 1
    assert d.action == "forward"
    stale = Request(sid=7, origin=1, n_tokens=1)
    eng.certifier.enqueue(0, stale, d.epoch)
    eng.router.evict(7)
    eng.backend.drop(0, 7)
    aborts0 = eng.certifier.metrics.aborts
    d2 = eng.submit(Request(sid=7, origin=1, n_tokens=1))  # re-placement
    assert d2.epoch > d.epoch
    eng.drain()
    assert eng.certifier.metrics.aborts == aborts0 + 1
