"""System-level tests of the discrete-event cluster (the paper's runtime)."""
import numpy as np
import pytest

from repro.core import (ALGORITHMS, BankWorkload, Cluster, SimConfig,
                        TpccConflictMap, TpccLayout, TpccWorkload, make_cluster)


def _bank(algo, locality=0.9, seed=0, duration=300.0, **kw):
    cfg = SimConfig(duration_ms=duration, warmup_ms=50.0, seed=seed, **kw)
    wl = BankWorkload(n_nodes=cfg.n_nodes, n_items=cfg.n_items, locality=locality)
    return make_cluster(algo, wl, cfg)


@pytest.mark.parametrize("algo", list(ALGORITHMS))
def test_conservation_and_convergence(algo):
    """Total money is conserved and replicas converge (after drain)."""
    c = _bank(algo)
    m = c.run()
    assert m.commits > 100
    totals = [r.store.total() for r in c.replicas]
    expect = c.cfg.n_items * c.cfg.init_value
    for t in totals:
        assert t == pytest.approx(expect, abs=1e-6)
    # replicated stores bytewise identical
    v0 = c.replicas[0].store.values
    for r in c.replicas[1:]:
        np.testing.assert_array_equal(v0, r.store.values)


def test_determinism():
    a = _bank("LILAC-TM-ST", seed=3).run()
    b = _bank("LILAC-TM-ST", seed=3).run()
    assert a.commits == b.commits
    assert a.commit_times == b.commit_times


def test_conflict_queue_state_replicated():
    c = _bank("FGL")
    c.run()
    owners0 = c.replicas[0].lm.owner_view()
    for r in c.replicas[1:]:
        assert r.lm.owner_view() == owners0


def test_fgl_beats_alc_at_high_locality():
    thr = {}
    for algo in ("ALC", "FGL"):
        cl = _bank(algo, locality=0.95, duration=500.0)
        cl.run()
        thr[algo] = cl.throughput()
    assert thr["FGL"] > 1.5 * thr["ALC"]


def test_migration_helps_at_low_locality():
    thr = {}
    for algo in ("ALC", "LILAC-TM-ST"):
        cl = _bank(algo, locality=0.3, duration=500.0)
        cl.run()
        thr[algo] = cl.throughput()
    assert thr["LILAC-TM-ST"] > 1.15 * thr["ALC"]


def test_lease_reuse_rate_tracks_locality():
    lo = _bank("FGL", locality=0.1, duration=400.0)
    hi = _bank("FGL", locality=0.95, duration=400.0)
    lo.run(); hi.run()
    assert hi.metrics.lease_reuse_rate() > lo.metrics.lease_reuse_rate() + 0.3


def test_node_failure_recovery():
    """Crash a node mid-run: survivors keep committing, leases reclaimed."""
    c = _bank("LILAC-TM-ST", locality=0.5, duration=600.0)
    c.events.schedule(200.0, lambda: c.gcs.fail(3))
    m = c.run()
    # survivors continued past the failure
    late = [t for (t, n) in m.commit_times if t > 300.0]
    assert len(late) > 50
    assert all(n != 3 for (t, n) in m.commit_times if t > 250.0)
    # no dangling LORs of the failed node at survivors
    for r in c.replicas[:3]:
        for q in r.lm.cq:
            assert all(l.proc != 3 for l in q)


def test_overload_control_avoids_hot_node():
    """Fig 3(c): with ctrl, throughput under overload is much higher.

    Setup per the paper: every node accesses the hot partition with prob.
    0.2 except its home node, which accesses only it; the home node is then
    overloaded with external CPU jobs.  Conflict classes are coarse enough
    (4/partition) that the home node holds the hot partition's leases —
    the attractor premise of §4.
    """
    from dataclasses import replace
    thr = {}
    for ctrl in (True, False):
        cfg = SimConfig(duration_ms=800.0, warmup_ms=100.0, n_classes=16)
        cfg = replace(cfg, dtd=replace(cfg.dtd, policy="short",
                                       enable_overload_ctrl=ctrl))
        wl = BankWorkload(n_nodes=4, n_items=cfg.n_items, locality=1.0,
                          hot_partition=0, hot_fraction=0.2)
        c = Cluster(cfg, wl)
        c.events.schedule(
            150.0, lambda c=c: c.inject_load(0, extra_load=0.95,
                                             slowdown=50.0, seize_slots=1))
        c.run()
        thr[ctrl] = c.metrics.throughput(300.0, 800.0)
    assert thr[True] > 1.5 * thr[False]


def test_forward_in_flight_to_failed_node_restarts_thread():
    """Fail the target while a forwarded txn is on the wire: the p2p is
    dropped (fail-stop), and the originating thread must be restarted by the
    view change — it used to wedge forever because ``exec_node`` was only
    recorded when the *target* ran ``_certify``."""
    c = _bank("LILAC-TM-ST", locality=0.3, duration=600.0)
    orig_send = c.gcs.p2p_send
    hit = {}

    def send_and_fail(sender, dest, msg):
        orig_send(sender, dest, msg)
        if not hit and isinstance(msg, tuple) and msg[0] == "forward" \
                and c.events.now > 100.0:
            txn = msg[1]
            assert txn.exec_node == dest      # target recorded at send time
            hit.update(origin=txn.origin, txid=txn.txid, dest=dest)
            c.gcs.fail(dest)                  # dies with the forward in flight

    c.gcs.p2p_send = send_and_fail
    m = c.run()
    assert hit, "no forward happened — weaken the trigger"
    # the in-flight transaction was restarted, not wedged: it left _inflight,
    # and no *survivor's* txn still points at the dead node (the dead node's
    # own in-flight txns died with it — that's fail-stop, not a wedge)
    assert hit["txid"] not in c._inflight
    assert all(t.exec_node != hit["dest"] for t in c._inflight.values()
               if t.origin != hit["dest"])
    t_fail = [t for (t, n) in m.commit_times if n == hit["origin"]]
    assert any(t > 450.0 for t in t_fail), "originating thread wedged"
    # the dead node never executed the dropped forward: survivors converge
    expect = c.cfg.n_items * c.cfg.init_value
    for r in c.replicas:
        if r.node != hit["dest"]:
            assert r.store.total() == pytest.approx(expect, abs=1e-6)


def test_tpcc_runs_and_fgl_helps():
    lay = TpccLayout(n_nodes=4)
    ccmap = TpccConflictMap(lay)
    thr = {}
    for algo in ("ALC", "LILAC-TM-LT"):
        cfg = SimConfig(duration_ms=600.0, warmup_ms=100.0,
                        n_items=lay.n_items, n_classes=ccmap.n_classes)
        c = make_cluster(algo, TpccWorkload(lay), cfg, ccmap=ccmap)
        c.run()
        thr[algo] = c.throughput()
        assert c.metrics.commits > 100
    assert thr["LILAC-TM-LT"] > thr["ALC"]
