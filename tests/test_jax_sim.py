"""The vectorized sweep model reproduces the event simulator's trends."""
import jax.numpy as jnp
import numpy as np

from repro.core import jax_sim


def test_reuse_rises_with_locality():
    out = jax_sim.locality_sweep([0.0, 0.5, 0.95], seeds=4)
    r = np.asarray(out["reuse"])
    assert r[2] > r[1] > r[0]
    assert r[2] > 0.5


def test_fgl_beats_alc_reuse():
    fgl = jax_sim.locality_sweep([0.9], seeds=4, fine_grained=True)
    alc = jax_sim.locality_sweep([0.9], seeds=4, fine_grained=False)
    assert float(fgl["reuse"][0]) > float(alc["reuse"][0])
    assert float(fgl["throughput"][0]) >= float(alc["throughput"][0])


def test_migration_cuts_lease_moves():
    base = jax_sim.locality_sweep([0.3], seeds=4, migrate=False)
    mig = jax_sim.locality_sweep([0.3], seeds=4, migrate=True)
    assert float(mig["lease_moves"][0]) < float(base["lease_moves"][0])
    assert float(mig["throughput"][0]) >= float(base["throughput"][0])


def test_throughput_ordering_high_locality():
    """ALC <= FGL <= FGL+migration at high locality (paper Fig 3a shape)."""
    alc = jax_sim.locality_sweep([0.9], seeds=6, fine_grained=False)
    fgl = jax_sim.locality_sweep([0.9], seeds=6, fine_grained=True)
    lilac = jax_sim.locality_sweep([0.9], seeds=6, fine_grained=True,
                                   migrate=True)
    a, f, l = (float(x["throughput"][0]) for x in (alc, fgl, lilac))
    assert a <= f + 1e-6 and f <= l + 1e-6
