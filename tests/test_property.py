"""Property-based tests (hypothesis) of the system's invariants."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.conflict import ConflictClassMap
from repro.core.lease import FGLLeaseManager, LeaseRequest
from repro.launch import hlo_count


# ---------------------------------------------------------------------------
# Lease-manager invariants under arbitrary, consistently-ordered histories
# ---------------------------------------------------------------------------

@st.composite
def lease_histories(draw):
    n_classes = draw(st.integers(2, 6))
    n_procs = draw(st.integers(2, 4))
    ops = draw(st.lists(
        st.tuples(
            st.integers(0, n_procs - 1),                       # proc
            st.sets(st.integers(0, n_classes - 1), min_size=1,
                    max_size=n_classes),                        # ccs
        ),
        min_size=1, max_size=24,
    ))
    return n_classes, n_procs, ops


@settings(max_examples=80, deadline=None)
@given(lease_histories())
def test_conflict_queues_converge_across_replicas(hist):
    """Same TO-order at every replica -> identical queues (replication)."""
    n_classes, n_procs, ops = hist
    lms = [FGLLeaseManager(p, n_classes) for p in range(n_procs)]
    reqs = [LeaseRequest(i + 1, proc, tuple(sorted(ccs)))
            for i, (proc, ccs) in enumerate(ops)]
    for r in reqs:
        for lm in lms:
            lm.on_to_deliver(r)
    views = [lm.owner_view() for lm in lms]
    for v in views[1:]:
        assert v == views[0]
    # FIFO: per class, queue order == TO order of requests touching it
    for cc in range(n_classes):
        q = [l.req_id for l in lms[0].cq[cc]]
        want = [r.req_id for r in reqs if cc in r.ccs]
        assert q == want


@settings(max_examples=80, deadline=None)
@given(lease_histories(), st.integers(0, 2 ** 31 - 1))
def test_single_owner_per_class(hist, seed):
    """At any point, a class has at most one enabled owner across procs."""
    n_classes, n_procs, ops = hist
    rng = np.random.default_rng(seed)
    lms = [FGLLeaseManager(p, n_classes) for p in range(n_procs)]
    live = []
    for i, (proc, ccs) in enumerate(ops):
        r = LeaseRequest(i + 1, proc, tuple(sorted(ccs)))
        lors_by = {}
        for lm in lms:
            lors_by[lm.proc] = lm.on_to_deliver(r)
        live.append((r, lors_by))
        # randomly free some drained requests (uniform across replicas)
        if rng.random() < 0.4 and live:
            r0, lb = live.pop(int(rng.integers(len(live))))
            keys = [l.key() for l in lb[r0.proc]]
            for lm in lms:
                lm.on_ur_deliver_freed(keys)
        for cc in range(n_classes):
            owners = {lm.head_owner(cc) for lm in lms}
            assert len(owners) == 1          # replicas agree on the owner


# ---------------------------------------------------------------------------
# Conflict-class map
# ---------------------------------------------------------------------------

@settings(max_examples=50, deadline=None)
@given(st.integers(1, 64), st.integers(1, 32),
       st.lists(st.integers(0, 10_000), min_size=1, max_size=20))
def test_conflict_map_total_and_stable(n_classes, stride, items):
    m = ConflictClassMap(n_classes, stride)
    ccs = m.get_conflict_classes(items)
    assert all(0 <= c < n_classes for c in ccs)
    assert m.get_conflict_classes(items) == ccs
    # item -> class is a function (aliasing allowed, nondeterminism not)
    for i in items:
        assert m.of_item(i) == m.of_item(i)


# ---------------------------------------------------------------------------
# HLO shape parsing
# ---------------------------------------------------------------------------

@settings(max_examples=50, deadline=None)
@given(st.lists(st.integers(1, 64), min_size=0, max_size=4),
       st.sampled_from(["f32", "bf16", "s32", "pred", "u8"]))
def test_hlo_shape_elems(dims, dtype):
    ty = f"{dtype}[{','.join(map(str, dims))}]{{0}}"
    n = hlo_count._shape_elems(ty)
    assert n == int(np.prod(dims)) if dims else n == 1
