"""Runtime lease-protocol sanitizer: pass cases and byte-identity.

The sanitizer is a pure observer — these tests pin (a) that clean
protocol histories run through it without a violation on BOTH managers,
and (b) that a sanitize-on simulation is byte-identical to sanitize-off.
The detection side (each injected bug is flagged) lives in
``test_sanitizer_mutants.py``.
"""
import dataclasses

import numpy as np
import pytest

from repro.analysis.sanitizer import (LeaseSanitizer, SanitizerError,
                                      check_write_locks)
from repro.core import BankWorkload, SimConfig, make_cluster
from repro.core.lease import FGLLeaseManager, LeaseRequest
from repro.core.lease_batched import ShardedLeaseManager
from repro.serve.certifier import StepCertifier


def _req(req_id, proc, ccs):
    return LeaseRequest(req_id=req_id, proc=proc, ccs=tuple(sorted(ccs)))


def _keys(lors):
    return [l.key() for l in lors]


def _wrapped_sets(n_procs, n_classes, **kw):
    """(oracle replicas, batched replicas), every manager sanitized."""
    return ([LeaseSanitizer(FGLLeaseManager(p, n_classes))
             for p in range(n_procs)],
            [LeaseSanitizer(ShardedLeaseManager(p, n_classes, **kw))
             for p in range(n_procs)])


# ---------------------------------------------------------------------------
# Clean histories pass — and the proxy is transparent
# ---------------------------------------------------------------------------

def test_scripted_history_clean_on_both_managers():
    (a,), (b,) = _wrapped_sets(1, 8, n_shards=2)
    for lm in (a, b):
        lors = lm.on_to_deliver(_req(1, 0, (1, 2)))
        assert [l.cc for l in lors] == [1, 2]       # proxy returns verbatim
        assert lm.is_enabled(lors)                  # unknown attr forwards
        assert lm.on_opt_deliver(_req(2, 1, (2,))) == []
        freed = lm.finished_xact(lors)
        assert _keys(freed) == [(1, 0, (2,))]
        lm.on_ur_deliver_freed(_keys(freed))
        lm.on_to_deliver(_req(2, 1, (2,)))
        assert lm.try_piggyback(frozenset({1})) is not None
        lm.verify_full()
        c = lm.counters()
        assert c["created"] == 3 and c["freed"] == 1 and c["live"] == 2
    assert a.owner_view() == b.owner_view()


def _drive_replicated(mgr_sets, reqs_rounds, purge_at=None):
    """Protocol-ordered replay (opt -> freed -> TO -> finish -> freed)
    through replicated manager sets; returns each set's observable trace."""
    traces = []
    for mgrs in mgr_sets:
        waiters = [[] for _ in mgrs]
        trace = {"freed": [], "finished": 0}

        def deliver(frees_by_node, mgrs=mgrs, trace=trace):
            keys = [k for fr in frees_by_node for k in _keys(fr)]
            trace["freed"].extend(keys)
            for m in mgrs:
                m.on_ur_deliver_freed(keys)

        for rnd, reqs in enumerate(reqs_rounds):
            if purge_at == rnd:
                for m in mgrs:
                    m.purge_proc(1)
                waiters[1] = []
            deliver([sum((m.on_opt_deliver(r) for r in reqs), [])
                     for m in mgrs])
            for p, m in enumerate(mgrs):
                for r in reqs:
                    lors = m.on_to_deliver(r)
                    if r.proc == p and lors:
                        waiters[p].append(lors)
            fin = []
            for p, m in enumerate(mgrs):
                done = [g for g in waiters[p] if m.is_enabled(g)]
                waiters[p] = [g for g in waiters[p] if not m.is_enabled(g)]
                trace["finished"] += len(done)
                fin.append(sum((m.finished_xact(g) for g in done), []))
            deliver(fin)
        trace["owners"] = [m.owner_view() for m in mgrs]
        traces.append(trace)
    return traces


def _rounds(rng, n_rounds=6, per_round=12, n_procs=3, n_classes=10):
    rounds, rid = [], 0
    for _ in range(n_rounds):
        reqs = []
        for _ in range(per_round):
            rid += 1
            ccs = rng.choice(n_classes, size=int(rng.integers(1, 3)),
                             replace=False)
            reqs.append(_req(rid, rid % n_procs, tuple(int(c) for c in ccs)))
        rounds.append(reqs)
    return rounds


@pytest.mark.parametrize("seed", [7, 11, 23])
def test_random_histories_clean_and_trace_identical(seed):
    """Random replicated histories (with a mid-run view change) raise no
    violation on either sanitized manager, leave full reconciliation clean,
    and produce byte-identical traces to the unsanitized managers."""
    rng = np.random.default_rng(seed)
    rounds = _rounds(rng)
    plain = ([FGLLeaseManager(p, 10) for p in range(3)],
             [ShardedLeaseManager(p, 10, n_shards=2, jax_min=1)
              for p in range(3)])
    wrapped = _wrapped_sets(3, 10, n_shards=2, jax_min=1)
    t_plain = _drive_replicated(plain, rounds, purge_at=3)
    t_wrapped = _drive_replicated(wrapped, rounds, purge_at=3)
    assert t_wrapped == t_plain                     # pure observer
    assert t_wrapped[0] == t_wrapped[1]             # managers in lockstep
    for mgrs in wrapped:
        for m in mgrs:
            m.verify_full()
            assert m.counters()["checks"] > 0       # it actually looked


def test_hypothesis_histories_clean():
    """Property-based version of the above: arbitrary consistently-ordered
    histories keep both sanitized managers violation-free and in lockstep."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=40, deadline=None)
    @given(st.integers(0, 2 ** 31 - 1), st.integers(2, 4),
           st.booleans())
    def run(seed, n_procs, view_change):
        rng = np.random.default_rng(seed)
        rounds = _rounds(rng, n_rounds=4, per_round=8, n_procs=n_procs,
                         n_classes=6)
        oracle = [LeaseSanitizer(FGLLeaseManager(p, 6))
                  for p in range(n_procs)]
        batched = [LeaseSanitizer(
            ShardedLeaseManager(p, 6, n_shards=2, jax_min=1))
            for p in range(n_procs)]
        ta, tb = _drive_replicated(
            [oracle, batched], rounds, purge_at=2 if view_change else None)
        assert ta == tb
        for m in oracle + batched:
            m.verify_full()

    run()


# ---------------------------------------------------------------------------
# Full-simulation byte-identity: sanitize on == sanitize off
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("lease_mode", ["sequential", "batched"])
def test_sim_sanitize_on_is_byte_identical(lease_mode):
    def run(sanitize):
        cfg = SimConfig(duration_ms=300.0, warmup_ms=50.0, seed=3,
                        lease_mode=lease_mode, sanitize=sanitize)
        wl = BankWorkload(n_nodes=cfg.n_nodes, n_items=cfg.n_items,
                         locality=0.7)
        c = make_cluster("LILAC-TM-ST", wl, cfg)
        m = c.run()
        return c, m

    c_off, m_off = run(False)
    c_on, m_on = run(True)
    assert m_on.commits == m_off.commits
    assert m_on.commit_times == m_off.commit_times
    assert m_on.aborts == m_off.aborts
    for r_on, r_off in zip(c_on.replicas, c_off.replicas):
        np.testing.assert_array_equal(r_on.store.values, r_off.store.values)
        np.testing.assert_array_equal(r_on.store.versions,
                                      r_off.store.versions)
        assert r_on.lm.owner_view() == r_off.lm.owner_view()
    # the sanitized run actually checked something
    assert sum(r.lm.counters()["checks"] for r in c_on.replicas) > 0


def test_sim_sanitize_with_planner_and_failure():
    """Planner prefetches (prefetch-head rule) and a node failure
    (purge_proc conservation) both run clean under the sanitizer."""
    from repro.plan import PlanConfig

    plan = PlanConfig(epoch_ms=50.0, top_k=4, margin=0.0, min_frac=0.0,
                      min_events=2.0, hysteresis_epochs=2)
    cfg = SimConfig(duration_ms=500.0, warmup_ms=50.0, seed=5,
                    n_classes=32, plan=plan, sanitize=True)
    wl = BankWorkload(n_nodes=cfg.n_nodes, n_items=cfg.n_items, locality=0.6)
    c = make_cluster("LILAC-TM-ST", wl, cfg)
    c.events.schedule(250.0, lambda: c.gcs.fail(c.cfg.n_nodes - 1))
    m = c.run()
    assert m.commits > 0


# ---------------------------------------------------------------------------
# Certifier sanitize mode and the write-lock checker (pass cases)
# ---------------------------------------------------------------------------

def test_certifier_sanitize_clean_run():
    owner = {}
    c = StepCertifier(2, sanitize=True, owner_of=lambda s: owner.get(s, -1))

    class R:
        def __init__(self, sid):
            self.sid = sid

    owner[4] = 0
    c.bump(4, 1)
    c.enqueue(0, R(4), 1)
    passed, aborted, _ = c.drain(0)
    assert len(passed) == 1 and not aborted
    # ownership moves with a fresh bump: the stale forward aborts cleanly
    c.enqueue(0, R(4), 1)
    owner[4] = 1
    c.bump(4, 2)
    passed, aborted, _ = c.drain(0)
    assert not passed and len(aborted) == 1


def test_check_write_locks_clean():
    owners = np.array([0, 1, -1], np.int32)
    item_cc = np.array([0, 0, 1, 2], np.int32)
    locks = np.array([0, 0, 1, 0], np.int32)   # cc=1 leased to proc 1

    class T:
        def __init__(self, txid, writes):
            self.txid = txid
            self.write_set = {w: 1.0 for w in writes}

    n = check_write_locks(0, owners, item_cc, locks,
                          [T(1, [0, 3]), T(2, [2])], [True, False])
    assert n == 2
    assert check_write_locks(0, owners, None, None, [], []) == 0


def test_sanitizer_error_carries_invariant():
    err = SanitizerError("single-owner", "details here")
    assert isinstance(err, AssertionError)
    assert err.invariant == "single-owner"
    assert "single-owner" in str(err)
