"""Structural congruence of :mod:`repro.dist.sharding` spec trees.

The spec trees must mirror the ``init_params`` / ``init_cache`` pytrees
exactly — ``jax.tree.map`` across (tree, specs) is how every consumer zips
them — and every rule must degrade to replication on a mesh the dim sizes
don't divide (the 1-device CPU mesh exercises exactly that path).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import get_smoke_config
from repro.dist import sharding as shd
from repro.models import decoder
from repro.models.common import init_params, param_shapes

ARCHS = ["glm4-9b", "mixtral-8x7b", "deepseek-v2-236b", "mamba2-780m",
         "gemma3-27b"]


def cpu_mesh() -> Mesh:
    n = jax.device_count()
    return jax.make_mesh((n, 1), ("data", "model"))


def test_mesh_axes_split():
    ax = shd.MeshAxes.for_mesh(cpu_mesh())
    assert ax.batch == ("data",) and ax.model == "model"
    devs = np.array(jax.devices()).reshape(1, jax.device_count(), 1)
    ax3 = shd.MeshAxes.for_mesh(Mesh(devs, ("pod", "data", "model")))
    assert ax3.batch == ("pod", "data") and ax3.model == "model"
    # a mesh with no model axis is pure data parallelism, never megatron
    dp = Mesh(devs.reshape(1, -1), ("pod", "data"))
    ax_dp = shd.MeshAxes.for_mesh(dp)
    assert ax_dp.batch == ("pod", "data") and ax_dp.model_size(dp) == 1


@pytest.mark.parametrize("arch", ARCHS)
def test_param_shardings_congruent_with_init_params(arch):
    cfg = dataclasses.replace(get_smoke_config(arch), dtype="float32")
    mesh = cpu_mesh()
    params = init_params(cfg, jax.random.PRNGKey(0))
    shards = shd.param_shardings(cfg, mesh)
    assert jax.tree.structure(params) == jax.tree.structure(shards)
    assert all(isinstance(s, NamedSharding) for s in jax.tree.leaves(shards))
    # congruent trees zip: this is the exact device_put pattern consumers use
    placed = jax.tree.map(jax.device_put, params, shards)
    assert jax.tree.structure(placed) == jax.tree.structure(params)


@pytest.mark.parametrize("arch", ARCHS)
def test_cache_pspecs_congruent_with_init_cache(arch):
    cfg = dataclasses.replace(get_smoke_config(arch), dtype="float32")
    mesh = cpu_mesh()
    batch = 4
    tree = jax.eval_shape(lambda: decoder.init_cache(cfg, batch, 32, jnp.float32))
    specs = shd.cache_pspecs(cfg, mesh, tree, batch)
    assert jax.tree.structure(tree) == jax.tree.structure(
        specs, is_leaf=lambda x: isinstance(x, P))
    # the dryrun zip: struct tree × spec tree -> sharded struct tree
    structs = jax.tree.map(
        lambda s, p: jax.ShapeDtypeStruct(
            s.shape, s.dtype, sharding=NamedSharding(mesh, p)),
        tree, specs)
    assert jax.tree.structure(structs) == jax.tree.structure(tree)


def test_param_specs_follow_megatron_rules():
    """On a divisible mesh the name rules shard the intended dims."""
    cfg = dataclasses.replace(get_smoke_config("mixtral-8x7b"), dtype="float32")
    devs = np.array(jax.devices()).reshape(1, jax.device_count())
    mesh = Mesh(devs, ("data", "model"))  # model == device_count
    msize = int(mesh.shape["model"])
    specs = shd.param_pspecs(cfg, mesh)
    shapes = param_shapes(cfg, model_size=msize)

    flat_specs = jax.tree_util.tree_flatten_with_path(
        specs, is_leaf=lambda x: isinstance(x, P))[0]
    flat_shapes = jax.tree.leaves(shapes, is_leaf=lambda s: isinstance(s, tuple))
    for (path, spec), shape in zip(flat_specs, flat_shapes):
        name = path[-1].key
        sharded_dims = [i for i, a in enumerate(spec) if a is not None]
        if msize == 1:
            assert sharded_dims == [], (name, spec)
            continue
        for i in sharded_dims:          # every sharded dim must divide
            assert shape[i] % msize == 0, (name, shape, spec)
        if name in ("wq", "wk", "wv") and shape[-1] % msize == 0:
            assert spec[len(shape) - 1] == "model", (name, spec)
        if name == "wo" and shape[-2] % msize == 0:
            assert spec[len(shape) - 2] == "model", (name, spec)
        if name in ("ln_attn", "ln_mlp", "final_norm", "router"):
            assert sharded_dims == [], (name, spec)


def test_batch_pspecs_cover_train_and_decode_inputs():
    cfg = dataclasses.replace(get_smoke_config("glm4-9b"), dtype="float32")
    mesh = cpu_mesh()
    n_data = int(mesh.shape["data"])
    train = {
        "tokens": jax.ShapeDtypeStruct((8 * n_data, 32), jnp.int32),
        "positions": jax.ShapeDtypeStruct((8 * n_data, 32), jnp.int32),
        "labels": jax.ShapeDtypeStruct((8 * n_data, 32), jnp.int32),
    }
    ps = shd.batch_pspecs(cfg, mesh, train)
    assert set(ps) == set(train)
    if n_data > 1:
        assert ps["tokens"][0] == ("data",)
    decode = {
        "tokens": jax.ShapeDtypeStruct((8 * n_data,), jnp.int32),
        "pos": jax.ShapeDtypeStruct((), jnp.int32),
    }
    ps = shd.batch_pspecs(cfg, mesh, decode)
    assert ps["pos"] == P()
    # M-RoPE positions [3, B, S]: the batch dim is dim 1, never the sections
    mrope = {"positions": jax.ShapeDtypeStruct((3, 8 * n_data, 32), jnp.int32)}
    ps = shd.batch_pspecs(cfg, mesh, mrope)
    assert ps["positions"][0] is None


class _Key:
    def __init__(self, k):
        self.key = k


def _leaf_spec(names, shape, bdim, ssize, msize=1):
    path = tuple(_Key(n) for n in names)
    leaf = jax.ShapeDtypeStruct(tuple(shape), jnp.float32)
    return shd._cache_leaf_spec(path, leaf, bdim, ("data",), "model", msize,
                                "seq", ssize)


def test_mesh_axes_seq_split():
    """A ``seq`` axis is recognized and kept out of the batch axes."""
    devs = np.array(jax.devices()).reshape(1, jax.device_count(), 1)
    mesh = Mesh(devs, ("data", "seq", "model"))
    ax = shd.MeshAxes.for_mesh(mesh)
    assert ax.batch == ("data",) and ax.seq == "seq"
    assert ax.seq_size(mesh) == jax.device_count()
    # a seq-less mesh reports seq_size 1
    m2 = cpu_mesh()
    ax2 = shd.MeshAxes.for_mesh(m2)
    assert ax2.seq is None and ax2.seq_size(m2) == 1


def test_seq_rule_shards_attention_seq_dims():
    """GQA k/v and MLA c_kv/k_pe shard their seq dim over the seq axis —
    in both unrolled (bdim 0) and group-stacked (bdim 1) layouts — while
    the mamba conv/ssm state and indivisible lengths stay whole."""
    # GQA prefix [B, S, n_kv, hd] and body [G, B, S, n_kv, hd]
    s = _leaf_spec(("attn", "k"), (4, 32, 2, 16), 0, ssize=4)
    assert s[1] == "seq"
    s = _leaf_spec(("attn", "v"), (2, 4, 32, 2, 16), 1, ssize=4)
    assert s[2] == "seq"
    # MLA latent caches [B, S, r]
    s = _leaf_spec(("attn", "c_kv"), (4, 32, 24), 0, ssize=4)
    assert s[1] == "seq"
    s = _leaf_spec(("attn", "k_pe"), (2, 4, 32, 8), 1, ssize=4)
    assert s[2] == "seq"
    # indivisible seq length: replicated, not rejected
    s = _leaf_spec(("attn", "k"), (4, 30, 2, 16), 0, ssize=4)
    assert s[1] is None
    # seq axis of size 1 (smoke mesh): no seq sharding
    s = _leaf_spec(("attn", "k"), (4, 32, 2, 16), 0, ssize=1)
    assert s[1] is None
    # mamba state has no seq dim to shard
    s = _leaf_spec(("mamba", "conv"), (4, 3, 96), 0, ssize=3)
    assert all(a is None or a == ("data",) for a in s)
    s = _leaf_spec(("mamba", "ssm"), (4, 8, 16, 16), 0, ssize=4)
    assert s[1] is None


def test_seq_rule_composes_with_kv_head_sharding():
    """On a seq+model mesh a GQA cache shards seq AND kv heads at once."""
    s = _leaf_spec(("attn", "k"), (4, 32, 4, 16), 0, ssize=4, msize=2)
    assert s[1] == "seq" and s[2] == "model"


@pytest.mark.parametrize("arch", ["glm4-9b", "deepseek-v2-236b"])
def test_cache_pspecs_congruent_on_seq_mesh(arch):
    """cache_pspecs stays congruent with init_cache on a seq-bearing mesh
    (1-device host: the seq axis is size 1, so everything replicates but
    the tree structure and the zip must hold)."""
    cfg = dataclasses.replace(get_smoke_config(arch), dtype="float32")
    devs = np.array(jax.devices()).reshape(jax.device_count(), 1, 1)
    mesh = Mesh(devs, ("data", "seq", "model"))
    tree = jax.eval_shape(lambda: decoder.init_cache(cfg, 4, 32, jnp.float32))
    specs = shd.cache_pspecs(cfg, mesh, tree, 4)
    assert jax.tree.structure(tree) == jax.tree.structure(
        specs, is_leaf=lambda x: isinstance(x, P))
    structs = jax.tree.map(
        lambda s, p: jax.ShapeDtypeStruct(
            s.shape, s.dtype, sharding=NamedSharding(mesh, p)),
        tree, specs)
    assert jax.tree.structure(structs) == jax.tree.structure(tree)


def test_indivisible_dims_fall_back_to_replication():
    """A model-axis size that divides nothing must yield pure replication."""
    cfg = dataclasses.replace(
        get_smoke_config("glm4-9b"), dtype="float32",
        d_model=60, n_heads=3, n_kv_heads=3, head_dim=20, d_ff=90,
        vocab_size=255,
    )
    shapes = param_shapes(cfg)
    flat = jax.tree_util.tree_flatten_with_path(
        shapes, is_leaf=lambda s: isinstance(s, tuple))[0]
    for path, shape in flat:
        spec = shd._param_spec(path, shape, "model", 7)  # 7 divides no dim
        assert all(a is None for a in spec), (path, shape, spec)
        spec2 = shd._param_spec(path, shape, "model", 2)  # 60/90 divide by 2
        for i, a in enumerate(spec2):
            if a is not None:
                assert shape[i] % 2 == 0, (path, shape, spec2)
