"""Serving-layer tests: KV store migration, locality router, engine."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.dist.locality import price_moe_dispatch, price_session_dispatch
from repro.models import decoder
from repro.models.common import init_params
from repro.serve.engine import MultiPodEngine, RealBackend, Request, SimBackend
from repro.serve.kvcache import KVStore
from repro.serve.router import LocalityRouter

CFG = dataclasses.replace(get_smoke_config("glm4-9b"), dtype="float32")
CTX = decoder.RunCtx(mesh=None, use_kernel="ref")


def test_kvstore_export_import_roundtrip():
    """A migrated session decodes identically on the destination pod."""
    params = init_params(CFG, jax.random.PRNGKey(0))
    src, dst = KVStore(CFG, 4, 64, jnp.float32), KVStore(CFG, 4, 64, jnp.float32)
    s = src.alloc(42)
    # run a few decode steps on src to fill its cache column
    tok = jnp.zeros((4,), jnp.int32)
    pos = jnp.zeros((4,), jnp.int32)
    for t in range(3):
        logits, src.caches = decoder.decode_step(
            CFG, CTX, params, src.caches, tok, pos)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        pos = pos + 1
    s.length = 3
    s.last_token = int(tok[s.slot])
    logits_src, _ = decoder.decode_step(CFG, CTX, params, src.caches, tok, pos)

    blob = src.export_session(42)
    s2 = dst.import_session(blob)
    tok2 = jnp.zeros((4,), jnp.int32).at[s2.slot].set(s.last_token)
    # position vector: only the imported slot matters
    logits_dst, _ = decoder.decode_step(
        CFG, CTX, params, dst.caches, tok2, jnp.full((4,), 3, jnp.int32))
    np.testing.assert_allclose(
        np.asarray(logits_dst[s2.slot]), np.asarray(logits_src[s.slot]),
        rtol=1e-4, atol=1e-4)


def test_router_lease_stickiness_and_reuse():
    r = LocalityRouter(4, policy="short")
    d1 = r.route(origin=1, sid=7, session_len=10)
    assert d1.action == "local" and d1.target == 1
    # repeated requests from the owner are local (lease reuse)
    for _ in range(5):
        assert r.route(1, 7, 10).action == "local"
    assert r.metrics.lease_reuse_rate > 0.8


def test_router_forwards_to_owner():
    r = LocalityRouter(4, policy="short")
    r.route(0, 9, 0)                      # pod 0 becomes owner
    d = r.route(2, 9, 50)                 # long session: work migrates
    assert d.action == "forward" and d.target == 0


def test_router_overload_redirects():
    r = LocalityRouter(4, policy="short")
    r.route(0, 9, 0)
    r.observe_cpu(np.array([1.0, 0.0, 0.0, 0.0]))   # owner overloaded
    d = r.route(2, 9, 4)
    assert d.target != 0                  # constraint (3) excluded the owner


def test_engine_locality_improves_throughput():
    from repro.configs import get_config
    big = get_config("mixtral-8x7b")
    out = {}
    for P in (0.1, 0.9):
        router = LocalityRouter(4, policy="short", kv_bytes_per_token=2048.0 * 32)
        eng = MultiPodEngine(4, SimBackend(big), router)
        rng = np.random.default_rng(0)
        for _ in range(40):
            for _ in range(8):
                sid = int(rng.integers(64))
                origin = sid % 4 if rng.random() < P else int(rng.integers(4))
                eng.submit(Request(sid=sid, origin=origin, n_tokens=4))
            eng.run_step()
        eng.drain()
        out[P] = eng.metrics.as_dict()["tokens_per_s"]
    assert out[0.9] > 1.1 * out[0.1]


def test_price_session_dispatch_prefers_forward_for_long_sessions():
    short = price_session_dispatch(4096, 1024, kv_state_bytes=2_000)
    long_ = price_session_dispatch(4096, 1024, kv_state_bytes=50_000_000)
    assert long_.prefer_migration          # ship the request, not 50MB of KV
    assert long_.migrate_state_s > long_.migrate_work_s


def test_price_moe_dispatch_prefers_token_a2a_at_scale():
    c = price_moe_dispatch(tokens_per_device=4096, d_model=4096, top_k=2,
                           n_experts=8, d_expert=14336, ep_degree=8)
    assert c.prefer_dispatch               # a2a of tokens beats expert a-g


def test_kvstore_roundtrip_after_slot_recycling():
    """Export → free → import still decodes right when slot indices differ
    between pods (slots are recycled on the source, pre-claimed on the dst)."""
    params = init_params(CFG, jax.random.PRNGKey(1))
    src, dst = KVStore(CFG, 4, 64, jnp.float32), KVStore(CFG, 4, 64, jnp.float32)
    # churn the source ledger so sid 42 lands on a recycled slot
    for sid in (1, 2, 3):
        src.alloc(sid)
    src.free(2)
    s = src.alloc(42)                      # reuses slot freed by sid 2
    # occupy low slots on the destination so the import gets a different one
    for sid in (7, 8):
        dst.alloc(sid)

    tok = jnp.zeros((4,), jnp.int32)
    pos = jnp.zeros((4,), jnp.int32)
    for _ in range(3):
        logits, src.caches = decoder.decode_step(
            CFG, CTX, params, src.caches, tok, pos)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        pos = pos + 1
    s.length, s.last_token = 3, int(tok[s.slot])
    logits_src, _ = decoder.decode_step(CFG, CTX, params, src.caches, tok, pos)

    blob = src.export_session(42)
    src.free(42)
    s2 = dst.import_session(blob)
    assert s2.slot != s.slot               # the indirection must absorb this
    assert (s2.length, s2.last_token) == (3, s.last_token)
    tok2 = jnp.zeros((4,), jnp.int32).at[s2.slot].set(s.last_token)
    logits_dst, _ = decoder.decode_step(
        CFG, CTX, params, dst.caches, tok2, jnp.full((4,), 3, jnp.int32))
    np.testing.assert_allclose(
        np.asarray(logits_dst[s2.slot]), np.asarray(logits_src[s.slot]),
        rtol=1e-4, atol=1e-4)


def test_kvstore_mesh_allocates_with_cache_pspecs():
    """With a mesh, the store's trees carry the dist.sharding placements."""
    from jax.sharding import Mesh, NamedSharding

    from repro.dist.sharding import cache_shardings

    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    st = KVStore(CFG, 4, 32, jnp.float32, mesh=mesh)
    want = cache_shardings(CFG, mesh, st.caches, 4)
    for leaf, sh in zip(jax.tree.leaves(st.caches), jax.tree.leaves(want)):
        assert leaf.sharding.is_equivalent_to(sh, leaf.ndim)


def _crossover_len(r: LocalityRouter, handoff: float = 512.0) -> int:
    """session_len where forwarded work bytes == migrated state bytes."""
    work = r.request_bytes + r.response_bytes
    return int((work - handoff) / r.kv_bytes_per_token)


def test_router_priced_flips_at_byte_crossover():
    """The priced verdict alone picks the action: acquire below the byte
    crossover (KV lighter than the work description), forward above it."""
    for delta, want in ((0, "acquire"), (1, "forward")):
        r = LocalityRouter(4, policy="short", arbitration="priced",
                           kv_bytes_per_token=1.0)
        r.route(0, 5, 0)                   # pod 0 owns session 5
        d = r.route(2, 5, _crossover_len(r) + delta)
        assert d.action == want, (delta, d)
        assert d.target == (0 if want == "forward" else 2)
    # steps arbitration ignores the byte model: same inputs, always forward
    for delta in (0, 1):
        r = LocalityRouter(4, policy="short", arbitration="steps",
                           kv_bytes_per_token=1.0)
        r.route(0, 5, 0)
        assert r.route(2, 5, _crossover_len(r) + delta).action == "forward"


def test_router_hybrid_byte_model_breaks_disagreement():
    """SC step constants say forward; a featherweight KV says acquire —
    hybrid lets the byte model win and records the flip."""
    r = LocalityRouter(4, policy="short", arbitration="hybrid",
                       kv_bytes_per_token=1.0)
    r.route(0, 5, 0)
    d = r.route(2, 5, 1)                   # 1-byte KV state
    assert d.action == "acquire" and d.target == 2
    assert r.metrics.flips == 1


def test_route_decision_wire_s_set_on_every_branch():
    from repro.dist.locality import DCN_RTT_S

    r = LocalityRouter(4, policy="short", arbitration="priced",
                       kv_bytes_per_token=1.0)
    assert r.route(0, 5, 0).wire_s == 0.0              # local
    fwd = r.route(2, 5, 10**6)                         # forward to owner
    assert fwd.action == "forward" and fwd.wire_s > DCN_RTT_S
    acq = r.route(2, 6, 0)                             # new session, local
    assert acq.wire_s == 0.0
    acq = r.route(1, 5, 10)                            # tiny KV: acquire
    assert acq.action == "acquire" and acq.wire_s > DCN_RTT_S
    # both plans pay one RTT, so the gap between them is pure bytes
    assert fwd.wire_s != acq.wire_s


def test_engine_session_len_advances_once_per_sid_per_step():
    """Two queued requests on one sid must not double-advance session_len
    past the backend's cache length."""
    big = get_smoke_config("mixtral-8x7b")
    eng = MultiPodEngine(
        2, SimBackend(big), LocalityRouter(2, policy="short"))
    eng.submit(Request(sid=3, origin=0, n_tokens=2))
    eng.submit(Request(sid=3, origin=0, n_tokens=2))
    eng.run_step()
    assert eng.session_len[3] == 1
    assert eng.backend.lengths[(0, 3)] == 1
    eng.drain()
    assert eng.session_len[3] == eng.backend.lengths[(0, 3)] == 2


def test_engine_charges_priced_wire_time():
    """Wire time comes from price_session_dispatch (RTT included), not an
    ad-hoc bytes/bandwidth quotient."""
    from repro.dist.locality import DCN_RTT_S

    big = get_smoke_config("mixtral-8x7b")
    eng = MultiPodEngine(
        2, SimBackend(big),
        LocalityRouter(2, policy="short", kv_bytes_per_token=10_000.0))
    eng.submit(Request(sid=0, origin=0, n_tokens=1))   # pod 0 owns sid 0
    eng.run_step()
    base = eng.metrics.sim_time_s
    dec = eng.submit(Request(sid=0, origin=1, n_tokens=1))
    assert dec.action == "forward" and dec.wire_s >= DCN_RTT_S
    eng.run_step()
    assert eng.metrics.sim_time_s - base >= DCN_RTT_S


def test_engine_acquire_rehomes_queued_requests():
    """A lease move carries the session's pending work: requests queued on
    the old owner follow the KV cache to the acquiring pod."""
    big = get_smoke_config("mixtral-8x7b")
    eng = MultiPodEngine(
        2, SimBackend(big),
        LocalityRouter(2, policy="short", kv_bytes_per_token=1.0))
    eng.submit(Request(sid=4, origin=0, n_tokens=3))   # pod 0 owns, queues it
    dec = eng.submit(Request(sid=4, origin=1, n_tokens=3))
    assert dec.action == "acquire" and dec.target == 1  # tiny KV: state moves
    assert [r.sid for r in eng.queues[0]] == []
    assert [r.sid for r in eng.queues[1]] == [4, 4]
    eng.drain()                                        # both requests finish
    assert eng.metrics.tokens > 0 and not any(eng.queues)


def test_kvstore_roundtrip_when_n_groups_equals_n_slots():
    """The body caches' leading ``n_groups`` axis equals the slot count here
    (glm4-9b smoke has 2 scanned groups): the old shape-sniffing heuristic
    ``leaf.shape[0] != n_slots`` then picked the *group* axis as the batch
    axis and exported the wrong column.  The batch dim is now structural."""
    from repro.models.common import layer_plan

    n_slots = layer_plan(CFG).n_groups
    assert n_slots == 2                     # the collision this test needs
    params = init_params(CFG, jax.random.PRNGKey(2))
    src = KVStore(CFG, n_slots, 64, jnp.float32)
    dst = KVStore(CFG, n_slots, 64, jnp.float32)
    s = src.alloc(42)
    tok = jnp.zeros((n_slots,), jnp.int32)
    pos = jnp.zeros((n_slots,), jnp.int32)
    for _ in range(3):
        logits, src.caches = decoder.decode_step(
            CFG, CTX, params, src.caches, tok, pos)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        pos = pos + 1
    s.length, s.last_token = 3, int(tok[s.slot])
    logits_src, _ = decoder.decode_step(CFG, CTX, params, src.caches, tok, pos)

    blob = src.export_session(42)
    # the exported column must be one slot wide on the *batch* axis: body
    # leaves keep their full n_groups leading axis
    for leaf in jax.tree.leaves(blob["tree"]["body"]):
        assert leaf.shape[0] == n_slots and leaf.shape[1] == 1, leaf.shape
    # occupy a slot on dst so the imported session lands on a different one
    dst.alloc(7)
    s2 = dst.import_session(blob)
    tok2 = jnp.zeros((n_slots,), jnp.int32).at[s2.slot].set(s.last_token)
    logits_dst, _ = decoder.decode_step(
        CFG, CTX, params, dst.caches, tok2, jnp.full((n_slots,), 3, jnp.int32))
    np.testing.assert_allclose(
        np.asarray(logits_dst[s2.slot]), np.asarray(logits_src[s.slot]),
        rtol=1e-4, atol=1e-4)


def test_router_seq_shards_flips_near_crossover():
    """seq_shards feeds straight into the priced verdict: the same session
    length forwards on a whole-column router and acquires on a seq-sharded
    one (the state's per-hop bytes shrank 8x)."""
    for shards, want in ((1, "forward"), (8, "acquire")):
        r = LocalityRouter(4, policy="short", arbitration="priced",
                           kv_bytes_per_token=1.0, seq_shards=shards)
        r.route(0, 5, 0)                   # pod 0 owns session 5
        # 4x the work bytes: whole-column state clearly loses, 1/8-per-hop wins
        ln = 4 * int(r.request_bytes + r.response_bytes)
        d = r.route(2, 5, ln)
        assert d.action == want, (shards, d)


def test_engine_seq_shards_reprices_real_transfers():
    """RealBackend exposes its stores' seq_shards and the engine's re-pricing
    path uses it (sanity: attribute exists and is >= 1 without a mesh)."""
    params = init_params(CFG, jax.random.PRNGKey(0))
    backend = RealBackend(CFG, CTX, params, n_pods=2, n_slots=4, max_len=32)
    assert backend.seq_shards == 1
    assert backend.stores[0].seq_shards == 1


def test_router_freq_decays_with_clock():
    """Session-touch rates decay on the router clock (tick), so the LC
    attractor is rate-based: old bursts fade once time passes.  Rates live
    in ONE growable matrix (the planner-shared implementation), not a dict
    of per-sid trackers."""
    from repro.core.stats import DecayedFrequency

    r = LocalityRouter(2, policy="long", freq_tau_ms=100.0)
    assert isinstance(r.freq, DecayedFrequency) and r.freq.grow_cols
    for _ in range(8):
        r.route(0, 7, 4)
    hot = r.freq.rates(r._now)[0, 7]
    r.tick(1000.0)                          # 10 tau of idle time
    cold = r.freq.rates(r._now)[0, 7]
    assert cold < 1e-3 * hot
    r.evict(7)
    assert r.freq.rates(r._now)[0, 7] == 0.0


def test_engine_async_plan_epoch_kicks_then_harvests():
    """plan_async (the default): an epoch boundary KICKS scoring and the
    next step's start HARVESTS it, so the pending plan is observable
    between steps and the decode loop never stalls on the evaluation.
    Moves land one step later than synchronous planning, with live-
    ownership staleness re-checks at harvest — the steady-state outcome
    (the misplaced session re-homed to its hot pod) matches
    plan_async=False."""
    from repro.plan import PlacementPlanner

    big = get_smoke_config("mixtral-8x7b")

    def run(plan_async):
        router = LocalityRouter(2, policy="short",
                                kv_bytes_per_token=10_000.0)
        planner = PlacementPlanner.for_serving(2, 8)
        eng = MultiPodEngine(2, SimBackend(big), router, planner=planner,
                             plan_async=plan_async)
        eng.submit(Request(sid=5, origin=0, n_tokens=1))
        eng.run_step()                       # pod 0 takes first-touch ownership
        saw_pending = False
        for _ in range(40):                  # ...but pod 1 sends all traffic
            eng.submit(Request(sid=5, origin=1, n_tokens=1))
            eng.run_step()
            saw_pending |= eng._pending_plan is not None
        eng.drain()
        return eng, saw_pending

    eng_async, saw_pending = run(True)
    assert saw_pending                       # a kicked epoch outlived its step
    assert eng_async.metrics.plan_epochs > 0
    assert eng_async.planner.planned_moves >= 1
    assert eng_async.router.owner[5] == 1    # re-homed to the hot pod
    # the on-path accounting exists and is a sliver of simulated decode
    d = eng_async.metrics.as_dict()
    assert d["plan_block_s"] > 0.0

    eng_sync, saw_pending_sync = run(False)
    assert not saw_pending_sync              # sync epochs never leave a pending
    assert eng_sync.router.owner[5] == 1     # same steady state
