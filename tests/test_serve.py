"""Serving-layer tests: KV store migration, locality router, engine."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.dist.locality import price_moe_dispatch, price_session_dispatch
from repro.models import decoder
from repro.models.common import init_params
from repro.serve.engine import MultiPodEngine, RealBackend, Request, SimBackend
from repro.serve.kvcache import KVStore
from repro.serve.router import LocalityRouter

CFG = dataclasses.replace(get_smoke_config("glm4-9b"), dtype="float32")
CTX = decoder.RunCtx(mesh=None, use_kernel="ref")


def test_kvstore_export_import_roundtrip():
    """A migrated session decodes identically on the destination pod."""
    params = init_params(CFG, jax.random.PRNGKey(0))
    src, dst = KVStore(CFG, 4, 64, jnp.float32), KVStore(CFG, 4, 64, jnp.float32)
    s = src.alloc(42)
    # run a few decode steps on src to fill its cache column
    tok = jnp.zeros((4,), jnp.int32)
    pos = jnp.zeros((4,), jnp.int32)
    for t in range(3):
        logits, src.caches = decoder.decode_step(
            CFG, CTX, params, src.caches, tok, pos)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        pos = pos + 1
    s.length = 3
    s.last_token = int(tok[s.slot])
    logits_src, _ = decoder.decode_step(CFG, CTX, params, src.caches, tok, pos)

    blob = src.export_session(42)
    s2 = dst.import_session(blob)
    tok2 = jnp.zeros((4,), jnp.int32).at[s2.slot].set(s.last_token)
    # position vector: only the imported slot matters
    logits_dst, _ = decoder.decode_step(
        CFG, CTX, params, dst.caches, tok2, jnp.full((4,), 3, jnp.int32))
    np.testing.assert_allclose(
        np.asarray(logits_dst[s2.slot]), np.asarray(logits_src[s.slot]),
        rtol=1e-4, atol=1e-4)


def test_router_lease_stickiness_and_reuse():
    r = LocalityRouter(4, policy="short")
    d1 = r.route(origin=1, sid=7, session_len=10)
    assert d1.action == "local" and d1.target == 1
    # repeated requests from the owner are local (lease reuse)
    for _ in range(5):
        assert r.route(1, 7, 10).action == "local"
    assert r.metrics.lease_reuse_rate > 0.8


def test_router_forwards_to_owner():
    r = LocalityRouter(4, policy="short")
    r.route(0, 9, 0)                      # pod 0 becomes owner
    d = r.route(2, 9, 50)                 # long session: work migrates
    assert d.action == "forward" and d.target == 0


def test_router_overload_redirects():
    r = LocalityRouter(4, policy="short")
    r.route(0, 9, 0)
    r.observe_cpu(np.array([1.0, 0.0, 0.0, 0.0]))   # owner overloaded
    d = r.route(2, 9, 4)
    assert d.target != 0                  # constraint (3) excluded the owner


def test_engine_locality_improves_throughput():
    from repro.configs import get_config
    big = get_config("mixtral-8x7b")
    out = {}
    for P in (0.1, 0.9):
        router = LocalityRouter(4, policy="short", kv_bytes_per_token=2048.0 * 32)
        eng = MultiPodEngine(4, SimBackend(big), router)
        rng = np.random.default_rng(0)
        for _ in range(40):
            for _ in range(8):
                sid = int(rng.integers(64))
                origin = sid % 4 if rng.random() < P else int(rng.integers(4))
                eng.submit(Request(sid=sid, origin=origin, n_tokens=4))
            eng.run_step()
        eng.drain()
        out[P] = eng.metrics.as_dict()["tokens_per_s"]
    assert out[0.9] > 1.1 * out[0.1]


def test_price_session_dispatch_prefers_forward_for_long_sessions():
    short = price_session_dispatch(4096, 1024, kv_state_bytes=2_000)
    long_ = price_session_dispatch(4096, 1024, kv_state_bytes=50_000_000)
    assert long_.prefer_migration          # ship the request, not 50MB of KV
    assert long_.migrate_state_s > long_.migrate_work_s


def test_price_moe_dispatch_prefers_token_a2a_at_scale():
    c = price_moe_dispatch(tokens_per_device=4096, d_model=4096, top_k=2,
                           n_experts=8, d_expert=14336, ep_degree=8)
    assert c.prefer_dispatch               # a2a of tokens beats expert a-g
