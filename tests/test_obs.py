"""repro.obs tests: quantile metrics, trace schema, determinism, zero-cost."""
import json
import math

import numpy as np
import pytest

from repro.core import BankWorkload, SimConfig, make_cluster
from repro.obs import trace as obs_trace
from repro.obs.metrics import (Counter, Gauge, Histogram, MetricSet,
                               MonotonicSampler, Registry)
from repro.obs.trace import NULL, TraceRecorder


# --------------------------------------------------------------------------
# metrics
# --------------------------------------------------------------------------

def test_histogram_quantiles_match_numpy():
    rng = np.random.default_rng(0)
    for samples in (rng.exponential(3.0, 257), rng.normal(10.0, 2.0, 64),
                    np.array([4.2]), np.arange(100.0)):
        h = Histogram("lat")
        for v in samples:
            h.observe(float(v))
        for q in (0.0, 0.5, 0.9, 0.99, 1.0):
            want = float(np.percentile(samples, 100.0 * q))
            assert h.quantile(q) == pytest.approx(want, rel=1e-12, abs=1e-12)


def test_histogram_pow2_bucket_edges():
    h = Histogram("b")
    # exact powers of two land in their own bucket [2^k, 2^(k+1)),
    # just-below values in the one underneath
    for v in (1.0, 2.0, 4.0, 8.0):
        h.observe(v)
    h.observe(3.999999)
    h.observe(0.0)
    h.observe(-1.5)
    assert h.buckets[0] == 1          # [1, 2)
    assert h.buckets[1] == 2          # [2, 4): 2.0 and 3.999999
    assert h.buckets[2] == 1          # [4, 8)
    assert h.buckets[3] == 1          # [8, 16)
    assert h.buckets["le_zero"] == 2  # 0.0 and -1.5
    assert h.count == 7
    # fractional values bucket by floor(log2): 0.3 -> k=-2
    h.observe(0.3)
    assert h.buckets[math.floor(math.log2(0.3))] == 1


def test_histogram_slo_attainment_and_summary():
    h = Histogram("lat")
    assert h.quantile(0.5) is None
    assert h.slo_attainment(1.0) is None
    for v in range(1, 11):
        h.observe(float(v))
    assert h.slo_attainment(5.0) == 0.5
    assert h.slo_attainment(10.0) == 1.0
    assert h.slo_attainment(0.5) == 0.0
    s = h.summary()
    assert s["count"] == 10
    assert s["p50"] == pytest.approx(np.percentile(range(1, 11), 50))
    assert set(s) == {"count", "p50", "p90", "p99"}


def test_registry_and_scalar_metrics():
    r = Registry()
    c = r.counter("steps")
    c.inc()
    c.inc(4)
    assert r.counter("steps").value == 5       # same object on re-access
    g = r.gauge("depth")
    g.set(3.5)
    r.histogram("lat").observe(2.0)
    d = r.as_dict()
    assert d["steps"] == 5 and d["depth"] == 3.5
    assert d["lat"]["count"] == 1
    assert "steps" in r and "missing" not in r
    assert isinstance(r.counter("steps"), Counter)
    assert isinstance(r.gauge("depth"), Gauge)


def test_metricset_facade_routes_to_registry():
    class M(MetricSet):
        FIELDS = {"forwards": 0, "wire_s": 0.0}

    m = M()
    m.forwards += 1
    m.forwards += 2
    m.wire_s += 0.25
    assert m.forwards == 3
    assert m.registry.counter("forwards").value == 3
    assert m.as_dict() == {"forwards": 3, "wire_s": 0.25}
    # non-FIELDS attributes behave like normal instance attributes
    m.note = "x"
    assert m.note == "x" and "note" not in m.registry
    with pytest.raises(AttributeError):
        _ = m.nonexistent


def test_monotonic_sampler_with_fake_clock():
    ticks = iter([10.0, 10.5, 11.0, 13.25])
    s = MonotonicSampler(clock=lambda: next(ticks))
    assert s.lap() == 0.0            # lap before mark is a no-op
    s.mark()
    assert s.lap() == pytest.approx(0.5)
    assert s.lap() == 0.0            # interval consumed
    s.mark()
    assert s.lap() == pytest.approx(2.25)


# --------------------------------------------------------------------------
# trace recorder + schema
# --------------------------------------------------------------------------

def test_trace_schema_roundtrip(tmp_path):
    tr = TraceRecorder()
    tr.span("exec", "node0/t0", 1.0, 2.5, txid=7)
    tr.instant("forward", "node0/dtd", ts=1.25, target=1)
    tr.abegin("lease-round", "node1/lease", 42, ts=0.5, ccs=3)
    tr.aend("lease-round", "node1/lease", 42, ts=3.5)
    tr.counter("depth", "node0/gcs", 2.0, 9)
    tr.set_time(8.0)
    tr.instant("late", "node0/gcs")          # ts=None -> last set_time
    assert len(tr) == 6

    path = tmp_path / "t.json"
    tr.export(str(path))
    raw = json.loads(path.read_text())
    assert set(raw) == {"traceEvents", "displayTimeUnit"}
    events = obs_trace.load(str(path))
    meta = [e for e in events if e["ph"] == "M"]
    data = [e for e in events if e["ph"] != "M"]
    # every track got process_name + thread_name metadata
    assert {e["name"] for e in meta} == {"process_name", "thread_name"}
    procs = {e["args"]["name"] for e in meta if e["name"] == "process_name"}
    assert procs == {"node0", "node1"}
    # ph/ts schema: X carries dur, i carries s, b/e carry id; ts is us
    by_ph = {e["ph"]: e for e in data}
    assert by_ph["X"]["dur"] == 2500.0 and by_ph["X"]["ts"] == 1000.0
    assert by_ph["i"]["s"] == "t"
    assert by_ph["b"]["id"] == "42" and by_ph["e"]["id"] == "42"
    assert by_ph["C"]["args"]["value"] == 9
    assert [e["name"] for e in data] == ["exec", "forward", "lease-round",
                                         "lease-round", "depth", "late"]
    assert data[-1]["ts"] == 8000.0
    # distinct tracks get distinct (pid, tid) pairs
    keys = {(e["pid"], e["tid"]) for e in data}
    assert len(keys) == 4

    # summarize sees X durations and matched b/e pairs
    rows = {r["name"]: r for r in obs_trace.summarize(events)}
    assert rows["exec"]["total_us"] == 2500.0
    assert rows["lease-round"]["total_us"] == 3000.0
    assert rows["forward"]["count"] == 1
    # bare-list form loads too
    bare = tmp_path / "bare.json"
    bare.write_text(json.dumps(events))
    assert obs_trace.load(str(bare)) == events


def test_trace_diff_and_null_recorder():
    a = TraceRecorder()
    a.span("exec", "n0", 0.0, 1.0)
    b = TraceRecorder()
    b.span("exec", "n0", 0.0, 1.0)
    b.span("exec", "n0", 2.0, 3.0)
    b.instant("abort", "n0", ts=1.0)
    rows = {r["name"]: r for r in obs_trace.diff(a.to_events(), b.to_events())}
    assert rows["exec"]["d_count"] == 1
    assert rows["exec"]["d_total_us"] == pytest.approx(3000.0)
    assert rows["abort"]["count_a"] == 0 and rows["abort"]["count_b"] == 1
    # the disabled recorder records nothing and reports enabled=False
    assert NULL.enabled is False and TraceRecorder.enabled is True
    NULL.span("x", "t", 0.0, 1.0)
    NULL.instant("x", "t")
    NULL.counter("x", "t", 0.0, 1)


def test_install_uninstall_singleton():
    assert obs_trace.TRACE is NULL
    rec = TraceRecorder()
    obs_trace.install(rec)
    try:
        assert obs_trace.TRACE is rec
    finally:
        obs_trace.uninstall()
    assert obs_trace.TRACE is NULL


# --------------------------------------------------------------------------
# sim: determinism + zero-perturbation
# --------------------------------------------------------------------------

def _sim_result(trace: bool, lease_mode: str, seed: int = 0):
    cfg = SimConfig(duration_ms=60.0, warmup_ms=10.0, seed=seed,
                    lease_mode=lease_mode, trace=trace)
    wl = BankWorkload(n_nodes=cfg.n_nodes, n_items=cfg.n_items, locality=0.8)
    c = make_cluster("LILAC-TM-OPT", wl, cfg)
    m = c.run()
    return c, {"throughput": c.throughput(), "forwards": m.forwards,
               "aborts": m.aborts, "reuse": m.lease_reuse_rate()}


@pytest.mark.parametrize("lease_mode", ["batched", "sequential"])
def test_tracing_does_not_perturb_sim(lease_mode):
    _, off = _sim_result(False, lease_mode)
    c_on, on = _sim_result(True, lease_mode)
    assert off == on
    assert c_on.trace is not None and len(c_on.trace) > 0


def test_seeded_traces_are_byte_identical(tmp_path):
    paths = []
    for i in range(2):
        c, _ = _sim_result(True, "batched")
        p = tmp_path / f"run{i}.json"
        c.trace.export(str(p))
        paths.append(p)
    assert paths[0].read_bytes() == paths[1].read_bytes()
    # and the trace actually carries the protocol vocabulary
    names = {e["name"] for e in obs_trace.load(str(paths[0]))
             if e["ph"] != "M"}
    assert "exec" in names and "lease-round" in names
    assert "certify-batch" in names


def test_untraced_sim_allocates_no_recorder():
    cfg = SimConfig(duration_ms=20.0, warmup_ms=5.0, seed=0)
    wl = BankWorkload(n_nodes=cfg.n_nodes, n_items=cfg.n_items)
    c = make_cluster("LILAC-TM-OPT", wl, cfg)
    assert c.trace is None
    c.run()


# --------------------------------------------------------------------------
# engine: per-pod breakdown + zero-perturbation
# --------------------------------------------------------------------------

def _engine_run(trace, pods=2, sessions=8, steps=8, seed=0):
    from repro.configs import get_config
    from repro.serve.engine import MultiPodEngine, Request, SimBackend
    from repro.serve.router import LocalityRouter

    cfg = get_config("mixtral-8x7b")
    kv = 2.0 * 2 * cfg.n_kv_heads * cfg.head_dim * cfg.n_layers \
        if cfg.n_kv_heads else 4096.0 * cfg.n_layers
    router = LocalityRouter(pods, policy="short", arbitration="priced",
                            kv_bytes_per_token=kv)
    eng = MultiPodEngine(pods, SimBackend(cfg), router, trace=trace)
    rng = np.random.default_rng(seed)
    for _ in range(steps):
        for _ in range(2 * pods):
            sid = int(rng.integers(sessions))
            origin = sid % pods if rng.random() < 0.5 \
                else int(rng.integers(pods))
            eng.submit(Request(sid=sid, origin=origin, n_tokens=4))
        eng.run_step()
    eng.drain()
    return eng


def test_engine_per_pod_breakdown_sums_to_fleet():
    eng = _engine_run(trace=False)
    m = eng.metrics.as_dict()
    per_pod = m["per_pod"]
    assert set(per_pod) == {0, 1}
    assert sum(p["forwards"] for p in per_pod.values()) == m["forwards"]
    assert sum(p["local"] for p in per_pod.values()) == m["local"]
    assert sum(p["wire_GB"] for p in per_pod.values()) == \
        pytest.approx(m["wire_GB"])
    # fleet token-latency quantiles present and ordered
    assert m["token_lat_p50_s"] <= m["token_lat_p90_s"] \
        <= m["token_lat_p99_s"]
    for p in per_pod.values():
        assert {"token_lat_p50_s", "token_lat_p99_s"} <= set(p)
    # the per-pod histograms partition the fleet histogram
    fleet = eng.metrics.token_latency()
    assert sum(eng.metrics.token_latency(p).count for p in per_pod) \
        == fleet.count


def test_engine_tracing_does_not_perturb_metrics():
    off = _engine_run(trace=False).metrics.as_dict()
    eng_on = _engine_run(trace=True)
    assert eng_on.trace is not None and len(eng_on.trace) > 0
    assert off == eng_on.metrics.as_dict()
    names = {e["name"] for e in eng_on.trace.to_events() if e["ph"] != "M"}
    assert {"wire", "certify", "decode"} <= names


def test_engine_trace_flag_forms():
    assert _engine_run(trace=None, steps=1).trace is None
    assert _engine_run(trace=False, steps=1).trace is None
    rec = TraceRecorder()
    assert _engine_run(trace=rec, steps=1).trace is rec


# --------------------------------------------------------------------------
# CLI
# --------------------------------------------------------------------------

def test_repro_trace_cli(tmp_path, capsys):
    from repro.obs import cli

    out = tmp_path / "trace.json"
    rc = cli.main(["export", "--out", str(out), "--steps", "4",
                   "--sessions", "4", "--no-moe"])
    assert rc == 0
    events = obs_trace.load(str(out))
    assert any(e["ph"] == "X" for e in events)
    assert cli.main(["summarize", str(out)]) == 0
    assert cli.main(["diff", str(out), str(out)]) == 0
    text = capsys.readouterr().out
    assert "no per-name differences" in text
    assert cli.main([]) == 2
    assert cli.main(["--help"]) == 0
