"""Unit tests for the lease managers (Algorithm 1 + coarse ALC baseline)."""
import pytest

from repro.core.lease import ALCLeaseManager, FGLLeaseManager, LeaseRequest


def _req(req_id, proc, ccs, coarse=False):
    return LeaseRequest(req_id=req_id, proc=proc, ccs=tuple(sorted(ccs)),
                        coarse=coarse)


def test_fgl_piggyback_fig2_scenario():
    """Fig. 2: T2 on {1,3,4} piggybacks on T0's {1,2} + T1's {2,3,4} LORs."""
    lm = FGLLeaseManager(proc=0, n_classes=8)
    lm.on_to_deliver(_req(1, 0, (1, 2)))          # T0
    lm.on_to_deliver(_req(2, 0, (2, 3, 4)))       # T1
    got = lm.try_piggyback(frozenset({1, 3, 4}))
    assert got is not None
    assert sorted(l.cc for l in got) == [1, 3, 4]
    # piggybacked LORs counted an extra active transaction
    assert all(l.activeXacts == 2 for l in got)


def test_alc_cannot_reuse_across_leases():
    """The same scenario under coarse ALC requires a new lease request."""
    lm = ALCLeaseManager(proc=0, n_classes=8)
    lm.on_to_deliver(_req(1, 0, (1, 2), coarse=True))
    lm.on_to_deliver(_req(2, 0, (2, 3, 4), coarse=True))
    assert lm.try_piggyback(frozenset({1, 3, 4})) is None
    # subset of a single lease is reusable
    assert lm.try_piggyback(frozenset({3, 4})) is not None


def test_fgl_blocked_lor_not_reusable():
    lm = FGLLeaseManager(proc=0, n_classes=4)
    lm.on_to_deliver(_req(1, 0, (1,)))
    # remote request on cc=1 opt-delivered -> local LOR blocked (fairness)
    lm.on_opt_deliver(_req(2, 1, (1,)))
    assert lm.try_piggyback(frozenset({1})) is None


def test_opt_deliver_frees_idle_head_lor():
    lm = FGLLeaseManager(proc=0, n_classes=4)
    lors = lm.on_to_deliver(_req(1, 0, (1,)))
    lm.finished_xact(lors)                        # drains activeXacts to 0
    to_free = lm.on_opt_deliver(_req(2, 1, (1,)))
    assert to_free and to_free[0] is lors[0]


def test_finished_xact_frees_blocked_lor_on_drain():
    lm = FGLLeaseManager(proc=0, n_classes=4)
    lors = lm.on_to_deliver(_req(1, 0, (1,)))
    assert lm.on_opt_deliver(_req(2, 1, (1,))) == []   # busy: not freed yet
    to_free = lm.finished_xact(lors)
    assert to_free == [lors[0]]


def test_is_enabled_requires_queue_head():
    lm = FGLLeaseManager(proc=0, n_classes=4)
    first = lm.on_to_deliver(_req(1, 1, (2,)))    # remote holds the lease
    mine = lm.on_to_deliver(_req(2, 0, (2,)))
    assert not lm.is_enabled(mine)
    lm.on_ur_deliver_freed([first[0].key()])
    assert lm.is_enabled(mine)


def test_ur_deliver_dequeues():
    lm = FGLLeaseManager(proc=0, n_classes=4)
    lors = lm.on_to_deliver(_req(1, 1, (0, 3)))
    assert lm.head_owner(0) == 1 and lm.head_owner(3) == 1
    lm.on_ur_deliver_freed([l.key() for l in lors])
    assert lm.head_owner(0) == -1 and lm.head_owner(3) == -1


def test_purge_proc_reclaims_failed_member():
    lm = FGLLeaseManager(proc=0, n_classes=4)
    lm.on_to_deliver(_req(1, 1, (0, 1)))
    mine = lm.on_to_deliver(_req(2, 0, (0,)))
    assert not lm.is_enabled(mine)
    lm.purge_proc(1)                              # view change: node 1 failed
    assert lm.is_enabled(mine)


def test_pending_opt_blocks_lors_born_after():
    """LORs enqueued while a conflicting request is opt-pending are born
    blocked (the opt/TO race the module docstring documents)."""
    lm = FGLLeaseManager(proc=0, n_classes=4)
    lm.on_opt_deliver(_req(2, 1, (1,)))           # remote req, TO pending
    lors = lm.on_to_deliver(_req(1, 0, (1,)))     # mine arrives after
    assert lors[0].blocked


def test_fgl_missing_ccs():
    lm = FGLLeaseManager(proc=0, n_classes=8)
    lm.on_to_deliver(_req(1, 0, (1, 2)))
    assert lm.missing_ccs(frozenset({1, 5})) == frozenset({5})
    assert lm.missing_ccs(frozenset({1, 2})) == frozenset()
