"""Equivalence and regression tests for the sharded batched lease manager.

The contract under test: :class:`repro.core.lease_batched.ShardedLeaseManager`
is *byte-identical* to the Algorithm 1 oracle
(:class:`repro.core.lease.FGLLeaseManager`) — same frees in the same order,
same owner views, same enablement — while doing its queue work in batched
array ops.  Plus the failure-path / bookkeeping regressions that ride this
PR: planner view-change purge, whole-request ``purge_proc``, lease-epoch
tombstones with stat-matrix compaction, and engine session eviction.
"""
import dataclasses

import numpy as np
import pytest

from repro.core import BankWorkload, SimConfig, make_cluster
from repro.core.lease import FGLLeaseManager, LeaseRequest
from repro.core.lease_batched import ShardedLeaseManager, _settle_np


def _req(req_id, proc, ccs):
    return LeaseRequest(req_id=req_id, proc=proc, ccs=tuple(sorted(ccs)))


def _mgrs(n_procs, n_classes, **kw):
    """(oracle replicas, batched replicas) over the same class space."""
    return ([FGLLeaseManager(p, n_classes) for p in range(n_procs)],
            [ShardedLeaseManager(p, n_classes, **kw) for p in range(n_procs)])


def _keys(lors):
    return [l.key() for l in lors]


# ---------------------------------------------------------------------------
# Manager-level equivalence
# ---------------------------------------------------------------------------

def test_scripted_ops_match_oracle():
    """A hand-rolled opt/TO/free/finish script produces identical frees,
    owner views and enablement through both managers."""
    (a,), (b,) = _mgrs(1, 8, n_shards=2)
    remote = FGLLeaseManager(1, 8)       # drives deliveries for proc 1
    for lm in (a, b):
        lors = lm.on_to_deliver(_req(1, 0, (1, 2)))
        assert [l.cc for l in lors] == [1, 2]
        assert lm.is_enabled(lors)
        # remote request opt-delivered -> own busy head blocked, not freed
        assert lm.on_opt_deliver(_req(2, 1, (2,))) == []
        freed = lm.finished_xact(lors)   # drain -> the blocked LOR frees
        assert _keys(freed) == [(1, 0, (2,))]
        lm.on_ur_deliver_freed(_keys(freed))
        lm.on_to_deliver(_req(2, 1, (2,)))
    assert a.owner_view() == b.owner_view()
    assert a.head_owner(2) == b.head_owner(2) == 1
    assert a.head_owner(1) == b.head_owner(1) == 0   # retained for reuse
    # piggyback parity: the retained cc=1 LOR is reusable, cc=2 is not
    assert a.try_piggyback(frozenset({1, 2})) is None
    assert b.try_piggyback(frozenset({1, 2})) is None
    assert a.try_piggyback(frozenset({1})) is not None
    assert b.try_piggyback(frozenset({1})) is not None


def _drive_replicated(mgr_sets, reqs_rounds, purge_at=None):
    """Replay rounds of requests through replicated manager sets in the
    protocol order (opt -> freed -> TO -> enable/finish -> freed), returning
    each set's observable trace.  ``purge_at`` injects a view change (node 1
    fails) before that round at every replica."""
    traces = []
    for mgrs in mgr_sets:
        n = len(mgrs)
        waiters = [[] for _ in mgrs]
        trace = {"freed": [], "finished": 0}

        def deliver(frees_by_node):
            keys = [k for fr in frees_by_node for k in _keys(fr)]
            trace["freed"].extend(keys)
            for m in mgrs:
                m.on_ur_deliver_freed(keys)

        for rnd, reqs in enumerate(reqs_rounds):
            if purge_at == rnd:
                for m in mgrs:
                    m.purge_proc(1)
                waiters[1] = []
            deliver([sum((m.on_opt_deliver(r) for r in reqs), [])
                     for m in mgrs])
            for p, m in enumerate(mgrs):
                for r in reqs:
                    lors = m.on_to_deliver(r)
                    if r.proc == p and lors:
                        waiters[p].append(lors)
            fin = []
            for p, m in enumerate(mgrs):
                done = [g for g in waiters[p] if m.is_enabled(g)]
                waiters[p] = [g for g in waiters[p] if not m.is_enabled(g)]
                trace["finished"] += len(done)
                fin.append(sum((m.finished_xact(g) for g in done), []))
            deliver(fin)
        trace["owners"] = [m.owner_view() for m in mgrs]
        traces.append(trace)
    return traces


def test_replicated_rounds_match_oracle_with_view_change():
    """Multi-round replicated run, including a mid-run purge_proc, keeps
    the two managers in lockstep (frees, finish counts, owner views)."""
    rng = np.random.default_rng(7)
    rounds, rid = [], 0
    for _ in range(6):
        reqs = []
        for _ in range(12):
            rid += 1
            ccs = rng.choice(10, size=int(rng.integers(1, 3)), replace=False)
            reqs.append(_req(rid, rid % 3, tuple(int(c) for c in ccs)))
        rounds.append(reqs)
    oracle, batched = _mgrs(3, 10, n_shards=2, jax_min=1)
    ta, tb = _drive_replicated([oracle, batched], rounds, purge_at=3)
    assert ta == tb


try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:          # pragma: no cover - CI installs hypothesis
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    @st.composite
    def _histories(draw):
        n_classes = draw(st.integers(2, 8))
        n_procs = draw(st.integers(2, 3))
        rounds = draw(st.lists(
            st.lists(st.sets(st.integers(0, n_classes - 1), min_size=1,
                             max_size=min(3, n_classes)),
                     min_size=1, max_size=6),
            min_size=1, max_size=5))
        purge_at = draw(st.one_of(st.none(),
                                  st.integers(0, len(rounds) - 1)))
        return n_classes, n_procs, rounds, purge_at

    @settings(max_examples=60, deadline=None)
    @given(_histories())
    def test_random_histories_match_oracle(hist):
        """Arbitrary replicated histories (multi-class requests, delayed
        frees, optional view change): the batched manager tracks the
        oracle exactly."""
        n_classes, n_procs, rounds, purge_at = hist
        rid = 0
        reqs_rounds = []
        for rnd in rounds:
            reqs = []
            for ccs in rnd:
                rid += 1
                reqs.append(_req(rid, rid % n_procs, tuple(ccs)))
            reqs_rounds.append(reqs)
        oracle, batched = _mgrs(n_procs, n_classes, n_shards=2, jax_min=1)
        ta, tb = _drive_replicated([oracle, batched], reqs_rounds,
                                   purge_at=purge_at)
        assert ta == tb


def test_purge_proc_removes_whole_requests():
    """S2 regression: a failed member's multi-class request vanishes from
    EVERY queue it sat in — no half-purged request may linger."""
    (a,), (b,) = _mgrs(1, 8, n_shards=2)
    for lm in (a, b):
        lm.on_to_deliver(_req(1, 1, (0, 3, 5)))
        mine = lm.on_to_deliver(_req(2, 0, (0, 5)))
        assert not lm.is_enabled(mine)
        lm.purge_proc(1)
        assert lm.is_enabled(mine)
        # late free of the purged request is a no-op, not a crash
        lm.on_ur_deliver_freed([(1, 1, (0, 3, 5))])
    assert a.owner_view() == b.owner_view()


# ---------------------------------------------------------------------------
# Kernel parity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", range(4))
def test_settle_kernel_matches_numpy(seed):
    """The jit'd settle_lease_batch and its numpy twin agree bitwise on
    random compact head states and waiter groups."""
    from repro.kernels import ops

    rng = np.random.default_rng(seed)
    C, B, K, proc = 16, 8, 4, 0
    qlen = rng.integers(0, 3, C).astype(np.int32)
    head_req = rng.integers(1, 6, C).astype(np.int32)
    head_proc = rng.integers(0, 3, C).astype(np.int32)
    head_active = rng.integers(0, 2, C).astype(np.int32)
    fresh = rng.random(C) < 0.4
    wait_req = rng.integers(1, 6, (B, K)).astype(np.int32)
    wait_cc = np.where(rng.random((B, K)) < 0.3, -1,
                       rng.integers(0, C, (B, K))).astype(np.int32)
    got = ops.settle_lease_batch(head_req, head_proc, head_active, qlen,
                                 fresh, wait_req, wait_cc, proc)
    want = _settle_np(head_req, head_proc, head_active, qlen, fresh,
                      wait_req, wait_cc, proc)
    for g, w in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), w)


# ---------------------------------------------------------------------------
# Full-simulation byte-equivalence + pipelined handoff
# ---------------------------------------------------------------------------

def _run_sim(mode, *, fail_at=None, jax_min=64, handoff="drain",
             duration=250.0, locality=0.6, seed=0):
    cfg = SimConfig(duration_ms=duration, warmup_ms=50.0, seed=seed,
                    lease_mode=mode, lease_jax_min=jax_min, handoff=handoff)
    wl = BankWorkload(n_nodes=cfg.n_nodes, n_items=cfg.n_items,
                      locality=locality)
    c = make_cluster("LILAC-TM-ST", wl, cfg)
    if fail_at is not None:
        c.events.schedule(fail_at, lambda: c.gcs.fail(3))
    freed = []
    orig = c.gcs.ur_broadcast

    def wrap(msg, *a, **k):
        freed.append(repr(msg))
        return orig(msg, *a, **k)

    c.gcs.ur_broadcast = wrap
    m = c.run()
    return dict(commits=m.commits, aborts=m.aborts, forwards=m.forwards,
                commit_times=tuple(m.commit_times), freed=tuple(freed),
                owners=[r.lm.owner_view() for r in c.replicas])


@pytest.mark.parametrize("kw", [
    dict(),
    dict(fail_at=120.0, jax_min=1),
])
def test_full_sim_batched_is_byte_identical(kw):
    """End to end: commits, aborts, forwards, commit times, the UR-broadcast
    freed stream and every replica's owner view match the sequential oracle
    — with and without a mid-run node failure."""
    assert _run_sim("sequential", **kw) == _run_sim("batched", **kw)


def test_pipelined_handoff_runs_batched_and_matches_oracle():
    """Zeus-style pipelined handoff composes with the batched control plane:
    the sim commits work and stays byte-identical to the sequential manager
    under the same handoff mode."""
    a = _run_sim("sequential", handoff="pipelined")
    b = _run_sim("batched", handoff="pipelined")
    assert a == b
    assert b["commits"] > 0


def test_batched_is_the_default_lease_mode():
    assert SimConfig().lease_mode == "batched"


# ---------------------------------------------------------------------------
# Satellite regressions: planner purge, router tombstones, engine eviction
# ---------------------------------------------------------------------------

def test_planner_purge_node_drops_ghost_state():
    """S1 regression: after a view change the planner keeps no trace of the
    dead node — no affinity pull toward it, no history entries gating live
    moves against it."""
    from repro.plan.planner import PlacementPlanner, PlanConfig

    p = PlacementPlanner(3, 8, PlanConfig(min_events=1.0))
    for t in (1.0, 2.0, 3.0):
        p.affinity.record_commit(t, 1, (2, 5))
        p.affinity.record_commit(t, 0, (3,))
    p._history.append((0, 2, 0, 1))      # class 2 moved 0 -> 1 (dead dst)
    p._history.append((0, 3, 1, 2))      # class 3 moved 1 -> 2 (dead src)
    p._history.append((0, 4, 0, 2))      # survivor entry
    p.purge_node(1)
    assert not p.affinity.node.counts[1].any()
    assert not p.affinity.aborts.counts[1].any()
    assert list(p._history) == [(0, 4, 0, 2)]
    p.purge_node(1)                      # idempotent (every replica calls it)
    assert list(p._history) == [(0, 4, 0, 2)]


def test_router_evict_tombstones_and_recycles():
    """S3 regression: an evicted sid's stale epoch can never certify again,
    and the recycled sid's first placement starts above the tombstone."""
    from repro.serve.certifier import StepCertifier
    from repro.serve.engine import Request
    from repro.serve.router import LocalityRouter

    r = LocalityRouter(2, policy="short")
    cert = StepCertifier(2, jax_min=1)
    dec = r.route(0, 5, 0)               # first placement
    cert.bump(5, dec.epoch)
    stale_epoch = dec.epoch
    tomb = r.evict(5)
    cert.purge(5)
    cert.bump(5, tomb)
    assert tomb > stale_epoch
    assert 5 not in r.lease_epoch        # live dict holds live sessions only
    # a forward of the dead tenancy still on the wire fails certification
    cert.enqueue(0, Request(sid=5, origin=1), stale_epoch)
    passed, aborted, _ = cert.drain(0)
    assert passed == [] and len(aborted) == 1
    # the recycled sid places above the tombstone: no aliasing possible
    dec2 = r.route(1, 5, 0)
    assert dec2.epoch > tomb >= stale_epoch


def test_router_compacts_stat_columns_after_mass_eviction():
    """S3 regression: a burst of high sids must not pin the per-session
    stat matrix after the sessions are gone (pow2 + 4x hysteresis)."""
    from repro.serve.router import LocalityRouter

    r = LocalityRouter(2, policy="short")
    for sid in range(1500):
        r.route(sid % 2, sid, 0)
    assert r.freq.n_cols >= 2048
    for sid in range(1, 1500):
        r.evict(sid)
    assert max(r.owner) == 0
    assert r.freq.n_cols <= 512          # shrunk back toward the floor
    # and the survivor's state is intact
    assert r.owner[0] in (0, 1)


def test_decayed_frequency_shrink_preserves_live_columns():
    from repro.core.stats import DecayedFrequency

    f = DecayedFrequency(2, 64, grow_cols=True)
    f.record(1.0, 0, (900,))
    f.record(1.0, 1, (3,))
    assert f.n_cols >= 1024
    f.shrink_to(4)
    assert f.n_cols == 64                # pow2(4) = 4, floored at 64
    assert f.counts[1, 3] > 0            # live column survived
    f2 = DecayedFrequency(2, 8)          # fixed width: shrink is a no-op
    f2.shrink_to(1)
    assert f2.n_cols == 8


def test_engine_evict_session_retires_everywhere():
    """S3 regression: evict_session drops the cache column, queued work and
    pending forwards, and a resubmitted (recycled) sid starts a fresh
    tenancy with an epoch above the tombstone."""
    from repro.configs import get_smoke_config
    from repro.serve.engine import MultiPodEngine, Request, SimBackend
    from repro.serve.router import LocalityRouter

    cfg = get_smoke_config("glm4-9b")
    eng = MultiPodEngine(2, SimBackend(cfg), LocalityRouter(2, policy="short"))
    eng.submit(Request(sid=7, origin=0, n_tokens=4))
    eng.run_step()
    eng.submit(Request(sid=7, origin=1, n_tokens=4))   # forward or acquire
    assert 7 in eng.session_home
    old_epoch = eng.router.lease_epoch[7]
    eng.evict_session(7)
    assert 7 not in eng.session_home and 7 not in eng.session_len
    assert all(all(r.sid != 7 for r in q) for q in eng.queues)
    assert not eng.certifier.has_pending()
    assert 7 not in eng.router.owner
    # recycled tenancy: placement epoch strictly above the old one
    dec = eng.submit(Request(sid=7, origin=1, n_tokens=2))
    assert dec.epoch > old_epoch
    eng.drain()
