"""Per-arch smoke tests (reduced configs): train step + decode consistency."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.configs.shapes import SHAPES, input_specs, skip_reason
from repro.models import decoder
from repro.models.common import init_params, layer_plan, param_shapes

CTX = decoder.RunCtx(mesh=None, use_kernel="ref")


def _batch(cfg, key, b=2, s=32):
    batch = {}
    if cfg.family in ("vlm", "audio"):
        batch["embeds"] = jax.random.normal(key, (b, s, cfg.d_model), jnp.float32)
    else:
        batch["tokens"] = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    if cfg.mrope_sections is not None:
        pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
        batch["positions"] = jnp.broadcast_to(pos[None], (3, b, s))
    batch["labels"] = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_shapes_and_finite(arch):
    cfg = get_smoke_config(arch)
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    batch = _batch(cfg, key)
    logits = decoder.forward(cfg, CTX, params, {k: v for k, v in batch.items()
                                                if k != "labels"})
    assert logits.shape == (2, 32, cfg.vocab_size)
    (loss, aux), grads = jax.value_and_grad(
        lambda p: decoder.loss_fn(cfg, CTX, p, batch), has_aux=True)(params)
    assert np.isfinite(float(loss))
    for g in jax.tree.leaves(grads):
        assert np.all(np.isfinite(np.asarray(g, np.float32)))


@pytest.mark.parametrize("arch", [a for a in ARCH_IDS
                                  if get_config(a).causal])
def test_decode_matches_forward(arch):
    """prefill(S) + decode(S) logits == forward(S+1) last-position logits."""
    cfg = dataclasses.replace(get_smoke_config(arch), dtype="float32")
    key = jax.random.PRNGKey(1)
    params = init_params(cfg, key)
    b, s = 2, 33
    batch = _batch(cfg, key, b=b, s=s)
    batch.pop("labels")
    full = decoder.forward(cfg, CTX, params, batch)

    def cut(x, n):
        if x.ndim == 3 and x.shape[0] == 3:     # mrope positions
            return x[:, :, :n]
        return x[:, :n]

    prompt = {k: cut(v, s - 1) for k, v in batch.items()}
    logits0, caches = decoder.prefill(cfg, CTX, params, prompt)
    np.testing.assert_allclose(
        np.asarray(logits0), np.asarray(full[:, s - 2]), rtol=2e-3, atol=2e-3)

    # move the prompt cache into a longer ring and take one decode step
    ring = decoder.init_cache(cfg, b, s + 4, jnp.float32)

    def merge(dst, src):
        if src is None:
            return dst
        return jax.lax.dynamic_update_slice(dst, src.astype(dst.dtype),
                                            (0,) * dst.ndim)

    caches = jax.tree.map(merge, ring, caches)
    if "tokens" in batch:
        tok = batch["tokens"][:, s - 1]
    else:
        tok = batch["embeds"][:, s - 1:s]
    logits1, _ = decoder.decode_step(
        cfg, CTX, params, caches, tok, jnp.asarray(s - 1, jnp.int32))
    np.testing.assert_allclose(
        np.asarray(logits1), np.asarray(full[:, s - 1]), rtol=2e-3, atol=2e-3)


def test_decode_vector_positions_match_scalar():
    """Continuous batching: per-row pos == scalar pos when rows align."""
    cfg = dataclasses.replace(get_smoke_config("glm4-9b"), dtype="float32")
    key = jax.random.PRNGKey(2)
    params = init_params(cfg, key)
    b, s = 3, 16
    batch = _batch(cfg, key, b=b, s=s)
    batch.pop("labels")
    _, caches = decoder.prefill(cfg, CTX, params, batch)
    ring = decoder.init_cache(cfg, b, s + 4, jnp.float32)
    caches = jax.tree.map(
        lambda d, c: d if c is None else jax.lax.dynamic_update_slice(
            d, c.astype(d.dtype), (0,) * d.ndim), ring, caches)
    tok = jnp.asarray([1, 2, 3], jnp.int32)
    l_scalar, _ = decoder.decode_step(cfg, CTX, params, caches, tok,
                                      jnp.asarray(s, jnp.int32))
    l_vec, _ = decoder.decode_step(cfg, CTX, params, caches, tok,
                                   jnp.full((b,), s, jnp.int32))
    np.testing.assert_allclose(np.asarray(l_scalar), np.asarray(l_vec),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_shapes_consistent(arch):
    """Full configs: layer plan covers n_layers; param tree constructible."""
    cfg = get_config(arch)
    plan = layer_plan(cfg)
    assert plan.prefix + plan.period * plan.n_groups + plan.suffix == cfg.n_layers
    shapes = param_shapes(cfg, model_size=16)
    n = sum(int(np.prod(s)) for s in jax.tree.leaves(
        shapes, is_leaf=lambda s: isinstance(s, tuple)))
    assert n > 0
    # headline parameter counts are in the right ballpark
    expected = {
        "qwen2-vl-2b": (1.2e9, 2.6e9), "glm4-9b": (8e9, 10.5e9),
        "phi4-mini-3.8b": (3.0e9, 4.6e9), "minitron-4b": (3.6e9, 5.0e9),
        "gemma3-27b": (2.2e10, 3.0e10), "deepseek-v2-236b": (2.1e11, 2.5e11),
        "mixtral-8x7b": (4.2e10, 5.0e10), "hubert-xlarge": (0.8e9, 1.3e9),
        "mamba2-780m": (6.5e8, 9.5e8), "zamba2-1.2b": (1.0e9, 1.6e9),
    }[arch]
    assert expected[0] < cfg.param_count() < expected[1], cfg.param_count()


def test_input_specs_grid():
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape in SHAPES:
            if skip_reason(arch, cfg, shape):
                continue
            specs = input_specs(cfg, shape)
            assert specs, (arch, shape)
            for v in specs.values():
                assert isinstance(v, jax.ShapeDtypeStruct)
