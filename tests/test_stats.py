"""Unit tests of the DTD input statistics (:mod:`repro.core.stats`)."""
import math

import pytest

from repro.core.stats import CpuMeter, DecayedFrequency


def test_cpu_meter_steady_state_tracks_busy_fraction():
    """With one of two slots held, utilization converges to 0.5."""
    m = CpuMeter(n_slots=2, tau_ms=10.0)
    m.acquire(0.0)
    assert m.utilization(50 * m.tau) == pytest.approx(0.5, abs=1e-3)


def test_cpu_meter_counts_extra_load_once():
    """Fig-3c regression: injected load must raise utilization by exactly
    ``extra_load``, not 2x it — the old code folded it into the EWMA target
    *and* re-added it in ``utilization()``, so the constraint-(3) valve read
    ~2x the injection and tripped at ~half the configured max_cpu."""
    m = CpuMeter(n_slots=2, tau_ms=10.0)
    m.acquire(0.0)                      # busy fraction 0.5
    m.extra_load = 0.2                  # inject background jobs
    u = m.utilization(50 * m.tau)       # many tau: EWMA fully converged
    assert u == pytest.approx(0.7, abs=1e-3)   # 0.5 + 0.2, NOT 0.9


def test_cpu_meter_extra_load_saturates_at_one():
    m = CpuMeter(n_slots=1, tau_ms=5.0)
    m.acquire(0.0)
    m.extra_load = 0.95
    assert m.utilization(100 * m.tau) == pytest.approx(1.0)


def test_cpu_meter_release_decays_back():
    m = CpuMeter(n_slots=1, tau_ms=10.0)
    m.acquire(0.0)
    m.release(20 * m.tau)
    assert m.utilization(40 * m.tau) < 0.2


def test_decayed_frequency_rate_and_decay():
    f = DecayedFrequency(n_nodes=2, n_classes=1, tau_ms=100.0)
    for _ in range(10):
        f.record(0.0, 0, (0,))
    hot = f.rates(0.0)[0, 0]
    assert hot == pytest.approx(10 / 100.0)
    cold = f.rates(10 * f.tau)[0, 0]
    assert cold < 1e-3 * hot
